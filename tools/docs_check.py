#!/usr/bin/env python
"""Docs consistency gate (the CI ``docs-check`` step).

Five checks, all stdlib + repro only:

1. **Backend support matrix** — the table tagged
   ``<!-- docs-check:backend-matrix -->`` in ``docs/backends.md`` must
   have one row per *registered* index kind (``registry.kinds()``) and
   one column per query backend (``repro.index.BACKENDS``), every cell
   non-empty.  Registering a new kind or backend without documenting it
   fails CI — the matrix can never silently rot.
2. **Analysis rule catalogue** — the table tagged
   ``<!-- docs-check:analysis-rules -->`` in ``docs/analysis.md`` must
   have one row per registered rule in ``tools.analysis.ALL_RULES``
   (matching id and title, non-empty description) — adding a rule
   without documenting it fails CI, same deal as the backend matrix.
3. **Metric catalogue** — the table tagged
   ``<!-- docs-check:metric-catalogue -->`` in
   ``docs/observability.md`` must have one row per metric in
   ``repro.obs.metric_catalogue()`` with the matching type and label
   set — register a metric, document it, or CI fails.
4. **Fit-mode matrix** — the table tagged
   ``<!-- docs-check:fit-modes -->`` in ``docs/build_pipeline.md``
   must have one row per registered kind and one column per build fit
   capability (``host``, ``vmap``, ``fast``, ``device refresh``), and
   each cell's support claim (anything not starting with ``n/a``)
   must match the live capability tuples ``repro.tune.VMAP_KINDS`` /
   ``FAST_KINDS`` / ``DEVICE_REFRESH_KINDS`` — documenting a fit mode
   the code does not register (or vice versa) fails CI.
5. **Links and anchors** — every relative markdown link in README.md
   and docs/*.md must resolve to an existing file, and ``#anchor``
   fragments must match a heading in the target (GitHub slugification).

Run from the repo root::

    PYTHONPATH=src python tools/docs_check.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
MATRIX_TAG = "<!-- docs-check:backend-matrix -->"
RULES_TAG = "<!-- docs-check:analysis-rules -->"
METRICS_TAG = "<!-- docs-check:metric-catalogue -->"
FIT_MODES_TAG = "<!-- docs-check:fit-modes -->"
LINK_RE = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def parse_matrix(md_text: str, tag: str = MATRIX_TAG):
    """The first markdown table after ``tag``: (columns, {row: cells})."""
    try:
        tail = md_text.split(tag, 1)[1]
    except IndexError:
        raise ValueError(f"document is missing the {tag!r} tag")
    lines = [ln.strip() for ln in tail.splitlines()]
    rows = [ln for ln in lines if ln.startswith("|")]
    if len(rows) < 3:
        raise ValueError("backend matrix table not found after the docs-check tag")
    split = lambda ln: [c.strip() for c in ln.strip("|").split("|")]
    header = split(rows[0])
    body = {}
    for ln in rows[2:]:  # rows[1] is the |---| separator
        cells = split(ln)
        if len(cells) != len(header):
            raise ValueError(f"matrix row has {len(cells)} cells, header has {len(header)}: {ln}")
        body[cells[0]] = dict(zip(header[1:], cells[1:]))
    return header[1:], body


def check_backend_matrix() -> list:
    from repro.index import BACKENDS, registry

    errors = []
    columns, rows = parse_matrix((ROOT / "docs" / "backends.md").read_text())
    for backend in BACKENDS:
        if backend not in columns:
            errors.append(f"backend {backend!r} missing from the docs/backends.md matrix columns")
    for kind in registry.kinds():
        if kind not in rows:
            errors.append(f"registered kind {kind!r} has no row in the docs/backends.md matrix")
            continue
        for backend in BACKENDS:
            if backend in columns and not rows[kind].get(backend):
                errors.append(f"matrix cell ({kind}, {backend}) is empty")
    for kind in rows:
        if kind not in registry.kinds():
            errors.append(f"matrix documents unregistered kind {kind!r}")
    return errors


def check_analysis_rules() -> list:
    """docs/analysis.md's catalogue table rows == tools.analysis.ALL_RULES."""
    if str(ROOT) not in sys.path:
        sys.path.insert(0, str(ROOT))
    from tools.analysis import rule_catalogue

    errors = []
    try:
        columns, rows = parse_matrix((ROOT / "docs" / "analysis.md").read_text(), RULES_TAG)
    except (OSError, ValueError) as e:
        return [f"docs/analysis.md rule catalogue: {e}"]
    registered = rule_catalogue()
    for rid, title, _blurb in registered:
        if rid not in rows:
            errors.append(f"rule {rid!r} ({title}) has no row in the docs/analysis.md catalogue")
            continue
        cells = rows[rid]
        doc_title = cells.get(columns[0], "") if columns else ""
        if doc_title != title:
            errors.append(
                f"catalogue row {rid!r} titles the rule {doc_title!r}; the code says {title!r}"
            )
        if not all(cells.values()):
            errors.append(f"catalogue row {rid!r} has an empty cell")
    known = {rid for rid, _, _ in registered}
    for rid in rows:
        if rid not in known:
            errors.append(f"catalogue documents unknown rule {rid!r}")
    return errors


def check_metric_catalogue() -> list:
    """docs/observability.md's metric table rows == repro.obs catalogue."""
    from repro.obs import metric_catalogue

    errors = []
    try:
        columns, rows = parse_matrix(
            (ROOT / "docs" / "observability.md").read_text(), METRICS_TAG
        )
    except (OSError, ValueError) as e:
        return [f"docs/observability.md metric catalogue: {e}"]
    registered = metric_catalogue()
    for name, mtype, labels, _desc in registered:
        if name not in rows:
            errors.append(f"metric {name!r} has no row in the docs/observability.md catalogue")
            continue
        cells = rows[name]
        doc_type = cells.get("type", "")
        if doc_type != mtype:
            errors.append(f"metric {name!r} documented as {doc_type!r}; the code says {mtype!r}")
        doc_labels = cells.get("labels", "").replace("`", "")
        want_labels = ", ".join(labels) if labels else "-"
        if doc_labels != want_labels:
            errors.append(
                f"metric {name!r} documents labels {doc_labels!r}; the code says {want_labels!r}"
            )
        if not all(cells.values()):
            errors.append(f"metric catalogue row {name!r} has an empty cell")
    known = {name for name, _, _, _ in registered}
    for name in rows:
        if name not in known:
            errors.append(f"metric catalogue documents unregistered metric {name!r}")
    return errors


def check_fit_modes() -> list:
    """docs/build_pipeline.md's fit-mode matrix == the live capability
    tuples: a cell not starting with ``n/a`` claims support, and the
    claim set per column must equal the corresponding registry tuple
    (``host`` = every registered kind)."""
    from repro.index import registry
    from repro.tune import DEVICE_REFRESH_KINDS, FAST_KINDS, VMAP_KINDS

    errors = []
    try:
        columns, rows = parse_matrix(
            (ROOT / "docs" / "build_pipeline.md").read_text(), FIT_MODES_TAG
        )
    except (OSError, ValueError) as e:
        return [f"docs/build_pipeline.md fit-mode matrix: {e}"]
    kinds = registry.kinds()
    capability = {
        "host": tuple(kinds),
        "vmap": VMAP_KINDS,
        "fast": FAST_KINDS,
        "device refresh": DEVICE_REFRESH_KINDS,
    }
    for col in capability:
        if col not in columns:
            errors.append(f"fit-mode matrix is missing the {col!r} column")
    for kind in kinds:
        if kind not in rows:
            errors.append(f"registered kind {kind!r} has no row in the fit-mode matrix")
            continue
        for col, supported in capability.items():
            cell = rows[kind].get(col, "")
            if not cell:
                errors.append(f"fit-mode matrix cell ({kind}, {col}) is empty")
                continue
            claims = not cell.lower().startswith("n/a")
            if claims and kind not in supported:
                errors.append(
                    f"fit-mode matrix claims {col!r} support for {kind!r}; the code "
                    f"registers {supported}"
                )
            if not claims and kind in supported:
                errors.append(
                    f"fit-mode matrix marks ({kind}, {col}) n/a; the code registers "
                    f"{kind!r} in {supported}"
                )
    for kind in rows:
        if kind not in kinds:
            errors.append(f"fit-mode matrix documents unregistered kind {kind!r}")
    return errors


def slugify(heading: str) -> str:
    """GitHub-style heading -> anchor slug."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def doc_files() -> list:
    return [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))


def check_links() -> list:
    errors = []
    anchors = {}  # path -> set of slugs

    def anchors_of(path: Path):
        if path not in anchors:
            anchors[path] = {slugify(h) for h in HEADING_RE.findall(path.read_text())}
        return anchors[path]

    for doc in doc_files():
        rel = doc.relative_to(ROOT)
        for target in LINK_RE.findall(doc.read_text()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, fragment = target.partition("#")
            dest = doc if not path_part else (doc.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{rel}: broken link -> {target}")
                continue
            if fragment and dest.suffix == ".md":
                if slugify(fragment) not in anchors_of(dest):
                    errors.append(f"{rel}: broken anchor -> {target}")
    return errors


def main() -> int:
    errors = (
        check_backend_matrix()
        + check_analysis_rules()
        + check_metric_catalogue()
        + check_fit_modes()
        + check_links()
    )
    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    if errors:
        print(f"docs-check: FAILED ({len(errors)} problem(s))", file=sys.stderr)
        return 1
    n_docs = len(doc_files())
    print(f"docs-check: OK ({n_docs} files, matrices cover the registries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
