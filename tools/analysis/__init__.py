"""repro.analysis — project-specific static-analysis pass.

Eight rule families, each grounded in a bug this repo actually shipped
(or a contract a past PR had to retrofit):

====  =========================  ==================================================
R1    salted-hash seeding        PR 5: ``seed + hash(name)`` made bench tables
                                 non-reproducible across processes
R2    unclamped kernel cast      PR 1: out-of-range f32→i32 in the RMI kernel
                                 survived the later window clip
R3    trace discipline           python branches on traced args, concretizing
                                 tracers, mutable-global capture in jitted code
R4    registry/pytree contract   registered kinds must grid/stack/account —
                                 the code analogue of docs_check's docs matrix
R5    magic sentinel literal     raw ``-2``/``-1`` where DROPPED/NO_PRED exist
R6    f64 in kernel body         TPU kernels are f32/i32; f64 belongs on the host
R7    removed-API resurrection   the mutation-API redesign deleted the PR 1
                                 shims; this keeps the old names gone
R8    raw timing outside obs     PR 8 unified telemetry in repro.obs; ad-hoc
                                 ``perf_counter`` deltas bypass its histograms
====  =========================  ==================================================

Run ``python -m tools.analysis --check`` (CI gate), or pass explicit
files to scan fixtures hermetically (project rules are skipped then).
"""

from __future__ import annotations

from .framework import (  # noqa: F401  (re-exported API)
    BASELINE_PATH,
    DEFAULT_ROOTS,
    REPO_ROOT,
    AstRule,
    Finding,
    Module,
    ProjectRule,
    Rule,
    iter_py_files,
    load_baseline,
    report_json,
    run_rules,
    split_by_baseline,
)
from .rules_hash import SaltedHashRule
from .rules_casts import UnclampedCastRule
from .rules_trace import TraceDisciplineRule
from .rules_contract import RegistryContractRule
from .rules_sentinel import MagicSentinelRule
from .rules_f64 import KernelF64Rule
from .rules_removed import RemovedApiRule
from .rules_time import RawTimingRule

#: the registered pass, in rule-id order
ALL_RULES = (
    SaltedHashRule(),
    UnclampedCastRule(),
    TraceDisciplineRule(),
    RegistryContractRule(),
    MagicSentinelRule(),
    KernelF64Rule(),
    RemovedApiRule(),
    RawTimingRule(),
)


def rule_catalogue():
    """(id, title, blurb) rows — the source of truth docs_check verifies
    ``docs/analysis.md``'s table against."""
    return [(r.id, r.title, r.blurb) for r in ALL_RULES]


def analyze_paths(paths, *, root=REPO_ROOT, project=False, rules=ALL_RULES):
    """Analyze explicit files (fixtures/tests).  Project rules off by
    default so the run has no import-time dependency on jax."""
    return run_rules(list(paths), list(rules), root=root, project=project)


def analyze_tree(*, root=REPO_ROOT, project=True, rules=ALL_RULES):
    """Full-tree scan: every .py under DEFAULT_ROOTS + project rules."""
    files = iter_py_files(root)
    return files, run_rules(files, list(rules), root=root, project=project)
