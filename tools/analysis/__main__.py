"""CLI: ``python -m tools.analysis [paths...] [--check] [--json OUT]``.

Exit codes: 0 clean (or baselined), 1 new findings (with ``--check``),
2 usage/parse trouble.  Without ``--check`` findings are printed but the
exit code stays 0 — the exploratory mode.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import (
    ALL_RULES,
    BASELINE_PATH,
    REPO_ROOT,
    analyze_paths,
    analyze_tree,
    load_baseline,
    report_json,
    split_by_baseline,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="project-specific static analysis (JAX/Pallas invariant linter)",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="explicit files/dirs to scan (default: tree-wide scan of "
        "src/ benchmarks/ examples/ tools/ tests/)",
    )
    ap.add_argument(
        "--check",
        action="store_true",
        help="exit 1 on any non-baselined finding (and on stale baseline entries)",
    )
    ap.add_argument("--json", metavar="OUT", help="write the machine-readable report here")
    ap.add_argument(
        "--baseline",
        metavar="PATH",
        default=None,
        help=f"suppression baseline (default {BASELINE_PATH.relative_to(REPO_ROOT)})",
    )
    ap.add_argument(
        "--no-baseline",
        action="store_true",
        help="ignore the baseline: report every finding",
    )
    ap.add_argument(
        "--no-project",
        action="store_true",
        help="skip project rules (R4) even on tree-wide scans",
    )
    ap.add_argument("--list-rules", action="store_true", help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.id}  {r.title}\n    {r.blurb}")
        return 0

    if args.paths:
        files = []
        for p in map(Path, args.paths):
            if p.is_dir():
                files.extend(sorted(p.rglob("*.py")))
            elif p.exists():
                files.append(p)
            else:
                print(f"error: no such path: {p}", file=sys.stderr)
                return 2
        # explicit paths: hermetic — project rules never run
        findings = analyze_paths(files, project=False)
    else:
        files, findings = analyze_tree(project=not args.no_project)

    entries = [] if args.no_baseline else load_baseline(Path(args.baseline) if args.baseline else None)
    new, suppressed, stale = split_by_baseline(findings, entries)

    report = report_json(new, suppressed, stale, list(ALL_RULES), len(files))
    if args.json:
        Path(args.json).write_text(json.dumps(report, indent=2) + "\n")

    for f in new:
        print(f.format())
    if suppressed:
        print(f"-- {len(suppressed)} baselined finding(s) suppressed", file=sys.stderr)
    for e in stale:
        print(
            f"stale baseline entry: [{e.get('rule')}] {e.get('path')}: "
            f"{e.get('snippet') or e.get('message')}",
            file=sys.stderr,
        )
    parse_failures = [f for f in new if f.rule == "PARSE"]
    print(
        f"{len(files)} file(s), {len(new)} new finding(s), "
        f"{len(suppressed)} baselined, {len(stale)} stale baseline entr"
        f"{'y' if len(stale) == 1 else 'ies'}",
        file=sys.stderr,
    )
    if parse_failures:
        return 2
    if args.check and (new or stale):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
