"""R2 — unclamped narrowing casts in Pallas kernel bodies (PR 1 bug class).

An out-of-range ``f32 -> i32`` cast is implementation-defined garbage on
every backend, and the garbage *survives* later ``jnp.clip`` calls: the
PR 1 bug was an RMI root prediction blowing up to ``|p| ~ 1e15`` on key
gaps, casting to a nonsense i32, and the later window clip happily
clamping nonsense into a plausible-looking (wrong) search window.  The
fix — and the invariant this rule enforces — is a *dominating* clamp
(``clip`` / ``minimum`` / ``maximum``) applied to the float value BEFORE
the cast (``kernels/rmi_search.py``: ``jnp.clip(p_root, -1e9, 1e9)``).

Scope: kernel-context functions (see ``astutil.is_kernel_context``) in
``kernels/`` modules.  Boolean-shaped values (limb compares) cast to i32
are fine — that's the branch-free select idiom.
"""

from __future__ import annotations

import ast

from .framework import AstRule, Module
from . import astutil

_INT_DTYPES = {"int32", "int64", "int16", "int8", "i32", "i64"}
_HINT = (
    "clamp the float value before the cast — jnp.clip(pred, -1.0e9, 1.0e9) "
    "(the rmi_search.py idiom); clipping after .astype(int32) cannot undo an "
    "out-of-range cast"
)


def _int_dtype_arg(call: ast.Call) -> bool:
    if not call.args:
        return False
    arg = call.args[0]
    if isinstance(arg, ast.Attribute):
        return arg.attr in _INT_DTYPES
    if isinstance(arg, ast.Name):
        return arg.id in _INT_DTYPES
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return arg.value in _INT_DTYPES
    return False


def _float_evidence(node) -> bool:
    """Only flag receivers that plausibly carry a float *prediction*:
    floor/ceil/round of something, or arithmetic mentioning a float
    literal.  Plain int-valued gathers/counters cast to i32 stay quiet."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and astutil.call_name(sub) in (
            "floor",
            "ceil",
            "round",
            "rint",
        ):
            return True
        if isinstance(sub, ast.Constant) and isinstance(sub.value, float):
            return True
        if isinstance(sub, ast.Call) and astutil.call_name(sub) == "astype":
            # x.astype(f32) re-entering an int cast chain
            if sub.args and not _int_dtype_arg(sub):
                return True
    return False


class UnclampedCastRule(AstRule):
    id = "R2"
    title = "unclamped kernel cast"
    blurb = (
        "`.astype(int32)` on an unclamped float inside a Pallas kernel body — "
        "out-of-range f32→i32 is garbage that survives later clips"
    )

    def check_module(self, mod: Module):
        bool_funcs = astutil.module_bool_functions(mod.tree)
        for fn in ast.walk(mod.tree):
            if not astutil.is_kernel_context(fn, mod.rel):
                continue
            classes = astutil.ValueClasses(fn, bool_funcs, float_pred=_float_evidence)
            yield from self._check_fn(mod, fn, classes)

    def _check_fn(self, mod: Module, fn, classes):
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
                continue
            if node.func.attr != "astype" or not _int_dtype_arg(node):
                continue
            recv = node.func.value
            if classes.is_boolish(recv) or classes.is_clamped(recv):
                continue
            if not classes.is_floaty(recv):
                continue
            yield mod.finding(
                self.id,
                node,
                f"float->int cast without a dominating clamp in kernel body "
                f"`{fn.name}` — out-of-range f32->i32 is undefined garbage "
                f"that later clips cannot repair",
                _HINT,
            )
