"""R6 — f64 literals / dtypes inside Pallas kernel bodies.

TPU vector units have no f64: a ``float64`` dtype reaching a Pallas body
either fails lowering on real hardware or silently runs in interpret
mode only — and this repo's contract is that ALL in-kernel arithmetic is
f32/i32 over u32 limb pairs, with any f64 precision work done **once on
the host at build time** (``kernels/ops.py`` pre-normalises the CDF
coordinate in f64 and re-measures ε with the kernel's exact f32
arithmetic).  A kernel-body f64 is always a porting mistake.
"""

from __future__ import annotations

import ast

from .framework import AstRule, Module
from . import astutil

_F64_NAMES = {"float64", "f64", "double", "complex128"}
_HINT = (
    "kernels are f32/i32 over u32 limbs; do f64 work on the host at build "
    "time (kernels/ops.py idiom) and pass pre-normalised f32 arrays in"
)


class KernelF64Rule(AstRule):
    id = "R6"
    title = "f64 in kernel body"
    blurb = (
        "float64 literals/dtypes inside a Pallas kernel body — TPUs have no "
        "f64; precision work belongs on the host at build time"
    )

    def check_module(self, mod: Module):
        for fn in ast.walk(mod.tree):
            if not astutil.is_kernel_context(fn, mod.rel):
                continue
            for node in ast.walk(fn):
                hit = None
                if isinstance(node, ast.Attribute) and node.attr in _F64_NAMES:
                    hit = node.attr
                elif isinstance(node, ast.Name) and node.id in _F64_NAMES:
                    hit = node.id
                elif (
                    isinstance(node, ast.Constant)
                    and isinstance(node.value, str)
                    and node.value in _F64_NAMES
                ):
                    # .astype("float64") / dtype="float64"
                    parent = getattr(node, "_parent", None)
                    in_dtype_pos = isinstance(parent, ast.Call) or (
                        isinstance(parent, ast.keyword) and parent.arg in ("dtype", None)
                    )
                    if in_dtype_pos:
                        hit = node.value
                if hit:
                    yield mod.finding(
                        self.id,
                        node,
                        f"`{hit}` inside kernel body `{fn.name}` — TPU kernels "
                        f"have no f64 (lowering failure or interpret-only)",
                        _HINT,
                    )
