"""R4 — registry / pytree contract (project rule).

The decorator registry (``repro.index.registry``) made adding an index
kind a one-decorator affair — which also made it easy to add a kind that
*looks* registered but violates the contracts every composite path
assumes.  ``tools/docs_check.py`` already guards the docs matrix; this
rule extends the same idea from docs into code, by importing the live
registry and probing each registered kind:

* the spec class round-trips through ``spec_for(kind)`` and contributes
  a non-empty ``default_grid`` of registered specs (the Pareto tuner's
  enrolment contract);
* a :class:`~repro.index.impls.QueryImpl` exists with ``intervals``
  and ``space_bytes``; its ``backends`` honesty tuple is a subset of
  ``BACKENDS``, and ``pallas``/``pallas_batched`` are required exactly
  when the kind *claims* the ``"pallas"`` backend (GAPPED legitimately
  claims only ``xla``/``bbs``/``ref``);
* ``BATCH_BACKENDS`` == ``TIER_BACKENDS`` ⊆ ``BACKENDS`` — a backend
  claimed by the batched builder must be claimable by the sharded tier
  and known to ``Index.lookup``;
* the **stacking probe**: the kind builds on two small tables of
  different hardness and ``stack_indexes`` accepts the pair — i.e. every
  *data-dependent* static (bucketed trip counts) is declared in
  ``_STEP_KEYS`` (or harmonised, like PGM ``levels``); a new kind whose
  trip-count static is missing from ``_STEP_KEYS`` fails here instead of
  deep inside a tier refresh;
* ``space_bytes() <= nbytes()`` on the built artifact (the PR 3
  model-constituent accounting invariant);
* the **fit-mode probe**: the batched-build capability ladder must
  nest — ``DEVICE_REFRESH_KINDS ⊆ FAST_KINDS ⊆ VMAP_KINDS ⊆ kinds()``
  (a kind cannot claim the O(log n) fast fit without the scan fallback
  the fast path re-fits with, nor a device refresh without a fast
  fit) — and each FAST kind's corridor fit honours the verified-ε
  contract: ``ok`` on a well-conditioned probe table, ``ok == False``
  on f64-colliding keys (the NaN veto that triggers the lazy scan
  fallback);
* the **mutation probe**: every kind in ``updatable_kinds()`` must
  absorb/overflow an insert batch with a coherent
  :class:`~repro.index.mutation.InsertReport`, stay bit-exact against
  ``searchsorted`` on the merged keyset (including with a non-empty
  delta, where ``space_bytes() <= nbytes()`` must still hold), and
  drain the delta on ``compact()``; every *static* kind must raise
  ``TypeError`` from ``insert_batch`` (the capability is per-kind, not
  assumed).

Runs only on full-tree scans (it imports jax); findings anchor at the
registration site ``src/repro/index/impls.py``.
"""

from __future__ import annotations

import sys
from pathlib import Path

from .framework import Finding, ProjectRule

_ANCHOR = "src/repro/index/impls.py"


def _finding(message: str, hint: str = "") -> Finding:
    return Finding(
        rule="R4",
        path=_ANCHOR,
        line=1,
        col=0,
        message=message,
        hint=hint,
        snippet=message,  # project findings fingerprint on the message
    )


def _probe_mutation(kind, idx, table, np):
    """Exercise the ``insert_batch``/``compact`` lifecycle of one
    updatable kind against searchsorted ground truth on the merged keys."""
    rng = np.random.default_rng(17)
    fresh = np.setdiff1d(
        np.unique(rng.integers(1, int(table.max()), size=96, dtype=np.uint64)),
        table,
    )
    if not len(fresh):  # pragma: no cover - 96 draws over a huge range
        return
    try:
        idx2, rep = idx.insert_batch(fresh)
    except Exception as e:
        yield _finding(f"kind {kind!r}: insert_batch raised {e!r} on a small in-range batch")
        return
    if rep.requested != len(fresh) or rep.absorbed + rep.overflowed + rep.duplicates != rep.requested:
        yield _finding(
            f"kind {kind!r}: InsertReport does not add up "
            f"(requested={rep.requested}, absorbed={rep.absorbed}, "
            f"overflowed={rep.overflowed}, duplicates={rep.duplicates})"
        )
    merged = np.union1d(table, fresh)
    queries = np.concatenate([merged, fresh + np.uint64(1)])
    truth = np.searchsorted(merged, queries, side="right") - 1
    for be in idx2.backends():
        got = np.asarray(idx2.lookup(table, queries, backend=be))
        if not np.array_equal(got, truth):
            yield _finding(
                f"kind {kind!r}: post-insert lookup (backend {be!r}) disagrees "
                f"with searchsorted on the merged keyset"
            )
    if rep.delta_count > 0:
        sb, nb = idx2.space_bytes(), idx2.nbytes()
        if not (0 < sb <= nb):
            yield _finding(
                f"kind {kind!r}: space_bytes()={sb} outside (0, nbytes()={nb}] "
                f"with a non-empty delta buffer"
            )
    try:
        idx3 = idx2.compact()
    except Exception as e:
        yield _finding(f"kind {kind!r}: compact() raised {e!r}")
        return
    if "delta_count" in idx3.arrays and int(np.asarray(idx3.arrays["delta_count"]).sum()):
        yield _finding(f"kind {kind!r}: compact() left a non-empty delta buffer")
    got = np.asarray(idx3.lookup(table, queries, backend="xla"))
    if not np.array_equal(got, truth):
        yield _finding(
            f"kind {kind!r}: post-compact lookup disagrees with searchsorted "
            f"on the merged keyset"
        )


class RegistryContractRule(ProjectRule):
    id = "R4"
    title = "registry/pytree contract"
    blurb = (
        "every registered kind must define a usable default_grid/space_bytes, "
        "stack through the `_STEP_KEYS` machinery, and back every claimed "
        "backend (BATCH_BACKENDS/TIER_BACKENDS ⊆ BACKENDS)"
    )

    def _check_fit_modes(self, kinds, registry, np):
        """The batched-build capability ladder and the fit="fast"
        verified-ε contract, probed against the live registry."""
        try:
            from repro.core.pgm import pgm_fit_fast
            from repro.core.radix_spline import rs_knots_fast
            from repro.tune.batched import FAST_KINDS, VMAP_KINDS
            from repro.tune.device_fit import DEVICE_REFRESH_KINDS
        except Exception as e:  # pragma: no cover - partial tree
            yield _finding(f"fit-mode probe could not import repro.tune ({e!r})")
            return

        ladder = (
            ("DEVICE_REFRESH_KINDS", DEVICE_REFRESH_KINDS, "FAST_KINDS", FAST_KINDS),
            ("FAST_KINDS", FAST_KINDS, "VMAP_KINDS", VMAP_KINDS),
            ("VMAP_KINDS", VMAP_KINDS, "registry.kinds()", kinds),
        )
        for lo_name, lo, hi_name, hi in ladder:
            extra = set(lo) - set(hi)
            if extra:
                yield _finding(
                    f"{lo_name} claims kind(s) {sorted(extra)} outside {hi_name} "
                    f"— the fit capability ladder must nest (a fast fit needs "
                    f"the scan fallback; a device refresh needs a fast fit)"
                )

        # verified-ε contract per fast corridor fit (by query_key: PGM_M
        # produces PGM-shaped indexes and shares PGM's fit)
        fits = {"pgm": pgm_fit_fast, "rs": rs_knots_fast}
        well = np.arange(1, 513, dtype=np.uint64) * np.uint64(977)
        # adjacent u64 keys at 2^60 collide after the f64 cast
        colliding = (np.uint64(1) << np.uint64(60)) + np.arange(512, dtype=np.uint64)
        for kind in FAST_KINDS:
            if kind not in kinds:
                continue  # already reported by the ladder check
            fit = fits.get(registry.entry(kind).query_key)
            if fit is None:
                yield _finding(
                    f"kind {kind!r} is in FAST_KINDS but no fast corridor fit "
                    f"is known for its query_key — wire it in repro.tune.batched"
                )
                continue
            try:
                _, ok_good = fit(well.astype(np.float64), 32.0)
                _, ok_bad = fit(colliding.astype(np.float64), 32.0)
            except Exception as e:
                yield _finding(f"kind {kind!r}: fast fit probe raised {e!r}")
                continue
            if not bool(ok_good):
                yield _finding(
                    f"kind {kind!r}: fast fit returned ok=False on a "
                    f"well-conditioned table — every fit='fast' build would "
                    f"silently pay the scan fallback"
                )
            if bool(ok_bad):
                yield _finding(
                    f"kind {kind!r}: fast fit returned ok=True on f64-colliding "
                    f"keys — the verified-ε re-measure lost its NaN veto and "
                    f"invalid models would install",
                )

    def check_project(self, root: Path):
        src = root / "src"
        if str(src) not in sys.path:
            sys.path.insert(0, str(src))
        try:
            import numpy as np

            from repro.index import BACKENDS, registry
            from repro.index.impls import query_impl
            from repro.index.mutation import updatable_kinds
            from repro.dist.sharded_index import _STEP_KEYS, _harmonize, stack_indexes
            from repro.tune.batched import BATCH_BACKENDS
            from repro.dist.sharded_index import TIER_BACKENDS
            from repro.data import distributions
        except Exception as e:  # pragma: no cover - container without jax
            yield _finding(
                f"registry contract probe could not import repro ({e!r})",
                "run from the repo root with the package installed (pip install -e .)",
            )
            return

        kinds = registry.kinds()
        if not kinds:
            yield _finding("registry is empty — no index kind registered")
            return

        # --- backend claims ---
        for name, claimed in (("BATCH_BACKENDS", BATCH_BACKENDS), ("TIER_BACKENDS", TIER_BACKENDS)):
            extra = set(claimed) - set(BACKENDS)
            if extra:
                yield _finding(
                    f"{name} claims backend(s) {sorted(extra)} unknown to "
                    f"repro.index.BACKENDS {tuple(BACKENDS)}"
                )
        if set(BATCH_BACKENDS) != set(TIER_BACKENDS):
            yield _finding(
                f"BATCH_BACKENDS {tuple(sorted(BATCH_BACKENDS))} != TIER_BACKENDS "
                f"{tuple(sorted(TIER_BACKENDS))} — the batched builder and the "
                f"sharded tier must claim the same backends",
                "a kind answered batched must be answerable in a tier (both run "
                "the same batched kernels)",
            )
        # --- fit-mode capability ladder + verified-ε probe ---
        yield from self._check_fit_modes(kinds, registry, np)

        # --- probe tables: one easy (near-uniform), one hard (clustered) ---
        t_easy = distributions.generate("face", 512, seed=11)
        t_hard = distributions.generate("osm", 512, seed=13)

        for kind in kinds:
            try:
                spec = registry.spec_for(kind)
            except Exception as e:
                yield _finding(f"kind {kind!r}: spec_for() failed: {e!r}")
                continue
            if spec.kind != kind:
                yield _finding(
                    f"kind {kind!r}: spec_for() returned a spec of kind "
                    f"{spec.kind!r} — registry key and spec.kind disagree"
                )
            try:
                grid = type(spec).default_grid(4096)
            except Exception as e:
                yield _finding(f"kind {kind!r}: default_grid(4096) raised {e!r}")
                grid = ()
            if not grid:
                yield _finding(
                    f"kind {kind!r}: default_grid(4096) is empty — the kind "
                    f"never enrols in the Pareto tuner sweep",
                    "return at least the default spec (IndexSpec.default_grid does)",
                )
            for g in grid:
                if g.kind not in kinds:
                    yield _finding(
                        f"kind {kind!r}: default_grid yields spec of "
                        f"unregistered kind {g.kind!r}"
                    )
            try:
                impl = query_impl(kind)
            except Exception as e:
                yield _finding(f"kind {kind!r}: no QueryImpl ({e!r})")
                continue
            for attr in ("intervals", "space_bytes"):
                if not callable(getattr(impl, attr, None)):
                    yield _finding(f"kind {kind!r}: QueryImpl.{attr} is not callable")
            claimed_by_kind = tuple(getattr(impl, "backends", ()) or BACKENDS)
            unknown = set(claimed_by_kind) - set(BACKENDS)
            if unknown:
                yield _finding(
                    f"kind {kind!r}: QueryImpl.backends claims {sorted(unknown)} "
                    f"unknown to repro.index.BACKENDS {tuple(BACKENDS)}"
                )
            if "pallas" in claimed_by_kind:
                for attr in ("pallas", "pallas_batched"):
                    if getattr(impl, attr, None) is None:
                        yield _finding(
                            f"kind {kind!r}: QueryImpl.{attr} is missing but "
                            f"'pallas' is a claimed backend",
                            "wire the fused kernel or the k-ary fallback "
                            "(_kary_pallas_fallback / _kary_pallas_batched), or "
                            "drop 'pallas' from the kind's backends tuple",
                        )

            # --- build + stacking probe ---
            try:
                i_easy = registry.entry(kind).build(spec, t_easy)
                i_hard = registry.entry(kind).build(spec, t_hard)
            except Exception as e:
                yield _finding(f"kind {kind!r}: default-spec build failed on probe tables: {e!r}")
                continue
            try:
                sb, nb = i_hard.space_bytes(), i_hard.nbytes()
            except Exception as e:
                yield _finding(f"kind {kind!r}: space accounting raised {e!r}")
            else:
                if not (0 < sb <= nb):
                    yield _finding(
                        f"kind {kind!r}: space_bytes()={sb} outside (0, "
                        f"nbytes()={nb}] — model-constituent accounting is broken"
                    )
            harmonized_ok = {"levels"} if registry.entry(kind).query_key == "pgm" else set()
            diff = {
                a for (a, va), (b, vb) in zip(i_easy.static, i_hard.static) if va != vb or a != b
            }
            rogue = diff - set(_STEP_KEYS) - harmonized_ok
            if rogue:
                yield _finding(
                    f"kind {kind!r}: static key(s) {sorted(rogue)} are "
                    f"data-dependent but not in _STEP_KEYS — stacking/tier "
                    f"refresh will reject same-spec rebuilds",
                    "add the key to repro.dist.sharded_index._STEP_KEYS (bucketed "
                    "trip counts take the max) or harmonise like PGM levels",
                )
            try:
                stacked = stack_indexes(_harmonize(kind, [i_easy, i_hard]))
            except Exception as e:
                yield _finding(
                    f"kind {kind!r}: stack_indexes() rejects two same-spec "
                    f"builds ({e!r}) — the kind cannot join a sharded tier or "
                    f"BatchedIndexes",
                )
                continue
            missing = set(stacked.arrays) ^ set(i_easy.arrays)
            if missing:
                yield _finding(
                    f"kind {kind!r}: stacked leaves {sorted(missing)} do not "
                    f"match the single-index leaf set"
                )

            # --- mutation probe: updatability is a per-kind capability ---
            if kind in updatable_kinds():
                yield from _probe_mutation(kind, i_easy, t_easy, np)
            else:
                try:
                    i_easy.insert_batch(np.asarray([t_easy[0]], dtype=np.uint64))
                except TypeError:
                    pass
                except Exception as e:
                    yield _finding(
                        f"kind {kind!r}: static kind raised {e!r} from "
                        f"insert_batch — the contract is TypeError"
                    )
                else:
                    yield _finding(
                        f"kind {kind!r}: static kind accepted insert_batch — "
                        f"either register a Mutator or let mutation raise TypeError"
                    )
