"""R4 — registry / pytree contract (project rule).

The decorator registry (``repro.index.registry``) made adding an index
kind a one-decorator affair — which also made it easy to add a kind that
*looks* registered but violates the contracts every composite path
assumes.  ``tools/docs_check.py`` already guards the docs matrix; this
rule extends the same idea from docs into code, by importing the live
registry and probing each registered kind:

* the spec class round-trips through ``spec_for(kind)`` and contributes
  a non-empty ``default_grid`` of registered specs (the Pareto tuner's
  enrolment contract);
* a :class:`~repro.index.impls.QueryImpl` exists with ``intervals``,
  ``space_bytes``, ``pallas`` and ``pallas_batched`` — required since
  ``"pallas"`` is in every backend tuple;
* ``BATCH_BACKENDS`` == ``TIER_BACKENDS`` ⊆ ``BACKENDS`` — a backend
  claimed by the batched builder must be claimable by the sharded tier
  and known to ``Index.lookup``;
* the **stacking probe**: the kind builds on two small tables of
  different hardness and ``stack_indexes`` accepts the pair — i.e. every
  *data-dependent* static (bucketed trip counts) is declared in
  ``_STEP_KEYS`` (or harmonised, like PGM ``levels``); a new kind whose
  trip-count static is missing from ``_STEP_KEYS`` fails here instead of
  deep inside a tier refresh;
* ``space_bytes() <= nbytes()`` on the built artifact (the PR 3
  model-constituent accounting invariant).

Runs only on full-tree scans (it imports jax); findings anchor at the
registration site ``src/repro/index/impls.py``.
"""

from __future__ import annotations

import sys
from pathlib import Path

from .framework import Finding, ProjectRule

_ANCHOR = "src/repro/index/impls.py"


def _finding(message: str, hint: str = "") -> Finding:
    return Finding(
        rule="R4",
        path=_ANCHOR,
        line=1,
        col=0,
        message=message,
        hint=hint,
        snippet=message,  # project findings fingerprint on the message
    )


class RegistryContractRule(ProjectRule):
    id = "R4"
    title = "registry/pytree contract"
    blurb = (
        "every registered kind must define a usable default_grid/space_bytes, "
        "stack through the `_STEP_KEYS` machinery, and back every claimed "
        "backend (BATCH_BACKENDS/TIER_BACKENDS ⊆ BACKENDS)"
    )

    def check_project(self, root: Path):
        src = root / "src"
        if str(src) not in sys.path:
            sys.path.insert(0, str(src))
        try:
            from repro.index import BACKENDS, registry
            from repro.index.impls import query_impl
            from repro.dist.sharded_index import _STEP_KEYS, _harmonize, stack_indexes
            from repro.tune.batched import BATCH_BACKENDS
            from repro.dist.sharded_index import TIER_BACKENDS
            from repro.data import distributions
        except Exception as e:  # pragma: no cover - container without jax
            yield _finding(
                f"registry contract probe could not import repro ({e!r})",
                "run from the repo root with the package installed (pip install -e .)",
            )
            return

        kinds = registry.kinds()
        if not kinds:
            yield _finding("registry is empty — no index kind registered")
            return

        # --- backend claims ---
        for name, claimed in (("BATCH_BACKENDS", BATCH_BACKENDS), ("TIER_BACKENDS", TIER_BACKENDS)):
            extra = set(claimed) - set(BACKENDS)
            if extra:
                yield _finding(
                    f"{name} claims backend(s) {sorted(extra)} unknown to "
                    f"repro.index.BACKENDS {tuple(BACKENDS)}"
                )
        if set(BATCH_BACKENDS) != set(TIER_BACKENDS):
            yield _finding(
                f"BATCH_BACKENDS {tuple(sorted(BATCH_BACKENDS))} != TIER_BACKENDS "
                f"{tuple(sorted(TIER_BACKENDS))} — the batched builder and the "
                f"sharded tier must claim the same backends",
                "a kind answered batched must be answerable in a tier (both run "
                "the same batched kernels)",
            )
        need_pallas = "pallas" in set(BACKENDS) | set(BATCH_BACKENDS) | set(TIER_BACKENDS)

        # --- probe tables: one easy (near-uniform), one hard (clustered) ---
        t_easy = distributions.generate("face", 512, seed=11)
        t_hard = distributions.generate("osm", 512, seed=13)

        for kind in kinds:
            try:
                spec = registry.spec_for(kind)
            except Exception as e:
                yield _finding(f"kind {kind!r}: spec_for() failed: {e!r}")
                continue
            if spec.kind != kind:
                yield _finding(
                    f"kind {kind!r}: spec_for() returned a spec of kind "
                    f"{spec.kind!r} — registry key and spec.kind disagree"
                )
            try:
                grid = type(spec).default_grid(4096)
            except Exception as e:
                yield _finding(f"kind {kind!r}: default_grid(4096) raised {e!r}")
                grid = ()
            if not grid:
                yield _finding(
                    f"kind {kind!r}: default_grid(4096) is empty — the kind "
                    f"never enrols in the Pareto tuner sweep",
                    "return at least the default spec (IndexSpec.default_grid does)",
                )
            for g in grid:
                if g.kind not in kinds:
                    yield _finding(
                        f"kind {kind!r}: default_grid yields spec of "
                        f"unregistered kind {g.kind!r}"
                    )
            try:
                impl = query_impl(kind)
            except Exception as e:
                yield _finding(f"kind {kind!r}: no QueryImpl ({e!r})")
                continue
            for attr in ("intervals", "space_bytes"):
                if not callable(getattr(impl, attr, None)):
                    yield _finding(f"kind {kind!r}: QueryImpl.{attr} is not callable")
            if need_pallas:
                for attr in ("pallas", "pallas_batched"):
                    if getattr(impl, attr, None) is None:
                        yield _finding(
                            f"kind {kind!r}: QueryImpl.{attr} is missing but "
                            f"'pallas' is a claimed backend",
                            "wire the fused kernel or the k-ary fallback "
                            "(_kary_pallas_fallback / _kary_pallas_batched)",
                        )

            # --- build + stacking probe ---
            try:
                i_easy = registry.entry(kind).build(spec, t_easy)
                i_hard = registry.entry(kind).build(spec, t_hard)
            except Exception as e:
                yield _finding(f"kind {kind!r}: default-spec build failed on probe tables: {e!r}")
                continue
            try:
                sb, nb = i_hard.space_bytes(), i_hard.nbytes()
            except Exception as e:
                yield _finding(f"kind {kind!r}: space accounting raised {e!r}")
            else:
                if not (0 < sb <= nb):
                    yield _finding(
                        f"kind {kind!r}: space_bytes()={sb} outside (0, "
                        f"nbytes()={nb}] — model-constituent accounting is broken"
                    )
            harmonized_ok = {"levels"} if registry.entry(kind).query_key == "pgm" else set()
            diff = {
                a for (a, va), (b, vb) in zip(i_easy.static, i_hard.static) if va != vb or a != b
            }
            rogue = diff - set(_STEP_KEYS) - harmonized_ok
            if rogue:
                yield _finding(
                    f"kind {kind!r}: static key(s) {sorted(rogue)} are "
                    f"data-dependent but not in _STEP_KEYS — stacking/tier "
                    f"refresh will reject same-spec rebuilds",
                    "add the key to repro.dist.sharded_index._STEP_KEYS (bucketed "
                    "trip counts take the max) or harmonise like PGM levels",
                )
            try:
                stacked = stack_indexes(_harmonize(kind, [i_easy, i_hard]))
            except Exception as e:
                yield _finding(
                    f"kind {kind!r}: stack_indexes() rejects two same-spec "
                    f"builds ({e!r}) — the kind cannot join a sharded tier or "
                    f"BatchedIndexes",
                )
                continue
            missing = set(stacked.arrays) ^ set(i_easy.arrays)
            if missing:
                yield _finding(
                    f"kind {kind!r}: stacked leaves {sorted(missing)} do not "
                    f"match the single-index leaf set"
                )
