"""Shared AST helpers for the rule modules (stdlib ``ast`` only).

Conventions the rules key on are *this repo's* conventions, documented in
docs/analysis.md:

* **Kernel contexts** — Pallas kernel bodies are functions whose
  parameters end in ``_ref`` (the ``pl.pallas_call`` convention) or whose
  name ends in ``_kernel`` / ``_body`` / ``_kernel_batched`` (the shared
  single/batched body idiom of ``kernels/*_search.py``).  Inside a kernel
  context, keyword-only parameters (after ``*``) are static Python ints;
  positional parameters are traced arrays.
* **Jit contexts** — functions decorated ``@jax.jit`` / ``@jit`` /
  ``@(functools.)partial(jax.jit, static_argnames=..., static_argnums=...)``,
  plus functions wrapped by a ``jax.jit(fn)`` call expression elsewhere in
  the module (the ``self._decode = jax.jit(self._decode_impl)`` idiom).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Optional, Set, Tuple

FuncDef = (ast.FunctionDef, ast.AsyncFunctionDef)

_KERNEL_NAME_RE = re.compile(r".*(_kernel|_body|_kernel_batched)$")
_BOOL_FN_RE = re.compile(r"^_?(is|has|_?le|_?lt|_?ge|_?gt|_?eq|_?ne)_?")


def call_name(node: ast.AST) -> str:
    """Trailing name of a call target: ``jnp.clip`` -> ``clip``."""
    if isinstance(node, ast.Call):
        node = node.func
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def is_kernel_context(fn: ast.AST, rel: str = "") -> bool:
    """Pallas kernel body: has ``_ref`` params (any file), or carries a
    kernel-suffixed name inside a kernel-ish module (``kernels/*.py``,
    or any file with ``kernel`` in its name — fixtures use this).  The
    path condition keeps e.g. models/transformer.py's ``_layer_body``
    (a plain shard_map layer fn) out of kernel scope."""
    if not isinstance(fn, FuncDef):
        return False
    if any(a.arg.endswith("_ref") for a in fn.args.args + fn.args.posonlyargs):
        return True
    return bool(_KERNEL_NAME_RE.match(fn.name)) and "kernel" in rel


def kernel_traced_params(fn) -> Set[str]:
    """Positional params of a kernel context (kw-only = static)."""
    return {a.arg for a in fn.args.posonlyargs + fn.args.args}


def _literal_names(node) -> Set[str]:
    """String elements of a tuple/list/constant literal."""
    out: Set[str] = set()
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        out.add(node.value)
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.add(el.value)
    return out


def _literal_ints(node) -> Set[int]:
    out: Set[int] = set()
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        out.add(node.value)
    elif isinstance(node, (ast.Tuple, ast.List)):
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, int):
                out.add(el.value)
    return out


def _is_jax_jit_ref(node) -> bool:
    """``jax.jit`` / ``jit`` as a decorator or partial() first arg."""
    if isinstance(node, ast.Attribute):
        return node.attr == "jit"
    return isinstance(node, ast.Name) and node.id == "jit"


def jit_static_info(fn) -> Optional[Tuple[Set[str], Set[int]]]:
    """``(static_argnames, static_argnums)`` when ``fn`` is jit-decorated,
    else None.  Handles bare ``@jax.jit`` and the ``@partial(jax.jit, ...)``
    forms used throughout this repo."""
    if not isinstance(fn, FuncDef):
        return None
    for dec in fn.decorator_list:
        if _is_jax_jit_ref(dec):
            return set(), set()
        if isinstance(dec, ast.Call):
            if _is_jax_jit_ref(dec.func):
                return _jit_call_statics(dec)
            if call_name(dec.func) == "partial" and dec.args and _is_jax_jit_ref(dec.args[0]):
                return _jit_call_statics(dec)
    return None


def _jit_call_statics(call: ast.Call) -> Tuple[Set[str], Set[int]]:
    names: Set[str] = set()
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names |= _literal_names(kw.value)
        elif kw.arg == "static_argnums":
            nums |= _literal_ints(kw.value)
    return names, nums


def module_jit_wrapped(tree) -> Dict[str, Tuple[Set[str], Set[int]]]:
    """Function names wrapped by a ``jax.jit(<fn>)`` call expression
    anywhere in the module (``jax.jit(self._decode_impl)`` idiom)."""
    wrapped: Dict[str, Tuple[Set[str], Set[int]]] = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _is_jax_jit_ref(node.func) and node.args):
            continue
        target = node.args[0]
        name = ""
        if isinstance(target, ast.Name):
            name = target.id
        elif isinstance(target, ast.Attribute):
            name = target.attr
        if name:
            wrapped[name] = _jit_call_statics(node)
    return wrapped


def traced_params(fn, statics: Tuple[Set[str], Set[int]]) -> Set[str]:
    """Non-static parameter names of a jit-decorated function."""
    names, nums = statics
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    out = set()
    for i, p in enumerate(params):
        if p in ("self", "cls") or p in names or i in nums:
            continue
        out.add(p)
    # kw-only args are traced too unless named static
    for a in fn.args.kwonlyargs:
        if a.arg not in names:
            out.add(a.arg)
    return out


def names_in(node) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def enclosing_statement(node):
    cur = node
    while cur is not None and not isinstance(cur, ast.stmt):
        cur = getattr(cur, "_parent", None)
    return cur


def enclosing_function(node):
    cur = getattr(node, "_parent", None)
    while cur is not None and not isinstance(cur, FuncDef):
        cur = getattr(cur, "_parent", None)
    return cur


# ---------------------------------------------------------------------------
# Local value classification for the cast rule (R2)
# ---------------------------------------------------------------------------

CLAMP_CALLS = {"clip", "minimum", "maximum"}
_SHAPE_CALLS = {"floor", "ceil", "round", "rint", "abs", "absolute"}


def module_bool_functions(tree) -> Set[str]:
    """Module-level functions whose every ``return`` is boolean-shaped
    (comparison / boolean combination) — e.g. ``_le_u64``."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, FuncDef):
            continue
        rets = [r.value for r in ast.walk(node) if isinstance(r, ast.Return) and r.value]
        if rets and all(_boolish_expr(r, set(), set()) for r in rets):
            out.add(node.name)
    return out


def _boolish_expr(node, bool_names: Set[str], bool_funcs: Set[str]) -> bool:
    if isinstance(node, (ast.Compare, ast.BoolOp)):
        return True
    if isinstance(node, ast.Name):
        return node.id in bool_names
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, (ast.Not, ast.Invert)):
        return _boolish_expr(node.operand, bool_names, bool_funcs)
    if isinstance(node, ast.BinOp) and isinstance(node.op, (ast.BitAnd, ast.BitOr, ast.BitXor)):
        return _boolish_expr(node.left, bool_names, bool_funcs) or _boolish_expr(
            node.right, bool_names, bool_funcs
        )
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name in bool_funcs or name.startswith("logical_") or _BOOL_FN_RE.match(name):
            return True
    return False


def _simple_expr(node) -> bool:
    """Constants / plain names / arithmetic thereof — cannot *introduce*
    an unbounded float into a clamped product (statics like ``b / n``)."""
    if isinstance(node, (ast.Constant, ast.Name)):
        return True
    if isinstance(node, ast.UnaryOp):
        return _simple_expr(node.operand)
    if isinstance(node, ast.BinOp):
        return _simple_expr(node.left) and _simple_expr(node.right)
    return False


def _clamped_expr(node, clamped_names: Set[str]) -> bool:
    if isinstance(node, ast.Name):
        return node.id in clamped_names
    if isinstance(node, ast.Call):
        name = call_name(node)
        if name in CLAMP_CALLS:
            return True
        if name in _SHAPE_CALLS and node.args:
            return _clamped_expr(node.args[0], clamped_names)
        return False
    if isinstance(node, ast.UnaryOp):
        return _clamped_expr(node.operand, clamped_names)
    if isinstance(node, ast.BinOp):
        lc = _clamped_expr(node.left, clamped_names)
        rc = _clamped_expr(node.right, clamped_names)
        return (lc and (rc or _simple_expr(node.right))) or (
            rc and (lc or _simple_expr(node.left))
        )
    return False


class ValueClasses:
    """Order-sensitive classification of assigned names in one function:
    which locals are clamped (dominated by clip/minimum/maximum), which
    are boolean-shaped, and which are *floaty* (carry float evidence per
    ``float_pred`` — directly or through a chain of assignments, the
    ``pred = slope * q + icept`` PR 1 shape).  Reassignment updates the
    class — the ``pred = ...; pred = jnp.clip(pred, ...)`` idiom works."""

    def __init__(self, fn, bool_funcs: Set[str], float_pred=None):
        self.clamped: Set[str] = set()
        self.boolish: Set[str] = set()
        self.floaty: Set[str] = set()
        self.bool_funcs = bool_funcs
        self.float_pred = float_pred
        self._walk(fn.body)

    def _walk(self, stmts):
        for st in stmts:
            if isinstance(st, ast.Assign) and len(st.targets) == 1:
                self._classify(st.targets[0], st.value)
            elif isinstance(st, ast.AugAssign):
                self._classify(st.target, st.value)
            for sub in ("body", "orelse", "finalbody"):
                inner = getattr(st, sub, None)
                if inner:
                    self._walk(inner)

    def _classify(self, target, value):
        names = []
        if isinstance(target, ast.Name):
            names = [target.id]
        elif isinstance(target, ast.Tuple):
            names = [t.id for t in target.elts if isinstance(t, ast.Name)]
            # tuple unpack: conservatively drop prior classes only
            for n in names:
                self.clamped.discard(n)
                self.boolish.discard(n)
                self.floaty.discard(n)
            return
        for n in names:
            if _clamped_expr(value, self.clamped):
                self.clamped.add(n)
                self.boolish.discard(n)
                self.floaty.discard(n)
            elif _boolish_expr(value, self.boolish, self.bool_funcs):
                self.boolish.add(n)
                self.clamped.discard(n)
                self.floaty.discard(n)
            else:
                self.clamped.discard(n)
                self.boolish.discard(n)
                if self._floaty_value(value):
                    self.floaty.add(n)
                else:
                    self.floaty.discard(n)

    def _floaty_value(self, value) -> bool:
        if self.float_pred is None:
            return False
        return bool(self.float_pred(value)) or bool(names_in(value) & self.floaty)

    def is_clamped(self, node) -> bool:
        return _clamped_expr(node, self.clamped)

    def is_boolish(self, node) -> bool:
        return _boolish_expr(node, self.boolish, self.bool_funcs)

    def is_floaty(self, node) -> bool:
        return self._floaty_value(node)
