"""R5 — sentinel discipline: magic routing literals shadowing named constants.

The sharded tier routes with named sentinels (``repro.dist.DROPPED = -2``
for capacity-dropped queries, ``NO_PRED = -1`` for "no predecessor").
A bare ``-2`` in a comparison or fill does the same thing until someone
renumbers the constant — then it silently mis-classifies.  This rule
collects every module-level ``ALL_CAPS = -k`` constant across the
scanned set and flags raw ``-k`` literals used in sentinel positions
(equality comparisons; fill-value arguments of ``where`` / ``full`` /
``asarray`` / ``select``) anywhere a named constant for that value
exists.

Arithmetic (``rank - 1``), indexing (``shape[-2]``), ``axis=-2`` keywords
and ``reshape(-1)`` never flag — only *sentinel positions* do.
"""

from __future__ import annotations

import ast
from typing import Dict, List

from .framework import AstRule, Module
from . import astutil

#: callee -> positional arg indices that are fill/sentinel values
_FILL_POSITIONS = {
    "where": (1, 2),
    "full": (1,),
    "full_like": (1,),
    "asarray": (0,),
    "array": (0,),
    "select": (2,),
    "fill": (0,),
}
_FILL_KEYWORDS = {"fill_value", "constant_values"}


def _neg_int(node) -> int | None:
    """-k literal (UnaryOp USub over an int constant) -> -k, else None."""
    if (
        isinstance(node, ast.UnaryOp)
        and isinstance(node.op, ast.USub)
        and isinstance(node.operand, ast.Constant)
        and isinstance(node.operand.value, int)
        and not isinstance(node.operand.value, bool)
    ):
        return -node.operand.value
    return None


class MagicSentinelRule(AstRule):
    id = "R5"
    title = "magic sentinel literal"
    blurb = (
        "raw `-2`/`-1` routing literals in comparisons/fills where a named "
        "constant (`DROPPED`, `NO_PRED`) exists — renumbering would silently "
        "mis-classify"
    )

    def check_module(self, mod: Module):
        # two-phase: constants are collected across the whole module set
        # first, findings emitted in finish()
        return ()

    def finish(self, modules: List[Module]):
        constants: Dict[int, str] = {}
        for mod in modules:
            for node in mod.tree.body:
                if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
                    continue
                t = node.targets[0]
                v = _neg_int(node.value)
                if v is not None and isinstance(t, ast.Name) and t.id.isupper():
                    constants.setdefault(v, t.id)
        if not constants:
            return
        for mod in modules:
            yield from self._check(mod, constants)

    def _check(self, mod: Module, constants: Dict[int, str]):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Compare):
                for op, comp in zip(node.ops, node.comparators):
                    if not isinstance(op, (ast.Eq, ast.NotEq)):
                        continue
                    v = _neg_int(comp)
                    if v in constants and not self._is_defining(mod, comp):
                        yield self._finding(mod, comp, v, constants[v], "comparison")
            elif isinstance(node, ast.Call):
                callee = astutil.call_name(node)
                spots = _FILL_POSITIONS.get(callee, ())
                for i in spots:
                    if i < len(node.args):
                        v = _neg_int(node.args[i])
                        if v in constants:
                            yield self._finding(
                                mod, node.args[i], v, constants[v], f"{callee}() fill"
                            )
                for kw in node.keywords:
                    if kw.arg in _FILL_KEYWORDS:
                        v = _neg_int(kw.value)
                        if v in constants:
                            yield self._finding(mod, kw.value, v, constants[v], f"{kw.arg}=")

    @staticmethod
    def _is_defining(mod: Module, node) -> bool:
        # `NAME = -k` module-level defining assignments are the one
        # allowed raw use (and asserts like `DROPPED == -2` in tests of
        # the constant itself still flag — compare against the name)
        stmt = astutil.enclosing_statement(node)
        return (
            isinstance(stmt, ast.Assign)
            and len(stmt.targets) == 1
            and isinstance(stmt.targets[0], ast.Name)
            and stmt.targets[0].id.isupper()
        )

    def _finding(self, mod: Module, node, value: int, name: str, where: str):
        return mod.finding(
            self.id,
            node,
            f"magic sentinel `{value}` in {where} — the named constant "
            f"`{name}` exists for this value",
            f"use the named constant (e.g. `from repro.dist import {name}`); "
            f"a renumber would otherwise silently mis-route",
        )
