"""R1 — salted-hash seeding (the PR 5 bug class).

``hash(str)`` is salted per interpreter process (PYTHONHASHSEED), so any
``hash()`` feeding a seed / rng / checksum path silently produces
*different* values in every process — the exact bug that made every
benchmark table non-reproducible until ``benchmarks/trend.py`` caught it
(``data/distributions.generate`` seeded ``seed + hash(name)``; now
``zlib.crc32``).
"""

from __future__ import annotations

import ast
import re

from .framework import AstRule, Module
from . import astutil

#: a hash() call is "feeding a seed path" when the enclosing statement
#: mentions one of these, or when the enclosing call target matches
_SEEDY_NAME_RE = re.compile(r"(seed|rng|random|salt|crc|checksum|digest|entropy)", re.I)
_SEEDY_CALLEE_RE = re.compile(r"(default_rng|RandomState|PRNGKey|Generator|seed|crc32|adler32)", re.I)

_HINT = (
    "builtin hash() is salted per process (PYTHONHASHSEED); use "
    "zlib.crc32(x.encode()) for a process-stable offset (the "
    "data/distributions.generate idiom)"
)


def _is_stringish(arg: ast.AST) -> bool:
    if isinstance(arg, ast.JoinedStr):
        return True
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return True
    if isinstance(arg, ast.Call) and astutil.call_name(arg) in ("str", "repr", "format"):
        return True
    if isinstance(arg, ast.BinOp) and isinstance(arg.op, (ast.Add, ast.Mod)):
        # "a" + b / "fmt" % x string building
        return _is_stringish(arg.left) or _is_stringish(arg.right)
    return False


def _seedy_context(call: ast.Call) -> bool:
    # enclosing call chain: default_rng(hash(name)), crc_update(hash(x)), ...
    cur = getattr(call, "_parent", None)
    while cur is not None and not isinstance(cur, ast.stmt):
        if isinstance(cur, ast.Call) and _SEEDY_CALLEE_RE.search(astutil.call_name(cur)):
            return True
        cur = getattr(cur, "_parent", None)
    stmt = astutil.enclosing_statement(call)
    if stmt is None:
        return False
    mentioned = set(astutil.names_in(stmt))
    for node in ast.walk(stmt):
        if isinstance(node, ast.Attribute):
            mentioned.add(node.attr)
        elif isinstance(node, ast.keyword) and node.arg:
            mentioned.add(node.arg)
    return any(_SEEDY_NAME_RE.search(n) for n in mentioned)


class SaltedHashRule(AstRule):
    id = "R1"
    title = "salted-hash seeding"
    blurb = (
        "builtin `hash()` feeding a seed/rng/crc path — per-process salted "
        "(PYTHONHASHSEED), so derived artifacts are not reproducible across runs"
    )

    def check_module(self, mod: Module):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if not (isinstance(node.func, ast.Name) and node.func.id == "hash"):
                continue
            if len(node.args) != 1:
                continue
            arg = node.args[0]
            if _is_stringish(arg):
                yield mod.finding(
                    self.id,
                    node,
                    "hash() of a string is process-salted — any value derived "
                    "from it differs run to run",
                    _HINT,
                )
            elif _seedy_context(node):
                yield mod.finding(
                    self.id,
                    node,
                    "hash() feeding a seed/rng path — process-salted for str/bytes "
                    "(and any object hash can vary across runs)",
                    _HINT,
                )
