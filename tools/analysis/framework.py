"""The analyzer framework: findings, rule registry, file walking, baseline.

Everything here is **stdlib-only** so the pass runs in the offline
container before any test (or third-party tool) does.  Two rule shapes:

* :class:`AstRule` — per-module AST visitors.  The framework parses each
  file once into a :class:`Module` (source, tree, parent links) and
  hands it to every AST rule.
* :class:`ProjectRule` — whole-tree semantic rules that may import
  ``repro`` itself (the registry/pytree contract check R4 — the code
  analogue of ``tools/docs_check.py``'s docs matrix check).  Project
  rules only run on full-tree scans, never on explicit file arguments,
  so fixture runs stay hermetic.

A finding is suppressed when the committed baseline
(``tools/analysis/baseline.json``) carries a matching entry — matched on
``(rule, path, snippet)`` so accepted pre-existing findings survive line
drift but *new* occurrences of the same pattern in other lines/files
still fail ``--check``.
"""

from __future__ import annotations

import ast
import json
from dataclasses import dataclass, field, asdict
from pathlib import Path
from typing import Iterable, List, Optional

REPO_ROOT = Path(__file__).resolve().parents[2]

#: Tree-wide scan roots (repo-relative).  ``tests/analysis_fixtures`` is
#: excluded below: it holds deliberate rule violations.
DEFAULT_ROOTS = ("src", "benchmarks", "examples", "tools", "tests")
EXCLUDE_PARTS = {"__pycache__", ".git", "analysis_fixtures"}


@dataclass(frozen=True)
class Finding:
    rule: str  # rule id, e.g. "R1"
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str
    hint: str = ""
    snippet: str = ""  # stripped source line — the baseline fingerprint

    def key(self) -> tuple:
        # line numbers deliberately NOT part of the key: baselines
        # survive unrelated edits above the finding
        return (self.rule, self.path, self.snippet or self.message)

    def format(self) -> str:
        loc = f"{self.path}:{self.line}:{self.col}"
        out = f"{loc}: [{self.rule}] {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


@dataclass
class Module:
    """One parsed source file, shared by every AST rule."""

    path: Path
    rel: str
    source: str
    tree: ast.AST
    lines: List[str] = field(default_factory=list)

    @classmethod
    def parse(cls, path: Path, root: Path = REPO_ROOT) -> "Module":
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
        # parent links: rules walk up to find enclosing statements/defs
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                child._parent = node  # type: ignore[attr-defined]
        try:
            rel = path.resolve().relative_to(root).as_posix()
        except ValueError:
            rel = path.as_posix()
        return cls(path=path, rel=rel, source=source, tree=tree, lines=source.splitlines())

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, message: str, hint: str = "") -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Finding(
            rule=rule,
            path=self.rel,
            line=line,
            col=col,
            message=message,
            hint=hint,
            snippet=self.line_at(line),
        )


class Rule:
    """Base: one registered rule family (id, title, bug-class blurb)."""

    id: str = "R?"
    title: str = "?"
    #: one-line description for the docs catalogue (docs/analysis.md);
    #: verified against the table by tools/docs_check.py
    blurb: str = "?"


class AstRule(Rule):
    def check_module(self, mod: Module) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finish(self, modules: List[Module]) -> Iterable[Finding]:
        """Hook for rules needing a cross-module view (after all
        check_module calls).  Default: nothing."""
        return ()


class ProjectRule(Rule):
    def check_project(self, root: Path) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


def iter_py_files(root: Path = REPO_ROOT, roots=DEFAULT_ROOTS) -> List[Path]:
    files: List[Path] = []
    for sub in roots:
        base = root / sub
        if not base.exists():
            continue
        for p in sorted(base.rglob("*.py")):
            if any(part in EXCLUDE_PARTS for part in p.parts):
                continue
            files.append(p)
    return files


def run_rules(
    files: List[Path],
    rules: List[Rule],
    *,
    root: Path = REPO_ROOT,
    project: bool = False,
) -> List[Finding]:
    """Run ``rules`` over ``files``; project rules only when ``project``."""
    findings: List[Finding] = []
    modules: List[Module] = []
    for path in files:
        try:
            modules.append(Module.parse(path, root=root))
        except SyntaxError as e:
            findings.append(
                Finding(
                    rule="PARSE",
                    path=str(path),
                    line=e.lineno or 1,
                    col=e.offset or 0,
                    message=f"syntax error: {e.msg}",
                )
            )
    ast_rules = [r for r in rules if isinstance(r, AstRule)]
    for mod in modules:
        for rule in ast_rules:
            findings.extend(rule.check_module(mod))
    for rule in ast_rules:
        findings.extend(rule.finish(modules))
    if project:
        for rule in rules:
            if isinstance(rule, ProjectRule):
                findings.extend(rule.check_project(root))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


# ---------------------------------------------------------------------------
# Baseline: committed, fingerprint-matched suppressions
# ---------------------------------------------------------------------------

BASELINE_PATH = Path(__file__).resolve().parent / "baseline.json"


def load_baseline(path: Optional[Path] = None) -> List[dict]:
    path = path or BASELINE_PATH
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return list(data.get("entries", []))


def split_by_baseline(findings: List[Finding], entries: List[dict]):
    """-> (new, suppressed, stale_entries).

    An entry suppresses every finding with the same (rule, path, snippet)
    fingerprint; entries matching nothing are reported stale so the
    baseline can only shrink as findings are fixed.
    """
    keys = {(e.get("rule"), e.get("path"), e.get("snippet") or e.get("message")) for e in entries}
    new = [f for f in findings if f.key() not in keys]
    suppressed = [f for f in findings if f.key() in keys]
    hit = {f.key() for f in suppressed}
    stale = [
        e
        for e in entries
        if (e.get("rule"), e.get("path"), e.get("snippet") or e.get("message")) not in hit
    ]
    return new, suppressed, stale


def report_json(
    findings_new: List[Finding],
    suppressed: List[Finding],
    stale: List[dict],
    rules: List[Rule],
    n_files: int,
) -> dict:
    return {
        "version": 1,
        "rules": [{"id": r.id, "title": r.title, "blurb": r.blurb} for r in rules],
        "n_files": n_files,
        "findings": [asdict(f) for f in findings_new],
        "baselined": [asdict(f) for f in suppressed],
        "stale_baseline": stale,
        "counts": {
            "new": len(findings_new),
            "baselined": len(suppressed),
            "stale_baseline": len(stale),
        },
    }
