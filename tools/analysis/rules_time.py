"""R8 — raw wall-clock deltas in ``src/repro/`` outside ``repro.obs``.

PR 8 unified telemetry behind ``repro.obs``: latency measured with ad-hoc
``time.perf_counter()`` subtraction bypasses the registry — it reaches no
histogram, no snapshot, no SLO gate, and silently diverges from the
distributions the bench-trend baselines assert on.  Library code takes
wall-clock deltas through ``repro.obs.timing`` instead: ``stopwatch()``
for build-time accounting, ``span("name")`` for traced blocks,
``timed_lookup`` for lookup latency.

Scope: ``src/repro/`` only, minus ``src/repro/obs/`` (the one place the
raw clock is allowed — it *implements* the stopwatch).  ``benchmarks/``
and ``tools/`` are exempt: harness plumbing (best-of-reps loops, CI
timers) is not serving telemetry.

A timer *call* alone does not flag — only a call whose value flows into
a subtraction (directly, or through a name assigned in the same scope):
that is the "record a delta" signature.
"""

from __future__ import annotations

import ast
from pathlib import Path

from .framework import AstRule, Module

#: the timer functions whose deltas belong in repro.obs.timing
_TIMER_ATTRS = frozenset({"perf_counter", "perf_counter_ns", "time", "monotonic", "monotonic_ns"})
_HINT = (
    "take deltas through repro.obs.timing — stopwatch().elapsed for build "
    "accounting, span()/timed_lookup() for serving latency — so they land "
    "in the registry histograms"
)


def _in_scope(rel: str) -> bool:
    if "analysis_fixtures" in rel:
        return Path(rel).name.startswith("r8")
    return rel.startswith("src/repro/") and not rel.startswith("src/repro/obs/")


def _enclosing_scope(node: ast.AST) -> ast.AST:
    while node is not None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda, ast.Module)):
            return node
        node = getattr(node, "_parent", None)
    return node


class RawTimingRule(AstRule):
    id = "R8"
    title = "raw timing outside repro.obs"
    blurb = (
        "`time.perf_counter()`/`time.time()` deltas taken in `src/repro/` "
        "outside the repro.obs layer — latency that bypasses the unified "
        "registry histograms (benchmarks/ and tools/ are exempt)"
    )

    def check_module(self, mod: Module):
        if not _in_scope(mod.rel):
            return
        timer_aliases = self._timer_aliases(mod.tree)
        # names assigned from a timer call, per enclosing scope
        assigned: dict = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and self._is_timer_call(node.value, timer_aliases):
                scope = _enclosing_scope(node)
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        assigned.setdefault(scope, set()).add(t.id)
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub)):
                continue
            scope_names = assigned.get(_enclosing_scope(node), set())
            for side in (node.left, node.right):
                if self._is_timer_call(side, timer_aliases) or (
                    isinstance(side, ast.Name) and side.id in scope_names
                ):
                    yield mod.finding(
                        self.id,
                        node,
                        "raw wall-clock delta recorded outside repro.obs",
                        hint=_HINT,
                    )
                    break

    @staticmethod
    def _timer_aliases(tree: ast.AST) -> frozenset:
        """Local names bound to timer functions via ``from time import ...``."""
        names = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in _TIMER_ATTRS:
                        names.add(alias.asname or alias.name)
        return frozenset(names)

    @staticmethod
    def _is_timer_call(node: ast.AST, aliases: frozenset) -> bool:
        if not isinstance(node, ast.Call):
            return False
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr in _TIMER_ATTRS:
            return isinstance(fn.value, ast.Name) and fn.value.id == "time"
        return isinstance(fn, ast.Name) and fn.id in aliases
