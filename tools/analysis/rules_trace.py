"""R3 — trace discipline in jitted functions and Pallas kernel bodies.

Today the one-trace-per-(kind, backend) invariant is enforced only by
after-the-fact ``trace_counts()`` asserts in tests and bench gates; this
rule catches the mechanical violations at the AST level, before any
trace happens:

* **Python control flow on traced arguments** — ``if``/``while`` on a
  non-static jit parameter (or a positional kernel parameter) either
  raises a ConcretizationError or, worse, silently burns one trace per
  Python-visible value.  Static args are fine: the rule parses
  ``static_argnames`` / ``static_argnums`` from the decorator, and in
  kernel contexts keyword-only params are static by this repo's
  convention (``*, b, n, steps``).
* **Concretizing calls on tracers** — ``float()`` / ``int()`` / ``bool()``
  / ``.item()`` / ``.tolist()`` / ``np.asarray()`` applied to a traced
  parameter forces a device sync per call at best, a trace error at
  worst.
* **Captured mutable module globals** — a jitted function reading a
  module-level dict/list/set/Counter closes over *trace-time* state:
  mutations after the first trace are silently invisible.  (The
  deliberate ``count_trace`` python-side-effect idiom routes through a
  function call and is not flagged.)
* **Unledgered fit/refresh programs** — every *module-level* jitted
  function in ``src/repro/tune/`` must call ``count_trace`` in its
  body: the bench-trend baselines diff trace counts exactly, so a new
  batched-fit or device-refresh program that skips the ledger ships a
  blind spot the trend gate can never catch.
"""

from __future__ import annotations

import ast

from .framework import AstRule, Module
from . import astutil

_CONCRETIZE_BUILTINS = {"float", "int", "bool"}
_CONCRETIZE_METHODS = {"item", "tolist", "__array__"}
_NP_CONCRETIZE = {"asarray", "array", "asnumpy"}
_MUTABLE_CALLS = {"dict", "list", "set", "Counter", "defaultdict", "OrderedDict", "deque"}


def _module_mutable_globals(tree) -> set:
    out = set()
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
            value = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
            value = node.value
        else:
            continue
        mutable = isinstance(value, (ast.Dict, ast.List, ast.Set, ast.DictComp, ast.ListComp))
        if isinstance(value, ast.Call) and astutil.call_name(value) in _MUTABLE_CALLS:
            mutable = True
        if not mutable:
            continue
        for t in targets:
            if isinstance(t, ast.Name):
                out.add(t.id)
    return out


class TraceDisciplineRule(AstRule):
    id = "R3"
    title = "trace discipline"
    blurb = (
        "jitted / kernel functions branching on traced args, concretizing "
        "tracers (float()/.item()/np.*), or capturing mutable module globals"
    )

    def check_module(self, mod: Module):
        mutable_globals = _module_mutable_globals(mod.tree)
        jit_wrapped = astutil.module_jit_wrapped(mod.tree)
        if mod.rel.replace("\\", "/").startswith("src/repro/tune/"):
            yield from self._check_tune_trace_ledger(mod, jit_wrapped)
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, astutil.FuncDef):
                continue
            statics = astutil.jit_static_info(fn)
            if statics is None and fn.name in jit_wrapped:
                statics = jit_wrapped[fn.name]
            kernel = astutil.is_kernel_context(fn, mod.rel)
            if statics is None and not kernel:
                continue
            if statics is not None:
                traced = astutil.traced_params(fn, statics)
                kind = "jitted function"
            else:
                traced = astutil.kernel_traced_params(fn)
                kind = "kernel body"
            yield from self._check_fn(mod, fn, traced, mutable_globals, kind)

    def _check_tune_trace_ledger(self, mod: Module, jit_wrapped):
        """Module-level jitted functions in repro.tune must count their
        traces: the bench-trend baselines diff ``trace_counts()``
        exactly, so an unledgered fit/refresh program is invisible to
        the trend gate."""
        for fn in mod.tree.body:
            if not isinstance(fn, astutil.FuncDef):
                continue
            if astutil.jit_static_info(fn) is None and fn.name not in jit_wrapped:
                continue
            calls = (
                astutil.call_name(n)
                for n in ast.walk(fn)
                if isinstance(n, ast.Call)
            )
            if "count_trace" not in calls:
                yield mod.finding(
                    self.id,
                    fn,
                    f"module-level jitted function `{fn.name}` in repro.tune "
                    f"never calls count_trace — its compiles are invisible to "
                    f"the trace ledger and the bench-trend baselines",
                    "add count_trace(<name>, <backend>) as the first statement "
                    "(python side effect: runs once per trace)",
                )

    def _check_fn(self, mod: Module, fn, traced, mutable_globals, kind):
        # nested defs (shard_map blocks, fori bodies) are walked in place:
        # their params may shadow fn's traced names, but this repo's
        # nested blocks rename locals, so the cheap approximation holds.
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                used = astutil.names_in(node.test) & traced
                if used:
                    stmt = "while" if isinstance(node, ast.While) else "if"
                    yield mod.finding(
                        self.id,
                        node,
                        f"python `{stmt}` on traced argument(s) {sorted(used)} in "
                        f"{kind} `{fn.name}` — data-dependent python control flow "
                        f"breaks tracing (or re-traces per value)",
                        "use jnp.where / lax.cond / lax.while_loop, or declare the "
                        "argument static (static_argnames; kernels: keyword-only)",
                    )
            elif isinstance(node, ast.Call):
                yield from self._check_call(mod, fn, node, traced, kind)
            elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                if node.id in mutable_globals and node.id not in traced:
                    yield mod.finding(
                        self.id,
                        node,
                        f"{kind} `{fn.name}` reads mutable module global "
                        f"`{node.id}` — captured at trace time; later mutations "
                        f"are invisible to the compiled function",
                        "pass the value as an argument, or hoist the read to the "
                        "host-side caller",
                    )

    def _check_call(self, mod: Module, fn, node: ast.Call, traced, kind):
        name = astutil.call_name(node)
        direct_on_traced = bool(
            node.args
            and isinstance(node.args[0], ast.Name)
            and node.args[0].id in traced
        )
        if isinstance(node.func, ast.Name) and name in _CONCRETIZE_BUILTINS and direct_on_traced:
            yield mod.finding(
                self.id,
                node,
                f"`{name}()` on traced argument `{node.args[0].id}` in {kind} "
                f"`{fn.name}` — concretizes the tracer",
                "keep the value on device (jnp ops), or mark the argument static",
            )
            return
        if isinstance(node.func, ast.Attribute):
            recv = node.func.value
            if (
                node.func.attr in _CONCRETIZE_METHODS
                and isinstance(recv, ast.Name)
                and recv.id in traced
            ):
                yield mod.finding(
                    self.id,
                    node,
                    f"`.{node.func.attr}()` on traced argument `{recv.id}` in "
                    f"{kind} `{fn.name}` — forces a host sync / trace error",
                    "return the array and reduce on the host, outside the jit",
                )
                return
            # np.asarray(traced) — numpy pulling a tracer to host
            if (
                isinstance(recv, ast.Name)
                and recv.id in ("np", "numpy")
                and node.func.attr in _NP_CONCRETIZE
                and direct_on_traced
            ):
                yield mod.finding(
                    self.id,
                    node,
                    f"`np.{node.func.attr}()` on traced argument "
                    f"`{node.args[0].id}` in {kind} `{fn.name}` — numpy cannot "
                    f"consume tracers",
                    "use jnp inside jit; convert on the host boundary instead",
                )
