"""R7 — removed-API resurrection: deleted shim names must stay gone.

The mutation-API redesign finished the PR 1 migration by *deleting* the
deprecated shims: ``repro.core.build_index`` / ``repro.core.KINDS``
(use ``repro.index.build`` / ``repro.index.kinds()``),
``prepare_rmi_kernel_index`` / ``fused_rmi_search`` /
``RMIKernelIndex`` (the kernel re-encoding is folded into ``Index``
build; ``Index.lookup(..., backend="pallas")`` runs the fused kernel),
and the ``.rmi`` alias on ``LearnedKeyedEmbedding`` (use ``.index``).

A later PR re-introducing any of these names — as a definition, an
import, or a ``repro.core``/``repro.kernels`` attribute access — would
silently resurrect the two-API split this codebase just paid to close.
This rule flags:

* any definition (``def``/``class``/assignment) of a banned name,
* any ``import``/``from ... import`` binding one,
* any attribute access spelling one (``ops.fused_rmi_search``),
* ``KINDS`` only when imported from / accessed on ``repro.core`` (the
  bare word is too common to ban outright).

String/docstring mentions never flag — prose may reference history.
"""

from __future__ import annotations

import ast

from .framework import AstRule, Module

#: identifiers that must not reappear anywhere in the scanned tree
BANNED_NAMES = frozenset(
    {"build_index", "prepare_rmi_kernel_index", "fused_rmi_search", "RMIKernelIndex"}
)
#: names banned only in a repro.core context (import-from or attribute)
BANNED_CORE_ONLY = frozenset({"KINDS"})
_CORE_MODULES = ("repro.core", "repro.core.builder")

_REPLACEMENT = {
    "build_index": "repro.index.build",
    "KINDS": "repro.index.kinds()",
    "prepare_rmi_kernel_index": 'repro.index.build + lookup(backend="pallas")',
    "fused_rmi_search": 'Index.lookup(..., backend="pallas")',
    "RMIKernelIndex": "repro.index.Index (k_* leaves)",
}


def _is_core_module(modname: str | None) -> bool:
    return modname is not None and (
        modname in _CORE_MODULES or modname.startswith("repro.core")
    )


class RemovedApiRule(AstRule):
    id = "R7"
    title = "removed-API resurrection"
    blurb = (
        "deleted pre-unified-API shims (`build_index`, `core.KINDS`, "
        "`prepare_rmi_kernel_index`, `fused_rmi_search`, `RMIKernelIndex`) "
        "reappearing as definitions, imports, or attribute accesses"
    )

    def check_module(self, mod: Module):
        for node in ast.walk(mod.tree):
            name, context = self._banned_use(node)
            if name is not None:
                yield mod.finding(
                    self.id,
                    node,
                    f"removed API {name!r} {context}",
                    hint=f"use {_REPLACEMENT[name]} instead",
                )

    @staticmethod
    def _banned_use(node):
        """(banned_name, context) for a violating node, else (None, None)."""
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            if node.name in BANNED_NAMES:
                return node.name, "redefined"
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id in BANNED_NAMES:
                    return t.id, "redefined"
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name in BANNED_NAMES:
                    return alias.name, f"imported from {node.module or '.'}"
                if alias.name in BANNED_CORE_ONLY and _is_core_module(node.module):
                    return alias.name, f"imported from {node.module}"
        elif isinstance(node, ast.Attribute):
            if node.attr in BANNED_NAMES:
                return node.attr, "attribute access"
            if node.attr in BANNED_CORE_ONLY:
                # only flag KINDS on a repro.core-ish base (core.KINDS)
                base = node.value
                parts = []
                while isinstance(base, ast.Attribute):
                    parts.append(base.attr)
                    base = base.value
                if isinstance(base, ast.Name):
                    parts.append(base.id)
                dotted = ".".join(reversed(parts))
                if dotted.endswith("core") or _is_core_module(dotted):
                    return node.attr, f"attribute access on {dotted}"
        return None, None
