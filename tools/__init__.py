# repo tooling namespace (docs_check, analysis) — stdlib-only entry points
