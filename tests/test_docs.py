"""The docs tree stays consistent with the code (the CI docs-check gate
run as a tier-1 test, so local runs catch doc rot before CI does)."""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

import docs_check  # noqa: E402


def test_backend_matrix_covers_registry():
    assert docs_check.check_backend_matrix() == []


def test_readme_and_docs_links_resolve():
    assert docs_check.check_links() == []


def test_metric_catalogue_covers_registry():
    assert docs_check.check_metric_catalogue() == []


def test_required_docs_exist():
    for rel in (
        "README.md",
        "docs/architecture.md",
        "docs/backends.md",
        "docs/benchmarks.md",
        "docs/analysis.md",
        "docs/observability.md",
    ):
        assert (ROOT / rel).exists(), rel


def test_matrix_check_catches_missing_kind(monkeypatch):
    """The gate actually gates: drop a kind's row and it must fail."""
    text = (ROOT / "docs" / "backends.md").read_text()
    broken = "\n".join(ln for ln in text.splitlines() if not ln.startswith("| RMI |"))
    monkeypatch.setattr(docs_check.Path, "read_text", lambda self, *a, **k: broken, raising=True)
    errors = docs_check.check_backend_matrix()
    assert any("RMI" in e for e in errors)


def test_metric_check_catches_missing_metric(monkeypatch):
    text = (ROOT / "docs" / "observability.md").read_text()
    broken = "\n".join(
        ln for ln in text.splitlines() if not ln.startswith("| lookup_latency_us |")
    )
    monkeypatch.setattr(docs_check.Path, "read_text", lambda self, *a, **k: broken, raising=True)
    errors = docs_check.check_metric_catalogue()
    assert any("lookup_latency_us" in e for e in errors)
