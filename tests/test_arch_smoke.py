"""Per-architecture smoke tests: reduced config, one step per shape cell
on CPU, asserting output shapes and finiteness (assignment requirement)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.dist.sharding import single_device_ctx
from repro.launch import steps
from repro.models import dimenet, recsys, transformer
from repro.train import TrainConfig, init_train_state

CTX = single_device_ctx()
TCFG = TrainConfig(total_steps=4, warmup=1)

ALL_CELLS = [
    (arch, cell.name)
    for arch in configs.list_archs()
    for cell in configs.get(arch, reduced=True).shapes
]


def _init_params(spec, cfg):
    if spec.family == "lm":
        return transformer.init(jax.random.key(0), cfg)
    if spec.family == "gnn":
        return dimenet.init(jax.random.key(0), cfg)
    return recsys.init(jax.random.key(0), cfg, CTX)


@pytest.mark.parametrize("arch,cell_name", ALL_CELLS, ids=[f"{a}-{c}" for a, c in ALL_CELLS])
def test_smoke(arch, cell_name):
    spec = configs.get(arch, reduced=True)
    cell = next(c for c in spec.shapes if c.name == cell_name)
    bundle = steps.build_step(spec, cell, CTX, TCFG)
    batch = steps.make_inputs(spec, cell, abstract=False)
    cfg = bundle.extra["cfg"]

    if spec.family == "lm" and cell.kind == "decode":
        params = _init_params(spec, cfg)
        cache = transformer.init_cache(cfg, cell.dims["global_batch"], cell.dims["seq_len"])
        logits, new_cache = jax.jit(bundle.fn)(params, cache, batch, jnp.int32(2))
        assert logits.shape == (cell.dims["global_batch"], cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        assert new_cache["k"].shape == cache["k"].shape
    elif cell.kind in ("prefill", "serve", "retrieval"):
        params = _init_params(spec, cfg)
        out = jax.jit(bundle.fn)(params, batch)
        assert np.isfinite(np.asarray(out).astype(np.float32)).all()
        if cell.kind == "retrieval":
            assert out.shape == (cell.dims["n_candidates"],)
    else:  # train
        init_fn = lambda r: _init_params(spec, cfg)
        state = init_train_state(jax.random.key(0), init_fn, TCFG)
        state2, metrics = jax.jit(bundle.fn)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert int(state2["step"]) == 1
        # params actually changed
        l0 = jax.tree_util.tree_leaves(state["params"])[0]
        l1 = jax.tree_util.tree_leaves(state2["params"])[0]
        assert not np.allclose(np.asarray(l0, np.float32), np.asarray(l1, np.float32))


def test_all_archs_registered():
    assert len(configs.list_archs()) == 10
    assert sum(len(configs.get(a).shapes) for a in configs.list_archs()) == 40
