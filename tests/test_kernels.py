"""Pallas kernel sweeps: shapes/dtypes vs the pure-jnp oracles (ref.py).

Search kernels assert exact integer equality; float kernels use
tolerances calibrated to f32 reduction error.  The search kernels are
reached through the unified ``repro.index`` API (``backend="pallas"``):
fused RMI, fused PGM descent, fused RadixSpline, the batched
(table, q_tile)-grid RMI kernel, and the k-ary fallback — every
registered kind must be bit-exact vs ``backend="ref"``.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import index as ix
from repro.core import true_ranks
from repro.core.rmi import build_rmi
from repro.kernels import ops, ref

from conftest import make_table


@pytest.mark.parametrize("kind", ["uniform", "clustered", "bursty"])
@pytest.mark.parametrize("n", [64, 1000, 65536])
def test_fused_rmi_kernel(rng, kind, n):
    table = make_table(rng, kind, n)
    qs = np.concatenate(
        [rng.choice(table, 300), rng.integers(0, 2**64 - 1, 100, dtype=np.uint64),
         np.array([0, table.min(), table.max(), 2**64 - 1], dtype=np.uint64)]
    ).astype(np.uint64)
    want = true_ranks(table, qs)
    m = ix.build(ix.RMISpec(b=max(2, min(256, n // 4)), root_type="linear"), table)
    got = np.asarray(m.lookup(table, qs, backend="pallas"))
    np.testing.assert_array_equal(got, want)


def test_fused_rmi_kernel_from_fitted_model(rng):
    """A separately fitted core RMIModel reaches the fused kernel via
    ``repro.index.impls.rmi_model_to_index`` (the migration path for
    the removed prepare/search shim pair)."""
    from repro.index.impls import rmi_model_to_index

    table = make_table(rng, "uniform", 4096)
    qs = rng.choice(table, 256).astype(np.uint64)
    m = build_rmi(table, b=64, root_type="linear")
    idx = rmi_model_to_index("RMI", m, table)
    got = np.asarray(idx.lookup(jnp.asarray(table), jnp.asarray(qs), backend="pallas"))
    np.testing.assert_array_equal(got, true_ranks(table, qs))


def test_pallas_window_center_clamp_regression():
    """Dense clusters inside a huge key span collapse f32 ``u``
    resolution: the leaf/segment prediction overshoots the fence range
    by thousands of ranks, and a ±ε window around the *unclamped*
    center used to collapse to a single fence slot (wrong rank for
    in-cluster queries).  The kernels now clamp the predicted center
    into the fence range before widening; this pins the exact table
    that exposed it."""
    rng = np.random.default_rng(42)
    centers = rng.integers(0, 2**63, size=8, dtype=np.uint64)
    parts = [c + rng.integers(0, 2**20, size=256, dtype=np.uint64) for c in centers]
    table = np.unique(np.concatenate(parts))
    qs = np.concatenate(
        [rng.choice(table, 400), rng.integers(0, 2**63, 100, dtype=np.uint64)]
    ).astype(np.uint64)
    want = true_ranks(table, qs)
    for spec in (
        ix.PGMSpec(eps=32),
        ix.RMISpec(b=64, root_type="linear"),
        ix.RSSpec(eps=32, r_bits=10),
    ):
        m = ix.build(spec, table)
        got = np.asarray(m.lookup(table, qs, backend="pallas"))
        np.testing.assert_array_equal(got, want, err_msg=spec.kind)


def _edge_queries(rng, table, n_random=200):
    """Query mix aimed at ε-window edges: exact keys (window centre),
    keys ± 1 (boundary predecessors — one sits at the previous rank,
    one is an equality hit), uniform misses, and the extremes."""
    keys = rng.choice(table, min(len(table), 150)).astype(np.uint64)
    return np.concatenate(
        [
            keys,
            keys - np.uint64(1),  # just below a key: predecessor rank - 1
            keys + np.uint64(1),  # just above: same rank as the key
            rng.integers(0, 2**64 - 1, n_random, dtype=np.uint64),
            np.array(
                [0, table.min() - 1, table.min(), table.max(), table.max() + 1, 2**64 - 1],
                dtype=np.uint64,
            ),
        ]
    ).astype(np.uint64)


@pytest.mark.parametrize("kind", ["uniform", "clustered", "bursty", "sequential"])
@pytest.mark.parametrize("n", [64, 1000, 65536])
def test_fused_pgm_kernel(rng, kind, n):
    """Fused PGM descent == searchsorted, incl. boundary predecessors,
    out-of-range keys and ε-window edges, on every table shape."""
    table = make_table(rng, kind, n)
    qs = _edge_queries(rng, table)
    want = true_ranks(table, qs)
    m = ix.build(ix.PGMSpec(eps=max(4, n // 256)), table)
    got = np.asarray(m.lookup(table, qs, backend="pallas"))
    np.testing.assert_array_equal(got, want)
    # bit-exact vs the ref backend too (the acceptance contract)
    ref_ranks = np.asarray(m.lookup(table, qs, backend="ref"))
    np.testing.assert_array_equal(got, ref_ranks)


@pytest.mark.parametrize("kind", ["uniform", "clustered", "bursty", "sequential"])
@pytest.mark.parametrize("n", [64, 1000, 65536])
def test_fused_rs_kernel(rng, kind, n):
    """Fused RadixSpline lookup == searchsorted across table shapes."""
    table = make_table(rng, kind, n)
    qs = _edge_queries(rng, table)
    want = true_ranks(table, qs)
    m = ix.build(ix.RSSpec(eps=16, r_bits=10), table)
    got = np.asarray(m.lookup(table, qs, backend="pallas"))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got, np.asarray(m.lookup(table, qs, backend="ref")))


def test_pallas_bit_exact_all_kinds(rng):
    """Acceptance: lookup(backend="pallas") is bit-exact vs
    backend="ref" for EVERY registered kind that claims a pallas path
    (kinds that don't — GAPPED — must reject the backend loudly)."""
    table = make_table(rng, "lognormal", 8192)
    qs = _edge_queries(rng, table)
    params = {
        "L": {},
        "Q": {},
        "C": {},
        "KO": {"k": 7},
        "RMI": {"b": 128},
        "SY-RMI": {"space_pct": 2.0, "ub": 0.04},
        "PGM": {"eps": 32},
        "PGM_M": {"space_pct": 2.0, "a": 1.0},
        "RS": {"eps": 32, "r_bits": 10},
        "BTREE": {"fanout": 16},
        "GAPPED": {"leaf_cap": 64, "delta_cap": 256},
    }
    assert set(params) == set(ix.kinds())
    for kind in ix.kinds():
        m = ix.build(kind, table, **params[kind])
        if "pallas" not in m.backends():
            with pytest.raises(ValueError, match="supports backends"):
                m.lookup(table, qs, backend="pallas")
            continue
        got = np.asarray(m.lookup(table, qs, backend="pallas"))
        want = np.asarray(m.lookup(table, qs, backend="ref"))
        np.testing.assert_array_equal(got, want, err_msg=kind)


def test_batched_rmi_kernel(rng):
    """The batched (table, q_tile)-grid fused RMI kernel answers every
    table of a stacked batch exactly, with one merged trip count
    covering heterogeneous per-table windows."""
    from repro import tune
    from repro.core import true_ranks as tr

    tables = [make_table(rng, k, 2048) for k in ("uniform", "clustered", "bursty")]
    qs = _edge_queries(rng, np.concatenate(tables))
    for spec in (ix.RMISpec(b=64), ix.SYRMISpec(space_pct=2.0, ub=0.04)):
        bm = tune.build_many(spec, tables)
        # the merged static is the max of the per-table trip counts
        singles = [ix.build(spec, t) for t in tables]
        assert bm.index.s("ksteps") == max(s.s("ksteps") for s in singles)
        outs = np.asarray(bm.lookup(qs, backend="pallas"))
        for i, t in enumerate(tables):
            np.testing.assert_array_equal(outs[i], tr(t, qs), err_msg=f"{spec.kind}/{i}")


def test_batched_pgm_kernel(rng):
    """The batched (table, q_tile)-grid fused PGM kernel answers every
    table of a stacked batch exactly — including the level-lifted
    members (data-dependent level counts harmonised at stack time) and
    the max-merged trip count."""
    from repro import tune
    from repro.core import true_ranks as tr

    tables = [make_table(rng, k, 2048) for k in ("uniform", "clustered", "sequential")]
    qs = _edge_queries(rng, np.concatenate(tables))
    for spec in (ix.PGMSpec(eps=16), ix.PGMBicriteriaSpec(space_pct=2.0)):
        bm = tune.build_many(spec, tables)
        singles = [ix.build(spec, t) for t in tables]
        assert bm.index.s("levels") == max(s.s("levels") for s in singles)
        assert bm.index.s("pksteps") == max(s.s("pksteps") for s in singles)
        outs = np.asarray(bm.lookup(qs, backend="pallas"))
        for i, t in enumerate(tables):
            np.testing.assert_array_equal(outs[i], tr(t, qs), err_msg=f"{spec.kind}/{i}")
        # bit-exact vs the vmapped ref backend too (the acceptance contract)
        refs = np.asarray(bm.lookup(qs, backend="ref"))
        np.testing.assert_array_equal(outs, refs, err_msg=spec.kind)


def test_batched_rs_kernel(rng):
    """The batched (table, q_tile)-grid fused RadixSpline kernel answers
    every table of a stacked batch exactly, with per-table radix/knot
    blocks and max-merged knot-search/window trip counts."""
    from repro import tune
    from repro.core import true_ranks as tr

    tables = [make_table(rng, k, 2048) for k in ("uniform", "clustered", "bursty")]
    qs = _edge_queries(rng, np.concatenate(tables))
    spec = ix.RSSpec(eps=16, r_bits=8)
    bm = tune.build_many(spec, tables)
    singles = [ix.build(spec, t) for t in tables]
    assert bm.index.s("ksteps") == max(s.s("ksteps") for s in singles)
    assert bm.index.s("rk_epi") == max(s.s("rk_epi") for s in singles)
    outs = np.asarray(bm.lookup(qs, backend="pallas"))
    for i, t in enumerate(tables):
        np.testing.assert_array_equal(outs[i], tr(t, qs), err_msg=f"RS/{i}")
    refs = np.asarray(bm.lookup(qs, backend="ref"))
    np.testing.assert_array_equal(outs, refs)


def test_pgm_rs_kernel_f32_widening():
    """The fused kernels' f32 re-encodings carry their own re-measured
    ε and stay within sane bounds (the window must remain a guarantee
    without degenerating to the whole table on benign data).

    Uses its own rng: the session rng's stream position depends on test
    order, and some clustered draws (few centres over a 2^60 span)
    legitimately blow the f32 re-anchored ε up to n — the clamp keeps
    those windows guarantees, but they are not the benign case this
    test pins down."""
    rng = np.random.default_rng(7)
    table = make_table(rng, "clustered", 20000)
    pgm = ix.build(ix.PGMSpec(eps=16), table)
    assert 1 <= int(np.asarray(pgm.arrays["pk_eps"])) < len(table)
    assert pgm.s("pksteps") >= 4
    rs = ix.build(ix.RSSpec(eps=16, r_bits=10), table)
    assert 1 <= int(np.asarray(rs.arrays["rk_eps"])) < len(table)
    assert rs.s("rk_epi") >= 4


@pytest.mark.parametrize("k", [8, 128])
@pytest.mark.parametrize("n", [50, 4096, 100_000])
def test_kary_kernel(rng, k, n):
    table = make_table(rng, "lognormal", n)
    qs = np.concatenate(
        [rng.choice(table, 200), np.array([0, 2**64 - 1], dtype=np.uint64)]
    ).astype(np.uint64)
    want = true_ranks(table, qs)
    got = np.asarray(ops.kary_search(table, qs, k=k, tile_q=128))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("v,d,n_items,bags,vtile", [
    (100, 8, 50, 4, 32),
    (1000, 64, 300, 16, 512),
    (513, 32, 128, 8, 128),
])
def test_embedding_bag_kernel(rng, v, d, n_items, bags, vtile):
    table = rng.normal(size=(v, d)).astype(np.float32)
    ids = rng.integers(0, v, n_items).astype(np.int32)
    seg = np.sort(rng.integers(0, bags, n_items)).astype(np.int32)
    w = rng.normal(size=n_items).astype(np.float32)
    got = np.asarray(ops.embedding_bag(table, ids, seg, w, num_bags=bags, v_tile=vtile))
    want = np.asarray(
        ref.embedding_bag_ref(
            jnp.asarray(table), jnp.asarray(ids), jnp.asarray(seg), jnp.asarray(w), bags
        )
    )
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("b,hq,hkv,d,s,stile", [
    (2, 4, 4, 16, 64, 32),    # MHA
    (3, 8, 2, 32, 300, 128),  # GQA, ragged lengths, padded tiles
    (1, 16, 1, 64, 512, 256), # MQA
])
def test_decode_attention_kernel(rng, b, hq, hkv, d, s, stile):
    q = rng.normal(size=(b, hq, d)).astype(np.float32)
    k = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
    v = rng.normal(size=(b, s, hkv, d)).astype(np.float32)
    kvl = rng.integers(1, s + 1, size=b).astype(np.int32)
    got = np.asarray(ops.decode_attention(q, k, v, kvl, s_tile=stile))
    want = np.asarray(
        ref.decode_attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(kvl))
    )
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_rmi_kernel_f32_widening(rng):
    """The kernel's f32 eps must be >= the f64 model's (safety margin).

    The f32/i32 re-encoding is folded into Index construction as the
    ``k_*`` leaves, so the invariant is checked on the Index itself.
    """
    table = make_table(rng, "clustered", 20000)
    m = ix.build(ix.RMISpec(b=128), table)
    assert int(jnp.max(m.arrays["k_eps"])) >= 1
    # windows clamp within leaf rank ranges
    assert (np.asarray(m.arrays["k_rlo"]) <= np.asarray(m.arrays["k_rhi"])).all()
