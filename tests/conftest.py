import os

import numpy as np
import pytest

import repro  # noqa: F401  — enables x64 before any test imports jax

from repro.core import as_table


def selected_backends() -> tuple:
    """Query backends under test, selectable via ``REPRO_TEST_BACKENDS``.

    The CI matrix runs one leg per backend (``REPRO_TEST_BACKENDS=xla``,
    ``bbs``, ``ref``); unset or empty means every registered backend
    (local full runs, the multihost CI leg).  Comma-separated, order
    preserved, unknown names fail loudly rather than silently testing
    nothing.
    """
    from repro.index import BACKENDS

    raw = os.environ.get("REPRO_TEST_BACKENDS", "").strip()
    if not raw:
        return tuple(BACKENDS)
    sel = tuple(b.strip() for b in raw.split(",") if b.strip())
    unknown = [b for b in sel if b not in BACKENDS]
    if unknown:
        raise ValueError(
            f"REPRO_TEST_BACKENDS names unknown backends {unknown}; known: {BACKENDS}"
        )
    return sel


def pytest_generate_tests(metafunc):
    # any test taking a ``backend`` argument fans out over the selected
    # backends — the hook the CI backend matrix drives
    if "backend" in metafunc.fixturenames:
        metafunc.parametrize("backend", selected_backends())


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


def make_table(rng, kind: str, n: int) -> np.ndarray:
    if kind == "uniform":
        return as_table(rng.integers(0, 2**63, size=n, dtype=np.uint64))
    if kind == "lognormal":
        return as_table(np.exp(rng.normal(20, 2, size=n)).astype(np.uint64))
    if kind == "clustered":
        c = rng.integers(0, 2**60, size=max(4, n // 500), dtype=np.uint64)
        return as_table(c[rng.integers(0, len(c), n)] + rng.integers(0, 2**30, n).astype(np.uint64))
    if kind == "bursty":
        g = rng.exponential(100, size=n) * (1 + 50 * (rng.random(n) < 0.01))
        return as_table(np.cumsum(g).astype(np.uint64) + 10**15)
    if kind == "sequential":
        return as_table(np.arange(n, dtype=np.uint64) * 7 + 3)
    raise ValueError(kind)


TABLE_KINDS = ("uniform", "lognormal", "clustered", "bursty", "sequential")


def make_queries(rng, table: np.ndarray, n: int) -> np.ndarray:
    extremes = np.array(
        [0, table.min(), table.max(), np.iinfo(np.uint64).max], dtype=np.uint64
    )
    mix = [rng.choice(table, size=n // 2)]
    if len(table) > 1:
        mix.append(rng.integers(table.min(), table.max(), size=n // 2, dtype=np.uint64))
    return np.concatenate(mix + [extremes]).astype(np.uint64)
