"""Data pipeline, graph sampler, serving engine, paged KV cache."""

import numpy as np
import jax

from repro.data import distributions, pipeline, sampler, tables
from repro.serve.engine import DecodeEngine, Request
from repro.serve.kvcache import PagedPool
from repro.dist.sharding import single_device_ctx
from repro.models import transformer
from repro.configs import get as get_arch


def test_datasets_shapes():
    for ds in distributions.DATASETS:
        t = distributions.generate(ds, 5000, seed=3)
        assert len(t) == 5000
        assert (np.diff(t.astype(np.float64)) > 0).all()


def test_ks_subsample_preserves_cdf(rng):
    parent = distributions.generate("osm", 40000, seed=1)
    sub = tables.subsample_preserving_cdf(parent, 4000, seed=2)
    assert len(sub) == 4000
    assert tables.ks_statistic(sub, parent) < 0.05


def test_pipeline_determinism_and_sharding():
    c = pipeline.synth_corpus(vocab_size=500, n_docs=40, mean_len=64, seed=1)
    full = pipeline.TokenBatcher(c, batch_size=8, seq_len=16, seed=5)
    sh0 = pipeline.TokenBatcher(c, batch_size=8, seq_len=16, seed=5, shard=0, num_shards=2)
    sh1 = pipeline.TokenBatcher(c, batch_size=8, seq_len=16, seed=5, shard=1, num_shards=2)
    b = np.asarray(full.batch_at(3)["tokens"])
    b0 = np.asarray(sh0.batch_at(3)["tokens"])
    b1 = np.asarray(sh1.batch_at(3)["tokens"])
    np.testing.assert_array_equal(np.concatenate([b0, b1]), b)


def test_doc_lookup_learned_index():
    c = pipeline.synth_corpus(vocab_size=100, n_docs=64, mean_len=32, seed=2)
    offs = np.array([0, 1, int(c.doc_starts[-1]) + 1, len(c.tokens) - 1], dtype=np.int64)
    got = np.asarray(c.doc_of(offs))
    want = np.searchsorted(c.doc_starts, offs, side="right") - 1
    np.testing.assert_array_equal(got, want)


def test_neighbor_sampler_fanout():
    g = sampler.synth_powerlaw_graph(500, 6, 8, seed=4)
    nodes, hops = sampler.sample_neighbors(g, np.arange(32), [5, 3], seed=1)
    assert hops[0][0].shape == (32 * 5,)
    # every sampled edge's dst is in the previous frontier
    assert set(hops[0][1].tolist()) <= set(range(32))
    # sampled neighbors are real neighbors (or self-loops for isolated)
    src_all, dst_all = g.src_dst_arrays()
    adj = {}
    for s, d in zip(src_all, dst_all):
        adj.setdefault(int(s), set()).add(int(d))
    for s, d in zip(hops[0][0][:200], hops[0][1][:200]):
        assert int(s) in adj.get(int(d), set()) or int(s) == int(d)


def test_paged_pool_lookup():
    pool = PagedPool(n_pages=16, n_layers=2, page_size=8, n_kv=1, head_dim=4)
    pool.add_sequence(7)
    pool.ensure_capacity(7, 50)
    assert len(pool.seq_pages[7]) == 7  # ceil(50/8)
    pages, offs = pool.position_lookup(7, np.array([0, 7, 8, 49]))
    want_pages = [pool.seq_pages[7][i] for i in [0, 0, 1, 6]]
    np.testing.assert_array_equal(np.asarray(pages), want_pages)
    np.testing.assert_array_equal(np.asarray(offs), [0, 7, 0, 1])
    pool.release(7)
    assert pool.utilization() == 0.0


def test_decode_engine_continuous_batching():
    spec = get_arch("qwen2-0.5b", reduced=True)
    cfg = spec.config
    ctx = single_device_ctx()
    params = transformer.init(jax.random.key(0), cfg)
    eng = DecodeEngine(params, cfg, ctx, batch_slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32), max_new_tokens=4)
        for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    ticks = eng.run_until_drained(max_ticks=200)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) >= 4 for r in reqs)
    assert ticks < 200
    m = eng.metrics()
    assert m["requests_finished"] == 5
    assert m["tokens_decoded"] >= 5 * 3  # first token comes from prefill
    assert m["queued"] == 0 and m["live_slots"] == 0
    # learned-index trace telemetry rides along (dict, possibly empty)
    assert isinstance(m["index_trace_counts"], dict)
    assert m["index_traces"] == sum(m["index_trace_counts"].values())
    # sharded-tier routing counters ride along too (engine has no tier
    # here, so they are the module-level dist counters)
    assert {"drop_rate", "imbalance_mean", "lookups"} <= set(m["tier_routing"])


def test_decode_engine_drives_tuned_tier():
    from repro.dist import reset_tier_metrics
    from repro.index import RMISpec
    from repro.tune import RebuildPolicy, TunedTier
    from repro.core import as_table, true_ranks

    spec = get_arch("qwen2-0.5b", reduced=True)
    cfg = spec.config
    ctx = single_device_ctx()
    params = transformer.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(5)
    table = as_table(rng.integers(0, 2**61, size=2048, dtype=np.uint64))
    reset_tier_metrics()
    tier = TunedTier(
        table,
        n_shards=2,
        policy=RebuildPolicy(shard_refresh_frac=0.01, retune_frac=10.0, n_queries=128),
        spec=RMISpec(b=32),  # pinned spec: the test exercises the refresh path
    )
    eng = DecodeEngine(params, cfg, ctx, batch_slots=2, max_seq=64, tier=tier)
    qs = rng.choice(table, size=256).astype(np.uint64)
    np.testing.assert_array_equal(np.asarray(tier.lookup(qs, mode="ref")), true_ranks(table, qs))
    # ingest drift, then let the engine's tick drive the rebuild policy
    new_keys = np.setdiff1d(
        np.unique(rng.integers(0, 2**61, size=64, dtype=np.uint64)), table
    )
    tier._pending[0].append(new_keys)  # buffer only: engine tick applies the policy
    tier.counters.pending += len(new_keys)
    eng.submit(Request(rid=0, prompt=np.array([1, 2], np.int32), max_new_tokens=2))
    eng.run_until_drained(max_ticks=50)
    m = eng.metrics()
    assert m["tier"]["shard_refreshes"] + m["tier"]["forced_restacks"] >= 1
    assert m["tier"]["routing"]["lookups"] >= 1
    merged = np.union1d(table, new_keys)
    q2 = rng.choice(merged, size=256).astype(np.uint64)
    np.testing.assert_array_equal(
        np.asarray(tier.lookup(q2, mode="ref")), true_ranks(merged, q2)
    )


def test_hotcache_coherent_through_mutation_lifecycle(rng):
    """Cache-on answers must stay bit-identical to a cache-off tier on
    the SAME state through every mutation arm: insert (host-buffered
    pending on a static kind), shard refresh, and fence rebalance."""
    from repro.core import as_table
    from repro.index import RMISpec
    from repro.serve.hotcache import HotKeyCache
    from repro.tune import RebuildPolicy, TunedTier

    table = as_table(rng.integers(1, 2**61, size=3000, dtype=np.uint64))
    policy = RebuildPolicy(shard_refresh_frac=10.0, retune_frac=10.0)
    tier = TunedTier(table, n_shards=4, policy=policy, spec=RMISpec(b=64))
    cache = HotKeyCache(tier, capacity=256)
    hot = rng.choice(table, size=200).astype(np.uint64)
    cache.sketch.update(hot)
    cache.rebuild()

    def qs():
        mix = np.concatenate(
            [
                rng.choice(table, size=64),
                rng.choice(hot, size=32),
                rng.integers(0, 2**61, size=32, dtype=np.uint64),
            ]
        )
        mix[0] = np.uint64(0)  # below-min: NO_PRED must round-trip too
        return mix

    def assert_coherent():
        q = qs()
        np.testing.assert_array_equal(
            np.asarray(cache.lookup(q, mode="ref")),
            np.asarray(tier.lookup(q, mode="ref")),
        )

    assert_coherent()
    # insert: static kind buffers host-side; pending keys are invisible
    # to BOTH paths until a refresh lands them — coherence must hold on
    # the tier's served (pre-refresh) state
    new = np.unique(rng.integers(1, 2**61, size=200, dtype=np.uint64))
    cache.insert_batch(new)
    assert tier.counters.pending > 0
    assert_coherent()
    # refresh: pending keys land, epoch bumps, the next cached lookup
    # detects staleness and rebuilds before serving
    for s in range(tier.sidx.n_shards):
        tier.refresh(s)
    assert cache.stale()
    assert_coherent()
    assert not cache.stale()  # the coherence lookup itself rebuilt
    # rebalance: fences move under the cache
    tier.rebalance(weights=np.array([8.0, 1.0, 1.0, 1.0]))
    assert cache.stale()
    assert_coherent()


def test_hotcache_stale_epoch_is_load_bearing(rng):
    """Negative control for the epoch check: force the cache to skip
    invalidation (rebuild_on_stale=False bypasses instead) and verify
    (a) the epoch comparison flags staleness after a mutation, and
    (b) with the check disabled entirely, served answers really would
    diverge — the seam the soak suite's seeded-bug fixture leans on."""
    from repro.core import as_table, true_ranks
    from repro.index import GappedSpec
    from repro.serve.hotcache import HotKeyCache
    from repro.tune import RebuildPolicy, TunedTier

    table = as_table(rng.integers(1, 2**61, size=2000, dtype=np.uint64))
    tier = TunedTier(
        table,
        n_shards=2,
        policy=RebuildPolicy(retune_frac=10.0),
        spec=GappedSpec(leaf_cap=64, fill=0.5, delta_cap=256),
    )
    cache = HotKeyCache(tier, capacity=128, rebuild_on_stale=False)
    hot = table[-64:].copy()
    cache.sketch.update(hot)
    cache.rebuild()
    assert not cache.stale()
    # a mutation bumps the epoch: the cache flags itself stale...
    below = np.setdiff1d(
        np.unique(rng.integers(1, int(table[0]), size=40, dtype=np.uint64)), table
    )
    cache.insert_batch(below)
    merged = np.union1d(table, below)
    assert cache.stale()
    # ...and the bypass arm serves tier-fresh (correct) answers anyway
    np.testing.assert_array_equal(
        np.asarray(cache.lookup(hot, mode="ref")), true_ranks(merged, hot)
    )
    stale = int(cache.metrics()["hotcache"]["stale_detected"])
    assert stale >= 1
    # (b) the resident ranks really are stale: replaying them against the
    # merged oracle diverges, so WITHOUT the epoch check these would have
    # been served as wrong answers
    resident = np.asarray(cache._ranks)[: cache.n_hot]
    assert not (resident == true_ranks(merged, hot)).all()
