"""Sorted Table Search procedures vs the numpy oracle (paper §3.1)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import search
from repro.core.cdf import true_ranks

from conftest import TABLE_KINDS, make_table, make_queries


@pytest.mark.parametrize("kind", TABLE_KINDS)
@pytest.mark.parametrize("n", [1, 2, 7, 100, 4096])
def test_bfs_bbs_ibs_tip(rng, kind, n):
    table = make_table(rng, kind, n)
    qs = make_queries(rng, table, 100)
    want = true_ranks(table, qs)
    tj, qj = jnp.asarray(table), jnp.asarray(qs)
    for name in ("bfs", "bbs", "ibs", "tip"):
        got = np.asarray(search.PROCEDURES[name](tj, qj))
        np.testing.assert_array_equal(got, want, err_msg=f"{name} {kind} n={n}")


@pytest.mark.parametrize("k", [3, 6, 15, 20, 128])
def test_kary(rng, k):
    table = make_table(rng, "clustered", 3000)
    qs = make_queries(rng, table, 200)
    want = true_ranks(table, qs)
    tj, qj = jnp.asarray(table), jnp.asarray(qs)
    np.testing.assert_array_equal(np.asarray(search.kbfs(tj, qj, k=k)), want)
    np.testing.assert_array_equal(np.asarray(search.kbbs(tj, qj, k=k)), want)


@pytest.mark.parametrize("kind", TABLE_KINDS)
@pytest.mark.parametrize("n", [1, 2, 15, 16, 1000])
def test_eytzinger(rng, kind, n):
    table = make_table(rng, kind, n)
    qs = make_queries(rng, table, 100)
    want = true_ranks(table, qs)
    layout, ranks, h = search.eytzinger_layout(table)
    got = np.asarray(
        search.bfe(jnp.asarray(layout), jnp.asarray(ranks), jnp.asarray(qs), height=h, n=len(table))
    )
    np.testing.assert_array_equal(got, want)


def test_bounded_bbs_branchy_windows(rng):
    """Branchy bounded epilogue (Index backend='bbs') honours windows."""
    table = make_table(rng, "clustered", 800)
    qs = make_queries(rng, table, 100)
    want = true_ranks(table, qs)
    lo = jnp.maximum(jnp.asarray(want) - 5, 0)
    hi = jnp.minimum(jnp.asarray(want) + 5, len(table) - 1)
    hi = jnp.maximum(hi, 0)
    got = np.asarray(search.bounded_bbs_branchy(jnp.asarray(table), jnp.asarray(qs), lo, hi))
    np.testing.assert_array_equal(got, want)


def test_bounded_upper_bound_windows(rng):
    """Bounded search honours arbitrary (lo, length) windows."""
    table = make_table(rng, "uniform", 500)
    q = jnp.asarray(rng.choice(table, 50))
    want = np.searchsorted(table, np.asarray(q), side="right")
    lo = jnp.maximum(jnp.asarray(want) - 7, 0)
    length = jnp.minimum(jnp.full(lo.shape, 20, dtype=jnp.int64), len(table) - lo)
    ub = search.bounded_upper_bound(jnp.asarray(table), q, lo, length, steps=6)
    np.testing.assert_array_equal(np.asarray(ub), want)
