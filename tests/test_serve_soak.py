"""Stateful serving soak suite: cache-fronted tier vs a sorted-numpy oracle.

The PR-9 acceptance harness: a :class:`SoakHarness` interleaves lookups,
inserts, compactions, refreshes, fence rebalances, and hot-key-cache
rebuilds against a plain sorted-numpy oracle, asserting bit-exactness
(including the ``NO_PRED``/``DROPPED`` sentinels) and structural
invariants after every operation.

Three profiles:

* **fast** (tier-1, hypothesis-free) — a deterministic scripted soak
  covering every operation type, plus the seeded-coherence-bug
  regression (the suite must *catch* a skipped cache invalidation).
* **hypothesis** (tier-1 when hypothesis is installed) — a
  ``RuleBasedStateMachine`` drawing random operation interleavings.
* **deep** (``-m soak``, the scheduled CI lane) — the same machine and
  script at much larger step counts.
"""

import numpy as np
import pytest

from repro.core import as_table, true_ranks
from repro.dist.sharded_index import DROPPED
from repro.index import GappedSpec
from repro.serve.hotcache import HotKeyCache
from repro.tune.rebuild import RebuildPolicy, TunedTier

try:
    from hypothesis import settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (
        RuleBasedStateMachine,
        initialize,
        invariant,
        rule,
        run_state_machine_as_test,
    )

    HAVE_HYPOTHESIS = True
except ImportError:  # hypothesis is a [test] extra, not baked into the image
    HAVE_HYPOTHESIS = False

# stay well under 2**64: max-key is GAPPED's pad/route sentinel, and the
# soak's near-miss probes (key+1) must never wrap
_KEYSPACE = 2**61


class SoakHarness:
    """A hot-key-cache-fronted ``TunedTier`` plus the oracle key set.

    Uses the updatable ``GAPPED`` kind so ingested keys are visible to
    lookups immediately (device-side absorb) — the oracle is simply the
    union of every key ever inserted, with no pending-visibility
    bookkeeping.  Static-kind (buffered-pending) coherence is covered by
    ``tests/test_data_serve.py``.
    """

    def __init__(self, seed: int, n0: int = 1200, n_shards: int = 4):
        self.rng = np.random.default_rng(seed)
        self.oracle = as_table(
            self.rng.integers(1, _KEYSPACE, size=n0, dtype=np.uint64)
        )
        self.tier = TunedTier(
            self.oracle,
            n_shards=n_shards,
            # retune_frac=10: the soak exercises refresh/rebalance/compact,
            # never the (expensive, spec-changing) full re-tune sweep
            policy=RebuildPolicy(retune_frac=10.0, shard_refresh_frac=0.25),
            spec=GappedSpec(leaf_cap=64, fill=0.5, delta_cap=256),
        )
        self.cache = HotKeyCache(self.tier, capacity=256)

    # -- operations (the state machine's rules call straight into these) --
    def queries(self, n: int = 96) -> np.ndarray:
        """A soak query mix: live keys, key+1 near-misses, uniform
        randoms, and a below-minimum probe (the ``NO_PRED`` arm)."""
        hits = self.rng.choice(self.oracle, size=n // 2)
        probes = self.rng.choice(self.oracle, size=n // 4) + np.uint64(1)
        rand = self.rng.integers(0, _KEYSPACE, size=n - len(hits) - len(probes))
        qs = np.concatenate([hits, probes, rand.astype(np.uint64)])
        qs[0] = np.uint64(0)  # below-min: the oracle answers NO_PRED (-1)
        return qs

    def do_lookup(self) -> None:
        qs = self.queries()
        want = true_ranks(self.oracle, qs)
        got = np.asarray(self.cache.lookup(qs))
        # the capacity-factored exchange may drop, but must never lie:
        # every non-dropped answer is the oracle's, sentinels included
        bad = (got != want) & (got != DROPPED)
        assert not bad.any(), (qs[bad][:8], got[bad][:8], want[bad][:8])

    def do_insert(self, n: int) -> None:
        new = np.unique(self.rng.integers(1, _KEYSPACE, size=n, dtype=np.uint64))
        self.cache.insert_batch(new)  # passthrough: tier absorbs device-side
        self.oracle = np.union1d(self.oracle, new)

    def do_compact(self) -> None:
        self.cache.maybe_compact()

    def do_refresh(self, s: int) -> None:
        self.tier.refresh(s % self.tier.sidx.n_shards)

    def do_rebalance(self) -> None:
        # direct trigger with a random traffic histogram: the windowed
        # drift detector is exercised separately (test_sharded_index)
        self.tier.rebalance(weights=self.rng.random(self.tier.sidx.n_shards))

    def do_cache_rebuild(self, n: int) -> None:
        self.cache.sketch.update(self.rng.choice(self.oracle, size=max(n, 1)))
        self.cache.rebuild()

    # -- invariants (asserted after every rule) ---------------------------
    def check(self) -> None:
        sidx = self.tier.sidx
        # the tier's merged live key set IS the oracle, bit for bit
        np.testing.assert_array_equal(self.tier._merged_table(), self.oracle)
        fences = np.asarray(sidx.fences)
        assert (fences[:-1] < fences[1:]).all(), "fences must stay strictly increasing"
        assert fences[0] == self.oracle[0], "first fence anchors the table minimum"
        # a derived read structure can lag the tier, never lead it
        assert self.cache.built_epoch <= self.tier.epoch
        # the drop-free reference sweep is bit-exact, sentinels included
        qs = self.queries(64)
        np.testing.assert_array_equal(
            np.asarray(self.tier.lookup(qs, mode="ref")), true_ranks(self.oracle, qs)
        )


def _scripted_soak(seed: int, rounds: int) -> SoakHarness:
    """The deterministic soak script: every operation type, every round."""
    h = SoakHarness(seed=seed)
    h.do_cache_rebuild(64)
    h.check()
    for r in range(rounds):
        h.do_lookup()
        h.do_insert(48 + 16 * (r % 3))
        h.check()
        if r % 2 == 0:
            h.do_compact()
        if r % 3 == 1:
            h.do_refresh(r)
        if r % 3 == 2:
            h.do_rebalance()
        h.do_cache_rebuild(32)
        h.check()
    return h


def test_scripted_soak_fast():
    h = _scripted_soak(seed=11, rounds=4)
    # the script must actually have exercised the mutation lifecycle
    m = h.tier.metrics()
    assert m["ingested"] > 0 and m["rebalances"] >= 1
    assert h.cache.metrics()["hotcache"]["rebuilds"] >= 5


@pytest.mark.soak
def test_scripted_soak_deep():
    h = _scripted_soak(seed=13, rounds=24)
    assert h.tier.metrics()["rebalances"] >= 8


def test_soak_catches_skipped_invalidation(monkeypatch):
    """The seeded-coherence-bug regression: if a tier mutation skips the
    epoch bump, the cache keeps serving pre-mutation ranks — and this
    suite's oracle comparison must catch exactly that.  The positive
    control (real epoch path) stays coherent on the same scenario."""
    h = SoakHarness(seed=7)
    hot = h.oracle[-64:].copy()  # top keys: any insert below them shifts their ranks
    h.cache.sketch.update(hot)
    h.cache.rebuild()
    below = np.unique(
        h.rng.integers(1, int(h.oracle[0]), size=32, dtype=np.uint64)
    )
    below = np.setdiff1d(below, h.oracle)
    assert len(below) > 0

    # positive control: the real epoch path detects the mutation and the
    # cached answers track the oracle
    h.do_insert(len(below) // 2 or 1)
    want = true_ranks(h.oracle, hot)
    np.testing.assert_array_equal(np.asarray(h.cache.lookup(hot)), want)

    # seed the bug: mutations stop bumping the staleness epoch
    stale_ranks = np.asarray(h.cache.lookup(hot)).copy()
    monkeypatch.setattr(TunedTier, "_bump_epoch", lambda self: None)
    h.cache.insert_batch(below)
    h.oracle = np.union1d(h.oracle, below)
    got = np.asarray(h.cache.lookup(hot))
    want = true_ranks(h.oracle, hot)
    assert not (got == want).all(), "soak oracle failed to catch the seeded bug"
    # and the divergence is precisely the stale pre-mutation ranks
    np.testing.assert_array_equal(got, stale_ranks)
    assert h.cache.metrics()["hotcache"]["stale"] is False  # undetected, as seeded


if HAVE_HYPOTHESIS:

    class ServingSoakMachine(RuleBasedStateMachine):
        """Random interleavings of the soak operations; every rule ends
        in the full invariant check against the numpy oracle."""

        @initialize(seed=st.integers(min_value=0, max_value=2**16))
        def setup(self, seed):
            self.h = SoakHarness(seed=seed, n0=600, n_shards=4)

        @rule()
        def lookup(self):
            self.h.do_lookup()

        @rule(n=st.integers(min_value=1, max_value=96))
        def insert(self, n):
            self.h.do_insert(n)

        @rule()
        def compact(self):
            self.h.do_compact()

        @rule(s=st.integers(min_value=0, max_value=7))
        def refresh(self, s):
            self.h.do_refresh(s)

        @rule()
        def rebalance(self):
            self.h.do_rebalance()

        @rule(n=st.integers(min_value=1, max_value=64))
        def rebuild_cache(self, n):
            self.h.do_cache_rebuild(n)

        @invariant()
        def oracle_invariants(self):
            if hasattr(self, "h"):
                self.h.check()


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not baked into the image")
def test_soak_machine_fast():
    run_state_machine_as_test(
        ServingSoakMachine,
        settings=settings(max_examples=3, stateful_step_count=6, deadline=None),
    )


@pytest.mark.soak
@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not baked into the image")
def test_soak_machine_deep():
    run_state_machine_as_test(
        ServingSoakMachine,
        settings=settings(max_examples=15, stateful_step_count=30, deadline=None),
    )
