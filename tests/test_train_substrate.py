"""Training substrate: optimizers, compression, checkpoint/restart,
fault-tolerant loop semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.dist import collectives
from repro.train import TrainConfig, checkpoint, init_train_state, loop, make_train_step


def quad_loss(params, batch):
    return jnp.sum((params["w"] - batch["target"]) ** 2)


def _mk_state(tcfg):
    init_fn = lambda r: {"w": jnp.ones((4, 8), jnp.float32) * 5.0}
    return init_train_state(jax.random.key(0), init_fn, tcfg)


def test_adamw_converges():
    tcfg = TrainConfig(optimizer="adamw", lr=0.2, weight_decay=0.0, schedule="constant")
    step = jax.jit(make_train_step(quad_loss, tcfg))
    state = _mk_state(tcfg)
    batch = {"target": jnp.zeros((4, 8))}
    for _ in range(200):
        state, m = step(state, batch)
    assert float(m["loss"]) < 1e-2


def test_adafactor_converges():
    tcfg = TrainConfig(optimizer="adafactor", lr=0.5, schedule="constant")
    step = jax.jit(make_train_step(quad_loss, tcfg))
    state = _mk_state(tcfg)
    batch = {"target": jnp.zeros((4, 8))}
    for _ in range(300):
        state, m = step(state, batch)
    assert float(m["loss"]) < 1.0


def test_grad_clipping():
    tcfg = TrainConfig(lr=1e-3, grad_clip=0.5, schedule="constant")
    step = jax.jit(make_train_step(quad_loss, tcfg))
    state = _mk_state(tcfg)
    _, m = step(state, {"target": jnp.zeros((4, 8)) + 1000.0})
    assert float(m["grad_norm"]) > 0.5  # raw norm reported pre-clip


def test_microbatch_equivalence():
    """4 microbatches of N == 1 batch of 4N (same grads for linear loss)."""
    def loss(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
    init_fn = lambda r: {"w": jnp.zeros((8, 4), jnp.float32)}

    t1 = TrainConfig(lr=0.1, microbatches=1, schedule="constant")
    t4 = TrainConfig(lr=0.1, microbatches=4, schedule="constant")
    s1 = init_train_state(jax.random.key(0), init_fn, t1)
    s4 = init_train_state(jax.random.key(0), init_fn, t4)
    s1, _ = jax.jit(make_train_step(loss, t1))(s1, {"x": x, "y": y})
    s4, _ = jax.jit(make_train_step(loss, t4))(s4, {"x": x, "y": y})
    np.testing.assert_allclose(
        np.asarray(s1["params"]["w"]), np.asarray(s4["params"]["w"]), rtol=2e-5, atol=2e-6
    )


@pytest.mark.parametrize("method", ["bf16", "int8"])
def test_grad_compression_error_feedback(method):
    g = jnp.asarray(np.linspace(-1, 1, 64, dtype=np.float32).reshape(8, 8))
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    # accumulated compressed grads converge to accumulated true grads
    for i in range(50):
        gh, err = collectives.compressed_grad_leaf(g, err, method)
        total = total + gh
    rel = float(jnp.linalg.norm(total - 50 * g) / jnp.linalg.norm(50 * g))
    assert rel < 0.02, rel


def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "opt": {"m": jnp.ones((3, 4)), "step": jnp.int32(7)},
    }
    checkpoint.save(tmp_path, state, step=7, async_write=False)
    assert checkpoint.latest_step(tmp_path) == 7
    restored, step = checkpoint.restore(tmp_path, state)
    assert step == 7
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )
    assert int(restored["opt"]["step"]) == 7


def test_checkpoint_detects_corruption(tmp_path):
    state = {"w": jnp.ones((4,))}
    checkpoint.save(tmp_path, state, step=1, async_write=False)
    # corrupt the leaf file
    leaf = next((tmp_path / "step_1").glob("leaf_*.npy"))
    arr = np.load(leaf)
    arr[0] = 999
    np.save(leaf, arr)
    with pytest.raises(IOError):
        checkpoint.restore(tmp_path, state)


def test_restart_equivalence(tmp_path):
    """Kill at step 6, restore, continue -> identical params to a
    straight-through run (pure-function-of-step batcher)."""
    def loss(params, batch):
        return jnp.mean((params["w"] - batch["t"]) ** 2)

    def batch_at(step):
        return {"t": jnp.full((4,), float(step % 3), jnp.float32)}

    init_fn = lambda r: {"w": jnp.zeros((4,), jnp.float32)}
    tcfg = TrainConfig(lr=0.05, schedule="constant")
    step_fn = jax.jit(make_train_step(loss, tcfg))

    # uninterrupted reference
    ref = init_train_state(jax.random.key(0), init_fn, tcfg)
    for s in range(12):
        ref, _ = step_fn(ref, batch_at(s))

    # interrupted run: 6 steps, checkpoint, "crash", restore, continue
    d1 = tmp_path / "ckpt"
    st = init_train_state(jax.random.key(0), init_fn, tcfg)
    st, rep = loop.run(
        step_fn,
        st,
        batch_at,
        loop.LoopConfig(total_steps=6, ckpt_dir=str(d1), ckpt_every=3, log_every=0),
    )
    st2 = init_train_state(jax.random.key(0), init_fn, tcfg)  # fresh process
    st2, rep2 = loop.run(
        step_fn,
        st2,
        batch_at,
        loop.LoopConfig(total_steps=12, ckpt_dir=str(d1), ckpt_every=100, log_every=0),
    )
    assert rep2.restored_from == 6
    np.testing.assert_allclose(
        np.asarray(st2["params"]["w"]), np.asarray(ref["params"]["w"]), rtol=1e-6
    )


def test_preemption_checkpoint(tmp_path):
    def loss(params, batch):
        return jnp.sum(params["w"] ** 2)

    init_fn = lambda r: {"w": jnp.ones((2,), jnp.float32)}
    tcfg = TrainConfig(lr=0.01, schedule="constant")
    step_fn = jax.jit(make_train_step(loss, tcfg))
    st = init_train_state(jax.random.key(0), init_fn, tcfg)
    flag = {"n": 0}

    def preempt():
        flag["n"] += 1
        return flag["n"] >= 4

    st, rep = loop.run(
        step_fn,
        st,
        lambda s: {},
        loop.LoopConfig(total_steps=100, ckpt_dir=str(tmp_path), ckpt_every=0, log_every=0),
        preempt_flag=preempt,
    )
    assert rep.preempted
    assert checkpoint.latest_step(tmp_path) == rep.final_step
