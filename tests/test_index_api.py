"""Unified Index API: registry completeness, pytree round-trips, npz
save/load, backend parity, and the shared-jit trace-count guarantee.

These are the acceptance tests of the api_redesign PR: an index is a
pytree of flat arrays driven by ONE jitted lookup per kind — not a
Python object closed over by a fresh ``jax.jit`` per model.
"""

import os

import numpy as np
import jax
import pytest

from repro import index as ix
from repro.core.cdf import true_ranks
from repro.data import distributions

from conftest import make_table, make_queries

# one cheap spec per registered kind (covers the whole registry)
SPEC_PER_KIND = {
    "L": ix.AtomicSpec(degree=1),
    "Q": ix.AtomicSpec(degree=2),
    "C": ix.AtomicSpec(degree=3),
    "KO": ix.KOSpec(k=7),
    "RMI": ix.RMISpec(b=64, root_type="linear"),
    "SY-RMI": ix.SYRMISpec(space_pct=2.0, ub=0.04),
    "PGM": ix.PGMSpec(eps=32),
    "PGM_M": ix.PGMBicriteriaSpec(space_pct=2.0, a=1.0),
    "RS": ix.RSSpec(eps=16, r_bits=8),
    "BTREE": ix.BTreeSpec(fanout=8),
    "GAPPED": ix.GappedSpec(leaf_cap=64, fill=0.75, delta_cap=256),
}


def _tables(rng, n=4000):
    uniform = make_table(rng, "uniform", n)
    osm = np.unique(distributions.generate("osm", n, seed=11))
    return {"uniform": uniform, "osm": osm}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_completeness():
    """Every paper kind is registered, in the paper's order, plus the
    updatable GAPPED kind appended by the mutation-API redesign."""
    assert ix.kinds() == (
        "L", "Q", "C", "KO", "RMI", "SY-RMI", "PGM", "PGM_M", "RS", "BTREE", "GAPPED",
    )
    assert set(SPEC_PER_KIND) == set(ix.kinds())
    for kind in ix.kinds():
        e = ix.entry(kind)
        assert e.kind == kind
        assert callable(e.build)
        # loose-params shim constructs the right spec class
        assert isinstance(ix.spec_for(kind), e.spec_cls)


def test_registry_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown index kind"):
        ix.entry("ZZTREE")


def test_spec_hashable_and_named():
    seen = {s for s in SPEC_PER_KIND.values()}  # hashable
    assert len(seen) == len(SPEC_PER_KIND)
    assert ix.RMISpec(b=64).display_name() == "RMI[b=64,root_type=linear]"
    assert ix.AtomicSpec(degree=2).kind == "Q"


# ---------------------------------------------------------------------------
# Pytree round-trip under jit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", list(SPEC_PER_KIND))
def test_pytree_roundtrip_under_jit(rng, kind):
    table = _tables(rng)["uniform"]
    idx = ix.build(SPEC_PER_KIND[kind], table)

    leaves, treedef = jax.tree_util.tree_flatten(idx)
    assert all(hasattr(l, "dtype") for l in leaves), "leaves must be arrays"
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.kind == idx.kind and rebuilt.static == idx.static

    through = jax.jit(lambda i: i)(idx)  # Index passes through jit boundaries
    assert through.kind == idx.kind and through.static == idx.static
    for k in idx.arrays:
        np.testing.assert_array_equal(np.asarray(through.arrays[k]), np.asarray(idx.arrays[k]))
    # and it still answers queries exactly
    qs = make_queries(rng, table, 100)
    got = np.asarray(through.lookup(table, qs))
    np.testing.assert_array_equal(got, true_ranks(table, qs))


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", list(SPEC_PER_KIND))
def test_save_load_bit_exact(rng, kind, tmp_path):
    """Acceptance: Index.save/load round-trips every registered kind."""
    table = _tables(rng)["osm"]
    idx = ix.build(SPEC_PER_KIND[kind], table)
    path = os.path.join(tmp_path, f"{kind}.npz")
    idx.save(path)
    idx2 = ix.Index.load(path)
    assert idx2.kind == idx.kind
    assert idx2.static == idx.static
    assert set(idx2.arrays) == set(idx.arrays)
    for k, v in idx.arrays.items():
        a, b = np.asarray(v), np.asarray(idx2.arrays[k])
        assert a.dtype == b.dtype, k
        np.testing.assert_array_equal(a, b, err_msg=k)
    assert idx2.space_bytes() == idx.space_bytes()
    qs = make_queries(rng, table, 100)
    np.testing.assert_array_equal(
        np.asarray(idx2.lookup(table, qs)), np.asarray(idx.lookup(table, qs))
    )


# ---------------------------------------------------------------------------
# Backend parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("table_kind", ["uniform", "osm"])
@pytest.mark.parametrize("kind", list(SPEC_PER_KIND))
def test_backend_parity(rng, kind, table_kind, backend):
    """xla == ref == bbs == pallas (interpret mode) on every kind.

    ``backend`` comes from the conftest fixture driven by
    ``REPRO_TEST_BACKENDS`` — one CI matrix leg per backend."""
    table = _tables(rng)[table_kind]
    qs = make_queries(rng, table, 200)
    want = true_ranks(table, qs)
    idx = ix.build(SPEC_PER_KIND[kind], table)
    if backend not in idx.backends():
        # honest claims: an unimplemented backend is a loud error, not a
        # silent fallback (GAPPED has no pallas path yet)
        with pytest.raises(ValueError, match="supports backends"):
            idx.lookup(table, qs, backend=backend)
        return
    got = np.asarray(idx.lookup(table, qs, backend=backend))
    np.testing.assert_array_equal(got, want, err_msg=f"{kind}/{backend}")


# ---------------------------------------------------------------------------
# Shared jitted lookup: trace counts
# ---------------------------------------------------------------------------


def test_single_trace_per_kind_across_instances(rng):
    """The headline of the redesign: N same-structure models of a kind
    share exactly ONE trace of the shared lookup (the old API paid one
    ``jax.jit`` closure trace per model)."""
    n = 4096
    tables = [make_table(np.random.default_rng(s), "uniform", n) for s in (1, 2, 3)]
    tables = [t[:4000] for t in tables]  # identical shapes across instances
    qs = tables[0][:256].astype(np.uint64)

    ix.reset_trace_counts()
    for t in tables:
        idx = ix.build(ix.RMISpec(b=64), t)
        idx.lookup(t, qs)
    counts = ix.trace_counts()
    assert counts == {("RMI", "xla"): 1}, counts

    # a different kind gets its own (single) trace; same kind again: none
    ix.reset_trace_counts()
    for t in tables:
        ix.build(ix.BTreeSpec(fanout=8), t).lookup(t, qs)
        ix.build(ix.RMISpec(b=64), t).lookup(t, qs)
    counts = ix.trace_counts()
    assert counts.get(("BTREE", "xla")) == 1, counts
    assert counts.get(("RMI", "xla"), 0) == 0, counts  # cache survived the reset window


def test_parametric_budget_sweep_traces_bounded(rng):
    """The query_parametric scenario: a sweep of SY-RMI space budgets
    over several same-tier tables compiles once per distinct budget
    (array structure), not once per model — 6 models, <= 3 traces."""
    n = 4000
    t1 = make_table(np.random.default_rng(7), "uniform", 4300)[:n]
    t2 = make_table(np.random.default_rng(8), "uniform", 4300)[:n]
    qs = t1[:256].astype(np.uint64)

    ix.reset_trace_counts()
    n_models = 0
    for t in (t1, t2):
        for pct in (0.5, 1.0, 2.0):
            ix.build(ix.SYRMISpec(space_pct=pct, ub=0.04), t).lookup(t, qs)
            n_models += 1
    counts = ix.trace_counts()
    assert n_models == 6
    assert sum(counts.values()) <= 3, counts


def test_info_metadata_passthrough(rng):
    """Build metadata (name, eps, ...) rides on the host-side Index but
    never enters the pytree (so it cannot fragment jit caches)."""
    table = _tables(rng)["uniform"]
    idx = ix.build(ix.PGMSpec(eps=32), table)
    assert idx.eps == 32
    assert idx.n_segments_l0 >= 1
    assert idx.name.startswith("PGM")
    _, treedef = jax.tree_util.tree_flatten(idx)
    idx2 = jax.tree_util.tree_unflatten(treedef, jax.tree_util.tree_flatten(idx)[0])
    assert idx2.info == {}  # metadata intentionally dropped
