"""GAPPED + mutation-API edge cases.

The broad-strokes coverage (registry completeness, backend parity on
random tables, kernel rejection) lives in ``test_index_api.py``; this
file pins the *corners* of the absorb -> overflow -> compact -> retune
lifecycle: fence-key inserts, duplicate routing, a delta filled to
exactly its capacity, predecessors answered from each tier, the
per-kind updatability capability, trace-count discipline, and the
sharded/tier write surface (donated shard swaps, deprecation wrappers).

Tests use *local* rngs on purpose: the shared session ``rng`` fixture
is a single stream, and drawing from it here would shift every
downstream test's tables.
"""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro import index as ix
from repro.core.cdf import true_ranks
from repro.dist import NO_PRED
from repro.index import GappedSpec, NeedsRebuild, build, updatable_kinds
from repro.index.updatable import live_keys

_MAXKEY = np.uint64(2**64 - 1)


def _lookup(idx, queries, backend="xla"):
    # GAPPED is self-contained: the table argument is a stale snapshot
    # and deliberately ignored, so any placeholder works
    return np.asarray(idx.lookup(jnp.zeros(1, jnp.uint64), jnp.asarray(queries), backend=backend))


def _assert_exact(idx, merged, queries):
    want = true_ranks(merged, np.asarray(queries))
    for be in idx.backends():
        got = _lookup(idx, queries, backend=be)
        np.testing.assert_array_equal(got, want, err_msg=be)


# ---------------------------------------------------------------------------
# capability: updatability is per-kind
# ---------------------------------------------------------------------------


def test_updatable_kinds_capability():
    assert updatable_kinds() == ("GAPPED",)
    table = np.arange(1, 65, dtype=np.uint64) * np.uint64(977)
    static = build("RMI", table, b=8, root_type="linear")
    with pytest.raises(TypeError, match="updatable"):
        static.insert_batch(np.asarray([np.uint64(5)]))
    with pytest.raises(TypeError, match="updatable"):
        static.compact()


def test_compact_on_fresh_build_is_identity_on_answers():
    table = np.arange(1, 129, dtype=np.uint64) * np.uint64(1009)
    g = build("GAPPED", table, leaf_cap=16, fill=0.5, delta_cap=32)
    g2 = g.compact()
    assert int(np.asarray(g2.arrays["delta_count"])) == 0
    q = np.concatenate([table, table - np.uint64(1), [np.uint64(0), _MAXKEY]])
    _assert_exact(g2, table, q)


def test_empty_batch_is_a_noop():
    table = np.arange(1, 65, dtype=np.uint64) * np.uint64(13)
    g = build("GAPPED", table, leaf_cap=16, fill=0.5, delta_cap=32)
    g2, rep = g.insert_batch(np.asarray([], dtype=np.uint64))
    assert g2 is g
    assert (rep.requested, rep.absorbed, rep.overflowed, rep.duplicates) == (0, 0, 0, 0)
    assert rep.delta_count == 0 and not rep.compacted and not rep.needs_compaction


# ---------------------------------------------------------------------------
# fence keys, duplicates, below-min inserts
# ---------------------------------------------------------------------------


def test_insert_exactly_at_fence_keys_is_duplicate():
    table = np.arange(1, 129, dtype=np.uint64) * np.uint64(101)
    g = build("GAPPED", table, leaf_cap=16, fill=0.5, delta_cap=64)
    fences = np.asarray(g.arrays["fences"])
    g2, rep = g.insert_batch(fences)
    assert rep.duplicates == len(fences) and rep.absorbed == rep.overflowed == 0
    _assert_exact(g2, table, fences)


def test_insert_just_below_fences_lands_in_previous_leaf():
    table = np.arange(1, 129, dtype=np.uint64) * np.uint64(100)
    g = build("GAPPED", table, leaf_cap=16, fill=0.5, delta_cap=64)
    fences = np.asarray(g.arrays["fences"])
    probe = np.setdiff1d(fences[1:] - np.uint64(1), table)
    g2, rep = g.insert_batch(probe)
    assert rep.absorbed + rep.overflowed == len(probe) and rep.duplicates == 0
    merged = np.union1d(table, probe)
    q = np.concatenate([merged, probe + np.uint64(1), probe - np.uint64(1)])
    _assert_exact(g2, merged, q)
    np.testing.assert_array_equal(live_keys(g2), merged)


def test_duplicates_batch_internal_and_cross_tier():
    table = np.arange(1, 65, dtype=np.uint64) * np.uint64(1000)
    g = build("GAPPED", table, leaf_cap=8, fill=0.5, delta_cap=32)
    first = np.asarray([1500, 2500], dtype=np.uint64)
    g, rep = g.insert_batch(first)
    assert rep.absorbed + rep.overflowed == 2
    # one batch-internal dup, one dup vs the main tier, one dup vs the
    # keys just inserted (leaf or delta), and one genuinely fresh key
    batch = np.asarray([3500, 3500, 1000, 1500, 4500], dtype=np.uint64)
    g, rep = g.insert_batch(batch)
    assert rep.requested == 5
    assert rep.duplicates == 3
    assert rep.absorbed + rep.overflowed == 2
    merged = np.union1d(table, [1500, 2500, 3500, 4500])
    np.testing.assert_array_equal(live_keys(g), merged)
    _assert_exact(g, merged, np.concatenate([merged, merged + np.uint64(1)]))


def test_insert_below_minimum_key():
    table = (np.arange(1, 65, dtype=np.uint64) + np.uint64(100)) * np.uint64(50)
    g = build("GAPPED", table, leaf_cap=16, fill=0.5, delta_cap=32)
    below = np.asarray([7, 23], dtype=np.uint64)
    g, rep = g.insert_batch(below)
    assert rep.absorbed + rep.overflowed == 2
    merged = np.union1d(table, below)
    q = np.asarray([0, 6, 7, 8, 22, 23, 24, int(table[0])], dtype=np.uint64)
    _assert_exact(g, merged, q)
    assert _lookup(g, np.asarray([6], dtype=np.uint64))[0] == NO_PRED  # below new min


# ---------------------------------------------------------------------------
# delta buffer: all-or-nothing leaf absorption, exact-capacity fill
# ---------------------------------------------------------------------------


def _crowded_leaf_setup():
    """64 well-spaced keys, leaf 0 covering [1000, 4000): its 4 gap
    slots cannot take an 8-key batch, so absorption (all-or-nothing per
    leaf) diverts the whole batch to the delta."""
    table = np.arange(1, 65, dtype=np.uint64) * np.uint64(1000)
    g = build("GAPPED", table, leaf_cap=8, fill=0.5, delta_cap=16)
    assert int(g.arrays["keys"].shape[1]) == 8
    assert int(np.asarray(g.arrays["counts"])[0]) == 4
    return table, g


def test_overfull_leaf_batch_diverts_wholesale_to_delta():
    table, g = _crowded_leaf_setup()
    batch = np.uint64(1000) + np.arange(1, 9, dtype=np.uint64) * np.uint64(100)
    g, rep = g.insert_batch(batch)
    assert rep.absorbed == 0 and rep.overflowed == 8
    merged = np.union1d(table, batch)
    _assert_exact(g, merged, np.concatenate([merged, batch + np.uint64(1)]))


def test_delta_filled_to_exactly_its_capacity():
    table, g = _crowded_leaf_setup()
    b1 = np.uint64(1000) + np.arange(1, 9, dtype=np.uint64) * np.uint64(100)
    b2 = np.uint64(2000) + np.arange(1, 9, dtype=np.uint64) * np.uint64(100)
    g, r1 = g.insert_batch(b1)
    g, r2 = g.insert_batch(b2)
    assert r1.overflowed == r2.overflowed == 8
    assert r2.delta_count == r2.delta_cap == 16  # exactly full, no raise
    assert r2.delta_fill == 1.0 and r2.needs_compaction and not r2.compacted
    merged = np.union1d(table, np.concatenate([b1, b2]))
    _assert_exact(g, merged, merged)

    # another leaf-0-crowding batch (5 keys > the 4 free gaps, so it
    # overflows) would push the delta past 16: auto_compact=False must
    # refuse...
    b3 = np.uint64(3000) + np.arange(1, 6, dtype=np.uint64) * np.uint64(20)
    with pytest.raises(NeedsRebuild, match="compact"):
        g.insert_batch(b3, auto_compact=False)
    # ...and the default folds the delta first, then retries the batch
    g2, r3 = g.insert_batch(b3)
    assert r3.compacted and r3.absorbed + r3.overflowed == 5
    merged = np.union1d(merged, b3)
    _assert_exact(g2, merged, merged)
    np.testing.assert_array_equal(live_keys(g2), merged)


def test_needs_rebuild_on_capacity_exhaustion():
    table = np.arange(1, 9, dtype=np.uint64) * np.uint64(1 << 32)
    g = build("GAPPED", table, leaf_cap=4, fill=1.0, delta_cap=4)
    # leaves are built full (fill=1.0): every fresh key overflows, and
    # compaction cannot rebalance past L*cap live keys
    rng = np.random.default_rng(5)
    with pytest.raises(NeedsRebuild, match="larger spec"):
        for _ in range(16):
            batch = rng.integers(1, 1 << 35, size=4, dtype=np.uint64)
            g, _ = g.insert_batch(np.setdiff1d(batch, live_keys(g)))


# ---------------------------------------------------------------------------
# predecessors answered from each tier
# ---------------------------------------------------------------------------


def test_predecessor_from_leaf_delta_and_merged_tiers():
    table, g = _crowded_leaf_setup()
    batch = np.uint64(1000) + np.arange(1, 9, dtype=np.uint64) * np.uint64(100)
    g, rep = g.insert_batch(batch)
    assert rep.overflowed == 8  # the whole batch lives in the delta
    merged = np.union1d(table, batch)
    # predecessor key in the delta only (1150 -> 1100), in the main
    # tier only (64000+5 -> 64000), the shared boundary (2000+1 ->
    # 2000), and below everything (-> NO_PRED)
    q = np.asarray([1150, 64005, 2001, 999], dtype=np.uint64)
    want = true_ranks(merged, q)
    assert want[-1] == NO_PRED
    for be in g.backends():
        np.testing.assert_array_equal(_lookup(g, q, backend=be), want, err_msg=be)


def test_backend_parity_after_inserts(backend):
    rng = np.random.default_rng(77)
    table = np.unique(rng.integers(1, 2**62, size=2000, dtype=np.uint64))
    g = build("GAPPED", table, leaf_cap=64, fill=0.75, delta_cap=256)
    fresh = np.setdiff1d(
        np.unique(rng.integers(1, 2**62, size=300, dtype=np.uint64)), table
    )
    g, rep = g.insert_batch(fresh)
    assert rep.absorbed + rep.overflowed == len(fresh)
    merged = np.union1d(table, fresh)
    q = np.concatenate([rng.choice(merged, 256), rng.integers(0, 2**62, 256, dtype=np.uint64)])
    q = q.astype(np.uint64)
    if backend not in g.backends():
        with pytest.raises(ValueError, match="supports backends"):
            _lookup(g, q, backend=backend)
        return
    np.testing.assert_array_equal(_lookup(g, q, backend=backend), true_ranks(merged, q))


# ---------------------------------------------------------------------------
# trace discipline: pow2-bucketed insert batches, one compact trace
# ---------------------------------------------------------------------------


def test_insert_traces_bucket_by_batch_size():
    table = np.arange(1, 257, dtype=np.uint64) * np.uint64(10_000)
    g = build("GAPPED", table, leaf_cap=16, fill=0.5, delta_cap=64)
    ix.reset_trace_counts()
    base = np.uint64(5)
    g, _ = g.insert_batch(base + np.arange(3, dtype=np.uint64))  # bucket 4
    g, _ = g.insert_batch(base + np.uint64(100) + np.arange(4, dtype=np.uint64))  # bucket 4
    g, _ = g.insert_batch(base + np.uint64(200) + np.arange(5, dtype=np.uint64))  # bucket 8
    counts = ix.trace_counts()
    assert counts[("GAPPED", "insert")] == 2  # two pow2 buckets, three batches
    g = g.compact()
    g2, _ = g.insert_batch(base + np.uint64(300) + np.arange(6, dtype=np.uint64))  # bucket 8
    counts = ix.trace_counts()
    assert counts[("GAPPED", "insert")] == 2
    assert counts[("GAPPED", "compact")] == 1


# ---------------------------------------------------------------------------
# sharded + tier write surface
# ---------------------------------------------------------------------------


def test_sharded_insert_compact_and_fence_discipline():
    from repro.dist import ShardedIndex, compact_shard, insert_into_shard, sharded_lookup
    from repro.dist.sharded_index import route_owners

    rng = np.random.default_rng(3)
    table = np.unique(rng.integers(1, 2**62, size=3000, dtype=np.uint64))
    spec = GappedSpec(leaf_cap=64, fill=0.75, delta_cap=128)
    sidx = ShardedIndex.build(spec, table, n_shards=4)

    fresh = np.setdiff1d(np.unique(rng.integers(1, 2**62, size=400, dtype=np.uint64)), table)
    owners = np.asarray(route_owners(sidx.fences, fresh))
    for s in range(4):
        mine = fresh[owners == s]
        if len(mine):
            sidx, rep = insert_into_shard(sidx, s, mine)
            assert rep.absorbed + rep.overflowed + rep.duplicates == len(mine)
    merged = np.union1d(table, fresh)
    q = np.concatenate([rng.choice(merged, 256), rng.integers(0, 2**62, 256, dtype=np.uint64)])
    q = q.astype(np.uint64)
    for be in ("xla", "bbs", "ref"):
        got = np.asarray(sharded_lookup(sidx, q, mode="ref", backend=be))
        np.testing.assert_array_equal(got, true_ranks(merged, q), err_msg=be)

    for s in range(4):
        sidx = compact_shard(sidx, s)
    assert int(np.asarray(sidx.index.arrays["delta_count"]).sum()) == 0
    got = np.asarray(sharded_lookup(sidx, q, mode="ref"))
    np.testing.assert_array_equal(got, true_ranks(merged, q))

    # a key owned by shard 3 cannot be inserted into shard 0
    stray = np.asarray([merged[-1] - np.uint64(1)], dtype=np.uint64)
    if int(route_owners(sidx.fences, stray)[0]) != 0:
        with pytest.raises(ValueError, match="fence"):
            insert_into_shard(sidx, 0, stray)


def test_tuned_tier_gapped_absorbs_without_rebuilds():
    from repro.tune import RebuildPolicy, TunedTier

    rng = np.random.default_rng(9)
    table = np.unique(rng.integers(1, 2**62, size=4000, dtype=np.uint64))
    tier = TunedTier(
        table,
        n_shards=4,
        policy=RebuildPolicy(
            shard_refresh_frac=0.02, retune_frac=5.0, n_queries=128, kinds=("GAPPED", "RMI")
        ),
        spec=GappedSpec(leaf_cap=64, fill=0.75, delta_cap=128),
    )
    drift = np.setdiff1d(np.unique(rng.integers(1, 2**62, size=600, dtype=np.uint64)), table)
    tier.insert_batch(drift)
    c = tier.counters
    assert c.absorbed + c.overflowed == len(drift)
    assert c.shard_refreshes == 0 and c.forced_restacks == 0 and c.retunes == 0
    merged = np.union1d(table, drift)
    q = rng.choice(merged, 512).astype(np.uint64)
    np.testing.assert_array_equal(
        np.asarray(tier.lookup(q, mode="ref")), true_ranks(merged, q)
    )


def test_tier_deprecation_wrappers_still_work():
    from repro.tune import RebuildPolicy, TunedTier

    rng = np.random.default_rng(11)
    table = np.unique(rng.integers(1, 2**62, size=1500, dtype=np.uint64))
    tier = TunedTier(
        table,
        n_shards=2,
        policy=RebuildPolicy(shard_refresh_frac=0.5, retune_frac=5.0, n_queries=64),
        spec=GappedSpec(leaf_cap=64, fill=0.75, delta_cap=128),
    )
    fresh = np.setdiff1d(np.asarray([12345, 67890], dtype=np.uint64), table)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        tier.ingest(fresh)  # -> insert_batch
        tier.maybe_rebuild()  # -> maybe_compact
    assert sum(issubclass(x.category, DeprecationWarning) for x in w) == 2
    merged = np.union1d(table, fresh)
    q = rng.choice(merged, 256).astype(np.uint64)
    np.testing.assert_array_equal(
        np.asarray(tier.lookup(q, mode="ref")), true_ranks(merged, q)
    )
