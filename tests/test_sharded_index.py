"""Sharded multi-table lookup: stacking, routing, SPMD modes, refresh.

In-process tests cover the vmapped fallback path on whatever devices the
test process has, plus the shard_map a2a/allgather paths whenever the
process was started with enough (possibly forced) devices — the CI
``multihost`` leg sets ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
so these run on a real 4-way mesh there.  A subprocess test (the
``test_multidevice`` pattern) forces a 4-device CPU platform even when
the main process is single-device, so the collective paths are always
exercised by a plain local ``pytest`` run too.
"""

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax
import pytest

from repro import index as ix
from repro.core.cdf import true_ranks
from repro.dist import sharded_index as si
from repro.dist.sharding import ShardingCtx
from repro.index import registry

from conftest import make_table, make_queries

N = 2048
PARAMS_PER_KIND = {
    "L": {},
    "Q": {},
    "C": {},
    "KO": {"k": 7},
    "RMI": {"b": 64},
    "SY-RMI": {"space_pct": 2.0, "ub": 0.04},
    "PGM": {"eps": 32},
    "PGM_M": {"space_pct": 2.0, "a": 1.0},
    "RS": {"eps": 16, "r_bits": 8},
    "BTREE": {"fanout": 8},
}


def _table_and_queries(rng, n=N, nq=256):
    table = make_table(rng, "uniform", n)
    qs = make_queries(rng, table, nq)
    return table, qs


def _mesh_ctx(n_shards):
    """A mesh whose tp extent is ``n_shards``, or None if the process
    does not have enough devices."""
    if len(jax.devices()) < n_shards:
        return None
    mesh = jax.make_mesh((1, n_shards), ("data", "model"))
    return ShardingCtx(mesh=mesh)  # tp_fsdp: tp -> model


# ---------------------------------------------------------------------------
# ShardingCtx.n / mesh_axes (the router reads both)
# ---------------------------------------------------------------------------


def test_sharding_ctx_n_resolved_product():
    """n() returns the resolved product over every mesh axis a logical
    axis occupies — including size-1-padded axes — and normalises
    string-valued rules instead of iterating their characters."""
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    ctx = ShardingCtx(mesh=mesh)
    assert ctx.mesh_axes("dp") == ("pod", "data")
    assert ctx.n("dp") == 1  # 1 * 1, both axes resolved
    assert ctx.n("tp") == 1

    # a bare-string rule must mean ONE mesh axis, not iter("model")
    ctx_s = ShardingCtx(mesh=mesh, rules={"tp": "model", "dp": ("pod", "data")})
    assert ctx_s.mesh_axes("tp") == ("model",)
    assert ctx_s.n("tp") == 1

    # unmapped -> 1; unknown mesh axis -> loud error, not silent 1
    assert ctx.n("nonexistent") == 1
    ctx_bad = ShardingCtx(mesh=mesh, rules={"tp": ("ghost",)})
    with pytest.raises(ValueError, match="ghost"):
        ctx_bad.n("tp")


def test_sharding_ctx_n_multidevice_extent():
    ctx = _mesh_ctx(len(jax.devices()))
    assert ctx is not None
    assert ctx.n("tp") == len(jax.devices())


# ---------------------------------------------------------------------------
# Build + stack + fallback lookup: bit-exact vs the concatenated table
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", list(PARAMS_PER_KIND))
def test_sharded_matches_concat_reference(rng, kind, backend):
    """Acceptance: sharded lookup == single-table Index.lookup on the
    concatenated table, for every registered kind."""
    table, qs = _table_and_queries(rng)
    want = true_ranks(table, qs)
    ref_idx = ix.build(kind, table, **PARAMS_PER_KIND[kind])
    ref = np.asarray(ref_idx.lookup(table, qs, backend=backend))
    np.testing.assert_array_equal(ref, want)
    for n_shards in (1, 2, 4):
        sidx = si.ShardedIndex.build(kind, table, n_shards=n_shards, **PARAMS_PER_KIND[kind])
        got = np.asarray(si.sharded_lookup(sidx, qs, backend=backend))
        np.testing.assert_array_equal(got, ref, err_msg=f"{kind}/{n_shards}-way/{backend}")


def test_routing_at_fence_keys(rng):
    """Exact fence keys route to the shard that starts with them;
    out-of-range queries resolve to -1 / n-1."""
    table, _ = _table_and_queries(rng)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=4, b=64)
    fences = np.asarray(sidx.fences)
    owners = np.asarray(si.route_owners(sidx.fences, sidx.fences))
    np.testing.assert_array_equal(owners, np.arange(4))
    qs = np.concatenate(
        [
            fences,
            fences - 1,  # last key of the previous shard's range
            fences + 1,
            np.array([0, table.min(), table.max(), np.iinfo(np.uint64).max], np.uint64),
        ]
    ).astype(np.uint64)
    got = np.asarray(si.sharded_lookup(sidx, qs))
    np.testing.assert_array_equal(got, true_ranks(table, qs))
    assert got[len(fences)] == si.NO_PRED or fences[0] == 0  # below the global min


def test_predecessor_at_shard_boundaries(rng):
    """Predecessor semantics survive partitioning: for boundary keys the
    global rank is the last key of the *previous* shard for q just below
    a fence, and the fence key's own rank at the fence."""
    table, _ = _table_and_queries(rng)
    sidx = si.ShardedIndex.build("PGM", table, n_shards=4, eps=32)
    offsets = np.asarray(sidx.offsets)
    fences = np.asarray(sidx.fences)
    at = np.asarray(si.sharded_lookup(sidx, fences))
    np.testing.assert_array_equal(at, offsets)  # fence key ranks = shard offsets
    below = np.asarray(si.sharded_lookup(sidx, (fences[1:] - 1).astype(np.uint64)))
    np.testing.assert_array_equal(below, offsets[1:] - 1)  # predecessor in previous shard
    # and the plain Index.predecessor API agrees on the concatenated table
    ref_idx = ix.build("PGM", table, eps=32)
    np.testing.assert_array_equal(np.asarray(ref_idx.predecessor(table, fences)), offsets)


def test_stack_rejects_structural_mismatch(rng):
    table, _ = _table_and_queries(rng)
    a = ix.build("BTREE", table, fanout=8)
    b = ix.build("BTREE", table[:64], fanout=8)  # fewer levels
    with pytest.raises(ValueError, match="static"):
        si.stack_indexes([a, b])
    with pytest.raises(ValueError, match="kinds"):
        si.stack_indexes([a, ix.build("RMI", table, b=64)])


# ---------------------------------------------------------------------------
# save/load round-trip of the stacked tier
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["RMI", "PGM", "RS"])
def test_stacked_save_load_bit_exact(rng, kind, tmp_path):
    """npz of the stacked leaves stays bit-exact, and per-shard slices
    round-trip against a per-shard Index.save/load."""
    table, qs = _table_and_queries(rng)
    sidx = si.ShardedIndex.build(kind, table, n_shards=4, **PARAMS_PER_KIND[kind])
    path = os.path.join(tmp_path, f"{kind}-tier.npz")
    sidx.save(path)
    s2 = si.ShardedIndex.load(path)
    assert s2.kind == sidx.kind
    assert s2.index.static == sidx.index.static
    assert set(s2.index.arrays) == set(sidx.index.arrays)
    for k, v in sidx.index.arrays.items():
        a, b = np.asarray(v), np.asarray(s2.index.arrays[k])
        assert a.dtype == b.dtype, k
        np.testing.assert_array_equal(a, b, err_msg=k)
    for name in ("tables", "fences", "counts", "offsets"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sidx, name)), np.asarray(getattr(s2, name)), err_msg=name
        )
    np.testing.assert_array_equal(
        np.asarray(si.sharded_lookup(s2, qs)), np.asarray(si.sharded_lookup(sidx, qs))
    )
    # a shard sliced out of the tier round-trips through Index.save/load
    shard = sidx.shard(2)
    spath = os.path.join(tmp_path, f"{kind}-shard2.npz")
    shard.save(spath)
    shard2 = ix.Index.load(spath)
    for k, v in shard.arrays.items():
        np.testing.assert_array_equal(np.asarray(v), np.asarray(shard2.arrays[k]), err_msg=k)


# ---------------------------------------------------------------------------
# Donated refresh
# ---------------------------------------------------------------------------


def test_refresh_shard_swaps_rebuilt_shard():
    # own deterministic rng: the rebuilt shard must land in the same
    # bucketed-static tier regardless of which tests ran before
    rng = np.random.default_rng(42)
    table, qs = _table_and_queries(rng)
    sidx = si.ShardedIndex.build("BTREE", table, n_shards=4, fanout=8)
    m = int(sidx.tables.shape[1])
    counts = np.asarray(sidx.counts)
    shard_tables = [np.asarray(sidx.tables[i])[: counts[i]] for i in range(4)]
    # rebuild shard 2 with its last 3 keys retired (same padded length m,
    # so the B+-tree statics are identical by construction)
    new_keys = shard_tables[2][:-3]
    spec = registry.spec_for("BTREE", fanout=8)
    new_idx = registry.entry("BTREE").build(spec, si._pad_sorted_table(new_keys, m))
    s2 = si.refresh_shard(sidx, 2, new_idx, new_keys)
    new_table = np.concatenate([shard_tables[0], shard_tables[1], new_keys, shard_tables[3]])
    got = np.asarray(si.sharded_lookup(s2, qs))
    np.testing.assert_array_equal(got, true_ranks(new_table, qs))
    # offsets beyond the refreshed shard shifted down by the retired keys
    assert int(np.asarray(s2.offsets)[3]) == len(new_table) - len(shard_tables[3])
    assert int(np.asarray(s2.counts)[2]) == len(new_keys)


def test_refresh_shard_rejects_incompatible(rng):
    table, _ = _table_and_queries(rng)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=2, b=64)
    other = ix.build("PGM", table, eps=32)
    with pytest.raises(ValueError, match="kind mismatch"):
        si.refresh_shard(sidx, 0, other, table[:10])


def test_refresh_shard_rejects_out_of_range_keys():
    """A rebuilt shard whose keys stray into a neighbour's fence slot is
    refused — it would silently corrupt every later shard's ranks."""
    rng = np.random.default_rng(43)
    table, _ = _table_and_queries(rng)
    sidx = si.ShardedIndex.build("BTREE", table, n_shards=4, fanout=8)
    m = int(sidx.tables.shape[1])
    spec = registry.spec_for("BTREE", fanout=8)
    # shard 1 rebuilt with keys reaching back into shard 0's range
    bad_low = table[: int(sidx.counts[0]) + 4]
    idx_low = registry.entry("BTREE").build(spec, si._pad_sorted_table(bad_low[:m], m))
    with pytest.raises(ValueError, match="previous"):
        si.refresh_shard(sidx, 1, idx_low, bad_low[:m])
    # shard 1 rebuilt with its key window shifted into the next fence slot
    hi_start = int(sidx.offsets[1]) + 4
    bad_hi = table[hi_start : hi_start + int(sidx.counts[1])]
    idx_hi = registry.entry("BTREE").build(spec, si._pad_sorted_table(bad_hi, m))
    with pytest.raises(ValueError, match="next"):
        si.refresh_shard(sidx, 1, idx_hi, bad_hi)


def test_sharded_lookup_rejects_unknown_backend(rng):
    table, qs = _table_and_queries(rng, n=256, nq=16)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=2, b=64)
    with pytest.raises(ValueError, match="tier backend"):
        si.sharded_lookup(sidx, qs, backend="xIa")
    # pallas is a first-class tier backend (batched fused kernels)
    assert "pallas" in si.TIER_BACKENDS
    got = np.asarray(si.sharded_lookup(sidx, qs, backend="pallas"))
    np.testing.assert_array_equal(got, true_ranks(table, qs))


# ---------------------------------------------------------------------------
# shard_map paths in-process (needs >= 4 devices, e.g. the multihost leg)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["a2a", "allgather"])
@pytest.mark.parametrize("n_shards", [2, 4])
def test_spmd_modes_match_reference(rng, n_shards, mode, backend):
    ctx = _mesh_ctx(n_shards)
    if ctx is None:
        pytest.skip(f"needs {n_shards} devices (multihost CI leg / subprocess test)")
    table, qs = _table_and_queries(rng)
    want = true_ranks(table, qs)
    for kind in ("RMI", "PGM"):
        sidx = si.ShardedIndex.build(kind, table, n_shards=n_shards, **PARAMS_PER_KIND[kind])
        got = np.asarray(
            si.sharded_lookup(
                sidx, qs, ctx, mode=mode, backend=backend, cap_factor=float(n_shards)
            )
        )
        np.testing.assert_array_equal(got, want, err_msg=f"{kind}/{mode}/{n_shards}")


def test_a2a_capacity_overflow_reports_dropped(rng):
    ctx = _mesh_ctx(4)
    if ctx is None:
        pytest.skip("needs 4 devices (multihost CI leg / subprocess test)")
    table, _ = _table_and_queries(rng)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=4, b=64)
    skew = np.full(64, table[-1], dtype=np.uint64)  # all owned by the last shard
    got = np.asarray(si.sharded_lookup(sidx, skew, ctx, mode="a2a", cap_factor=0.26))
    n = len(table)
    assert np.all((got == si.DROPPED) | (got == n - 1))
    assert np.any(got == si.DROPPED)  # dropped, never silently mis-answered
    exact = np.asarray(si.sharded_lookup(sidx, skew, ctx, mode="a2a", cap_factor=4.0))
    np.testing.assert_array_equal(exact, np.full(64, n - 1))


# ---------------------------------------------------------------------------
# Forced 4-device subprocess: collective paths without relying on the
# parent process's device count (the test_multidevice pattern).
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
import repro
from repro import index as ix
from repro.core import as_table
from repro.core.cdf import true_ranks
from repro.dist import sharded_index as si
from repro.dist.sharding import ShardingCtx

assert len(jax.devices()) == 4
rng = np.random.default_rng(5)
table = as_table(rng.integers(0, 2**63, size=2500, dtype=np.uint64))
qs = np.concatenate([
    rng.choice(table, 200),
    rng.integers(0, 2**63, 100, dtype=np.uint64),
    np.array([0, table.min(), table.max(), 2**64 - 1], dtype=np.uint64),
]).astype(np.uint64)
want = true_ranks(table, qs)

for n_shards, mesh_shape in ((2, (2, 2)), (4, (1, 4))):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    ctx = ShardingCtx(mesh=mesh, rules={"tp": ("model",) if n_shards != 4 else ("data", "model")})
    assert ctx.n("tp") == n_shards, (ctx.n("tp"), n_shards)
    for kind, params in [("RMI", dict(b=64)), ("PGM", dict(eps=32)), ("BTREE", dict(fanout=8))]:
        sidx = si.ShardedIndex.build(kind, table, n_shards=n_shards, **params)
        for mode in ("a2a", "allgather"):
            got = np.asarray(si.sharded_lookup(
                sidx, qs, ctx, mode=mode, cap_factor=float(n_shards)))
            assert np.array_equal(got, want), (kind, n_shards, mode)
    print(f"OK {n_shards}-way a2a+allgather")

# donated refresh under the 4-way mesh: swap shard 1, results track the new tier
from repro.index import registry
mesh = jax.make_mesh((1, 4), ("data", "model"))
ctx = ShardingCtx(mesh=mesh)
sidx = si.ShardedIndex.build("RMI", table, n_shards=4, b=64)
m = int(sidx.tables.shape[1])
counts = np.asarray(sidx.counts)
shard_tables = [np.asarray(sidx.tables[i])[: counts[i]] for i in range(4)]
new_keys = shard_tables[1][:-5]
spec = registry.spec_for("RMI", b=64)
new_idx = registry.entry("RMI").build(spec, si._pad_sorted_table(new_keys, m))
s2 = si.refresh_shard(sidx, 1, new_idx, new_keys)
new_table = np.concatenate([shard_tables[0], new_keys, shard_tables[2], shard_tables[3]])
got = np.asarray(si.sharded_lookup(s2, qs, ctx, mode="a2a", cap_factor=4.0))
assert np.array_equal(got, true_ranks(new_table, qs))
print("OK donated refresh under mesh")

# LearnedKeyedEmbedding id-translation through the sharded tier
from repro.models.embedding import LearnedKeyedEmbedding
raw = rng.integers(0, 2**63, size=800, dtype=np.uint64)
lke = LearnedKeyedEmbedding.build(raw, dim=8, seed=3, ctx=ctx, n_shards=4)
probe = np.concatenate([raw[:16], rng.integers(0, 2**63, 8, dtype=np.uint64)])
vecs_sharded = np.asarray(lke.lookup(probe))
lke1 = LearnedKeyedEmbedding.build(raw, dim=8, seed=3)
np.testing.assert_allclose(vecs_sharded, np.asarray(lke1.lookup(probe)))
print("OK LearnedKeyedEmbedding sharded id-translation")
print("ALL SHARDED OK")
"""


@pytest.mark.slow
def test_sharded_collectives_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True, timeout=1200
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-4000:]}"
    assert "ALL SHARDED OK" in res.stdout


# ---------------------------------------------------------------------------
# Skew-aware rebalancing (PR 9): weighted-quantile fences + donated re-shard
# ---------------------------------------------------------------------------


def test_weighted_quantile_bounds_degenerate_skew():
    """All observed traffic on one shard: the split must hand that
    shard's keys out across every shard while staying a strictly
    increasing >= 1-key partition; all-zero weights fall back even."""
    rng = np.random.default_rng(51)
    table, _ = _table_and_queries(rng, n=4096)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=4, b=64)
    fences = np.asarray(sidx.fences)
    bounds = si.weighted_quantile_bounds(table, fences, [1.0, 0.0, 0.0, 0.0])
    assert bounds[0] == 0 and bounds[-1] == len(table)
    assert (np.diff(bounds) >= 1).all()
    # the hot shard's old key range (first quarter) is split across all
    # shards: every inner bound lands inside it
    assert (bounds[1:-1] <= len(table) // 4).all()
    even = si.weighted_quantile_bounds(table, fences, [0.0, 0.0, 0.0, 0.0])
    np.testing.assert_array_equal(even, [0, 1024, 2048, 3072, 4096])
    # single-key-per-shard degenerate table still partitions
    tiny = table[:4]
    tb = si.weighted_quantile_bounds(tiny, tiny, [9.0, 0.0, 0.0, 0.0])
    np.testing.assert_array_equal(tb, [0, 1, 2, 3, 4])


def test_rebalance_shards_donated_path_exact(rng):
    """Moderate skew on a tier with stacked-capacity slack: the pure
    donated re-shard path (no restack) must produce bit-exact lookups
    with zero drops, and move the fences to the new bounds."""
    # 4 x 2176 resident keys, m = pow2ceil(2176) = 4096: every shard has
    # slack, so moderate boundary moves install via refresh_shard alone
    table, qs = _table_and_queries(rng, n=8704, nq=512)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=4, b=64)
    spec = registry.spec_for("RMI", b=64)
    build = registry.entry("RMI").build
    bounds = si.weighted_quantile_bounds(
        table, np.asarray(sidx.fences), [2.0, 1.0, 1.0, 1.0]
    )
    assert not np.array_equal(np.diff(bounds), np.asarray(sidx.counts))
    s2 = si.rebalance_shards(sidx, table, bounds, lambda part: build(spec, part))
    np.testing.assert_array_equal(np.asarray(s2.counts), np.diff(bounds))
    np.testing.assert_array_equal(np.asarray(s2.fences), table[bounds[:-1]])
    got = np.asarray(si.sharded_lookup(s2, qs))
    assert (got != si.DROPPED).all()
    np.testing.assert_array_equal(got, true_ranks(table, qs))


def test_rebalance_boundary_fence_keys(rng):
    """Queries exactly ON and adjacent to the rebalanced fences — the
    routing seam a off-by-one in the new bounds would corrupt first."""
    table, _ = _table_and_queries(rng, n=8704)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=4, b=64)
    spec = registry.spec_for("RMI", b=64)
    build = registry.entry("RMI").build
    bounds = si.weighted_quantile_bounds(
        table, np.asarray(sidx.fences), [3.0, 1.0, 2.0, 1.0]
    )
    s2 = si.rebalance_shards(sidx, table, bounds, lambda part: build(spec, part))
    fence_keys = table[bounds[1:-1]]
    qs = np.concatenate(
        [fence_keys, fence_keys - np.uint64(1), fence_keys + np.uint64(1), table[:1]]
    )
    got = np.asarray(si.sharded_lookup(s2, qs, mode="ref"))
    np.testing.assert_array_equal(got, true_ranks(table, qs))


def test_tier_rebalance_with_populated_gapped_delta():
    """Rebalancing a GAPPED tier whose delta buffers hold live overflow
    keys: the re-shard must fold delta + leaves into the new partition
    with zero key loss and exact answers."""
    from repro.index import GappedSpec
    from repro.tune import RebuildPolicy, TunedTier

    rng = np.random.default_rng(57)
    table = np.unique(rng.integers(1, 2**61, size=3000, dtype=np.uint64))
    tier = TunedTier(
        table,
        n_shards=4,
        policy=RebuildPolicy(retune_frac=10.0),
        spec=GappedSpec(leaf_cap=64, fill=0.75, delta_cap=512),
    )
    # a dense cluster inside one leaf's key range exhausts its gaps and
    # overflows into the shard's sorted delta
    lo, hi = int(table[40]), int(table[41])
    cluster = np.unique(
        rng.integers(lo + 1, max(hi, lo + 2), size=120, dtype=np.uint64)
    )
    cluster = np.setdiff1d(cluster, table)
    tier.insert_batch(cluster)
    merged = np.union1d(table, cluster)
    assert tier.counters.overflowed > 0, "cluster failed to reach the delta buffer"
    delta_live = int(np.asarray(tier.sidx.index.arrays["delta_count"]).sum())
    assert delta_live > 0
    tier.rebalance(weights=np.array([6.0, 1.0, 1.0, 1.0]))
    np.testing.assert_array_equal(tier._merged_table(), merged)
    qs = np.concatenate([rng.choice(merged, 256), cluster[:32]])
    got = np.asarray(tier.lookup(qs, mode="ref"))
    np.testing.assert_array_equal(got, true_ranks(merged, qs))
    assert tier.metrics()["rebalances"] >= 1
    assert tier.metrics()["retunes"] == 0


def test_tier_refresh_non_pow2_shard_regression():
    """Regression: a refreshed shard whose resident count is not a power
    of two must be FITTED on the padded capacity-m table.  The seed
    built the replacement on the raw merged keys, so static-kind models
    (which normalise predictions by lookup-time table length)
    mispredicted against the stacked padded row the moment pad > 0."""
    from repro.tune import RebuildPolicy, TunedTier

    rng = np.random.default_rng(59)
    # 500 keys/shard, m = 512: pad > 0, the seed-corrupting shape
    table = np.unique(rng.integers(1, 2**61, size=1100, dtype=np.uint64))[:1000]
    tier = TunedTier(
        table,
        n_shards=2,
        policy=RebuildPolicy(retune_frac=10.0, shard_refresh_frac=10.0),
        spec=ix.RMISpec(b=32),
    )
    assert int(tier.sidx.counts[0]) < int(tier.sidx.tables.shape[1])
    for s in range(2):
        tier.refresh(s)  # identity refresh: no pending keys land
    assert tier.counters.forced_restacks == 0
    qs = rng.choice(table, size=512).astype(np.uint64)
    np.testing.assert_array_equal(
        np.asarray(tier.lookup(qs, mode="ref")), true_ranks(table, qs)
    )


def test_tier_maybe_rebalance_windowed_trigger(rng):
    """The drift window: sustained single-shard hammering must trip the
    query-driven rebalance (and only after ``rebalance_min_lookups``),
    serving every batch exactly throughout."""
    from repro.dist import reset_tier_metrics
    from repro.tune import RebuildPolicy, TunedTier

    reset_tier_metrics()
    table, _ = _table_and_queries(rng, n=8704)
    tier = TunedTier(
        table,
        n_shards=4,
        policy=RebuildPolicy(
            retune_frac=10.0,
            rebalance_imbalance=1.5,
            rebalance_min_lookups=3,
        ),
        spec=ix.RMISpec(b=64),
    )
    hot = table[: len(table) // 4]  # every query owned by shard 0
    for _ in range(8):
        qs = rng.choice(hot, size=256).astype(np.uint64)
        got = np.asarray(tier.lookup(qs, mode="ref"))
        np.testing.assert_array_equal(got, true_ranks(table, qs))
    m = tier.metrics()
    assert m["rebalances"] >= 1, "sustained skew never tripped the rebalancer"
    assert m["retunes"] == 0
    # post-rebalance: shard 0 no longer owns the whole hot range
    assert int(np.asarray(tier.sidx.counts)[0]) < len(hot)
