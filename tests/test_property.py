"""Hypothesis property tests: system invariants on adversarial tables.

Invariant under test (all models, all tables, all queries):
    A[pred(q)] <= q < A[pred(q)+1]     (pred = -1 iff q < A[0])
plus interval soundness: the model's predicted window always contains
the true predecessor (the guarantee DESIGN.md §3 argues for).
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not baked into the image")
from hypothesis import given, settings, strategies as st

from repro.index import NeedsRebuild, build
from repro.core.cdf import as_table, true_ranks

key_lists = st.lists(
    st.integers(min_value=0, max_value=2**64 - 1), min_size=2, max_size=300, unique=True
)
query_lists = st.lists(st.integers(min_value=0, max_value=2**64 - 1), min_size=1, max_size=64)

MODELS = [
    ("L", {}),
    ("KO", {"k": 5}),
    ("RMI", {"b": 16, "root_type": "linear"}),
    ("PGM", {"eps": 4}),
    ("RS", {"eps": 4, "r_bits": 6}),
    ("BTREE", {"fanout": 4}),
]


@settings(max_examples=40, deadline=None)
@given(keys=key_lists, queries=query_lists)
def test_predecessor_invariant(keys, queries):
    table = as_table(np.array(keys, dtype=np.uint64))
    qs = np.array(queries, dtype=np.uint64)
    want = true_ranks(table, qs)
    tj, qj = jnp.asarray(table), jnp.asarray(qs)
    for kind, params in MODELS:
        m = build(kind, table, **params)
        got = np.asarray(m.predecessor(tj, qj))
        assert (got == want).all(), (kind, table[:8], qs[:8], got, want)
        # interval soundness
        lo, hi = m.intervals(tj, qj)
        lo, hi = np.asarray(lo), np.asarray(hi)
        assert (lo <= np.maximum(want, 0)).all() or (want < 0).any() is not None
        inside = (want < lo - 1) & (want >= 0)
        assert not inside.any(), (kind, "window missed predecessor")


@settings(max_examples=25, deadline=None)
@given(keys=key_lists)
def test_self_query_identity(keys):
    """Querying every table key must return its own rank."""
    table = as_table(np.array(keys, dtype=np.uint64))
    tj = jnp.asarray(table)
    want = np.arange(len(table))
    for kind, params in MODELS:
        m = build(kind, table, **params)
        got = np.asarray(m.predecessor(tj, tj))
        assert (got == want).all(), kind


@settings(max_examples=25, deadline=None)
@given(
    keys=key_lists,
    eps=st.integers(min_value=1, max_value=64),
)
def test_pgm_segment_error_bound(keys, eps):
    """PGM construction invariant: every key's prediction within eps+1."""
    from repro.core.pgm import pla_segments

    table = as_table(np.array(keys, dtype=np.uint64)).astype(np.float64)
    starts, slopes = pla_segments(table, eps)
    seg_of = np.searchsorted(starts, np.arange(len(table)), side="right") - 1
    x0 = table[starts[seg_of]]
    pred = starts[seg_of] + slopes[seg_of] * (table - x0)
    assert np.all(np.abs(pred - np.arange(len(table))) <= eps + 1e-6)


# max-key is GAPPED's pad/route sentinel and cannot be stored live
_gapped_keys = st.integers(min_value=0, max_value=2**64 - 2)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_gapped_after_inserts_matches_fresh_static_build(data):
    """The ISSUE acceptance invariant: a GAPPED index after N insert
    batches answers bit-exactly like a static RMI built fresh on the
    merged keyset, on every backend GAPPED claims.  A batch that
    exhausts the fixed capacity exercises the retune arm instead (the
    documented ``NeedsRebuild`` escalation: rebuild on the merged keys).
    """
    keys = data.draw(st.lists(_gapped_keys, min_size=2, max_size=200, unique=True))
    table = as_table(np.array(keys, dtype=np.uint64))
    spec = dict(leaf_cap=16, fill=0.5, delta_cap=32)
    g = build("GAPPED", table, **spec)
    merged = table
    for _ in range(data.draw(st.integers(min_value=1, max_value=3), label="batches")):
        batch = data.draw(st.lists(_gapped_keys, min_size=1, max_size=40))
        batch = np.array(batch, dtype=np.uint64)
        target = np.union1d(merged, batch)
        try:
            g, report = g.insert_batch(batch)
        except NeedsRebuild:
            g = build("GAPPED", target, **spec)
        else:
            fresh = len(target) - len(merged)
            assert report.absorbed + report.overflowed == fresh
            assert report.duplicates == len(batch) - fresh
        merged = target
    static = build("RMI", merged, b=16, root_type="linear")
    qs = np.array(
        data.draw(st.lists(_gapped_keys, min_size=1, max_size=64)), dtype=np.uint64
    )
    want = np.asarray(static.predecessor(jnp.asarray(merged), jnp.asarray(qs)))
    np.testing.assert_array_equal(want, true_ranks(merged, qs))
    for be in g.backends():
        got = np.asarray(g.lookup(jnp.asarray(table), jnp.asarray(qs), backend=be))
        assert (got == want).all(), (be, merged[:8], qs[:8], got, want)


# ---------------------------------------------------------------------------
# Scan-formulated fits: device corridor scans == host greedy builds
# ---------------------------------------------------------------------------

_SCAN_DISTS = ("amzn64", "face", "osm", "wiki")  # the benchmark distributions


def _scan_table(data) -> np.ndarray:
    """A table from one of the benchmark distributions, or an
    adversarial shape: duplicate-adjacent keys (the degenerate
    no-headroom pad), constant-gap runs, and sizes that are not a
    multiple of the scan chunk (SCAN_CHUNK = 128 -> odd sizes)."""
    from repro.data import distributions

    kind = data.draw(
        st.sampled_from(_SCAN_DISTS + ("dup-tail", "const-gap")), label="dist"
    )
    n = data.draw(st.integers(min_value=3, max_value=700), label="n")
    seed = data.draw(st.integers(min_value=0, max_value=2**16), label="seed")
    if kind == "dup-tail":
        # duplicate-adjacent keys after padding: the _pad_sorted_table
        # degenerate case (no u64 headroom repeats the last key)
        base = np.arange(1, n + 1, dtype=np.uint64) * np.uint64(977)
        dup = data.draw(st.integers(min_value=1, max_value=max(n // 2, 1)), label="dup")
        return np.concatenate([base, np.full(dup, base[-1], dtype=np.uint64)])
    if kind == "const-gap":
        gap = data.draw(st.integers(min_value=1, max_value=1 << 20), label="gap")
        start = data.draw(st.integers(min_value=0, max_value=1 << 40), label="start")
        return np.uint64(start) + np.arange(n, dtype=np.uint64) * np.uint64(gap)
    return as_table(distributions.generate(kind, n, seed=seed))


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_pgm_segments_scan_matches_greedy(data):
    """pgm_segments_scan boundary masks == pla_segments starts (and the
    mask-derived slopes == the greedy's) on benchmark distributions and
    adversarial tables, for the paper's ε range."""
    from repro.core.pgm import pgm_segments_scan, pla_segments, segment_slopes

    table = _scan_table(data)
    eps = data.draw(st.sampled_from((8, 32, 128)), label="eps")
    keys = table.astype(np.float64)
    starts, slopes = pla_segments(keys, eps)
    mask = np.asarray(pgm_segments_scan(keys, float(eps)))
    assert np.array_equal(np.flatnonzero(mask), starts)
    got = segment_slopes(keys, starts, eps)
    assert np.array_equal(got, slopes, equal_nan=True)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_rs_knots_scan_matches_greedy(data):
    """rs_knots_scan knot masks == spline_knots on benchmark
    distributions and adversarial tables, for the paper's ε range."""
    from repro.core.radix_spline import rs_knots_scan, spline_knots

    table = _scan_table(data)
    eps = data.draw(st.sampled_from((8, 32, 128)), label="eps")
    keys = table.astype(np.float64)
    knots = spline_knots(keys, eps)
    mask = np.asarray(rs_knots_scan(keys, float(eps)))
    assert np.array_equal(np.flatnonzero(mask), knots)


# ---------------------------------------------------------------------------
# O(log n) fast fits: valid ε-models, verified-ε fallback on degenerate keys
# ---------------------------------------------------------------------------


def _fast_table(data) -> np.ndarray:
    """A table for the fast-fit validity tests: the benchmark
    distributions plus constant-gap runs (f64-exact keys, so the
    verified-ε re-measure must pass).  The degenerate dup-tail shape is
    exercised by the deterministic fallback tests below instead — f64
    key collisions are *supposed* to fail the re-measure."""
    from repro.data import distributions

    kind = data.draw(st.sampled_from(_SCAN_DISTS + ("const-gap",)), label="dist")
    n = data.draw(st.integers(min_value=3, max_value=700), label="n")
    seed = data.draw(st.integers(min_value=0, max_value=2**16), label="seed")
    if kind == "const-gap":
        gap = data.draw(st.integers(min_value=1, max_value=1 << 20), label="gap")
        start = data.draw(st.integers(min_value=0, max_value=1 << 40), label="start")
        return np.uint64(start) + np.arange(n, dtype=np.uint64) * np.uint64(gap)
    return as_table(distributions.generate(kind, n, seed=seed))


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_pgm_fit_fast_is_valid_eps_pla(data):
    """pgm_fit_fast returns ``ok`` and a mask whose induced PLA (with
    the shared segment_slopes) predicts every rank within ε — the
    fit="fast" contract: a *valid* ε-model, not a bit-identical one.
    The check recomputes the error on host, independently of the
    device verified-ε re-measure that produced ``ok``."""
    from repro.core.pgm import pgm_fit_fast, segment_slopes

    table = _fast_table(data)
    eps = data.draw(st.sampled_from((8, 32, 128)), label="eps")
    keys = table.astype(np.float64)
    mask, ok = pgm_fit_fast(keys, float(eps))
    assert bool(ok)
    starts = np.flatnonzero(np.asarray(mask))
    assert starts[0] == 0
    slopes = segment_slopes(keys, starts, eps)
    seg_of = np.searchsorted(starts, np.arange(len(keys)), side="right") - 1
    pred = starts[seg_of] + slopes[seg_of] * (keys - keys[starts[seg_of]])
    assert np.all(np.abs(pred - np.arange(len(keys))) <= eps + 1e-6)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_rs_knots_fast_is_valid_spline(data):
    """rs_knots_fast returns ``ok`` and a knot mask whose chord
    interpolation (the same clipped formula build_rs re-measures with)
    predicts every rank within ε; first and last key are always
    knots."""
    from repro.core.radix_spline import rs_knots_fast

    table = _fast_table(data)
    eps = data.draw(st.sampled_from((8, 32, 128)), label="eps")
    keys = table.astype(np.float64)
    kmask, ok = rs_knots_fast(keys, float(eps))
    assert bool(ok)
    knots = np.flatnonzero(np.asarray(kmask))
    n = len(keys)
    assert knots[0] == 0 and knots[-1] == n - 1
    j = np.searchsorted(knots, np.arange(n), side="right") - 1
    j = np.minimum(j, max(len(knots) - 2, 0))
    p0, p1 = knots[j], knots[np.minimum(j + 1, len(knots) - 1)]
    t = np.clip((keys - keys[p0]) / np.maximum(keys[p1] - keys[p0], 1.0), 0.0, 1.0)
    pred = p0 + t * (p1 - p0)
    assert np.all(np.abs(pred - np.arange(n)) <= eps + 1e-6)


# The deterministic fallback-trigger regressions (f64-colliding keys ->
# ok=False -> per-member scan re-fit) live in tests/test_device_fit.py:
# they need no hypothesis, so they run even where it isn't installed.


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_searchsorted_segments(data):
    """MoE-dispatch boundary search: branch-free bfs on int32 tables."""
    from repro.core import search

    vals = data.draw(st.lists(st.integers(0, 63), min_size=1, max_size=200))
    arr = np.sort(np.array(vals, dtype=np.int32))
    q = np.arange(-1, 64, dtype=np.int32)
    got = np.asarray(search.bfs(jnp.asarray(arr), jnp.asarray(q)))
    want = np.searchsorted(arr, q, side="right") - 1
    assert (got == want).all()
