"""Hypothesis property tests: system invariants on adversarial tables.

Invariant under test (all models, all tables, all queries):
    A[pred(q)] <= q < A[pred(q)+1]     (pred = -1 iff q < A[0])
plus interval soundness: the model's predicted window always contains
the true predecessor (the guarantee DESIGN.md §3 argues for).
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not baked into the image")
from hypothesis import given, settings, strategies as st

from repro.core import build_index
from repro.core.cdf import as_table, true_ranks

key_lists = st.lists(
    st.integers(min_value=0, max_value=2**64 - 1), min_size=2, max_size=300, unique=True
)
query_lists = st.lists(st.integers(min_value=0, max_value=2**64 - 1), min_size=1, max_size=64)

MODELS = [
    ("L", {}),
    ("KO", {"k": 5}),
    ("RMI", {"b": 16, "root_type": "linear"}),
    ("PGM", {"eps": 4}),
    ("RS", {"eps": 4, "r_bits": 6}),
    ("BTREE", {"fanout": 4}),
]


@settings(max_examples=40, deadline=None)
@given(keys=key_lists, queries=query_lists)
def test_predecessor_invariant(keys, queries):
    table = as_table(np.array(keys, dtype=np.uint64))
    qs = np.array(queries, dtype=np.uint64)
    want = true_ranks(table, qs)
    tj, qj = jnp.asarray(table), jnp.asarray(qs)
    for kind, params in MODELS:
        m = build_index(kind, table, **params)
        got = np.asarray(m.predecessor(tj, qj))
        assert (got == want).all(), (kind, table[:8], qs[:8], got, want)
        # interval soundness
        lo, hi = m.intervals(tj, qj)
        lo, hi = np.asarray(lo), np.asarray(hi)
        assert (lo <= np.maximum(want, 0)).all() or (want < 0).any() is not None
        inside = (want < lo - 1) & (want >= 0)
        assert not inside.any(), (kind, "window missed predecessor")


@settings(max_examples=25, deadline=None)
@given(keys=key_lists)
def test_self_query_identity(keys):
    """Querying every table key must return its own rank."""
    table = as_table(np.array(keys, dtype=np.uint64))
    tj = jnp.asarray(table)
    want = np.arange(len(table))
    for kind, params in MODELS:
        m = build_index(kind, table, **params)
        got = np.asarray(m.predecessor(tj, tj))
        assert (got == want).all(), kind


@settings(max_examples=25, deadline=None)
@given(
    keys=key_lists,
    eps=st.integers(min_value=1, max_value=64),
)
def test_pgm_segment_error_bound(keys, eps):
    """PGM construction invariant: every key's prediction within eps+1."""
    from repro.core.pgm import pla_segments

    table = as_table(np.array(keys, dtype=np.uint64)).astype(np.float64)
    starts, slopes = pla_segments(table, eps)
    seg_of = np.searchsorted(starts, np.arange(len(table)), side="right") - 1
    x0 = table[starts[seg_of]]
    pred = starts[seg_of] + slopes[seg_of] * (table - x0)
    assert np.all(np.abs(pred - np.arange(len(table))) <= eps + 1e-6)


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_searchsorted_segments(data):
    """MoE-dispatch boundary search: branch-free bfs on int32 tables."""
    from repro.core import search

    vals = data.draw(st.lists(st.integers(0, 63), min_size=1, max_size=200))
    arr = np.sort(np.array(vals, dtype=np.int32))
    q = np.arange(-1, 64, dtype=np.int32)
    got = np.asarray(search.bfs(jnp.asarray(arr), jnp.asarray(q)))
    want = np.searchsorted(arr, q, side="right") - 1
    assert (got == want).all()
