"""Every index kind in the paper's hierarchy returns exact predecessor
ranks on every table family, and space accounting is sane (paper §3.2).

Builds go through the unified ``repro.index`` spec API (string-kind
builds exercise ``repro.index.build``'s registry dispatch).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro import index as ix
from repro.core import model_reduction_factor
from repro.core.cdf import true_ranks

from conftest import TABLE_KINDS, make_table, make_queries

CASES = [
    ix.AtomicSpec(degree=1),
    ix.AtomicSpec(degree=2),
    ix.AtomicSpec(degree=3),
    ix.KOSpec(k=15),
    ix.KOSpec(k=3),
    ix.RMISpec(b=64, root_type="linear"),
    ix.RMISpec(b=256, root_type="cubic"),
    ix.RMISpec(b=256, root_type="spline"),
    ix.PGMSpec(eps=16),
    ix.PGMSpec(eps=128),
    ix.PGMBicriteriaSpec(space_pct=2.0, a=1.0),
    ix.RSSpec(eps=16, r_bits=10),
    ix.BTreeSpec(fanout=16),
    ix.SYRMISpec(space_pct=2.0, ub=0.04),
]


@pytest.mark.parametrize("spec", CASES, ids=[f"{s.kind}-{i}" for i, s in enumerate(CASES)])
@pytest.mark.parametrize("table_kind", TABLE_KINDS)
def test_exact_predecessor(rng, spec, table_kind):
    table = make_table(rng, table_kind, 5000)
    qs = make_queries(rng, table, 300)
    want = true_ranks(table, qs)
    m = ix.build(spec, table)
    got = np.asarray(m.predecessor(jnp.asarray(table), jnp.asarray(qs)))
    np.testing.assert_array_equal(got, want)


def test_space_hierarchy(rng):
    """Constant-space models stay constant; parametric models scale."""
    small = make_table(rng, "uniform", 1000)
    big = make_table(rng, "uniform", 30000)
    for kind in ("L", "Q", "C"):
        assert ix.build(kind, small).space_bytes() == ix.build(kind, big).space_bytes()
    ko_s, ko_b = ix.build("KO", small, k=15), ix.build("KO", big, k=15)
    assert ko_s.space_bytes() == ko_b.space_bytes()  # constant in n for fixed k
    rmi_64 = ix.build("RMI", big, b=64)
    rmi_1k = ix.build("RMI", big, b=1024)
    assert rmi_1k.space_bytes() > rmi_64.space_bytes()


def test_pgm_eps_space_tradeoff(rng):
    table = make_table(rng, "clustered", 30000)
    small_eps = ix.build("PGM", table, eps=8)
    big_eps = ix.build("PGM", table, eps=256)
    assert small_eps.space_bytes() > big_eps.space_bytes()
    assert small_eps.n_segments_l0 > big_eps.n_segments_l0


def test_pgm_bicriteria_budget(rng):
    table = make_table(rng, "bursty", 30000)
    budget = int(0.02 * len(table) * 8)
    m = ix.build("PGM_M", table, space_budget_bytes=budget, a=1.0)
    assert m.space_bytes() <= budget or m.eps >= len(table) // 2


def test_reduction_factor_ordering(rng):
    """Better (smaller-eps) models discard more of the table (paper §2)."""
    table = make_table(rng, "lognormal", 20000)
    qs = make_queries(rng, table, 500)
    rf_l = model_reduction_factor(ix.build("L", table), table, qs)
    rf_pgm = model_reduction_factor(ix.build("PGM", table, eps=16), table, qs)
    assert rf_pgm > rf_l
    assert rf_pgm > 99.0


def test_sy_rmi_mining(rng):
    from repro.core.sy_rmi import mine_sy_rmi, build_sy_rmi

    tables = [make_table(rng, k, 4000) for k in ("uniform", "lognormal")]
    res = mine_sy_rmi(tables, n_queries=2000, max_models=4)
    assert res.ub > 0
    assert res.winner_root in ("linear", "cubic", "spline")
    m = build_sy_rmi(tables[0], space_pct=2.0, ub=res.ub, winner_root=res.winner_root)
    budget = 0.02 * len(tables[0]) * 8
    assert m.space_bytes() < 12 * budget  # same order as the budget
