"""Multi-device semantics on 8 fake CPU devices (subprocess so the main
test process keeps its single-device view).

Checks: sharded train step == single-device step (DP+TP correctness),
MoE shard_map dispatch == dense reference, elastic checkpoint restore
across mesh shapes, a2a embedding lookup == allreduce lookup.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
import repro
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.dist.sharding import ShardingCtx
from repro.configs import get as get_arch
from repro.launch import steps
from repro.models import transformer, recsys
from repro.train import TrainConfig, init_train_state, make_train_step, checkpoint

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))
ctx = ShardingCtx(mesh=mesh)
mesh1 = jax.make_mesh((1, 1), ("data", "model"))
ctx1 = ShardingCtx(mesh=mesh1)

# ---- 1. sharded vs single-device LM train step ----
import dataclasses
spec = get_arch("moonshot-v1-16b-a3b", reduced=True)  # exercises MoE EP
# no-drop capacity: capacity depends on per-shard token counts, so token
# dropping would (legitimately) differ across mesh shapes
spec = dataclasses.replace(spec, config=dataclasses.replace(spec.config, capacity_factor=16.0))
cell = spec.shapes[0]
tcfg = TrainConfig(lr=1e-3, schedule="constant")
rng = jax.random.key(0)

def run(ctx_, mesh_):
    cfg = spec.config
    from functools import partial
    loss = lambda p, b: transformer.loss_fn(p, b, cfg, ctx_)
    step = make_train_step(loss, tcfg)
    init_fn = lambda r: transformer.init(r, cfg)
    state = init_train_state(rng, init_fn, tcfg)
    batch = steps.make_inputs(spec, cell, abstract=False)
    with mesh_:
        state, metrics = jax.jit(step)(state, batch)
    return float(metrics["loss"]), state

l8, st8 = run(ctx, mesh)
l1, st1 = run(ctx1, mesh1)
assert abs(l8 - l1) < 2e-2, (l8, l1)
w8 = np.asarray(jax.tree_util.tree_leaves(st8["params"])[0], np.float32)
w1 = np.asarray(jax.tree_util.tree_leaves(st1["params"])[0], np.float32)
np.testing.assert_allclose(w8, w1, rtol=2e-2, atol=2e-3)
print("OK sharded==single LM+MoE train step")

# ---- 2. elastic checkpoint: save on (4,2), restore on (2,4) ----
import tempfile
d = tempfile.mkdtemp()
checkpoint.save(d, st8, step=1, async_write=False)
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
ctx_b = ShardingCtx(mesh=mesh_b)
sh = steps.state_shardings(st8, "lm", ctx_b)
sh = steps.fit_tree(jax.eval_shape(lambda: st8), sh, mesh_b)
restored, _ = checkpoint.restore(d, st8, shardings=sh)
r0 = np.asarray(jax.tree_util.tree_leaves(restored["params"])[0], np.float32)
np.testing.assert_allclose(r0, w8, rtol=1e-6)
print("OK elastic restore across mesh shapes")

# ---- 3. a2a embedding lookup == allreduce lookup ----
from repro.models.embedding import sharded_lookup
rng2 = np.random.default_rng(0)
table = jnp.asarray(rng2.normal(size=(64, 8)).astype(np.float32))
ids = jnp.asarray(rng2.integers(0, 64, size=(16, 3)).astype(np.int32))
with mesh:
    out_ar = jax.jit(lambda t, i: sharded_lookup(t, i, ctx, mode="allreduce"))(table, ids)
    out_a2a = jax.jit(lambda t, i: sharded_lookup(t, i, ctx, mode="a2a", cap_factor=16.0))(
        table, ids
    )
np.testing.assert_allclose(np.asarray(out_ar), np.asarray(out_a2a), rtol=1e-5, atol=1e-6)
print("OK a2a == allreduce embedding lookup")

# ---- 4. decode step under sharding ----
spec2 = get_arch("granite-3-8b", reduced=True)
cell2 = [c for c in spec2.shapes if c.name == "decode_32k"][0]
cfg2 = spec2.config
params2 = transformer.init(jax.random.key(1), cfg2)
cache2 = transformer.init_cache(cfg2, cell2.dims["global_batch"], cell2.dims["seq_len"])
batch2 = steps.make_inputs(spec2, cell2, abstract=False)
with mesh:
    lg8, _ = jax.jit(lambda p, c, b, s: transformer.decode_step(p, c, b["tokens"], s, cfg2, ctx))(
        params2, cache2, batch2, jnp.int32(3)
    )
with mesh1:
    lg1, _ = jax.jit(
        lambda p, c, b, s: transformer.decode_step(p, c, b["tokens"], s, cfg2, ctx1)
    )(params2, cache2, batch2, jnp.int32(3))
np.testing.assert_allclose(np.asarray(lg8), np.asarray(lg1), rtol=5e-2, atol=5e-2)
print("OK decode step sharded == single")
print("ALL MULTIDEVICE OK")
"""


@pytest.mark.slow
def test_multidevice_semantics(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True, timeout=1200
    )
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-4000:]}"
    assert "ALL MULTIDEVICE OK" in res.stdout
