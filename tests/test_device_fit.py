"""The single-program device fit-to-serve pipeline and the fast-fit
fallback machinery (no hypothesis needed — these run everywhere).

Covers the fit="fast" verified-ε contract's failure arm (f64-colliding
keys must veto, and build_many must re-fit just the bad members with
the exact scan), plus tune.device_refresh: ok installs serve the merged
keys exactly, rejected builds leave the tier bit-identically serving
the old model, and the TunedTier policy arm counts both outcomes in the
``device_refreshes`` metric.
"""

import numpy as np
import pytest

from repro import obs, tune
from repro import index as ix
from repro.core.cdf import true_ranks
from repro.core.pgm import pgm_fit_fast
from repro.core.radix_spline import rs_knots_fast
from repro.data import distributions
from repro.dist import sharded_index as si
from repro.tune.device_fit import DEVICE_REFRESH_KINDS, device_refresh

# adjacent keys at 2^60 collide in f64 (53-bit mantissa): the corridor
# sees dx = 0, slopes go NaN, and the verified-ε re-measure must veto
_COLLIDING = (np.uint64(1) << np.uint64(60)) + np.arange(1024, dtype=np.uint64)

# 2000 keys/shard in a pow2-2048 stacked table: headroom for drift
_N, _SHARDS = 8000, 4

_SPECS = {
    "PGM": ix.PGMSpec(eps=32),
    "RS": ix.RSSpec(eps=16, r_bits=8),
}


def _drifted(sidx, shard, n_new, seed=1):
    """``n_new``-ish fresh keys strictly inside ``shard``'s key range,
    plus the shard's merged keyset."""
    cnt = int(sidx.counts[shard])
    old = np.asarray(sidx.tables[shard][:cnt])
    rng = np.random.default_rng(seed)
    drift = np.unique(rng.integers(int(old[10]), int(old[-10]), n_new, dtype=np.uint64))
    return drift, np.union1d(old, drift)


# ---------------------------------------------------------------------------
# fit="fast" fallback machinery
# ---------------------------------------------------------------------------


def test_fast_fit_rejects_f64_collisions():
    """Fallback-trigger regression: on a table whose u64 keys collide
    after the f64 cast, both fast fits must return ``ok == False``
    (NaN propagates through the re-measure and compares False against
    any bound) — never a silently invalid model."""
    keys = _COLLIDING.astype(np.float64)
    assert len(np.unique(keys)) < len(keys)  # the collision premise
    _, ok = pgm_fit_fast(keys, 16.0)
    assert not bool(ok)
    _, ok = rs_knots_fast(keys, 16.0)
    assert not bool(ok)


def test_build_many_fast_falls_back_per_member():
    """The lazy host fallback: in a mixed fit="fast" batch the
    colliding member is re-fit with the exact scan (counted once in the
    fit_fast_fallbacks metric, per kind) while the healthy member keeps
    its fast fit — and the healthy member's ranks stay exact."""
    good = distributions.generate("osm", 1024, seed=3)
    qs = np.sort(np.random.default_rng(0).choice(good, 256))
    for spec, kind in ((ix.PGMSpec(eps=16), "PGM"), (ix.RSSpec(eps=16, r_bits=8), "RS")):
        before = obs.metric("fit_fast_fallbacks").value(kind=kind)
        bm = tune.build_many(spec, [_COLLIDING, good], fit="fast")
        assert obs.metric("fit_fast_fallbacks").value(kind=kind) - before == 1
        got = np.asarray(bm.lookup(qs))[1]
        np.testing.assert_array_equal(got, true_ranks(good, qs))


# ---------------------------------------------------------------------------
# tune.device_refresh: the ok-gated single-program install
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", sorted(DEVICE_REFRESH_KINDS))
@pytest.mark.parametrize("fit", ("fast", "scan"))
def test_device_refresh_contract(kind, fit):
    """The install contract, both arms: on ``ok`` the tier serves the
    merged keyset exactly; on rejection every leaf kept its old value,
    so lookups stay exact against the *original* table.  The exact scan
    fit must always install here (ample capacity headroom); the fast
    fit may trade a rejection for its O(log n) depth when the refit
    lands on a capacity/trip-budget boundary — either arm is correct,
    and both are asserted."""
    table = distributions.generate("osm", _N, seed=0)
    spec = _SPECS[kind]
    sidx = si.ShardedIndex.build(spec, table, n_shards=_SHARDS)
    drift, merged = _drifted(sidx, shard=1, n_new=40)
    # sidx is DONATED to the refresh program: no reads after this call
    s2, ok = device_refresh(sidx, 1, merged, eps=spec.eps, fit=fit)
    if fit == "scan":
        assert bool(ok)
    served = np.union1d(table, drift) if bool(ok) else table
    if bool(ok):
        assert int(s2.counts[1]) == len(merged)
    qs = np.sort(np.random.default_rng(2).choice(served, 512))
    got = np.asarray(si.sharded_lookup(s2, qs))
    np.testing.assert_array_equal(got, true_ranks(served, qs))


def test_device_refresh_host_side_rejections():
    """Conditions that need a restack anyway raise host-side instead of
    burning a device program: unsupported kinds and over-capacity
    merges (same cues as refresh_shard)."""
    table = distributions.generate("osm", _N, seed=0)
    rmi = si.ShardedIndex.build(ix.RMISpec(b=64), table, n_shards=_SHARDS)
    with pytest.raises(ValueError, match="device_refresh supports"):
        device_refresh(rmi, 0, table[:100], eps=32)
    sidx = si.ShardedIndex.build(_SPECS["PGM"], table, n_shards=_SHARDS)
    cap = int(sidx.tables.shape[1])
    over = np.arange(1, cap + 2, dtype=np.uint64)
    with pytest.raises(ValueError, match="restack the tier"):
        device_refresh(sidx, 0, over, eps=32)
    with pytest.raises(ValueError, match="unknown device fit"):
        device_refresh(sidx, 0, table[:100], eps=32, fit="greedy")


# ---------------------------------------------------------------------------
# TunedTier policy arm: ok / fallback outcomes
# ---------------------------------------------------------------------------


def _tier(kind, device_fit):
    table = distributions.generate("osm", _N, seed=0)
    tier = tune.TunedTier(
        table,
        n_shards=_SHARDS,
        spec=_SPECS[kind],
        policy=tune.RebuildPolicy(
            shard_refresh_frac=0.015,  # 30 pending keys per 2000-key shard
            retune_frac=10.0,
            device_refresh=True,
            device_fit=device_fit,
        ),
    )
    return table, tier


def test_tuned_tier_device_refresh_ok():
    """Drift past shard_refresh_frac with device_refresh=True runs the
    single-program path: the ok outcome is counted, the pending buffer
    drains, and lookups are exact on the merged keyset."""
    table, tier = _tier("PGM", device_fit="scan")
    # stay under the 2048 pow2 capacity: merged <= 2000 + ~35
    drift, _ = _drifted(tier.sidx, shard=1, n_new=35)
    before = obs.metric("device_refreshes").value(kind="PGM", outcome="ok")
    tier.insert_batch(drift)
    assert obs.metric("device_refreshes").value(kind="PGM", outcome="ok") - before == 1
    assert tier.counters.pending == 0
    merged = np.union1d(table, drift)
    qs = np.sort(np.random.default_rng(3).choice(merged, 512))
    np.testing.assert_array_equal(np.asarray(tier.lookup(qs)), true_ranks(merged, qs))


def test_tuned_tier_device_refresh_fallback_stays_exact():
    """A rejected device build (fast fit on a capacity boundary) counts
    the fallback outcome and the classic host refresh still lands the
    drift — the tier never serves a stale or invalid model."""
    table, tier = _tier("RS", device_fit="fast")
    # stay under the 2048 pow2 capacity: merged <= 2000 + ~35
    drift, _ = _drifted(tier.sidx, shard=1, n_new=35)
    fb = obs.metric("device_refreshes").value(kind="RS", outcome="fallback")
    ok = obs.metric("device_refreshes").value(kind="RS", outcome="ok")
    tier.insert_batch(drift)
    fb = obs.metric("device_refreshes").value(kind="RS", outcome="fallback") - fb
    ok = obs.metric("device_refreshes").value(kind="RS", outcome="ok") - ok
    assert fb + ok == 1  # exactly one device attempt, outcome recorded
    assert tier.counters.pending == 0
    merged = np.union1d(table, drift)
    qs = np.sort(np.random.default_rng(4).choice(merged, 512))
    np.testing.assert_array_equal(np.asarray(tier.lookup(qs)), true_ranks(merged, qs))
