"""The repro.tune subsystem: batched builds, Pareto tuner, rebuilds.

Covers the PR's acceptance contract:

* ``build_many`` output is bit-exact vs per-table ``build`` for every
  registered kind (host fit, equal-length tables), with at most one
  shared-lookup trace per (kind, backend);
* ``space_bytes`` agrees with the summed nbytes of the model's
  constituent leaves for every registered kind;
* frontier reports JSON-round-trip and ``best_spec_for_budget``
  respects the budget on every bench tier;
* the tuned tier refreshes drifted shards through the donated swap and
  re-tunes on large drift.
"""

import json

import numpy as np
import pytest

import repro  # noqa: F401
import jax.numpy as jnp

from repro import index as ix
from repro import tune
from repro.core import true_ranks

from conftest import make_table

PARAMS = {
    "L": {},
    "Q": {},
    "C": {},
    "KO": {"k": 7},
    "RMI": {"b": 64},
    "SY-RMI": {"space_pct": 2.0, "ub": 0.04},
    "PGM": {"eps": 16},
    "PGM_M": {"space_pct": 2.0, "a": 1.0},
    "RS": {"eps": 16, "r_bits": 8},
    "BTREE": {"fanout": 8},
    "GAPPED": {"leaf_cap": 64, "fill": 0.75, "delta_cap": 128},
}


def _tables(rng, n=2048):
    # distributions with different PGM segment structures, so stacking
    # exercises the level lift and unstack exercises its inverse
    return [make_table(rng, k, n) for k in ("uniform", "sequential", "clustered")]


def _queries(rng, tables, n=512):
    qs = rng.choice(np.concatenate(tables), size=n).astype(np.uint64)
    extremes = np.array([0, np.iinfo(np.uint64).max], dtype=np.uint64)
    return np.concatenate([qs, extremes])


# ---------------------------------------------------------------------------
# space accounting
# ---------------------------------------------------------------------------


def _leaf_nbytes(idx, names):
    return sum(int(np.asarray(idx.arrays[k]).nbytes) for k in names)


def expected_model_bytes(idx) -> int:
    """Summed nbytes of the model-constituent leaves, independently of
    the per-kind ``space_bytes`` implementations (valid prefixes for
    padded leaves; the RMI family's f32 kernel re-encoding excluded)."""
    a = idx.arrays
    key = ix.entry(idx.kind).query_key
    if key == "atomic":
        return 8 * (idx.s("degree") + 1) + _leaf_nbytes(idx, ("kmin", "inv_span", "eps"))
    if key == "ko":
        return _leaf_nbytes(
            idx, ("fences", "coef", "kmin_seg", "inv_span_seg", "eps", "seg_start")
        )
    if key == "rmi":
        return _leaf_nbytes(
            idx,
            ("root_coef", "leaf_slope", "leaf_icept", "leaf_eps", "leaf_r", "kmin", "inv_span"),
        )
    if key == "pgm":
        sizes = np.asarray(a["sizes"])
        kv, rv = int(sizes.sum()), int((sizes + 1).sum())
        return (
            kv * 16 + rv * 8 + _leaf_nbytes(idx, ("off", "off_r", "sizes", "eps"))
        )
    if key == "rs":
        m = int(np.asarray(a["m_valid"]))
        return m * 16 + _leaf_nbytes(idx, ("radix_table", "kmin", "shift", "eps_eff", "m_valid"))
    if key == "btree":
        return _leaf_nbytes(idx, ("keys", "off", "valid"))
    if key == "gapped":
        live = int(np.asarray(a["counts"]).sum()) + int(np.asarray(a["delta_count"]))
        return live * 8 + _leaf_nbytes(
            idx,
            (
                "counts", "fences", "route", "delta_count",
                "kmin", "inv_span", "root_slope", "root_icept", "root_eps",
            ),
        )
    raise AssertionError(key)


def test_space_bytes_agrees_with_leaf_nbytes(rng):
    table = make_table(rng, "uniform", 4096)
    for kind in ix.kinds():
        idx = ix.build(kind, table, **PARAMS[kind])
        assert idx.space_bytes() == expected_model_bytes(idx), kind
        # the model is never accounted larger than its resident arrays
        assert idx.space_bytes() <= idx.nbytes(), kind


# ---------------------------------------------------------------------------
# build_many
# ---------------------------------------------------------------------------


def test_build_many_bit_exact_all_kinds(rng):
    tables = _tables(rng)
    qs = _queries(rng, tables)
    for kind in ix.kinds():
        spec = ix.spec_for(kind, **PARAMS[kind])
        bm = tune.build_many(spec, tables)
        singles = [ix.build(spec, t) for t in tables]
        for i, (got, want) in enumerate(zip(bm.unstack(), singles)):
            assert got.kind == want.kind, kind
            assert got.static == want.static, (kind, i)
            assert set(got.arrays) == set(want.arrays), kind
            for name in want.arrays:
                assert np.array_equal(
                    np.asarray(got.arrays[name]), np.asarray(want.arrays[name])
                ), (kind, i, name)
        # the batched lookup answers every table exactly
        outs = np.asarray(bm.lookup(qs))
        for i, t in enumerate(tables):
            np.testing.assert_array_equal(outs[i], true_ranks(t, qs), err_msg=f"{kind}/{i}")
        assert bm.space_bytes() == sum(s.space_bytes() for s in singles), kind


def test_build_many_ragged_tables_lookup_exact(rng):
    tables = [make_table(rng, "uniform", n) for n in (1500, 700, 1024)]
    qs = _queries(rng, tables, n=256)
    for kind in ("RMI", "PGM", "RS", "BTREE"):
        bm = tune.build_many(ix.spec_for(kind, **PARAMS[kind]), tables)
        outs = np.asarray(bm.lookup(qs))
        for i, t in enumerate(tables):
            np.testing.assert_array_equal(outs[i], true_ranks(t, qs), err_msg=f"{kind}/{i}")


def test_batched_lookup_pallas_exact_all_kinds(rng):
    """Acceptance: BATCH_BACKENDS includes pallas, and the batched
    (table, q_tile)-grid kernels answer every kind exactly — fused
    batched RMI for the RMI family, batched lane-wide k-ary otherwise —
    including padded-tail clamping on ragged batches."""
    assert "pallas" in tune.BATCH_BACKENDS
    tables = _tables(rng)
    qs = _queries(rng, tables)
    for kind in ix.kinds():
        bm = tune.build_many(ix.spec_for(kind, **PARAMS[kind]), tables)
        if "pallas" not in bm.index.backends():
            # per-kind backend honesty: unclaimed backends raise loudly
            with pytest.raises(ValueError, match="supports backends"):
                bm.lookup(qs, backend="pallas")
            continue
        outs = np.asarray(bm.lookup(qs, backend="pallas"))
        for i, t in enumerate(tables):
            np.testing.assert_array_equal(outs[i], true_ranks(t, qs), err_msg=f"{kind}/{i}")
    # ragged: lookups against the padded tables clamp back to real keys
    ragged = [make_table(rng, "uniform", n) for n in (1500, 700, 1024)]
    for kind in ("RMI", "SY-RMI", "PGM", "RS"):
        bm = tune.build_many(ix.spec_for(kind, **PARAMS[kind]), ragged)
        outs = np.asarray(bm.lookup(qs, backend="pallas"))
        for i, t in enumerate(ragged):
            np.testing.assert_array_equal(outs[i], true_ranks(t, qs), err_msg=f"{kind}/{i}")


def test_build_many_vmap_fit_equivalent(rng):
    tables = [make_table(rng, k, 2048) for k in ("uniform", "lognormal", "bursty")]
    qs = _queries(rng, tables, n=256)
    for kind, params in (
        ("RMI", {"b": 128, "root_type": "cubic"}),
        ("SY-RMI", {"space_pct": 2.0, "ub": 0.04}),
    ):
        spec = ix.spec_for(kind, **params)
        bm = tune.build_many(spec, tables, fit="vmap")
        singles = [ix.build(spec, t) for t in tables]
        for i, (got, want) in enumerate(zip(bm.unstack(), singles)):
            # same structure as the host fit: leaf shapes/dtypes equal;
            # bucketed trip counts may shift one 4-step bucket when an
            # ulp-level eps difference crosses an integer boundary
            assert [k for k, _ in got.static] == [k for k, _ in want.static], (kind, i)
            for (name, g_v), (_, w_v) in zip(got.static, want.static):
                if name in ("epi", "ksteps"):
                    assert abs(g_v - w_v) <= 4, (kind, i, name, g_v, w_v)
                else:
                    assert g_v == w_v, (kind, i, name)
            for name in want.arrays:
                g, w = np.asarray(got.arrays[name]), np.asarray(want.arrays[name])
                assert g.shape == w.shape and g.dtype == w.dtype, (kind, i, name)
        # ... and exact predecessor ranks (the windows stay guarantees)
        outs = np.asarray(bm.lookup(qs))
        for i, t in enumerate(tables):
            np.testing.assert_array_equal(outs[i], true_ranks(t, qs), err_msg=f"{kind}/{i}")

    # explicit vmap on a kind without an array-native fit stays a crisp error
    with pytest.raises(ValueError, match="no array-native fit"):
        tune.build_many(ix.BTreeSpec(fanout=8), tables, fit="vmap")


def test_build_many_vmap_fit_scan_kinds_bit_exact(rng):
    """Acceptance: the PGM / PGM_M / RS scan fits are BIT-exact with the
    host greedy builds — segment/knot boundaries and every derived array
    identical per table after unstack() — in one fit trace per
    (kind, batch shape); ε is traced, so the bi-criteria bisection
    shares the PGM scan trace."""
    tables = _tables(rng)
    qs = _queries(rng, tables)
    ix.reset_trace_counts()
    for kind, params in (
        ("PGM", {"eps": 16}),
        ("PGM_M", {"space_pct": 2.0, "a": 1.0}),
        ("RS", {"eps": 16, "r_bits": 8}),
    ):
        spec = ix.spec_for(kind, **params)
        bm = tune.build_many(spec, tables, fit="vmap")
        singles = [ix.build(spec, t) for t in tables]
        for i, (got, want) in enumerate(zip(bm.unstack(), singles)):
            assert got.static == want.static, (kind, i)
            assert got.info.get("name") == want.info.get("name"), (kind, i)
            for name in want.arrays:
                g, w = np.asarray(got.arrays[name]), np.asarray(want.arrays[name])
                assert np.array_equal(g, w), (kind, i, name)
        outs = np.asarray(bm.lookup(qs))
        for i, t in enumerate(tables):
            np.testing.assert_array_equal(outs[i], true_ranks(t, qs), err_msg=f"{kind}/{i}")
    fit_traces = {k: v for (k, b), v in ix.trace_counts().items() if k.startswith("fit:")}
    # one shared scan trace per kind for the whole (N, n) batch shape —
    # PGM_M's bisection re-uses fit:PGM (ε is traced, not static)
    assert fit_traces == {"fit:PGM": 1, "fit:RS": 1}, fit_traces


def test_build_many_vmap_fit_scan_kinds_ragged(rng):
    """Scan fits compose with the ragged-batch padding idiom (strictly
    increasing continuation): lookups stay exact after the clamp."""
    ragged = [make_table(rng, "uniform", n) for n in (1500, 700, 1024)]
    qs = _queries(rng, ragged, n=256)
    for kind in ("PGM", "PGM_M", "RS"):
        bm = tune.build_many(ix.spec_for(kind, **PARAMS[kind]), ragged, fit="vmap")
        outs = np.asarray(bm.lookup(qs))
        for i, t in enumerate(ragged):
            np.testing.assert_array_equal(outs[i], true_ranks(t, qs), err_msg=f"{kind}/{i}")


def test_build_many_one_trace_per_kind_backend(backend, rng):
    tables = _tables(rng, n=1024)
    qs = _queries(rng, tables, n=128)
    ix.reset_trace_counts()
    for kind in ix.kinds():
        bm = tune.build_many(ix.spec_for(kind, **PARAMS[kind]), tables)
        if backend not in bm.index.backends():
            with pytest.raises(ValueError, match="supports backends"):
                bm.lookup(qs, backend=backend)
            continue
        bm.lookup(qs, backend=backend)
        bm.lookup(qs[: len(qs)], backend=backend)  # same shapes: no retrace
    for key, n in ix.trace_counts().items():
        assert n == 1, (key, n, ix.trace_counts())


# ---------------------------------------------------------------------------
# build_grid
# ---------------------------------------------------------------------------


def test_build_grid_shares_vmapped_fit_trace(rng):
    # table length / branching factor unique to this test: the fit-trace
    # assertion must not be satisfied by another test's cached trace
    table = make_table(rng, "uniform", 1600)
    qs = _queries(rng, [table], n=256)
    specs = [ix.RMISpec(b=96, root_type=r) for r in ("linear", "cubic", "spline")]
    specs += [ix.PGMSpec(eps=16), ix.BTreeSpec(fanout=8)]
    ix.reset_trace_counts()
    built = tune.build_grid(specs, table)
    assert ix.trace_counts().get(("fit:RMI", "vmap"), 0) == 1
    assert [b.kind for b in built] == [s.kind for s in specs]
    tj, qj = jnp.asarray(table), jnp.asarray(qs)
    for spec, idx in zip(specs, built):
        np.testing.assert_array_equal(
            np.asarray(idx.lookup(tj, qj)), true_ranks(table, qs), err_msg=str(spec)
        )


def test_build_grid_scan_kinds_share_fit_trace(rng):
    """A grid's PGM / RS entries share ONE vmapped corridor-scan trace
    per kind (ε traced), and the built indexes stay bit-exact with the
    registered host builders."""
    table = make_table(rng, "uniform", 1728)  # length unique to this test
    specs = [ix.PGMSpec(eps=e) for e in (8, 16, 32)]
    specs += [ix.RSSpec(eps=e, r_bits=8) for e in (8, 32)]
    specs += [ix.PGMBicriteriaSpec(space_pct=2.0), ix.PGMBicriteriaSpec(space_pct=10.0)]
    ix.reset_trace_counts()
    built = tune.build_grid(specs, table, fit="auto")
    fit_traces = {k: v for (k, b), v in ix.trace_counts().items() if k.startswith("fit:")}
    assert fit_traces.get("fit:PGM", 0) <= 2  # (3,)- and (2,)-member batch shapes
    assert fit_traces.get("fit:RS", 0) == 1
    for spec, idx in zip(specs, built):
        want = ix.build(spec, table)
        assert idx.static == want.static, spec
        for name in want.arrays:
            assert np.array_equal(
                np.asarray(idx.arrays[name]), np.asarray(want.arrays[name])
            ), (spec, name)


def test_build_grid_host_fit_matches_build(rng):
    table = make_table(rng, "clustered", 1024)
    specs = [ix.RMISpec(b=64), ix.PGMSpec(eps=16), ix.RSSpec(eps=16, r_bits=8)]
    for spec, idx in zip(specs, tune.build_grid(specs, table, fit="host")):
        want = ix.build(spec, table)
        assert idx.static == want.static
        for name in want.arrays:
            assert np.array_equal(np.asarray(idx.arrays[name]), np.asarray(want.arrays[name]))


# ---------------------------------------------------------------------------
# pareto tuner
# ---------------------------------------------------------------------------


def test_candidate_grid_covers_registry():
    specs = tune.candidate_grid(1 << 20)
    assert {s.kind for s in specs} == set(ix.kinds())
    restricted = tune.candidate_grid(1 << 20, kinds=("RMI", "PGM"))
    assert {s.kind for s in restricted} == {"RMI", "PGM"}


def test_frontier_monotone_and_json_roundtrip(rng):
    table = make_table(rng, "uniform", 4096)
    cands = tune.sweep(table, n_queries=256, reps=1, check_exact=True)
    assert all(c.exact for c in cands)
    front = tune.pareto_frontier(cands)
    assert front
    spaces = [c.space_bytes for c in front]
    times = [c.ns_per_query for c in front]
    assert spaces == sorted(spaces) and len(set(spaces)) == len(spaces)
    assert all(times[i] > times[i + 1] for i in range(len(times) - 1))
    report = tune.frontier_report(table, cands, front)
    decoded = json.loads(json.dumps(report))
    assert decoded["n_keys"] == len(table)
    assert tune.report_specs(decoded, "frontier") == [c.spec for c in front]
    assert tune.report_specs(decoded, "candidates") == [c.spec for c in cands]


def test_best_spec_for_budget_respects_budget_on_all_tiers(rng):
    from repro.data import tables as dtables

    # the bench tiers, scaled to test size (same shape: one table per
    # tier subsampled CDF-preservingly from the largest)
    tiers = {"L1": 2048, "L2": 8192, "L3": 16384}
    bts = dtables.make_bench_tables(datasets=("osm",), tiers=tiers, seed=3)
    assert {bt.tier for bt in bts} == set(tiers)
    for bt in bts:
        for pct in (0.7, 2.0, 10.0):
            spec = tune.best_spec_for_budget(bt.table, pct, n_queries=128, reps=1)
            built = ix.build(spec, bt.table)
            budget = pct / 100.0 * len(bt.table) * 8
            assert built.space_bytes() <= budget, (bt.tier, pct, spec, built.space_bytes())


def test_best_spec_for_budget_impossible_budget(rng):
    table = make_table(rng, "uniform", 1024)
    with pytest.raises(ValueError):
        tune.best_spec_for_budget(table, 0.01, n_queries=64, reps=1)


# ---------------------------------------------------------------------------
# rebuild policy / tuned tier
# ---------------------------------------------------------------------------


def test_tuned_tier_refresh_and_retune(rng):
    from repro.dist import reset_tier_metrics, tier_metrics

    table = make_table(rng, "uniform", 4096)
    reset_tier_metrics()
    tier = tune.TunedTier(
        table,
        n_shards=4,
        policy=tune.RebuildPolicy(
            space_budget_pct=2.0,
            shard_refresh_frac=0.02,
            retune_frac=0.5,
            n_queries=128,
            kinds=("RMI", "PGM", "BTREE"),
        ),
    )
    budget = 2.0 / 100.0 * len(table) * 8
    assert tier.sidx.space_bytes() <= budget * 4  # per-shard models + router
    qs = rng.choice(table, size=512).astype(np.uint64)
    np.testing.assert_array_equal(np.asarray(tier.lookup(qs, mode="ref")), true_ranks(table, qs))

    # small drift: shard refresh (donated swap) or forced restack
    new_keys = np.setdiff1d(
        np.unique(rng.integers(0, 2**63, size=300, dtype=np.uint64)), table
    )
    tier.insert_batch(new_keys)
    c = tier.counters
    assert c.shard_refreshes + c.forced_restacks + c.retunes >= 1
    merged = np.union1d(table, new_keys)
    q2 = rng.choice(merged, size=512).astype(np.uint64)
    np.testing.assert_array_equal(np.asarray(tier.lookup(q2, mode="ref")), true_ranks(merged, q2))

    # large drift: full re-tune through the bi-criteria sweep
    big = np.setdiff1d(
        np.unique(rng.integers(0, 2**63, size=3000, dtype=np.uint64)), merged
    )
    tier.insert_batch(big)
    assert tier.counters.retunes >= 1
    merged2 = np.union1d(merged, big)
    q3 = rng.choice(merged2, size=512).astype(np.uint64)
    np.testing.assert_array_equal(
        np.asarray(tier.lookup(q3, mode="ref")), true_ranks(merged2, q3)
    )

    m = tier.metrics()
    assert m["n_keys"] == len(merged2)
    assert m["routing"]["lookups"] == tier_metrics()["lookups"] >= 3
    assert m["routing"]["imbalance_last"] >= 1.0
    assert m["routing"]["drop_rate"] == 0.0


def test_sharded_lookup_telemetry_counters(rng):
    from repro.dist import reset_tier_metrics, tier_metrics
    from repro.dist.sharded_index import ShardedIndex, sharded_lookup

    table = make_table(rng, "uniform", 2048)
    sidx = ShardedIndex.build("RMI", table, n_shards=4, b=32)
    qs = rng.choice(table, size=256).astype(np.uint64)
    reset_tier_metrics()
    sharded_lookup(sidx, qs)  # telemetry off by default
    assert tier_metrics()["lookups"] == 0
    sharded_lookup(sidx, qs, telemetry=True)
    m = tier_metrics()
    assert m["lookups"] == 1 and m["queries"] == len(qs)
    assert m["imbalance_last"] >= 1.0 and m["imbalance_mean"] >= 1.0
    assert m["dropped"] == 0 and m["drop_rate"] == 0.0
    # skewed batch: every query owned by one shard -> imbalance ~ n_shards
    skew = np.full(256, np.asarray(table)[-1], dtype=np.uint64)
    sharded_lookup(sidx, skew, telemetry=True)
    assert tier_metrics()["imbalance_peak"] == pytest.approx(4.0)
    # a per-tier sink receives its own counters; the global view aggregates
    from repro.dist.sharded_index import _fresh_tier_metrics

    sink = _fresh_tier_metrics()
    sharded_lookup(sidx, qs, telemetry=True, telemetry_sink=sink)
    assert sink["lookups"] == 1 and sink["queries"] == len(qs)
    assert tier_metrics()["lookups"] == 3
