"""repro.analysis — the project static-analysis pass.

Three layers of coverage:

* **fixtures** — every rule family has a ``*_bad.py`` fixture that must
  flag and a ``*_ok.py`` counterpart that must stay clean (the false-
  positive budget is part of the contract);
* **gate demonstration** — the PR 5 salted-seed bug and the PR 1
  unclamped-cast bug, re-introduced verbatim in
  ``pr_regression_bad.py``, must both be caught; their shipped fixes
  must not be;
* **tree-wide** — the analyzer runs over the real tree (project rules
  included) and every finding must be covered by the committed baseline,
  with no stale baseline entries.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

from tools import analysis
from tools.analysis import (
    ALL_RULES,
    Finding,
    analyze_paths,
    analyze_tree,
    load_baseline,
    split_by_baseline,
)

ROOT = Path(__file__).resolve().parents[1]
FIX = ROOT / "tests" / "analysis_fixtures"


def _scan(*names):
    return analyze_paths([FIX / n for n in names])


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Rule catalogue sanity
# ---------------------------------------------------------------------------


def test_rule_ids_unique_and_complete():
    ids = [r.id for r in ALL_RULES]
    assert ids == sorted(set(ids)), "duplicate or unordered rule ids"
    assert ids == ["R1", "R2", "R3", "R4", "R5", "R6", "R7", "R8"]
    for r in ALL_RULES:
        assert r.title != "?" and r.blurb != "?"


# ---------------------------------------------------------------------------
# Per-rule fixtures: bad flags, ok stays clean
# ---------------------------------------------------------------------------


def test_r1_salted_hash_fixture():
    bad = _scan("r1_bad.py")
    assert _rules(bad) == {"R1"}
    assert len(bad) == 4  # one per seeding form in the fixture
    assert not _scan("r1_ok.py")


def test_r2_unclamped_cast_fixture():
    bad = _scan("r2_kernel_bad.py")
    assert _rules(bad) == {"R2"}
    names = {f.message for f in bad}
    assert any("_predict_kernel" in m for m in names)
    assert any("_scaled_body" in m for m in names)
    assert not _scan("r2_kernel_ok.py")


def test_r3_trace_discipline_fixture():
    bad = _scan("r3_bad.py")
    assert _rules(bad) == {"R3"}
    flagged_fns = {
        fn
        for fn in (
            "branch_on_traced",
            "concretize_traced",
            "item_on_traced",
            "numpy_on_traced",
            "reads_mutable_global",
            "_loop_kernel",
        )
        if any(fn in f.message for f in bad)
    }
    assert len(flagged_fns) == 6, f"missing: {flagged_fns ^ set()}"
    assert not _scan("r3_ok.py")


def test_r5_magic_sentinel_fixture():
    bad = _scan("r5_bad.py")
    assert _rules(bad) == {"R5"}
    assert len(bad) == 3  # comparison, where() fill, full() fill
    assert not _scan("r5_ok.py")


def test_r6_kernel_f64_fixture():
    bad = _scan("r6_kernel_bad.py")
    assert "R6" in _rules(bad)
    assert sum(f.rule == "R6" for f in bad) == 3
    assert not _scan("r6_kernel_ok.py")


def test_r7_removed_api_fixture():
    bad = _scan("r7_bad.py")
    assert _rules(bad) == {"R7"}
    # imports (build_index, prepare_rmi_kernel_index, core KINDS),
    # attribute accesses (core.build_index, ops.fused_rmi_search,
    # core.KINDS), and the class redefinition of RMIKernelIndex
    assert len(bad) == 7, [f.format() for f in bad]
    names = " ".join(f.message for f in bad)
    for gone in (
        "build_index",
        "prepare_rmi_kernel_index",
        "fused_rmi_search",
        "RMIKernelIndex",
        "KINDS",
    ):
        assert gone in names
    # the `_pallas`-suffixed real kernel and registry kinds() stay legal
    assert not _scan("r7_ok.py")


# ---------------------------------------------------------------------------
# Gate demonstration: the two shipped bugs, re-introduced
# ---------------------------------------------------------------------------


def test_r8_raw_timing_fixture():
    bad = _scan("r8_bad.py")
    assert _rules(bad) == {"R8"}
    # inline perf_counter delta, time.time delta, from-import alias delta
    assert len(bad) == 3, [f.format() for f in bad]
    assert all("repro.obs.timing" in f.hint for f in bad)  # the fix hint
    assert not _scan("r8_ok.py")


def test_shipped_bugs_are_caught():
    bad = _scan("pr_regression_bad.py")
    assert _rules(bad) == {"R1", "R2"}, [f.format() for f in bad]
    r1 = [f for f in bad if f.rule == "R1"]
    r2 = [f for f in bad if f.rule == "R2"]
    assert len(r1) == 1 and "hash" in r1[0].snippet  # PR 5 seeding bug
    assert len(r2) == 1 and "_rmi_kernel" in r2[0].message  # PR 1 cast bug


def test_shipped_fixes_stay_clean():
    assert not _scan("pr_regression_ok.py")


# ---------------------------------------------------------------------------
# Tree-wide: findings ⊆ baseline, no stale suppressions
# ---------------------------------------------------------------------------


def test_tree_clean_modulo_baseline():
    files, findings = analyze_tree()  # project rules (R4) included
    assert len(files) > 50
    assert not any("analysis_fixtures" in f.path for f in findings)
    new, _suppressed, stale = split_by_baseline(findings, load_baseline())
    assert not new, "new findings:\n" + "\n".join(f.format() for f in new)
    assert not stale, f"stale baseline entries: {stale}"


def test_registry_contract_flags_broken_kind():
    from repro.index import registry

    def _boom(**params):
        raise RuntimeError("deliberately broken spec factory")

    registry._REGISTRY["BROKEN"] = registry.KindEntry(
        kind="BROKEN",
        spec_cls=None,
        build=None,
        query_key="atomic",
        spec_from_params=_boom,
    )
    try:
        from tools.analysis.rules_contract import RegistryContractRule

        findings = list(RegistryContractRule().check_project(ROOT))
    finally:
        del registry._REGISTRY["BROKEN"]
    assert any("BROKEN" in f.message and f.rule == "R4" for f in findings)
    # and the healthy kinds contribute nothing
    assert all("BROKEN" in f.message for f in findings)


# ---------------------------------------------------------------------------
# Baseline mechanics
# ---------------------------------------------------------------------------


def _finding(line=3, snippet="x = hash(name)"):
    return Finding(
        rule="R1", path="src/x.py", line=line, col=0, message="msg", snippet=snippet
    )


def test_baseline_suppresses_on_fingerprint_not_line():
    entries = [{"rule": "R1", "path": "src/x.py", "snippet": "x = hash(name)", "why": "test"}]
    new, supp, stale = split_by_baseline([_finding(line=3)], entries)
    assert (len(new), len(supp), len(stale)) == (0, 1, 0)
    # same fingerprint on a drifted line: still suppressed
    new, supp, stale = split_by_baseline([_finding(line=99)], entries)
    assert (len(new), len(supp), len(stale)) == (0, 1, 0)
    # different snippet (a NEW occurrence): not suppressed
    new, supp, stale = split_by_baseline([_finding(snippet="y = hash(other)")], entries)
    assert (len(new), len(supp), len(stale)) == (1, 0, 1)


def test_unmatched_baseline_entry_is_stale():
    entries = [{"rule": "R9", "path": "gone.py", "snippet": "never"}]
    new, supp, stale = split_by_baseline([], entries)
    assert not new and not supp and stale == entries


# ---------------------------------------------------------------------------
# CLI contract (the CI gate invocation)
# ---------------------------------------------------------------------------


def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return subprocess.run(
        [sys.executable, "-m", "tools.analysis", *args],
        cwd=ROOT,
        capture_output=True,
        text=True,
        env=env,
    )


def test_cli_check_nonzero_on_reintroduced_bugs():
    r = _cli("--check", "--no-baseline", str(FIX / "pr_regression_bad.py"))
    assert r.returncode == 1
    assert "[R1]" in r.stdout and "[R2]" in r.stdout


def test_cli_check_clean_on_fixed_forms():
    r = _cli("--check", "--no-baseline", str(FIX / "pr_regression_ok.py"))
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_json_artifact(tmp_path):
    out = tmp_path / "analysis.json"
    # without --check the exit stays 0 (exploratory mode) but the JSON
    # artifact still carries the findings
    r = _cli("--json", str(out), "--no-baseline", str(FIX / "r1_bad.py"))
    assert r.returncode == 0, r.stdout + r.stderr
    data = json.loads(out.read_text())
    assert data["counts"]["new"] == 4
    assert {row["id"] for row in data["rules"]} == {
        "R1",
        "R2",
        "R3",
        "R4",
        "R5",
        "R6",
        "R7",
        "R8",
    }
    assert all(f["rule"] == "R1" for f in data["findings"])


def test_cli_list_rules():
    r = _cli("--list-rules")
    assert r.returncode == 0
    for rid in ("R1", "R2", "R3", "R4", "R5", "R6", "R7", "R8"):
        assert rid in r.stdout


def test_catalogue_matches_all_rules():
    rows = analysis.rule_catalogue()
    assert [rid for rid, _, _ in rows] == [r.id for r in ALL_RULES]
