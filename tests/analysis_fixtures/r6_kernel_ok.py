"""R6 fixture: f32/i32-only kernel + host-side f64 — must stay clean."""

import jax.numpy as jnp
import numpy as np


def _interp_kernel(x_ref, cdf_ref, out_ref, *, n: int):
    x = x_ref[...].astype(jnp.float32)
    pos = jnp.clip(x * float(n), 0.0, float(n - 1))
    out_ref[...] = pos.astype(jnp.int32)


def build_host_tables(keys):
    # host-side build-time f64 precision work is the kernels/ops.py idiom
    cdf = np.cumsum(keys.astype(np.float64))
    return (cdf / cdf[-1]).astype(np.float32)
