"""R7 fixture: every way a removed shim name can sneak back in."""

from repro.core import build_index  # BAD: deleted builder shim
from repro.kernels import prepare_rmi_kernel_index  # BAD: deleted kernel shim
from repro.core import KINDS  # BAD: core-scoped KINDS tuple is gone

from repro import core
from repro.kernels import ops


def legacy_build(table):
    # BAD: attribute access resurrects the shim spelling
    return core.build_index("RMI", table)


def legacy_kernel_path(m, table, u, qh, ql):
    ki = prepare_rmi_kernel_index(m, table)
    # BAD: deleted fused entry point (the `_pallas`-suffixed one is the
    # real kernel and stays legal — see r7_ok.py)
    return ops.fused_rmi_search(ki, u, qh, ql)


class RMIKernelIndex:  # BAD: redefining the deleted container
    pass


def list_kinds():
    # BAD: core.KINDS attribute access
    return core.KINDS
