"""R6 fixture: f64 dtypes inside kernel bodies (must flag)."""

import jax.numpy as jnp
import numpy as np


def _interp_kernel(x_ref, cdf_ref, out_ref, *, n: int):
    # BAD: f64 arithmetic in a TPU kernel body — no f64 vector unit
    x = x_ref[...].astype(jnp.float64)
    out_ref[...] = (x * n).astype(jnp.int32)


def _dtype_string_kernel(x_ref, out_ref, *, n: int):
    out_ref[...] = x_ref[...].astype("float64")  # BAD: string dtype form


def _np_double_body(x_ref, out_ref, *, n: int):
    out_ref[...] = x_ref[...] * np.float64(0.5)  # BAD: np scalar f64
