"""R7 fixture: the unified replacements and near-miss names — must stay clean."""

from repro import index as ix
from repro.index import build
from repro.kernels.rmi_search import fused_rmi_search_pallas  # suffixed real kernel


def unified_build(table):
    return build("RMI", table)


def unified_lookup(idx, queries):
    return idx.lookup(queries, backend="pallas")


def list_kinds():
    # registry kinds() is fine; only repro.core's deleted KINDS is banned
    return ix.kinds()


def local_kinds_tuple():
    # a *local* KINDS name (not on repro.core) is legal
    KINDS = ("L", "Q")
    return KINDS


def kernel_call(u, qh, ql, th, tl, coef, s, i, e, rlo, rhi, steps):
    # exact-name matching: the `_pallas` suffix must not flag
    return fused_rmi_search_pallas(
        u, qh, ql, th, tl, coef, s, i, e, rlo, rhi, steps=steps
    )
