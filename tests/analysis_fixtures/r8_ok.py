"""R8 fixture: sanctioned timing — stopwatch/span, or no delta at all."""

import time

from repro.obs.timing import span, stopwatch


def build_with_stopwatch(table):
    sw = stopwatch()
    model = sum(table)
    return model, sw.elapsed  # OK: delta through repro.obs


def traced_block(run):
    with span("fixture.block"):  # OK: span records the histogram
        run()


def timestamp_only():
    # OK: a timer call that never flows into a subtraction (wall-clock
    # stamping, not a recorded delta)
    return {"started_at": time.time()}


def unrelated_subtraction(a, b):
    return a - b  # OK: not a timer delta
