"""R2 fixture: unclamped f32 -> i32 casts in kernel bodies (must flag)."""

import jax.numpy as jnp


def _predict_kernel(q_ref, slope_ref, icept_ref, out_ref, *, n: int):
    q = q_ref[...].astype(jnp.float32)
    pred = slope_ref[...] * q + icept_ref[...]
    # BAD: |pred| can exceed i32 range on key gaps; the cast is garbage
    # and the later clip happily clamps garbage into a plausible window
    pos = pred.astype(jnp.int32)
    out_ref[...] = jnp.clip(pos, 0, n - 1)


def _scaled_body(x_ref, out_ref, *, scale: float):
    # BAD: float arithmetic (scale literal mention) cast without clamp
    out_ref[...] = (x_ref[...] * 0.5).astype("int32")
