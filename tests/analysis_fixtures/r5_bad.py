"""R5 fixture: magic sentinel literals where named constants exist."""

import jax.numpy as jnp

DROPPED = -2
NO_PRED = -1


def drop_rate(out):
    # BAD: raw -2 comparison; renumbering DROPPED silently breaks this
    return (out == -2).mean()


def mask_no_pred(r, offset):
    # BAD: raw -1 in a where() fill position
    return jnp.where(r < 0, -1, offset + r)


def fill_dropped(shape):
    # BAD: raw -2 as a full() fill value
    return jnp.full(shape, -2, dtype=jnp.int64)
