"""Gate-demonstration fixture: the two bugs this repo actually shipped.

Form 1 is the PR 5 seeding bug (``data/distributions.generate`` before
the fix): ``seed + hash(name)`` is PYTHONHASHSEED-salted, so every
process generated a *different* "deterministic" dataset and the bench
trend gate compared apples to oranges.

Form 2 is the PR 1 kernel bug (``kernels/rmi_search.py`` before the
fix): on key gaps the root model's prediction blows up to ``|p| ~ 1e15``;
the unclamped f32→i32 cast is implementation-defined garbage, and the
*later* window clip just clamps garbage into a plausible-looking (wrong)
search window.
"""

import jax.numpy as jnp
import numpy as np


def generate(name: str, n: int, seed: int = 0):
    # PR 5 bug form: salted hash feeding the rng seed
    rng = np.random.default_rng(seed + hash(name) % (2**31))
    return np.sort(rng.integers(0, 2**63, size=n, dtype=np.uint64))


def _rmi_kernel(qhi_ref, qlo_ref, slope_ref, icept_ref, out_ref, *, b: int, n: int):
    # PR 1 bug form: unclamped root prediction cast straight to i32
    u = qhi_ref[...].astype(jnp.float32) * 2.0
    p_root = slope_ref[...] * u + icept_ref[...]
    leaf = p_root.astype(jnp.int32)
    out_ref[...] = jnp.clip(leaf, 0, b - 1)  # clips garbage, not the float
