"""R3 fixture: trace-discipline violations (every function must flag)."""

from functools import partial

import jax
import numpy as np

_CACHE = {}


@jax.jit
def branch_on_traced(x, threshold):
    # BAD: python `if` on a traced argument -> ConcretizationError (or a
    # silent re-trace per value if threshold is weakly typed)
    if threshold > 0:
        return x * threshold
    return x


@partial(jax.jit, static_argnames=("n",))
def concretize_traced(x, n: int):
    # BAD: float() forces the tracer to a host value
    scale = float(x)
    return scale * n


@jax.jit
def item_on_traced(x):
    return x.item()  # BAD: host sync / trace error


@jax.jit
def numpy_on_traced(x):
    return np.asarray(x).sum()  # BAD: numpy cannot consume tracers


@jax.jit
def reads_mutable_global(x):
    # BAD: dict captured at trace time; later mutations invisible
    return x * _CACHE.get("scale", 1)


def _loop_kernel(x_ref, out_ref, *, steps: int):
    acc = x_ref[...]
    # BAD: python while on a traced ref inside a kernel body
    while x_ref[0] > 0:
        acc = acc - 1
    out_ref[...] = acc
