"""R5 fixture: named sentinels and non-sentinel negatives — must stay clean."""

import jax.numpy as jnp

DROPPED = -2
NO_PRED = -1


def drop_rate(out):
    return (out == DROPPED).mean()


def mask_no_pred(r, offset):
    return jnp.where(r < 0, NO_PRED, offset + r)


def non_sentinel_uses(x):
    # arithmetic, indexing, axis= and reshape(-1) are not sentinel spots
    y = x - 1
    last_two = x[-2]
    flat = x.reshape(-1)
    s = jnp.sum(x, axis=-2)
    lo = x > -1  # ordering comparison, not equality routing
    return y, last_two, flat, s, lo
