"""R1 fixture: salted-hash seeding (every form must flag)."""

import numpy as np


def seed_from_name(seed: int, name: str):
    # hash() of a str is PYTHONHASHSEED-salted: different every process
    return np.random.default_rng(seed + hash(name))


def string_literal_hash():
    return hash("osm_cellids")  # stringish arg: flagged unconditionally


def fstring_hash(tag):
    return hash(f"dataset-{tag}")


def seedy_statement(obj):
    rng_seed = hash(obj) % (2**31)  # seedy context via name mention
    return rng_seed
