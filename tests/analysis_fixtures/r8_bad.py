"""R8 fixture: raw wall-clock deltas that bypass repro.obs.timing."""

import time
from time import perf_counter as pc


def build_with_inline_delta(table):
    t0 = time.perf_counter()
    model = sum(table)
    dt = time.perf_counter() - t0  # BAD: name-flow delta
    return model, dt


def lookup_with_direct_delta(run):
    start = time.time()
    run()
    return time.time() - start  # BAD: name-flow delta on time.time


def best_of_reps(run):
    best = float("inf")
    for _ in range(3):
        t = pc()
        run()
        best = min(best, pc() - t)  # BAD: from-import alias delta
    return best
