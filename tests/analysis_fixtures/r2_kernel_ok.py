"""R2 fixture: clamped / boolean casts in kernel bodies — must stay clean."""

import jax.numpy as jnp


def _predict_kernel(q_ref, slope_ref, icept_ref, out_ref, *, n: int):
    q = q_ref[...].astype(jnp.float32)
    pred = slope_ref[...] * q + icept_ref[...]
    # the rmi_search.py idiom: dominating clamp BEFORE the narrowing cast
    pred = jnp.clip(pred, -1.0e9, 1.0e9)
    out_ref[...] = pred.astype(jnp.int32)


def _select_kernel(a_ref, b_ref, out_ref, *, n: int):
    # boolean-shaped cast: the branch-free select idiom, always in range
    le = a_ref[...] <= b_ref[...]
    out_ref[...] = le.astype(jnp.int32)


def _floor_clamped_kernel(x_ref, out_ref, *, n: int):
    # clamp survives shape-preserving floor()
    pos = jnp.floor(jnp.clip(x_ref[...] * 2.0, 0.0, float(n)))
    out_ref[...] = pos.astype(jnp.int32)
