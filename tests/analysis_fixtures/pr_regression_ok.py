"""Gate-demonstration fixture: the shipped (fixed) forms — must stay clean."""

import zlib

import jax.numpy as jnp
import numpy as np


def generate(name: str, n: int, seed: int = 0):
    # PR 5 fix: process-stable crc32 offset (data/distributions.generate)
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (2**31))
    return np.sort(rng.integers(0, 2**63, size=n, dtype=np.uint64))


def _rmi_kernel(qhi_ref, qlo_ref, slope_ref, icept_ref, out_ref, *, b: int, n: int):
    # PR 1 fix: dominating clamp on the float BEFORE the narrowing cast
    u = qhi_ref[...].astype(jnp.float32) * 2.0
    p_root = slope_ref[...] * u + icept_ref[...]
    p_root = jnp.clip(p_root, -1.0e9, 1.0e9)
    leaf = p_root.astype(jnp.int32)
    out_ref[...] = jnp.clip(leaf, 0, b - 1)
