"""R1 fixture: process-stable seeding — must stay clean."""

import zlib

import numpy as np


def seed_from_name(seed: int, name: str):
    # the data/distributions.generate idiom: crc32 is process-stable
    return np.random.default_rng(seed + zlib.crc32(name.encode()) % (2**31))


def hash_outside_seed_path(d: dict, key):
    # plain dict-protocol use of hash() away from any seed/rng context
    bucket = hash(key)
    return bucket in d
