"""R3 fixture: trace-disciplined code — must stay clean."""

from functools import partial

import jax
import jax.numpy as jnp

_LIMIT = 8  # immutable module global: fine to capture


@partial(jax.jit, static_argnames=("flavor", "n"))
def branch_on_static(x, flavor: str, n: int):
    # branching on declared-static args is the repo's standard idiom
    if flavor == "wide":
        return x * n
    return x


@jax.jit
def branchless(x, threshold):
    return jnp.where(threshold > 0, x * threshold, x)


def _step_body(x_ref, out_ref, *, steps: int):
    # kw-only kernel params are static by convention: python range() is fine
    acc = x_ref[...]
    for _ in range(steps):
        acc = acc + _LIMIT
    out_ref[...] = acc


def host_helper(arr):
    # not jitted and not a kernel context: float()/if are unrestricted
    if float(arr[0]) > 0:
        return list(arr)
    return []
