"""repro.obs — the unified metrics/tracing/profiling layer.

Four layers of coverage:

* **registry unit tests** — counters/gauges/histograms on a private
  ``Registry`` (no global state), snapshot/diff/JSONL round-trips,
  quantile math;
* **overhead gates** — telemetry-on ``sharded_lookup`` adds at most ONE
  new jitted trace (the owner histogram) and never perturbs the lookup
  traces; telemetry-off lookups import nothing from ``repro.obs``;
* **view parity** — ``tier_metrics()`` / ``TunedTier.metrics()`` /
  ``DecodeEngine.metrics()`` render from registry snapshots but keep
  their PR 2/6 shapes, and the PR 8 regressions
  (``derived_tier_metrics({})``, sink-reset ownership) stay fixed;
* **harness smoke** — ``serve_slo.check_slo`` gates and the
  ``python -m repro.obs`` CLI.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import repro
from repro import index as ix
from repro import obs
from repro.dist import sharded_index as si
from repro.obs import registry as obs_registry
from repro.obs.timing import span, stopwatch, timed_lookup

from conftest import make_queries, make_table

ROOT = Path(__file__).resolve().parents[1]
N = 2048


def fresh_registry() -> obs_registry.Registry:
    return obs_registry.Registry()


# ---------------------------------------------------------------------------
# Registry unit tests (private registry: no global state)
# ---------------------------------------------------------------------------


def test_counter_gauge_basics():
    reg = fresh_registry()
    c = reg.metric("route_queries")  # catalogue-backed: labels=("tier",)
    c.inc(3, tier="a")
    c.inc(4, tier="a")
    c.inc(1, tier="b")
    assert c.value(tier="a") == 7.0
    assert c.value(tier="b") == 1.0
    with pytest.raises(ValueError):
        c.inc(1, wrong_label="a")
    g = reg.metric("tier_pending")
    g.set(5, tier="a")
    g.set(2, tier="a")
    assert g.value(tier="a") == 2.0
    g.max(9, tier="a")
    g.max(4, tier="a")
    assert g.value(tier="a") == 9.0


def test_metric_catalogue_names_are_closed():
    reg = fresh_registry()
    with pytest.raises(KeyError):
        reg.metric("not_a_registered_metric")
    # every catalogue entry materialises with its declared type
    for name, mtype, _labels, desc in obs.metric_catalogue():
        m = reg.metric(name)
        assert type(m).__name__.lower() == mtype
        assert desc


def test_histogram_observe_and_quantiles():
    reg = fresh_registry()
    h = reg.histogram("obs_test_us", labels=("name",), edges=[1.0, 10.0, 100.0])
    for v in (0.5, 5.0, 5.0, 50.0, 500.0):
        h.observe(v, name="t")
    snap = reg.snapshot()
    s = obs.find_sample(snap, "obs_test_us", name="t")
    assert s["count"] == 5
    assert s["counts"] == [1, 2, 1, 1]
    assert s["sum"] == pytest.approx(560.5)
    # quantiles: interpolated within buckets, saturating at the top edge
    assert 0.0 < obs.hist_quantile(s, 0.5) <= 10.0
    assert obs.hist_quantile(s, 0.99) == pytest.approx(100.0)
    empty = {"edges": [1.0, 10.0], "counts": [0, 0, 0], "count": 0, "sum": 0.0}
    assert obs.hist_quantile(empty, 0.5) == 0.0


def test_histogram_edges_must_increase():
    reg = fresh_registry()
    with pytest.raises(ValueError):
        reg.histogram("obs_test_us", edges=[10.0, 1.0])


def test_exp_edges_and_default_latency_edges():
    e = obs_registry.exp_edges(1.0, 1000.0, 4)
    assert e[0] == pytest.approx(1.0) and e[-1] == pytest.approx(1000.0)
    assert all(b > a for a, b in zip(e, e[1:]))
    d = obs_registry.DEFAULT_LATENCY_EDGES
    assert d[0] == pytest.approx(1.0) and d[-1] == pytest.approx(1e7)


def test_snapshot_diff_counters_subtract_gauges_latch():
    reg = fresh_registry()
    reg.metric("route_queries").inc(10, tier="a")
    reg.metric("tier_pending").set(3, tier="a")
    before = reg.snapshot()
    reg.metric("route_queries").inc(5, tier="a")
    reg.metric("tier_pending").set(8, tier="a")
    after = reg.snapshot()
    d = obs.diff(before, after)
    assert obs.sample_value(d, "route_queries", tier="a") == 5.0
    assert obs.sample_value(d, "tier_pending", tier="a") == 8.0


def test_jsonl_round_trip_is_stable():
    reg = fresh_registry()
    reg.metric("route_queries").inc(4, tier="a")
    reg.metric("span_us").observe(5.0, name="x")
    snap = reg.snapshot()
    text = obs.to_jsonl(snap)
    for line in text.strip().splitlines():
        row = json.loads(line)  # one valid JSON object per line
        assert {"name", "type", "labels"} <= set(row)
    back = obs.from_jsonl(text)
    assert obs.sample_value(back, "route_queries", tier="a") == 4.0
    assert obs.find_sample(back, "span_us", name="x")["count"] == 1
    assert obs.to_jsonl(back) == text


def test_reset_prefix_only_clears_that_family():
    reg = fresh_registry()
    reg.metric("route_queries").inc(4, tier="a")
    reg.metric("tier_lookups").inc(2, tier="a")
    reg.reset(prefix="route_")
    snap = reg.snapshot()
    assert obs.sample_value(snap, "route_queries", tier="a", default=0.0) == 0.0
    assert obs.sample_value(snap, "tier_lookups", tier="a") == 2.0


def test_span_and_stopwatch_record():
    reg = fresh_registry()
    sw = stopwatch()
    with span("obs_test.block", registry=reg):
        pass
    assert sw.elapsed >= 0.0
    s = obs.find_sample(reg.snapshot(), "span_us", name="obs_test.block")
    assert s["count"] == 1


# ---------------------------------------------------------------------------
# Overhead gates: traces and imports
# ---------------------------------------------------------------------------


def test_telemetry_on_adds_at_most_one_trace(rng):
    """Telemetry-on sharded lookups leave the shared lookup traces
    untouched and add at most one jitted dispatch (the owner
    histogram); timed_lookup adds only the single histogram-update
    trace."""
    table = make_table(rng, "uniform", N)
    qs = make_queries(rng, table, 512)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=4, b=64)
    si.sharded_lookup(sidx, qs)  # telemetry-off: prime the lookup trace
    before = dict(ix.trace_counts())

    si.sharded_lookup(sidx, qs, telemetry=True)
    after = dict(ix.trace_counts())
    lookup_keys = {k for k in before if not k[0].startswith("obs:")}
    assert {k: after[k] for k in lookup_keys} == {k: before[k] for k in lookup_keys}
    new = {k: v for k, v in after.items() if k not in before}
    assert set(new) <= {("obs:owner_hist", "jit")}
    assert sum(new.values()) <= 1

    idx = ix.build(ix.RMISpec(b=64), table)
    idx.lookup(table, qs)  # prime
    before = dict(ix.trace_counts())
    out = timed_lookup(idx, table, qs, tier="obs_test")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(idx.lookup(table, qs)))
    after = dict(ix.trace_counts())
    new = {k: v for k, v in after.items() if after[k] != before.get(k, 0)}
    assert set(new) <= {("obs:hist", "update")}


def test_telemetry_off_paths_never_import_obs(rng):
    """With ``repro.obs`` evicted, telemetry-off ``Index.lookup`` and
    ``sharded_lookup`` complete without re-importing it — the hot path
    has zero obs surface unless telemetry is requested."""
    table = make_table(rng, "uniform", N)
    qs = make_queries(rng, table, 256)
    idx = ix.build(ix.RMISpec(b=64), table)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=4, b=64)

    saved = {k: sys.modules.pop(k) for k in list(sys.modules) if k.startswith("repro.obs")}
    saved_attr = repro.__dict__.pop("obs", None)
    try:
        idx.lookup(table, qs)
        si.sharded_lookup(sidx, qs, telemetry=False)
        leaked = [k for k in sys.modules if k.startswith("repro.obs")]
        assert not leaked, f"telemetry-off lookup imported {leaked}"
    finally:
        sys.modules.update(saved)
        if saved_attr is not None:
            repro.obs = saved_attr


# ---------------------------------------------------------------------------
# View parity: the old surfaces render from registry snapshots
# ---------------------------------------------------------------------------


def test_derived_tier_metrics_tolerates_empty_and_zero():
    m = si.derived_tier_metrics({})
    assert m["queries"] == 0
    assert m["drop_rate"] == 0.0
    assert m["imbalance_mean"] == 0.0
    m = si.derived_tier_metrics(
        {"queries": 100, "dropped": 1, "routed_max": 50, "routed_even": 25.0}
    )
    assert m["drop_rate"] == pytest.approx(0.01)
    assert m["imbalance_mean"] == pytest.approx(2.0)


def test_reset_tier_metrics_leaves_caller_sink_alone(rng):
    table = make_table(rng, "uniform", N)
    qs = make_queries(rng, table, 256)
    n_q = len(qs)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=4, b=64)
    sink = si._fresh_tier_metrics()
    si.sharded_lookup(sidx, qs, telemetry=True, telemetry_sink=sink)
    assert sink["queries"] == n_q
    si.reset_tier_metrics()
    # the registry aggregate resets; the caller-owned sink is untouched
    assert si.tier_metrics()["queries"] == 0
    assert sink["queries"] == n_q


def test_tier_metrics_aggregates_via_registry(rng):
    table = make_table(rng, "uniform", N)
    qs = make_queries(rng, table, 512)
    n_q = len(qs)
    sidx = si.ShardedIndex.build("RMI", table, n_shards=4, b=64)
    si.reset_tier_metrics()
    si.sharded_lookup(sidx, qs, telemetry=True)
    si.sharded_lookup(sidx, qs, telemetry=True)
    m = si.tier_metrics()
    assert m["lookups"] == 2
    assert m["queries"] == 2 * n_q
    assert m["imbalance_peak"] >= m["imbalance_last"] > 0
    # and the same numbers are visible in a raw snapshot
    snap = obs.snapshot(prefix="route_")
    assert obs.sample_value(snap, "route_queries", tier="all") == 2 * n_q


def test_tuned_tier_metrics_render_from_snapshot(rng):
    from repro.index import RMISpec
    from repro.tune.rebuild import RebuildPolicy, TunedTier

    table = make_table(rng, "uniform", N)
    qs = make_queries(rng, table, 256)
    tier = TunedTier(table, n_shards=2, policy=RebuildPolicy(), spec=RMISpec(b=64))
    tier.lookup(qs)
    m = tier.metrics()
    assert m["lookups"] == 1
    assert m["routing"]["queries"] == len(qs)
    # the per-tier labelset backs the proxy: poking it shows up in both
    tier.counters.pending += 7
    assert tier.counters.pending == 7
    assert obs.metric("tier_pending").value(tier=tier.name) == 7.0
    assert tier.metrics()["pending"] == 7


def test_engine_metrics_are_a_registry_snapshot():
    import jax

    from repro.configs import get as get_arch
    from repro.dist.sharding import single_device_ctx
    from repro.models import transformer
    from repro.serve.engine import DecodeEngine, Request

    spec = get_arch("qwen2-0.5b", reduced=True)
    cfg = spec.config
    params = transformer.init(jax.random.key(0), cfg)
    eng = DecodeEngine(params, cfg, single_device_ctx(), batch_slots=2, max_seq=64)
    prompt = np.arange(4, dtype=np.int32) % cfg.vocab
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    eng.run_until_drained(max_ticks=50)
    m = eng.metrics()
    assert m["requests_finished"] == 1
    assert m["tokens_decoded"] >= 2
    assert isinstance(m["index_trace_counts"], dict)
    snap = obs.snapshot(prefix="serve_")
    got = obs.sample_value(snap, "serve_requests_finished", engine=eng.name)
    assert got == m["requests_finished"]


def test_mutation_reports_feed_the_registry(rng):
    from repro.index import mutation

    table = make_table(rng, "uniform", N)
    idx = ix.build("GAPPED", table, leaf_cap=16, fill=0.5, delta_cap=64)
    before = obs.metric("mutation_requested").value(kind="GAPPED")
    keys = np.unique(make_queries(rng, table, 32))
    _idx2, report = mutation.insert_batch(idx, keys)
    assert report.requested == len(keys)
    after = obs.metric("mutation_requested").value(kind="GAPPED")
    assert after - before == len(keys)


# ---------------------------------------------------------------------------
# Harness smoke: SLO gates + CLI
# ---------------------------------------------------------------------------


def _slo_report(**over):
    metrics = {
        "slo/p50_us": 100.0,
        "slo/p99_us": 400.0,
        "slo/drop_rate": 0.0,
        "slo/exact": 1.0,
        "slo/cache_off/p50_us": 100.0,
        "slo/cache_off/p99_us": 400.0,
        "slo/cache/p50_us": 50.0,
        "slo/cache/p99_us": 200.0,
        "slo/cache/exact": 1.0,
        "slo/adv/drop_rate": 0.0,
        "slo/adv/retunes": 0.0,
        "slo/adv/hammer/exact": 1.0,
    }
    metrics.update(over)
    # drop a metric by passing <name>=None
    metrics = {k: v for k, v in metrics.items() if v is not None}
    return {"metrics": metrics, "slo": {"drop_rate_max": 0.01}}


def test_serve_slo_absolute_gates():
    sys.path.insert(0, str(ROOT))
    try:
        from benchmarks.serve_slo import check_slo
    finally:
        sys.path.pop(0)
    assert check_slo(_slo_report()) == []
    assert any("drop_rate" in f for f in check_slo(_slo_report(**{"slo/drop_rate": 0.5})))
    assert any("quantiles" in f for f in check_slo(_slo_report(**{"slo/p99_us": 1.0})))
    assert any("exact" in f for f in check_slo(_slo_report(**{"slo/exact": 0.0})))
    # PR 9 gates: adversarial drop rate, the retune-free invariant, the
    # cache leg's quantile sanity, and a leg dropped from the report
    assert any("drop_rate" in f for f in check_slo(_slo_report(**{"slo/adv/drop_rate": 0.5})))
    assert any("retunes" in f for f in check_slo(_slo_report(**{"slo/adv/retunes": 2.0})))
    assert any("quantiles" in f for f in check_slo(_slo_report(**{"slo/cache/p99_us": 1.0})))
    assert any("exact" in f for f in check_slo(_slo_report(**{"slo/adv/hammer/exact": 0.0})))
    assert any("missing" in f for f in check_slo(_slo_report(**{"slo/adv/retunes": None})))


def test_obs_cli_dump_and_diff(tmp_path):
    reg = fresh_registry()
    reg.metric("route_queries").inc(4, tier="a")
    reg.metric("span_us").observe(5.0, name="x")
    before = tmp_path / "before.jsonl"
    before.write_text(obs.to_jsonl(reg.snapshot()))
    reg.metric("route_queries").inc(6, tier="a")
    after = tmp_path / "after.jsonl"
    after.write_text(obs.to_jsonl(reg.snapshot()))

    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    dump = subprocess.run(
        [sys.executable, "-m", "repro.obs", "dump", str(after)],
        capture_output=True, text=True, env=env, cwd=ROOT,
    )
    assert dump.returncode == 0, dump.stderr
    assert "route_queries" in dump.stdout and "span_us" in dump.stdout
    d = subprocess.run(
        [sys.executable, "-m", "repro.obs", "diff", str(before), str(after)],
        capture_output=True, text=True, env=env, cwd=ROOT,
    )
    assert d.returncode == 0, d.stderr
    assert "route_queries" in d.stdout and "6" in d.stdout
