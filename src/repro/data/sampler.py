"""Graph substrate: synthetic power-law graphs in CSR + neighbor sampler.

The ``minibatch_lg`` shape cell requires a real fanout sampler
(GraphSAGE-style).  CSR navigation — "which row owns edge e?" and
cumulative-degree inverse lookup — is predecessor search over
``row_offsets`` (a sorted table whose CDF is the degree distribution);
a learned index serves it (DESIGN.md §3, integration point 3).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.core.rmi import build_rmi


@dataclass
class CSRGraph:
    row_offsets: np.ndarray  # (N+1,) int64
    col_idx: np.ndarray  # (E,) int32
    n_nodes: int
    n_edges: int
    feat_dim: int
    rmi: object = None  # learned index over row_offsets

    def row_of_edge(self, edge_ids) -> jnp.ndarray:
        """Owning row of each edge id — learned predecessor search."""
        table = jnp.asarray(self.row_offsets.astype(np.uint64))
        q = jnp.asarray(np.asarray(edge_ids).astype(np.uint64))
        return self.rmi.predecessor(table, q)

    def src_dst_arrays(self):
        """(src, dst) int32 edge list (host) for segment-sum message passing."""
        degrees = np.diff(self.row_offsets)
        src = np.repeat(np.arange(self.n_nodes, dtype=np.int32), degrees)
        return src, self.col_idx.astype(np.int32)


def synth_powerlaw_graph(
    n_nodes: int, avg_degree: int, feat_dim: int, seed: int = 0
) -> CSRGraph:
    """Preferential-attachment-flavoured random graph in CSR."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    # power-law target popularity
    pop = rng.pareto(1.5, n_nodes) + 1.0
    pop /= pop.sum()
    dst = rng.choice(n_nodes, size=n_edges, p=pop).astype(np.int32)
    src = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    row_offsets = np.searchsorted(src, np.arange(n_nodes + 1)).astype(np.int64)
    rmi = build_rmi(row_offsets.astype(np.uint64), b=max(2, n_nodes // 256))
    return CSRGraph(
        row_offsets=row_offsets,
        col_idx=dst,
        n_nodes=n_nodes,
        n_edges=n_edges,
        feat_dim=feat_dim,
        rmi=rmi,
    )


def sample_neighbors(
    graph: CSRGraph, seeds: np.ndarray, fanouts, seed: int = 0
):
    """GraphSAGE fanout sampling -> (nodes, hop_edges).

    Returns the union of sampled nodes (int32) and per-hop (src, dst)
    edge arrays (dst are parents).  Uniform-without-replacement when a
    node has more neighbors than the fanout, with-replacement pad
    otherwise (standard minibatch semantics).
    """
    rng = np.random.default_rng(seed)
    ro, ci = graph.row_offsets, graph.col_idx
    frontier = np.unique(seeds.astype(np.int64))
    all_nodes = [frontier]
    hop_edges = []
    for fanout in fanouts:
        deg = ro[frontier + 1] - ro[frontier]
        # sample `fanout` slots per frontier node (with replacement pad)
        offs = rng.integers(0, np.maximum(deg, 1)[:, None], size=(len(frontier), fanout))
        idx = ro[frontier][:, None] + offs
        nbrs = ci[np.minimum(idx, len(ci) - 1)]
        nbrs = np.where((deg > 0)[:, None], nbrs, frontier[:, None])  # isolated: self-loop
        src = nbrs.reshape(-1).astype(np.int32)
        dst = np.repeat(frontier, fanout).astype(np.int32)
        hop_edges.append((src, dst))
        frontier = np.unique(src.astype(np.int64))
        all_nodes.append(frontier)
    nodes = np.unique(np.concatenate(all_nodes)).astype(np.int32)
    return nodes, hop_edges
