"""Data substrate: synthetic SOSD datasets, memory-tier tables, the LM
packed-token pipeline, and the GNN neighbor sampler."""

from . import distributions, pipeline, sampler, tables
