"""LM token pipeline with a learned-index-accelerated packed corpus.

Documents of varying length are packed into one flat token stream; the
classic pipeline question "which document owns global token offset t?"
(needed for attention-boundary resets and provenance) is predecessor
search over the sorted doc-boundary table — served by a PGM index
(DESIGN.md §3, integration point 4).

The pipeline is deterministic, seedable, shard-aware (each data-parallel
host slices its own batch rows) and restartable from a step counter —
the properties a production loader needs for fault-tolerant training.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.core.pgm import build_pgm


@dataclass
class PackedCorpus:
    tokens: np.ndarray  # (T,) int32 flat packed stream
    doc_starts: np.ndarray  # (D,) int64 sorted boundary table
    vocab_size: int
    pgm: object  # PGM index over doc_starts

    def doc_of(self, offsets) -> jnp.ndarray:
        """Owning document of each global token offset (learned lookup)."""
        q = jnp.asarray(offsets, dtype=jnp.uint64)
        table = jnp.asarray(self.doc_starts.astype(np.uint64))
        return self.pgm.predecessor(table, q)


def synth_corpus(
    vocab_size: int = 32_000,
    n_docs: int = 2_000,
    mean_len: int = 512,
    seed: int = 0,
) -> PackedCorpus:
    """Synthetic Zipf-token corpus with lognormal doc lengths."""
    rng = np.random.default_rng(seed)
    lengths = np.maximum(8, rng.lognormal(np.log(mean_len), 0.8, n_docs).astype(np.int64))
    total = int(lengths.sum())
    # Zipf-ish unigram stream (fast approximate via pareto)
    ranks = (rng.pareto(1.1, total) * 10).astype(np.int64) % vocab_size
    tokens = ranks.astype(np.int32)
    doc_starts = np.concatenate([[0], np.cumsum(lengths)[:-1]]).astype(np.int64)
    pgm = build_pgm(doc_starts.astype(np.uint64), eps=16)
    return PackedCorpus(tokens=tokens, doc_starts=doc_starts, vocab_size=vocab_size, pgm=pgm)


class TokenBatcher:
    """Deterministic, restartable next-token-prediction batches.

    ``batch(step)`` is a pure function of (corpus, seed, step): restart
    after failure replays the exact same data order (checkpoint only
    needs the step counter).  ``shard``/``num_shards`` slice batch rows
    for data-parallel hosts.
    """

    def __init__(
        self,
        corpus: PackedCorpus,
        batch_size: int,
        seq_len: int,
        seed: int = 0,
        shard: int = 0,
        num_shards: int = 1,
    ):
        assert batch_size % num_shards == 0
        self.corpus = corpus
        self.batch = batch_size
        self.local_batch = batch_size // num_shards
        self.seq = seq_len
        self.seed = seed
        self.shard = shard
        self.num_shards = num_shards
        self._t = len(corpus.tokens)

    def batch_at(self, step: int):
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        starts = rng.integers(0, self._t - self.seq - 1, size=self.batch)
        starts = starts[self.shard * self.local_batch : (self.shard + 1) * self.local_batch]
        idx = starts[:, None] + np.arange(self.seq + 1)[None, :]
        window = self.corpus.tokens[idx]
        tokens = window[:, :-1].astype(np.int32)
        labels = window[:, 1:].astype(np.int32)
        return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
