"""Synthetic SOSD-style key datasets (paper §3.4; DESIGN.md §7).

The container is offline, so each of the paper's four real datasets is
replaced by a generator matched to its published CDF shape:

- ``amzn``  — book popularity: heavy-tailed lognormal counts (the SOSD
  amzn CDF is smooth but strongly convex).  32- and 64-bit variants.
- ``face``  — uniformly sampled user ids: near-uniform with sparse
  "rough spots" (id-block gaps), per the paper's observation that
  face-L4 looks uniform but is locally hard.
- ``osm``   — cell ids: strongly clustered (embedded locations hash to
  dense clusters separated by voids).
- ``wiki``  — edit timestamps: bursty inter-arrival times (piecewise
  exponential with burst episodes), many near-duplicates.
"""

from __future__ import annotations

import zlib

import numpy as np

from repro.core.cdf import as_table

DATASETS = ("amzn32", "amzn64", "face", "osm", "wiki")


def _gen_amzn(rng: np.random.Generator, n: int, bits: int) -> np.ndarray:
    # oversample: dedup of a heavy-tailed integer distribution loses keys
    raw = np.exp(rng.normal(24.0, 3.0, size=int(n * 1.35))).astype(np.uint64)
    scale = np.uint64(2 ** (bits - 1) // max(1, int(raw.max()) or 1))
    keys = raw * np.maximum(scale, np.uint64(1))
    return keys


def _gen_face(rng: np.random.Generator, n: int) -> np.ndarray:
    # near-uniform ids with id-block voids ("rough spots")
    keys = rng.integers(0, 2**63, size=int(n * 1.25), dtype=np.uint64)
    # carve voids: drop ids landing in ~10 random blocks covering ~15%
    for _ in range(10):
        lo = np.uint64(rng.integers(0, 2**63, dtype=np.uint64))
        width = np.uint64(2**63 // 64)
        keys = keys[~((keys >= lo) & (keys < lo + width))]
    return keys


def _gen_osm(rng: np.random.Generator, n: int) -> np.ndarray:
    n_clusters = max(8, n // 2000)
    centers = rng.integers(0, 2**62, size=n_clusters, dtype=np.uint64)
    assign = rng.integers(0, n_clusters, size=int(n * 1.25))
    spread = rng.exponential(2.0**34, size=int(n * 1.25)).astype(np.uint64)
    return centers[assign] + spread


def _gen_wiki(rng: np.random.Generator, n: int) -> np.ndarray:
    base_rate = rng.exponential(1000.0, size=int(n * 1.2))
    burst = (rng.random(int(n * 1.2)) < 0.02).astype(np.float64) * rng.exponential(
        80_000.0, size=int(n * 1.2)
    )
    gaps = (base_rate + burst).astype(np.uint64) + np.uint64(1)
    return np.cumsum(gaps).astype(np.uint64) + np.uint64(1_500_000_000_000)


def generate(name: str, n: int, seed: int = 0) -> np.ndarray:
    """Sorted deduplicated uint64 table of >= n keys, truncated to n.

    The per-dataset seed offset must be process-stable: ``hash(str)`` is
    salted per interpreter (PYTHONHASHSEED), which silently made every
    process generate *different* bench tables — fatal for baseline
    diffing (``benchmarks/trend.py``).  crc32 is deterministic forever.
    """
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (2**31))
    if name == "amzn32":
        keys = _gen_amzn(rng, n, bits=32)
    elif name == "amzn64":
        keys = _gen_amzn(rng, n, bits=64)
    elif name == "face":
        keys = _gen_face(rng, n)
    elif name == "osm":
        keys = _gen_osm(rng, n)
    elif name == "wiki":
        keys = _gen_wiki(rng, n)
    else:
        raise ValueError(f"unknown dataset {name!r}; choose from {DATASETS}")
    table = as_table(keys)
    if len(table) < n:  # top up (rare): re-generate with a new seed
        extra = generate(name, n, seed=seed + 977)
        table = as_table(np.concatenate([table, extra]))
    return table[:n]
