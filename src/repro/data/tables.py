"""Memory-tier tables + CDF-preserving subsampling (paper §3.4, supp §2).

The paper sizes tables to the i7's cache hierarchy (L1..L4).  Our target
is a TPU v5e, so tiers map to the TPU hierarchy (DESIGN.md §3):

  L1 — fits a VMEM tile alongside the model      (16K keys,   128 KiB)
  L2 — fits VMEM entirely                        (256K keys,    2 MiB)
  L3 — HBM-resident, cache-friendly              (2M keys,     16 MiB)
  L4 — HBM-resident, bandwidth-bound             (16M keys,   128 MiB)

Subsampling follows the paper's supplementary: draw uniform samples,
Kolmogorov–Smirnov-test each against the parent CDF, keep the candidate
with the smallest KL divergence (pure-numpy KS/KL, no scipy).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.cdf import as_table
from . import distributions

# tier name -> number of keys (overridable; tests shrink these)
TIERS = {
    "L1": 16_384,
    "L2": 262_144,
    "L3": 2_097_152,
    "L4": 16_777_216,
}


def ks_statistic(sample: np.ndarray, parent: np.ndarray) -> float:
    """Two-sample KS statistic, numpy-only (both arrays sorted u64)."""
    n, m = len(sample), len(parent)
    grid = np.concatenate([sample, parent])
    grid.sort(kind="mergesort")
    cdf_s = np.searchsorted(sample, grid, side="right") / n
    cdf_p = np.searchsorted(parent, grid, side="right") / m
    return float(np.max(np.abs(cdf_s - cdf_p)))


def kl_divergence(sample: np.ndarray, parent: np.ndarray, bins: int = 256) -> float:
    """KL(PDF_sample || PDF_parent) over a common histogram."""
    lo = min(sample[0], parent[0])
    hi = max(sample[-1], parent[-1])
    edges = np.linspace(np.float64(lo), np.float64(hi), bins + 1)
    ps, _ = np.histogram(sample.astype(np.float64), bins=edges)
    pp, _ = np.histogram(parent.astype(np.float64), bins=edges)
    ps = (ps + 1e-9) / (ps.sum() + bins * 1e-9)
    pp = (pp + 1e-9) / (pp.sum() + bins * 1e-9)
    return float(np.sum(ps * np.log(ps / pp)))


def subsample_preserving_cdf(
    parent: np.ndarray, n: int, seed: int = 0, tries: int = 8
) -> np.ndarray:
    """Paper supp §2: repeat {uniform sample -> KS test}; keep min-KL."""
    rng = np.random.default_rng(seed)
    ks_crit = 1.63 * np.sqrt((n + len(parent)) / (n * len(parent)))  # alpha=0.01
    best, best_kl = None, np.inf
    for _ in range(tries):
        cand = as_table(rng.choice(parent, size=int(n * 1.1), replace=False))[:n]
        if len(cand) < n:
            continue
        if ks_statistic(cand, parent) > ks_crit:
            continue  # KS says distributions differ -> reject
        kl = kl_divergence(cand, parent)
        if kl < best_kl:
            best, best_kl = cand, kl
    if best is None:  # fall back to a plain stratified subsample
        idx = np.linspace(0, len(parent) - 1, n).astype(np.int64)
        best = parent[idx]
    return best


@dataclass
class BenchTable:
    dataset: str
    tier: str
    table: np.ndarray

    @property
    def name(self) -> str:
        return f"{self.dataset}-{self.tier}"


def make_bench_tables(
    datasets=distributions.DATASETS,
    tiers=None,
    seed: int = 0,
    scale: float = 1.0,
):
    """All (dataset x tier) tables; generate at the largest tier and
    subsample the smaller tiers from it (CDF-preserving), as the paper
    derives its tiers from the full dataset."""
    tiers = tiers or TIERS
    out = []
    max_n = max(tiers.values())
    for ds in datasets:
        parent = distributions.generate(
            ds, int(max_n * scale) if scale != 1.0 else max_n, seed=seed
        )
        for tier, n in tiers.items():
            n_eff = max(16, int(n * scale))
            if n_eff >= len(parent):
                table = parent
            else:
                table = subsample_preserving_cdf(parent, n_eff, seed=seed)
            out.append(BenchTable(dataset=ds, tier=tier, table=table))
    return out


def make_queries(table: np.ndarray, n_queries: int, seed: int = 0) -> np.ndarray:
    """Paper §3.4: uniform with replacement from the table's elements."""
    rng = np.random.default_rng(seed + 7)
    return rng.choice(table, size=n_queries, replace=True)
