"""``python -m repro.obs`` — dump / diff JSONL metric snapshots.

::

    python -m repro.obs dump snapshot.jsonl        # pretty-print one export
    python -m repro.obs diff before.jsonl after.jsonl   # delta (after - before)

Snapshots come from ``repro.obs.to_jsonl(repro.obs.snapshot())`` — e.g.
the ``serve_slo_snapshot.jsonl`` artifact the bench-smoke CI job
uploads.  Histograms print count / sum plus p50/p90/p99 estimates.
"""

from __future__ import annotations

import argparse
import sys

from .registry import diff, from_jsonl, hist_quantile


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def _print_snapshot(snap: dict, *, skip_zero: bool = False) -> None:
    for name in sorted(snap):
        entry = snap[name]
        for s in entry.get("samples", []):
            label = f"{name}{_fmt_labels(s['labels'])}"
            if entry["type"] == "histogram":
                if skip_zero and s["count"] == 0:
                    continue
                sample = {**s, "edges": entry["edges"]}
                qs = " ".join(
                    f"p{int(q * 100)}={hist_quantile(sample, q):.3g}"
                    for q in (0.5, 0.9, 0.99)
                )
                print(f"{label} count={s['count']} sum={s['sum']:.6g} {qs}")
            else:
                if skip_zero and s["value"] == 0:
                    continue
                print(f"{label} = {s['value']:.6g}")


def _load(path: str) -> dict:
    with open(path) as f:
        return from_jsonl(f.read())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    d = sub.add_parser("dump", help="pretty-print one JSONL snapshot")
    d.add_argument("snapshot")
    dd = sub.add_parser("diff", help="print the delta between two snapshots")
    dd.add_argument("before")
    dd.add_argument("after")
    args = ap.parse_args(argv)

    if args.cmd == "dump":
        _print_snapshot(_load(args.snapshot))
    else:
        _print_snapshot(diff(_load(args.before), _load(args.after)), skip_zero=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
