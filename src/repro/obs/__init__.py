"""repro.obs — unified metrics / tracing / profiling (docs/observability.md).

One labeled registry (:mod:`repro.obs.registry`) backs every telemetry
surface in the project; :mod:`repro.obs.timing` adds spans, stopwatches
and the device-latency ``timed_lookup`` wrapper; ``python -m repro.obs``
dumps/diffs JSONL snapshot exports.

Import discipline: this package imports nothing from ``repro.*`` at
module scope (the jitted histogram update and the trace-count collector
bind lazily), so any layer may depend on it — and the telemetry-off
lookup paths never import it at call time.
"""

from __future__ import annotations

import sys

from .registry import (
    CATALOGUE,
    Counter,
    Gauge,
    Histogram,
    Registry,
    default_registry,
    diff,
    exp_edges,
    find_sample,
    from_jsonl,
    hist_quantile,
    metric,
    metric_catalogue,
    register_collector,
    reset,
    sample_value,
    snapshot,
    to_jsonl,
)
from .timing import Stopwatch, span, stopwatch, timed_lookup

__all__ = [
    "CATALOGUE",
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "Stopwatch",
    "default_registry",
    "diff",
    "exp_edges",
    "find_sample",
    "from_jsonl",
    "hist_quantile",
    "metric",
    "metric_catalogue",
    "register_collector",
    "reset",
    "sample_value",
    "snapshot",
    "span",
    "stopwatch",
    "timed_lookup",
    "to_jsonl",
]


def _collect_index_traces(reg: Registry) -> None:
    """Mirror ``repro.index.trace_counts()`` into ``index_traces`` gauges
    at snapshot time.  Polls ``sys.modules`` only — never forces the
    index machinery in just to report that it was never used."""
    ix = sys.modules.get("repro.index")
    if ix is None:
        return
    g = reg.metric("index_traces")
    g.clear()  # trace counts can reset (reset_trace_counts); gauges follow
    for (kind, backend), n in ix.trace_counts().items():
        g.set(float(n), kind=kind, backend=backend)


register_collector(_collect_index_traces)
