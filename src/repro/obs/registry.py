"""Labeled metrics registry: Counter / Gauge / Histogram + snapshots.

One process-wide :class:`Registry` (``default_registry()``) is the
single sink every telemetry surface renders from — the sharded tier's
routing counters, :class:`~repro.tune.rebuild.TunedTier` lifecycle
counters, mutation-report aggregation, the serving engine's counters,
and the lookup-latency histograms of :mod:`repro.obs.timing`.  The old
per-surface accessors (``dist.tier_metrics()``, ``TunedTier.metrics()``,
``DecodeEngine.metrics()``) are thin views over snapshots of this
registry, so their call signatures and return shapes are unchanged.

Device discipline
-----------------
Histograms accumulate through ONE jitted ``jnp.searchsorted`` +
``segment_sum`` update per :meth:`Histogram.observe_groups` call —
telemetry-on adds at most one extra dispatch to a serving step, the
same budget ``_record_tier_metrics`` already spends on its owner
histogram.  Scalar :meth:`Histogram.observe` (used by host-side spans)
is pure numpy: zero device dispatches.  Counter/Gauge updates are plain
host floats.

Nothing in this module imports ``repro.*`` at module scope: the core
index/serving code can depend on ``repro.obs`` without cycles, and the
telemetry-off lookup paths never pull this module in at call time.

Export schema (stable)
----------------------
``to_jsonl(snapshot)`` emits one JSON object per sample line::

    {"name": ..., "type": "counter"|"gauge", "labels": {...}, "value": f}
    {"name": ..., "type": "histogram", "labels": {...}, "count": n,
     "sum": f, "edges": [...], "counts": [...]}   # len(counts) == len(edges)+1

``from_jsonl`` reconstructs the snapshot dict; ``python -m repro.obs``
dumps/diffs these files.
"""

from __future__ import annotations

import json
import threading
from functools import partial

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "default_registry",
    "diff",
    "exp_edges",
    "from_jsonl",
    "hist_quantile",
    "metric",
    "metric_catalogue",
    "register_collector",
    "reset",
    "sample_value",
    "snapshot",
    "to_jsonl",
]

#: default exponential bucket edges for latency histograms, microseconds:
#: 1us .. 10s, ~1.33x per bucket (57 edges -> 58 buckets incl. overflow).
DEFAULT_LATENCY_EDGES = tuple(float(x) for x in np.geomspace(1.0, 1e7, 57))


def exp_edges(lo: float, hi: float, n: int) -> tuple:
    """``n`` exponentially spaced bucket edges covering ``[lo, hi]``."""
    if not (0 < lo < hi) or n < 2:
        raise ValueError(f"need 0 < lo < hi and n >= 2, got ({lo}, {hi}, {n})")
    return tuple(float(x) for x in np.geomspace(lo, hi, n))


# ---------------------------------------------------------------------------
# Metric catalogue: the declared project-wide metric names.  docs_check
# verifies docs/observability.md against this table; metric() creates
# registry entries from it so every surface agrees on labels and help.
# ---------------------------------------------------------------------------

#: (name, type, label names, description)
CATALOGUE: tuple = (
    ("index_traces", "gauge", ("kind", "backend"),
     "jitted lookup traces per (kind, backend) — mirror of repro.index.trace_counts()"),
    ("route_lookups", "counter", ("tier",),
     "telemetry-enabled sharded_lookup calls"),
    ("route_queries", "counter", ("tier",),
     "queries routed through the tier"),
    ("route_dropped", "counter", ("tier",),
     "queries dropped by the capacity-factored exchange"),
    ("route_max", "counter", ("tier",),
     "busiest shard's queries, summed over lookups"),
    ("route_even", "counter", ("tier",),
     "perfectly even per-shard load, summed over lookups"),
    ("route_imbalance_last", "gauge", ("tier",),
     "last lookup's max-shard load over the even load"),
    ("route_imbalance_peak", "gauge", ("tier",),
     "peak routing imbalance since reset"),
    ("tier_lookups", "counter", ("tier",),
     "TunedTier.lookup calls"),
    ("tier_ingested", "counter", ("tier",),
     "keys ingested via TunedTier.insert_batch"),
    ("tier_absorbed", "counter", ("tier",),
     "keys merged into gapped leaves in place"),
    ("tier_overflowed", "counter", ("tier",),
     "keys diverted to a shard's delta buffer"),
    ("tier_duplicates", "counter", ("tier",),
     "ingested keys already present"),
    ("tier_shard_compactions", "counter", ("tier",),
     "delta -> leaves folds (device-side)"),
    ("tier_shard_refreshes", "counter", ("tier",),
     "single-shard rebuild + donated hot swap"),
    ("tier_retunes", "counter", ("tier",),
     "full bi-criteria re-tune + restack"),
    ("tier_forced_restacks", "counter", ("tier",),
     "refresh_shard rejected (capacity/static) -> full restack"),
    ("tier_pending", "gauge", ("tier",),
     "host-buffered keys (static-kind fallback arm)"),
    ("route_shard_queries", "counter", ("tier", "shard"),
     "queries routed to each owner shard (labeled tiers only — feeds rebalancing)"),
    ("rebalance_total", "counter", ("tier",),
     "fence rebalances triggered by sustained query-skew drift"),
    ("rebalance_moved_keys", "counter", ("tier",),
     "keys whose owner shard changed across rebalances"),
    ("rebalance_last_imbalance", "gauge", ("tier",),
     "windowed routing imbalance that triggered the last rebalance"),
    ("hotcache_hits", "counter", ("tier",),
     "queries answered by the hot-key cache in one gather"),
    ("hotcache_misses", "counter", ("tier",),
     "queries that fell through the hot-key cache to the tier"),
    ("hotcache_stale", "counter", ("tier",),
     "lookups that found the cache epoch behind the tier (invalidated)"),
    ("hotcache_rebuilds", "counter", ("tier",),
     "hot-key cache rebuilds from the decayed frequency sketch"),
    ("hotcache_entries", "gauge", ("tier",),
     "resident hot keys in the cache"),
    ("hotcache_space_bytes", "gauge", ("tier",),
     "hot-key cache residency: device arrays + host sketch bytes"),
    ("mutation_requested", "counter", ("kind",),
     "keys requested via repro.index.mutation.insert_batch"),
    ("mutation_absorbed", "counter", ("kind",),
     "keys absorbed into gapped leaves"),
    ("mutation_overflowed", "counter", ("kind",),
     "keys diverted to the delta buffer"),
    ("mutation_duplicates", "counter", ("kind",),
     "keys rejected as duplicates"),
    ("mutation_compactions", "counter", ("kind",),
     "compact() calls (explicit + auto)"),
    ("fit_fast_fallbacks", "counter", ("kind",),
     "fit='fast' verified-eps failures that fell back to the exact scan fit"),
    ("device_refreshes", "counter", ("kind", "outcome"),
     "single-program device shard refreshes (outcome=ok | fallback)"),
    ("serve_ticks", "counter", ("engine",),
     "DecodeEngine continuous-batching ticks"),
    ("serve_tokens_decoded", "counter", ("engine",),
     "tokens decoded across all slots"),
    ("serve_requests_finished", "counter", ("engine",),
     "requests retired from the batch"),
    ("serve_queued", "gauge", ("engine",),
     "requests waiting for a batch slot"),
    ("serve_live_slots", "gauge", ("engine",),
     "occupied batch slots"),
    ("lookup_latency_us", "histogram", ("kind", "backend", "tier", "phase"),
     "timed_lookup latency: phase=host (dispatch returned) / device (block_until_ready)"),
    ("span_us", "histogram", ("name",),
     "host wall-time of span(name) blocks"),
)


def metric_catalogue() -> tuple:
    """The declared metric table: (name, type, label names, description).
    ``tools/docs_check.py`` asserts docs/observability.md matches this."""
    return CATALOGUE


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


class _Metric:
    """Base: samples keyed by label-value tuples in declared order."""

    kind = "abstract"

    def __init__(self, name: str, label_names=(), help: str = ""):
        self.name = name
        self.label_names = tuple(label_names)
        self.help = help
        self._samples: dict = {}
        self._lock = threading.Lock()

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared {sorted(self.label_names)}"
            )
        return tuple(str(labels[k]) for k in self.label_names)

    def clear(self) -> None:
        with self._lock:
            self._samples.clear()

    def labelsets(self) -> list:
        return [dict(zip(self.label_names, k)) for k in sorted(self._samples)]


class Counter(_Metric):
    """Monotone by convention; ``set_value`` exists so proxy views
    (``TunedTier.counters``) can implement ``+=``/``-=`` semantics."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        k = self._key(labels)
        with self._lock:
            self._samples[k] = self._samples.get(k, 0.0) + float(amount)

    def set_value(self, value: float, **labels) -> None:
        with self._lock:
            self._samples[self._key(labels)] = float(value)

    def value(self, **labels) -> float:
        return float(self._samples.get(self._key(labels), 0.0))


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._samples[self._key(labels)] = float(value)

    # alias so Counter/Gauge share the proxy-write surface
    set_value = set

    def max(self, value: float, **labels) -> None:
        k = self._key(labels)
        with self._lock:
            self._samples[k] = max(self._samples.get(k, float("-inf")), float(value))

    def value(self, **labels) -> float:
        return float(self._samples.get(self._key(labels), 0.0))


def _hist_update_fn():
    """The jitted device-side histogram update, built lazily so importing
    repro.obs never forces jax/repro.index in (and telemetry-off code
    pays nothing)."""
    import jax
    import jax.numpy as jnp

    from repro.index import count_trace

    @partial(jax.jit, static_argnames=("n_segs",))
    def _hist_update(edges, values, segs, n_segs: int):
        count_trace("obs:hist", "update")
        nb = edges.shape[0] + 1
        b = jnp.searchsorted(edges, values, side="right").astype(jnp.int32)
        ids = segs * nb + b
        counts = jax.ops.segment_sum(
            jnp.ones_like(ids), ids, num_segments=n_segs * nb
        ).reshape(n_segs, nb)
        sums = jax.ops.segment_sum(values, segs, num_segments=n_segs)
        return counts, sums

    return _hist_update


_HIST_UPDATE = None


class Histogram(_Metric):
    """Exponential-bucket histogram: per-labelset bucket counts + sum.

    ``observe()`` is host-side numpy (spans — zero dispatch).
    ``observe_groups()`` batches any number of (labels, values) groups
    through ONE jitted ``searchsorted`` + ``segment_sum`` dispatch.
    """

    kind = "histogram"

    def __init__(self, name, label_names=(), help="", edges=None):
        super().__init__(name, label_names, help)
        self.edges = np.asarray(
            DEFAULT_LATENCY_EDGES if edges is None else edges, dtype=np.float64
        )
        if self.edges.ndim != 1 or len(self.edges) < 2 or (np.diff(self.edges) <= 0).any():
            raise ValueError(f"{name}: edges must be a strictly increasing 1-D array")
        self._edges_dev = None

    def _row(self, key: tuple) -> dict:
        row = self._samples.get(key)
        if row is None:
            row = self._samples[key] = {
                "counts": np.zeros(len(self.edges) + 1, dtype=np.int64),
                "sum": 0.0,
            }
        return row

    def observe(self, value: float, **labels) -> None:
        """Host-side scalar observation: numpy only, no device dispatch."""
        key = self._key(labels)
        i = int(np.searchsorted(self.edges, value, side="right"))
        with self._lock:
            row = self._row(key)
            row["counts"][i] += 1
            row["sum"] += float(value)

    def observe_batch(self, values, **labels) -> None:
        self.observe_groups([(labels, values)])

    def observe_groups(self, groups) -> None:
        """Accumulate several (labels, values) groups with ONE jitted
        dispatch (the device-friendly path ``timed_lookup`` uses)."""
        global _HIST_UPDATE
        import jax.numpy as jnp

        if _HIST_UPDATE is None:
            _HIST_UPDATE = _hist_update_fn()
        groups = list(groups)
        if not groups:
            return
        if self._edges_dev is None:
            self._edges_dev = jnp.asarray(self.edges, dtype=jnp.float32)
        vals, segs = [], []
        for i, (_, values) in enumerate(groups):
            v = np.asarray(values, dtype=np.float32).reshape(-1)
            vals.append(v)
            segs.append(np.full(v.shape, i, dtype=np.int32))
        counts, sums = _HIST_UPDATE(
            self._edges_dev,
            jnp.asarray(np.concatenate(vals)),
            jnp.asarray(np.concatenate(segs)),
            len(groups),
        )
        counts = np.asarray(counts, dtype=np.int64)
        sums = np.asarray(sums, dtype=np.float64)
        with self._lock:
            for i, (labels, _) in enumerate(groups):
                row = self._row(self._key(labels))
                row["counts"] += counts[i]
                row["sum"] += float(sums[i])


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


class Registry:
    def __init__(self):
        self._metrics: dict = {}
        self._collectors: list = []
        self._lock = threading.Lock()

    # -- declaration -------------------------------------------------------
    def _get_or_make(self, cls, name, label_names, help, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if m.kind != cls.kind or m.label_names != tuple(label_names):
                    raise ValueError(
                        f"metric {name!r} already declared as {m.kind}{m.label_names}"
                    )
                return m
            m = self._metrics[name] = cls(name, label_names, help, **kw)
            return m

    def counter(self, name, labels=(), help: str = "") -> Counter:
        return self._get_or_make(Counter, name, labels, help)

    def gauge(self, name, labels=(), help: str = "") -> Gauge:
        return self._get_or_make(Gauge, name, labels, help)

    def histogram(self, name, labels=(), help: str = "", edges=None) -> Histogram:
        return self._get_or_make(Histogram, name, labels, help, edges=edges)

    def metric(self, name: str):
        """Get-or-create a metric declared in :data:`CATALOGUE`."""
        m = self._metrics.get(name)
        if m is not None:
            return m
        for cname, kind, labels, help in CATALOGUE:
            if cname == name:
                ctor = {"counter": self.counter, "gauge": self.gauge,
                        "histogram": self.histogram}[kind]
                return ctor(name, labels=labels, help=help)
        raise KeyError(
            f"metric {name!r} is not in the repro.obs catalogue; declare custom "
            "metrics explicitly via counter()/gauge()/histogram()"
        )

    def register_collector(self, fn) -> None:
        """``fn(registry)`` runs at every snapshot (pull-style gauges)."""
        if fn not in self._collectors:
            self._collectors.append(fn)

    # -- render ------------------------------------------------------------
    def snapshot(self, prefix: str | None = None) -> dict:
        """Point-in-time render: ``{name: {type, labels, help[, edges],
        samples: [...]}}``.  Runs registered collectors first."""
        for fn in list(self._collectors):
            fn(self)
        out: dict = {}
        for name in sorted(self._metrics):
            if prefix is not None and not name.startswith(prefix):
                continue
            m = self._metrics[name]
            entry: dict = {"type": m.kind, "labels": list(m.label_names), "help": m.help}
            if m.kind == "histogram":
                entry["edges"] = [float(e) for e in m.edges]
            samples = []
            with m._lock:
                for key in sorted(m._samples):
                    s: dict = {"labels": dict(zip(m.label_names, key))}
                    if m.kind == "histogram":
                        row = m._samples[key]
                        s["count"] = int(row["counts"].sum())
                        s["sum"] = float(row["sum"])
                        s["counts"] = [int(c) for c in row["counts"]]
                    else:
                        s["value"] = float(m._samples[key])
                    samples.append(s)
            entry["samples"] = samples
            out[name] = entry
        return out

    def reset(self, prefix: str | None = None) -> None:
        """Clear samples (metric declarations survive)."""
        with self._lock:
            for name, m in self._metrics.items():
                if prefix is None or name.startswith(prefix):
                    m.clear()


_DEFAULT = Registry()


def default_registry() -> Registry:
    return _DEFAULT


def metric(name: str):
    """Catalogue-backed metric on the default registry."""
    return _DEFAULT.metric(name)


def snapshot(prefix: str | None = None) -> dict:
    return _DEFAULT.snapshot(prefix)


def reset(prefix: str | None = None) -> None:
    _DEFAULT.reset(prefix)


def register_collector(fn) -> None:
    _DEFAULT.register_collector(fn)


# ---------------------------------------------------------------------------
# Snapshot utilities
# ---------------------------------------------------------------------------


def sample_value(snap: dict, name: str, /, default: float = 0.0, **labels) -> float:
    """Counter/gauge value for a labelset in a snapshot (0.0 if absent)."""
    want = {k: str(v) for k, v in labels.items()}
    for s in snap.get(name, {}).get("samples", []):
        if s["labels"] == want:
            return float(s["value"])
    return default


def find_sample(snap: dict, name: str, /, **labels) -> dict | None:
    """Full sample dict (histograms included) for a labelset, or None."""
    want = {k: str(v) for k, v in labels.items()}
    entry = snap.get(name, {})
    for s in entry.get("samples", []):
        if s["labels"] == want:
            out = dict(s)
            if "edges" in entry:
                out["edges"] = entry["edges"]
            return out
    return None


def hist_quantile(sample: dict, q: float) -> float:
    """Quantile estimate from a histogram sample (``counts`` + ``edges``):
    linear interpolation inside the winning bucket, edge-saturated at the
    extremes.  Returns 0.0 for an empty histogram."""
    counts = np.asarray(sample["counts"], dtype=np.float64)
    edges = np.asarray(sample["edges"], dtype=np.float64)
    total = counts.sum()
    if total <= 0:
        return 0.0
    target = q * total
    cum = np.cumsum(counts)
    i = int(np.searchsorted(cum, target, side="left"))
    i = min(i, len(counts) - 1)
    lo = 0.0 if i == 0 else edges[i - 1]
    hi = edges[min(i, len(edges) - 1)]
    if i >= len(edges):  # overflow bucket: saturate at the top edge
        return float(edges[-1])
    prev = cum[i - 1] if i > 0 else 0.0
    frac = (target - prev) / counts[i] if counts[i] > 0 else 0.0
    return float(lo + frac * (hi - lo))


def diff(a: dict, b: dict) -> dict:
    """Snapshot delta ``b - a``: counters and histogram counts/sums
    subtract; gauges take ``b``'s value.  Samples only in ``b`` count
    from zero; samples only in ``a`` are dropped."""
    out: dict = {}
    for name, eb in b.items():
        ea = a.get(name, {})
        asamp = {tuple(sorted(s["labels"].items())): s for s in ea.get("samples", [])}
        entry = {k: v for k, v in eb.items() if k != "samples"}
        samples = []
        for s in eb.get("samples", []):
            key = tuple(sorted(s["labels"].items()))
            prev = asamp.get(key)
            d = {"labels": dict(s["labels"])}
            if eb["type"] == "histogram":
                pc = np.asarray(prev["counts"]) if prev else 0
                d["counts"] = [int(c) for c in (np.asarray(s["counts"]) - pc)]
                d["count"] = int(sum(d["counts"]))
                d["sum"] = float(s["sum"] - (prev["sum"] if prev else 0.0))
            elif eb["type"] == "counter":
                d["value"] = float(s["value"] - (prev["value"] if prev else 0.0))
            else:  # gauge: last-write-wins
                d["value"] = float(s["value"])
            samples.append(d)
        entry["samples"] = samples
        out[name] = entry
    return out


# ---------------------------------------------------------------------------
# JSONL export
# ---------------------------------------------------------------------------


def to_jsonl(snap: dict) -> str:
    """One JSON object per sample line (schema in the module docstring)."""
    lines = []
    for name, entry in snap.items():
        for s in entry.get("samples", []):
            rec: dict = {"name": name, "type": entry["type"], "labels": s["labels"]}
            if entry["type"] == "histogram":
                rec.update(
                    count=s["count"], sum=s["sum"],
                    edges=entry["edges"], counts=s["counts"],
                )
            else:
                rec["value"] = s["value"]
            lines.append(json.dumps(rec, sort_keys=True))
    return "\n".join(lines) + ("\n" if lines else "")


def from_jsonl(text: str) -> dict:
    """Inverse of :func:`to_jsonl` (help strings are not round-tripped)."""
    snap: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        entry = snap.setdefault(
            rec["name"],
            {"type": rec["type"], "labels": sorted(rec["labels"]), "help": "", "samples": []},
        )
        s: dict = {"labels": rec["labels"]}
        if rec["type"] == "histogram":
            entry.setdefault("edges", rec["edges"])
            s.update(count=rec["count"], sum=rec["sum"], counts=rec["counts"])
        else:
            s["value"] = rec["value"]
        entry["samples"].append(s)
    return snap
