"""Spans, stopwatches, and the latency-histogram lookup wrapper.

* :func:`span` — ``with span("name"):`` records host wall-time into the
  ``span_us`` histogram (host-side observe: zero device dispatches).
  When ``REPRO_PROFILE=<dir>`` is set, the *outermost* span additionally
  brackets its body with ``jax.profiler.start_trace``/``stop_trace`` so
  Pallas kernels and XLA ops land in a TensorBoard-readable trace.
* :func:`stopwatch` — the sanctioned way to take a wall-clock delta in
  ``src/repro/`` (analyzer rule R8 flags raw ``time.perf_counter()``
  subtraction outside ``repro.obs``): ``sw = stopwatch(); ...;
  sw.elapsed`` seconds.
* :func:`timed_lookup` — wraps any ``.lookup(...)`` target (``Index``,
  ``ShardedIndex`` via ``sharded_lookup`` partial, ``TunedTier``) and
  records BOTH the host dispatch time and the device completion time
  (``jax.block_until_ready``) into the ``lookup_latency_us`` histogram,
  labeled (kind, backend, tier, phase) — through ONE jitted histogram
  update, so telemetry-on costs at most one extra dispatch per call.
"""

from __future__ import annotations

import os
import time
from contextlib import contextmanager

from . import registry as _registry

__all__ = ["Stopwatch", "span", "stopwatch", "timed_lookup"]


class Stopwatch:
    """Monotonic wall-clock delta without raw ``perf_counter`` math."""

    __slots__ = ("_t0",)

    def __init__(self):
        self._t0 = time.perf_counter()

    def restart(self) -> None:
        self._t0 = time.perf_counter()

    @property
    def elapsed(self) -> float:
        """Seconds since construction / the last :meth:`restart`."""
        return time.perf_counter() - self._t0

    def __enter__(self) -> "Stopwatch":
        self.restart()
        return self

    def __exit__(self, *exc) -> None:
        pass


def stopwatch() -> Stopwatch:
    return Stopwatch()


_SPAN_DEPTH = 0  # outermost-span detection for the profiler bracket


@contextmanager
def span(name: str, *, registry: "_registry.Registry | None" = None):
    """Record the block's host wall-time into ``span_us{name=...}``.

    Nested spans each record their own time; only the outermost span
    starts/stops the optional ``jax.profiler`` trace
    (``REPRO_PROFILE=<dir>``), so a profiled serving step yields one
    coherent trace file rather than one per nested span.
    """
    global _SPAN_DEPTH
    reg = registry or _registry.default_registry()
    prof_dir = os.environ.get("REPRO_PROFILE")
    profiling = bool(prof_dir) and _SPAN_DEPTH == 0
    if profiling:
        import jax

        jax.profiler.start_trace(prof_dir)
    _SPAN_DEPTH += 1
    sw = Stopwatch()
    try:
        yield sw
    finally:
        elapsed_us = sw.elapsed * 1e6
        _SPAN_DEPTH -= 1
        if profiling:
            import jax

            jax.profiler.stop_trace()
        reg.metric("span_us").observe(elapsed_us, name=name)


def _target_kind(target) -> str:
    kind = getattr(target, "kind", None)
    if kind is None:
        kind = getattr(getattr(target, "spec", None), "kind", "?")
    return str(kind)


def _target_backend(target, kw: dict) -> str:
    be = kw.get("backend")
    if be is None:
        be = getattr(getattr(target, "policy", None), "backend", None)
    return str(be or "xla")


def timed_lookup(target, *args, tier: str = "-", registry=None, **kw):
    """``target.lookup(*args, **kw)`` + latency histograms.

    Records two phases into ``lookup_latency_us``:

    * ``phase=host`` — wall time until the (async) dispatch returns;
    * ``phase=device`` — wall time until ``jax.block_until_ready``,
      i.e. the latency a synchronous caller actually observes.

    Both land through one :meth:`Histogram.observe_groups` call — ONE
    extra jitted dispatch per lookup, zero extra *lookup* traces (the
    histogram update has its own ``obs:hist/update`` trace entry).
    """
    import jax

    labels = dict(
        kind=_target_kind(target), backend=_target_backend(target, kw), tier=str(tier)
    )
    sw = Stopwatch()
    out = target.lookup(*args, **kw)
    host_us = sw.elapsed * 1e6
    jax.block_until_ready(out)
    device_us = sw.elapsed * 1e6
    reg = registry or _registry.default_registry()
    reg.metric("lookup_latency_us").observe_groups(
        [
            ({**labels, "phase": "host"}, [host_us]),
            ({**labels, "phase": "device"}, [device_us]),
        ]
    )
    return out
