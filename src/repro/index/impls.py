"""Per-kind build + query implementations behind the :class:`Index` API.

Each kind contributes:

* a **build** function (registered via :mod:`repro.index.registry`) that
  runs the existing fitting code in :mod:`repro.core` and flattens the
  resulting model into the Index's array leaves + static aux;
* a **query impl** (:data:`QUERY_IMPLS`) with ``intervals`` /
  ``epi_steps`` / ``space_bytes`` / ``pallas`` / ``pallas_batched``
  operating purely on the array leaves — the data-driven form of the
  old per-class methods.  ``pallas`` is the kind's fused kernel where
  one exists (RMI family, PGM family, RS) and the lane-wide k-ary
  kernel otherwise; ``pallas_batched`` is its ``(table, q_tile)``-grid
  batched counterpart used by tiers and batches.

Two deliberate normalisations make jit caches collide across instances:

* variable-length leaves (PGM levels, RS knots) are padded to the next
  power of two with inert sentinels (max-key / repeated last entry), so
  same-kind indexes over different tables share leaf shapes far more
  often;
* every bounded-search trip count is rounded up to a multiple of 4
  (:func:`_bucket_steps`) — extra iterations of the Khuong–Morin loop
  are no-ops once the window reaches width 1, so this trades a few idle
  gathers for one shared trace per kind.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import search
from repro.core.atomic import build_atomic, poly_eval_jnp
from repro.core.btree import build_btree
from repro.core.cdf import POS_DTYPE, ceil_log2
from repro.core.kbfs import build_ko
from repro.core.pgm import build_pgm, build_pgm_bicriteria
from repro.core.radix_spline import build_rs
from repro.core.rmi import build_rmi
from repro.core.sy_rmi import build_sy_rmi

from .index import Index
from .registry import register
from .specs import (
    AtomicSpec,
    BTreeSpec,
    KOSpec,
    PGMBicriteriaSpec,
    PGMSpec,
    RMISpec,
    RSSpec,
    SYRMISpec,
)

_MAXKEY = np.uint64(np.iinfo(np.uint64).max)


def _bucket_steps(window: int) -> int:
    """ceil_log2 rounded up to a multiple of 4 (jit-cache bucketing)."""
    s = ceil_log2(max(int(window), 2))
    return max(4, 4 * math.ceil(s / 4))


def _pow2ceil(x: int) -> int:
    x = max(int(x), 1)
    return 1 << (x - 1).bit_length()


def _pad_pow2(arr: np.ndarray, fill) -> np.ndarray:
    arr = np.asarray(arr)
    m = _pow2ceil(arr.shape[0])
    if m == arr.shape[0]:
        return arr
    pad = np.full(m - arr.shape[0], fill, dtype=arr.dtype)
    return np.concatenate([arr, pad])


def _scalar(x, dtype):
    return jnp.asarray(np.asarray(x), dtype=dtype).reshape(())


# ---------------------------------------------------------------------------
# Query impls
# ---------------------------------------------------------------------------


@dataclass
class QueryImpl:
    intervals: Callable  # (index, table, q) -> (lo, hi)
    space_bytes: Callable  # (index) -> int
    pallas: Callable = None  # (index, table, q) -> ranks
    pallas_batched: Callable = None  # (stacked index, tables, queries) -> ranks
    epi_key: str = "epi"
    #: full lookup override — ``(index, table, q, backend) -> ranks``.
    #: Kinds whose answer is not "interval + bounded search over ``table``"
    #: (GAPPED: self-contained two-tier merge) set this; ``lookup_impl``
    #: dispatches to it before any generic backend handling.
    lookup: Callable = None
    #: the backends this kind honestly supports (R4 probes the claim and
    #: docs/backends.md documents it; ``Index.lookup`` enforces it)
    backends: tuple = ("xla", "bbs", "pallas", "ref")

    def __post_init__(self):
        # kinds without a fused batched kernel answer tiers/batches with
        # the model-free batched k-ary kernel (exact, shared trace)
        if self.pallas_batched is None and "pallas" in self.backends:
            self.pallas_batched = _kary_pallas_batched

    def epi_steps(self, index: Index) -> int:
        return index.s(self.epi_key)


def _pad_queries(arrs, tile: int, axis: int = 0):
    """Zero-pad query-shaped arrays to a tile multiple along ``axis``."""
    nq = arrs[0].shape[axis]
    pad = (-nq) % tile
    if pad == 0:
        return arrs
    widths = [(0, 0)] * arrs[0].ndim
    widths[axis] = (0, pad)
    return [jnp.pad(a, widths) for a in arrs]


def _kary_pallas_fallback(index: Index, table, q):
    """Model-free lane-wide k-ary kernel: the TPU-native K-BFS baseline
    for kinds without a fused kernel (returns exact predecessor ranks)."""
    from repro.kernels.kary_search import kary_search_pallas, LANES
    from repro.kernels.ops import split_u64

    thi, tlo = split_u64(table)
    qhi, qlo = split_u64(q)
    nq = q.shape[0]
    tile = min(512, _pow2ceil(nq))
    qhi, qlo = _pad_queries([qhi, qlo], tile)
    interpret = jax.default_backend() != "tpu"
    out = kary_search_pallas(qhi, qlo, thi, tlo, k=LANES, tile_q=tile, interpret=interpret)
    return out[:nq].astype(POS_DTYPE)


def _kary_pallas_batched(index: Index, tables, queries):
    """Batched k-ary kernel over ``(n_tables, m)`` tables: the Pallas
    tier/batch baseline for kinds without a fused batched kernel."""
    from repro.kernels.kary_search import batched_kary_search_pallas, LANES
    from repro.kernels.ops import split_u64

    thi, tlo = split_u64(tables)
    qhi, qlo = split_u64(queries)
    nq = queries.shape[1]
    tile = min(512, _pow2ceil(nq))
    qhi, qlo = _pad_queries([qhi, qlo], tile, axis=1)
    interpret = jax.default_backend() != "tpu"
    out = batched_kary_search_pallas(qhi, qlo, thi, tlo, k=LANES, tile_q=tile, interpret=interpret)
    return out[:, :nq].astype(POS_DTYPE)


# -- atomic (L / Q / C) ------------------------------------------------------


def _atomic_intervals(idx: Index, table, q):
    a = idx.arrays
    n = table.shape[0]
    eps = a["eps"]
    u = jnp.clip((q.astype(jnp.float64) - a["kmin"]) * a["inv_span"], 0.0, 1.0)
    p = jnp.clip(poly_eval_jnp(a["coef"], u), -4.0e15, 4.0e15)
    lo = jnp.floor(p).astype(POS_DTYPE) - eps
    hi = jnp.ceil(p).astype(POS_DTYPE) + eps
    return jnp.clip(lo, 0, n - 1), jnp.clip(hi, 0, n - 1)


def _atomic_space(idx: Index) -> int:
    # coef valid prefix (degree+1 of the padded 4) + kmin/inv_span + eps
    a = idx.arrays
    return 8 * (idx.s("degree") + 1) + a["kmin"].nbytes + a["inv_span"].nbytes + a["eps"].nbytes


ATOMIC_IMPL = QueryImpl(
    intervals=_atomic_intervals, space_bytes=_atomic_space, pallas=_kary_pallas_fallback
)


def _build_atomic_index(spec: AtomicSpec, table_np: np.ndarray) -> Index:
    m = build_atomic(table_np, degree=spec.degree)
    arrays = {
        "coef": jnp.asarray(m.coef, jnp.float64),
        "kmin": _scalar(m.kmin, jnp.float64),
        "inv_span": _scalar(m.inv_span, jnp.float64),
        "eps": _scalar(m.eps, jnp.int64),
    }
    static = (("degree", spec.degree), ("epi", _bucket_steps(min(2 * m.eps + 3, m.n))))
    info = {"name": m.name, "build_time": m.build_time, "eps": m.eps, "n": m.n}
    return Index(spec.kind, static, arrays, info)


# -- KO ----------------------------------------------------------------------


def _ko_intervals(idx: Index, table, q):
    a = idx.arrays
    fences = a["fences"]
    s = jnp.sum((q[..., None] >= fences[None, :]).astype(POS_DTYPE), axis=-1)
    coef = jnp.take(a["coef"], s, axis=0)
    kmin = jnp.take(a["kmin_seg"], s)
    inv_span = jnp.take(a["inv_span_seg"], s)
    eps = jnp.take(a["eps"], s)
    u = jnp.clip((q.astype(jnp.float64) - kmin) * inv_span, 0.0, 1.0)
    p = jnp.clip(poly_eval_jnp(coef, u), -4.0e15, 4.0e15)
    lo = jnp.floor(p).astype(POS_DTYPE) - eps
    hi = jnp.ceil(p).astype(POS_DTYPE) + eps
    b_lo = jnp.maximum(jnp.take(a["seg_start"], s) - 1, 0)
    b_hi = jnp.take(a["seg_start"], s + 1) - 1
    return jnp.clip(lo, b_lo, b_hi), jnp.clip(hi, b_lo, b_hi)


def _ko_space(idx: Index) -> int:
    a = idx.arrays
    return sum(
        a[k].nbytes
        for k in ("fences", "coef", "kmin_seg", "inv_span_seg", "eps", "seg_start")
    )


KO_IMPL = QueryImpl(intervals=_ko_intervals, space_bytes=_ko_space, pallas=_kary_pallas_fallback)


def _build_ko_index(spec: KOSpec, table_np: np.ndarray) -> Index:
    m = build_ko(table_np, k=spec.k)
    arrays = {
        "fences": jnp.asarray(m.fences),
        "coef": jnp.asarray(m.coef),
        "kmin_seg": jnp.asarray(m.kmin_seg),
        "inv_span_seg": jnp.asarray(m.inv_span_seg),
        "eps": jnp.asarray(m.eps),
        "seg_start": jnp.asarray(m.seg_start),
    }
    static = (("epi", _bucket_steps(m.max_window)),)
    info = {
        "name": m.name,
        "build_time": m.build_time,
        "k": m.k,
        "max_eps": m.max_eps,
        "n": m.n,
    }
    return Index(spec.kind, static, arrays, info)


# -- RMI / SY-RMI ------------------------------------------------------------


def _rmi_intervals(idx: Index, table, q):
    a = idx.arrays
    n = table.shape[0]
    b = a["leaf_slope"].shape[0]
    u = jnp.clip((q.astype(jnp.float64) - a["kmin"]) * a["inv_span"], 0.0, 1.0)
    p_root = jnp.clip(poly_eval_jnp(a["root_coef"], u), -4.0e15, 4.0e15)
    leaf = jnp.clip(jnp.floor(p_root * (b / n)).astype(POS_DTYPE), 0, b - 1)
    slope = jnp.take(a["leaf_slope"], leaf)
    icept = jnp.take(a["leaf_icept"], leaf)
    eps = jnp.take(a["leaf_eps"], leaf)
    p = jnp.clip(slope * u + icept, -4.0e15, 4.0e15)
    lo = jnp.floor(p).astype(POS_DTYPE) - eps
    hi = jnp.ceil(p).astype(POS_DTYPE) + eps
    # high fence is r_{l+1}, not r_{l+1} - 1: tolerates a 1-ulp root-eval
    # divergence between build (NumPy) and query (XLA) flipping floor()
    # at a leaf boundary — the extended eps covers the boundary key.
    b_lo = jnp.maximum(jnp.take(a["leaf_r"], leaf) - 1, 0)
    b_hi = jnp.minimum(jnp.take(a["leaf_r"], leaf + 1), n - 1)
    return jnp.clip(lo, b_lo, b_hi), jnp.clip(hi, b_lo, b_hi)


def _rmi_space(idx: Index) -> int:
    # the k_* leaves are the fused kernel's f32 re-encoding of the same
    # model — a query-time cache, not model space, so they don't count
    a = idx.arrays
    return sum(
        a[k].nbytes
        for k in ("root_coef", "leaf_slope", "leaf_icept", "leaf_eps", "leaf_r", "kmin", "inv_span")
    )


def _rmi_pallas(idx: Index, table, q):
    """Fused predict+search Pallas kernel; the f32/i32 re-encoding was
    folded into the Index leaves at build time (``k_*`` arrays)."""
    from repro.kernels.ops import split_u64
    from repro.kernels.rmi_search import fused_rmi_search_pallas

    a = idx.arrays
    u = jnp.clip((q.astype(jnp.float64) - a["kmin"]) * a["inv_span"], 0.0, 1.0).astype(
        jnp.float32
    )
    qhi, qlo = split_u64(q)
    thi, tlo = split_u64(table)
    nq = q.shape[0]
    tile = min(512, _pow2ceil(nq))
    u, qhi, qlo = _pad_queries([u, qhi, qlo], tile)
    out = fused_rmi_search_pallas(
        u,
        qhi,
        qlo,
        thi,
        tlo,
        a["k_root"],
        a["k_slope"],
        a["k_icept"],
        a["k_eps"],
        a["k_rlo"],
        a["k_rhi"],
        steps=idx.s("ksteps"),
        tile_q=tile,
        interpret=jax.default_backend() != "tpu",
    )
    return out[:nq].astype(POS_DTYPE)


def _rmi_pallas_batched(idx: Index, tables, queries):
    """Batched fused RMI kernel: grid over (table, q_tile), per-table
    parameter blocks from the stacked ``k_*`` leaves.  The bucketed
    ``ksteps`` static took the max across tables at stack time, so one
    trip count covers the widest per-table window."""
    from repro.kernels.ops import split_u64
    from repro.kernels.rmi_search import batched_rmi_search_pallas

    a = idx.arrays
    u = jnp.clip(
        (queries.astype(jnp.float64) - a["kmin"][:, None]) * a["inv_span"][:, None],
        0.0,
        1.0,
    ).astype(jnp.float32)
    qhi, qlo = split_u64(queries)
    thi, tlo = split_u64(tables)
    nq = queries.shape[1]
    tile = min(512, _pow2ceil(nq))
    u, qhi, qlo = _pad_queries([u, qhi, qlo], tile, axis=1)
    out = batched_rmi_search_pallas(
        u,
        qhi,
        qlo,
        thi,
        tlo,
        a["k_root"],
        a["k_slope"],
        a["k_icept"],
        a["k_eps"],
        a["k_rlo"],
        a["k_rhi"],
        steps=idx.s("ksteps"),
        tile_q=tile,
        interpret=jax.default_backend() != "tpu",
    )
    return out[:, :nq].astype(POS_DTYPE)


RMI_IMPL = QueryImpl(
    intervals=_rmi_intervals,
    space_bytes=_rmi_space,
    pallas=_rmi_pallas,
    pallas_batched=_rmi_pallas_batched,
)


def rmi_model_to_index(kind: str, m, table_np: np.ndarray, extra_info=None) -> Index:
    """Wrap an already-fitted :class:`repro.core.rmi.RMIModel` as an
    Index without refitting (sweep reuse, e.g. CDFShop's candidates)."""
    return _rmi_to_index(kind, m, table_np, extra_info)


def _rmi_to_index(kind: str, m, table_np: np.ndarray, extra_info=None) -> Index:
    from repro.kernels.ops import rmi_kernel_arrays

    karr, ksteps = rmi_kernel_arrays(m, table_np)
    arrays = {
        "root_coef": jnp.asarray(m.root_coef),
        "leaf_slope": jnp.asarray(m.leaf_slope),
        "leaf_icept": jnp.asarray(m.leaf_icept),
        "leaf_eps": jnp.asarray(m.leaf_eps),
        "leaf_r": jnp.asarray(m.leaf_r),
        "kmin": _scalar(m.kmin, jnp.float64),
        "inv_span": _scalar(m.inv_span, jnp.float64),
        "k_root": jnp.asarray(karr["root"]),
        "k_slope": jnp.asarray(karr["slope"]),
        "k_icept": jnp.asarray(karr["icept"]),
        "k_eps": jnp.asarray(karr["eps"]),
        "k_rlo": jnp.asarray(karr["rlo"]),
        "k_rhi": jnp.asarray(karr["rhi"]),
    }
    static = (("epi", _bucket_steps(m.max_window)), ("ksteps", _bucket_steps(1 << ksteps)))
    info = {
        "name": m.name,
        "build_time": m.build_time,
        "b": m.b,
        "max_eps": m.max_eps,
        "root_type": m.root_type,
        "n": m.n,
    }
    info.update(extra_info or {})
    return Index(kind, static, arrays, info)


def _build_rmi_index(spec: RMISpec, table_np: np.ndarray) -> Index:
    m = build_rmi(table_np, b=spec.b, root_type=spec.root_type)
    return _rmi_to_index(spec.kind, m, table_np)


def _build_sy_rmi_index(spec: SYRMISpec, table_np: np.ndarray) -> Index:
    m = build_sy_rmi(
        table_np, space_pct=spec.space_pct, ub=spec.ub, winner_root=spec.winner_root
    )
    return _rmi_to_index(spec.kind, m, table_np, {"space_pct": spec.space_pct})


# -- PGM / PGM_M -------------------------------------------------------------


def _pgm_intervals(idx: Index, table, q):
    a = idx.arrays
    n = table.shape[0]
    levels = idx.s("levels")
    steps = idx.s("epi")
    eps = a["eps"]
    qf = q.astype(jnp.float64)
    seg = jnp.zeros(q.shape, dtype=POS_DTYPE)
    for lvl in range(levels):
        off = a["off"][lvl]
        off_r = a["off_r"][lvl]
        x0 = jnp.take(a["keys"], off + seg).astype(jnp.float64)
        slope = jnp.take(a["slope"], off + seg)
        r0 = jnp.take(a["rank0"], off_r + seg)
        pred = r0.astype(jnp.float64) + slope * jnp.maximum(qf - x0, 0.0)
        pred = jnp.clip(pred, -1.0, 4.0e15)
        b_lo = jnp.maximum(r0 - 1, 0)
        b_hi = jnp.take(a["rank0"], off_r + seg + 1) - 1
        lo = jnp.clip(jnp.floor(pred).astype(POS_DTYPE) - (eps + 1), b_lo, b_hi)
        hi = jnp.clip(jnp.ceil(pred).astype(POS_DTYPE) + (eps + 1), b_lo, b_hi)
        if lvl + 1 < levels:
            off_n = a["off"][lvl + 1]
            size_n = a["sizes"][lvl + 1]
            length = jnp.maximum(hi - lo + 1, 1)
            ub = search.bounded_upper_bound(a["keys"], q, off_n + lo, length, steps=steps)
            seg = jnp.clip(ub - off_n - 1, 0, size_n - 1)
        else:
            return jnp.clip(lo, 0, n - 1), jnp.clip(hi, 0, n - 1)
    raise AssertionError("unreachable")


def _pgm_space(idx: Index) -> int:
    # valid prefixes of the level-concatenated leaves (the pow2 sentinel
    # pad is jit-cache bucketing, not model space) + level directories
    a = idx.arrays
    sizes = np.asarray(a["sizes"])
    kv, rv = int(sizes.sum()), int((sizes + 1).sum())
    per_seg = kv * (a["keys"].dtype.itemsize + a["slope"].dtype.itemsize)
    ranks = rv * a["rank0"].dtype.itemsize
    meta = a["off"].nbytes + a["off_r"].nbytes + a["sizes"].nbytes + a["eps"].nbytes
    return per_seg + ranks + meta


def _pgm_pallas(idx: Index, table, q):
    """Fused PGM descent (root route + per-level segment gather +
    ε-window search); the f32 re-anchored segment models were folded
    into the Index leaves at build time (``pk_*`` arrays)."""
    from repro.kernels.ops import split_u64
    from repro.kernels.pgm_search import fused_pgm_search_pallas

    a = idx.arrays
    u = jnp.clip((q.astype(jnp.float64) - a["pk_kmin"]) * a["pk_inv_span"], 0.0, 1.0).astype(
        jnp.float32
    )
    qhi, qlo = split_u64(q)
    thi, tlo = split_u64(table)
    khi, klo = split_u64(a["keys"])
    nq = q.shape[0]
    tile = min(512, _pow2ceil(nq))
    u, qhi, qlo = _pad_queries([u, qhi, qlo], tile)
    out = fused_pgm_search_pallas(
        u,
        qhi,
        qlo,
        thi,
        tlo,
        khi,
        klo,
        a["pk_u0"],
        a["pk_slope"],
        a["rank0"].astype(jnp.int32),
        a["off"].astype(jnp.int32),
        a["off_r"].astype(jnp.int32),
        a["sizes"].astype(jnp.int32),
        a["pk_eps"].reshape(1),
        levels=idx.s("levels"),
        steps=idx.s("pksteps"),
        tile_q=tile,
        interpret=jax.default_backend() != "tpu",
    )
    return out[:nq].astype(POS_DTYPE)


def _pgm_pallas_batched(idx: Index, tables, queries):
    """Batched fused PGM descent: grid over (table, q_tile), per-table
    leaf/directory blocks from the stacked arrays.  The lifted level
    structure is common across tables (``_lift_pgm_levels``) and the
    bucketed ``pksteps`` static took the max at stack time, so one trip
    count covers the widest per-table window."""
    from repro.kernels.ops import split_u64
    from repro.kernels.pgm_search import batched_pgm_search_pallas

    a = idx.arrays
    u = jnp.clip(
        (queries.astype(jnp.float64) - a["pk_kmin"][:, None]) * a["pk_inv_span"][:, None],
        0.0,
        1.0,
    ).astype(jnp.float32)
    qhi, qlo = split_u64(queries)
    thi, tlo = split_u64(tables)
    khi, klo = split_u64(a["keys"])
    nq = queries.shape[1]
    tile = min(512, _pow2ceil(nq))
    u, qhi, qlo = _pad_queries([u, qhi, qlo], tile, axis=1)
    out = batched_pgm_search_pallas(
        u,
        qhi,
        qlo,
        thi,
        tlo,
        khi,
        klo,
        a["pk_u0"],
        a["pk_slope"],
        a["rank0"].astype(jnp.int32),
        a["off"].astype(jnp.int32),
        a["off_r"].astype(jnp.int32),
        a["sizes"].astype(jnp.int32),
        a["pk_eps"].reshape(-1, 1),
        levels=idx.s("levels"),
        steps=idx.s("pksteps"),
        tile_q=tile,
        interpret=jax.default_backend() != "tpu",
    )
    return out[:, :nq].astype(POS_DTYPE)


PGM_IMPL = QueryImpl(
    intervals=_pgm_intervals,
    space_bytes=_pgm_space,
    pallas=_pgm_pallas,
    pallas_batched=_pgm_pallas_batched,
)


def pgm_model_to_index(kind: str, m, table_np: np.ndarray, extra_info=None) -> Index:
    """Wrap an already-fitted :class:`repro.core.pgm.PGMModel` as an
    Index without refitting (the batched scan-fit path)."""
    return _pgm_to_index(kind, m, table_np, extra_info)


def _pgm_to_index(kind: str, m, table_np: np.ndarray, extra_info=None) -> Index:
    from repro.kernels.ops import pgm_kernel_arrays

    karr, pksteps = pgm_kernel_arrays(m, table_np)
    level_keys = [np.asarray(k) for k in m.level_keys]
    level_slope = [np.asarray(s) for s in m.level_slope]
    level_rank0 = [np.asarray(r) for r in m.level_rank0]
    sizes = np.asarray(m.level_sizes, dtype=np.int64)
    off = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
    off_r = np.concatenate([[0], np.cumsum(sizes + 1)]).astype(np.int64)
    keys = np.concatenate(level_keys)
    slope = np.concatenate(level_slope)
    rank0 = np.concatenate(level_rank0)
    arrays = {
        "keys": jnp.asarray(_pad_pow2(keys, _MAXKEY)),
        "slope": jnp.asarray(_pad_pow2(slope, 0.0)),
        "rank0": jnp.asarray(_pad_pow2(rank0, rank0[-1])),
        "off": jnp.asarray(off),
        "off_r": jnp.asarray(off_r),
        "sizes": jnp.asarray(sizes),
        "eps": _scalar(m.eps, jnp.int64),
        # fused-kernel re-encoding (query-time cache, not model space)
        "pk_u0": jnp.asarray(_pad_pow2(karr["u0"], np.float32(1.0))),
        "pk_slope": jnp.asarray(_pad_pow2(karr["slope"], np.float32(0.0))),
        "pk_eps": _scalar(karr["eps"], jnp.int32),
        "pk_kmin": _scalar(karr["kmin"], jnp.float64),
        "pk_inv_span": _scalar(karr["inv_span"], jnp.float64),
    }
    static = (
        ("levels", len(level_keys)),
        ("epi", _bucket_steps(min(2 * (m.eps + 2) + 3, m.n))),
        ("pksteps", _bucket_steps(1 << pksteps)),
    )
    info = {
        "name": m.name,
        "build_time": m.build_time,
        "eps": m.eps,
        "n_segments_l0": m.n_segments_l0,
        "n": m.n,
    }
    info.update(extra_info or {})
    return Index(kind, static, arrays, info)


def _build_pgm_index(spec: PGMSpec, table_np: np.ndarray) -> Index:
    return _pgm_to_index(spec.kind, build_pgm(table_np, eps=spec.eps), table_np)


def _build_pgm_m_index(spec: PGMBicriteriaSpec, table_np: np.ndarray) -> Index:
    m = build_pgm_bicriteria(
        table_np, space_budget_bytes=spec.budget_for(len(table_np)), a=spec.a
    )
    return _pgm_to_index(spec.kind, m, table_np, {"a": spec.a})


# -- RadixSpline -------------------------------------------------------------


def _rs_intervals(idx: Index, table, q):
    a = idx.arrays
    n = table.shape[0]
    r_bits = idx.s("r_bits")
    m_valid = a["m_valid"]
    eps_eff = a["eps_eff"]
    qc = jnp.maximum(q, a["kmin"])
    prefix = ((qc - a["kmin"]) >> a["shift"]).astype(POS_DTYPE)
    prefix = jnp.clip(prefix, 0, (1 << r_bits) - 1)
    lo_k = jnp.maximum(jnp.take(a["radix_table"], prefix) - 1, 0)
    hi_k = jnp.take(a["radix_table"], prefix + 1)
    length = jnp.maximum(hi_k - lo_k, 1)
    ub = search.bounded_upper_bound(
        a["knot_keys"], q, lo_k, length, steps=idx.s("ksteps")
    )
    j = jnp.clip(ub - 1, 0, m_valid - 2)
    x1 = jnp.take(a["knot_keys"], j).astype(jnp.float64)
    x2 = jnp.take(a["knot_keys"], j + 1).astype(jnp.float64)
    y1 = jnp.take(a["knot_ranks"], j).astype(jnp.float64)
    y2 = jnp.take(a["knot_ranks"], j + 1).astype(jnp.float64)
    t = (qc.astype(jnp.float64) - x1) / jnp.maximum(x2 - x1, 1.0)
    pred = y1 + jnp.clip(t, 0.0, 1.0) * (y2 - y1)
    lo = jnp.floor(pred).astype(POS_DTYPE) - eps_eff
    hi = jnp.ceil(pred).astype(POS_DTYPE) + eps_eff
    return jnp.clip(lo, 0, n - 1), jnp.clip(hi, 0, n - 1)


def _rs_space(idx: Index) -> int:
    a = idx.arrays
    m = int(np.asarray(a["m_valid"]))
    knots = m * (a["knot_keys"].dtype.itemsize + a["knot_ranks"].dtype.itemsize)
    scalars = a["kmin"].nbytes + a["shift"].nbytes + a["eps_eff"].nbytes + a["m_valid"].nbytes
    return knots + a["radix_table"].nbytes + scalars


def _rs_pallas(idx: Index, table, q):
    """Fused RadixSpline lookup (radix gather + knot search + ε-window
    probe); the f32 re-anchored spline was folded into the Index leaves
    at build time (``rk_*`` arrays).  The radix prefix is query-side
    integer work and is computed here, outside the kernel."""
    from repro.kernels.ops import split_u64
    from repro.kernels.rs_search import fused_rs_search_pallas

    a = idx.arrays
    r_bits = idx.s("r_bits")
    qc = jnp.maximum(q, a["kmin"])
    prefix = jnp.minimum((qc - a["kmin"]) >> a["shift"], jnp.uint64((1 << r_bits) - 1)).astype(
        jnp.int32
    )
    u = jnp.clip((q.astype(jnp.float64) - a["rk_kmin"]) * a["rk_inv_span"], 0.0, 1.0).astype(
        jnp.float32
    )
    qhi, qlo = split_u64(q)
    thi, tlo = split_u64(table)
    khi, klo = split_u64(a["knot_keys"])
    nq = q.shape[0]
    tile = min(512, _pow2ceil(nq))
    u, qhi, qlo, prefix = _pad_queries([u, qhi, qlo, prefix], tile)
    out = fused_rs_search_pallas(
        u,
        qhi,
        qlo,
        prefix,
        thi,
        tlo,
        khi,
        klo,
        a["rk_u0"],
        a["rk_slope"],
        a["knot_ranks"].astype(jnp.int32),
        a["radix_table"].astype(jnp.int32),
        a["m_valid"].reshape(1).astype(jnp.int32),
        a["rk_eps"].reshape(1),
        ksteps=idx.s("ksteps"),
        steps=idx.s("rk_epi"),
        tile_q=tile,
        interpret=jax.default_backend() != "tpu",
    )
    return out[:nq].astype(POS_DTYPE)


def _rs_pallas_batched(idx: Index, tables, queries):
    """Batched fused RadixSpline lookup: grid over (table, q_tile),
    per-table knot/radix blocks from the stacked arrays.  ``r_bits`` is
    a structural static (stacking requires it to agree), so the radix
    prefix is computed per table outside the kernel exactly as in the
    single-table path."""
    from repro.kernels.ops import split_u64
    from repro.kernels.rs_search import batched_rs_search_pallas

    a = idx.arrays
    r_bits = idx.s("r_bits")
    kmin = a["kmin"][:, None]
    qc = jnp.maximum(queries, kmin)
    prefix = jnp.minimum(
        (qc - kmin) >> a["shift"][:, None], jnp.uint64((1 << r_bits) - 1)
    ).astype(jnp.int32)
    u = jnp.clip(
        (queries.astype(jnp.float64) - a["rk_kmin"][:, None]) * a["rk_inv_span"][:, None],
        0.0,
        1.0,
    ).astype(jnp.float32)
    qhi, qlo = split_u64(queries)
    thi, tlo = split_u64(tables)
    khi, klo = split_u64(a["knot_keys"])
    nq = queries.shape[1]
    tile = min(512, _pow2ceil(nq))
    u, qhi, qlo, prefix = _pad_queries([u, qhi, qlo, prefix], tile, axis=1)
    out = batched_rs_search_pallas(
        u,
        qhi,
        qlo,
        prefix,
        thi,
        tlo,
        khi,
        klo,
        a["rk_u0"],
        a["rk_slope"],
        a["knot_ranks"].astype(jnp.int32),
        a["radix_table"].astype(jnp.int32),
        a["m_valid"].reshape(-1, 1).astype(jnp.int32),
        a["rk_eps"].reshape(-1, 1),
        ksteps=idx.s("ksteps"),
        steps=idx.s("rk_epi"),
        tile_q=tile,
        interpret=jax.default_backend() != "tpu",
    )
    return out[:, :nq].astype(POS_DTYPE)


RS_IMPL = QueryImpl(
    intervals=_rs_intervals,
    space_bytes=_rs_space,
    pallas=_rs_pallas,
    pallas_batched=_rs_pallas_batched,
)


def rs_model_to_index(kind: str, m, table_np: np.ndarray) -> Index:
    """Wrap an already-fitted :class:`repro.core.radix_spline.RSModel`
    as an Index without refitting (the batched scan-fit path)."""
    from repro.kernels.ops import rs_kernel_arrays

    karr, rksteps = rs_kernel_arrays(m, table_np)
    knot_keys = np.asarray(m.knot_keys)
    knot_ranks = np.asarray(m.knot_ranks)
    arrays = {
        "knot_keys": jnp.asarray(_pad_pow2(knot_keys, _MAXKEY)),
        "knot_ranks": jnp.asarray(_pad_pow2(knot_ranks, knot_ranks[-1])),
        "radix_table": jnp.asarray(m.radix_table),
        "kmin": jnp.asarray(m.kmin).reshape(()),
        "shift": _scalar(m.shift, jnp.uint64),
        "eps_eff": _scalar(m.eps_eff, jnp.int64),
        "m_valid": _scalar(m.m, jnp.int64),
        # fused-kernel re-encoding (query-time cache, not model space)
        "rk_u0": jnp.asarray(_pad_pow2(karr["u0"], np.float32(1.0))),
        "rk_slope": jnp.asarray(_pad_pow2(karr["slope"], np.float32(0.0))),
        "rk_eps": _scalar(karr["eps"], jnp.int32),
        "rk_kmin": _scalar(karr["kmin"], jnp.float64),
        "rk_inv_span": _scalar(karr["inv_span"], jnp.float64),
    }
    static = (
        ("r_bits", m.r_bits),
        ("ksteps", _bucket_steps(_pow2ceil(len(knot_keys)))),
        ("epi", _bucket_steps(min(2 * m.eps_eff + 3, m.n))),
        ("rk_epi", _bucket_steps(1 << rksteps)),
    )
    info = {
        "name": m.name,
        "build_time": m.build_time,
        "eps": m.eps,
        "eps_eff": m.eps_eff,
        "m": m.m,
        "n": m.n,
    }
    return Index(kind, static, arrays, info)


def _build_rs_index(spec: RSSpec, table_np: np.ndarray) -> Index:
    m = build_rs(table_np, eps=spec.eps, r_bits=spec.r_bits)
    return rs_model_to_index(spec.kind, m, table_np)


# -- B+-tree -----------------------------------------------------------------


def _btree_intervals(idx: Index, table, q):
    a = idx.arrays
    n = table.shape[0]
    f = idx.s("fanout")
    levels = idx.s("levels")
    if levels == 0:  # degenerate: table fits one block
        z = jnp.zeros(q.shape, dtype=POS_DTYPE)
        return z, z + (n - 1)
    node = jnp.zeros(q.shape, dtype=POS_DTYPE)
    for lvl in range(levels):
        base = node * f
        fence = a["off"][lvl] + base[..., None] + jnp.arange(f, dtype=POS_DTYPE)
        v = jnp.take(a["keys"], fence, mode="clip")
        child = jnp.sum((v <= q[..., None]).astype(POS_DTYPE), axis=-1)
        child = jnp.maximum(child - 1, 0)
        node = jnp.minimum(base + child, a["valid"][lvl] - 1)
    node = jnp.minimum(node, (n + f - 1) // f - 1)
    lo = node * f
    hi = jnp.minimum(lo + f - 1, n - 1)
    lo = jnp.maximum(lo - 1, 0)
    return lo, hi


def _btree_space(idx: Index) -> int:
    a = idx.arrays
    return a["keys"].nbytes + a["off"].nbytes + a["valid"].nbytes


BTREE_IMPL = QueryImpl(
    intervals=_btree_intervals, space_bytes=_btree_space, pallas=_kary_pallas_fallback
)


def _build_btree_index(spec: BTreeSpec, table_np: np.ndarray) -> Index:
    m = build_btree(table_np, fanout=spec.fanout)
    lvls = [np.asarray(l) for l in m.levels]
    keys = (
        np.concatenate(lvls) if lvls else np.zeros((0,), dtype=np.uint64)
    )
    off = np.concatenate([[0], np.cumsum([len(l) for l in lvls])]).astype(np.int64)
    valid = np.asarray(m.valid, dtype=np.int64)
    arrays = {
        "keys": jnp.asarray(keys),
        "off": jnp.asarray(off),
        "valid": jnp.asarray(valid),
    }
    static = (
        ("fanout", m.fanout),
        ("levels", len(lvls)),
        ("epi", _bucket_steps(min(m.fanout + 1, m.n))),
    )
    info = {"name": m.name, "build_time": m.build_time, "n": m.n}
    return Index(spec.kind, static, arrays, info)


# ---------------------------------------------------------------------------
# Registry wiring — registration order IS the paper's hierarchy order.
# ---------------------------------------------------------------------------

QUERY_IMPLS = {
    "atomic": ATOMIC_IMPL,
    "ko": KO_IMPL,
    "rmi": RMI_IMPL,
    "pgm": PGM_IMPL,
    "rs": RS_IMPL,
    "btree": BTREE_IMPL,
}

_KIND_TO_IMPL = {}


def query_impl(kind: str) -> QueryImpl:
    return QUERY_IMPLS[_KIND_TO_IMPL[kind.upper()]]


def _reg(kind, spec_cls, query_key, build_fn, spec_from_params):
    _KIND_TO_IMPL[kind] = query_key
    register(kind, spec_cls, query_key=query_key, spec_from_params=spec_from_params)(build_fn)


_reg("L", AtomicSpec, "atomic", _build_atomic_index, lambda **p: AtomicSpec(degree=1))
_reg("Q", AtomicSpec, "atomic", _build_atomic_index, lambda **p: AtomicSpec(degree=2))
_reg("C", AtomicSpec, "atomic", _build_atomic_index, lambda **p: AtomicSpec(degree=3))
_reg("KO", KOSpec, "ko", _build_ko_index, lambda **p: KOSpec(k=p.get("k", 15)))
_reg(
    "RMI",
    RMISpec,
    "rmi",
    _build_rmi_index,
    lambda **p: RMISpec(b=p.get("b", 1024), root_type=p.get("root_type", "linear")),
)
_reg(
    "SY-RMI",
    SYRMISpec,
    "rmi",
    _build_sy_rmi_index,
    lambda **p: SYRMISpec(
        space_pct=p.get("space_pct", 2.0),
        ub=p.get("ub", 0.05),
        winner_root=p.get("winner_root", "linear"),
    ),
)
_reg("PGM", PGMSpec, "pgm", _build_pgm_index, lambda **p: PGMSpec(eps=p.get("eps", 64)))
_reg(
    "PGM_M",
    PGMBicriteriaSpec,
    "pgm",
    _build_pgm_m_index,
    lambda **p: PGMBicriteriaSpec(
        space_budget_bytes=p.get("space_budget_bytes", 0),
        space_pct=p.get("space_pct", 2.0),
        a=p.get("a", 1.0),
    ),
)
_reg(
    "RS",
    RSSpec,
    "rs",
    _build_rs_index,
    lambda **p: RSSpec(eps=p.get("eps", 32), r_bits=p.get("r_bits", 12)),
)
_reg(
    "BTREE",
    BTreeSpec,
    "btree",
    _build_btree_index,
    lambda **p: BTreeSpec(fanout=p.get("fanout", 16)),
)
