"""The redesigned mutation surface for updatable index kinds.

PRs 2–3 grew the write path ad hoc: every ingest was buffered host-side
and absorbed by a *full shard rebuild* (``refresh_shard`` after
``TunedTier.ingest`` / ``maybe_rebuild``).  This module is the one
coherent replacement — a small per-kind mutator registry behind two
``Index`` methods, with one documented lifecycle::

    absorb -> overflow -> compact -> retune

* ``Index.insert_batch(keys)`` — keys are routed to their model-guided
  leaf; leaves with room **absorb** them in place (gapped arrays), full
  leaves **overflow** the keys into the sorted delta buffer, and the
  returned :class:`InsertReport` carries a ``needs_compaction`` signal
  once the delta fills past :data:`COMPACT_FILL`.
* ``Index.compact()`` — folds the delta into rebalanced gapped leaves in
  one device-side program (no host round-trip, no model refit; only the
  root model's ε is re-measured against the new fences).
* **retune** stays where it always was — the Pareto tuner
  (:class:`repro.tune.rebuild.TunedTier`) — and now fires on *capacity
  exhaustion* (:class:`NeedsRebuild`), not on every insert.

Static kinds raise ``TypeError`` from both methods: updatability is a
per-kind capability registered via :func:`register_mutator`, exactly
like query impls are registered per kind.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

#: delta fill fraction past which ``InsertReport.needs_compaction`` is
#: set — the tier's cue to schedule a compaction *between* batches
COMPACT_FILL = 0.5


class NeedsRebuild(RuntimeError):
    """Raised when a mutation cannot fit the index's fixed capacity
    (leaves + delta exhausted): the kind-level escape hatch that tells
    the serving tier to rebuild/retune with a larger spec."""


@dataclass(frozen=True)
class InsertReport:
    """Host-side summary of one ``insert_batch`` call."""

    requested: int  #: keys passed in
    absorbed: int  #: merged into leaf gaps in place
    overflowed: int  #: diverted to the delta buffer
    duplicates: int  #: already present (batch-internal or in the index)
    delta_count: int  #: delta occupancy after the call
    delta_cap: int  #: delta capacity
    compacted: bool  #: True if an automatic compaction ran mid-call

    @property
    def delta_fill(self) -> float:
        return self.delta_count / max(self.delta_cap, 1)

    @property
    def needs_compaction(self) -> bool:
        return self.delta_fill >= COMPACT_FILL


@dataclass(frozen=True)
class Mutator:
    """Per-kind mutation implementation.

    ``insert_batch(index, keys, auto_compact=...) -> (Index, InsertReport)``
    and ``compact(index) -> Index``; both may raise :class:`NeedsRebuild`.
    """

    insert_batch: Callable
    compact: Callable


MUTATORS: Dict[str, Mutator] = {}


def register_mutator(kind: str, mutator: Mutator) -> None:
    if kind in MUTATORS:
        raise ValueError(f"mutator for kind {kind!r} registered twice")
    MUTATORS[kind] = mutator


def updatable_kinds() -> tuple:
    """Kinds that support ``insert_batch``/``compact``."""
    return tuple(MUTATORS)


def _mutator(index) -> Mutator:
    m = MUTATORS.get(index.kind)
    if m is None:
        raise TypeError(
            f"index kind {index.kind!r} is static — only {updatable_kinds()} "
            "support insert_batch/compact (rebuild instead, or route ingest "
            "through an updatable kind such as GAPPED)"
        )
    return m


def _record_report(kind: str, report: "InsertReport") -> None:
    """Aggregate an InsertReport into the ``mutation_*`` registry
    counters (labeled by kind).  Host-side only: no extra dispatches,
    no lookup-trace changes."""
    from repro import obs

    obs.metric("mutation_requested").inc(report.requested, kind=kind)
    obs.metric("mutation_absorbed").inc(report.absorbed, kind=kind)
    obs.metric("mutation_overflowed").inc(report.overflowed, kind=kind)
    obs.metric("mutation_duplicates").inc(report.duplicates, kind=kind)
    if report.compacted:
        obs.metric("mutation_compactions").inc(kind=kind)


def insert_batch(index, keys, *, auto_compact: bool = True):
    """Dispatch ``insert_batch`` to the kind's registered mutator."""
    new, report = _mutator(index).insert_batch(index, keys, auto_compact=auto_compact)
    _record_report(index.kind, report)
    return new, report


def compact(index):
    """Dispatch ``compact`` to the kind's registered mutator."""
    out = _mutator(index).compact(index)
    from repro import obs

    obs.metric("mutation_compactions").inc(kind=index.kind)
    return out
