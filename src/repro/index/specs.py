"""Hashable build specs — one frozen dataclass per index kind.

A spec is pure *configuration*: everything needed to (re)build an index
of its kind over any table, hashable so it can key jit caches, sweep
grids and result dictionaries.  Specs know their registry ``kind`` string
and a display name; the heavy lifting (fitting, flattening to arrays)
lives with the per-kind impls in :mod:`repro.index.kinds`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class IndexSpec:
    """Base class for all index build specs (hashable, immutable)."""

    kind = "?"  # overridden per subclass (class attribute, not a field)

    def display_name(self) -> str:
        params = ",".join(
            f"{f.name}={getattr(self, f.name)}" for f in dataclasses.fields(self)
        )
        return f"{self.kind}[{params}]" if params else self.kind

    def params(self) -> dict:
        return dataclasses.asdict(self)


@dataclass(frozen=True)
class AtomicSpec(IndexSpec):
    """L / Q / C: one degree-1/2/3 polynomial over the whole CDF."""

    degree: int = 1

    @property
    def kind(self) -> str:  # type: ignore[override]
        return {1: "L", 2: "Q", 3: "C"}[self.degree]

    def display_name(self) -> str:
        return self.kind


@dataclass(frozen=True)
class KOSpec(IndexSpec):
    """KO-BFS hybrid: k equal-rank segments, best atomic model each."""

    k: int = 15
    kind = "KO"


@dataclass(frozen=True)
class RMISpec(IndexSpec):
    """Two-level RMI: monotone root + b linear leaves."""

    b: int = 1024
    root_type: str = "linear"
    kind = "RMI"


@dataclass(frozen=True)
class SYRMISpec(IndexSpec):
    """Synoptic RMI: winner architecture at a % -of-table space budget."""

    space_pct: float = 2.0
    ub: float = 0.05
    winner_root: str = "linear"
    kind = "SY-RMI"


@dataclass(frozen=True)
class PGMSpec(IndexSpec):
    """PGM: ε-controlled recursive piecewise-linear model."""

    eps: int = 64
    kind = "PGM"


@dataclass(frozen=True)
class PGMBicriteriaSpec(IndexSpec):
    """Bi-criteria PGM_M_a: smallest ε fitting a byte budget.

    ``space_budget_bytes`` <= 0 means "derive from space_pct".
    """

    space_budget_bytes: int = 0
    space_pct: float = 2.0
    a: float = 1.0
    kind = "PGM_M"

    def budget_for(self, n_keys: int) -> int:
        if self.space_budget_bytes > 0:
            return int(self.space_budget_bytes)
        return int(self.space_pct / 100.0 * n_keys * 8)


@dataclass(frozen=True)
class RSSpec(IndexSpec):
    """RadixSpline: greedy ε-spline + radix table over top r bits."""

    eps: int = 32
    r_bits: int = 12
    kind = "RS"


@dataclass(frozen=True)
class BTreeSpec(IndexSpec):
    """Array-packed static B+-tree baseline."""

    fanout: int = 16
    kind = "BTREE"
