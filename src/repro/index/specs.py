"""Hashable build specs — one frozen dataclass per index kind.

A spec is pure *configuration*: everything needed to (re)build an index
of its kind over any table, hashable so it can key jit caches, sweep
grids and result dictionaries.  Specs know their registry ``kind`` string
and a display name; the heavy lifting (fitting, flattening to arrays)
lives with the per-kind impls in :mod:`repro.index.kinds`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class IndexSpec:
    """Base class for all index build specs (hashable, immutable)."""

    kind = "?"  # overridden per subclass (class attribute, not a field)

    def display_name(self) -> str:
        params = ",".join(
            f"{f.name}={getattr(self, f.name)}" for f in dataclasses.fields(self)
        )
        return f"{self.kind}[{params}]" if params else self.kind

    def params(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def default_grid(cls, n_keys: int) -> tuple:
        """The kind's default candidate specs for a table of ``n_keys``.

        This is the registry-derived sweep grid of the Pareto auto-tuner
        (:mod:`repro.tune.pareto`): every spec class contributes the
        handful of configurations that span its own time-space curve, so
        the tuner needs no per-kind knowledge.  Subclasses override;
        the base grid is the kind's default configuration.
        """
        return (cls(),)


@dataclass(frozen=True)
class AtomicSpec(IndexSpec):
    """L / Q / C: one degree-1/2/3 polynomial over the whole CDF."""

    degree: int = 1

    @property
    def kind(self) -> str:  # type: ignore[override]
        return {1: "L", 2: "Q", 3: "C"}[self.degree]

    def display_name(self) -> str:
        return self.kind

    @classmethod
    def default_grid(cls, n_keys: int) -> tuple:
        return tuple(cls(degree=d) for d in (1, 2, 3))


@dataclass(frozen=True)
class KOSpec(IndexSpec):
    """KO-BFS hybrid: k equal-rank segments, best atomic model each."""

    k: int = 15
    kind = "KO"

    @classmethod
    def default_grid(cls, n_keys: int) -> tuple:
        return tuple(cls(k=k) for k in (7, 15, 31) if k <= max(n_keys // 2, 2))


@dataclass(frozen=True)
class RMISpec(IndexSpec):
    """Two-level RMI: monotone root + b linear leaves."""

    b: int = 1024
    root_type: str = "linear"
    kind = "RMI"

    @classmethod
    def default_grid(cls, n_keys: int) -> tuple:
        bs = [b for b in (64, 1024, 16384, 262144) if b <= max(n_keys // 2, 2)] or [2]
        return tuple(cls(b=b) for b in bs)


@dataclass(frozen=True)
class SYRMISpec(IndexSpec):
    """Synoptic RMI: winner architecture at a % -of-table space budget."""

    space_pct: float = 2.0
    ub: float = 0.05
    winner_root: str = "linear"
    kind = "SY-RMI"

    @classmethod
    def default_grid(cls, n_keys: int) -> tuple:
        # the paper's small-model-space sweep: budgets as a % of table bytes
        return tuple(cls(space_pct=p) for p in (0.05, 0.7, 2.0, 10.0))


@dataclass(frozen=True)
class PGMSpec(IndexSpec):
    """PGM: ε-controlled recursive piecewise-linear model."""

    eps: int = 64
    kind = "PGM"

    @classmethod
    def default_grid(cls, n_keys: int) -> tuple:
        return tuple(cls(eps=e) for e in (16, 64, 256))


@dataclass(frozen=True)
class PGMBicriteriaSpec(IndexSpec):
    """Bi-criteria PGM_M_a: smallest ε fitting a byte budget.

    ``space_budget_bytes`` <= 0 means "derive from space_pct".
    """

    space_budget_bytes: int = 0
    space_pct: float = 2.0
    a: float = 1.0
    kind = "PGM_M"

    def budget_for(self, n_keys: int) -> int:
        if self.space_budget_bytes > 0:
            return int(self.space_budget_bytes)
        return int(self.space_pct / 100.0 * n_keys * 8)

    @classmethod
    def default_grid(cls, n_keys: int) -> tuple:
        return tuple(cls(space_pct=p) for p in (0.05, 0.7, 2.0))


@dataclass(frozen=True)
class RSSpec(IndexSpec):
    """RadixSpline: greedy ε-spline + radix table over top r bits."""

    eps: int = 32
    r_bits: int = 12
    kind = "RS"

    @classmethod
    def default_grid(cls, n_keys: int) -> tuple:
        r = 8 if n_keys < 1 << 16 else 12
        return tuple(cls(eps=e, r_bits=r) for e in (16, 64))


@dataclass(frozen=True)
class BTreeSpec(IndexSpec):
    """Array-packed static B+-tree baseline."""

    fanout: int = 16
    kind = "BTREE"

    @classmethod
    def default_grid(cls, n_keys: int) -> tuple:
        return tuple(cls(fanout=f) for f in (8, 16))


@dataclass(frozen=True)
class GappedSpec(IndexSpec):
    """ALEX-style updatable index: gapped leaves + sorted delta buffer.

    ``leaf_cap`` keys of capacity per leaf, filled to ``fill`` at build /
    compaction time (the rest are model-guided insertion gaps);
    ``delta_cap`` bounds the sorted overflow buffer merged at lookup.
    """

    leaf_cap: int = 256
    fill: float = 0.75
    delta_cap: int = 1024
    kind = "GAPPED"

    @classmethod
    def default_grid(cls, n_keys: int) -> tuple:
        caps = [c for c in (64, 256, 1024) if c <= max(n_keys, 64)]
        return tuple(cls(leaf_cap=c) for c in caps) or (cls(leaf_cap=64),)
