r"""The :class:`Index` pytree and the shared jitted query path.

The paper's view — and Kraska et al.'s — is that a learned index *is
data*: a handful of flat arrays (segments, fences, slopes, intercepts)
driven by one generic lookup procedure.  ``Index`` realises that view as
a registered JAX pytree:

* **leaves** — the model's arrays (``index.arrays``), so an ``Index``
  can be passed through ``jax.jit``, ``vmap``, donated, sharded, or
  serialized like any other pytree of arrays;
* **treedef aux** — the kind tag plus a small tuple of static ints
  (loop trip counts, level counts), deliberately log-bucketed so that
  different instances of a kind collide onto the *same* jit cache entry.

Because the model is an argument rather than a closure constant, there
is exactly **one** jitted query function per (kind, backend) — building
ten SY-RMIs at ten space budgets re-traces zero to one times instead of
ten.  ``trace_counts()`` exposes the cache behaviour for tests and
benchmarks.

Backends (``lookup(..., backend=...)``):

* ``"xla"``    — intervals + branch-free bounded search (default);
* ``"bbs"``    — intervals + branchy early-exit epilogue (paper's \*-BBS);
* ``"pallas"`` — fused Pallas kernels for the learned-model families
  (RMI/SY-RMI predict+search, PGM descent, RadixSpline radix+knot+probe)
  and the lane-wide k-ary kernel for the model-free kinds (atomic / KO /
  B+-tree); interpret mode off-TPU.  Batched/tier lookups dispatch the
  ``(table, q_tile)``-grid batched kernel variants via
  :func:`batched_pallas_impl`;
* ``"ref"``    — ``jnp.searchsorted`` oracle (parity testing).
"""

from __future__ import annotations

import collections
import json
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.cdf import POS_DTYPE

BACKENDS = ("xla", "bbs", "pallas", "ref")

_TRACE_COUNTS: collections.Counter = collections.Counter()


def trace_counts() -> dict:
    """(kind, backend) -> number of times the shared lookup was traced."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


class Index:
    """A learned static index as a pytree of flat arrays.

    Attributes
    ----------
    kind:    registry kind tag (``"RMI"``, ``"PGM"``, ...) — static.
    static:  tuple of ``(name, int)`` pairs — static query metadata
             (bucketed loop trip counts, level counts, degrees).
    arrays:  dict name -> jnp.ndarray — the pytree leaves.
    info:    host-side build metadata (name, build_time, eps, ...).
             *Not* part of the pytree: it is dropped under tracing and
             by ``tree_unflatten`` so it can never fragment jit caches.
    """

    __slots__ = ("kind", "static", "arrays", "info")

    def __init__(self, kind: str, static: tuple, arrays: dict, info: dict | None = None):
        object.__setattr__(self, "kind", kind)
        object.__setattr__(self, "static", tuple(static))
        object.__setattr__(self, "arrays", dict(arrays))
        object.__setattr__(self, "info", dict(info or {}))

    # -- static metadata --------------------------------------------------
    def s(self, name: str) -> int:
        for k, v in self.static:
            if k == name:
                return v
        raise KeyError(name)

    @property
    def name(self) -> str:
        return self.info.get("name", self.kind)

    def __getattr__(self, item):
        # convenience passthrough: idx.eps, idx.b, idx.n_segments_l0, ...
        info = object.__getattribute__(self, "info")
        if item in info:
            return info[item]
        raise AttributeError(item)

    def __repr__(self):
        shapes = {k: tuple(v.shape) for k, v in self.arrays.items()}
        return f"Index(kind={self.kind!r}, static={dict(self.static)}, arrays={shapes})"

    # -- pytree protocol --------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.arrays))
        children = tuple(self.arrays[k] for k in names)
        return children, (self.kind, self.static, names)

    @classmethod
    def tree_unflatten(cls, aux, children):
        kind, static, names = aux
        return cls(kind, static, dict(zip(names, children)), info=None)

    # -- queries ----------------------------------------------------------
    def intervals(self, table, queries):
        """Predicted inclusive window [lo, hi] per query (jittable)."""
        from . import impls

        return impls.query_impl(self.kind).intervals(self, table, queries)

    def backends(self) -> tuple:
        """The backends this kind supports (subset of :data:`BACKENDS`)."""
        from . import impls

        return impls.query_impl(self.kind).backends

    def lookup(self, table, queries, *, backend: str = "xla"):
        """Predecessor ranks through the shared jitted query path."""
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; choose from {BACKENDS}")
        if backend not in self.backends():
            raise ValueError(
                f"kind {self.kind!r} supports backends {self.backends()}, not {backend!r}"
            )
        return _lookup_jit(self, jnp.asarray(table), jnp.asarray(queries), backend)

    def predecessor(self, table, queries, *, branchy: bool = False, backend: str | None = None):
        r"""Predecessor ranks; ``branchy=True`` selects the \*-BBS epilogue."""
        return self.lookup(table, queries, backend=backend or ("bbs" if branchy else "xla"))

    # -- mutation (updatable kinds only) ----------------------------------
    def insert_batch(self, keys, *, auto_compact: bool = True):
        """Insert a batch of keys (updatable kinds, e.g. ``GAPPED``).

        Returns ``(new_index, InsertReport)`` — absorption into leaf gaps
        first, overflow to the delta buffer, ``auto_compact`` folding the
        delta into the leaves when it would overflow.  Static kinds raise
        ``TypeError``; see :mod:`repro.index.mutation`.
        """
        from . import mutation

        return mutation.insert_batch(self, keys, auto_compact=auto_compact)

    def compact(self) -> "Index":
        """Fold the delta buffer into the gapped leaves (device-side)."""
        from . import mutation

        return mutation.compact(self)

    # -- accounting / serialization --------------------------------------
    def space_bytes(self) -> int:
        """Model space in the paper's sense: the bytes of the leaves that
        constitute the model (valid prefixes of padded leaves; query-time
        caches like the fused kernel's f32 re-encoding excluded)."""
        from . import impls

        return impls.query_impl(self.kind).space_bytes(self)

    def nbytes(self) -> int:
        """Total resident bytes of every pytree leaf as stored (padding
        and kernel re-encodings included) — ``space_bytes`` <= this."""
        return sum(int(v.nbytes) for v in self.arrays.values())

    def save(self, path) -> None:
        """npz round-trip: arrays bit-exact, kind/static/info as JSON."""
        payload = {f"arr_{k}": np.asarray(v) for k, v in self.arrays.items()}
        meta = {
            "kind": self.kind,
            "static": list(map(list, self.static)),
            "info": {k: v for k, v in self.info.items() if isinstance(v, (str, int, float, bool))},
        }
        payload["__meta__"] = np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)
        np.savez(path, **payload)

    @classmethod
    def load(cls, path) -> "Index":
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
            arrays = {
                k[len("arr_"):]: jnp.asarray(z[k]) for k in z.files if k.startswith("arr_")
            }
        static = tuple((k, int(v)) for k, v in meta["static"])
        return cls(meta["kind"], static, arrays, info=meta.get("info"))


jax.tree_util.register_pytree_node_class(Index)


# ---------------------------------------------------------------------------
# The shared jitted query path: ONE trace per (kind structure, backend)
# ---------------------------------------------------------------------------


def lookup_impl(index: Index, table, queries, backend: str):
    """Traceable body of the shared lookup (no jit wrapper of its own).

    Composite query paths — the shard_map'd sharded lookup, vmapped
    multi-index sweeps — call this inside their *own* single jitted
    function instead of nesting ``Index.lookup``'s jit, so they keep the
    one-trace-per-kind guarantee."""
    from . import impls

    impl = impls.query_impl(index.kind)

    if impl.lookup is not None:
        # self-contained kinds (GAPPED two-tier merge): the index owns its
        # keys, so the answer ignores ``table`` on every backend
        return impl.lookup(index, table, queries, backend)
    if backend == "ref":
        return jnp.searchsorted(table, queries, side="right").astype(POS_DTYPE) - 1
    if backend == "pallas":
        return impl.pallas(index, table, queries)

    lo, hi = impl.intervals(index, table, queries)
    from repro.core import search

    if backend == "bbs":
        return search.bounded_bbs_branchy(table, queries, lo, hi)
    return search.bounded_bfs(table, queries, lo, hi, max_window=1 << impl.epi_steps(index))


def batched_pallas_impl(index: Index, tables, queries):
    """Traceable batched-Pallas lookup body: ``(n_tables, B)`` raw local
    predecessor ranks for stacked leaves / tables / queries.

    The ``backend="pallas"`` counterpart of ``vmap``-over-
    :func:`lookup_impl`: instead of vmapping the single-table kernels,
    it dispatches the kind's batched kernel (fused RMI with a
    ``(table, q_tile)`` grid; batched lane-wide k-ary otherwise), so a
    whole tier/batch is one ``pallas_call``.  Callers own the valid-count
    clamp and any rank rebasing, exactly as with ``vmap``'d
    ``lookup_impl`` — see ``BatchedIndexes.lookup`` and the sharded
    tier's fallback path.
    """
    from . import impls

    return impls.query_impl(index.kind).pallas_batched(index, tables, queries)


def count_trace(kind: str, backend: str) -> None:
    """Record one trace of a shared query path (python side effect: call
    it from *inside* a jitted function so it fires once per trace)."""
    _TRACE_COUNTS[(kind, backend)] += 1


@partial(jax.jit, static_argnames=("backend",))
def _lookup_jit(index: Index, table, queries, backend: str):
    count_trace(index.kind, backend)  # python side effect: runs per trace
    return lookup_impl(index, table, queries, backend)


def build(kind_or_spec, table_np, **params) -> Index:
    """Build an :class:`Index` from a spec (or kind string + params)."""
    from . import registry
    from .specs import IndexSpec

    if isinstance(kind_or_spec, IndexSpec):
        spec = kind_or_spec
    else:
        spec = registry.spec_for(str(kind_or_spec), **params)
    return registry.entry(spec.kind).build(spec, table_np)
