"""GAPPED — the updatable learned index kind (ALEX-style gapped arrays
plus a delta-merge buffer), registered like every static kind.

Encoding (all flat array leaves, one registered pytree):

* ``keys``   — ``(n_leaves, leaf_cap)`` uint64 rows.  Row ``l`` holds its
  leaf's ``counts[l]`` live keys sorted in a *valid prefix*; the unused
  tail is the strictly-increasing pad-with-continuation idiom the static
  kinds already use for stacking (last key + 1, + 2, ... saturating at
  the max-key sentinel).  The gaps are the insertion slots.
* ``counts`` / ``fences`` / ``route`` — per-leaf occupancy, per-leaf
  first key, and the routing array ``fences[1:]`` padded with max-key.
* ``delta`` / ``delta_count`` — a small sorted overflow buffer (max-key
  padded valid prefix) merged into every lookup.
* root model — one monotone linear model on the normalised key
  (``root_slope``/``root_icept``/``kmin``/``inv_span``) predicts the
  owning leaf; ``root_eps`` is its measured error bound, re-measured
  (not refitted) device-side at compaction.

Read path (two-tier): route the query to its leaf, bounded-search the
leaf's valid prefix, add the leaf's global offset -> the query's rank in
the main tier; bounded-search the delta prefix -> its rank in the delta.
The main and delta key sets are disjoint (inserts dedupe), so the
predecessor in the merged set is the *sum of the two upper bounds* minus
one — the rank-space form of "take the max of the two per-tier
predecessor keys".  ``NO_PRED`` (-1) falls out exactly as in the static
kinds, and the tier keeps mapping capacity drops to ``DROPPED``.

Because the index owns its keys, lookups answer from the leaves + delta
and *ignore the table argument* — after ``insert_batch`` the build table
is a stale snapshot.  Backends: ``xla`` (branch-free), ``bbs``
(early-exit epilogue), ``ref`` (materialise + searchsorted oracle).
There is deliberately **no pallas claim yet** — the per-kind
``QueryImpl.backends`` tuple keeps docs/backends.md and the R4 analyzer
probe honest.

The max-key value ``2**64 - 1`` is reserved as the pad/route sentinel
and cannot be stored as a live key.
"""

from __future__ import annotations


import numpy as np
import jax
import jax.numpy as jnp

from repro.obs.timing import stopwatch
from repro.core import search
from repro.core.cdf import POS_DTYPE

from . import impls, mutation
from .impls import _MAXKEY, _bucket_steps, _pow2ceil, _scalar, QueryImpl
from .index import Index, count_trace
from .specs import GappedSpec


# ---------------------------------------------------------------------------
# Routing + two-tier read path
# ---------------------------------------------------------------------------


def _route(index: Index, q):
    """Model-guided owner leaf: root prediction, then a bounded search of
    the ``route`` fences within the measured ±``root_eps`` window."""
    a = index.arrays
    L = a["route"].shape[0]
    u = jnp.clip((q.astype(jnp.float64) - a["kmin"]) * a["inv_span"], 0.0, 1.0)
    pred = jnp.clip(jnp.floor(a["root_slope"] * u + a["root_icept"]), -4.0e15, 4.0e15)
    pred = jnp.clip(pred.astype(POS_DTYPE), 0, L - 1)
    eps = a["root_eps"]
    lo = jnp.clip(pred - eps, 0, L - 1)
    hi = jnp.clip(pred + eps, 0, L - 1)
    ub = search.bounded_upper_bound(a["route"], q, lo, hi - lo + 1, steps=index.s("ksteps"))
    return jnp.clip(ub, 0, L - 1)


def _main_ub(index: Index, q, *, branchy: bool):
    """Number of live main-tier keys ``<= q`` (global rank upper bound)."""
    a = index.arrays
    keys = a["keys"]
    L, cap = keys.shape
    counts = a["counts"]
    owner = _route(index, q)
    base = owner * cap
    cnt = jnp.take(counts, owner)
    flat = keys.reshape(-1)
    if branchy:
        ub_in = search.bounded_upper_bound_branchy(flat, q, base, cnt)
    else:
        ub_in = search.bounded_upper_bound(flat, q, base, cnt, steps=index.s("epi")) - base
    offsets = jnp.cumsum(counts) - counts
    return jnp.take(offsets, owner) + ub_in


def _delta_ub(index: Index, q, *, branchy: bool):
    """Number of delta-buffer keys ``<= q``."""
    a = index.arrays
    zero = jnp.zeros(q.shape, dtype=jnp.int64)
    cnt = jnp.broadcast_to(a["delta_count"], q.shape)
    if branchy:
        return search.bounded_upper_bound_branchy(a["delta"], q, zero, cnt)
    return search.bounded_upper_bound(a["delta"], q, zero, cnt, steps=index.s("epi"))


def _materialize(index: Index):
    """(sorted merged keys padded with max-key, live total) — traceable."""
    a = index.arrays
    keys = a["keys"]
    cap = keys.shape[1]
    pos = jnp.arange(cap)
    flat = jnp.where(pos[None, :] < a["counts"][:, None], keys, _MAXKEY).reshape(-1)
    dc = a["delta_count"]
    dvals = jnp.where(jnp.arange(a["delta"].shape[0]) < dc, a["delta"], _MAXKEY)
    merged = jnp.sort(jnp.concatenate([flat, dvals]))
    return merged, jnp.sum(a["counts"]) + dc


def live_keys(index: Index) -> np.ndarray:
    """Host-side sorted live key set (main tier + delta merged)."""
    merged, total = jax.jit(_materialize)(index)
    return np.asarray(merged)[: int(total)]


def _gapped_lookup(index: Index, table, q, backend: str):
    if backend == "ref":
        merged, total = _materialize(index)
        ub = jnp.minimum(jnp.searchsorted(merged, q, side="right"), total)
        return (ub - 1).astype(POS_DTYPE)
    branchy = backend == "bbs"
    ub = _main_ub(index, q, branchy=branchy) + _delta_ub(index, q, branchy=branchy)
    return (ub - 1).astype(POS_DTYPE)


def _gapped_intervals(index: Index, table, q):
    # the two-tier merge is exact, so the "interval" is the answer itself
    r = _main_ub(index, q, branchy=False) + _delta_ub(index, q, branchy=False) - 1
    return r, r


def _gapped_space(index: Index) -> int:
    a = index.arrays
    live = int(np.asarray(jnp.sum(a["counts"]))) + int(np.asarray(a["delta_count"]))
    meta = sum(
        a[k].nbytes
        for k in (
            "counts",
            "fences",
            "route",
            "delta_count",
            "kmin",
            "inv_span",
            "root_slope",
            "root_icept",
            "root_eps",
        )
    )
    return live * a["keys"].dtype.itemsize + meta


GAPPED_IMPL = QueryImpl(
    intervals=_gapped_intervals,
    space_bytes=_gapped_space,
    lookup=_gapped_lookup,
    backends=("xla", "bbs", "ref"),
)


# ---------------------------------------------------------------------------
# Build
# ---------------------------------------------------------------------------


def _build_gapped_index(spec: GappedSpec, table_np: np.ndarray) -> Index:
    sw = stopwatch()
    table = np.asarray(table_np, dtype=np.uint64)
    n = int(table.shape[0])
    if n == 0:
        raise ValueError("GAPPED requires a non-empty table")
    cap = int(spec.leaf_cap)
    per = max(1, min(cap, int(round(cap * float(spec.fill)))))
    L = _pow2ceil(-(-n // per))
    dcap = _pow2ceil(int(spec.delta_cap))

    base, rem = divmod(n, L)
    counts = (base + (np.arange(L) < rem)).astype(np.int64)
    bounds = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    fences = table[np.minimum(bounds[:-1], n - 1)]
    route = np.concatenate([fences[1:], [_MAXKEY]]).astype(np.uint64)

    pos = np.arange(cap)
    valid = pos[None, :] < counts[:, None]
    vals = table[np.minimum(bounds[:-1, None] + pos[None, :], n - 1)]
    last = table[np.minimum(np.maximum(bounds[1:] - 1, 0), n - 1)]
    lastv = np.where(counts > 0, last, fences).astype(np.uint64)
    over = np.maximum(pos[None, :] - counts[:, None] + 1, 0).astype(np.uint64)
    pad = lastv[:, None] + np.minimum(over, (_MAXKEY - lastv)[:, None])
    rows = np.where(valid, vals, pad).astype(np.uint64)

    # root model: least-squares leaf id over the normalised fence key,
    # slope clamped monotone so the measured ε bounds *every* query
    kmin = np.float64(table[0])
    span = np.float64(table[-1]) - kmin
    inv_span = np.float64(1.0 / span) if span > 0 else np.float64(0.0)
    uf = np.clip((fences.astype(np.float64) - kmin) * inv_span, 0.0, 1.0)
    lids = np.arange(L, dtype=np.float64)
    var = float(np.mean((uf - uf.mean()) ** 2))
    slope = float(np.mean((uf - uf.mean()) * (lids - lids.mean())) / var) if var > 0 else 0.0
    slope = max(slope, 0.0)
    icept = float(lids.mean() - slope * uf.mean())
    pred = np.clip(np.floor(slope * uf + icept), 0, L - 1).astype(np.int64)
    eps = int(np.max(np.abs(pred - np.arange(L)))) + 2

    arrays = {
        "keys": jnp.asarray(rows),
        "counts": jnp.asarray(counts),
        "fences": jnp.asarray(fences),
        "route": jnp.asarray(route),
        "delta": jnp.full((dcap,), _MAXKEY, dtype=jnp.uint64),
        "delta_count": _scalar(0, jnp.int64),
        "kmin": _scalar(kmin, jnp.float64),
        "inv_span": _scalar(inv_span, jnp.float64),
        "root_slope": _scalar(slope, jnp.float64),
        "root_icept": _scalar(icept, jnp.float64),
        "root_eps": _scalar(eps, jnp.int64),
    }
    static = (("epi", _bucket_steps(max(cap, dcap))), ("ksteps", _bucket_steps(L)))
    info = {
        "name": f"GAPPED(cap={cap},fill={spec.fill},delta={dcap})",
        "build_time": sw.elapsed,
        "n": n,
        "n_leaves": L,
        "leaf_cap": cap,
        "delta_cap": dcap,
        "root_eps": eps,
    }
    return Index(spec.kind, static, arrays, info)


# ---------------------------------------------------------------------------
# Mutation: insert_batch (absorb -> overflow) and compact (delta -> leaves)
# ---------------------------------------------------------------------------


@jax.jit
def _insert_jit(index: Index, batch, bcount):
    """One insert step: dedupe the sorted batch against itself and the
    index, absorb per-leaf where gaps suffice (all-or-nothing per leaf),
    divert the rest to the delta.  Touches at most ``len(batch)`` leaf
    rows — cost is O(batch · leaf_cap), independent of the table size."""
    count_trace("GAPPED", "insert")
    a = index.arrays
    keys = a["keys"]
    L, cap = keys.shape
    counts = a["counts"]
    delta = a["delta"]
    dcap = delta.shape[0]
    dc = a["delta_count"]
    Bp = batch.shape[0]

    b = jnp.sort(batch)  # max-key pads sort to the tail
    i = jnp.arange(Bp)
    in_batch = i < bcount
    dup_adj = jnp.concatenate([jnp.zeros((1,), bool), b[1:] == b[:-1]])

    flat = keys.reshape(-1)
    owner = _route(index, b)
    base = owner * cap
    cnt = jnp.take(counts, owner)
    ub_in = search.bounded_upper_bound(flat, b, base, cnt, steps=index.s("epi")) - base
    hit_main = (ub_in > 0) & (jnp.take(flat, base + ub_in - 1, mode="clip") == b)
    zero = jnp.zeros(b.shape, dtype=jnp.int64)
    ub_d = search.bounded_upper_bound(
        delta, b, zero, jnp.broadcast_to(dc, b.shape), steps=index.s("epi")
    )
    hit_delta = (ub_d > 0) & (jnp.take(delta, ub_d - 1, mode="clip") == b)

    fresh = in_batch & ~dup_adj & ~hit_main & ~hit_delta
    hist = jax.ops.segment_sum(fresh.astype(jnp.int64), owner, num_segments=L)
    absorb_leaf = hist <= (cap - counts)
    to_main = fresh & jnp.take(absorb_leaf, owner)
    to_delta = fresh & ~jnp.take(absorb_leaf, owner)

    # -- absorb: merge only the touched leaf rows (<= Bp of them) --------
    touched = absorb_leaf & (hist > 0)
    aff = jnp.nonzero(touched, size=Bp, fill_value=L)[0]  # sorted ascending
    aff_c = jnp.minimum(aff, L - 1)
    arows = jnp.take(keys, aff_c, axis=0)
    acnt = jnp.take(counts, aff_c)
    pos = jnp.arange(cap)
    arows_masked = jnp.where(pos[None, :] < acnt[:, None], arows, _MAXKEY)
    slot = jnp.searchsorted(aff, owner)  # row of each key's leaf in aff
    newmat = jnp.full((Bp, Bp), _MAXKEY, dtype=jnp.uint64)
    newmat = newmat.at[slot, i].set(jnp.where(to_main, b, _MAXKEY), mode="drop")
    merged = jnp.sort(jnp.concatenate([arows_masked, newmat], axis=1), axis=1)[:, :cap]
    new_acnt = acnt + jnp.take(hist, aff_c)
    last = jnp.take_along_axis(merged, jnp.clip(new_acnt - 1, 0, cap - 1)[:, None], axis=1)[:, 0]
    lastv = jnp.where(new_acnt > 0, last, jnp.take(a["fences"], aff_c))
    over = jnp.clip(pos[None, :] - new_acnt[:, None] + 1, 0, None).astype(jnp.uint64)
    pad = lastv[:, None] + jnp.minimum(over, (_MAXKEY - lastv)[:, None])
    newrows = jnp.where(pos[None, :] < new_acnt[:, None], merged, pad)
    new_keys = keys.at[aff].set(newrows, mode="drop")
    new_counts = counts + jnp.where(absorb_leaf, hist, 0)

    # -- overflow: merge diverted keys into the sorted delta prefix ------
    dvals = jnp.where(jnp.arange(dcap) < dc, delta, _MAXKEY)
    dnew = jnp.where(to_delta, b, _MAXKEY)
    new_dc = dc + jnp.sum(to_delta)
    new_delta = jnp.sort(jnp.concatenate([dvals, dnew]))[:dcap]
    ok = new_dc <= dcap

    # fences[0] tracks the live minimum (metadata; routing uses route)
    first = jnp.where(bcount > 0, jnp.minimum(a["fences"][0], b[0]), a["fences"][0])
    new_fences = a["fences"].at[0].set(first)

    arrays = dict(a)
    arrays.update(
        keys=new_keys,
        counts=new_counts,
        fences=new_fences,
        delta=new_delta,
        delta_count=new_dc,
    )
    stats = {
        "absorbed": jnp.sum(to_main),
        "overflowed": jnp.sum(to_delta),
        "duplicates": jnp.sum(in_batch & (dup_adj | hit_main | hit_delta)),
        "new_dc": new_dc,
        "ok": ok,
    }
    return Index(index.kind, index.static, arrays), stats


@jax.jit
def _compact_jit(index: Index):
    """Fold delta into rebalanced leaves: one device-side sort + gather.
    Re-measures ``root_eps`` against the new fences with the query path's
    exact arithmetic; the root model itself is not refitted."""
    count_trace("GAPPED", "compact")
    a = index.arrays
    keys = a["keys"]
    L, cap = keys.shape
    counts = a["counts"]
    dcap = a["delta"].shape[0]
    dc = a["delta_count"]
    N = L * cap + dcap

    pos = jnp.arange(cap)
    flat = jnp.where(pos[None, :] < counts[:, None], keys, _MAXKEY).reshape(-1)
    dvals = jnp.where(jnp.arange(dcap) < dc, a["delta"], _MAXKEY)
    merged = jnp.sort(jnp.concatenate([flat, dvals]))
    total = jnp.sum(counts) + dc
    ok = total <= L * cap

    ncnt = total // L + (jnp.arange(L) < total % L)
    gstart = jnp.cumsum(ncnt) - ncnt
    vals = jnp.take(merged, gstart[:, None] + pos[None, :], mode="clip")
    last = jnp.take(merged, jnp.clip(gstart + ncnt - 1, 0, N - 1))
    over = jnp.clip(pos[None, :] - ncnt[:, None] + 1, 0, None).astype(jnp.uint64)
    pad = last[:, None] + jnp.minimum(over, (_MAXKEY - last)[:, None])
    nkeys = jnp.where(pos[None, :] < ncnt[:, None], vals, pad)
    nfences = nkeys[:, 0]
    nroute = jnp.concatenate([nfences[1:], jnp.full((1,), _MAXKEY, dtype=jnp.uint64)])

    uf = jnp.clip((nfences.astype(jnp.float64) - a["kmin"]) * a["inv_span"], 0.0, 1.0)
    pred = jnp.clip(jnp.floor(a["root_slope"] * uf + a["root_icept"]), -4.0e15, 4.0e15)
    pred = jnp.clip(pred.astype(POS_DTYPE), 0, L - 1)
    neps = jnp.max(jnp.abs(pred - jnp.arange(L))) + 2

    arrays = dict(a)
    arrays.update(
        keys=nkeys,
        counts=ncnt,
        fences=nfences,
        route=nroute,
        delta=jnp.full((dcap,), _MAXKEY, dtype=jnp.uint64),
        delta_count=jnp.zeros((), dtype=jnp.int64),
        root_eps=neps.astype(jnp.int64),
    )
    return Index(index.kind, index.static, arrays), ok


def gapped_compact(index: Index) -> Index:
    new_index, ok = _compact_jit(index)
    if not bool(ok):
        live = int(np.asarray(jnp.sum(index.arrays["counts"]))) + int(
            np.asarray(index.arrays["delta_count"])
        )
        L, cap = index.arrays["keys"].shape
        raise mutation.NeedsRebuild(
            f"GAPPED capacity exhausted: {live} live keys exceed "
            f"{L} leaves x {cap} slots — rebuild with a larger spec"
        )
    return new_index


def gapped_insert_batch(index: Index, insert_keys, *, auto_compact: bool = True):
    arr = np.asarray(insert_keys, dtype=np.uint64).reshape(-1)
    nb = int(arr.size)
    dcap = int(index.arrays["delta"].shape[0])
    if nb == 0:
        dc = int(np.asarray(index.arrays["delta_count"]))
        return index, mutation.InsertReport(0, 0, 0, 0, dc, dcap, False)
    # pow2-bucketed batch padding: one insert trace per batch-size bucket
    batch = np.full(_pow2ceil(nb), _MAXKEY, dtype=np.uint64)
    batch[:nb] = arr
    batch = jnp.asarray(batch)

    compacted = False
    new_index, st = _insert_jit(index, batch, nb)
    if not bool(st["ok"]):
        if not auto_compact:
            raise mutation.NeedsRebuild(
                f"insert_batch would overflow the delta buffer "
                f"({int(st['new_dc'])} > {dcap}) — compact() first or pass "
                "auto_compact=True"
            )
        index = gapped_compact(index)  # raises NeedsRebuild when full
        compacted = True
        new_index, st = _insert_jit(index, batch, nb)
        if not bool(st["ok"]):
            raise mutation.NeedsRebuild(
                f"batch of {nb} overflows the delta buffer (cap {dcap}) even "
                "after compaction — rebuild with a larger spec or split the batch"
            )
    report = mutation.InsertReport(
        requested=nb,
        absorbed=int(st["absorbed"]),
        overflowed=int(st["overflowed"]),
        duplicates=int(st["duplicates"]),
        delta_count=int(st["new_dc"]),
        delta_cap=dcap,
        compacted=compacted,
    )
    return new_index, report


# ---------------------------------------------------------------------------
# Registration — one decorator call enrols GAPPED everywhere (spec_for,
# default_grid, Pareto tuner, stack_indexes, npz save/load), exactly as
# for the static kinds; the mutator registration adds the write path.
# ---------------------------------------------------------------------------

impls.QUERY_IMPLS["gapped"] = GAPPED_IMPL
impls._reg(
    "GAPPED",
    GappedSpec,
    "gapped",
    _build_gapped_index,
    lambda **p: GappedSpec(
        leaf_cap=p.get("leaf_cap", 256),
        fill=p.get("fill", 0.75),
        delta_cap=p.get("delta_cap", 1024),
    ),
)
mutation.register_mutator(
    "GAPPED", mutation.Mutator(insert_batch=gapped_insert_batch, compact=gapped_compact)
)
