"""repro.index — the unified public API for learned static indexes.

Design (the math lives in :mod:`repro.core`; this package owns the
public API):

* **Specs** (:mod:`~repro.index.specs`): one hashable frozen dataclass
  per kind describes *how to build* an index — nothing else.
* **Registry** (:mod:`~repro.index.registry`): kinds register once, in
  the paper's hierarchy order, via a decorator; ``kinds()`` is the only
  source of truth for the kind list.
* **Index** (:mod:`~repro.index.index`): the built artifact — a
  registered JAX pytree whose leaves are the model's flat arrays, so
  indexes can flow through jit/vmap/shard/donate and serialize via
  ``save``/``load`` npz round-trips.
* **Backends**: ``lookup(table, queries, backend="xla"|"bbs"|"pallas"|
  "ref")`` — one shared jitted query path per kind; the Pallas fast
  path's f32/i32 re-encoding is folded into build.  Batched/tier
  lookups dispatch
  through :func:`batched_pallas_impl` to the fused ``(table, q_tile)``-
  grid kernels — RMI, PGM and RS families each answer a whole batch
  with ONE ``pallas_call``; the model-free kinds use the batched k-ary
  kernel.

Quick start::

    from repro.index import Index, RMISpec, build
    idx = build(RMISpec(b=2048), table)     # or build("RMI", table, b=2048)
    ranks = idx.lookup(table, queries)      # shared jit: no per-model trace
    idx.save("rmi.npz"); idx2 = Index.load("rmi.npz")
"""

from .index import (
    BACKENDS,
    Index,
    batched_pallas_impl,
    build,
    count_trace,
    lookup_impl,
    reset_trace_counts,
    trace_counts,
)
from .mutation import InsertReport, NeedsRebuild, updatable_kinds
from .registry import entry, kinds, spec_for
from .specs import (
    AtomicSpec,
    BTreeSpec,
    GappedSpec,
    IndexSpec,
    KOSpec,
    PGMBicriteriaSpec,
    PGMSpec,
    RMISpec,
    RSSpec,
    SYRMISpec,
)
from . import impls as _impls  # noqa: F401  — populates the registry
from . import updatable as _updatable  # noqa: F401  — registers GAPPED

__all__ = [
    "BACKENDS",
    "Index",
    "batched_pallas_impl",
    "build",
    "count_trace",
    "lookup_impl",
    "trace_counts",
    "reset_trace_counts",
    "entry",
    "kinds",
    "spec_for",
    "IndexSpec",
    "AtomicSpec",
    "KOSpec",
    "RMISpec",
    "SYRMISpec",
    "PGMSpec",
    "PGMBicriteriaSpec",
    "RSSpec",
    "BTreeSpec",
    "GappedSpec",
    "InsertReport",
    "NeedsRebuild",
    "updatable_kinds",
]
