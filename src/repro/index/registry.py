"""Decorator-based kind registry: one decorator per index kind.

Each index kind registers once, in the paper's hierarchy order, binding:

* ``spec_cls``   — the hashable :class:`~repro.index.specs.IndexSpec`
* ``build``      — ``build(spec, table_np) -> Index``
* ``query_key``  — which shared query implementation the kind uses
  (L/Q/C share ``atomic``; PGM_M produces a ``PGM``-shaped index, so the
  two share one jitted query path)

``kinds()`` enumerates registered kinds in registration order, which is
the paper's order and is the only source of truth for the kind list.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Type

from .specs import IndexSpec


@dataclass
class KindEntry:
    kind: str
    spec_cls: Type[IndexSpec]
    build: Callable  # (spec, table_np) -> Index
    query_key: str  # key into kinds.QUERY_IMPLS
    spec_from_params: Callable  # (**params) -> spec


_REGISTRY: Dict[str, KindEntry] = {}


def register(kind: str, spec_cls: Type[IndexSpec], *, query_key: str, spec_from_params=None):
    """Class/function decorator registering a build function for ``kind``."""

    def deco(build_fn):
        if kind in _REGISTRY:
            raise ValueError(f"index kind {kind!r} registered twice")
        _REGISTRY[kind] = KindEntry(
            kind=kind,
            spec_cls=spec_cls,
            build=build_fn,
            query_key=query_key,
            spec_from_params=spec_from_params or (lambda **p: spec_cls(**p)),
        )
        return build_fn

    return deco


def kinds() -> tuple:
    """Registered kinds, in the paper's hierarchy order."""
    return tuple(_REGISTRY)


def entry(kind: str) -> KindEntry:
    kind = kind.upper()
    if kind not in _REGISTRY:
        raise ValueError(f"unknown index kind {kind!r}; choose from {kinds()}")
    return _REGISTRY[kind]


def spec_for(kind: str, **params) -> IndexSpec:
    """Build the kind's spec from loose kwargs (legacy entry-point shim)."""
    return entry(kind).spec_from_params(**params)
