"""Model zoo: the 10 assigned architectures on shared substrates."""

from . import dimenet, embedding, layers, moe, recsys, transformer
