"""DimeNet (directional message passing) — arXiv:2003.03123.

Kernel regime: triplet gather (kernel_taxonomy §GNN) — messages live on
*directed edges* and interact over (k->j->i) triplets with radial (RBF)
and angular (SBF) bases.  Message passing is built on
``jax.ops.segment_sum`` over edge/triplet index lists (JAX has no sparse
message-passing primitive — this IS part of the system).

Faithful pieces: embedding block, ``n_blocks`` interaction blocks with
the bilinear triplet contraction (n_bilinear), per-block output blocks,
Bessel RBF with polynomial envelope.  Documented adaptation (DESIGN.md
§4): the angular basis uses cos(l·θ) x Bessel products instead of full
spherical harmonics, and non-molecular graphs (Cora/Reddit/ogbn-
products) synthesise positions from random feature projections with
triplets capped at ``t_max`` per edge.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L


@dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    envelope_p: int = 6
    n_species: int = 95  # atom-type vocabulary (molecule cells)
    d_feat: int = 0  # >0: project raw features instead of species embed
    n_out: int = 1  # 1 = energy regression; >1 = node classification
    n_graphs: int = 0  # >0: batched-small-graphs (molecule) readout
    # triplet layout: "flat" (T,) index lists (baseline) or "padded"
    # (E, t_max) rows + mask — §Perf iteration B: aligns every triplet
    # with the shard of its target edge, so the interaction needs ONE
    # explicit bf16 all-gather of messages instead of SPMD-inserted f32
    # all-gathers per gather op, and the per-edge aggregation is a local
    # masked row-sum (no segment_sum, no psum).
    triplet_layout: str = "flat"
    t_max: int = 4
    dtype: str = "float32"

    @property
    def n_sbf(self) -> int:
        return self.n_spherical * self.n_radial


def _envelope(d, cutoff, p):
    """DimeNet polynomial envelope u(d) (smooth cutoff)."""
    x = d / cutoff
    a = -(p + 1) * (p + 2) / 2
    b = p * (p + 2)
    c = -p * (p + 1) / 2
    env = 1.0 / jnp.maximum(x, 1e-9) + a * x ** (p - 1) + b * x**p + c * x ** (p + 1)
    return jnp.where(x < 1.0, env, 0.0)


def rbf_basis(d, cfg: DimeNetConfig):
    """Bessel radial basis: (E, n_radial)."""
    n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    env = _envelope(d, cfg.cutoff, cfg.envelope_p)
    return env[:, None] * jnp.sin(n[None, :] * jnp.pi * d[:, None] / cfg.cutoff)


def sbf_basis(d_kj, angle, cfg: DimeNetConfig):
    """Angular x radial basis: (T, n_spherical * n_radial)."""
    n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    l = jnp.arange(cfg.n_spherical, dtype=jnp.float32)
    env = _envelope(d_kj, cfg.cutoff, cfg.envelope_p)
    radial = env[:, None] * jnp.sin(n[None, :] * jnp.pi * d_kj[:, None] / cfg.cutoff)
    angular = jnp.cos(l[None, :] * angle[:, None])  # (T, n_spherical)
    return (angular[:, :, None] * radial[:, None, :]).reshape(d_kj.shape[0], -1)


def _dense(key, i, o, dt):
    return L.dense_init(key, (i, o), dt)


def init(rng, cfg: DimeNetConfig):
    dt = L.dtype_of(cfg.dtype)
    d = cfg.d_hidden
    k = jax.random.split(rng, 8 + cfg.n_blocks)
    params = {
        "embed_z": L.embed_init(k[0], (cfg.n_species, d), dt)
        if cfg.d_feat == 0
        else _dense(k[0], cfg.d_feat, d, dt),
        "emb_rbf": _dense(k[1], cfg.n_radial, d, dt),
        "emb_msg": _dense(k[2], 3 * d, d, dt),
        "out_final": _dense(k[3], d, cfg.n_out, dt),
        "blocks": [],
    }
    for i in range(cfg.n_blocks):
        bk = jax.random.split(k[4 + i], 10)
        params["blocks"].append(
            {
                "w_msg": _dense(bk[0], d, d, dt),
                "w_kj": _dense(bk[1], d, d, dt),
                "w_sbf": _dense(bk[2], cfg.n_sbf, cfg.n_bilinear, dt),
                "w_bil": (
                    jax.random.normal(bk[3], (cfg.n_bilinear, d, d), jnp.float32) * 0.01
                ).astype(dt),
                "w_rbf_g": _dense(bk[4], cfg.n_radial, d, dt),
                "w_up": _dense(bk[5], d, d, dt),
                "w_res1": _dense(bk[6], d, d, dt),
                "w_res2": _dense(bk[7], d, d, dt),
                "w_out_rbf": _dense(bk[8], cfg.n_radial, d, dt),
                "w_out": _dense(bk[9], d, d, dt),
            }
        )
    return params


def synth_positions(feat_or_n, seed: int = 0):
    """Positions for non-molecular graphs: random 3-D projection of
    features (or random coords when only a node count is given)."""
    rng = np.random.default_rng(seed)
    if isinstance(feat_or_n, int):
        return rng.normal(0, 2.0, size=(feat_or_n, 3)).astype(np.float32)
    feat = np.asarray(feat_or_n)
    proj = rng.normal(0, 1.0 / np.sqrt(feat.shape[1]), size=(feat.shape[1], 3))
    return (feat @ proj).astype(np.float32)


def build_triplets_padded(src: np.ndarray, dst: np.ndarray, n_nodes: int, t_max: int = 4):
    """Padded (E, t_max) triplet rows: row ji holds up to t_max incoming
    edges k->j of its source node j (k != i), plus a validity mask."""
    e = len(src)
    order = np.argsort(dst, kind="stable")
    start = np.searchsorted(dst[order], np.arange(n_nodes + 1))
    tri = np.zeros((e, t_max), dtype=np.int32)
    mask = np.zeros((e, t_max), dtype=np.float32)
    for ji in range(e):
        j = src[ji]
        lo, hi = start[j], start[j + 1]
        t = 0
        for p in range(lo, hi):
            if t >= t_max:
                break
            kj = order[p]
            if src[kj] != dst[ji]:
                tri[ji, t] = kj
                mask[ji, t] = 1.0
                t += 1
    return tri, mask


def build_triplets(src: np.ndarray, dst: np.ndarray, n_nodes: int, t_max: int = 4):
    """Triplet index lists (edge_kj -> edge_ji sharing node j), capped at
    ``t_max`` incoming edges per target edge (DESIGN.md §4 adaptation)."""
    e = len(src)
    order = np.argsort(dst, kind="stable")
    by_dst_start = np.searchsorted(dst[order], np.arange(n_nodes + 1))
    tri_kj, tri_ji = [], []
    for ji in range(e):
        j = src[ji]
        lo, hi = by_dst_start[j], by_dst_start[j + 1]
        take = min(t_max, hi - lo)
        for t in range(take):
            kj = order[lo + t]
            if dst[kj] == j and src[kj] != dst[ji]:  # k != i
                tri_kj.append(kj)
                tri_ji.append(ji)
    if not tri_kj:
        tri_kj, tri_ji = [0], [0]
    return np.asarray(tri_kj, dtype=np.int32), np.asarray(tri_ji, dtype=np.int32)


def _edge_axes(ctx):
    ax = ctx.rules.get("edge")
    return tuple(ax) if ax else ()


def _padded_geometry(vec, tri_kj, cfg: DimeNetConfig, ctx):
    """sbf (E_loc rows): one explicit bf16 all-gather of edge vectors,
    then fully local gathers/angles."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes = _edge_axes(ctx)
    spec = axes if len(axes) > 1 else (axes[0] if axes else None)

    def block(vec_loc, tri_loc):
        vg = vec_loc.astype(jnp.bfloat16)
        if axes:
            vg = lax.all_gather(vg, axes, axis=0, tiled=True)
        v_kj = -jnp.take(vg, tri_loc, axis=0).astype(jnp.float32)  # (E_loc, t, 3)
        v_ji = vec_loc.astype(jnp.float32)[:, None, :]
        cos = jnp.sum(v_ji * v_kj, -1) / (
            jnp.linalg.norm(v_ji, axis=-1) * jnp.linalg.norm(v_kj, axis=-1) + 1e-9
        )
        ang = jnp.arccos(jnp.clip(cos, -1.0, 1.0))  # (E_loc, t)
        d_kj = jnp.linalg.norm(v_kj, axis=-1)
        e, t = ang.shape
        return sbf_basis(d_kj.reshape(-1), ang.reshape(-1), cfg).reshape(e, t, -1)

    if not axes:
        return block(vec, tri_kj)
    return shard_map(
        block,
        mesh=ctx.mesh,
        in_specs=(P(spec, None), P(spec, None)),
        out_specs=P(spec, None, None),
        check_rep=False,
    )(vec, tri_kj)


def _padded_interaction(m, sbf, tri_kj, blk, cfg: DimeNetConfig, ctx):
    """Per-edge triplet aggregation: ONE bf16 all-gather of messages,
    local gathers, masked row-sum — no segment_sum, no psum."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dt = m.dtype
    axes = _edge_axes(ctx)
    spec = axes if len(axes) > 1 else (axes[0] if axes else None)
    w_kj = blk["w_kj"].astype(dt)
    w_sbf = blk["w_sbf"].astype(dt)
    w_bil = blk["w_bil"].astype(dt)

    def block(m_loc, sbf_loc, tri_loc):
        mg = m_loc.astype(jnp.bfloat16)
        if axes:
            mg = lax.all_gather(mg, axes, axis=0, tiled=True)  # (E, d) bf16
        x_kj = jax.nn.silu(jnp.take(mg, tri_loc, axis=0).astype(dt) @ w_kj)  # (E_loc,t,d)
        a = sbf_loc @ w_sbf  # (E_loc, t, n_bil)
        tri = jnp.einsum("etb,bdf,etf->etd", a, w_bil, x_kj)
        return jnp.sum(tri, axis=1)  # masked via sbf's tri_mask factor

    if not axes:
        return block(m, sbf, tri_kj)
    return shard_map(
        block,
        mesh=ctx.mesh,
        in_specs=(P(spec, None), P(spec, None, None), P(spec, None)),
        out_specs=P(spec, None),
        check_rep=False,
    )(m, sbf, tri_kj)


def forward(params, batch, cfg: DimeNetConfig, ctx):
    """batch: pos (N,3), z (N,) or feat (N,F), edge_src/dst (E,),
    tri_kj/tri_ji (T,), node_graph (N,) -> (n_graphs|N, n_out)."""
    dt = L.dtype_of(cfg.dtype)
    pos = batch["pos"].astype(dt)
    src = batch["edge_src"]
    dst = batch["edge_dst"]
    n_nodes = pos.shape[0]

    if cfg.d_feat:
        h = batch["feat"].astype(dt) @ params["embed_z"].astype(dt)
    else:
        h = jnp.take(params["embed_z"], batch["z"], axis=0).astype(dt)

    vec = jnp.take(pos, dst, axis=0) - jnp.take(pos, src, axis=0)  # (E,3)
    vec = ctx.constrain(vec, "edge", None)
    dist = jnp.sqrt(jnp.sum(vec * vec, axis=-1) + 1e-9)
    rbf = rbf_basis(dist, cfg).astype(dt)  # (E, n_radial)

    padded = cfg.triplet_layout == "padded"
    if padded:
        # geometry via one explicit bf16 all-gather of edge vectors
        sbf = _padded_geometry(vec, batch["tri_kj"], cfg, ctx).astype(dt)
        sbf = sbf * batch["tri_mask"][..., None].astype(dt)  # (E, tmax, n_sbf)
        sbf = ctx.constrain(sbf, "edge", None, None)
    else:
        # angles for triplets k->j->i: between edge_kj and edge_ji
        v_ji = jnp.take(vec, batch["tri_ji"], axis=0)
        v_kj = -jnp.take(vec, batch["tri_kj"], axis=0)
        cosang = jnp.sum(v_ji * v_kj, -1) / (
            jnp.linalg.norm(v_ji, axis=-1) * jnp.linalg.norm(v_kj, axis=-1) + 1e-9
        )
        angle = jnp.arccos(jnp.clip(cosang, -1.0, 1.0))
        d_kj = jnp.take(dist, batch["tri_kj"])
        sbf = sbf_basis(d_kj, angle, cfg).astype(dt)  # (T, n_sbf)
        sbf = ctx.constrain(sbf, "edge", None)

    # embedding block: directed edge messages
    hj = jnp.take(h, src, axis=0)
    hi = jnp.take(h, dst, axis=0)
    m = jax.nn.silu(
        jnp.concatenate([hj, hi, rbf @ params["emb_rbf"].astype(dt)], -1)
        @ params["emb_msg"].astype(dt)
    )  # (E, d)
    if "edge_mask" in batch:  # padded layout: kill pad-edge messages
        m = m * batch["edge_mask"][:, None].astype(dt)
    m = ctx.constrain(m, "edge", None)

    node_out = jnp.zeros((n_nodes, cfg.d_hidden), dt)
    for blk in params["blocks"]:
        if padded:
            agg = _padded_interaction(m, sbf, batch["tri_kj"], blk, cfg, ctx)
        else:
            # triplet interaction with bilinear contraction
            x_kj = jax.nn.silu(jnp.take(m, batch["tri_kj"], axis=0) @ blk["w_kj"].astype(dt))
            a = sbf @ blk["w_sbf"].astype(dt)  # (T, n_bilinear)
            tri = jnp.einsum("tb,bde,te->td", a, blk["w_bil"].astype(dt), x_kj)
            agg = jax.ops.segment_sum(tri, batch["tri_ji"], num_segments=m.shape[0])
        g = rbf @ blk["w_rbf_g"].astype(dt)
        x = jax.nn.silu(m @ blk["w_msg"].astype(dt)) * g + agg @ blk["w_up"].astype(dt)
        x = x + jax.nn.silu(x @ blk["w_res1"].astype(dt)) @ blk["w_res2"].astype(dt)
        m = m + x  # residual edge-message update
        # output block: edges -> nodes
        contrib = (rbf @ blk["w_out_rbf"].astype(dt)) * m
        node_out = node_out + jax.ops.segment_sum(
            contrib, dst, num_segments=n_nodes
        ) @ blk["w_out"].astype(dt)

    out = node_out @ params["out_final"].astype(dt)  # (N, n_out)
    if cfg.n_out == 1 and cfg.n_graphs > 0:  # molecule energy readout
        return jax.ops.segment_sum(
            out[:, 0], batch["node_graph"], num_segments=cfg.n_graphs
        )
    return out


def loss_fn(params, batch, cfg: DimeNetConfig, ctx):
    out = forward(params, batch, cfg, ctx)
    if cfg.n_out == 1:
        err = out.astype(jnp.float32) - batch["target"].astype(jnp.float32)
        return jnp.mean(err * err)
    logits = out.astype(jnp.float32)
    labels = batch["labels"]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    mask = batch.get("label_mask", jnp.ones_like(gold))
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
