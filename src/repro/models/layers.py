"""Shared neural layers (pure-JAX, no flax): params are plain pytrees.

Every layer takes explicit params and a :class:`ShardingCtx`; dtypes are
explicit everywhere (global x64 is enabled for the learned-index core
and must not leak into model compute).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype, std: float = 0.02):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def rope_tables(seq_len: int, head_dim: int, theta: float, dtype=jnp.float32, offset=0):
    """(S, hd/2) cos/sin tables; ``offset`` supports decode positions."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    pos = jnp.arange(seq_len, dtype=jnp.float32) + offset
    ang = pos[:, None] * freqs[None, :]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :].astype(x.dtype)
    s = sin[None, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def swiglu(x, wg, wu, wd):
    g = jnp.einsum("btd,df->btf", x, wg.astype(x.dtype))
    u = jnp.einsum("btd,df->btf", x, wu.astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("btf,fd->btd", h, wd.astype(x.dtype))


def causal_attention(q, k, v, *, q_chunk: int = 1024, ctx=None):
    """Materialisation-bounded causal GQA attention.

    q: (B, S, Hq, hd); k/v: (B, S, Hkv, hd).  Scans over q chunks so the
    live logits tensor is (B, Hq, q_chunk, S) — the XLA fallback path for
    training (the serve path uses the Pallas flash-decode kernel).
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    kk = k  # (B, S, Hkv, hd)
    vv = v

    q_chunk = min(q_chunk, s)
    n_chunks = s // q_chunk if s % q_chunk == 0 else 1
    if s % q_chunk != 0:
        q_chunk = s

    q5 = jnp.moveaxis(q.reshape(b, n_chunks, q_chunk, hkv, group, hd), 1, 0)

    @jax.checkpoint
    def attend_chunk(ci, qc):  # qc: (B, qc, Hkv, g, hd)
        # rematerialised: per-chunk (B, H, qc, S) logits/weights are
        # recomputed in backward, never stacked across chunks
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qc, kk) * scale
        qpos = ci * q_chunk + lax.broadcasted_iota(jnp.int32, (q_chunk, s), 0)
        kpos = lax.broadcasted_iota(jnp.int32, (q_chunk, s), 1)
        mask = (kpos <= qpos)[None, None, None, :, :]
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
        w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
        return jnp.einsum("bkgqs,bskd->bqkgd", w, vv)

    def chunk_fn(ci, qc):
        return ci + 1, attend_chunk(ci, qc)

    _, outs = lax.scan(chunk_fn, 0, q5)
    # outs: (nC, B, qc, Hkv, g, hd) -> (B, S, Hq, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, hq, hd)
    return out


def decode_attention_xla(q, k_cache, v_cache, kv_len):
    """One-token GQA attention over a cache (XLA path; Pallas kernel in
    kernels/decode_attention.py is the TPU fast path).

    q: (B, Hq, hd); caches: (B, Smax, Hkv, hd); kv_len: scalar int.
    """
    b, hq, hd = q.shape
    smax, hkv = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    q4 = q.reshape(b, hkv, group, hd)
    logits = jnp.einsum("bkgd,bskd->bkgs", q4, k_cache) * scale
    pos = lax.broadcasted_iota(jnp.int32, (1, 1, 1, smax), 3)
    logits = jnp.where(pos < kv_len, logits, jnp.finfo(logits.dtype).min)
    w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v_cache)
    return out.reshape(b, hq, hd)


def cross_entropy(logits_f32, labels, *, ctx=None):
    """Token-mean cross entropy; logits may be vocab-sharded (XLA inserts
    the psum for the logsumexp under the sharding constraint)."""
    lse = jax.scipy.special.logsumexp(logits_f32, axis=-1)
    gold = jnp.take_along_axis(logits_f32, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
