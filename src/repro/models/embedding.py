"""Sharded embedding substrate for the recsys architectures.

JAX has no EmbeddingBag or giant-table primitive; this module builds
both from scratch (kernel_taxonomy §RecSys):

* :func:`sharded_lookup` — rows of each table sharded over the whole
  mesh.  Two modes, selectable per config (the §Perf hillclimb target):
    - ``allreduce``: every shard masked-gathers its local rows and the
      partial results are psummed (simple; collective = batch x dim x
      n_fields floats).
    - ``a2a``: requests are bucketed to owner shards via shard_map +
      all_to_all (collective = only the vectors actually needed).
* :class:`LearnedKeyedEmbedding` — the paper's technique on the hottest
  path: raw 64-bit hashed ids are looked up in a *compressed sorted key
  table* via an RMI/PGM learned index instead of allocating dense
  hash-space tables (DESIGN.md §3, integration point 1).
* :func:`embedding_bag` — take + segment_sum (the XLA path; the Pallas
  one-hot-matmul kernel covers the VMEM-resident tier).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax


def embedding_bag(table, ids, seg_ids, num_bags: int, weights=None):
    """EmbeddingBag via take + segment_sum (sum mode)."""
    vecs = jnp.take(table, ids, axis=0)
    if weights is not None:
        vecs = vecs * weights[:, None]
    return jax.ops.segment_sum(vecs, seg_ids, num_segments=num_bags)


def sharded_lookup(table, ids, ctx, mode: str = "allreduce", cap_factor: float = 2.0):
    """ids (B, F) int32 rows into ``table`` (V, D) row-sharded over mesh.

    Returns (B, F, D).  ``allreduce``: local masked gather + psum.
    ``a2a``: shard_map all_to_all exchange of (id -> vector) requests,
    capacity-bounded at ``cap_factor`` x the per-shard average (skewed
    ids beyond capacity are dropped to the zero vector — the standard
    bounded-exchange contract; raise cap_factor for exactness).
    """
    if mode == "allreduce":
        # XLA's SPMD partitioner turns the gather-from-row-sharded into
        # exactly the masked-gather+psum pattern under these constraints.
        table = ctx.constrain(table, "row", None)
        out = jnp.take(table, ids, axis=0)
        return ctx.constrain(out, "dp", None, None)

    if mode == "a2a":
        b = ids.shape[0]
        dp = ctx.n("dp")
        pad = (-b) % dp
        if pad:
            ids = jnp.concatenate([ids, jnp.zeros((pad,) + ids.shape[1:], ids.dtype)])
        out = _a2a_lookup(table, ids, ctx, cap_factor)
        return out[:b] if pad else out
    raise ValueError(mode)


def _a2a_lookup(table, ids, ctx, cap_factor: float = 2.0):
    """Owner-exchange lookup via shard_map over the flattened mesh.

    Each shard owns a contiguous row range.  Every shard sends each of
    its local ids to the owner (all_to_all), owners gather locally and
    the vectors return (second all_to_all).  Collective bytes = the
    vectors actually requested (vs the psum of full batch in allreduce
    mode).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    axes = tuple(mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    v, d = table.shape
    b, f = ids.shape
    rows_per = v // n_shards
    dp_axes = ctx.rules["dp"] or ()

    def block(tab, local_ids):
        from repro.dist import collectives

        # tab: (rows_per, D); local_ids: (B_loc, F)
        flat = local_ids.reshape(-1).astype(jnp.int64)  # (N,)
        n = flat.shape[0]
        owner = jnp.clip(flat // rows_per, 0, n_shards - 1)
        # bucket ids by owner shard into the capacity-bounded request matrix
        cap = collectives.exchange_capacity(n, n_shards, cap_factor)
        req, slots, valid, order = collectives.bucket_by_owner(
            owner, flat, n_shards, cap, jnp.zeros((), flat.dtype)
        )

        # 1st all_to_all: requests travel to their owner shard
        req_x = _all_to_all_flat(req, axes)  # (n_shards, cap) ids this shard owns
        local_rows = jnp.clip(
            req_x - _shard_offset(axes, rows_per), 0, rows_per - 1
        ).astype(jnp.int32)
        vecs = jnp.take(tab, local_rows.reshape(-1), axis=0).reshape(n_shards, cap, d)
        # 2nd all_to_all: vectors travel back to the requesters
        vecs_back = _all_to_all_flat(vecs, axes)

        # scatter vectors back to input order (over-capacity -> zero vector)
        out = collectives.unbucket_inverse(vecs_back, slots, valid, order, n, 0)
        return out.reshape(local_ids.shape[0], f, d)

    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    return shard_map(
        block,
        mesh=mesh,
        in_specs=(P(axes, None), P(dp_spec, None)),
        out_specs=P(dp_spec, None, None),
        check_rep=False,
    )(table, ids)


def _dp_size(ctx):
    return ctx.n("dp")


def _shard_offset(axes, rows_per):
    idx = lax.axis_index(axes)
    return (idx * rows_per).astype(jnp.int64)


def _all_to_all_flat(x, axes):
    """all_to_all over the flattened mesh axes: x (n_shards, ...) swaps
    the leading chunk axis with the shard axis."""
    return lax.all_to_all(x, axes, split_axis=0, concat_axis=0, tiled=True)


@dataclass
class LearnedKeyedEmbedding:
    """Compressed-vocabulary embedding keyed by a learned index.

    Production recsys ids are 64-bit hashes; a dense table over the hash
    space is impossible and hashing-by-modulo collides.  Here the *sorted
    unique key set* (built offline) is searched with the paper's learned
    index to map raw id -> dense row — predecessor search on the hot
    path (the id-translation step).

    Built with ``n_shards > 1`` and a :class:`~repro.dist.ShardingCtx`,
    the key set is partitioned into a :class:`~repro.dist.ShardedIndex`
    tier and id translation runs through the shard_map'd
    :func:`repro.dist.sharded_lookup` (fence-route-answer-return) before
    the vector gather.
    """

    keys: jnp.ndarray  # (V,) uint64 sorted unique raw ids
    table: jnp.ndarray  # (V+1, D) f32 — last row is the OOV vector
    index: object = None  # repro.index.Index over ``keys`` (unsharded tier)
    sharded: object = None  # repro.dist.ShardedIndex tier (n_shards > 1)
    ctx: object = None  # ShardingCtx the tier is laid out on
    cap_factor: float = 0.0  # 0 -> n_shards (exchange can never drop)

    @staticmethod
    def build(
        raw_keys: np.ndarray,
        dim: int,
        seed: int = 0,
        b: int | None = None,
        *,
        kind: str = "RMI",
        ctx=None,
        n_shards: int = 1,
        **params,
    ):
        from repro import index as ix

        keys = np.unique(raw_keys.astype(np.uint64))
        v = len(keys)
        rng = np.random.default_rng(seed)
        table = (rng.normal(0, 0.05, size=(v + 1, dim))).astype(np.float32)
        if kind.upper() == "RMI" and "b" not in params:
            params["b"] = b or max(2, v // 128)
        index = sharded = None
        if n_shards > 1:
            from repro.dist.sharded_index import ShardedIndex

            sharded = ShardedIndex.build(kind, keys, n_shards=n_shards, **params)
        else:
            index = ix.build(kind, keys, **params)
        return LearnedKeyedEmbedding(
            keys=jnp.asarray(keys),
            table=jnp.asarray(table),
            index=index,
            sharded=sharded,
            ctx=ctx,
        )

    def translate(self, raw_ids, *, backend: str = "xla"):
        """Raw 64-bit ids -> predecessor ranks in the sorted key set."""
        qf = jnp.asarray(raw_ids, dtype=jnp.uint64).reshape(-1)
        if self.sharded is not None:
            from repro.dist.sharded_index import sharded_lookup as tier_lookup

            cap = self.cap_factor or float(self.sharded.n_shards)
            return tier_lookup(self.sharded, qf, self.ctx, backend=backend, cap_factor=cap)
        return self.index.lookup(self.keys, qf, backend=backend)

    def lookup(self, raw_ids, *, backend: str = "xla"):
        q = jnp.asarray(raw_ids, dtype=jnp.uint64)
        shape = q.shape
        qf = q.reshape(-1)
        rank = self.translate(qf, backend=backend)
        # misses (no exact key, capacity drops) fall through to OOV
        hit = (rank >= 0) & (jnp.take(self.keys, jnp.maximum(rank, 0)) == qf)
        v = self.table.shape[0] - 1
        row = jnp.where(hit, jnp.maximum(rank, 0), v)  # miss -> OOV row
        out = jnp.take(self.table, row, axis=0)
        return out.reshape(*shape, -1)
