"""Sharded embedding substrate for the recsys architectures.

JAX has no EmbeddingBag or giant-table primitive; this module builds
both from scratch (kernel_taxonomy §RecSys):

* :func:`sharded_lookup` — rows of each table sharded over the whole
  mesh.  Two modes, selectable per config (the §Perf hillclimb target):
    - ``allreduce``: every shard masked-gathers its local rows and the
      partial results are psummed (simple; collective = batch x dim x
      n_fields floats).
    - ``a2a``: requests are bucketed to owner shards via shard_map +
      all_to_all (collective = only the vectors actually needed).
* :class:`LearnedKeyedEmbedding` — the paper's technique on the hottest
  path: raw 64-bit hashed ids are looked up in a *compressed sorted key
  table* via an RMI/PGM learned index instead of allocating dense
  hash-space tables (DESIGN.md §3, integration point 1).
* :func:`embedding_bag` — take + segment_sum (the XLA path; the Pallas
  one-hot-matmul kernel covers the VMEM-resident tier).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.rmi import build_rmi


def embedding_bag(table, ids, seg_ids, num_bags: int, weights=None):
    """EmbeddingBag via take + segment_sum (sum mode)."""
    vecs = jnp.take(table, ids, axis=0)
    if weights is not None:
        vecs = vecs * weights[:, None]
    return jax.ops.segment_sum(vecs, seg_ids, num_segments=num_bags)


def sharded_lookup(table, ids, ctx, mode: str = "allreduce", cap_factor: float = 2.0):
    """ids (B, F) int32 rows into ``table`` (V, D) row-sharded over mesh.

    Returns (B, F, D).  ``allreduce``: local masked gather + psum.
    ``a2a``: shard_map all_to_all exchange of (id -> vector) requests,
    capacity-bounded at ``cap_factor`` x the per-shard average (skewed
    ids beyond capacity are dropped to the zero vector — the standard
    bounded-exchange contract; raise cap_factor for exactness).
    """
    if mode == "allreduce":
        # XLA's SPMD partitioner turns the gather-from-row-sharded into
        # exactly the masked-gather+psum pattern under these constraints.
        table = ctx.constrain(table, "row", None)
        out = jnp.take(table, ids, axis=0)
        return ctx.constrain(out, "dp", None, None)

    if mode == "a2a":
        b = ids.shape[0]
        n_shards = 1
        for a in ctx.mesh.axis_names:
            n_shards *= ctx.mesh.shape[a]
        dp = ctx.n("dp")
        pad = (-b) % dp
        if pad:
            ids = jnp.concatenate([ids, jnp.zeros((pad,) + ids.shape[1:], ids.dtype)])
        out = _a2a_lookup(table, ids, ctx, cap_factor)
        return out[:b] if pad else out
    raise ValueError(mode)


def _a2a_lookup(table, ids, ctx, cap_factor: float = 2.0):
    """Owner-exchange lookup via shard_map over the flattened mesh.

    Each shard owns a contiguous row range.  Every shard sends each of
    its local ids to the owner (all_to_all), owners gather locally and
    the vectors return (second all_to_all).  Collective bytes = the
    vectors actually requested (vs the psum of full batch in allreduce
    mode).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    axes = tuple(mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    v, d = table.shape
    b, f = ids.shape
    rows_per = v // n_shards
    dp_axes = ctx.rules["dp"] or ()

    def block(tab, local_ids):
        from repro.core import search

        # tab: (rows_per, D); local_ids: (B_loc, F)
        flat = local_ids.reshape(-1).astype(jnp.int64)  # (N,)
        n = flat.shape[0]
        owner = jnp.clip(flat // rows_per, 0, n_shards - 1)
        # bucket ids by owner shard: sort + branch-free boundary search
        order = jnp.argsort(owner)
        s_owner = jnp.take(owner, order)
        s_ids = jnp.take(flat, order)
        cap = max(1, int(-(-cap_factor * n // n_shards)))  # capacity-bounded
        shard_q = jnp.arange(n_shards, dtype=s_owner.dtype)
        bounds = search.bfs(s_owner, shard_q - 1) + 1
        ends = search.bfs(s_owner, shard_q) + 1
        slots = bounds[:, None] + lax.broadcasted_iota(jnp.int64, (n_shards, cap), 1)
        valid = slots < ends[:, None]
        req = jnp.where(valid, jnp.take(s_ids, jnp.minimum(slots, n - 1)), 0)

        # 1st all_to_all: requests travel to their owner shard
        req_x = _all_to_all_flat(req, axes)  # (n_shards, cap) ids this shard owns
        local_rows = jnp.clip(
            req_x - _shard_offset(axes, rows_per), 0, rows_per - 1
        ).astype(jnp.int32)
        vecs = jnp.take(tab, local_rows.reshape(-1), axis=0).reshape(n_shards, cap, d)
        # 2nd all_to_all: vectors travel back to the requesters
        vecs_back = _all_to_all_flat(vecs, axes)

        # place vectors at their sorted positions, then unsort
        flat_slots = jnp.minimum(slots, n - 1).reshape(-1)
        sorted_out = jnp.zeros((n, d), tab.dtype)
        sorted_out = sorted_out.at[flat_slots].add(
            vecs_back.reshape(-1, d) * valid.reshape(-1, 1).astype(tab.dtype)
        )
        inv = jnp.argsort(order)
        out = jnp.take(sorted_out, inv, axis=0)
        return out.reshape(local_ids.shape[0], f, d)

    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    return shard_map(
        block,
        mesh=mesh,
        in_specs=(P(axes, None), P(dp_spec, None)),
        out_specs=P(dp_spec, None, None),
        check_rep=False,
    )(table, ids)


def _dp_size(ctx):
    return ctx.n("dp")


def _shard_offset(axes, rows_per):
    idx = lax.axis_index(axes)
    return (idx * rows_per).astype(jnp.int64)


def _all_to_all_flat(x, axes):
    """all_to_all over the flattened mesh axes: x (n_shards, ...) swaps
    the leading chunk axis with the shard axis."""
    return lax.all_to_all(x, axes, split_axis=0, concat_axis=0, tiled=True)


@dataclass
class LearnedKeyedEmbedding:
    """Compressed-vocabulary embedding keyed by a learned index.

    Production recsys ids are 64-bit hashes; a dense table over the hash
    space is impossible and hashing-by-modulo collides.  Here the *sorted
    unique key set* (built offline) is searched with the paper's RMI to
    map raw id -> dense row — predecessor search on the hot path.
    """

    keys: jnp.ndarray  # (V,) uint64 sorted unique raw ids
    table: jnp.ndarray  # (V+1, D) f32 — last row is the OOV vector
    rmi: object

    @staticmethod
    def build(raw_keys: np.ndarray, dim: int, seed: int = 0, b: int | None = None):
        keys = np.unique(raw_keys.astype(np.uint64))
        v = len(keys)
        rng = np.random.default_rng(seed)
        table = (rng.normal(0, 0.05, size=(v + 1, dim))).astype(np.float32)
        rmi = build_rmi(keys, b=b or max(2, v // 128))
        return LearnedKeyedEmbedding(
            keys=jnp.asarray(keys), table=jnp.asarray(table), rmi=rmi
        )

    def lookup(self, raw_ids):
        q = jnp.asarray(raw_ids, dtype=jnp.uint64)
        shape = q.shape
        qf = q.reshape(-1)
        rank = self.rmi.predecessor(self.keys, qf)
        hit = (rank >= 0) & (jnp.take(self.keys, jnp.maximum(rank, 0)) == qf)
        v = self.table.shape[0] - 1
        row = jnp.where(hit, jnp.maximum(rank, 0), v)  # miss -> OOV row
        out = jnp.take(self.table, row, axis=0)
        return out.reshape(*shape, -1)
