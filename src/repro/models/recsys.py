"""RecSys architectures: DLRM (MLPerf), DIN, Wide&Deep, SASRec.

All four share the sharded embedding substrate (embedding.py) — huge
tables row-sharded over the whole mesh, tiny MLPs replicated, batch on
DP.  Entry points per arch:

  init(rng, cfg)                          -> params
  loss_fn(params, batch, cfg, ctx)        -> scalar BCE loss
  score_fn(params, batch, cfg, ctx)       -> (B,) logits  (serve_* cells)
  retrieval_fn(params, batch, cfg, ctx)   -> (n_cand,) logits, user-side
                                             compute hoisted out of the
                                             candidate loop (two-tower-
                                             ised; retrieval_cand cell)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from . import layers as L
from .embedding import sharded_lookup

# MLPerf DLRM (Criteo 1TB) vocabulary sizes, 26 sparse fields
CRITEO_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str  # dlrm | din | wide_deep | sasrec
    embed_dim: int
    vocab_sizes: tuple  # per sparse field (dense tables, row-sharded)
    n_dense: int = 0
    bot_mlp: tuple = ()
    top_mlp: tuple = ()
    attn_mlp: tuple = ()
    seq_len: int = 0
    n_blocks: int = 0
    n_heads: int = 1
    interaction: str = "dot"
    lookup_mode: str = "a2a"  # §Perf iteration-C default; "allreduce" = baseline
    dtype: str = "float32"

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_rows(self) -> int:
        return int(sum(self.vocab_sizes))


def _mlp_init(key, sizes: Sequence[int], dtype):
    params = []
    ks = jax.random.split(key, len(sizes) - 1)
    for i in range(len(sizes) - 1):
        params.append(
            {
                "w": L.dense_init(ks[i], (sizes[i], sizes[i + 1]), dtype),
                "b": jnp.zeros((sizes[i + 1],), dtype),
            }
        )
    return params


def _mlp_apply(params, x, final_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"].astype(x.dtype) + lyr["b"].astype(x.dtype)
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _round_up(v, mult):
    return ((v + mult - 1) // mult) * mult


def init(rng, cfg: RecsysConfig, ctx=None):
    dt = L.dtype_of(cfg.dtype)
    keys = jax.random.split(rng, 8)
    d = cfg.embed_dim
    # one concatenated mega-table: field f's rows live at [offset_f, ...)
    # (single row-sharded array shards far better than 26 ragged ones)
    n_shards = 1
    if ctx is not None:
        for a in ctx.mesh.axis_names:
            n_shards *= ctx.mesh.shape[a]
    total = _round_up(int(sum(cfg.vocab_sizes)), max(n_shards, 1))
    params = {
        "embed": L.embed_init(keys[0], (total, d), dt, std=0.05),
    }
    if cfg.kind == "dlrm":
        n_int = (cfg.n_sparse + 1) * cfg.n_sparse // 2
        top_in = cfg.bot_mlp[-1] + n_int
        params["bot"] = _mlp_init(keys[1], (cfg.n_dense,) + tuple(cfg.bot_mlp), dt)
        params["top"] = _mlp_init(keys[2], (top_in,) + tuple(cfg.top_mlp), dt)
    elif cfg.kind == "din":
        att_in = 4 * d  # [target, hist, target-hist, target*hist]
        params["attn"] = _mlp_init(keys[1], (att_in,) + tuple(cfg.attn_mlp) + (1,), dt)
        mlp_in = 3 * d  # user interest + target + user profile
        params["mlp"] = _mlp_init(keys[2], (mlp_in,) + tuple(cfg.top_mlp) + (1,), dt)
    elif cfg.kind == "wide_deep":
        deep_in = cfg.n_sparse * d
        params["deep"] = _mlp_init(keys[1], (deep_in,) + tuple(cfg.top_mlp) + (1,), dt)
        params["wide"] = L.embed_init(keys[2], (total, 1), dt, std=0.01)
    elif cfg.kind == "sasrec":
        params["pos"] = L.embed_init(keys[1], (cfg.seq_len, d), dt)
        blocks = []
        for i in range(cfg.n_blocks):
            bk = jax.random.fold_in(keys[2], i)
            bks = jax.random.split(bk, 4)
            blocks.append(
                {
                    "ln1": jnp.ones((d,), dt),
                    "ln2": jnp.ones((d,), dt),
                    "wq": L.dense_init(bks[0], (d, d), dt),
                    "wk": L.dense_init(bks[1], (d, d), dt),
                    "wv": L.dense_init(bks[2], (d, d), dt),
                    "w1": L.dense_init(bks[3], (d, d), dt),
                    "w2": L.dense_init(jax.random.fold_in(bk, 9), (d, d), dt),
                }
            )
        params["blocks"] = blocks
        params["ln_f"] = jnp.ones((d,), dt)
    else:
        raise ValueError(cfg.kind)
    return params


def field_offsets(cfg: RecsysConfig) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(np.asarray(cfg.vocab_sizes))[:-1]]).astype(np.int64)


def _lookup(params, sparse_ids, cfg, ctx):
    """sparse_ids (B, F) local ids -> (B, F, D) via the mega-table."""
    offs = jnp.asarray(field_offsets(cfg), dtype=sparse_ids.dtype)
    rows = sparse_ids + offs[None, :]
    return sharded_lookup(params["embed"], rows, ctx, mode=cfg.lookup_mode)


# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------


def _dlrm_features(params, dense, emb, cfg, ctx):
    bot = _mlp_apply(params["bot"], dense, final_act=True)  # (B, D)
    allv = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, F+1, D)
    inter = jnp.einsum("bfd,bgd->bfg", allv, allv)  # (B, F+1, F+1)
    f = allv.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    flat = inter[:, iu, ju]  # (B, F(F+1)/2)... upper triangle, no diag
    return jnp.concatenate([bot, flat], axis=1)


def dlrm_scores(params, batch, cfg, ctx):
    emb = _lookup(params, batch["sparse"], cfg, ctx)
    feats = _dlrm_features(params, batch["dense"], emb, cfg, ctx)
    return _mlp_apply(params["top"], feats)[:, 0]


# ---------------------------------------------------------------------------
# DIN — target attention over user history
# ---------------------------------------------------------------------------


def _din_interest(params, hist, target, cfg):
    # hist (B, S, D); target (B, D)
    b, s, d = hist.shape
    t = jnp.broadcast_to(target[:, None, :], (b, s, d))
    att_in = jnp.concatenate([t, hist, t - hist, t * hist], axis=-1)
    w = _mlp_apply(params["attn"], att_in)[..., 0]  # (B, S) raw weights
    w = jnp.where(jnp.sum(jnp.abs(hist), -1) > 0, w, -1e9)  # mask padding
    w = jax.nn.softmax(w, axis=-1)
    return jnp.einsum("bs,bsd->bd", w, hist)


def din_scores(params, batch, cfg, ctx):
    # fields: [target_item, user_profile] + history
    emb = _lookup(params, batch["sparse"], cfg, ctx)  # (B, 2, D)
    target, profile = emb[:, 0], emb[:, 1]
    offs = jnp.asarray(field_offsets(cfg), dtype=batch["hist"].dtype)
    hist_rows = batch["hist"] + offs[0]  # history shares the item table
    hist = sharded_lookup(params["embed"], hist_rows, ctx, mode=cfg.lookup_mode)
    interest = _din_interest(params, hist, target, cfg)
    x = jnp.concatenate([interest, target, profile], axis=-1)
    return _mlp_apply(params["mlp"], x)[:, 0]


# ---------------------------------------------------------------------------
# Wide & Deep
# ---------------------------------------------------------------------------


def wide_deep_scores(params, batch, cfg, ctx):
    emb = _lookup(params, batch["sparse"], cfg, ctx)  # (B, F, D)
    b = emb.shape[0]
    deep = _mlp_apply(params["deep"], emb.reshape(b, -1))[:, 0]
    offs = jnp.asarray(field_offsets(cfg), dtype=batch["sparse"].dtype)
    rows = batch["sparse"] + offs[None, :]
    wide = sharded_lookup(params["wide"], rows, ctx, mode=cfg.lookup_mode)
    return deep + jnp.sum(wide[..., 0], axis=-1)


# ---------------------------------------------------------------------------
# SASRec — self-attentive sequential recommendation
# ---------------------------------------------------------------------------


def _sasrec_encode(params, seq_rows, cfg, ctx):
    emb = sharded_lookup(params["embed"], seq_rows, ctx, mode=cfg.lookup_mode)
    x = emb + params["pos"].astype(emb.dtype)[None]
    b, s, d = x.shape
    for blk in params["blocks"]:
        h = L.rms_norm(x, blk["ln1"])
        q = (h @ blk["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, d // cfg.n_heads)
        k = (h @ blk["wk"].astype(x.dtype)).reshape(b, s, cfg.n_heads, d // cfg.n_heads)
        v = (h @ blk["wv"].astype(x.dtype)).reshape(b, s, cfg.n_heads, d // cfg.n_heads)
        o = L.causal_attention(q, k, v, q_chunk=s, ctx=ctx).reshape(b, s, d)
        x = x + o
        h = L.rms_norm(x, blk["ln2"])
        x = x + jax.nn.relu(h @ blk["w1"].astype(x.dtype)) @ blk["w2"].astype(x.dtype)
    return L.rms_norm(x, params["ln_f"])


def sasrec_scores(params, batch, cfg, ctx):
    """Score target item against the sequence-final user state."""
    enc = _sasrec_encode(params, batch["seq"], cfg, ctx)  # (B, S, D)
    user = enc[:, -1]  # (B, D)
    target = sharded_lookup(
        params["embed"], batch["target"][:, None], ctx, mode=cfg.lookup_mode
    )[:, 0]
    return jnp.sum(user * target, axis=-1)


# ---------------------------------------------------------------------------
# Unified entry points
# ---------------------------------------------------------------------------

_SCORERS = {
    "dlrm": dlrm_scores,
    "din": din_scores,
    "wide_deep": wide_deep_scores,
    "sasrec": sasrec_scores,
}


def score_fn(params, batch, cfg: RecsysConfig, ctx):
    return _SCORERS[cfg.kind](params, batch, cfg, ctx)


def loss_fn(params, batch, cfg: RecsysConfig, ctx):
    logits = score_fn(params, batch, cfg, ctx)
    y = batch["label"].astype(jnp.float32)
    z = logits.astype(jnp.float32)
    # numerically-stable BCE with logits
    loss = jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    return jnp.mean(loss)


def retrieval_fn(params, batch, cfg: RecsysConfig, ctx):
    """Score 1 user context against n_candidates items, user-side hoisted."""
    cands = batch["candidates"]  # (N,) item ids
    if cfg.kind == "sasrec":
        enc = _sasrec_encode(params, batch["seq"], cfg, ctx)
        user = enc[0, -1]  # (D,)
        cvecs = sharded_lookup(params["embed"], cands[None, :], ctx, cfg.lookup_mode)[0]
        cvecs = ctx.constrain(cvecs, "dp", None)
        return ctx.constrain(cvecs @ user, "dp")
    if cfg.kind == "din":
        hist = sharded_lookup(
            params["embed"], batch["hist"], ctx, mode=cfg.lookup_mode
        )  # (1, S, D)
        profile = sharded_lookup(
            params["embed"], batch["sparse"][:, 1:2], ctx, mode=cfg.lookup_mode
        )[:, 0]
        cvecs = sharded_lookup(params["embed"], cands[None, :], ctx, cfg.lookup_mode)[0]
        cvecs = ctx.constrain(cvecs, "dp", None)

        def score_chunk(tgt):  # vectorised over candidates
            b = tgt.shape[0]
            h = jnp.broadcast_to(hist, (b,) + hist.shape[1:])
            interest = _din_interest(params, h, tgt, cfg)
            p = jnp.broadcast_to(profile, (b, profile.shape[-1]))
            x = ctx.constrain(jnp.concatenate([interest, tgt, p], axis=-1), "dp", None)
            return _mlp_apply(params["mlp"], x)[:, 0]

        return ctx.constrain(score_chunk(cvecs), "dp")
    # dlrm / wide_deep: vary one item field over candidates
    n = cands.shape[0]
    sparse = jnp.broadcast_to(batch["sparse"], (n, cfg.n_sparse)).at[:, 0].set(cands)
    sparse = ctx.constrain(sparse, "dp", None)
    b2 = {"sparse": sparse}
    if cfg.kind == "dlrm":
        b2["dense"] = jnp.broadcast_to(batch["dense"], (n, cfg.n_dense))
    return score_fn(params, b2, cfg, ctx)
