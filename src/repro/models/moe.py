"""Mixture-of-Experts block: expert-parallel shard_map dispatch.

EP design (DESIGN.md §5): activations at block boundaries are replicated
over the ``model`` axis (the TP convention), so each model column routes
the *same* local-token set to its *own* E/ep experts, computes them, and
a psum over ``model`` assembles the block output — no token all-to-all
is needed and the collective cost equals the TP FFN reduction.  Expert
weights are additionally FSDP-sharded over the DP axes and all-gathered
per layer inside the block (manual ZeRO-3).

The capacity dispatch is **sort-based**: flatten (token, k) pairs, sort
by expert id, find each expert's boundary with the paper's branch-free
predecessor search over the sorted expert-id table (DESIGN.md §3,
integration point 2), then slot tokens with pure gathers.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import search


def _dispatch_local(x, gate_w, *, e_loc: int, col, n_experts: int, top_k: int,
                    capacity: int, dtype):
    """Route local tokens to this column's experts.

    x: (T, d) local tokens.  Returns (xe, combine) where
    xe: (E_loc, C, d) dispatched tokens and combine(ye) -> (T, d).
    """
    t, d = x.shape
    logits = jnp.einsum("td,de->te", x, gate_w.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, top_k)  # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1).astype(jnp.int32)  # (T*k,)
    flat_t = (
        lax.broadcasted_iota(jnp.int32, (t, top_k), 0).reshape(-1)
    )
    local = (flat_e >= col * e_loc) & (flat_e < (col + 1) * e_loc)
    # push non-local pairs to the end of the sort with a sentinel
    sort_key = jnp.where(local, flat_e - col * e_loc, n_experts + 1)
    order = jnp.argsort(sort_key)
    s_key = jnp.take(sort_key, order)
    s_tok = jnp.take(flat_t, order)

    # expert boundaries via the paper's branch-free predecessor search
    eq = jnp.arange(e_loc, dtype=jnp.int32)
    bounds = search.bfs(s_key, eq - 1) + 1  # first sorted pos of each local expert
    ends = search.bfs(s_key, eq) + 1

    # slot gather: expert e takes sorted positions [bounds[e], bounds[e]+C)
    slots = bounds[:, None] + lax.broadcasted_iota(jnp.int32, (e_loc, capacity), 1)
    valid = slots < ends[:, None]
    tok_idx = jnp.take(s_tok, jnp.minimum(slots, t * top_k - 1))
    xe = jnp.take(x, tok_idx, axis=0) * valid[..., None].astype(x.dtype)  # (E_loc, C, d)

    # combine indices: position of each (t, k) pair within its expert
    pos_sorted = (
        lax.broadcasted_iota(jnp.int32, (t * top_k,), 0)
        - jnp.take(bounds, jnp.clip(s_key, 0, e_loc - 1))
    )
    inv = jnp.argsort(order)
    pos = jnp.take(pos_sorted, inv)  # (T*k,) position-in-expert
    keep = local & (pos < capacity)
    le = jnp.clip(flat_e - col * e_loc, 0, e_loc - 1)

    def combine(ye):  # ye: (E_loc, C, d)
        flat_pos = jnp.clip(pos, 0, capacity - 1)
        vecs = ye[le, flat_pos]  # (T*k, d) gather
        w = (top_p.reshape(-1).astype(ye.dtype) * keep.astype(ye.dtype))[:, None]
        contrib = (vecs * w).reshape(t, top_k, d)
        return jnp.sum(contrib, axis=1)

    return xe, combine


def moe_ffn(x2d, moe_params, cfg, ctx, *, replicated_tokens: bool = False):
    """x2d: (T, d) replicated over 'model', sharded over DP axes.

    moe_params: {'router': (d, E), 'wg','wu': (E, d, ffe), 'wd': (E, ffe, d)}.
    Returns (T, d).  ``replicated_tokens`` handles tiny decode batches
    (e.g. long_500k with batch=1) that cannot shard over DP.
    """
    mesh = ctx.mesh
    dp_axes = () if replicated_tokens else (ctx.rules["dp"] or ())
    ep_axes = ctx.rules["ep"] or ()
    dp_size = 1 if replicated_tokens else ctx.n("dp")
    ep_size = ctx.n("ep")
    e_loc = cfg.n_experts // ep_size
    t_loc = x2d.shape[0] // dp_size
    capacity = max(1, int(math.ceil(t_loc * cfg.top_k / cfg.n_experts * cfg.capacity_factor)))
    dtype = x2d.dtype

    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    ep_spec = ep_axes[0] if ep_axes else None
    fsdp_axes = ctx.rules["dp"] or ()  # weights stay FSDP-sharded regardless

    def block(x, wr, wg, wu, wd):
        # x: (T_loc, d); wr replicated; w*: (E_loc, d/fsdp, ffe) shards.
        # §Perf iteration A: cast the FSDP shards to the compute dtype
        # BEFORE the all-gather — halves the dominant AG traffic.
        if fsdp_axes:
            wg = lax.all_gather(wg.astype(dtype), fsdp_axes, axis=1, tiled=True)
            wu = lax.all_gather(wu.astype(dtype), fsdp_axes, axis=1, tiled=True)
            wd = lax.all_gather(wd.astype(dtype), fsdp_axes, axis=2, tiled=True)
        col = lax.axis_index(ep_axes[0]) if ep_axes else 0
        xe, combine = _dispatch_local(
            x, wr, e_loc=e_loc, col=col, n_experts=cfg.n_experts,
            top_k=cfg.top_k, capacity=capacity, dtype=dtype,
        )
        g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(dtype))
        h = jax.nn.silu(g) * u
        ye = jnp.einsum("ecf,efd->ecd", h, wd.astype(dtype))
        y = combine(ye)
        if ep_axes:
            y = lax.psum(y, ep_axes)
        return y

    fsdp_spec = fsdp_axes if len(fsdp_axes) > 1 else (fsdp_axes[0] if fsdp_axes else None)
    return shard_map(
        block,
        mesh=mesh,
        in_specs=(
            P(dp_spec, None),          # x (T, d)
            P(None, None),             # router
            P(ep_spec, fsdp_spec, None),  # wg (E, d, ffe)
            P(ep_spec, fsdp_spec, None),  # wu
            P(ep_spec, None, fsdp_spec),  # wd (E, ffe, d)
        ),
        out_specs=P(dp_spec, None),
        check_rep=False,
    )(x2d, moe_params["router"], moe_params["wg"], moe_params["wu"], moe_params["wd"])
