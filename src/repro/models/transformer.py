"""Decoder-only LM (dense GQA + MoE variants) — scan-over-layers, remat.

Covers the five assigned LM architectures (granite-3-8b, minitron-8b,
qwen2-0.5b, moonshot-v1-16b-a3b, qwen3-moe-235b-a22b).  Params are plain
pytrees with the per-layer leaves stacked on a leading axis so the layer
stack is a single ``lax.scan`` (compact HLO — essential for the 512-
device dry-run compile) with ``jax.checkpoint`` remat.

Entry points:
  init(rng, cfg)                      -> params
  loss_fn(params, batch, cfg, ctx)    -> scalar loss   (train_step core)
  decode_step(params, cache, tok, pos, cfg, ctx) -> (logits, cache)
  init_cache(cfg, batch, seq)         -> KV cache pytree
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from . import layers as L
from .moe import moe_ffn


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # numerics / scheduling
    rope_theta: float = 1e4
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    q_chunk: int = 1024
    xent_chunk: int = 512
    sharding_profile: str = "tp_fsdp"
    remat: bool = True

    @property
    def params_count(self) -> int:
        d, hd = self.d_model, self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.moe:
            ffn = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
            ffn += self.n_shared * 3 * d * self.d_ff_expert
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d

    @property
    def active_params_count(self) -> int:
        if not self.moe:
            return self.params_count
        d = self.d_model
        hd = self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        ffn = (self.top_k + self.n_shared) * 3 * d * self.d_ff_expert + d * self.n_experts
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d


def init(rng, cfg: LMConfig):
    pd = L.dtype_of(cfg.param_dtype)
    d, hd = cfg.d_model, cfg.head_dim
    keys = jax.random.split(rng, 16)
    _ctr = [0]

    def stack(initf, *shape):
        _ctr[0] += 1
        base = jax.random.fold_in(keys[0], _ctr[0])

        def one(k):
            return initf(k, shape, pd)

        return jax.vmap(one)(jax.random.split(base, cfg.n_layers))

    layers = {
        "ln1": jnp.ones((cfg.n_layers, d), pd),
        "ln2": jnp.ones((cfg.n_layers, d), pd),
        "wq": stack(L.dense_init, d, cfg.n_heads * hd),
        "wk": stack(L.dense_init, d, cfg.n_kv_heads * hd),
        "wv": stack(L.dense_init, d, cfg.n_kv_heads * hd),
        "wo": stack(L.dense_init, cfg.n_heads * hd, d),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((cfg.n_layers, cfg.n_heads * hd), pd)
        layers["bk"] = jnp.zeros((cfg.n_layers, cfg.n_kv_heads * hd), pd)
        layers["bv"] = jnp.zeros((cfg.n_layers, cfg.n_kv_heads * hd), pd)
    if cfg.moe:
        layers["moe"] = {
            "router": stack(L.dense_init, d, cfg.n_experts),
            "wg": stack(L.dense_init, cfg.n_experts, d, cfg.d_ff_expert),
            "wu": stack(L.dense_init, cfg.n_experts, d, cfg.d_ff_expert),
            "wd": stack(L.dense_init, cfg.n_experts, cfg.d_ff_expert, d),
        }
        if cfg.n_shared:
            ffs = cfg.n_shared * cfg.d_ff_expert
            layers["wg"] = stack(L.dense_init, d, ffs)
            layers["wu"] = stack(L.dense_init, d, ffs)
            layers["wd"] = stack(L.dense_init, ffs, d)
    else:
        layers["wg"] = stack(L.dense_init, d, cfg.d_ff)
        layers["wu"] = stack(L.dense_init, d, cfg.d_ff)
        layers["wd"] = stack(L.dense_init, cfg.d_ff, d)

    return {
        "embed": L.embed_init(keys[1], (cfg.vocab, d), pd),
        "layers": layers,
        "ln_f": jnp.ones((d,), pd),
        "head": L.dense_init(keys[2], (d, cfg.vocab), pd),
    }


def param_logical_axes(cfg: LMConfig):
    """Logical sharding axes per param leaf (stacked layer dim first)."""
    lay = {
        "ln1": (None, None),
        "ln2": (None, None),
        "wq": (None, "fsdp", "tp"),
        "wk": (None, "fsdp", "tp"),
        "wv": (None, "fsdp", "tp"),
        "wo": (None, "tp", "fsdp"),
        "wg": (None, "fsdp", "tp"),
        "wu": (None, "fsdp", "tp"),
        "wd": (None, "tp", "fsdp"),
    }
    if cfg.qkv_bias:
        lay.update({"bq": (None, "tp"), "bk": (None, "tp"), "bv": (None, "tp")})
    if cfg.moe:
        lay["moe"] = {
            "router": (None, None, None),
            "wg": (None, "ep", "fsdp", None),
            "wu": (None, "ep", "fsdp", None),
            "wd": (None, "ep", None, "fsdp"),
        }
    return {
        "embed": ("tp", "fsdp"),
        "layers": lay,
        "ln_f": (None,),
        "head": ("fsdp", "tp"),
    }


def _layer_body(x, lp, cfg: LMConfig, ctx, cos, sin):
    b, s, d = x.shape
    hd = cfg.head_dim
    # §Perf iteration A: cast FSDP-sharded weights to the compute dtype
    # up front so SPMD's all-gathers move bf16, not f32 (2x less ICI).
    lp = {
        k: (v.astype(x.dtype) if k.startswith(("w", "b")) and k != "moe" else v)
        for k, v in lp.items()
    }
    # ---- attention ----
    h = L.rms_norm(x, lp["ln1"])
    q = jnp.einsum("bsd,dk->bsk", h, lp["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dk->bsk", h, lp["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dk->bsk", h, lp["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(x.dtype)
        k = k + lp["bk"].astype(x.dtype)
        v = v + lp["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    # GQA: when kv heads don't divide the TP axis, replicate KV (the
    # Megatron convention) instead of forcing a padded sharding.
    ntp = ctx.n("tp")
    kv_tp = "tp" if (ntp > 1 and cfg.n_kv_heads % ntp == 0) else None
    q = ctx.constrain(q, "dp", None, "tp", None)
    k = ctx.constrain(k, "dp", None, kv_tp, None)
    v = ctx.constrain(v, "dp", None, kv_tp, None)
    o = L.causal_attention(q, k, v, q_chunk=cfg.q_chunk, ctx=ctx)
    o = jnp.einsum("bsk,kd->bsd", o.reshape(b, s, cfg.n_heads * hd), lp["wo"].astype(x.dtype))
    x = x + ctx.constrain(o, "dp", None, None)

    # ---- FFN / MoE ----
    h = L.rms_norm(x, lp["ln2"])
    if cfg.moe:
        h2 = h.reshape(b * s, d)
        rep = (b * s) % ctx.n("dp") != 0
        y = moe_ffn(h2, lp["moe"], cfg, ctx, replicated_tokens=rep).reshape(b, s, d)
        if cfg.n_shared:
            y = y + L.swiglu(h, lp["wg"], lp["wu"], lp["wd"])
    else:
        h = ctx.constrain(h, "dp", None, None)
        y = L.swiglu(h, lp["wg"], lp["wu"], lp["wd"])
    x = x + ctx.constrain(y, "dp", None, None)
    return x


def forward(params, tokens, cfg: LMConfig, ctx):
    """tokens (B, S) -> final hidden states (B, S, d)."""
    dt = L.dtype_of(cfg.dtype)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = ctx.constrain(x, "dp", None, None)
    cos, sin = L.rope_tables(s, cfg.head_dim, cfg.rope_theta)

    body = partial(_layer_body, cfg=cfg, ctx=ctx, cos=cos, sin=sin)
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    def scan_fn(carry, lp):
        return body(carry, lp), None

    x, _ = lax.scan(scan_fn, x, params["layers"])
    return L.rms_norm(x, params["ln_f"])


def loss_fn(params, batch, cfg: LMConfig, ctx):
    """Next-token loss with a seq-chunked fused projection+softmax-xent:
    the (B, S, V) logits tensor is never materialised — only one
    (B, xent_chunk, V) bf16 chunk is live at a time."""
    x = forward(params, batch["tokens"], cfg, ctx)
    b, s, d = x.shape
    chunk = min(cfg.xent_chunk, s)
    n_chunks = s // chunk if s % chunk == 0 else 1
    if s % chunk != 0:
        chunk = s
    xs = jnp.moveaxis(x.reshape(b, n_chunks, chunk, d), 1, 0)
    ls = jnp.moveaxis(batch["labels"].reshape(b, n_chunks, chunk), 1, 0)
    head = params["head"]

    @jax.checkpoint
    def ce_one(xc, lc):
        # rematerialised: the (B, chunk, V) logits are recomputed in the
        # backward pass instead of being stacked as scan residuals
        logits = jnp.einsum("bsd,dv->bsv", xc, head.astype(xc.dtype))
        logits = ctx.constrain(logits, "dp", None, "tp").astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def ce_chunk(carry, xl):
        xc, lc = xl
        return carry + ce_one(xc, lc), None

    total, _ = lax.scan(ce_chunk, jnp.float32(0), (xs, ls))
    return total / jnp.float32(b * s)


# ---------------------------------------------------------------------------
# Serving: KV cache + single-token decode
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_seq: int, seq_shard: bool = False):
    dt = L.dtype_of(cfg.dtype)
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def cache_logical_axes(seq_shard: bool = False):
    # decode_32k: batch on dp, *sequence* on the model axis (KV heads are
    # usually < tp, so the spare TP capacity shards the cache length; the
    # masked-softmax collectives come out of SPMD automatically).
    # long_500k (batch=1): sequence over the whole mesh ('sp').
    if seq_shard:
        return {"k": (None, None, "sp", None, None), "v": (None, None, "sp", None, None)}
    return {"k": (None, "dp", "seqm", None, None), "v": (None, "dp", "seqm", None, None)}


def decode_step(params, cache, tokens, pos, cfg: LMConfig, ctx, seq_shard: bool = False):
    """tokens (B, 1) int32; pos scalar int32 -> (logits (B, V), new cache).

    Attention over the cache is computed with per-shard partial softmax
    statistics when the cache is sequence-sharded (XLA inserts the psum
    for the masked softmax under the sharding constraints).
    """
    dt = L.dtype_of(cfg.dtype)
    b = tokens.shape[0]
    hd = cfg.head_dim
    x = jnp.take(params["embed"], tokens[:, 0], axis=0).astype(dt)  # (B, d)
    cos, sin = L.rope_tables(1, hd, cfg.rope_theta, offset=pos)
    cax = cache_logical_axes(seq_shard)

    def body(carry, inputs):
        x, li = carry[0], carry[1]
        lp, kc, vc = inputs
        h = L.rms_norm(x, lp["ln1"])
        q = (h @ lp["wq"].astype(dt)).reshape(b, cfg.n_heads, hd)
        k = (h @ lp["wk"].astype(dt)).reshape(b, cfg.n_kv_heads, hd)
        v = (h @ lp["wv"].astype(dt)).reshape(b, cfg.n_kv_heads, hd)
        if cfg.qkv_bias:
            q = q + lp["bq"].astype(dt).reshape(cfg.n_heads, hd)
            k = k + lp["bk"].astype(dt).reshape(cfg.n_kv_heads, hd)
            v = v + lp["bv"].astype(dt).reshape(cfg.n_kv_heads, hd)
        q = L.apply_rope(q[:, None], cos, sin)[:, 0]
        k = L.apply_rope(k[:, None], cos, sin)[:, 0]
        z = jnp.zeros((), pos.dtype) if hasattr(pos, "dtype") else 0
        kc = lax.dynamic_update_slice(kc, k[:, None], (z, pos, z, z))
        vc = lax.dynamic_update_slice(vc, v[:, None], (z, pos, z, z))
        kc = ctx.constrain(kc, *cax["k"][1:])
        vc = ctx.constrain(vc, *cax["v"][1:])
        o = L.decode_attention_xla(q, kc, vc, pos + 1)
        o = o.reshape(b, cfg.n_heads * hd) @ lp["wo"].astype(dt)
        x = x + o
        h2 = L.rms_norm(x, lp["ln2"])
        if cfg.moe:
            rep = b % ctx.n("dp") != 0
            y = moe_ffn(h2, lp["moe"], cfg, ctx, replicated_tokens=rep)
            if cfg.n_shared:
                y = y + L.swiglu(h2[:, None], lp["wg"], lp["wu"], lp["wd"])[:, 0]
        else:
            y = L.swiglu(h2[:, None], lp["wg"], lp["wu"], lp["wd"])[:, 0]
        x = x + y
        return (x, li + 1), (kc, vc)

    (x, _), (k_new, v_new) = lax.scan(
        body, (x, 0), (params["layers"], cache["k"], cache["v"])
    )
    x = L.rms_norm(x, params["ln_f"])
    logits = (x @ params["head"].astype(dt)).astype(jnp.float32)
    return ctx.constrain(logits, "dp", "tp"), {"k": k_new, "v": v_new}
