"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` computes the exact semantics the kernel must reproduce,
with no tiling, no precision tricks and no layout assumptions.  Kernel
tests sweep shapes/dtypes and assert allclose (exact for integer
outputs) against these.
"""

from __future__ import annotations

import jax.numpy as jnp
import jax


def predecessor_ref(table_u64, queries_u64):
    """Oracle for every learned/plain search kernel: predecessor rank."""
    return jnp.searchsorted(table_u64, queries_u64, side="right").astype(jnp.int32) - 1


def rmi_predict_ref(
    u_f32, root_coef_f32, leaf_slope, leaf_icept, leaf_eps, leaf_rlo, leaf_rhi, b, n
):
    """Window prediction half of the fused RMI kernel, in f32 (the kernel's
    own arithmetic) — used to check the predict stage in isolation."""
    u = u_f32.astype(jnp.float32)
    c = root_coef_f32
    p_root = ((c[3] * u + c[2]) * u + c[1]) * u + c[0]
    leaf = jnp.clip(jnp.floor(p_root * (b / n)).astype(jnp.int32), 0, b - 1)
    slope = jnp.take(leaf_slope, leaf)
    icept = jnp.take(leaf_icept, leaf)
    eps = jnp.take(leaf_eps, leaf)
    rlo = jnp.take(leaf_rlo, leaf)
    rhi = jnp.take(leaf_rhi, leaf)
    p = slope * u + icept
    lo = jnp.clip(jnp.floor(p).astype(jnp.int32) - eps, rlo, rhi)
    hi = jnp.clip(jnp.ceil(p).astype(jnp.int32) + eps, rlo, rhi)
    return lo, hi


def embedding_bag_ref(table, ids, seg_ids, weights, num_bags):
    """EmbeddingBag oracle: out[b] = sum_i [seg_ids[i]==b] w[i] * table[ids[i]].

    ``table``: (V, D) f32; ``ids``/``seg_ids``: (N,) i32; weights (N,) f32.
    """
    gathered = jnp.take(table, ids, axis=0) * weights[:, None]
    return jax.ops.segment_sum(gathered, seg_ids, num_segments=num_bags)


def decode_attention_ref(q, k, v, kv_len):
    """Single-token GQA decode attention oracle.

    q: (B, Hq, D) f32; k/v: (B, S, Hkv, D) f32; kv_len: (B,) i32 valid
    lengths.  Hq must be a multiple of Hkv (GQA groups).
    """
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    group = hq // hkv
    kk = jnp.repeat(k, group, axis=2)  # (B, S, Hq, D)
    vv = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bhd,bshd->bhs", q, kk) / jnp.sqrt(jnp.float32(d))
    mask = (jnp.arange(s)[None, None, :] < kv_len[:, None, None])
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w, vv)
