"""Fused PGM descent — root route + per-level segment gather + ε-window
bounded search, one Pallas kernel.

The PGM query (paper §3.2) is a top-down walk: at each level, the
current segment's linear model predicts a window over the level below,
and an exact bounded search of that window yields the next level's
segment.  The XLA path in :mod:`repro.index.impls` unrolls this as one
``jnp`` stage per level; this kernel fuses the whole descent so every
level's gather + predict + search happens on the same resident query
tile (the paper's "tight search kernel" requirement for learned models
to beat binary search).

TPU adaptations, mirroring :mod:`rmi_search`:

* keys travel as u32 limb pairs; every search compare is the
  lexicographic limb compare (exact, so **routing is exact** — only the
  predictions are approximate);
* per-segment predictions are re-anchored into the f32 CDF coordinate
  ``u`` pre-normalised outside the kernel: ``pred = r0 + slope_u *
  max(u - u0, 0)`` with ``slope_u = slope * span``.  Anchoring at the
  segment's own ``u0`` keeps the multiplicand small (Sterbenz regime),
  so cancellation cannot blow the window;
* the build re-measures every level's prediction error with exactly
  this f32 arithmetic and widens ε accordingly
  (:func:`repro.kernels.ops.pgm_kernel_arrays`); f32 rounding is
  monotone, so the widened window stays a guarantee for queries between
  keys.  The predicted *center* is clamped into the exact
  ``[r0-1, r1-1]`` fence range before the ±ε widening, so
  gap-extrapolation and u-resolution blow-ups degrade to a full-segment
  window instead of collapsing it to one fence slot;
* the level directories (``off``/``off_r``/``sizes``) are tiny i32
  arrays indexed by the *static* level counter, so the level loop fully
  unrolls with static offsets into the flat padded leaf arrays —
  the same padded-leaf encoding ``_lift_pgm_levels`` produces for
  shard-stacking, which is what makes this kernel tier-stackable.

Two entry points share one kernel body: :func:`fused_pgm_search_pallas`
(single table, grid over query tiles) and
:func:`batched_pgm_search_pallas` (a tier/batch of level-harmonised
tables, grid over ``(table, q_tile)`` with per-table parameter blocks —
the pattern :mod:`rmi_search` established), the latter backing
``BatchedIndexes.lookup(backend="pallas")`` and the sharded tier's
vmapped fallback for the PGM family.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .rmi_search import _le_u64, DEFAULT_TILE_Q


def _bounded_ub_limbs(khi, klo, qhi, qlo, base, length, *, steps: int):
    """First index in [base, base+length) with key > q (limb compare);
    ``base + length`` if none.  Fixed-trip Khuong–Morin loop."""

    def body(_, carry):
        b, n = carry
        half = n >> 1
        mid = b + half
        go_right = _le_u64(jnp.take(khi, mid), jnp.take(klo, mid), qhi, qlo) & (n > 1)
        b = jnp.where(go_right, mid, b)
        n = n - jnp.where(n > 1, half, 0)
        return b, n

    b, _ = lax.fori_loop(0, steps, body, (base, length))
    le = _le_u64(jnp.take(khi, b), jnp.take(klo, b), qhi, qlo)
    return b + le.astype(jnp.int32)


def _pgm_body(
    u,
    qhi,
    qlo,
    thi,
    tlo,
    khi,
    klo,
    u0_a,
    slope_a,
    r0_a,
    off,
    off_r,
    sizes,
    eps,
    *,
    levels: int,
    n: int,
    steps: int,
):
    """The fused descent on plain arrays (shared single/batched body)."""
    seg = jnp.zeros(u.shape, dtype=jnp.int32)
    for lvl in range(levels):  # static unroll: off[lvl] reads are scalar
        base_k = off[lvl]
        base_r = off_r[lvl]
        u0 = jnp.take(u0_a, base_k + seg)
        slope = jnp.take(slope_a, base_k + seg)
        r0 = jnp.take(r0_a, base_r + seg)
        r1 = jnp.take(r0_a, base_r + seg + 1)
        pred = r0.astype(jnp.float32) + slope * jnp.maximum(u - u0, 0.0)
        pred = jnp.clip(pred, -1.0e9, 1.0e9)  # gap blow-ups: clamp pre-cast
        b_lo = jnp.maximum(r0 - 1, 0)
        b_hi = r1 - 1
        # clamp the predicted CENTER into the fence range before widening:
        # an f32 u-resolution collapse (dense cluster inside a huge key
        # span) can push pred thousands of ranks past the segment, and
        # ±(ε+1) around the raw pred would collapse the clipped window to
        # a single fence slot.  The true rank always lies in
        # [b_lo, b_hi], so clamping the center never increases
        # |center - true| and the measured-ε guarantee survives.
        p_lo = jnp.clip(jnp.floor(pred).astype(jnp.int32), b_lo, b_hi)
        p_hi = jnp.clip(jnp.ceil(pred).astype(jnp.int32), b_lo, b_hi)
        lo = jnp.clip(p_lo - (eps + 1), b_lo, b_hi)
        hi = jnp.clip(p_hi + (eps + 1), b_lo, b_hi)
        if lvl + 1 < levels:
            base_n = off[lvl + 1]
            ub = _bounded_ub_limbs(khi, klo, qhi, qlo, base_n + lo, hi - lo + 1, steps=steps)
            seg = jnp.clip(ub - base_n - 1, 0, sizes[lvl + 1] - 1)
        else:
            # leaf level: r0 indexes the table — final ε-window search
            lo = jnp.clip(lo, 0, n - 1)
            hi = jnp.clip(hi, 0, n - 1)
            ub = _bounded_ub_limbs(thi, tlo, qhi, qlo, lo, hi - lo + 1, steps=steps)
            return ub - 1
    raise AssertionError("unreachable")


def _pgm_kernel(
    u_ref,
    qhi_ref,
    qlo_ref,
    thi_ref,
    tlo_ref,
    khi_ref,
    klo_ref,
    u0_ref,
    slope_ref,
    r0_ref,
    off_ref,
    off_r_ref,
    sizes_ref,
    eps_ref,
    out_ref,
    *,
    levels: int,
    n: int,
    steps: int,
):
    out_ref[...] = _pgm_body(
        u_ref[...],
        qhi_ref[...],
        qlo_ref[...],
        thi_ref[...],
        tlo_ref[...],
        khi_ref[...],
        klo_ref[...],
        u0_ref[...],
        slope_ref[...],
        r0_ref[...],
        off_ref[...],
        off_r_ref[...],
        sizes_ref[...],
        eps_ref[0],
        levels=levels,
        n=n,
        steps=steps,
    )


def fused_pgm_search_pallas(
    u_f32,
    q_hi,
    q_lo,
    table_hi,
    table_lo,
    keys_hi,
    keys_lo,
    pk_u0,
    pk_slope,
    rank0_i32,
    off_i32,
    off_r_i32,
    sizes_i32,
    eps_i32,
    *,
    levels: int,
    steps: int,
    tile_q: int = DEFAULT_TILE_Q,
    interpret: bool = True,
):
    """pallas_call wrapper for the fused PGM descent.

    ``keys_hi/lo`` are the limb split of the level-concatenated padded
    segment keys; ``pk_u0``/``pk_slope`` the f32 re-anchored segment
    models (:func:`repro.kernels.ops.pgm_kernel_arrays`); ``rank0_i32``
    the concatenated level directories; ``eps_i32`` a one-element array
    holding the f32-widened ε.  Queries must be padded to a tile
    multiple.
    """
    nq = u_f32.shape[0]
    n = table_hi.shape[0]
    kn = keys_hi.shape[0]
    rn = rank0_i32.shape[0]
    assert nq % tile_q == 0, "pad queries to a tile multiple (see ops.py)"
    grid = (nq // tile_q,)

    def qspec():
        return pl.BlockSpec((tile_q,), lambda i: (i,))

    def full(m):
        return pl.BlockSpec((m,), lambda i: (0,))

    kernel = functools.partial(_pgm_kernel, levels=levels, n=n, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            qspec(),  # u
            qspec(),  # q_hi
            qspec(),  # q_lo
            full(n),  # table_hi
            full(n),  # table_lo
            full(kn),  # keys_hi
            full(kn),  # keys_lo
            full(kn),  # pk_u0
            full(kn),  # pk_slope
            full(rn),  # rank0
            full(levels + 1),  # off
            full(levels + 1),  # off_r
            full(levels),  # sizes
            full(1),  # eps
        ],
        out_specs=qspec(),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
        interpret=interpret,
    )(
        u_f32,
        q_hi,
        q_lo,
        table_hi,
        table_lo,
        keys_hi,
        keys_lo,
        pk_u0,
        pk_slope,
        rank0_i32,
        off_i32,
        off_r_i32,
        sizes_i32,
        eps_i32,
    )


def _pgm_kernel_batched(
    u_ref,
    qhi_ref,
    qlo_ref,
    thi_ref,
    tlo_ref,
    khi_ref,
    klo_ref,
    u0_ref,
    slope_ref,
    r0_ref,
    off_ref,
    off_r_ref,
    sizes_ref,
    eps_ref,
    out_ref,
    *,
    levels: int,
    n: int,
    steps: int,
):
    # every block carries a leading table axis of extent 1: squeeze it
    # and reuse the single-table body verbatim (the rmi_search pattern)
    out_ref[0, :] = _pgm_body(
        u_ref[0],
        qhi_ref[0],
        qlo_ref[0],
        thi_ref[0],
        tlo_ref[0],
        khi_ref[0],
        klo_ref[0],
        u0_ref[0],
        slope_ref[0],
        r0_ref[0],
        off_ref[0],
        off_r_ref[0],
        sizes_ref[0],
        eps_ref[0, 0],
        levels=levels,
        n=n,
        steps=steps,
    )


def batched_pgm_search_pallas(
    u_f32,
    q_hi,
    q_lo,
    table_hi,
    table_lo,
    keys_hi,
    keys_lo,
    pk_u0,
    pk_slope,
    rank0_i32,
    off_i32,
    off_r_i32,
    sizes_i32,
    eps_i32,
    *,
    levels: int,
    steps: int,
    tile_q: int = DEFAULT_TILE_Q,
    interpret: bool = True,
):
    """Batched/tier variant of the fused PGM descent: ``(n_tables, nq)``
    queries against ``(n_tables, n)`` tables with per-table segment
    leaves and level directories.

    Grid is ``(table, q_tile)``; the index maps hand each program its
    table's parameter blocks (leading axis extent 1) and one query
    tile, so ONE ``pallas_call`` answers a whole batch/tier — the
    kernel-level analogue of the vmapped shared lookup.  The level
    count is static and common across tables (``_lift_pgm_levels``
    harmonised it at stack time); ``steps`` and ``eps_i32`` must cover
    the widest per-table window (extra Khuong–Morin trips are no-ops,
    which is why the stacked Index takes the max across tables).
    """
    nt, nq = u_f32.shape
    n = table_hi.shape[1]
    kn = keys_hi.shape[1]
    rn = rank0_i32.shape[1]
    assert nq % tile_q == 0, "pad queries to a tile multiple (see ops.py)"
    grid = (nt, nq // tile_q)

    def qspec():
        return pl.BlockSpec((1, tile_q), lambda t, i: (t, i))

    def per_table(m):
        return pl.BlockSpec((1, m), lambda t, i: (t, 0))

    kernel = functools.partial(_pgm_kernel_batched, levels=levels, n=n, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            qspec(),  # u
            qspec(),  # q_hi
            qspec(),  # q_lo
            per_table(n),  # table_hi
            per_table(n),  # table_lo
            per_table(kn),  # keys_hi
            per_table(kn),  # keys_lo
            per_table(kn),  # pk_u0
            per_table(kn),  # pk_slope
            per_table(rn),  # rank0
            per_table(levels + 1),  # off
            per_table(levels + 1),  # off_r
            per_table(levels),  # sizes
            per_table(1),  # eps
        ],
        out_specs=qspec(),
        out_shape=jax.ShapeDtypeStruct((nt, nq), jnp.int32),
        interpret=interpret,
    )(
        u_f32,
        q_hi,
        q_lo,
        table_hi,
        table_lo,
        keys_hi,
        keys_lo,
        pk_u0,
        pk_slope,
        rank0_i32,
        off_i32,
        off_r_i32,
        sizes_i32,
        eps_i32,
    )
