"""Fused RadixSpline lookup — radix-table gather + spline-knot search +
error-window probe, one Pallas kernel.

The RadixSpline query (paper §3.2) is three dependent stages: a radix
table over the top ``r`` bits narrows the knot range, a bounded search
finds the enclosing knot pair, and linear interpolation between the
knots predicts an ε-window over the table.  The XLA path runs these as
separate gathers through :mod:`repro.index.impls`; here they fuse onto
one resident query tile, including the final ε-window probe (the
"radix-table gather + knot search fuses cleanly" item from ROADMAP).

TPU adaptations, mirroring :mod:`rmi_search` / :mod:`pgm_search`:

* the radix prefix ``(q - kmin) >> shift`` is pure query-side integer
  work, pre-computed outside the kernel in native u64 (no limb shifts
  in-kernel);
* knot selection is the exact limb-compare bounded search, so the knot
  pair is **exact**; only the interpolation is approximate;
* interpolation is re-anchored in f32 ``u`` space: ``pred = y1 +
  slope_j * (u - u1)`` with per-knot-segment slopes precomputed at
  build (:func:`repro.kernels.ops.rs_kernel_arrays`), which re-measures
  the prediction error of every table key *and every knot boundary*
  with exactly this f32 arithmetic and widens ε so the window stays a
  guarantee (f32 rounding is monotone between knots).

Two entry points share one kernel body: :func:`fused_rs_search_pallas`
(single table, grid over query tiles) and
:func:`batched_rs_search_pallas` (a tier/batch of tables, grid over
``(table, q_tile)`` with per-table knot/radix blocks — the pattern
:mod:`rmi_search` established), the latter backing
``BatchedIndexes.lookup(backend="pallas")`` and the sharded tier's
vmapped fallback for the RS kind.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pgm_search import _bounded_ub_limbs
from .rmi_search import DEFAULT_TILE_Q


def _rs_body(
    u,
    qhi,
    qlo,
    prefix,
    thi,
    tlo,
    khi,
    klo,
    u0_a,
    slope_a,
    rank_a,
    radix,
    m_valid,
    eps,
    *,
    n: int,
    ksteps: int,
    steps: int,
):
    """The fused three-stage lookup on plain arrays."""
    # --- stage 1: radix-table gather bounds the knot range ---
    lo_k = jnp.maximum(jnp.take(radix, prefix) - 1, 0)
    hi_k = jnp.take(radix, prefix + 1)
    length = jnp.maximum(hi_k - lo_k, 1)

    # --- stage 2: exact knot search (limb compare) + f32 interpolation ---
    ub = _bounded_ub_limbs(khi, klo, qhi, qlo, lo_k, length, steps=ksteps)
    j = jnp.clip(ub - 1, 0, m_valid - 2)
    y1 = jnp.take(rank_a, j).astype(jnp.float32)
    pred = y1 + jnp.take(slope_a, j) * jnp.maximum(u - jnp.take(u0_a, j), 0.0)
    pred = jnp.clip(pred, -1.0e9, 1.0e9)
    # clamp the predicted CENTER into the table before widening (see
    # pgm_search: an f32 u-resolution collapse can push pred far past
    # the table and collapse the ±ε window to the last slot; the true
    # rank is always in [0, n-1], so clamping the center is sound).
    p_lo = jnp.clip(jnp.floor(pred).astype(jnp.int32), 0, n - 1)
    p_hi = jnp.clip(jnp.ceil(pred).astype(jnp.int32), 0, n - 1)
    lo = jnp.clip(p_lo - eps, 0, n - 1)
    hi = jnp.clip(p_hi + eps, 0, n - 1)

    # --- stage 3: ε-window probe over the table limbs ---
    ub_t = _bounded_ub_limbs(thi, tlo, qhi, qlo, lo, hi - lo + 1, steps=steps)
    return ub_t - 1


def _rs_kernel(
    u_ref,
    qhi_ref,
    qlo_ref,
    prefix_ref,
    thi_ref,
    tlo_ref,
    khi_ref,
    klo_ref,
    u0_ref,
    slope_ref,
    rank_ref,
    radix_ref,
    mv_ref,
    eps_ref,
    out_ref,
    *,
    n: int,
    ksteps: int,
    steps: int,
):
    out_ref[...] = _rs_body(
        u_ref[...],
        qhi_ref[...],
        qlo_ref[...],
        prefix_ref[...],
        thi_ref[...],
        tlo_ref[...],
        khi_ref[...],
        klo_ref[...],
        u0_ref[...],
        slope_ref[...],
        rank_ref[...],
        radix_ref[...],
        mv_ref[0],
        eps_ref[0],
        n=n,
        ksteps=ksteps,
        steps=steps,
    )


def fused_rs_search_pallas(
    u_f32,
    q_hi,
    q_lo,
    prefix_i32,
    table_hi,
    table_lo,
    knot_hi,
    knot_lo,
    rk_u0,
    rk_slope,
    knot_rank_i32,
    radix_i32,
    m_valid_i32,
    eps_i32,
    *,
    ksteps: int,
    steps: int,
    tile_q: int = DEFAULT_TILE_Q,
    interpret: bool = True,
):
    """pallas_call wrapper for the fused RadixSpline lookup.

    ``prefix_i32`` is the per-query radix prefix (pre-computed outside,
    clipped to ``[0, 2^r - 1]``); ``knot_hi/lo`` the limb split of the
    padded knot keys; ``rk_u0``/``rk_slope`` the f32 re-anchored spline
    (:func:`repro.kernels.ops.rs_kernel_arrays`); ``m_valid_i32`` /
    ``eps_i32`` one-element arrays with the valid knot count and the
    f32-widened ε.  Queries must be padded to a tile multiple.
    """
    nq = u_f32.shape[0]
    n = table_hi.shape[0]
    mk = knot_hi.shape[0]
    rn = radix_i32.shape[0]
    assert nq % tile_q == 0, "pad queries to a tile multiple (see ops.py)"
    grid = (nq // tile_q,)

    def qspec():
        return pl.BlockSpec((tile_q,), lambda i: (i,))

    def full(m):
        return pl.BlockSpec((m,), lambda i: (0,))

    kernel = functools.partial(_rs_kernel, n=n, ksteps=ksteps, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            qspec(),  # u
            qspec(),  # q_hi
            qspec(),  # q_lo
            qspec(),  # prefix
            full(n),  # table_hi
            full(n),  # table_lo
            full(mk),  # knot_hi
            full(mk),  # knot_lo
            full(mk),  # rk_u0
            full(mk),  # rk_slope
            full(mk),  # knot ranks
            full(rn),  # radix table
            full(1),  # m_valid
            full(1),  # eps
        ],
        out_specs=qspec(),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
        interpret=interpret,
    )(
        u_f32,
        q_hi,
        q_lo,
        prefix_i32,
        table_hi,
        table_lo,
        knot_hi,
        knot_lo,
        rk_u0,
        rk_slope,
        knot_rank_i32,
        radix_i32,
        m_valid_i32,
        eps_i32,
    )


def _rs_kernel_batched(
    u_ref,
    qhi_ref,
    qlo_ref,
    prefix_ref,
    thi_ref,
    tlo_ref,
    khi_ref,
    klo_ref,
    u0_ref,
    slope_ref,
    rank_ref,
    radix_ref,
    mv_ref,
    eps_ref,
    out_ref,
    *,
    n: int,
    ksteps: int,
    steps: int,
):
    # leading table axis of extent 1 per block: squeeze and reuse the
    # single-table body verbatim (the rmi_search pattern)
    out_ref[0, :] = _rs_body(
        u_ref[0],
        qhi_ref[0],
        qlo_ref[0],
        prefix_ref[0],
        thi_ref[0],
        tlo_ref[0],
        khi_ref[0],
        klo_ref[0],
        u0_ref[0],
        slope_ref[0],
        rank_ref[0],
        radix_ref[0],
        mv_ref[0, 0],
        eps_ref[0, 0],
        n=n,
        ksteps=ksteps,
        steps=steps,
    )


def batched_rs_search_pallas(
    u_f32,
    q_hi,
    q_lo,
    prefix_i32,
    table_hi,
    table_lo,
    knot_hi,
    knot_lo,
    rk_u0,
    rk_slope,
    knot_rank_i32,
    radix_i32,
    m_valid_i32,
    eps_i32,
    *,
    ksteps: int,
    steps: int,
    tile_q: int = DEFAULT_TILE_Q,
    interpret: bool = True,
):
    """Batched/tier variant of the fused RadixSpline lookup:
    ``(n_tables, nq)`` queries against ``(n_tables, n)`` tables with
    per-table knot/radix blocks.

    Grid is ``(table, q_tile)``; each program gets its table's knot
    limbs, spline re-encoding, radix table, valid-knot count and
    ε (leading axis extent 1) plus one query tile, so ONE
    ``pallas_call`` answers a whole batch/tier.  ``r_bits`` is a
    structural static (stacking requires it to agree across tables), so
    every radix block has the same length; ``ksteps``/``steps`` must
    cover the widest per-table knot range / window (max-merged at stack
    time — extra fixed-trip iterations are no-ops).
    """
    nt, nq = u_f32.shape
    n = table_hi.shape[1]
    mk = knot_hi.shape[1]
    rn = radix_i32.shape[1]
    assert nq % tile_q == 0, "pad queries to a tile multiple (see ops.py)"
    grid = (nt, nq // tile_q)

    def qspec():
        return pl.BlockSpec((1, tile_q), lambda t, i: (t, i))

    def per_table(m):
        return pl.BlockSpec((1, m), lambda t, i: (t, 0))

    kernel = functools.partial(_rs_kernel_batched, n=n, ksteps=ksteps, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            qspec(),  # u
            qspec(),  # q_hi
            qspec(),  # q_lo
            qspec(),  # prefix
            per_table(n),  # table_hi
            per_table(n),  # table_lo
            per_table(mk),  # knot_hi
            per_table(mk),  # knot_lo
            per_table(mk),  # rk_u0
            per_table(mk),  # rk_slope
            per_table(mk),  # knot ranks
            per_table(rn),  # radix table
            per_table(1),  # m_valid
            per_table(1),  # eps
        ],
        out_specs=qspec(),
        out_shape=jax.ShapeDtypeStruct((nt, nq), jnp.int32),
        interpret=interpret,
    )(
        u_f32,
        q_hi,
        q_lo,
        prefix_i32,
        table_hi,
        table_lo,
        knot_hi,
        knot_lo,
        rk_u0,
        rk_slope,
        knot_rank_i32,
        radix_i32,
        m_valid_i32,
        eps_i32,
    )
