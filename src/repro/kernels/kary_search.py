"""Lane-wide k-ary search — the TPU-native K-BFS (DESIGN.md §3).

The paper's K-BFS uses k≈3 because a CPU core pays one cache line per
fence probe.  On a TPU the VPU compares a query against **k = 128 fences
in one vector op**, so the optimal k is the lane width: each step costs
one (TILE_Q, K) gather + compare + popcount-style reduce and shrinks the
window by 128x.  ceil(log_128 n) steps + one final lane sweep replace
ceil(log_2 n) dependent gathers — an 18->4 step reduction for n = 1M.

Keys are u32-limb pairs as in :mod:`rmi_search`.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .rmi_search import _le_u64, DEFAULT_TILE_Q

LANES = 128


def kary_owner_route(boundaries, q, *, k: int = LANES):
    """Branch-free owner-shard selection on a fence array.

    ``boundaries`` holds the first key of shards ``1..S-1`` (sorted); the
    owner of query ``q`` is ``#{i : boundaries[i] <= q}`` in ``[0, S-1]``
    — exact fence keys route to the shard that starts with them.  Up to
    ``k`` fences (every realistic tier) this is ONE lane-wide compare +
    popcount-style reduce, the same shape as a single :func:`_kary_kernel`
    step; beyond that it falls back to k-ary splitting.
    """
    nb = int(boundaries.shape[0])
    if nb == 0:
        return jnp.zeros(q.shape, dtype=jnp.int32)
    if nb <= k:
        le = boundaries[None, :] <= q[:, None]
        return jnp.sum(le.astype(jnp.int32), axis=-1)
    from repro.core import search

    lo = jnp.zeros(q.shape, dtype=jnp.int64)
    ln = jnp.full(q.shape, nb, dtype=jnp.int64)
    steps = max(1, int(math.ceil(math.log(nb) / math.log(k))))
    ub = search.bounded_kary_upper_bound(boundaries, q, lo, ln, k=k, steps=steps)
    return ub.astype(jnp.int32)


def _kary_body(qhi, qlo, thi, tlo, *, n: int, k: int, steps: int):
    """The lane-wide k-ary search on plain arrays (shared by the
    single-table and batched kernels)."""
    tq = qhi.shape[0]

    base = jnp.zeros((tq,), jnp.int32)
    length = jnp.full((tq,), n, jnp.int32)
    frac = lax.broadcasted_iota(jnp.int32, (tq, k - 1), 1) + 1  # 1..k-1

    def body(_, carry):
        base, length = carry
        fence = base[:, None] + (frac * length[:, None]) // k  # (TQ, K-1)
        fhi = jnp.take(thi, fence)
        flo = jnp.take(tlo, fence)
        le = _le_u64(fhi, flo, qhi[:, None], qlo[:, None])
        seg = jnp.sum(le, axis=1, dtype=jnp.int32)  # segment index
        new_base = base + (seg * length) // k
        new_len = (jnp.minimum(seg + 1, k) * length) // k - (seg * length) // k
        keep = length > k
        base = jnp.where(keep, new_base, base)
        length = jnp.where(keep, new_len, length)
        return base, length

    base, length = lax.fori_loop(0, steps, body, (base, length))

    # final lane sweep: window now <= k wide; one (TQ, K) gather + count
    offs = lax.broadcasted_iota(jnp.int32, (tq, k), 1)
    idx = jnp.minimum(base[:, None] + offs, n - 1)
    vhi = jnp.take(thi, idx)
    vlo = jnp.take(tlo, idx)
    le = _le_u64(vhi, vlo, qhi[:, None], qlo[:, None]) & (offs < length[:, None])
    cnt = jnp.sum(le, axis=1, dtype=jnp.int32)
    return base + cnt - 1


def _kary_kernel(qhi_ref, qlo_ref, thi_ref, tlo_ref, out_ref, *, n: int, k: int, steps: int):
    out_ref[...] = _kary_body(
        qhi_ref[...], qlo_ref[...], thi_ref[...], tlo_ref[...], n=n, k=k, steps=steps
    )


def _kary_steps(n: int, k: int) -> int:
    """Splitting steps until the window is <= k (then one lane sweep)."""
    steps = max(0, int(math.ceil(math.log(max(n, 2)) / math.log(k))) - 1) + (
        1 if n > k else 0
    )
    # conservative: ensure k^steps * k >= n
    while k ** (steps + 1) < n:
        steps += 1
    return steps


def kary_search_pallas(
    q_hi,
    q_lo,
    table_hi,
    table_lo,
    *,
    k: int = LANES,
    tile_q: int = DEFAULT_TILE_Q,
    interpret: bool = True,
):
    nq = q_hi.shape[0]
    n = table_hi.shape[0]
    assert nq % tile_q == 0
    steps = _kary_steps(n, k)
    grid = (nq // tile_q,)

    kernel = functools.partial(_kary_kernel, n=n, k=k, steps=steps)
    qspec = pl.BlockSpec((tile_q,), lambda i: (i,))
    full = pl.BlockSpec((n,), lambda i: (0,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qspec, qspec, full, full],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
        interpret=interpret,
    )(q_hi, q_lo, table_hi, table_lo)


def _kary_kernel_batched(qhi_ref, qlo_ref, thi_ref, tlo_ref, out_ref, *, n, k, steps):
    out_ref[0, :] = _kary_body(
        qhi_ref[0], qlo_ref[0], thi_ref[0], tlo_ref[0], n=n, k=k, steps=steps
    )


def batched_kary_search_pallas(
    q_hi,
    q_lo,
    table_hi,
    table_lo,
    *,
    k: int = LANES,
    tile_q: int = DEFAULT_TILE_Q,
    interpret: bool = True,
):
    """Batched/tier variant: ``(n_tables, nq)`` queries against
    ``(n_tables, n)`` tables, grid over ``(table, q_tile)``.

    The model-free Pallas baseline for the batched/sharded lookup of
    kinds without a fused kernel (same role :func:`kary_search_pallas`
    plays for single-table ``backend="pallas"``).
    """
    nt, nq = q_hi.shape
    n = table_hi.shape[1]
    assert nq % tile_q == 0
    steps = _kary_steps(n, k)
    grid = (nt, nq // tile_q)
    qspec = pl.BlockSpec((1, tile_q), lambda t, i: (t, i))
    per_table = pl.BlockSpec((1, n), lambda t, i: (t, 0))
    kernel = functools.partial(_kary_kernel_batched, n=n, k=k, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qspec, qspec, per_table, per_table],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((nt, nq), jnp.int32),
        interpret=interpret,
    )(q_hi, q_lo, table_hi, table_lo)
