"""Flash-decode GQA attention — Pallas TPU kernel for the serve path.

Single new token attends to a long KV cache: the classic decode hot spot
(``decode_32k`` / ``long_500k`` shape cells).  Online-softmax streaming
over KV tiles; the query block and running (m, l, acc) statistics stay
in VMEM scratch while KV tiles stream through the grid — the Pallas
double-buffered pipeline plays the role of the paper's CPU prefetch.

Grid: (batch, kv_tiles); scratch carries the softmax state across the
kv_tiles dimension; the output block is written on the final tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    q_ref,  # (1, Hq, D)
    k_ref,  # (1, St, Hkv, D)
    v_ref,  # (1, St, Hkv, D)
    len_ref,  # (1,) i32
    out_ref,  # (1, Hq, D)
    m_ref,  # scratch (Hq,)
    l_ref,  # scratch (Hq,)
    acc_ref,  # scratch (Hq, D)
    *,
    s_tile: int,
    num_s_tiles: int,
    group: int,
):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # (Hq, D)
    k = k_ref[0]  # (St, Hkv, D)
    v = v_ref[0]
    hq, d = q.shape
    hkv = k.shape[1]

    # GQA: fold query heads into (Hkv, group)
    q4 = q.reshape(hkv, group, d)
    logits = jnp.einsum("kgd,skd->kgs", q4, k).reshape(hq, s_tile)
    logits = logits / jnp.sqrt(jnp.float32(d))

    pos = s_idx * s_tile + lax.broadcasted_iota(jnp.int32, (hq, s_tile), 1)
    valid = pos < len_ref[0]
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    acc_prev = acc_ref[...]

    m_cur = jnp.max(logits, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])  # (Hq, St)
    p = jnp.where(valid, p, 0.0)

    p4 = p.reshape(hkv, group, s_tile)
    pv = jnp.einsum("kgs,skd->kgd", p4, v).reshape(hq, d)

    m_ref[...] = m_new
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_prev * alpha[:, None] + pv

    @pl.when(s_idx == num_s_tiles - 1)
    def _finish():
        out_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]


def decode_attention_pallas(q, k, v, kv_len, *, s_tile: int = 256, interpret: bool = True):
    """q (B,Hq,D) f32; k/v (B,S,Hkv,D) f32; kv_len (B,) i32 -> (B,Hq,D)."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    assert s % s_tile == 0, "pad KV length to a tile multiple (see ops.py)"
    assert hq % hkv == 0
    group = hq // hkv
    num_s_tiles = s // s_tile
    grid = (b, num_s_tiles)

    kernel = functools.partial(
        _decode_kernel, s_tile=s_tile, num_s_tiles=num_s_tiles, group=group
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, s_tile, hkv, d), lambda bi, si: (bi, si, 0, 0)),
            pl.BlockSpec((1, s_tile, hkv, d), lambda bi, si: (bi, si, 0, 0)),
            pl.BlockSpec((1,), lambda bi, si: (bi,)),
        ],
        out_specs=pl.BlockSpec((1, hq, d), lambda bi, si: (bi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hq,), jnp.float32),  # m
            pltpu.VMEM((hq,), jnp.float32),  # l
            pltpu.VMEM((hq, d), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v, kv_len)
