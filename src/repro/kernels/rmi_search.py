"""Fused RMI predict + ε-bounded branch-free search — Pallas TPU kernel.

TPU-native adaptation of the paper's hottest path (DESIGN.md §3):

* 64-bit keys are carried as **two u32 limbs** (TPU vector units have no
  64-bit integer compare; the lexicographic limb compare is one select).
* The CDF coordinate ``u`` is pre-normalised **once** outside the kernel
  (f64 -> f32); all in-kernel arithmetic is f32/i32.  The build widens
  each leaf's ε by the measured f32 rounding error so the window stays a
  guarantee.
* Grid over query tiles; the table limbs + leaf parameter arrays live in
  VMEM (VMEM-tier tables — the paper's L1/L2 regime; HBM-tier tables use
  the XLA path in :mod:`repro.core`).
* The bounded search is the fixed-trip Khuong–Morin loop: ``steps``
  iterations of gather + select, no data-dependent control flow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_TILE_Q = 512


def _le_u64(khi, klo, qhi, qlo):
    """(khi,klo) <= (qhi,qlo) as unsigned 64-bit via u32 limbs."""
    return (khi < qhi) | ((khi == qhi) & (klo <= qlo))


def _rmi_kernel(
    u_ref,
    qhi_ref,
    qlo_ref,
    thi_ref,
    tlo_ref,
    root_ref,
    slope_ref,
    icept_ref,
    eps_ref,
    rlo_ref,
    rhi_ref,
    out_ref,
    *,
    b: int,
    n: int,
    steps: int,
):
    u = u_ref[...]  # (TQ,) f32, pre-normalised and clamped to [0,1]
    qhi = qhi_ref[...]
    qlo = qlo_ref[...]
    thi = thi_ref[...]  # (N,) u32 table limbs
    tlo = tlo_ref[...]
    c = root_ref[...]  # (4,) f32

    # --- stage 1: root -> leaf ---
    # clamp BEFORE the i32 cast: model blow-ups on key gaps predict
    # |p| ~ 1e15 in f32, and an out-of-range float->int32 cast is
    # implementation-defined garbage that survives the later clips.
    p_root = ((c[3] * u + c[2]) * u + c[1]) * u + c[0]
    p_root = jnp.clip(p_root, -1.0e9, 1.0e9)  # b/n <= 1 keeps the product in i32
    leaf = jnp.clip(jnp.floor(p_root * (b / n)).astype(jnp.int32), 0, b - 1)

    # --- stage 2: leaf linear predict + guaranteed window ---
    slope = jnp.take(slope_ref[...], leaf)
    icept = jnp.take(icept_ref[...], leaf)
    eps = jnp.take(eps_ref[...], leaf)
    rlo = jnp.take(rlo_ref[...], leaf)
    rhi = jnp.take(rhi_ref[...], leaf)
    p = jnp.clip(slope * u + icept, -1.0e9, 1.0e9)  # +/-eps stays inside i32
    lo = jnp.clip(jnp.floor(p).astype(jnp.int32) - eps, rlo, rhi)
    hi = jnp.clip(jnp.ceil(p).astype(jnp.int32) + eps, rlo, rhi)

    # --- stage 3: fixed-trip branch-free bounded search ---
    base = lo
    length = hi - lo + 1

    def body(_, carry):
        base, length = carry
        half = length >> 1
        mid = base + half
        khi = jnp.take(thi, mid)
        klo = jnp.take(tlo, mid)
        go_right = _le_u64(khi, klo, qhi, qlo) & (length > 1)
        base = jnp.where(go_right, mid, base)
        length = length - jnp.where(length > 1, half, 0)
        return base, length

    base, _ = lax.fori_loop(0, steps, body, (base, length))
    le = _le_u64(jnp.take(thi, base), jnp.take(tlo, base), qhi, qlo)
    out_ref[...] = base + le.astype(jnp.int32) - 1


def fused_rmi_search_pallas(
    u_f32,
    q_hi,
    q_lo,
    table_hi,
    table_lo,
    root_coef,
    leaf_slope,
    leaf_icept,
    leaf_eps,
    leaf_rlo,
    leaf_rhi,
    *,
    steps: int,
    tile_q: int = DEFAULT_TILE_Q,
    interpret: bool = True,
):
    """pallas_call wrapper.  Queries must be padded to a tile multiple."""
    nq = u_f32.shape[0]
    n = table_hi.shape[0]
    b = leaf_slope.shape[0]
    assert nq % tile_q == 0, "pad queries to a tile multiple (see ops.py)"
    grid = (nq // tile_q,)

    def qspec():
        return pl.BlockSpec((tile_q,), lambda i: (i,))

    def full(shape):
        return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    kernel = functools.partial(_rmi_kernel, b=b, n=n, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            qspec(),  # u
            qspec(),  # q_hi
            qspec(),  # q_lo
            full((n,)),  # table_hi
            full((n,)),  # table_lo
            full((4,)),  # root coef
            full((b,)),  # slope
            full((b,)),  # icept
            full((b,)),  # eps
            full((b,)),  # rlo
            full((b,)),  # rhi
        ],
        out_specs=qspec(),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
        interpret=interpret,
    )(
        u_f32,
        q_hi,
        q_lo,
        table_hi,
        table_lo,
        root_coef,
        leaf_slope,
        leaf_icept,
        leaf_eps,
        leaf_rlo,
        leaf_rhi,
    )
