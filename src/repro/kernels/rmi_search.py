"""Fused RMI predict + ε-bounded branch-free search — Pallas TPU kernel.

TPU-native adaptation of the paper's hottest path (DESIGN.md §3):

* 64-bit keys are carried as **two u32 limbs** (TPU vector units have no
  64-bit integer compare; the lexicographic limb compare is one select).
* The CDF coordinate ``u`` is pre-normalised **once** outside the kernel
  (f64 -> f32); all in-kernel arithmetic is f32/i32.  The build widens
  each leaf's ε by the measured f32 rounding error so the window stays a
  guarantee.
* Grid over query tiles; the table limbs + leaf parameter arrays live in
  VMEM (VMEM-tier tables — the paper's L1/L2 regime; HBM-tier tables use
  the XLA path in :mod:`repro.core`).
* The bounded search is the fixed-trip Khuong–Morin loop: ``steps``
  iterations of gather + select, no data-dependent control flow.

Two entry points share one kernel body: :func:`fused_rmi_search_pallas`
(single table, grid over query tiles) and
:func:`batched_rmi_search_pallas` (a tier/batch of same-shape tables,
grid over ``(table, q_tile)`` with per-table parameter blocks) — the
latter is what lets :class:`repro.tune.batched.BatchedIndexes` and the
sharded tier dispatch ``backend="pallas"``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

DEFAULT_TILE_Q = 512


def _le_u64(khi, klo, qhi, qlo):
    """(khi,klo) <= (qhi,qlo) as unsigned 64-bit via u32 limbs."""
    return (khi < qhi) | ((khi == qhi) & (klo <= qlo))


def _rmi_body(u, qhi, qlo, thi, tlo, c, slope_a, icept_a, eps_a, rlo_a, rhi_a, *, b, n, steps):
    """The fused predict + bounded-search math on plain arrays.

    Shared by the single-table and batched kernels; every operand is a
    value (not a Ref), so the batched kernel can feed it per-table
    blocks squeezed down to the same shapes.
    """
    # --- stage 1: root -> leaf ---
    # clamp BEFORE the i32 cast: model blow-ups on key gaps predict
    # |p| ~ 1e15 in f32, and an out-of-range float->int32 cast is
    # implementation-defined garbage that survives the later clips.
    p_root = ((c[3] * u + c[2]) * u + c[1]) * u + c[0]
    p_root = jnp.clip(p_root, -1.0e9, 1.0e9)  # b/n <= 1 keeps the product in i32
    leaf = jnp.clip(jnp.floor(p_root * (b / n)).astype(jnp.int32), 0, b - 1)

    # --- stage 2: leaf linear predict + guaranteed window ---
    slope = jnp.take(slope_a, leaf)
    icept = jnp.take(icept_a, leaf)
    eps = jnp.take(eps_a, leaf)
    rlo = jnp.take(rlo_a, leaf)
    rhi = jnp.take(rhi_a, leaf)
    p = jnp.clip(slope * u + icept, -1.0e9, 1.0e9)  # +/-eps stays inside i32
    # clamp the predicted CENTER into the leaf fences before widening: a
    # prediction blown far past the leaf (f32 u collapse on dense
    # clusters) would otherwise collapse the ±ε window to one fence
    # slot; the true rank is always inside [rlo, rhi], so clamping the
    # center never increases |center - true|.
    p_lo = jnp.clip(jnp.floor(p).astype(jnp.int32), rlo, rhi)
    p_hi = jnp.clip(jnp.ceil(p).astype(jnp.int32), rlo, rhi)
    lo = jnp.clip(p_lo - eps, rlo, rhi)
    hi = jnp.clip(p_hi + eps, rlo, rhi)

    # --- stage 3: fixed-trip branch-free bounded search ---
    base = lo
    length = hi - lo + 1

    def body(_, carry):
        base, length = carry
        half = length >> 1
        mid = base + half
        khi = jnp.take(thi, mid)
        klo = jnp.take(tlo, mid)
        go_right = _le_u64(khi, klo, qhi, qlo) & (length > 1)
        base = jnp.where(go_right, mid, base)
        length = length - jnp.where(length > 1, half, 0)
        return base, length

    base, _ = lax.fori_loop(0, steps, body, (base, length))
    le = _le_u64(jnp.take(thi, base), jnp.take(tlo, base), qhi, qlo)
    return base + le.astype(jnp.int32) - 1


def _rmi_kernel(
    u_ref,
    qhi_ref,
    qlo_ref,
    thi_ref,
    tlo_ref,
    root_ref,
    slope_ref,
    icept_ref,
    eps_ref,
    rlo_ref,
    rhi_ref,
    out_ref,
    *,
    b: int,
    n: int,
    steps: int,
):
    out_ref[...] = _rmi_body(
        u_ref[...],  # (TQ,) f32, pre-normalised and clamped to [0,1]
        qhi_ref[...],
        qlo_ref[...],
        thi_ref[...],  # (N,) u32 table limbs
        tlo_ref[...],
        root_ref[...],  # (4,) f32
        slope_ref[...],
        icept_ref[...],
        eps_ref[...],
        rlo_ref[...],
        rhi_ref[...],
        b=b,
        n=n,
        steps=steps,
    )


def fused_rmi_search_pallas(
    u_f32,
    q_hi,
    q_lo,
    table_hi,
    table_lo,
    root_coef,
    leaf_slope,
    leaf_icept,
    leaf_eps,
    leaf_rlo,
    leaf_rhi,
    *,
    steps: int,
    tile_q: int = DEFAULT_TILE_Q,
    interpret: bool = True,
):
    """pallas_call wrapper.  Queries must be padded to a tile multiple."""
    nq = u_f32.shape[0]
    n = table_hi.shape[0]
    b = leaf_slope.shape[0]
    assert nq % tile_q == 0, "pad queries to a tile multiple (see ops.py)"
    grid = (nq // tile_q,)

    def qspec():
        return pl.BlockSpec((tile_q,), lambda i: (i,))

    def full(shape):
        return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    kernel = functools.partial(_rmi_kernel, b=b, n=n, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            qspec(),  # u
            qspec(),  # q_hi
            qspec(),  # q_lo
            full((n,)),  # table_hi
            full((n,)),  # table_lo
            full((4,)),  # root coef
            full((b,)),  # slope
            full((b,)),  # icept
            full((b,)),  # eps
            full((b,)),  # rlo
            full((b,)),  # rhi
        ],
        out_specs=qspec(),
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.int32),
        interpret=interpret,
    )(
        u_f32,
        q_hi,
        q_lo,
        table_hi,
        table_lo,
        root_coef,
        leaf_slope,
        leaf_icept,
        leaf_eps,
        leaf_rlo,
        leaf_rhi,
    )


def _rmi_kernel_batched(
    u_ref,
    qhi_ref,
    qlo_ref,
    thi_ref,
    tlo_ref,
    root_ref,
    slope_ref,
    icept_ref,
    eps_ref,
    rlo_ref,
    rhi_ref,
    out_ref,
    *,
    b: int,
    n: int,
    steps: int,
):
    # every block carries a leading table axis of extent 1: squeeze it
    # and reuse the single-table body verbatim
    out_ref[0, :] = _rmi_body(
        u_ref[0],
        qhi_ref[0],
        qlo_ref[0],
        thi_ref[0],
        tlo_ref[0],
        root_ref[0],
        slope_ref[0],
        icept_ref[0],
        eps_ref[0],
        rlo_ref[0],
        rhi_ref[0],
        b=b,
        n=n,
        steps=steps,
    )


def batched_rmi_search_pallas(
    u_f32,
    q_hi,
    q_lo,
    table_hi,
    table_lo,
    root_coef,
    leaf_slope,
    leaf_icept,
    leaf_eps,
    leaf_rlo,
    leaf_rhi,
    *,
    steps: int,
    tile_q: int = DEFAULT_TILE_Q,
    interpret: bool = True,
):
    """Batched/tier variant: ``(n_tables, nq)`` queries against
    ``(n_tables, n)`` tables with per-table leaf parameters.

    Grid is ``(table, q_tile)``; the index maps hand each program its
    table's parameter blocks (leading axis extent 1) and one query tile,
    so one trace answers the whole tier — the kernel-level analogue of
    the vmapped shared lookup.  ``steps`` must cover the *widest*
    per-table window (extra Khuong–Morin trips are no-ops, which is why
    the stacked Index takes the max across tables).
    """
    nt, nq = u_f32.shape
    n = table_hi.shape[1]
    b = leaf_slope.shape[1]
    assert nq % tile_q == 0, "pad queries to a tile multiple (see ops.py)"
    grid = (nt, nq // tile_q)

    def qspec():
        return pl.BlockSpec((1, tile_q), lambda t, i: (t, i))

    def per_table(m):
        return pl.BlockSpec((1, m), lambda t, i: (t, 0))

    kernel = functools.partial(_rmi_kernel_batched, b=b, n=n, steps=steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            qspec(),  # u
            qspec(),  # q_hi
            qspec(),  # q_lo
            per_table(n),  # table_hi
            per_table(n),  # table_lo
            per_table(4),  # root coef
            per_table(b),  # slope
            per_table(b),  # icept
            per_table(b),  # eps
            per_table(b),  # rlo
            per_table(b),  # rhi
        ],
        out_specs=qspec(),
        out_shape=jax.ShapeDtypeStruct((nt, nq), jnp.int32),
        interpret=interpret,
    )(
        u_f32,
        q_hi,
        q_lo,
        table_hi,
        table_lo,
        root_coef,
        leaf_slope,
        leaf_icept,
        leaf_eps,
        leaf_rlo,
        leaf_rhi,
    )
