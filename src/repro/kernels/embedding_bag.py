"""EmbeddingBag (gather + weighted segment-sum) — Pallas TPU kernel.

The recsys hot path (DESIGN.md §3).  JAX has no native EmbeddingBag; the
XLA path is ``take + segment_sum`` (see ref.py).  On TPU, row gathers
from VMEM are serialised — the MXU-native formulation is **one-hot
matmul over vocabulary tiles**:

    out[bag] += onehot_bags(B,N) @ (onehot_ids(N,Vt) @ slab(Vt,D))

The grid walks vocabulary tiles and revisits the same output block,
accumulating; both one-hot contractions hit the MXU.  This is the
VMEM-resident ("hot vocabulary") tier; the HBM-scale tables use the
sharded lookup in :mod:`repro.models.embedding`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _bag_kernel(table_ref, ids_ref, seg_ref, w_ref, out_ref, *, v_tile: int, num_bags: int):
    vt = pl.program_id(0)
    lo = vt * v_tile

    slab = table_ref[...]  # (Vt, D) f32
    ids = ids_ref[...]  # (N,) i32
    seg = seg_ref[...]  # (N,) i32
    w = w_ref[...]  # (N,) f32
    n_items = ids.shape[0]

    local = ids - lo
    in_tile = (ids >= lo) & (ids < lo + v_tile)

    # (N, Vt) one-hot of item ids within this vocab tile
    cols = lax.broadcasted_iota(jnp.int32, (n_items, v_tile), 1)
    oh_v = ((local[:, None] == cols) & in_tile[:, None]).astype(jnp.float32)
    item_vecs = oh_v @ slab  # (N, D) — MXU

    # (B, N) one-hot of bag membership, weighted
    rows = lax.broadcasted_iota(jnp.int32, (num_bags, n_items), 0)
    oh_b = (seg[None, :] == rows).astype(jnp.float32) * w[None, :]
    contrib = oh_b @ item_vecs  # (B, D) — MXU

    @pl.when(vt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += contrib


def embedding_bag_pallas(
    table,
    ids,
    seg_ids,
    weights,
    *,
    num_bags: int,
    v_tile: int = 512,
    interpret: bool = True,
):
    """table (V, D) f32; ids/seg_ids (N,) i32; weights (N,) f32 -> (B, D)."""
    v, d = table.shape
    assert v % v_tile == 0, "pad vocab to a tile multiple (see ops.py)"
    grid = (v // v_tile,)
    n = ids.shape[0]

    kernel = functools.partial(_bag_kernel, v_tile=v_tile, num_bags=num_bags)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_tile, d), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((num_bags, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_bags, d), jnp.float32),
        interpret=interpret,
    )(table, ids, seg_ids, weights)
