"""Pallas TPU kernels for the paper's compute hot spots (validated in
interpret mode on CPU; see DESIGN.md §3 for the TPU-native adaptations).

- rmi_search:      fused RMI predict + ε-bounded branch-free search
- kary_search:     lane-wide (k=128) k-ary search — TPU-native K-BFS
- embedding_bag:   one-hot-matmul EmbeddingBag over vocab tiles
- decode_attention: flash-decode GQA attention for the serve path
"""

from . import ops, ref
from .ops import (
    decode_attention,
    embedding_bag,
    fused_rmi_search,
    kary_search,
    prepare_rmi_kernel_index,
    split_u64,
)
