"""Pallas TPU kernels for the paper's compute hot spots (validated in
interpret mode on CPU; see DESIGN.md §3 for the TPU-native adaptations).

- rmi_search:      fused RMI predict + ε-bounded branch-free search,
                   single-table and batched (table, q_tile) grids
- pgm_search:      fused PGM descent — root route + per-level segment
                   gather + ε-window bounded search
- rs_search:       fused RadixSpline — radix gather + knot search +
                   error-window probe
- kary_search:     lane-wide (k=128) k-ary search — TPU-native K-BFS,
                   single-table and batched variants
- embedding_bag:   one-hot-matmul EmbeddingBag over vocab tiles
- decode_attention: flash-decode GQA attention for the serve path

The search kernels are reached through ``repro.index``: the f32/i32
re-encodings (``rmi_kernel_arrays`` / ``pgm_kernel_arrays`` /
``rs_kernel_arrays``) are folded into ``Index`` build as the
``k_*``/``pk_*``/``rk_*`` leaves, ``Index.lookup(..., backend="pallas")``
dispatches the fused kernels, and ``repro.index.batched_pallas_impl``
dispatches the batched grids for tiers/batches.
"""

from . import ops, ref
from .ops import (
    decode_attention,
    embedding_bag,
    kary_search,
    pgm_kernel_arrays,
    rmi_kernel_arrays,
    rs_kernel_arrays,
    split_u64,
)
