"""Pallas TPU kernels for the paper's compute hot spots (validated in
interpret mode on CPU; see DESIGN.md §3 for the TPU-native adaptations).

- rmi_search:      fused RMI predict + ε-bounded branch-free search
- kary_search:     lane-wide (k=128) k-ary search — TPU-native K-BFS
- embedding_bag:   one-hot-matmul EmbeddingBag over vocab tiles
- decode_attention: flash-decode GQA attention for the serve path

The search kernels are reached through ``repro.index``: the f32/i32
re-encoding (``rmi_kernel_arrays``) is folded into ``Index`` build and
``Index.lookup(..., backend="pallas")`` dispatches here.  The old
``prepare_rmi_kernel_index`` / ``fused_rmi_search`` pair remains as a
deprecated shim.
"""

from . import ops, ref
from .ops import (
    decode_attention,
    embedding_bag,
    fused_rmi_search,
    kary_search,
    prepare_rmi_kernel_index,
    rmi_kernel_arrays,
    split_u64,
)
