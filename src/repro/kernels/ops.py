"""Public jit'd wrappers around the Pallas kernels.

Handles host-side preparation (u32 limb split, f32 pre-normalisation,
query padding, f32-widened error bounds) and falls back to interpret
mode off-TPU.  ``ref.py`` holds the oracles; tests sweep shapes/dtypes.

The ``*_kernel_arrays`` re-encoders here serve both the single-table
fused kernels and their batched ``(table, q_tile)``-grid variants
(``batched_rmi_search_pallas`` / ``batched_pgm_search_pallas`` /
``batched_rs_search_pallas``): the re-encoded leaves stack leaf-wise
like the model arrays, and the bucketed trip-count statics merge by max
at stack time, so one re-encoding per table covers every dispatch path.
"""

from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.cdf import ceil_log2

from .rmi_search import DEFAULT_TILE_Q
from .kary_search import kary_search_pallas, LANES
from .embedding_bag import embedding_bag_pallas
from .decode_attention import decode_attention_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def split_u64(x_u64: np.ndarray):
    """uint64 -> (hi, lo) uint32 limbs (host or device arrays)."""
    x = jnp.asarray(x_u64, dtype=jnp.uint64)
    hi = (x >> jnp.uint64(32)).astype(jnp.uint32)
    lo = (x & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)
    return hi, lo


def _pad_to(x, mult, fill):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    return jnp.concatenate([x, jnp.full((pad,), fill, dtype=x.dtype)]), n


# ---------------------------------------------------------------------------
# Fused RMI search
# ---------------------------------------------------------------------------


def rmi_kernel_arrays(model, table_np: np.ndarray):
    """Re-encode a core.rmi.RMIModel in kernel precision, re-verifying ε.

    The kernel predicts in f32; we re-measure every leaf's max error with
    the kernel's exact arithmetic (f32 Horner on f32 u) and widen ε so
    the window remains a guarantee.  Returns ``(arrays, steps)`` where
    ``arrays`` holds the f32/i32 leaf parameters (``root``, ``slope``,
    ``icept``, ``eps``, ``rlo``, ``rhi``) — this is what
    :class:`repro.index.Index` folds into its pytree leaves at build
    time; ``Index.lookup(..., backend="pallas")`` runs the fused kernel.
    """
    n = model.n
    b = model.b
    kmin = np.float64(np.asarray(model.kmin))
    inv_span = np.float64(np.asarray(model.inv_span))

    u64 = (table_np.astype(np.float64) - kmin) * inv_span
    u32 = np.clip(u64, 0.0, 1.0).astype(np.float32)

    root = np.asarray(model.root_coef, dtype=np.float32)
    slopes = np.asarray(model.leaf_slope, dtype=np.float32)
    icepts = np.asarray(model.leaf_icept, dtype=np.float32)

    # leaf assignment with kernel arithmetic (f32)
    p_root = ((root[3] * u32 + root[2]) * u32 + root[1]) * u32 + root[0]
    leaf = np.clip(np.floor(p_root.astype(np.float64) * (b / n)), 0, b - 1).astype(np.int64)
    leaf = np.maximum.accumulate(leaf)
    r32 = np.searchsorted(leaf, np.arange(b + 1), side="left").astype(np.int64)

    # f32 leaf prediction error at every key (exactly the kernel math)
    pred = slopes[leaf] * u32 + icepts[leaf]
    ranks = np.arange(n, dtype=np.float64)
    err = np.abs(pred.astype(np.float64) - ranks)
    eps = np.zeros(b)
    np.maximum.at(eps, leaf, err)
    # extended boundary keys per leaf (guarantee argument, DESIGN.md §3)
    lo_idx = np.clip(r32[:-1] - 1, 0, n - 1)
    hi_idx = np.clip(r32[1:], 0, n - 1)
    err_lo = np.abs(slopes * u32[lo_idx] + icepts - ranks[lo_idx])
    err_hi = np.abs(slopes * u32[hi_idx] + icepts - ranks[hi_idx])
    eps = np.maximum(eps, np.maximum(err_lo, err_hi))
    eps_i = np.minimum(np.ceil(eps) + 2, float(n)).astype(np.int32)

    rlo = np.maximum(r32[:-1] - 1, 0).astype(np.int32)
    # high fence r32[l+1] (not -1): absorbs a 1-ulp leaf flip between the
    # host re-encoding and the kernel's f32 root eval (err_hi covers the
    # boundary key, so the widened window stays a guarantee).
    rhi = np.clip(r32[1:], 0, n - 1).astype(np.int32)
    widths = np.minimum(2 * eps_i.astype(np.int64) + 3, (rhi - rlo + 1).astype(np.int64))
    max_window = max(1, int(widths.max()))
    steps = max(1, int(math.ceil(math.log2(max(max_window, 2)))))

    arrays = {"root": root, "slope": slopes, "icept": icepts, "eps": eps_i, "rlo": rlo, "rhi": rhi}
    return arrays, steps


def pgm_kernel_arrays(model, table_np: np.ndarray):
    """Re-encode a :class:`repro.core.pgm.PGMModel` for the fused Pallas
    descent (:mod:`repro.kernels.pgm_search`), re-verifying ε.

    The kernel predicts per segment in f32 ``u`` space, anchored at the
    segment's own coordinate: ``pred = r0 + slope_u * max(u - u0, 0)``
    with ``slope_u = slope * span``.  This function re-measures every
    level's prediction error *with exactly that arithmetic* at every
    child entry (exact segment assignment — routing in the kernel is an
    exact limb-compare search) and widens ε so the window remains a
    guarantee; f32 rounding is monotone, so queries between keys stay
    covered, and the level fence clamp absorbs gap extrapolation just
    like the f64 path.

    Returns ``(arrays, steps)``: ``arrays`` holds the level-concatenated
    f32 leaves (``u0``, ``slope``) plus the scalar ``eps`` / ``kmin`` /
    ``inv_span``; ``steps`` is the unbucketed trip count for every
    in-kernel bounded search.  :mod:`repro.index.impls` folds these into
    the Index pytree as the ``pk_*`` leaves at build time, exactly as
    :func:`rmi_kernel_arrays` does for the RMI family.

    Example::

        m = build_pgm(table, eps=32)
        arrays, steps = pgm_kernel_arrays(m, table)
        assert arrays["u0"].shape[0] == sum(m.level_sizes)
    """
    n = model.n
    kmin = np.float64(table_np[0])
    span = np.float64(table_np[-1]) - kmin
    inv_span = np.float64(1.0) / span if span > 0 else np.float64(1.0)

    def u_of(keys_u64):
        u = (keys_u64.astype(np.float64) - kmin) * inv_span
        return np.clip(u, 0.0, 1.0).astype(np.float32)

    levels = len(model.level_keys)
    u0_parts, slope_parts = [], []
    max_err = 0.0
    for lvl in range(levels):
        keys_l = np.asarray(model.level_keys[lvl])
        u0_l = u_of(keys_l)
        slope_u = (np.asarray(model.level_slope[lvl]) * span).astype(np.float32)
        u0_parts.append(u0_l)
        slope_parts.append(slope_u)
        child = np.asarray(model.level_keys[lvl + 1]) if lvl + 1 < levels else table_np
        # exact segment assignment — mirrors the kernel's limb-compare route
        s = np.clip(np.searchsorted(keys_l, child, side="right") - 1, 0, len(keys_l) - 1)
        r0 = np.asarray(model.level_rank0[lvl])[s].astype(np.float32)
        du = np.maximum(u_of(child) - u0_l[s], np.float32(0.0))
        pred = r0 + slope_u[s] * du  # the kernel's f32 arithmetic, verbatim
        err = np.abs(pred.astype(np.float64) - np.arange(len(child), dtype=np.float64))
        if len(err):
            max_err = max(max_err, float(err.max()))
    # +2: one for between-keys interpolation drift beyond the widened ±1
    # the query path already adds, one for XLA fusing mul+add into an FMA
    eps = int(min(np.ceil(max_err) + 2, n))
    steps = ceil_log2(min(2 * (eps + 1) + 3, max(n, 2)))
    arrays = {
        "u0": np.concatenate(u0_parts),
        "slope": np.concatenate(slope_parts),
        "eps": eps,
        "kmin": kmin,
        "inv_span": inv_span,
    }
    return arrays, steps


def pgm_level_reencode_device(keys_l, slopes_l, start_l, nseg, child, child_count, kmin, span, inv_span):
    """Device (jittable) counterpart of ONE level of
    :func:`pgm_kernel_arrays`: re-encode a PGM level in the fused
    kernel's f32 anchored arithmetic and re-measure its prediction error
    at every *valid* child entry.

    Arrays are fixed-capacity with traced live counts: ``keys_l`` /
    ``slopes_l`` / ``start_l`` hold ``nseg`` valid segments (key pads
    are the max-key sentinel, so the segment route stays exact — see
    :func:`pgm_kernel_arrays` for the host-side arithmetic this
    replicates operation-for-operation), and ``child`` holds
    ``child_count`` valid entries whose errors count toward the bound.

    Returns ``(u0_l, slope_u, max_err)``; the caller accumulates the
    per-level errors into the widened ``pk_eps`` exactly as the host
    re-encoder does.

    Example::

        u0, su, err = pgm_level_reencode_device(
            lvl_keys, lvl_slopes, lvl_starts, nseg,
            child_keys, child_count, kmin, span, inv_span)
    """

    def u_of(keys_u64):
        u = (keys_u64.astype(jnp.float64) - kmin) * inv_span
        return jnp.clip(u, 0.0, 1.0).astype(jnp.float32)

    u0_l = u_of(keys_l)
    slope_u = (slopes_l * span).astype(jnp.float32)
    # exact segment assignment — max-key pads sort above every real child
    s = jnp.clip(
        jnp.searchsorted(keys_l, child, side="right") - 1, 0, jnp.maximum(nseg - 1, 0)
    )
    r0 = jnp.take(start_l, s).astype(jnp.float32)
    du = jnp.maximum(u_of(child) - jnp.take(u0_l, s), jnp.float32(0.0))
    pred = r0 + jnp.take(slope_u, s) * du  # the kernel's f32 arithmetic
    cap = child.shape[0]
    err = jnp.abs(pred.astype(jnp.float64) - jnp.arange(cap, dtype=jnp.float64))
    err = jnp.where(jnp.arange(cap) < child_count, err, 0.0)
    return u0_l, slope_u, jnp.max(err)


def rs_kernel_arrays_device(knot_keys, knot_ranks, m_valid, table_row, kmin, span, inv_span):
    """Device (jittable) counterpart of :func:`rs_kernel_arrays`:
    re-encode a RadixSpline knot set in the fused kernel's f32 anchored
    arithmetic and re-measure ε with that exact arithmetic.

    ``knot_keys`` / ``knot_ranks`` are fixed-capacity rows with
    ``m_valid`` live knots (max-key / edge sentinels beyond); every key
    of ``table_row`` is treated as valid (device refreshes fit on the
    padded capacity table, so ``n == table_row.shape[0]``).

    Returns ``(u0, slope, rk_eps)`` with ``rk_eps`` the widened i32
    bound — same ``ceil(max_err) + 2`` margin as the host re-encoder.

    Example::

        u0, sl, rk_eps = rs_kernel_arrays_device(
            kk, kr, m_valid, padded_tab, kmin, span, inv_span)
    """
    n = table_row.shape[0]
    cap = knot_keys.shape[0]

    def u_of(keys_u64):
        u = (keys_u64.astype(jnp.float64) - kmin) * inv_span
        return jnp.clip(u, 0.0, 1.0).astype(jnp.float32)

    u0 = u_of(knot_keys)
    i = jnp.arange(cap)
    nxt = jnp.minimum(i + 1, cap - 1)
    dy = (jnp.take(knot_ranks, nxt) - knot_ranks).astype(jnp.float32)
    du = jnp.take(u0, nxt) - u0
    valid_pair = (i + 1) < m_valid
    # u-collided knot pairs (f32 resolution) predict y1 flat, like host
    slope = jnp.where(valid_pair & (du > 0), dy / jnp.where(du > 0, du, 1.0), 0.0).astype(
        jnp.float32
    )
    j = jnp.clip(
        jnp.searchsorted(knot_keys, table_row, side="right") - 1,
        0,
        jnp.maximum(m_valid - 2, 0),
    )
    y1 = jnp.take(knot_ranks, j).astype(jnp.float32)
    pred = y1 + jnp.take(slope, j) * jnp.maximum(
        u_of(table_row) - jnp.take(u0, j), jnp.float32(0.0)
    )
    err = jnp.abs(pred.astype(jnp.float64) - jnp.arange(n, dtype=jnp.float64))
    # boundary extension: each knot under its left segment's model
    pred_b = knot_ranks.astype(jnp.float32) + slope * jnp.maximum(du, jnp.float32(0.0))
    err_b = jnp.abs(pred_b.astype(jnp.float64) - jnp.take(knot_ranks, nxt).astype(jnp.float64))
    err_b = jnp.where(valid_pair, err_b, 0.0)
    max_err = jnp.maximum(jnp.max(err), jnp.max(err_b))
    rk_eps = jnp.minimum(jnp.ceil(max_err) + 2.0, float(n)).astype(jnp.int32)
    return u0, slope, rk_eps


def rs_kernel_arrays(model, table_np: np.ndarray):
    """Re-encode a :class:`repro.core.radix_spline.RSModel` for the fused
    Pallas lookup (:mod:`repro.kernels.rs_search`), re-verifying ε.

    Interpolation between knots is re-anchored in f32 ``u`` space with a
    precomputed per-knot-segment slope: ``pred = y1 + slope_j *
    max(u - u1, 0)``.  The error of that exact arithmetic is re-measured
    at every table key *and* at every knot evaluated under its left
    neighbour's segment (the boundary a query can reach just below a
    knot), and ε widens accordingly, so the reported window stays a
    guarantee under f32 rounding (which is monotone between knots).

    Returns ``(arrays, steps)``: f32 ``u0``/``slope`` per knot plus the
    scalar ``eps``/``kmin``/``inv_span``, and the unbucketed trip count
    of the final window probe.  Folded into the Index as ``rk_*`` leaves
    at build time.

    Example::

        m = build_rs(table, eps=32, r_bits=10)
        arrays, steps = rs_kernel_arrays(m, table)
        assert arrays["u0"].shape[0] == m.m
    """
    n = model.n
    m = model.m
    knot_keys = np.asarray(model.knot_keys)[:m]
    knot_ranks = np.asarray(model.knot_ranks)[:m]
    kmin = np.float64(np.asarray(model.kmin))
    span = np.float64(table_np[-1]) - kmin
    inv_span = np.float64(1.0) / span if span > 0 else np.float64(1.0)

    def u_of(keys_u64):
        u = (keys_u64.astype(np.float64) - kmin) * inv_span
        return np.clip(u, 0.0, 1.0).astype(np.float32)

    u0 = u_of(knot_keys)
    slope = np.zeros(m, dtype=np.float32)
    if m >= 2:
        dy = (knot_ranks[1:] - knot_ranks[:-1]).astype(np.float32)
        du = u0[1:] - u0[:-1]
        # u-collided knot pairs (f32 resolution) predict y1 flat; the
        # measured ε absorbs the rank span they cover
        np.divide(dy, du, out=slope[:-1], where=du > 0)
        j = np.clip(np.searchsorted(knot_keys, table_np, side="right") - 1, 0, m - 2)
        y1 = knot_ranks[j].astype(np.float32)
        pred = y1 + slope[j] * np.maximum(u_of(table_np) - u0[j], np.float32(0.0))
        err = np.abs(pred.astype(np.float64) - np.arange(n, dtype=np.float64))
        # boundary extension: each knot under its left segment's model
        pred_b = knot_ranks[:-1].astype(np.float32) + slope[:-1] * np.maximum(du, np.float32(0.0))
        err_b = np.abs(pred_b.astype(np.float64) - knot_ranks[1:].astype(np.float64))
        max_err = max(float(err.max()), float(err_b.max()))
        eps = int(min(np.ceil(max_err) + 2, n))
    else:
        eps = max(int(n), 1)
    steps = ceil_log2(min(2 * eps + 3, max(n, 2)))
    arrays = {"u0": u0, "slope": slope, "eps": eps, "kmin": kmin, "inv_span": inv_span}
    return arrays, steps


# ---------------------------------------------------------------------------
# Lane-wide k-ary search
# ---------------------------------------------------------------------------


def kary_search(table_u64, queries_u64, *, k: int = LANES, tile_q: int = DEFAULT_TILE_Q):
    thi, tlo = split_u64(table_u64)
    qhi, qlo = split_u64(queries_u64)
    qhi, nq = _pad_to(qhi, tile_q, 0)
    qlo, _ = _pad_to(qlo, tile_q, 0)
    out = kary_search_pallas(qhi, qlo, thi, tlo, k=k, tile_q=tile_q, interpret=_interpret())
    return out[:nq]


# ---------------------------------------------------------------------------
# EmbeddingBag
# ---------------------------------------------------------------------------


def embedding_bag(table, ids, seg_ids, weights=None, *, num_bags: int, v_tile: int = 512):
    table = jnp.asarray(table, jnp.float32)
    v, d = table.shape
    pad_v = (-v) % v_tile
    if pad_v:
        table = jnp.concatenate([table, jnp.zeros((pad_v, d), jnp.float32)])
    ids = jnp.asarray(ids, jnp.int32)
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    if weights is None:
        weights = jnp.ones(ids.shape, jnp.float32)
    return embedding_bag_pallas(
        table, ids, seg_ids, jnp.asarray(weights, jnp.float32),
        num_bags=num_bags, v_tile=v_tile, interpret=_interpret(),
    )


# ---------------------------------------------------------------------------
# Flash-decode attention
# ---------------------------------------------------------------------------


def decode_attention(q, k, v, kv_len, *, s_tile: int = 256):
    q = jnp.asarray(q, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    b, s, hkv, d = k.shape
    pad_s = (-s) % s_tile
    if pad_s:
        zk = jnp.zeros((b, pad_s, hkv, d), jnp.float32)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
    return decode_attention_pallas(
        q, k, v, jnp.asarray(kv_len, jnp.int32), s_tile=s_tile, interpret=_interpret()
    )
