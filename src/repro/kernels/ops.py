"""Public jit'd wrappers around the Pallas kernels.

Handles host-side preparation (u32 limb split, f32 pre-normalisation,
query padding, f32-widened error bounds) and falls back to interpret
mode off-TPU.  ``ref.py`` holds the oracles; tests sweep shapes/dtypes.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from .rmi_search import fused_rmi_search_pallas, DEFAULT_TILE_Q
from .kary_search import kary_search_pallas, LANES
from .embedding_bag import embedding_bag_pallas
from .decode_attention import decode_attention_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def split_u64(x_u64: np.ndarray):
    """uint64 -> (hi, lo) uint32 limbs (host or device arrays)."""
    x = jnp.asarray(x_u64, dtype=jnp.uint64)
    hi = (x >> jnp.uint64(32)).astype(jnp.uint32)
    lo = (x & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)
    return hi, lo


def _pad_to(x, mult, fill):
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    return jnp.concatenate([x, jnp.full((pad,), fill, dtype=x.dtype)]), n


# ---------------------------------------------------------------------------
# Fused RMI search
# ---------------------------------------------------------------------------


@dataclass
class RMIKernelIndex:
    """f32/u32 re-encoding of a core RMIModel for the TPU kernel."""

    table_hi: jnp.ndarray
    table_lo: jnp.ndarray
    root_coef: jnp.ndarray  # (4,) f32
    leaf_slope: jnp.ndarray  # f32
    leaf_icept: jnp.ndarray  # f32
    leaf_eps: jnp.ndarray  # i32
    leaf_rlo: jnp.ndarray  # i32
    leaf_rhi: jnp.ndarray  # i32
    kmin: np.float64
    inv_span: np.float64
    steps: int
    n: int
    b: int


def rmi_kernel_arrays(model, table_np: np.ndarray):
    """Re-encode a core.rmi.RMIModel in kernel precision, re-verifying ε.

    The kernel predicts in f32; we re-measure every leaf's max error with
    the kernel's exact arithmetic (f32 Horner on f32 u) and widen ε so
    the window remains a guarantee.  Returns ``(arrays, steps)`` where
    ``arrays`` holds the f32/i32 leaf parameters (``root``, ``slope``,
    ``icept``, ``eps``, ``rlo``, ``rhi``) — this is what
    :class:`repro.index.Index` folds into its pytree leaves at build
    time, replacing the old separate ``prepare_rmi_kernel_index`` step.
    """
    n = model.n
    b = model.b
    kmin = np.float64(np.asarray(model.kmin))
    inv_span = np.float64(np.asarray(model.inv_span))

    u64 = (table_np.astype(np.float64) - kmin) * inv_span
    u32 = np.clip(u64, 0.0, 1.0).astype(np.float32)

    root = np.asarray(model.root_coef, dtype=np.float32)
    slopes = np.asarray(model.leaf_slope, dtype=np.float32)
    icepts = np.asarray(model.leaf_icept, dtype=np.float32)

    # leaf assignment with kernel arithmetic (f32)
    p_root = ((root[3] * u32 + root[2]) * u32 + root[1]) * u32 + root[0]
    leaf = np.clip(np.floor(p_root.astype(np.float64) * (b / n)), 0, b - 1).astype(np.int64)
    leaf = np.maximum.accumulate(leaf)
    r32 = np.searchsorted(leaf, np.arange(b + 1), side="left").astype(np.int64)

    # f32 leaf prediction error at every key (exactly the kernel math)
    pred = slopes[leaf] * u32 + icepts[leaf]
    ranks = np.arange(n, dtype=np.float64)
    err = np.abs(pred.astype(np.float64) - ranks)
    eps = np.zeros(b)
    np.maximum.at(eps, leaf, err)
    # extended boundary keys per leaf (guarantee argument, DESIGN.md §3)
    lo_idx = np.clip(r32[:-1] - 1, 0, n - 1)
    hi_idx = np.clip(r32[1:], 0, n - 1)
    err_lo = np.abs(slopes * u32[lo_idx] + icepts - ranks[lo_idx])
    err_hi = np.abs(slopes * u32[hi_idx] + icepts - ranks[hi_idx])
    eps = np.maximum(eps, np.maximum(err_lo, err_hi))
    eps_i = np.minimum(np.ceil(eps) + 2, float(n)).astype(np.int32)

    rlo = np.maximum(r32[:-1] - 1, 0).astype(np.int32)
    # high fence r32[l+1] (not -1): absorbs a 1-ulp leaf flip between the
    # host re-encoding and the kernel's f32 root eval (err_hi covers the
    # boundary key, so the widened window stays a guarantee).
    rhi = np.clip(r32[1:], 0, n - 1).astype(np.int32)
    widths = np.minimum(2 * eps_i.astype(np.int64) + 3, (rhi - rlo + 1).astype(np.int64))
    max_window = max(1, int(widths.max()))
    steps = max(1, int(math.ceil(math.log2(max(max_window, 2)))))

    arrays = {"root": root, "slope": slopes, "icept": icepts, "eps": eps_i, "rlo": rlo, "rhi": rhi}
    return arrays, steps


def prepare_rmi_kernel_index(model, table_np: np.ndarray) -> RMIKernelIndex:
    """DEPRECATED shim — build an :class:`repro.index.Index` instead; the
    kernel re-encoding now happens at Index construction and the fused
    kernel runs via ``Index.lookup(..., backend="pallas")``."""
    arrays, steps = rmi_kernel_arrays(model, table_np)
    thi, tlo = split_u64(table_np)
    return RMIKernelIndex(
        table_hi=thi,
        table_lo=tlo,
        root_coef=jnp.asarray(arrays["root"]),
        leaf_slope=jnp.asarray(arrays["slope"]),
        leaf_icept=jnp.asarray(arrays["icept"]),
        leaf_eps=jnp.asarray(arrays["eps"]),
        leaf_rlo=jnp.asarray(arrays["rlo"]),
        leaf_rhi=jnp.asarray(arrays["rhi"]),
        kmin=np.float64(np.asarray(model.kmin)),
        inv_span=np.float64(np.asarray(model.inv_span)),
        steps=steps,
        n=model.n,
        b=model.b,
    )


def fused_rmi_search(kidx: RMIKernelIndex, queries_u64, *, tile_q: int = DEFAULT_TILE_Q):
    """Predecessor ranks via the fused Pallas kernel (auto-padded)."""
    q = jnp.asarray(queries_u64, dtype=jnp.uint64)
    u = (q.astype(jnp.float64) - kidx.kmin) * kidx.inv_span
    u = jnp.clip(u, 0.0, 1.0).astype(jnp.float32)
    qhi, qlo = split_u64(q)
    u, nq = _pad_to(u, tile_q, 0.0)
    qhi, _ = _pad_to(qhi, tile_q, 0)
    qlo, _ = _pad_to(qlo, tile_q, 0)
    out = fused_rmi_search_pallas(
        u,
        qhi,
        qlo,
        kidx.table_hi,
        kidx.table_lo,
        kidx.root_coef,
        kidx.leaf_slope,
        kidx.leaf_icept,
        kidx.leaf_eps,
        kidx.leaf_rlo,
        kidx.leaf_rhi,
        steps=kidx.steps,
        tile_q=tile_q,
        interpret=_interpret(),
    )
    return out[:nq]


# ---------------------------------------------------------------------------
# Lane-wide k-ary search
# ---------------------------------------------------------------------------


def kary_search(table_u64, queries_u64, *, k: int = LANES, tile_q: int = DEFAULT_TILE_Q):
    thi, tlo = split_u64(table_u64)
    qhi, qlo = split_u64(queries_u64)
    qhi, nq = _pad_to(qhi, tile_q, 0)
    qlo, _ = _pad_to(qlo, tile_q, 0)
    out = kary_search_pallas(qhi, qlo, thi, tlo, k=k, tile_q=tile_q, interpret=_interpret())
    return out[:nq]


# ---------------------------------------------------------------------------
# EmbeddingBag
# ---------------------------------------------------------------------------


def embedding_bag(table, ids, seg_ids, weights=None, *, num_bags: int, v_tile: int = 512):
    table = jnp.asarray(table, jnp.float32)
    v, d = table.shape
    pad_v = (-v) % v_tile
    if pad_v:
        table = jnp.concatenate([table, jnp.zeros((pad_v, d), jnp.float32)])
    ids = jnp.asarray(ids, jnp.int32)
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    if weights is None:
        weights = jnp.ones(ids.shape, jnp.float32)
    return embedding_bag_pallas(
        table, ids, seg_ids, jnp.asarray(weights, jnp.float32),
        num_bags=num_bags, v_tile=v_tile, interpret=_interpret(),
    )


# ---------------------------------------------------------------------------
# Flash-decode attention
# ---------------------------------------------------------------------------


def decode_attention(q, k, v, kv_len, *, s_tile: int = 256):
    q = jnp.asarray(q, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    b, s, hkv, d = k.shape
    pad_s = (-s) % s_tile
    if pad_s:
        zk = jnp.zeros((b, pad_s, hkv, d), jnp.float32)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
    return decode_attention_pallas(
        q, k, v, jnp.asarray(kv_len, jnp.int32), s_tile=s_tile, interpret=_interpret()
    )
