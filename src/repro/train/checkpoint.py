"""Sharded, step-atomic, resharding-capable checkpointing (no orbax).

Layout:  <dir>/step_<N>/
           manifest.json   — tree structure, shapes, dtypes, checksums
           leaf_<i>.npy    — one file per pytree leaf (host-gathered)
         <dir>/LATEST      — atomically updated pointer (write+rename)

Properties needed at 1000-node scale, all implemented and tested:
  * step-atomic: a crash mid-write can never corrupt LATEST
  * async: the host gather happens synchronously (cheap), the disk write
    runs on a background thread
  * elastic restore: leaves are restored with ``jax.device_put`` against
    the *current* mesh's shardings — a 512-chip checkpoint restores onto
    any other mesh (resharding is free at load)
  * integrity: per-leaf crc32 checksums verified on load
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from pathlib import Path

import numpy as np
import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir, state, step: int, async_write: bool = True):
    """Save pytree ``state`` at ``step``.  Returns a join()-able handle."""
    ckpt_dir = Path(ckpt_dir)
    tmp = ckpt_dir / f".tmp_step_{step}"
    final = ckpt_dir / f"step_{step}"
    tmp.mkdir(parents=True, exist_ok=True)

    paths, leaves, _ = _flatten_with_paths(state)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

    def write():
        manifest = {"step": step, "leaves": []}
        for i, (p, arr) in enumerate(zip(paths, host_leaves)):
            fn = f"leaf_{i}.npy"
            np.save(tmp / fn, arr)
            manifest["leaves"].append(
                {
                    "path": p,
                    "file": fn,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF,
                }
            )
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
        if final.exists():
            import shutil

            shutil.rmtree(final)
        os.rename(tmp, final)
        latest_tmp = ckpt_dir / ".LATEST.tmp"
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.rename(latest_tmp, ckpt_dir / "LATEST")  # atomic pointer flip

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir):
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(ckpt_dir, state_template, step: int | None = None, shardings=None):
    """Restore into the structure of ``state_template``.

    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put against the *current* mesh (elastic resharding).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)

    paths, leaves, treedef = _flatten_with_paths(state_template)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(leaves)
    )

    out = []
    for p, tmpl, shd in zip(paths, leaves, shard_leaves):
        e = by_path[p]
        arr = np.load(d / e["file"])
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
        if crc != e["crc32"]:
            raise IOError(f"checksum mismatch for leaf {p}")
        if list(arr.shape) != list(tmpl.shape):
            raise ValueError(f"shape mismatch for {p}: {arr.shape} vs {tmpl.shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step
