"""Optimizers from scratch (no optax): AdamW and Adafactor.

Moments are f32 regardless of param dtype and shard exactly like the
parameters (ZeRO-3 equivalent under the FSDP rules).  The API mirrors
the (init, update) pair convention so the train step stays generic.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def adamw_update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0):
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_n = b1 * m + (1 - b1) * g32
        v_n = b2 * v + (1 - b2) * g32 * g32
        mh = m_n / bc1
        vh = v_n / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return m_n, v_n, p_n.astype(p.dtype)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    flat_p = jax.tree_util.tree_leaves(params)
    ms, vs, ps = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m_n, v_n, p_n = upd(g, m, v, p)
        ms.append(m_n)
        vs.append(v_n)
        ps.append(p_n)
    unf = partial(jax.tree_util.tree_unflatten, tdef)
    return unf(ps), {"step": step, "m": unf(ms), "v": unf(vs)}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment — the memory-lean option at scale)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0


def _factored(shape):
    return len(shape) >= 2


def adafactor_init(params):
    def one(p):
        if _factored(p.shape):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"step": jnp.zeros((), jnp.int32), "v": jax.tree.map(one, params,
            is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(grads, state, params, cfg: AdafactorConfig, lr_scale=1.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-cfg.decay)
    lr = cfg.lr * lr_scale

    def upd(g, v, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + cfg.eps
        if _factored(p.shape):
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            rfac = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), cfg.eps)
            u = g32 / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(vc)[..., None, :] + cfg.eps)
            v_n = {"vr": vr, "vc": vc}
        else:
            vn = beta * v["v"] + (1 - beta) * g2
            u = g32 / (jnp.sqrt(vn) + cfg.eps)
            v_n = {"v": vn}
        rms = jnp.sqrt(jnp.mean(u * u) + cfg.eps)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        return v_n, (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_v = state["v"]
    # walk the v-tree in the same flattened order
    flat_vs = jax.tree_util.tree_flatten(
        flat_v, is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    )[0]
    flat_p = jax.tree_util.tree_leaves(params)
    vs, ps = [], []
    for g, v, p in zip(flat_g, flat_vs, flat_p):
        v_n, p_n = upd(g, v, p)
        vs.append(v_n)
        ps.append(p_n)
    unf = partial(jax.tree_util.tree_unflatten, tdef)
    return unf(ps), {"step": step, "v": unf(vs)}


def sgd_init(params):
    return {"step": jnp.zeros((), jnp.int32)}


def sgd_update(grads, state, params, lr: float = 1e-2, lr_scale=1.0):
    ps = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * lr_scale * g.astype(jnp.float32)).astype(
            p.dtype
        ),
        params,
        grads,
    )
    return ps, {"step": state["step"] + 1}


OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update, AdamWConfig),
    "adafactor": (adafactor_init, adafactor_update, AdafactorConfig),
}
