"""Generic train step: loss -> grads -> (compression) -> clip -> update.

The step is family-agnostic: a ``loss_fn(params, batch)`` closure comes
from the model zoo, the optimizer from optimizer.py, compression from
dist.collectives.  Microbatch gradient accumulation loops inside the
step with ``lax.scan`` so HLO stays compact and the accumulated grads
live in f32.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.dist import collectives
from . import optimizer as opt
from . import schedule as sched


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    lr: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "warmup_cosine"
    warmup: int = 100
    total_steps: int = 10_000
    grad_compression: str = "none"  # none | bf16 | int8
    microbatches: int = 1


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree), norm


def init_train_state(rng, init_fn, tcfg: TrainConfig):
    params = init_fn(rng)
    init, _, occfg = opt.OPTIMIZERS[tcfg.optimizer]
    state = {
        "params": params,
        "opt": init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if tcfg.grad_compression != "none":
        state["comp_err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return state


def make_train_step(loss_fn, tcfg: TrainConfig):
    """loss_fn(params, batch) -> scalar.  Returns step(state, batch)."""
    _, update, occls = opt.OPTIMIZERS[tcfg.optimizer]
    ocfg = occls(lr=tcfg.lr)
    if tcfg.optimizer == "adamw":
        ocfg = opt.AdamWConfig(lr=tcfg.lr, weight_decay=tcfg.weight_decay)
    schedule = partial(
        sched.SCHEDULES[tcfg.schedule], warmup=tcfg.warmup, total=tcfg.total_steps
    )

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            acc, _ = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc, g)
            return (acc, l), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        mbs = jax.tree.map(
            lambda x: x.reshape(tcfg.microbatches, -1, *x.shape[1:]), batch
        )
        (acc, last_l), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
        n = jnp.float32(tcfg.microbatches)
        return last_l, jax.tree.map(lambda g: g / n, acc)

    def step(state, batch):
        loss, grads = grads_of(state["params"], batch)
        if tcfg.grad_compression != "none":
            grads, new_err = collectives.apply_grad_compression(
                grads, state["comp_err"], tcfg.grad_compression
            )
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr_scale = schedule(state["step"])
        new_params, new_opt = update(grads, state["opt"], state["params"], ocfg, lr_scale)
        out = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        if tcfg.grad_compression != "none":
            out["comp_err"] = new_err
        metrics = {"loss": loss, "grad_norm": gnorm, "lr_scale": lr_scale}
        return out, metrics

    return step
