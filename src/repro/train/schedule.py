"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 100, total: int = 10_000, floor: float = 0.1):
    # step+1: the first optimizer step must not be a zero-LR no-op
    s = (step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)) + 1.0
    w = jnp.minimum(s / max(warmup, 1), 1.0)
    t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return w * cos


def constant(step, **_):
    return jnp.float32(1.0)


def inv_sqrt(step, *, warmup: int = 100, **_):
    s = jnp.maximum(step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step), 1.0)
    return jnp.minimum(s / max(warmup, 1), jnp.sqrt(jnp.float32(max(warmup, 1)) / s))


SCHEDULES = {"warmup_cosine": warmup_cosine, "constant": constant, "inv_sqrt": inv_sqrt}
