"""Fault-tolerant training loop: checkpoint/restart, preemption hooks,
straggler detection.

The loop is deliberately host-side-simple: a jitted ``step_fn`` does all
device work; the loop adds the production concerns —

  * periodic async checkpoints + restore-on-start (restart replays the
    data order exactly because the batcher is a pure function of step)
  * a preemption flag (SIGTERM on real fleets; injectable in tests) that
    forces a final checkpoint and clean exit
  * straggler detection: per-step wall time EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged and counted (on a fleet this
    feeds the controller that evicts slow hosts)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.obs.timing import stopwatch
from . import checkpoint


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    straggler_factor: float = 3.0


@dataclass
class LoopReport:
    steps_run: int
    final_step: int
    losses: list = field(default_factory=list)
    straggler_steps: list = field(default_factory=list)
    restored_from: Optional[int] = None
    preempted: bool = False


def run(
    step_fn,
    state,
    batch_at: Callable[[int], dict],
    cfg: LoopConfig,
    shardings=None,
    preempt_flag: Optional[Callable[[], bool]] = None,
    log=print,
) -> tuple:
    """Run the loop; returns (state, LoopReport)."""
    report = LoopReport(steps_run=0, final_step=0)
    start_step = 0

    if cfg.ckpt_dir is not None:
        latest = checkpoint.latest_step(cfg.ckpt_dir)
        if latest is not None:
            state, start_step = checkpoint.restore(cfg.ckpt_dir, state, shardings=shardings)
            report.restored_from = start_step
            log(f"[loop] restored checkpoint at step {start_step}")

    ewma = None
    pending = None
    for step in range(start_step, cfg.total_steps):
        sw = stopwatch()
        batch = batch_at(step)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = sw.elapsed

        report.steps_run += 1
        report.losses.append(loss)
        if ewma is None:
            ewma = dt
        else:
            if dt > cfg.straggler_factor * ewma:
                report.straggler_steps.append((step, dt, ewma))
                log(f"[loop] straggler step {step}: {dt:.3f}s vs EWMA {ewma:.3f}s")
            ewma = 0.9 * ewma + 0.1 * dt

        if cfg.log_every and (step + 1) % cfg.log_every == 0:
            log(f"[loop] step {step + 1} loss {loss:.4f} ({dt * 1e3:.1f} ms)")

        next_step = step + 1
        if cfg.ckpt_dir and cfg.ckpt_every and next_step % cfg.ckpt_every == 0:
            if pending is not None:
                pending.join()
            pending = checkpoint.save(cfg.ckpt_dir, state, next_step)

        if preempt_flag is not None and preempt_flag():
            log(f"[loop] preemption at step {next_step}: checkpoint + exit")
            if pending is not None:
                pending.join()
            if cfg.ckpt_dir:
                checkpoint.save(cfg.ckpt_dir, state, next_step, async_write=False)
            report.preempted = True
            report.final_step = next_step
            return state, report

    if pending is not None:
        pending.join()
    if cfg.ckpt_dir:
        checkpoint.save(cfg.ckpt_dir, state, cfg.total_steps, async_write=False)
    report.final_step = cfg.total_steps
    return state, report
