"""Training substrate: optimizers (from scratch), schedules, generic
train step (grad compression + clipping + accumulation), sharded
checkpointing with elastic restore, fault-tolerant loop."""

from . import checkpoint, loop, optimizer, schedule, step
from .step import TrainConfig, init_train_state, make_train_step
