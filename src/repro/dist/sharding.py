"""Logical-axis sharding contexts.

Model and launch code talk in *logical* axes — ``dp`` (data parallel),
``fsdp`` (parameter shards), ``tp`` (tensor parallel), ``ep`` (expert
parallel), ``edge`` (GNN edge shards), ``row`` (embedding-table rows) —
and a :class:`ShardingCtx` resolves them onto the physical mesh axes the
launcher built (``('data', 'model')`` single-pod, ``('pod', 'data',
'model')`` multi-pod; see :mod:`repro.launch.mesh`).

Two profiles cover the repo's architectures:

* ``tp_fsdp`` (LMs): dp/fsdp over the data-like axes, tp/ep over
  ``model``.
* ``flat_dp`` (recsys / GNN): every logical data axis flattens over the
  whole mesh; tp/ep are unused.

``edge`` and ``row`` always span the full mesh — both are "shard the big
flat thing over everything" axes.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PROFILES = ("tp_fsdp", "flat_dp")

# logical name -> which mesh axes (by preference) it may occupy
_DATA_AXES = ("pod", "data")
_MODEL_AXES = ("model",)


def _rules_for(profile: str, mesh_axes: tuple) -> dict:
    present = tuple(a for a in mesh_axes)
    data = tuple(a for a in _DATA_AXES if a in present)
    model = tuple(a for a in _MODEL_AXES if a in present)
    if profile == "tp_fsdp":
        rules = {"dp": data, "fsdp": data, "tp": model, "ep": model}
    elif profile == "flat_dp":
        rules = {"dp": present, "fsdp": present, "tp": (), "ep": ()}
    else:
        raise ValueError(f"unknown sharding profile {profile!r}; choose from {PROFILES}")
    rules["edge"] = present
    rules["row"] = present
    return rules


@dataclass
class ShardingCtx:
    """Resolves logical axis names against a concrete mesh.

    ``rules`` maps each logical name to a (possibly empty) tuple of mesh
    axis names; model code may read it directly (e.g. for shard_map
    in_specs) or go through :meth:`sharding` / :meth:`constrain`.
    """

    mesh: Mesh
    profile: str = "tp_fsdp"
    rules: dict = field(default_factory=dict)

    def __post_init__(self):
        if not self.rules:
            self.rules = _rules_for(self.profile, tuple(self.mesh.axis_names))
        # normalise user-supplied rules: a bare string ("model") is one
        # mesh axis, not an iterable of single-character axis names
        self.rules = {
            k: ((v,) if isinstance(v, str) else tuple(v or ())) for k, v in self.rules.items()
        }

    # -- resolution -------------------------------------------------------
    def _resolve(self, logical):
        if logical is None:
            return None
        if isinstance(logical, tuple):  # already-flat tuple of logical names
            axes = []
            for l in logical:
                r = self._resolve(l)
                if r is None:
                    continue
                axes.extend(r if isinstance(r, tuple) else (r,))
            if not axes:
                return None
            return axes[0] if len(axes) == 1 else tuple(axes)
        ax = self.rules.get(logical, ())
        if not ax:
            return None
        return ax[0] if len(ax) == 1 else tuple(ax)

    def spec(self, *logical) -> P:
        return P(*[self._resolve(l) for l in logical])

    def sharding(self, *logical) -> NamedSharding:
        """NamedSharding for a value whose dims carry these logical axes."""
        return NamedSharding(self.mesh, self.spec(*logical))

    def constrain(self, x, *logical):
        """with_sharding_constraint, a no-op on a single-device mesh."""
        if self.mesh.size == 1:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(*logical))

    def mesh_axes(self, logical: str) -> tuple:
        """Mesh axis names a logical axis resolves to (possibly empty).

        shard_map callers need the *physical* axis names for collectives
        (``lax.all_to_all``/``psum`` take mesh axes, not logical ones).
        """
        return tuple(self.rules.get(logical, ()))

    def n(self, logical: str) -> int:
        """Number of shards a logical axis resolves to (1 if unmapped).

        Returns the resolved product over *all* mesh axes the logical
        axis occupies — size-1-padded axes multiply in as 1 rather than
        being dropped — and refuses to silently treat a rule that names
        a mesh axis absent from this mesh as unmapped.
        """
        out = 1
        for a in self.mesh_axes(logical):
            if a not in self.mesh.shape:
                raise ValueError(
                    f"logical axis {logical!r} resolves to mesh axis {a!r}, "
                    f"which is not on this mesh (axes: {tuple(self.mesh.axis_names)})"
                )
            out *= int(self.mesh.shape[a])
        return out


def single_device_ctx(profile: str = "tp_fsdp") -> ShardingCtx:
    """A (1, 1) ``('data', 'model')`` mesh on the first local device."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return ShardingCtx(mesh=mesh, profile=profile)
