"""Sharded multi-table predecessor lookup under ``shard_map``.

A serving tier holds *many* sorted tables — one per shard of a
partitioned keyspace — and an :class:`~repro.index.Index` is a pytree
precisely so a tier of same-spec per-shard indexes can be **stacked
leaf-wise** into one :class:`ShardedIndex` whose leading axis is the
shard axis.  One ``shard_map`` over the ``tp`` logical axis of
:class:`~repro.dist.sharding.ShardingCtx` then queries the whole tier
with a four-stage pipeline:

1. **fence** — every device holds the (tiny, replicated) fence array of
   shard boundary keys; a branch-free lane-wide k-ary compare
   (:func:`repro.kernels.kary_search.kary_owner_route`) assigns each
   resident query its owner shard.  Exact fence keys route to the shard
   that *starts* with them.
2. **route** — queries are bucketed by owner (argsort + branch-free
   boundary search, the ``_a2a_lookup`` pattern from
   :mod:`repro.models.embedding`) into a capacity-factored
   ``(n_shards, cap)`` request matrix and exchanged with ONE
   ``lax.all_to_all``.
3. **answer** — each shard answers the requests it owns against its
   *resident* index leaf through the shared traceable query body
   (:func:`repro.index.lookup_impl` — same code path as single-table
   ``Index.lookup``, so results are bit-identical to the concatenated
   reference), then maps local ranks to global ranks via its offset.
4. **return** — a second ``all_to_all`` carries global ranks back to the
   requesting device, where they are scattered into query order.

**Capacity-factor overflow policy**: the request matrix gives each
(source, owner) pair ``cap = ceil(cap_factor * B_local / n_shards)``
slots.  Queries beyond capacity (pathologically skewed batches) are NOT
silently mis-answered: they are dropped at the route stage and come back
as :data:`DROPPED` (``-2``), distinguishable from the legitimate
"before the first key" rank ``-1``.  Raise ``cap_factor`` for an
exactness guarantee (``cap_factor >= n_shards`` can never drop).

Two fallback modes complete the picture:

* ``mode="allgather"`` — for small tiers: queries stay replicated, every
  shard answers its owned subset and one ``psum`` merges the masked
  results (collective = the (B,) rank vector, no routing latency).
* single-device / mismatched mesh — a vmapped all-shards sweep with an
  owner-select, bit-identical semantics with zero collectives.

Heterogeneous shard sizes share one trace: local tables are padded to a
common power-of-two length with a strictly increasing continuation of
the last key (a clamp against the per-shard valid count restores exact
ranks), and variable-length index leaves reuse the power-of-two sentinel
padding idiom of :mod:`repro.index.impls`.

Rebuilds swap in without host round-trips: :func:`refresh_shard` donates
the old stacked pytree to a jitted ``.at[shard].set`` update
(``donate_argnums=0``), recomputing offsets on device.
"""

from __future__ import annotations

import json
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.cdf import POS_DTYPE
from repro.core.search import NO_PRED
from repro.index import Index, batched_pallas_impl, count_trace, lookup_impl, registry
from repro.index.specs import IndexSpec

from . import collectives

#: Rank reported for queries dropped by the capacity-factored exchange.
#: Distinct from :data:`repro.core.search.NO_PRED` (re-exported above),
#: the shared below-the-global-min sentinel.
DROPPED = -2

# ---------------------------------------------------------------------------
# Tier telemetry: routing imbalance + drop-rate counters.
#
# The counters live in the repro.obs registry (``route_*`` metrics,
# labeled by tier — "all" is the process-wide aggregate); everything
# below is a thin view so the PR 2 call signatures keep working.  obs is
# imported lazily inside the telemetry functions only: the telemetry-off
# lookup path never pulls repro.obs in at call time.
# ---------------------------------------------------------------------------

#: the tier label the global aggregate view reads
_ALL_TIERS = "all"


def _fresh_tier_metrics() -> dict:
    """A zeroed caller-owned ``telemetry_sink`` dict (the PR 2 shape)."""
    return {
        "lookups": 0,
        "queries": 0,
        "dropped": 0,
        "routed_max": 0,  # busiest shard's queries, summed over lookups
        "routed_even": 0.0,  # perfectly even per-shard load, summed
        "imbalance_last": 0.0,
        "imbalance_peak": 0.0,
    }


def reset_tier_metrics() -> None:
    """Zero the registry-backed ``route_*`` counters (every tier label,
    including the per-:class:`~repro.tune.rebuild.TunedTier` ones).

    Caller-owned ``telemetry_sink`` dicts are **not** reset — the sink
    contract is that the caller owns that dict's lifetime; zero it
    yourself (or take a fresh :func:`_fresh_tier_metrics`)."""
    from repro import obs

    obs.reset(prefix="route_")


def derived_tier_metrics(counters: dict) -> dict:
    """Raw routing counters + the derived rates (drop rate, mean
    imbalance) — shared by the global view and per-tier sinks.  Missing
    keys count as zero, so a zero-query (or empty) snapshot yields
    well-defined 0.0 rates instead of dividing by zero."""
    m = {**_fresh_tier_metrics(), **counters}
    m["drop_rate"] = m["dropped"] / m["queries"] if m["queries"] else 0.0
    m["imbalance_mean"] = m["routed_max"] / m["routed_even"] if m["routed_even"] else 0.0
    return m


def _tier_counters_from_obs(tier: str) -> dict:
    """Render one tier label's ``route_*`` registry samples back into the
    PR 2 counter-dict shape."""
    from repro import obs

    snap = obs.snapshot(prefix="route_")
    v = lambda name: obs.sample_value(snap, name, tier=tier)
    return {
        "lookups": int(v("route_lookups")),
        "queries": int(v("route_queries")),
        "dropped": int(v("route_dropped")),
        "routed_max": int(v("route_max")),
        "routed_even": v("route_even"),
        "imbalance_last": v("route_imbalance_last"),
        "imbalance_peak": v("route_imbalance_peak"),
    }


def tier_metrics() -> dict:
    """Routing-imbalance and drop-rate counters across every telemetry-
    enabled :func:`sharded_lookup` in the process since the last reset.

    ``imbalance_*`` is the busiest shard's load over the perfectly even
    load (1.0 = uniform routing; ``n_shards`` = fully skewed);
    ``drop_rate`` is the fraction of queries returned as
    :data:`DROPPED` by the capacity-factored exchange.  Surfaced by
    ``DecodeEngine.metrics()`` next to the lookup trace counts.  A
    caller serving several tiers passes its own ``telemetry_sink`` (or a
    ``telemetry_label``, which adds a per-tier ``route_*`` labelset in
    the registry) to :func:`sharded_lookup` for per-tier attribution;
    the global view here aggregates all of them.  Rendered from the
    ``repro.obs`` registry — ``obs.snapshot(prefix="route_")`` exposes
    the same counters with labels.
    """
    return derived_tier_metrics(_tier_counters_from_obs(_ALL_TIERS))


@partial(jax.jit, static_argnames=("n_shards",))
def _owner_histogram(fences, queries, n_shards: int):
    count_trace("obs:owner_hist", "jit")
    owners = route_owners(fences, queries)
    return jnp.bincount(owners.astype(jnp.int32), length=n_shards)


def _record_tier_metrics(
    sidx: "ShardedIndex",
    queries,
    out,
    sink: dict | None = None,
    label: str | None = None,
) -> None:
    from repro import obs

    hist = np.asarray(_owner_histogram(sidx.fences, queries, sidx.n_shards))
    b = int(hist.sum())
    even = b / sidx.n_shards
    imb = float(hist.max() / even) if even > 0 else 0.0
    dropped = int(np.asarray(out == DROPPED).sum())
    tiers = [_ALL_TIERS] if label is None else [_ALL_TIERS, str(label)]
    for t in tiers:
        obs.metric("route_lookups").inc(tier=t)
        obs.metric("route_queries").inc(b, tier=t)
        obs.metric("route_dropped").inc(dropped, tier=t)
        obs.metric("route_max").inc(int(hist.max()), tier=t)
        obs.metric("route_even").inc(even, tier=t)
        obs.metric("route_imbalance_last").set(imb, tier=t)
        obs.metric("route_imbalance_peak").max(imb, tier=t)
    if label is not None:
        # per-owner-shard histogram, labeled tiers only (the "all" view
        # would mix tiers of different shard counts): this is the density
        # estimate weighted_quantile_bounds rebalances from
        shard_q = obs.metric("route_shard_queries")
        for s, c in enumerate(hist):
            if c:
                shard_q.inc(int(c), tier=str(label), shard=s)
    if sink is not None:
        sink["lookups"] += 1
        sink["queries"] += b
        sink["dropped"] += dropped
        sink["routed_max"] += int(hist.max())
        sink["routed_even"] += even
        sink["imbalance_last"] = imb
        sink["imbalance_peak"] = max(sink["imbalance_peak"], imb)

def shard_query_weights(tier: str, n_shards: int) -> np.ndarray:
    """Observed per-owner-shard query counts for one labeled tier, read
    back from the ``route_shard_queries`` registry counter (zeros where a
    shard never owned a query).  The raw material of skew-aware
    rebalancing: :meth:`repro.tune.rebuild.TunedTier.maybe_rebalance`
    windows these counts to detect sustained drift."""
    from repro import obs

    snap = obs.snapshot(prefix="route_shard_queries")
    return np.asarray(
        [
            obs.sample_value(snap, "route_shard_queries", tier=str(tier), shard=s)
            for s in range(n_shards)
        ],
        dtype=np.float64,
    )


_MAXKEY = np.uint64(np.iinfo(np.uint64).max)

#: Static keys that hold bucketed loop trip counts: extra iterations are
#: no-ops, so stacking may take the max across shards.  ``pksteps`` /
#: ``rk_epi`` are the fused PGM / RadixSpline kernels' trip counts.
_STEP_KEYS = ("epi", "ksteps", "pksteps", "rk_epi")


def _pow2ceil(x: int) -> int:
    x = max(int(x), 1)
    return 1 << (x - 1).bit_length()


def _pad_to(arr: np.ndarray, shape: tuple) -> np.ndarray:
    """Pad ``arr`` up to ``shape`` with inert sentinels (the impls idiom):
    uint64 key arrays get the max-key sentinel, everything else repeats
    its last entry (edge replication)."""
    arr = np.asarray(arr)
    if arr.shape == tuple(shape):
        return arr
    widths = [(0, t - s) for s, t in zip(arr.shape, shape)]
    if any(w < 0 for _, w in widths):
        raise ValueError(f"cannot shrink leaf of shape {arr.shape} to {shape}")
    if arr.dtype == np.uint64:
        return np.pad(arr, widths, mode="constant", constant_values=_MAXKEY)
    return np.pad(arr, widths, mode="edge")


def _lift_pgm_levels(idx: Index, target: int) -> Index:
    """Lift a PGM-shaped index to ``target`` levels by prepending trivial
    one-segment root levels.

    ``build_pgm``'s recursion always terminates in a one-segment root, so
    a synthetic root (slope 0, ``rank0 = [0, 1]``) predicts window
    ``[0, 0]`` over the level below — the next-level search degenerates
    to the old root and the lifted index answers identically.  This is
    what makes PGM shard-stackable: per-shard level counts are
    data-dependent, and the shallow shards lift to the deepest one.
    """
    from repro.index.impls import _pad_pow2

    levels = idx.s("levels")
    extra = target - levels
    if extra == 0:
        return idx
    if extra < 0:
        raise ValueError(f"cannot lower a PGM from {levels} to {target} levels")
    sizes = np.asarray(idx.arrays["sizes"])
    keys = np.asarray(idx.arrays["keys"])
    slope = np.asarray(idx.arrays["slope"])
    rank0 = np.asarray(idx.arrays["rank0"])
    pk_u0 = np.asarray(idx.arrays["pk_u0"])
    pk_slope = np.asarray(idx.arrays["pk_slope"])
    kv = int(sizes.sum())  # valid prefix before the pow2 sentinel pad
    rv = int((sizes + 1).sum())
    new_keys = np.concatenate([np.full(extra, keys[0], keys.dtype), keys[:kv]])
    new_slope = np.concatenate([np.zeros(extra, slope.dtype), slope[:kv]])
    synth_rank0 = np.tile(np.asarray([0, 1], rank0.dtype), extra)
    new_rank0 = np.concatenate([synth_rank0, rank0[:rv]])
    # the synthetic roots anchor at keys[0], whose kernel coordinate is
    # pk_u0[0]; slope 0 keeps the fused descent's window at [0, 0] too
    new_pk_u0 = np.concatenate([np.full(extra, pk_u0[0], pk_u0.dtype), pk_u0[:kv]])
    new_pk_slope = np.concatenate([np.zeros(extra, pk_slope.dtype), pk_slope[:kv]])
    new_sizes = np.concatenate([np.ones(extra, sizes.dtype), sizes]).astype(np.int64)
    arrays = dict(idx.arrays)
    arrays["keys"] = jnp.asarray(_pad_pow2(new_keys, _MAXKEY))
    arrays["slope"] = jnp.asarray(_pad_pow2(new_slope, 0.0))
    arrays["rank0"] = jnp.asarray(_pad_pow2(new_rank0, new_rank0[-1]))
    arrays["pk_u0"] = jnp.asarray(_pad_pow2(new_pk_u0, np.float32(1.0)))
    arrays["pk_slope"] = jnp.asarray(_pad_pow2(new_pk_slope, np.float32(0.0)))
    arrays["sizes"] = jnp.asarray(new_sizes)
    arrays["off"] = jnp.asarray(np.concatenate([[0], np.cumsum(new_sizes)]).astype(np.int64))
    arrays["off_r"] = jnp.asarray(
        np.concatenate([[0], np.cumsum(new_sizes + 1)]).astype(np.int64),
    )
    static = tuple((k, target if k == "levels" else v) for k, v in idx.static)
    return Index(idx.kind, static, arrays, info=idx.info)


def _pad_gapped_leaves(idx: Index, target_l: int) -> Index:
    """Pad a GAPPED index to ``target_l`` leaves with *inert* rows:
    max-key ``keys``/``fences``/``route`` entries and **zero** counts.

    The generic :func:`_pad_to` edge-replicates integer leaves, which
    would fabricate live keys in the padded rows (``counts`` must be 0
    so the padded leaves hold nothing, absorb nothing at insert, and are
    skipped by compaction's valid mask); max-key route entries keep the
    model-guided owner search inside the real leaf range."""
    L, cap = (int(s) for s in idx.arrays["keys"].shape)
    if L == target_l:
        return idx
    if L > target_l:
        raise ValueError(f"cannot shrink a GAPPED index from {L} to {target_l} leaves")
    pad = target_l - L
    arrays = dict(idx.arrays)
    arrays["keys"] = jnp.concatenate(
        [idx.arrays["keys"], jnp.full((pad, cap), _MAXKEY, dtype=jnp.uint64)]
    )
    arrays["counts"] = jnp.concatenate(
        [idx.arrays["counts"], jnp.zeros((pad,), dtype=jnp.int64)]
    )
    arrays["fences"] = jnp.concatenate(
        [idx.arrays["fences"], jnp.full((pad,), _MAXKEY, dtype=jnp.uint64)]
    )
    arrays["route"] = jnp.concatenate(
        [idx.arrays["route"], jnp.full((pad,), _MAXKEY, dtype=jnp.uint64)]
    )
    return Index(idx.kind, idx.static, arrays, info=idx.info)


def _harmonize(kind: str, per_shard: list) -> list:
    """Make per-shard indexes structurally stackable where the kind
    allows it (PGM-shaped kinds: lift shallow shards to the max depth;
    GAPPED: pad shallow shards with inert zero-count leaves)."""
    if registry.entry(kind).query_key == "pgm":
        target = max(i.s("levels") for i in per_shard)
        return [_lift_pgm_levels(i, target) for i in per_shard]
    if kind == "GAPPED":
        target = max(int(i.arrays["keys"].shape[0]) for i in per_shard)
        return [_pad_gapped_leaves(i, target) for i in per_shard]
    return per_shard


def _merge_static(statics: list) -> tuple:
    """Merge per-shard static aux: bucketed trip counts take the max
    (extra bounded-search iterations are no-ops), everything structural
    (levels, fanout, degree, ...) must agree exactly."""
    merged = []
    for i, (name, v0) in enumerate(statics[0]):
        vals = [s[i][1] for s in statics]
        if any(s[i][0] != name for s in statics):
            raise ValueError("per-shard indexes have mismatched static keys")
        if name in _STEP_KEYS:
            merged.append((name, max(vals)))
        elif len(set(vals)) != 1:
            raise ValueError(
                f"cannot stack: static {name!r} differs across shards ({sorted(set(vals))}); "
                "structural statics must agree — rebuild with a shard-stable spec"
            )
        else:
            merged.append((name, v0))
    return tuple(merged)


def stack_indexes(indexes: list) -> Index:
    """Stack N same-spec per-shard indexes leaf-wise into one Index whose
    leaves carry a leading shard axis.  Leaf shapes are padded to the
    per-leaf max (power-of-two padding at build time makes collisions the
    common case), so heterogeneous shards share one stacked structure."""
    if not indexes:
        raise ValueError("need at least one index to stack")
    kinds = {i.kind for i in indexes}
    if len(kinds) != 1:
        raise ValueError(f"cannot stack indexes of different kinds: {sorted(kinds)}")
    names = set(indexes[0].arrays)
    if any(set(i.arrays) != names for i in indexes):
        raise ValueError("per-shard indexes have mismatched leaf names")
    static = _merge_static([i.static for i in indexes])
    arrays = {}
    for name in sorted(names):
        leaves = [np.asarray(i.arrays[name]) for i in indexes]
        if len({l_.ndim for l_ in leaves}) != 1:
            raise ValueError(f"leaf {name!r} rank differs across shards")
        target = tuple(max(dims) for dims in zip(*[l_.shape for l_ in leaves]))
        arrays[name] = jnp.stack([jnp.asarray(_pad_to(l_, target)) for l_ in leaves])
    info = {"n_shards": len(indexes), "name": f"sharded-{indexes[0].name}"}
    return Index(indexes[0].kind, static, arrays, info)


class ShardedIndex:
    """A tier of per-shard learned indexes over a partitioned keyspace.

    Attributes
    ----------
    index:   stacked :class:`Index` — every leaf has leading shard axis.
    tables:  ``(n_shards, m)`` uint64 — per-shard sorted tables, padded
             to a common power-of-two ``m`` (strictly increasing pad).
    fences:  ``(n_shards,)`` uint64 — first key of each shard; the
             router searches ``fences[1:]``.
    counts:  ``(n_shards,)`` int64 — valid (unpadded) keys per shard.
    offsets: ``(n_shards,)`` int64 — global rank of each shard's first
             key (exclusive cumsum of ``counts``).
    """

    __slots__ = ("index", "tables", "fences", "counts", "offsets", "info")

    def __init__(self, index: Index, tables, fences, counts, offsets, info=None):
        object.__setattr__(self, "index", index)
        object.__setattr__(self, "tables", tables)
        object.__setattr__(self, "fences", fences)
        object.__setattr__(self, "counts", counts)
        object.__setattr__(self, "offsets", offsets)
        object.__setattr__(self, "info", dict(info or {}))

    # -- pytree protocol --------------------------------------------------
    def tree_flatten(self):
        children = (self.index, self.tables, self.fences, self.counts, self.offsets)
        return children, ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children, info=None)

    # -- metadata ---------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return int(self.tables.shape[0])

    @property
    def kind(self) -> str:
        return self.index.kind

    def __repr__(self):
        return (
            f"ShardedIndex(kind={self.kind!r}, n_shards={self.n_shards}, "
            f"m={int(self.tables.shape[1])})"
        )

    def shard(self, s: int) -> Index:
        """The per-shard Index view of shard ``s`` (sliced leaves)."""
        return Index(
            self.index.kind,
            self.index.static,
            {k: v[s] for k, v in self.index.arrays.items()},
            info={"shard": s, **self.info},
        )

    def space_bytes(self) -> int:
        """Model bytes across the tier + the router's fence/offset arrays."""
        per_shard = self.shard(0).space_bytes()
        router = self.fences.size * 8 + self.counts.size * 8 + self.offsets.size * 8
        return self.n_shards * per_shard + router

    # -- build ------------------------------------------------------------
    @staticmethod
    def build(kind_or_spec, table_np, n_shards: int, *, bounds=None, **params) -> "ShardedIndex":
        """Partition a global sorted table into ``n_shards`` contiguous
        shards, build one same-spec Index per shard, and stack.

        ``bounds`` (optional) overrides the even split with an explicit
        strictly increasing rank partition ``[0, ..., n]`` of length
        ``n_shards + 1`` — the skew-aware rebalancer's restack fallback
        (:func:`weighted_quantile_bounds` computes such partitions from
        observed traffic)."""
        table_np = np.asarray(table_np, dtype=np.uint64)
        n = len(table_np)
        if n_shards < 1 or n_shards > n:
            raise ValueError(f"n_shards={n_shards} must be in [1, {n}]")
        if isinstance(kind_or_spec, IndexSpec):
            spec = kind_or_spec
        else:
            spec = registry.spec_for(str(kind_or_spec), **params)
        if bounds is None:
            bounds = [round(i * n / n_shards) for i in range(n_shards + 1)]
        else:
            bounds = [int(b) for b in np.asarray(bounds).reshape(-1)]
            if (
                len(bounds) != n_shards + 1
                or bounds[0] != 0
                or bounds[-1] != n
                or any(b1 <= b0 for b0, b1 in zip(bounds, bounds[1:]))
            ):
                raise ValueError(
                    f"bounds must be a strictly increasing rank partition [0, ..., {n}] "
                    f"of length {n_shards + 1}, got {bounds}"
                )
        locals_ = [table_np[bounds[i] : bounds[i + 1]] for i in range(n_shards)]
        m = _pow2ceil(max(len(t) for t in locals_))
        padded = [_pad_sorted_table(t, m) for t in locals_]
        # self-contained kinds (GAPPED) own their keys: build them on the
        # raw shard tables so the pad continuation never becomes a live
        # key (an insert could otherwise land *above* a pad key and shift
        # intermediate ranks); ragged leaf counts harmonize at stacking
        from repro.index.impls import query_impl

        build_tables = locals_ if query_impl(spec.kind).lookup is not None else padded
        per_shard = [registry.entry(spec.kind).build(spec, p) for p in build_tables]
        stacked = stack_indexes(_harmonize(spec.kind, per_shard))
        counts = np.asarray([len(t) for t in locals_], dtype=np.int64)
        offsets = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int64)
        fences = np.asarray([t[0] for t in locals_], dtype=np.uint64)
        info = {"spec": spec.display_name(), "n": n, "m": m}
        return ShardedIndex(
            index=stacked,
            tables=jnp.asarray(np.stack(padded)),
            fences=jnp.asarray(fences),
            counts=jnp.asarray(counts),
            offsets=jnp.asarray(offsets),
            info=info,
        )

    # -- serialization ----------------------------------------------------
    def save(self, path) -> None:
        """npz round-trip of the stacked tier: leaves stay bit-exact."""
        payload = {f"idx_{k}": np.asarray(v) for k, v in self.index.arrays.items()}
        payload.update(
            tables=np.asarray(self.tables),
            fences=np.asarray(self.fences),
            counts=np.asarray(self.counts),
            offsets=np.asarray(self.offsets),
        )
        meta = {
            "kind": self.index.kind,
            "static": list(map(list, self.index.static)),
            "info": {k: v for k, v in self.info.items() if isinstance(v, (str, int, float, bool))},
        }
        payload["__meta__"] = np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)
        np.savez(path, **payload)

    @classmethod
    def load(cls, path) -> "ShardedIndex":
        with np.load(path) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
            arrays = {k[len("idx_") :]: jnp.asarray(z[k]) for k in z.files if k.startswith("idx_")}
            tables = jnp.asarray(z["tables"])
            fences = jnp.asarray(z["fences"])
            counts = jnp.asarray(z["counts"])
            offsets = jnp.asarray(z["offsets"])
        static = tuple((k, int(v)) for k, v in meta["static"])
        index = Index(meta["kind"], static, arrays, info=meta.get("info"))
        return cls(index, tables, fences, counts, offsets, info=meta.get("info"))


jax.tree_util.register_pytree_node_class(ShardedIndex)


def _pad_sorted_table(t: np.ndarray, m: int) -> np.ndarray:
    """Pad a local sorted table to length ``m`` with a strictly
    increasing continuation of its last key (``last+1, last+2, ...``).

    The table stays sorted *and unique*, so every per-kind builder's
    fitting code sees a well-formed table (duplicate padding makes
    least-squares segment fits degenerate), and the rank clamp against
    the shard's valid count maps any hit in the padded tail back to the
    true local predecessor (the last real key).  Padded keys may overlap
    the next shard's key range; that is harmless because the router
    never sends a query at or beyond the next fence to this shard.  In
    the degenerate no-headroom case (last key at the top of the u64
    range) the pad repeats the last key instead."""
    if len(t) == 0:
        raise ValueError("empty shard")
    pad = m - len(t)
    if pad < 0:
        raise ValueError(f"shard has {len(t)} keys > padded capacity {m}")
    if pad == 0:
        return t
    last = np.uint64(t[-1])
    room = int(_MAXKEY) - int(last)
    if room >= pad:
        # spread the pad across the remaining headroom: tightly clustered
        # pad keys make per-segment least-squares fits ill-conditioned
        step = np.uint64(room // pad)
        ext = last + np.arange(1, pad + 1, dtype=np.uint64) * step
    else:
        ext = np.full(pad, last, dtype=t.dtype)
    return np.concatenate([t, ext])


# ---------------------------------------------------------------------------
# Routing + local answer
# ---------------------------------------------------------------------------


def route_owners(fences, queries):
    """Owner shard per query: branch-free k-ary search on the fence
    array (``fences[0]`` is the global min and not a boundary)."""
    from repro.kernels.kary_search import kary_owner_route

    return kary_owner_route(fences[1:], queries)


def _answer_local(local_index: Index, local_table, count, offset, queries, backend: str):
    """Resident-shard answer: shared per-kind lookup on the local leaf,
    local rank clamped to the valid count and rebased to a global rank."""
    r = lookup_impl(local_index, local_table, queries, backend)
    r = jnp.minimum(r.astype(POS_DTYPE), count - 1)
    return jnp.where(r < 0, jnp.asarray(NO_PRED, POS_DTYPE), offset + r)


# ---------------------------------------------------------------------------
# Single-device / mismatched-mesh fallback: vmapped all-shards sweep
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("backend",))
def _lookup_vmapped(sidx: ShardedIndex, queries, backend: str):
    count_trace(f"sharded:{sidx.kind}", f"ref:{backend}")
    owners = route_owners(sidx.fences, queries)

    if backend == "pallas":
        # one batched (table, q_tile)-grid kernel answers every shard;
        # clamp + rebase mirror _answer_local exactly
        bq = jnp.broadcast_to(queries[None, :], (sidx.n_shards, queries.shape[0]))
        r = batched_pallas_impl(sidx.index, sidx.tables, bq)
        r = jnp.minimum(r.astype(POS_DTYPE), sidx.counts[:, None] - 1)
        granks = jnp.where(r < 0, jnp.asarray(NO_PRED, POS_DTYPE), sidx.offsets[:, None] + r)
    else:

        def one(idx, tab, cnt, off):
            return _answer_local(idx, tab, cnt, off, queries, backend)

        granks = jax.vmap(one)(sidx.index, sidx.tables, sidx.counts, sidx.offsets)
    return jnp.take_along_axis(granks, owners[None, :].astype(POS_DTYPE), axis=0)[0]


# ---------------------------------------------------------------------------
# shard_map paths: a2a exchange and allgather(psum) fallback
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("mesh", "axes", "backend", "cap"))
def _lookup_a2a(sidx: ShardedIndex, queries, mesh, axes, backend: str, cap: int):
    from jax.experimental.shard_map import shard_map

    count_trace(f"sharded:{sidx.kind}", f"a2a:{backend}")
    n_shards = sidx.n_shards
    ax = axes if len(axes) > 1 else axes[0]

    def block(idx, tab, cnt, off, fences, q):
        local = jax.tree_util.tree_map(lambda v: v[0], idx)
        b_loc = q.shape[0]
        owner = route_owners(fences, q)
        # bucket queries by owner into the capacity-factored request matrix
        req, slots, valid, order = collectives.bucket_by_owner(
            owner, q, n_shards, cap, jnp.zeros((), q.dtype)
        )
        # 1st all_to_all: requests travel to their owner shard
        req_x = lax.all_to_all(req, ax, split_axis=0, concat_axis=0, tiled=True)
        g = _answer_local(local, tab[0], cnt[0], off[0], req_x.reshape(-1), backend)
        # 2nd all_to_all: global ranks travel back to the requesters
        back = lax.all_to_all(g.reshape(n_shards, cap), ax, split_axis=0, concat_axis=0, tiled=True)
        # unsort; entries that never fit a slot keep the DROPPED sentinel
        return collectives.unbucket_inverse(back, slots, valid, order, b_loc, DROPPED)

    return shard_map(
        block,
        mesh=mesh,
        in_specs=(P(ax), P(ax), P(ax), P(ax), P(None), P(ax)),
        out_specs=P(ax),
        check_rep=False,
    )(sidx.index, sidx.tables, sidx.counts, sidx.offsets, sidx.fences, queries)


@partial(jax.jit, static_argnames=("mesh", "axes", "backend"))
def _lookup_allgather(sidx: ShardedIndex, queries, mesh, axes, backend: str):
    from jax.experimental.shard_map import shard_map

    count_trace(f"sharded:{sidx.kind}", f"allgather:{backend}")
    ax = axes if len(axes) > 1 else axes[0]

    def block(idx, tab, cnt, off, fences, q):
        local = jax.tree_util.tree_map(lambda v: v[0], idx)
        me = lax.axis_index(axes)
        owner = route_owners(fences, q)
        g = _answer_local(local, tab[0], cnt[0], off[0], q, backend)
        mine = owner.astype(jnp.int64) == me.astype(jnp.int64)
        return lax.psum(jnp.where(mine, g, jnp.zeros_like(g)), axes)

    return shard_map(
        block,
        mesh=mesh,
        in_specs=(P(ax), P(ax), P(ax), P(ax), P(None), P(None)),
        out_specs=P(None),
        check_rep=False,
    )(sidx.index, sidx.tables, sidx.counts, sidx.offsets, sidx.fences, queries)


# ---------------------------------------------------------------------------
# Public entry point
# ---------------------------------------------------------------------------

MODES = ("auto", "a2a", "allgather", "ref")

#: Backends the tier's local answer supports — the full ``Index.lookup``
#: set.  Under ``pallas`` the shard_map paths run each shard's fused
#: kernel on its resident block, and the vmapped fallback dispatches the
#: batched ``(table, q_tile)``-grid kernels across the whole tier.
TIER_BACKENDS = ("xla", "bbs", "pallas", "ref")


def sharded_lookup(
    sidx: ShardedIndex,
    queries,
    ctx=None,
    *,
    backend: str = "xla",
    mode: str = "auto",
    cap_factor: float = 2.0,
    telemetry: bool = False,
    telemetry_sink: dict | None = None,
    telemetry_label: str | None = None,
):
    """Predecessor ranks of ``queries`` against the whole sharded tier.

    ``ctx`` is a :class:`~repro.dist.sharding.ShardingCtx`; the tier is
    laid out over its ``tp`` logical axis.  ``mode``:

    * ``"a2a"`` — queries sharded over ``tp``, capacity-factored double
      ``all_to_all`` exchange (the scale path; see the module docstring
      for the overflow policy).
    * ``"allgather"`` — queries replicated, masked local answers merged
      with one ``psum`` (small-tier fallback, never drops).
    * ``"ref"`` — vmapped all-shards sweep, no collectives (single
      device or mesh/tier mismatch).
    * ``"auto"`` — ``a2a`` when the mesh's ``tp`` extent matches the
      shard count (>1), else ``ref``.

    Ranks are bit-identical to ``Index.lookup`` on the concatenated
    table, except over-capacity drops in ``a2a`` mode, which report
    :data:`DROPPED`.

    ``backend`` selects the per-shard answer path (any
    :data:`TIER_BACKENDS` entry): under ``"pallas"`` the shard_map
    modes run each shard's fused kernel on its resident block, and the
    vmapped fallback answers the whole tier with ONE batched
    ``(table, q_tile)``-grid kernel call.

    Example — a 4-shard PGM tier on a ``tp=4`` mesh::

        sidx = ShardedIndex.build("PGM", table, n_shards=4, eps=64)
        ctx = ShardingCtx(mesh=jax.make_mesh((1, 4), ("data", "model")))
        ranks = sharded_lookup(sidx, queries, ctx, backend="pallas")
        # single-device fallback, still exact, no collectives:
        ranks = sharded_lookup(sidx, queries, mode="ref")

    ``telemetry=True`` additionally records per-call routing-imbalance
    and drop-rate counters into the ``repro.obs`` registry
    (:func:`tier_metrics` is the aggregate view) — one extra jitted
    owner histogram plus a host sync, so serving loops opt in and
    benchmarks stay untouched.  ``telemetry_label`` attributes the same
    counters to a per-tier ``route_*`` labelset when one process serves
    several tiers (the ``tier="all"`` aggregate always updates);
    ``telemetry_sink`` (a counter dict in :func:`_fresh_tier_metrics`
    shape) is the legacy dict-based attribution and receives the same
    updates.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; choose from {MODES}")
    if backend not in TIER_BACKENDS:
        raise ValueError(f"unknown tier backend {backend!r}; choose from {TIER_BACKENDS}")
    from repro.index.impls import query_impl

    kind_backends = query_impl(sidx.kind).backends
    if backend not in kind_backends:
        raise ValueError(
            f"kind {sidx.kind!r} supports backends {kind_backends}, not {backend!r}"
        )
    queries = jnp.asarray(queries)
    if queries.ndim != 1:
        raise ValueError("sharded_lookup expects a flat (B,) query vector")
    n_shards = sidx.n_shards
    tp = ctx.n("tp") if ctx is not None else 1
    axes = ctx.mesh_axes("tp") if ctx is not None else ()
    spmd_ok = tp == n_shards and n_shards > 1 and bool(axes)
    if mode == "auto":
        mode = "a2a" if spmd_ok else "ref"
    if mode in ("a2a", "allgather") and not spmd_ok:
        raise ValueError(
            f"mode={mode!r} needs the mesh tp extent ({tp}) to equal n_shards "
            f"({n_shards}); use mode='ref' or 'auto'"
        )
    if mode == "ref":
        out = _lookup_vmapped(sidx, queries, backend)
    elif mode == "allgather":
        out = _lookup_allgather(sidx, queries, ctx.mesh, axes, backend)
    else:
        b = queries.shape[0]
        pad = (-b) % n_shards
        padded = (
            jnp.concatenate([queries, jnp.zeros((pad,), queries.dtype)]) if pad else queries
        )
        b_loc = padded.shape[0] // n_shards
        cap = collectives.exchange_capacity(b_loc, n_shards, cap_factor)
        out = _lookup_a2a(sidx, padded, ctx.mesh, axes, backend, cap)
        out = out[:b] if pad else out
    if telemetry:
        _record_tier_metrics(sidx, queries, out, telemetry_sink, telemetry_label)
    return out


# ---------------------------------------------------------------------------
# Donated in-place refresh
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("shard",), donate_argnums=(0,))
def _install_shard(sidx: ShardedIndex, new_arrays, new_table, new_fence, new_count, shard: int):
    arrays = {k: v.at[shard].set(new_arrays[k]) for k, v in sidx.index.arrays.items()}
    counts = sidx.counts.at[shard].set(new_count)
    offsets = jnp.concatenate([jnp.zeros((1,), POS_DTYPE), jnp.cumsum(counts)[:-1]])
    return ShardedIndex(
        index=Index(sidx.index.kind, sidx.index.static, arrays),
        tables=sidx.tables.at[shard].set(new_table),
        fences=sidx.fences.at[shard].set(new_fence),
        counts=counts,
        offsets=offsets,
    )


def refresh_shard(sidx: ShardedIndex, shard: int, new_index: Index, new_table) -> ShardedIndex:
    """Swap a rebuilt shard into the tier without host round-trips.

    The old stacked pytree is *donated* to a jitted ``.at[shard].set``
    update, so the swap reuses the resident buffers instead of copying
    the whole tier through the host; offsets are recomputed on device
    (a rebuilt shard may change its key count).

    ``new_index`` must be built with a shard-stable spec: structural
    statics must match the tier and its (padded) leaves must fit the
    stacked leaf shapes.  ``new_table`` is the shard's raw (unpadded)
    sorted key array — but the *index* must be fitted on
    :func:`shard_build_table` of it: static kinds normalise predictions
    by the lookup-time table length, so an index fitted on the raw keys
    answers wrongly against the padded resident row whenever
    ``len(new_table) < m`` (exact-power-of-two shards mask this).
    """
    if new_index.kind != sidx.index.kind:
        raise ValueError(f"kind mismatch: tier is {sidx.index.kind!r}, got {new_index.kind!r}")
    if registry.entry(new_index.kind).query_key == "pgm":
        if new_index.s("levels") < sidx.index.s("levels"):
            new_index = _lift_pgm_levels(new_index, sidx.index.s("levels"))
    for (name, have), (n2, new) in zip(sidx.index.static, new_index.static):
        if name != n2:
            raise ValueError("static key mismatch between tier and rebuilt shard")
        if name in _STEP_KEYS:
            if new > have:
                raise ValueError(
                    f"rebuilt shard needs {name}={new} > tier's {have}: restack the tier "
                    "(a larger trip count cannot be installed without a retrace)"
                )
        elif new != have:
            raise ValueError(f"static {name!r} mismatch: tier {have}, rebuilt shard {new}")
    new_table = np.asarray(new_table, dtype=np.uint64)
    if len(new_table) == 0:
        raise ValueError("cannot install an empty shard")
    m = int(sidx.tables.shape[1])
    if len(new_table) > m:
        raise ValueError(f"rebuilt shard has {len(new_table)} keys > tier table capacity {m}")
    # the rebuilt key set must stay inside this shard's fence slot, or
    # global ranks would silently go wrong for every later shard
    if shard > 0:
        prev_last = np.uint64(sidx.tables[shard - 1, int(sidx.counts[shard - 1]) - 1])
        if new_table[0] <= prev_last:
            raise ValueError(
                f"rebuilt shard {shard} starts at {new_table[0]}, inside the previous "
                f"shard's range (its last key is {prev_last})"
            )
    if shard + 1 < sidx.n_shards:
        next_fence = np.uint64(sidx.fences[shard + 1])
        if new_table[-1] >= next_fence:
            raise ValueError(
                f"rebuilt shard {shard} ends at {new_table[-1]}, at or beyond the next "
                f"shard's fence {next_fence}"
            )
    padded_tab = jnp.asarray(_pad_sorted_table(new_table, m))
    if sidx.index.kind == "GAPPED":
        # inert zero-count leaf rows, not the generic edge-replication pad
        new_index = _pad_gapped_leaves(new_index, int(sidx.index.arrays["keys"].shape[1]))
    new_arrays = {}
    for k, v in sidx.index.arrays.items():
        if k not in new_index.arrays:
            raise ValueError(f"rebuilt shard is missing leaf {k!r}")
        new_arrays[k] = jnp.asarray(_pad_to(np.asarray(new_index.arrays[k]), v.shape[1:]))
    return _install_shard(
        sidx,
        new_arrays,
        padded_tab,
        jnp.asarray(new_table[0], jnp.uint64),
        jnp.asarray(len(new_table), POS_DTYPE),
        shard,
    )


# ---------------------------------------------------------------------------
# Skew-aware rebalancing: weighted-quantile fences + ordered re-shard
# ---------------------------------------------------------------------------


def shard_build_table(kind: str, part: np.ndarray, m: int) -> np.ndarray:
    """The table a replacement shard index must be *fitted* on to be
    installable at stacked capacity ``m`` (mirrors
    :meth:`ShardedIndex.build`): static kinds fit on the padded table —
    their query paths normalise model predictions by the lookup-time
    table length, which is the resident padded row — while
    self-contained kinds (GAPPED) own their keys and fit on the raw
    part so a pad key can never become live.  Raises ``ValueError``
    when ``part`` no longer fits ``m`` (the restack cue)."""
    from repro.index.impls import query_impl

    part = np.asarray(part, dtype=np.uint64)
    if query_impl(kind).lookup is not None:
        return part
    return _pad_sorted_table(part, m)


def weighted_quantile_bounds(merged_keys, fences, weights) -> np.ndarray:
    """Rank partition of ``merged_keys`` that evens out *observed* load.

    The per-shard query counts ``weights`` (one per current fence slot)
    define a piecewise-constant traffic density over the sorted global
    key set: every key in current shard ``s`` carries ``weights[s]``
    spread evenly over that shard's keys.  Inverting the cumulative
    weight at ``j/S`` for ``j = 1..S-1`` yields new shard bounds under
    which each shard would have answered an equal share of the observed
    traffic — the weighted-quantile split of the ISSUE/ROADMAP item.

    Degenerate inputs stay well-formed: an all-zero weight vector falls
    back to the even split, and the bounds are clamped to a strictly
    increasing partition with at least one key per shard (``refresh_shard``
    rejects empty shards).  Keys outside the current fence range (e.g.
    pending inserts below the global min) attach to the nearest shard.
    """
    merged = np.asarray(merged_keys, dtype=np.uint64)
    fences = np.asarray(fences, dtype=np.uint64)
    w = np.asarray(weights, dtype=np.float64).reshape(-1)
    n, S = len(merged), len(fences)
    if len(w) != S:
        raise ValueError(f"got {len(w)} weights for {S} fence slots")
    if n < S:
        raise ValueError(f"cannot split {n} keys across {S} shards")
    own = np.clip(np.searchsorted(fences, merged, side="right") - 1, 0, S - 1)
    per_owner = np.bincount(own, minlength=S).astype(np.float64)
    if w.sum() <= 0:
        w = np.ones(S, dtype=np.float64)
    # a shard that owns no current keys contributes no density rows;
    # spread every observed weight over its owner's resident keys
    per_key = np.where(per_owner[own] > 0, w[own] / np.maximum(per_owner[own], 1.0), 0.0)
    if per_key.sum() <= 0:
        per_key = np.ones(n, dtype=np.float64)
    cum = np.cumsum(per_key)
    targets = cum[-1] * np.arange(1, S, dtype=np.float64) / S
    inner = np.searchsorted(cum, targets, side="left") + 1
    # clamp to a strictly increasing partition with >= 1 key per shard
    for j in range(len(inner)):
        lo = (inner[j - 1] + 1) if j else 1
        inner[j] = max(int(inner[j]), lo)
    for j in range(len(inner) - 1, -1, -1):
        hi = (inner[j + 1] - 1) if j + 1 < len(inner) else n - 1
        inner[j] = min(int(inner[j]), hi)
    return np.concatenate([[0], inner, [n]]).astype(np.int64)


def rebalance_shards(sidx: ShardedIndex, merged_keys, bounds, build_shard) -> ShardedIndex:
    """Repartition the tier at ``bounds`` over the global sorted key set
    via the existing donated ``refresh_shard`` swaps — no restack, no
    host-side re-stacking of untouched leaves.

    Each boundary move creates an install-order dependency only between
    the two adjacent shards (``refresh_shard`` validates the new shard
    against the *current* neighbours: a boundary moving right means the
    right shard must shrink before the left can grow, and vice versa), so
    the dependency graph is an acyclically oriented path and a simple
    deferred-retry sweep always terminates in <= ``n_shards`` rounds.
    Raises ``ValueError`` when a rebuilt shard cannot be installed at all
    (e.g. it outgrew the stacked table capacity) — the caller's cue to
    fall back to ``ShardedIndex.build(..., bounds=...)``.

    ``build_shard(build_table)`` builds the per-shard index for a key
    slice already run through :func:`shard_build_table` (the tier passes
    its pinned spec, keeping rebalances retune-free).  Every shard is
    built — and capacity-checked — *before* the first donated install,
    so a non-installable partition fails with the old tier intact.
    """
    merged = np.asarray(merged_keys, dtype=np.uint64)
    bounds = np.asarray(bounds, dtype=np.int64).reshape(-1)
    S = sidx.n_shards
    if len(bounds) != S + 1 or bounds[0] != 0 or bounds[-1] != len(merged):
        raise ValueError(
            f"bounds must partition [0, {len(merged)}] into {S} shards, got {bounds.tolist()}"
        )
    if (np.diff(bounds) < 1).any():
        raise ValueError(f"bounds must give every shard >= 1 key, got {bounds.tolist()}")
    m = int(sidx.tables.shape[1])
    kind = sidx.index.kind
    parts = [merged[bounds[s] : bounds[s + 1]] for s in range(S)]
    built = [build_shard(shard_build_table(kind, p, m)) for p in parts]
    remaining = set(range(S))
    while remaining:
        progressed = False
        last_err: Exception | None = None
        for s in sorted(remaining):
            try:
                sidx = refresh_shard(sidx, s, built[s], parts[s])
            except ValueError as e:
                last_err = e
                continue
            remaining.discard(s)
            progressed = True
        if not progressed:
            raise ValueError(f"rebalance not installable via refresh_shard: {last_err}")
    return sidx


# ---------------------------------------------------------------------------
# Donated in-place shard mutation (updatable kinds: GAPPED)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("shard",), donate_argnums=(0,))
def _install_mutated(sidx: ShardedIndex, new_arrays, new_fence, new_count, shard: int):
    arrays = {k: v.at[shard].set(new_arrays[k]) for k, v in sidx.index.arrays.items()}
    counts = sidx.counts.at[shard].set(new_count)
    offsets = jnp.concatenate([jnp.zeros((1,), POS_DTYPE), jnp.cumsum(counts)[:-1]])
    return ShardedIndex(
        index=Index(sidx.index.kind, sidx.index.static, arrays),
        tables=sidx.tables,
        fences=sidx.fences.at[shard].set(new_fence),
        counts=counts,
        offsets=offsets,
    )


def insert_into_shard(sidx: ShardedIndex, shard: int, keys, *, auto_compact: bool = True):
    """Absorb a key batch into one *updatable* shard without rebuilding.

    The shard's sliced :class:`~repro.index.Index` view runs the kind's
    registered ``insert_batch`` mutator (gap absorption first, delta
    overflow second — see :mod:`repro.index.mutation`), and the mutated
    leaves are swapped back with a donated ``.at[shard].set`` update that
    also keeps ``counts``/``offsets``/``fences`` in sync with the
    shard's *live* key set.  ``sidx.tables`` is left untouched: for
    self-contained kinds the lookup ignores it, and it becomes a stale
    build-time snapshot (use :func:`repro.index.updatable.live_keys` on
    ``sidx.shard(s)`` to read the live keys).

    Returns ``(new_sidx, InsertReport)``.  Raises ``TypeError`` for
    static kinds and :class:`repro.index.mutation.NeedsRebuild` when the
    shard's fixed capacity is exhausted — the caller's cue to rebuild
    the shard via :func:`refresh_shard` (see
    :meth:`repro.tune.rebuild.TunedTier.insert_batch`).
    """
    from repro.index import mutation

    if not 0 <= shard < sidx.n_shards:
        raise ValueError(f"shard {shard} out of range [0, {sidx.n_shards})")
    keys = np.asarray(keys, dtype=np.uint64).reshape(-1)
    if keys.size and shard + 1 < sidx.n_shards:
        # fence discipline: a key at/beyond the next fence belongs to a
        # later shard — absorbing it here would corrupt global ranks
        next_fence = np.uint64(sidx.fences[shard + 1])
        if keys.max() >= next_fence:
            raise ValueError(
                f"key {int(keys.max())} at/beyond shard {shard}'s next fence "
                f"{int(next_fence)}: route keys with route_owners first"
            )
    new_local, report = mutation.insert_batch(
        sidx.shard(shard), keys, auto_compact=auto_compact
    )
    new_count = int(sidx.counts[shard]) + report.absorbed + report.overflowed
    new_sidx = _install_mutated(
        sidx,
        new_local.arrays,
        new_local.arrays["fences"][0],
        jnp.asarray(new_count, POS_DTYPE),
        shard,
    )
    return new_sidx, report


def compact_shard(sidx: ShardedIndex, shard: int) -> ShardedIndex:
    """Fold one updatable shard's delta buffer into its leaves in place
    (device-side compaction + donated swap; the live key set — and so
    ``counts``/``offsets`` — is unchanged).  Raises ``NeedsRebuild``
    when the live set no longer fits the shard's leaves."""
    from repro.index import mutation

    if not 0 <= shard < sidx.n_shards:
        raise ValueError(f"shard {shard} out of range [0, {sidx.n_shards})")
    new_local = mutation.compact(sidx.shard(shard))
    return _install_mutated(
        sidx,
        new_local.arrays,
        new_local.arrays["fences"][0],
        sidx.counts[shard],
        shard,
    )
