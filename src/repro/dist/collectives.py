"""Cross-device collective helpers.

Three concerns live here:

* ``OVERLAP_XLA_FLAGS`` — the XLA flag line a fleet launch exports so
  collectives (FSDP all-gathers, DP reduce-scatters) overlap with
  compute instead of serialising the step.
* psum helpers — thin guards around ``lax.psum`` that no-op when the
  logical axis is unmapped (single device / profile without that axis),
  so step code stays mesh-shape agnostic.
* error-feedback gradient compression (``bf16`` / ``int8``) — the DP
  psum payload shrinks 2-4x; the per-leaf quantisation residual is fed
  back into the next step so compressed training converges to the
  uncompressed trajectory (:mod:`repro.train.step` wires it in).
* owner-exchange bucketing (:func:`bucket_by_owner` /
  :func:`unbucket_inverse`) — the capacity-factored request-matrix
  construction shared by every all_to_all exchange in the repo
  (embedding row fetch, sharded-index query routing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Owner-exchange bucketing (the all_to_all request-matrix pattern)
# ---------------------------------------------------------------------------


def exchange_capacity(n_local: int, n_shards: int, cap_factor: float) -> int:
    """Slots per (source, owner) pair: ``ceil(cap_factor * n / shards)``,
    at least 1.  ``cap_factor >= n_shards`` can never drop."""
    return max(1, int(-(-cap_factor * n_local // n_shards)))


def bucket_by_owner(owner, values, n_shards: int, cap: int, fill):
    """Bucket ``values`` into a capacity-bounded ``(n_shards, cap)``
    request matrix by ``owner`` (inside a shard_map block).

    Sort by owner, find each owner's bucket bounds with a branch-free
    boundary search, and lay the first ``cap`` entries per owner into
    rows; over-capacity entries get ``fill`` and ``valid=False``.

    Returns ``(req, slots, valid, order)``: the request matrix, each
    slot's position in the sorted order, the in-capacity mask, and the
    sort permutation (pass ``slots``/``valid``/``order`` to
    :func:`unbucket_inverse` to scatter replies back to input order).
    """
    from repro.core import search

    n = values.shape[0]
    order = jnp.argsort(owner)
    s_owner = jnp.take(owner, order).astype(jnp.int64)
    s_val = jnp.take(values, order)
    shard_q = jnp.arange(n_shards, dtype=jnp.int64)
    starts = search.bfs(s_owner, shard_q - 1) + 1
    ends = search.bfs(s_owner, shard_q) + 1
    slots = starts[:, None] + lax.broadcasted_iota(jnp.int64, (n_shards, cap), 1)
    valid = slots < ends[:, None]
    req = jnp.where(valid, jnp.take(s_val, jnp.minimum(slots, n - 1)), fill)
    return req, slots, valid, order


def unbucket_inverse(replies, slots, valid, order, n: int, init):
    """Scatter ``(n_shards, cap)`` replies back to input order.

    Entries never sent (``valid=False``) keep ``init`` — callers encode
    their drop policy there (sentinel rank, zero vector, ...).
    """
    out_sorted = jnp.full((n,) + replies.shape[2:], init, dtype=replies.dtype)
    scatter_at = jnp.where(valid.reshape(-1), slots.reshape(-1), n)
    flat = replies.reshape((-1,) + replies.shape[2:])
    out_sorted = out_sorted.at[scatter_at].set(flat, mode="drop")
    return jnp.take(out_sorted, jnp.argsort(order), axis=0)

# Exported by ``python -m repro.launch.train --print-xla-flags``; a real
# fleet launch sets XLA_FLAGS to this before importing jax.
OVERLAP_XLA_FLAGS = " ".join(
    [
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
        "--xla_tpu_enable_async_collective_fusion_multiple_steps=true",
        "--xla_tpu_overlap_compute_collective_tc=true",
        "--xla_enable_async_all_gather=true",
        "--xla_enable_async_collective_permute=true",
    ]
)


# ---------------------------------------------------------------------------
# psum helpers
# ---------------------------------------------------------------------------


def psum_if_mapped(x, axes):
    """``lax.psum`` over mesh axes; identity when ``axes`` is empty/None."""
    axes = tuple(axes or ())
    return lax.psum(x, axes) if axes else x


def pmean_if_mapped(x, axes):
    """``lax.pmean`` over mesh axes; identity when ``axes`` is empty/None."""
    axes = tuple(axes or ())
    return lax.pmean(x, axes) if axes else x


def psum_tree(tree, axes):
    """psum every leaf of a pytree (gradient all-reduce)."""
    axes = tuple(axes or ())
    if not axes:
        return tree
    return jax.tree.map(lambda l: lax.psum(l, axes), tree)


# ---------------------------------------------------------------------------
# Error-feedback gradient compression
# ---------------------------------------------------------------------------

METHODS = ("bf16", "int8")


def compressed_grad_leaf(g, err, method: str):
    """Compress one gradient leaf with error feedback.

    Returns ``(g_hat, new_err)`` where ``g_hat`` is the decompressed
    (wire-format) gradient and ``new_err = (g + err) - g_hat`` is carried
    to the next step.  The telescoping sum makes the *accumulated*
    compressed gradients track the accumulated true gradients to within
    one step's quantisation error.
    """
    x = g.astype(jnp.float32) + err
    if method == "bf16":
        g_hat = x.astype(jnp.bfloat16).astype(jnp.float32)
    elif method == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
        g_hat = jnp.round(x / scale) * scale
    else:
        raise ValueError(f"unknown grad compression {method!r}; choose from {METHODS}")
    return g_hat, x - g_hat


def apply_grad_compression(grads, errs, method: str):
    """Leaf-wise :func:`compressed_grad_leaf` over matching pytrees.

    Returns ``(grads_hat, new_errs)`` with the same treedef as ``grads``.
    Flatten/unflatten rather than a tuple-valued ``tree.map``: an
    ``is_leaf=isinstance(..., tuple)`` unzip would misfire on pytrees
    that themselves contain tuple nodes.
    """
    leaves_g, treedef = jax.tree_util.tree_flatten(grads)
    leaves_e = treedef.flatten_up_to(errs)
    pairs = [compressed_grad_leaf(g, e, method) for g, e in zip(leaves_g, leaves_e)]
    grads_hat = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
    new_errs = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
    return grads_hat, new_errs
