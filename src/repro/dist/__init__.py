"""Distribution substrate: mesh-aware sharding contexts and collectives.

``sharding`` maps *logical* axis names (dp / fsdp / tp / ep / edge / row)
onto whatever physical mesh the launcher built, so model code never
hard-codes mesh axis names.  ``collectives`` holds the cross-device
helpers: overlap-friendly XLA flags, psum utilities and error-feedback
gradient compression used by :mod:`repro.train.step`.  ``sharded_index``
stacks a tier of per-shard learned indexes leaf-wise and queries them
collectively under ``shard_map`` (fence-route-answer-return pipeline).
"""

from . import collectives, sharded_index, sharding
from .collectives import OVERLAP_XLA_FLAGS, apply_grad_compression, compressed_grad_leaf
from .sharded_index import (
    DROPPED,
    NO_PRED,
    ShardedIndex,
    compact_shard,
    insert_into_shard,
    refresh_shard,
    reset_tier_metrics,
    sharded_lookup,
    stack_indexes,
    tier_metrics,
)
from .sharding import ShardingCtx, single_device_ctx

__all__ = [
    "collectives",
    "sharding",
    "sharded_index",
    "OVERLAP_XLA_FLAGS",
    "apply_grad_compression",
    "compressed_grad_leaf",
    "ShardingCtx",
    "single_device_ctx",
    "DROPPED",
    "NO_PRED",
    "ShardedIndex",
    "compact_shard",
    "insert_into_shard",
    "refresh_shard",
    "reset_tier_metrics",
    "sharded_lookup",
    "stack_indexes",
    "tier_metrics",
]
