"""Distribution substrate: mesh-aware sharding contexts and collectives.

``sharding`` maps *logical* axis names (dp / fsdp / tp / ep / edge / row)
onto whatever physical mesh the launcher built, so model code never
hard-codes mesh axis names.  ``collectives`` holds the cross-device
helpers: overlap-friendly XLA flags, psum utilities and error-feedback
gradient compression used by :mod:`repro.train.step`.
"""

from . import collectives, sharding
from .collectives import OVERLAP_XLA_FLAGS, apply_grad_compression, compressed_grad_leaf
from .sharding import ShardingCtx, single_device_ctx

__all__ = [
    "collectives",
    "sharding",
    "OVERLAP_XLA_FLAGS",
    "apply_grad_compression",
    "compressed_grad_leaf",
    "ShardingCtx",
    "single_device_ctx",
]
