import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^^^ MUST precede every other import (jax locks the device count on
# first backend init).  512 host devices back both production meshes:
# the (16,16) single pod uses the first 256, the (2,16,16) multi-pod all.

"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) cell:
  jit(step).lower(...abstract inputs...).compile()
then record  memory_analysis(), cost_analysis(), and the collective
bytes parsed from the partitioned HLO — the §Roofline inputs.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --cell train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import math
import re
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

import repro  # noqa: F401  (x64 etc.)
from repro import configs
from repro.dist.sharding import ShardingCtx
from repro.launch import steps
from repro.obs.timing import stopwatch
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis
from repro.train import TrainConfig

# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip traffic bytes by collective kind, from partitioned HLO.

    Shapes in post-SPMD HLO are per-device.  Ring-model accounting:
      all-reduce: 2x result; all-gather: result; reduce-scatter: sum of
      operands; all-to-all: result; collective-permute: result.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", s)
        if m is None:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if "-done(" in rhs:
            continue  # counted at -start
        shapes = _SHAPE_RE.findall(rhs)
        if not shapes:
            continue
        # first shape(s) before '(' are the result; ones inside are operands
        paren = rhs.index("(")
        result_shapes = _SHAPE_RE.findall(rhs[:paren])
        operand_shapes = _SHAPE_RE.findall(rhs[paren:])
        rbytes = sum(_shape_bytes(d, s_) for d, s_ in result_shapes)
        obytes = sum(_shape_bytes(d, s_) for d, s_ in operand_shapes)
        if kind == "all-reduce":
            traffic = 2 * rbytes
        elif kind == "reduce-scatter":
            traffic = obytes
        else:
            traffic = rbytes
        out[kind] += traffic
        counts[kind] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    out.update(out_counts)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e targets)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

LM_FLOP_FACTORS = {"train": 6, "prefill": 2, "decode": 2}


def model_flops(spec, cell) -> float:
    """Useful-math FLOPs for the cell (6ND train / 2ND inference)."""
    if spec.family == "lm":
        cfg = spec.config
        n = cfg.active_params_count if cfg.moe else cfg.params_count
        if cell.kind == "train":
            toks = cell.dims["global_batch"] * cell.dims["seq_len"]
            return 6.0 * n * toks
        if cell.kind == "prefill":
            toks = cell.dims["global_batch"] * cell.dims["seq_len"]
            return 2.0 * n * toks
        toks = cell.dims["global_batch"]  # one token per sequence
        return 2.0 * n * toks
    return float("nan")  # gnn / recsys: report HLO flops only


def roofline(entry: dict, n_chips: int) -> dict:
    flops = entry["hlo_analysis"].get("flops", 0.0)
    bytes_ = entry["hlo_analysis"].get("bytes_major", entry["hlo_analysis"].get("bytes", 0.0))
    coll = entry["collectives"]["total"]
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / ICI_BW
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,  # fusion-ideal (bytes_major)
        "t_memory_upper_s": entry["hlo_analysis"].get("bytes", 0.0) / HBM_BW,
        "t_collective_s": t_coll,
        "dominant": dom,
        "step_time_bound_s": max(t_compute, t_memory, t_coll),
    }


# ---------------------------------------------------------------------------
# Dry-run core
# ---------------------------------------------------------------------------


def profile_for(spec) -> str:
    explicit = getattr(spec.config, "sharding_profile", None)
    if explicit:
        return explicit
    return "flat_dp" if spec.family in ("recsys", "gnn") else "tp_fsdp"


# gradient-accumulation depth per (arch, cell): the activation-memory
# knob that makes the big train cells fit 16 GB HBM (see EXPERIMENTS.md
# §Perf iteration 1 — the naive mb=1 baselines are kept for contrast).
MICROBATCHES = {
    ("granite-3-8b", "train_4k"): 8,
    ("minitron-8b", "train_4k"): 8,
    ("moonshot-v1-16b-a3b", "train_4k"): 8,
    ("qwen3-moe-235b-a22b", "train_4k"): 16,
    ("qwen2-0.5b", "train_4k"): 4,
}


def run_cell(spec, cell, mesh, multi_pod: bool, verbose=True):
    ctx = ShardingCtx(mesh=mesh, profile=profile_for(spec))
    tcfg = TrainConfig(microbatches=MICROBATCHES.get((spec.arch_id, cell.name), 1))
    sw = stopwatch()
    bundle = steps.build_step(spec, cell, ctx, tcfg)
    batch = steps.make_inputs(spec, cell, abstract=True)

    rep = ctx.sharding()
    state_sh = steps.fit_tree(bundle.state_template, bundle.state_shardings, mesh)
    batch_sh = steps.fit_tree(batch, bundle.batch_shardings, mesh)
    if spec.family == "lm" and cell.kind == "decode":
        pos_t = jax.ShapeDtypeStruct((), jnp.int32)
        cache_sh = steps.fit_tree(
            bundle.extra["cache_template"], bundle.extra["cache_shardings"], mesh
        )
        in_sh = (state_sh, cache_sh, batch_sh, rep)
        args = (bundle.state_template, bundle.extra["cache_template"], batch, pos_t)
        fn = bundle.fn
    else:  # train / prefill / serve / retrieval
        in_sh = (state_sh, batch_sh)
        args = (bundle.state_template, batch)
        fn = bundle.fn

    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t_lower = sw.elapsed
        sw1 = stopwatch()
        compiled = lowered.compile()
        t_compile = sw1.elapsed

    entry = {
        "arch": spec.arch_id,
        "cell": cell.name,
        "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": 512 if multi_pod else 256,
        "lower_s": t_lower,
        "compile_s": t_compile,
    }

    try:
        ma = compiled.memory_analysis()
        entry["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(ma, k)
        }
        if verbose:
            print(f"  memory_analysis: {entry['memory_analysis']}")
    except Exception as e:  # pragma: no cover - backend specific
        entry["memory_analysis"] = {"error": repr(e)}

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        entry["cost_analysis"] = {
            k: float(v)
            for k, v in ca.items()
            if k in ("flops", "bytes accessed", "transcendentals", "bytes accessed output")
        }
        if verbose:
            print(f"  cost_analysis: {entry['cost_analysis']}")
    except Exception as e:  # pragma: no cover
        entry["cost_analysis"] = {"error": repr(e)}

    try:
        hlo = compiled.as_text()
        # trip-count-aware analysis (scan bodies expanded — see
        # hlo_analysis.py; raw cost_analysis counts while bodies once)
        ha = hlo_analysis.analyze(hlo)
        entry["hlo_analysis"] = {
            "flops": ha["flops"], "bytes": ha["bytes"],
            "bytes_major": ha["bytes_major"], "n_dots": ha["n_dots"],
        }
        entry["collectives"] = ha["collectives"]
        entry["collectives_raw_onepass"] = collective_bytes(hlo)
        entry["hlo_bytes"] = len(hlo)
    except Exception as e:  # pragma: no cover
        entry["collectives"] = {"total": 0, "error": repr(e)}
        entry["hlo_analysis"] = {"flops": 0.0, "bytes": 0.0, "error": repr(e)}

    entry["roofline"] = roofline(entry, entry["n_chips"])
    mf = model_flops(spec, cell)
    if not math.isnan(mf):
        entry["model_flops"] = mf
        hlo_flops_total = entry["hlo_analysis"].get("flops", 0.0) * entry["n_chips"]
        entry["model_flops_ratio"] = mf / max(hlo_flops_total, 1.0)
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument(
        "--override", action="append", default=[],
        help="config override key=value (e.g. triplet_layout=padded), for §Perf iterations",
    )
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = configs.list_archs() if (args.all or args.arch is None) else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_tag = "multi" if multi_pod else "single"
        for arch in archs:
            spec = configs.get(arch)
            if args.override:
                import dataclasses
                ov = {}
                for kv in args.override:
                    k, v = kv.split("=", 1)
                    cur = getattr(spec.config, k)
                    ov[k] = type(cur)(v) if not isinstance(cur, bool) else v == "True"
                spec = dataclasses.replace(spec, config=dataclasses.replace(spec.config, **ov))
            for cell in spec.shapes:
                if args.cell and cell.name != args.cell:
                    continue
                path = out_dir / f"{arch}__{cell.name}__{mesh_tag}.json"
                if args.skip_existing and path.exists():
                    print(f"[skip] {path}")
                    continue
                print(f"[dryrun] {arch} x {cell.name} on {mesh_tag} mesh ...", flush=True)
                try:
                    entry = run_cell(spec, cell, mesh, multi_pod)
                    path.write_text(json.dumps(entry, indent=1))
                    r = entry.get("roofline", {})
                    print(
                        f"  OK lower {entry['lower_s']:.1f}s compile {entry['compile_s']:.1f}s"
                        f" | dominant={r.get('dominant')}"
                        f" bound={r.get('step_time_bound_s', 0):.4f}s"
                        f" | coll={entry['collectives']['total'] / 1e9:.3f} GB/chip",
                        flush=True,
                    )
                except Exception as e:
                    failures.append((arch, cell.name, mesh_tag))
                    print(f"  FAIL: {e}\n{traceback.format_exc()[-2000:]}", flush=True)

    print(f"\n[dryrun] done; failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
