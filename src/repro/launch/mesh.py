"""Production mesh construction (see MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a function — importing this module never
touches jax device state.  The single-pod mesh is (16, 16) = 256 chips
('data', 'model'); the multi-pod mesh is (2, 16, 16) = 512 chips with a
leading 'pod' axis (DP/FSDP compose over ('pod', 'data'); collectives
over 'pod' cross the inter-pod links).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (XLA_FLAGS device count)."""
    return jax.make_mesh(shape, axes)
