"""Production train launcher:  python -m repro.launch.train --arch <id>

Wires mesh + sharding profile + data pipeline + fault-tolerant loop for
any registered architecture.  On this container use ``--reduced`` (the
full configs need the fleet; their compile-only path is dryrun.py).
Exports the collective-overlap XLA flags a real fleet launch would set.
"""

from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", default=None, help="shape cell (default: the train cell)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-compression", default="none", choices=["none", "bf16", "int8"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--print-xla-flags", action="store_true")
    args = ap.parse_args()

    if args.print_xla_flags:
        from repro.dist.collectives import OVERLAP_XLA_FLAGS

        print(OVERLAP_XLA_FLAGS)
        return

    import jax
    import repro  # noqa: F401
    from repro import configs
    from repro.dist.sharding import ShardingCtx, single_device_ctx
    from repro.launch import steps
    from repro.train import TrainConfig, init_train_state, loop

    spec = configs.get(args.arch, reduced=args.reduced)
    cells = [c for c in spec.shapes if c.kind in ("train", "graph_train")]
    cell = next((c for c in cells if c.name == args.cell), cells[0])

    n_dev = len(jax.devices())
    if n_dev == 1:
        ctx = single_device_ctx()
    else:
        from repro.launch.dryrun import profile_for
        import math

        d = int(math.sqrt(n_dev))
        mesh = jax.make_mesh((n_dev // d, d), ("data", "model"))
        ctx = ShardingCtx(mesh=mesh, profile=profile_for(spec))

    tcfg = TrainConfig(
        lr=args.lr,
        total_steps=args.steps,
        grad_compression=args.grad_compression,
        microbatches=args.microbatches,
    )
    bundle = steps.build_step(spec, cell, ctx, tcfg)

    def batch_at(step):
        return steps.make_inputs(spec, cell, abstract=False, rng=np.random.default_rng(step))

    from repro.models import dimenet, recsys, transformer

    if spec.family == "lm":
        init_fn = lambda r: transformer.init(r, bundle.extra["cfg"])
    elif spec.family == "gnn":
        init_fn = lambda r: dimenet.init(r, bundle.extra["cfg"])
    else:
        init_fn = lambda r: recsys.init(r, bundle.extra["cfg"], ctx)

    state = init_train_state(jax.random.key(0), init_fn, tcfg)
    step_fn = jax.jit(bundle.fn)
    with ctx.mesh:
        state, report = loop.run(
            step_fn, state, batch_at,
            loop.LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50),
        )
    print(f"[train] done: {report.steps_run} steps, final loss {report.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
