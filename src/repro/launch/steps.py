"""Per-(arch x shape-cell) step builders.

For every cell this module can produce:
  * ``abstract_inputs``  — ShapeDtypeStruct stand-ins (dry-run; no alloc)
  * ``concrete_inputs``  — real random arrays (smoke tests / examples)
  * ``build_step``       — the jittable step fn + state templates +
                           in/out sharding pytrees for jax.jit

Kinds: ``train`` lowers a full optimizer step; ``prefill`` a forward
pass; ``decode`` a single-token serve step against a KV cache;
``serve``/``retrieval`` the recsys scoring paths.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeCell
from repro.dist.sharding import ShardingCtx
from repro.models import dimenet, recsys, transformer
from repro.train import TrainConfig, init_train_state, make_train_step

I32 = jnp.int32
F32 = jnp.float32


# ---------------------------------------------------------------------------
# Param sharding by path (family-specific classifiers)
# ---------------------------------------------------------------------------


def _lm_logical(path: str):
    if "moe" in path:
        if "router" in path:
            return (None, None, None)
        if "wd" in path:
            return (None, "ep", None, "fsdp")
        return (None, "ep", "fsdp", None)
    if path.endswith("embed"):
        return ("tp", "fsdp")
    if path.endswith("head"):
        return ("fsdp", "tp")
    for nm in ("wq", "wk", "wv", "wg", "wu"):
        if path.endswith(nm):
            return (None, "fsdp", "tp")
    for nm in ("wo", "wd"):
        if path.endswith(nm):
            return (None, "tp", "fsdp")
    for nm in ("bq", "bk", "bv"):
        if path.endswith(nm):
            return (None, "tp")
    return None  # norms etc: replicated


def _recsys_logical(path: str):
    if path.endswith("embed") or path.endswith("wide"):
        return ("row", None)
    return None


def _gnn_logical(path: str):
    return None  # GNN params are small: replicated


_LOGICAL = {"lm": _lm_logical, "recsys": _recsys_logical, "gnn": _gnn_logical}


def fit_sharding(shape, sharding, mesh):
    """Drop mesh axes per-dim until the dim size divides evenly.

    jit in_shardings require exact divisibility; published vocab/batch
    sizes (151936, 1e6, ...) don't always divide 256/512, so each dim
    falls back to the largest prefix of its axis tuple that does.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = sharding.spec
    new = []
    for i, entry in enumerate(spec):
        if entry is None:
            new.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if shape[i] % prod == 0:
                break
            axes = axes[:-1]
        if not axes:
            new.append(None)
        elif len(axes) == 1:
            new.append(axes[0])
        else:
            new.append(tuple(axes))
    return NamedSharding(mesh, P(*new))


def fit_tree(templates, shardings, mesh):
    """Apply fit_sharding leaf-wise over matching pytrees."""
    return jax.tree.map(
        lambda t, s: fit_sharding(t.shape, s, mesh), templates, shardings
    )


def state_shardings(state_tree, family: str, ctx: ShardingCtx):
    """NamedSharding pytree for a train/serve state by param path."""
    classify = _LOGICAL[family]
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_tree)
    out = []
    for path, leaf in flat:
        p = "/".join(str(k.key) if hasattr(k, "key") else str(k) for k in path)
        # strip optimizer prefixes so moments shard like their params
        for prefix in ("opt/m/", "opt/v/", "comp_err/"):
            if p.startswith(prefix):
                p = p[len(prefix):]
        logical = classify(p)
        if logical is None or len(logical) != leaf.ndim:
            out.append(ctx.sharding())  # replicated
        else:
            out.append(ctx.sharding(*logical))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Inputs per family x kind
# ---------------------------------------------------------------------------


def _lm_inputs(cfg, cell: ShapeCell, abstract: bool, rng=None):
    b, s = cell.dims["global_batch"], cell.dims["seq_len"]
    if cell.kind == "train":
        shp = {"tokens": ((b, s), I32), "labels": ((b, s), I32)}
    elif cell.kind == "prefill":
        shp = {"tokens": ((b, s), I32)}
    else:  # decode: one new token against a seq_len cache
        shp = {"tokens": ((b, 1), I32)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, dt) in shp.items()}
    rng = rng or np.random.default_rng(0)
    return {
        k: jnp.asarray(rng.integers(0, cfg.vocab, size=sh), dt)
        for k, (sh, dt) in shp.items()
    }


def _lm_input_shardings(cell: ShapeCell, ctx: ShardingCtx):
    if cell.kind == "train":
        return {"tokens": ctx.sharding("dp", None), "labels": ctx.sharding("dp", None)}
    if cell.kind == "prefill":
        return {"tokens": ctx.sharding("dp", None)}
    if cell.dims.get("seq_shard"):
        return {"tokens": ctx.sharding(None, None)}
    return {"tokens": ctx.sharding("dp", None)}


def _gnn_inputs(cfg, cell: ShapeCell, abstract: bool, rng=None):
    d = cell.dims
    n, e = d["n_nodes"], d["n_edges"]
    t_max = d.get("t_max", 4)
    t = e * t_max
    padded = getattr(cfg, "triplet_layout", "flat") == "padded"
    if padded:
        e = ((e + 511) // 512) * 512  # shard_map needs even edge shards
    shp = {
        "pos": ((n, 3), F32),
        "edge_src": ((e,), I32),
        "edge_dst": ((e,), I32),
    }
    if padded:
        shp["tri_kj"] = ((e, t_max), I32)
        shp["tri_mask"] = ((e, t_max), F32)
        shp["edge_mask"] = ((e,), F32)
    else:
        shp["tri_kj"] = ((t,), I32)
        shp["tri_ji"] = ((t,), I32)
    if d.get("energy"):
        shp["z"] = ((n,), I32)
        shp["node_graph"] = ((n,), I32)
        shp["target"] = ((d["n_graphs"],), F32)
    else:
        shp["feat"] = ((n, d["d_feat"]), F32)
        shp["labels"] = ((n,), I32)
        shp["label_mask"] = ((n,), F32)
    if abstract:
        return {k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, dt) in shp.items()}

    rng = rng or np.random.default_rng(0)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    batch = {
        "pos": jnp.asarray(rng.normal(0, 2, (n, 3)).astype(np.float32)),
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
    }
    if padded:
        tk, tm = dimenet.build_triplets_padded(src, dst, n, t_max=t_max)
        batch["tri_kj"] = jnp.asarray(tk)
        batch["tri_mask"] = jnp.asarray(tm)
        batch["edge_mask"] = jnp.ones((e,), jnp.float32)
    else:
        tri_kj, tri_ji = dimenet.build_triplets(src, dst, n, t_max=t_max)
        # pad/trim triplets to the fixed cell size
        if len(tri_kj) < t:
            pad = t - len(tri_kj)
            tri_kj = np.concatenate([tri_kj, np.zeros(pad, np.int32)])
            tri_ji = np.concatenate([tri_ji, np.zeros(pad, np.int32)])
        batch["tri_kj"] = jnp.asarray(tri_kj[:t])
        batch["tri_ji"] = jnp.asarray(tri_ji[:t])
    if d.get("energy"):
        batch["z"] = jnp.asarray(rng.integers(0, cfg.n_species, n).astype(np.int32))
        ng = d["n_graphs"]
        batch["node_graph"] = jnp.asarray(np.sort(rng.integers(0, ng, n)).astype(np.int32))
        batch["target"] = jnp.asarray(rng.normal(0, 1, ng).astype(np.float32))
    else:
        batch["feat"] = jnp.asarray(rng.normal(0, 1, (n, d["d_feat"])).astype(np.float32))
        batch["labels"] = jnp.asarray(rng.integers(0, d["n_out"], n).astype(np.int32))
        batch["label_mask"] = jnp.asarray((rng.random(n) < 0.5).astype(np.float32))
    return batch


def _gnn_input_shardings(cell: ShapeCell, ctx: ShardingCtx, cfg=None):
    e_shard = ctx.sharding("edge")
    rep = ctx.sharding()
    out = {
        "pos": rep,
        "edge_src": e_shard,
        "edge_dst": e_shard,
    }
    if cfg is not None and getattr(cfg, "triplet_layout", "flat") == "padded":
        out["tri_kj"] = ctx.sharding("edge", None)
        out["tri_mask"] = ctx.sharding("edge", None)
        out["edge_mask"] = e_shard
    else:
        out["tri_kj"] = e_shard
        out["tri_ji"] = e_shard
    if cell.dims.get("energy"):
        out.update({"z": rep, "node_graph": rep, "target": rep})
    else:
        out.update({"feat": rep, "labels": rep, "label_mask": rep})
    return out


def _recsys_inputs(cfg, cell: ShapeCell, abstract: bool, rng=None):
    b = cell.dims["batch"]
    f = cfg.n_sparse
    shp = {"sparse": ((b, f), I32)}
    if cfg.kind == "dlrm":
        shp["dense"] = ((b, cfg.n_dense), F32)
    if cfg.kind == "din":
        shp["hist"] = ((b, cfg.seq_len), I32)
    if cfg.kind == "sasrec":
        shp = {"seq": ((b, cfg.seq_len), I32), "target": ((b,), I32)}
    if cell.kind == "train":
        shp["label"] = ((b,), F32)
    if cell.kind == "retrieval":
        shp["candidates"] = ((cell.dims["n_candidates"],), I32)
    if abstract:
        return {k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, dt) in shp.items()}
    rng = rng or np.random.default_rng(0)
    out = {}
    for k, (sh, dt) in shp.items():
        if dt == I32:
            if k in ("sparse",):
                cols = [rng.integers(0, v, size=(sh[0], 1)) for v in cfg.vocab_sizes]
                out[k] = jnp.asarray(np.concatenate(cols, 1).astype(np.int32))
            elif k in ("hist", "seq", "target", "candidates"):
                out[k] = jnp.asarray(rng.integers(0, cfg.vocab_sizes[0], size=sh).astype(np.int32))
            else:
                out[k] = jnp.asarray(rng.integers(0, 2, size=sh).astype(np.int32))
        else:
            out[k] = jnp.asarray(rng.normal(0, 1, sh).astype(np.float32))
    if cell.kind == "train":
        out["label"] = jnp.asarray((rng.random(b) < 0.3).astype(np.float32))
    return out


def _recsys_input_shardings(cfg, cell: ShapeCell, ctx: ShardingCtx):
    dp = lambda *rest: ctx.sharding("dp", *rest)
    rep = ctx.sharding()
    if cfg.kind == "sasrec":
        out = {"seq": dp(None), "target": ctx.sharding("dp")}
    else:
        out = {"sparse": dp(None)}
        if cfg.kind == "dlrm":
            out["dense"] = dp(None)
        if cfg.kind == "din":
            out["hist"] = dp(None)
    if cell.kind == "train":
        out["label"] = ctx.sharding("dp")
    if cell.kind == "retrieval":
        # batch=1: user side replicated, candidate list sharded on dp
        out = {k: rep for k in out}
        out["candidates"] = ctx.sharding("dp")
    return out


def make_inputs(spec: ArchSpec, cell: ShapeCell, abstract: bool, rng=None):
    if spec.family == "lm":
        return _lm_inputs(spec.config, cell, abstract, rng)
    if spec.family == "gnn":
        return _gnn_inputs(_cfg_for_cell(spec, cell), cell, abstract, rng)
    if spec.family == "recsys":
        return _recsys_inputs(spec.config, cell, abstract, rng)
    raise ValueError(spec.family)


def input_shardings(spec: ArchSpec, cell: ShapeCell, ctx: ShardingCtx):
    if spec.family == "lm":
        return _lm_input_shardings(cell, ctx)
    if spec.family == "gnn":
        return _gnn_input_shardings(cell, ctx, _cfg_for_cell(spec, cell))
    if spec.family == "recsys":
        return _recsys_input_shardings(spec.config, cell, ctx)
    raise ValueError(spec.family)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


@dataclass
class StepBundle:
    """Everything jax.jit needs for one (arch x cell)."""

    fn: object  # (state, batch) -> ...   or (params, cache, batch, pos)
    state_template: object  # pytree of ShapeDtypeStruct
    state_shardings: object
    batch_shardings: object
    extra: dict


def _cfg_for_cell(spec: ArchSpec, cell: ShapeCell):
    cfg = spec.config
    if spec.family == "gnn" and cell.kind == "graph_train":
        from dataclasses import replace

        d = cell.dims
        if d.get("energy"):
            cfg = replace(cfg, n_out=1, n_graphs=d["n_graphs"], d_feat=0, t_max=d.get("t_max", 4))
        else:
            cfg = replace(
                cfg, n_out=d["n_out"], d_feat=d["d_feat"], n_graphs=0, t_max=d.get("t_max", 4)
            )
    return cfg


def build_step(
    spec: ArchSpec, cell: ShapeCell, ctx: ShardingCtx, tcfg: Optional[TrainConfig] = None
):
    tcfg = tcfg or TrainConfig()
    cfg = _cfg_for_cell(spec, cell)
    family = spec.family

    if family == "lm":
        if cell.kind == "train":
            loss = partial(transformer.loss_fn, cfg=cfg, ctx=ctx)
            init_fn = lambda r: transformer.init(r, cfg)
            step = make_train_step(lambda p, b: loss(p, b), tcfg)
            state_t = jax.eval_shape(
                lambda r: init_train_state(r, init_fn, tcfg), jax.random.key(0)
            )
            st_shard = state_shardings(state_t, family, ctx)
            return StepBundle(step, state_t, st_shard, _lm_input_shardings(cell, ctx), {"cfg": cfg})
        if cell.kind == "prefill":
            def fn(params, batch):
                # full-sequence forward; only the last position's logits
                # leave the step (decode takes over from here) — the
                # (B, S, V) logits tensor is never materialised.
                h = transformer.forward(params, batch["tokens"], cfg, ctx)
                logits = jnp.einsum("bd,dv->bv", h[:, -1], params["head"].astype(h.dtype))
                return ctx.constrain(logits.astype(jnp.float32), "dp", "tp")

            params_t = jax.eval_shape(lambda r: transformer.init(r, cfg), jax.random.key(0))
            return StepBundle(fn, params_t, state_shardings(params_t, family, ctx),
                              _lm_input_shardings(cell, ctx), {"cfg": cfg})
        if cell.kind == "decode":
            seq_shard = bool(cell.dims.get("seq_shard"))
            b, s = cell.dims["global_batch"], cell.dims["seq_len"]

            def fn(params, cache, batch, pos):
                return transformer.decode_step(
                    params, cache, batch["tokens"], pos, cfg, ctx, seq_shard=seq_shard
                )

            params_t = jax.eval_shape(lambda r: transformer.init(r, cfg), jax.random.key(0))
            cache_t = jax.eval_shape(lambda: transformer.init_cache(cfg, b, s))
            cax = transformer.cache_logical_axes(seq_shard)
            cache_sh = {k: ctx.sharding(*v) for k, v in cax.items()}
            return StepBundle(
                fn, params_t, state_shardings(params_t, family, ctx),
                _lm_input_shardings(cell, ctx),
                {"cfg": cfg, "cache_template": cache_t, "cache_shardings": cache_sh},
            )

    if family == "gnn":
        loss = partial(dimenet.loss_fn, cfg=cfg, ctx=ctx)
        init_fn = lambda r: dimenet.init(r, cfg)
        step = make_train_step(lambda p, b: loss(p, b), tcfg)
        state_t = jax.eval_shape(lambda r: init_train_state(r, init_fn, tcfg), jax.random.key(0))
        return StepBundle(step, state_t, state_shardings(state_t, family, ctx),
                          _gnn_input_shardings(cell, ctx, cfg), {"cfg": cfg})

    if family == "recsys":
        params_init = lambda r: recsys.init(r, cfg, ctx)
        if cell.kind == "train":
            loss = partial(recsys.loss_fn, cfg=cfg, ctx=ctx)
            step = make_train_step(lambda p, b: loss(p, b), tcfg)
            state_t = jax.eval_shape(
                lambda r: init_train_state(r, params_init, tcfg), jax.random.key(0)
            )
            return StepBundle(step, state_t, state_shardings(state_t, family, ctx),
                              _recsys_input_shardings(cfg, cell, ctx), {"cfg": cfg})
        params_t = jax.eval_shape(params_init, jax.random.key(0))
        if cell.kind == "serve":
            fn = lambda p, b: recsys.score_fn(p, b, cfg, ctx)
        else:  # retrieval
            fn = lambda p, b: recsys.retrieval_fn(p, b, cfg, ctx)
        return StepBundle(fn, params_t, state_shardings(params_t, family, ctx),
                          _recsys_input_shardings(cfg, cell, ctx), {"cfg": cfg})

    raise ValueError((family, cell.kind))
