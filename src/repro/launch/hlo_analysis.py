"""Trip-count-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once**, but every
scanned layer stack / q-chunk loop / CE chunk loop is a while loop — so
flops, bytes and collective bytes would be under-counted by the trip
count (40-94x for the LM architectures).  This module re-derives the
three roofline terms from the partitioned HLO text with loops expanded:

  * computations are parsed with per-computation symbol tables
    (name -> shape) so operand shapes resolve;
  * ``while`` ops multiply their body/cond cost by the trip count
    recovered from the condition computation's compare constant;
  * dot flops = 2 x |result| x (contracted dims of lhs);
  * bytes model HBM traffic: result + operands per op, fusions counted
    at their boundary (internals stay in registers), gathers counted as
    touched-bytes (result + indices) rather than the full source array;
  * collective traffic uses the ring model (all-reduce 2x, all-gather
    result, reduce-scatter operands, all-to-all / permute result).

Shapes in post-SPMD HLO are per-device, so every total is per-chip.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]"
)
_DEF_RE = re.compile(r"^\s*(%[\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_CALLEE_RE = re.compile(r"(?:calls|to_apply|body|condition)=(%[\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"\b[su](?:8|16|32|64)\[\]\s+constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_ELEMENTWISE = (
    "add",
    "subtract",
    "multiply",
    "divide",
    "maximum",
    "minimum",
    "exponential",
    "log",
    "rsqrt",
    "sqrt",
    "tanh",
    "logistic",
    "power",
    "select",
    "compare",
    "and",
    "or",
    "xor",
    "negate",
    "abs",
    "floor",
    "ceil",
)
_FREE_OPS = ("parameter", "constant", "tuple(", "get-tuple-element", "bitcast", "iota")
_GATHERISH = ("gather(", "dynamic-slice(", "dynamic-update-slice(", "scatter(")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _dims(dim_str: str):
    return [int(d) for d in dim_str.split(",") if d]


def _shape_numel(dim_str: str) -> int:
    n = 1
    for d in _dims(dim_str):
        n *= d
    return n


def _shapes_bytes(text: str) -> int:
    return sum(_shape_numel(dims) * _DTYPE_BYTES[dt] for dt, dims in _SHAPE_RE.findall(text))


@dataclass
class Computation:
    name: str
    is_entry: bool
    lines: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)  # %name -> (dtype, dims)
    constants: dict = field(default_factory=dict)  # %name -> int value
    root: str = ""


def parse_computations(hlo: str) -> dict:
    comps = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_HDR_RE.match(line.strip())
        if m and line.strip().endswith("{"):
            cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        s = line.strip()
        is_root = s.startswith("ROOT ")
        if is_root:
            s = s[5:]
        d = _DEF_RE.match(s)
        if d:
            cur.lines.append((d.group(1), d.group(2)))
            if is_root:
                cur.root = d.group(1)
            first = _SHAPE_RE.findall(d.group(2).split("(")[0])
            if first:
                cur.symbols[d.group(1)] = first  # result shape(s)
            mc = re.search(r"constant\((\d+)\)", d.group(2))
            if mc and "[]" in d.group(2).split("(")[0]:
                cur.constants[d.group(1)] = int(mc.group(1))
    return comps


def _operand_names(rhs: str):
    paren = rhs.find("(")
    if paren < 0:
        return []
    inner = rhs[paren + 1 :]
    depth = 1
    out = []
    token = ""
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        token += ch
    for part in token.split(","):
        part = part.strip()
        m = re.search(r"(%[\w.\-]+)$", part)
        if m:
            out.append(m.group(1))
    return out


def _dot_flops(rhs: str, symbols: dict) -> float:
    res = _SHAPE_RE.findall(rhs.split("(")[0])
    if not res:
        return 0.0
    result_numel = sum(_shape_numel(d) for _, d in res)
    ops = _operand_names(rhs)
    k = 1
    m = _CONTRACT_RE.search(rhs)
    if m and ops:
        lhs_shapes = symbols.get(ops[0])
        if lhs_shapes:
            dims = _dims(lhs_shapes[0][1])
            for ci in _dims(m.group(1)):
                if ci < len(dims):
                    k *= dims[ci]
    return 2.0 * result_numel * k


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_major: float = 0.0  # fusion-ideal: dots/gathers/reduces/copies/colls
    coll: dict = None
    dots: int = 0

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in _COLLECTIVES}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_major += other.bytes_major * mult
        self.dots += int(other.dots * mult)
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult


def _param_name_of(callee: Computation, k: int):
    for nm, rhs in callee.lines:
        m = re.search(r"parameter\((\d+)\)", rhs)
        if m and int(m.group(1)) == k:
            return nm
    return None


def _fusion_operand_bytes(callee: Computation, k: int, full_bytes: int) -> int:
    """Touched bytes of fusion operand k: if the fused body only
    dynamic-slices/gathers from it, charge the slice, not the array
    (a scanned layer stack is read one layer at a time)."""
    pname = _param_name_of(callee, k)
    if pname is None:
        return full_bytes
    sliced = 0
    used_whole = False
    for nm, rhs in callee.lines:
        if nm == pname:
            continue
        ops = _operand_names(rhs)
        if pname not in ops:
            continue
        if "dynamic-slice(" in rhs or " gather(" in rhs:
            sliced += _shapes_bytes(rhs.split("(")[0])
        elif "dynamic-update-slice(" in rhs:
            # param is the big destination: traffic = the update operand
            upd = ops[1] if len(ops) > 1 else None
            shp = callee.symbols.get(upd) if upd else None
            if shp:
                sliced += sum(_shape_numel(d) * _DTYPE_BYTES[t] for t, d in shp)
            else:
                used_whole = True
        else:
            used_whole = True
    if used_whole or sliced == 0:
        return full_bytes
    return min(full_bytes, sliced)


def _trip_count(cond: Computation, comps: dict) -> int:
    """Trip count from the cond's ROOT compare: resolve its constant
    operand (directly, or through one wrapped-compare fusion level)."""
    root_rhs = None
    for nm, rhs in cond.lines:
        if nm == cond.root:
            root_rhs = rhs
            break
    candidates = []
    if root_rhs is not None:
        ops = _operand_names(root_rhs)
        for o in ops:
            if o in cond.constants:
                candidates.append(cond.constants[o])
        if not candidates and "fusion(" in root_rhs:
            # wrapped compare: the scalar constant is still a cond operand
            for o in ops:
                if o in cond.constants:
                    candidates.append(cond.constants[o])
    if not candidates:  # fallback: any scalar int constant in the cond
        candidates = [v for v in cond.constants.values()]
    return max(candidates) if candidates else 1


_COMPS_CTX: dict = {}


def analyze(hlo: str) -> dict:
    global _COMPS_CTX
    comps = parse_computations(hlo)
    _COMPS_CTX = comps
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {"total": 0.0}}

    memo: dict = {}

    def cost_of(name: str, bytes_at_boundary: bool) -> Cost:
        key = (name, bytes_at_boundary)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        total = Cost()
        if comp is None:
            memo[key] = total
            return total
        memo[key] = total  # guard cycles
        for sym, rhs in comp.lines:
            head = rhs.split("(")[0]
            # --- flops ---
            if " dot(" in f" {rhs}" or head.strip().endswith("dot"):
                total.flops += _dot_flops(rhs, comp.symbols)
                total.dots += 1
            else:
                pre = rhs.split("(")[0].split()
                op_kind = pre[-1] if ("(" in rhs and pre) else ""
                if op_kind in _ELEMENTWISE:
                    res = _SHAPE_RE.findall(rhs.split("(")[0])
                    total.flops += sum(_shape_numel(d) for _, d in res)

            # --- control flow ---
            if " while(" in rhs:
                body = cond = None
                for callee in _CALLEE_RE.findall(rhs):
                    if "body=" + callee in rhs:
                        body = callee
                    if "condition=" + callee in rhs:
                        cond = callee
                trips = _trip_count(comps[cond], comps) if cond in comps else 1
                if body:
                    total.add(cost_of(body, True), trips)
                if cond and cond in comps:
                    total.add(cost_of(cond, True), trips)
            elif " fusion(" in rhs:
                m = _CALLEE_RE.search(rhs)
                if m:  # flops recurse; bytes counted at the fusion boundary
                    inner = cost_of(m.group(1), False)
                    total.flops += inner.flops
                    total.dots += inner.dots
            elif " call(" in rhs or "to_apply=" in rhs:
                m = _CALLEE_RE.search(rhs)
                if m and ("custom-call" not in rhs):
                    total.add(cost_of(m.group(1), True), 1.0)
            elif " conditional(" in rhs:
                m = _BRANCH_RE.search(rhs)
                if m:
                    branches = [b.strip() for b in m.group(1).split(",")]
                    sub = [cost_of(b, True) for b in branches if b in comps]
                    if sub:  # worst-case branch
                        worst = max(sub, key=lambda c: c.flops + c.bytes)
                        total.add(worst, 1.0)

            # --- collectives ---
            kind = None
            for k in _COLLECTIVES:
                if re.search(rf"\b{k}(-start)?\(", rhs):
                    kind = k
                    break
            if kind is not None:
                # tuple-typed collectives: result shapes precede the op
                # keyword, not the first '(' (which opens the tuple type)
                kw = re.search(rf"\b{kind}(-start)?\(", rhs)
                res_b = _shapes_bytes(rhs[: kw.start()] if kw else rhs.split("(")[0])
                op_names = _operand_names(rhs[kw.start():] if kw else rhs)
                op_b = 0
                for on in op_names:
                    shp = comp.symbols.get(on)
                    if shp:
                        op_b += sum(_shape_numel(d) * _DTYPE_BYTES[t] for t, d in shp)
                if kind == "all-reduce":
                    traffic = 2 * res_b
                elif kind == "reduce-scatter":
                    traffic = op_b if op_b else res_b
                else:
                    traffic = res_b
                total.coll[kind] += traffic

            # --- bytes (HBM traffic model) ---
            if not bytes_at_boundary:
                continue
            if any(rhs.startswith(f) or f" {f}" in rhs[:32] for f in _FREE_OPS):
                continue
            res_b = _shapes_bytes(rhs.split("(")[0])
            if "dynamic-update-slice(" in rhs:
                ops = _operand_names(rhs)
                upd = ops[1] if len(ops) > 1 else None
                shp = comp.symbols.get(upd) if upd else None
                ub = (sum(_shape_numel(d) * _DTYPE_BYTES[t] for t, d in shp)
                      if shp else res_b)
                total.bytes += 2 * ub
                total.bytes_major += 2 * ub
                continue
            if any(g in rhs for g in _GATHERISH):
                # touched bytes: result (+update) + indices, not the source
                total.bytes += 2 * res_b
                total.bytes_major += 2 * res_b
                continue
            op_b = 0
            callee = None
            if " fusion(" in rhs:
                mcal = _CALLEE_RE.search(rhs)
                if mcal:
                    callee = _COMPS_CTX.get(mcal.group(1))
            for k, on in enumerate(_operand_names(rhs)):
                shp = comp.symbols.get(on)
                if shp:
                    full = sum(_shape_numel(d) * _DTYPE_BYTES[t] for t, d in shp)
                    if callee is not None:
                        full = _fusion_operand_bytes(callee, k, full)
                    op_b += full
            total.bytes += res_b + op_b
            # fusion-ideal traffic: only ops a TPU pipeline must spill
            opk = rhs.split("(")[0].split()
            opk = opk[-1] if opk else ""
            if (" dot(" in f" {rhs}" or " fusion(" in rhs or " copy(" in rhs
                    or " reduce(" in rhs or " custom-call(" in rhs
                    or any(re.search(rf"\b{k}(-start)?\(", rhs) for k in _COLLECTIVES)):
                total.bytes_major += res_b + op_b
        return total

    c = cost_of(entry.name, True)
    coll = {k: float(v) for k, v in c.coll.items()}
    coll["total"] = float(sum(coll.values()))
    return {
        "flops": float(c.flops),
        "bytes": float(c.bytes),
        "bytes_major": float(c.bytes_major),
        "collectives": coll,
        "n_dots": c.dots,
    }
