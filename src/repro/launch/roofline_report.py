"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.roofline_report results/dryrun
"""

from __future__ import annotations

import json
import math
import sys
from pathlib import Path

MOVE_HINTS = {
    ("lm", "compute"): "raise arithmetic intensity (larger per-chip batch; fuse attention)",
    ("lm", "memory"): (
        "flash-attention Pallas kernel + fused softmax-xent remove materialised logits"
    ),
    ("lm", "collective"): (
        "overlap FSDP all-gathers with layer compute; grad compression for DP psum"
    ),
    ("gnn", "collective"): (
        "node-shard the segment-sum: exchange sorted edge partials instead of"
        " all-gathering messages"
    ),
    ("gnn", "memory"): "cache RBF/SBF bases across blocks; fuse gather+MLP",
    ("recsys", "collective"): "a2a owner-exchange lookup instead of masked-gather+psum",
    ("recsys", "memory"): "fuse embedding gather with interaction (one-hot matmul kernel)",
    ("recsys", "compute"): "batch the candidate MLP; hoist user-side features",
}


def load(dirpath: str, mesh: str):
    rows = []
    for f in sorted(Path(dirpath).glob(f"*__{mesh}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


FAMILY = {}


def family_of(arch: str) -> str:
    if arch in ("dimenet",):
        return "gnn"
    if arch in ("dlrm-mlperf", "din", "wide-deep", "sasrec"):
        return "recsys"
    return "lm"


def dryrun_table(rows):
    out = [
        "| arch | cell | mesh | compile | temp/chip | args/chip | collectives (AR/AG/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|",
    ]
    for e in rows:
        ma = e.get("memory_analysis", {})
        c = e.get("collectives_raw_onepass", e.get("collectives", {}))
        counts = "/".join(
            str(c.get(f"n_{k}", "-"))
            for k in (
                "all-reduce",
                "all-gather",
                "reduce-scatter",
                "all-to-all",
                "collective-permute",
            )
        )
        out.append(
            f"| {e['arch']} | {e['cell']} | {e['mesh']} | {e['compile_s']:.1f}s "
            f"| {ma.get('temp_size_in_bytes', 0) / 1e9:.2f} GB "
            f"| {ma.get('argument_size_in_bytes', 0) / 1e9:.2f} GB "
            f"| {counts} |"
        )
    return "\n".join(out)


def roofline_table(rows):
    out = [
        "| arch | cell | t_compute | t_memory (ideal..upper) | t_collective | dominant"
        " | bound | MODEL/HLO flops | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for e in rows:
        r = e.get("roofline", {})
        fam = family_of(e["arch"])
        hint = MOVE_HINTS.get((fam, r.get("dominant", "")), "")
        mfr = e.get("model_flops_ratio")
        mfr_s = f"{mfr:.2f}" if isinstance(mfr, float) and not math.isnan(mfr) else "n/a"
        out.append(
            f"| {e['arch']} | {e['cell']} | {r.get('t_compute_s', 0):.3f}s "
            f"| {r.get('t_memory_s', 0):.3f}..{r.get('t_memory_upper_s', 0):.3f}s "
            f"| {r.get('t_collective_s', 0):.3f}s | {r.get('dominant', '?')} "
            f"| {r.get('step_time_bound_s', 0):.3f}s | {mfr_s} | {hint} |"
        )
    return "\n".join(out)


def mfu_summary(rows):
    out = ["| arch | cell | roofline fraction (t_compute / bound) |", "|---|---|---|"]
    for e in rows:
        r = e.get("roofline", {})
        b = r.get("step_time_bound_s", 0)
        frac = r.get("t_compute_s", 0) / b if b else 0.0
        out.append(f"| {e['arch']} | {e['cell']} | {frac * 100:.1f}% |")
    return "\n".join(out)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    for mesh in ("single", "multi"):
        rows = load(d, mesh)
        print(f"\n### Dry-run — {mesh} mesh ({'256' if mesh == 'single' else '512'} chips)\n")
        print(dryrun_table(rows))
        if mesh == "single":
            print(f"\n### Roofline — {mesh} mesh\n")
            print(roofline_table(rows))
            print("\n### Roofline fraction\n")
            print(mfu_summary(rows))


if __name__ == "__main__":
    main()
