"""The 10 assigned architectures, exact published configs.

  LM:     granite-3-8b, minitron-8b, qwen2-0.5b,
          moonshot-v1-16b-a3b (MoE 64e top-6), qwen3-moe-235b-a22b (128e top-8)
  GNN:    dimenet
  RecSys: dlrm-mlperf, din, wide-deep, sasrec

Each also ships a ``reduced`` variant (same topology, tiny dims) for the
CPU smoke tests; the full configs are exercised only via the dry-run.
"""

from __future__ import annotations

from dataclasses import replace

from repro.models.transformer import LMConfig
from repro.models.dimenet import DimeNetConfig
from repro.models.recsys import RecsysConfig, CRITEO_VOCABS

from .base import ArchSpec, LM_SHAPES, RECSYS_SHAPES, ShapeCell, gnn_shapes, register

# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

GRANITE_3_8B = LMConfig(
    name="granite-3-8b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    head_dim=128, d_ff=12800, vocab=49155,
)
MINITRON_8B = LMConfig(
    name="minitron-8b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    head_dim=128, d_ff=16384, vocab=256000,
)
QWEN2_05B = LMConfig(
    name="qwen2-0.5b", n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    head_dim=64, d_ff=4864, vocab=151936, qkv_bias=True,
    sharding_profile="dp_only",  # 14 heads don't divide a 16-way TP axis
)
MOONSHOT_16B_A3B = LMConfig(
    name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
    n_kv_heads=16, head_dim=128, d_ff=0, vocab=163840,
    moe=True, n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
)
QWEN3_MOE_235B = LMConfig(
    name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
    n_kv_heads=4, head_dim=128, d_ff=0, vocab=151936,
    moe=True, n_experts=128, top_k=8, n_shared=0, d_ff_expert=1536,
)


def _lm_reduced(cfg: LMConfig) -> LMConfig:
    return replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, cfg.n_kv_heads * 4 // cfg.n_heads),
        head_dim=16,
        d_ff=0 if cfg.moe else 128,
        vocab=256,
        n_experts=8 if cfg.moe else 0,
        top_k=min(2, cfg.top_k) if cfg.moe else 0,
        d_ff_expert=32 if cfg.moe else 0,
        n_shared=min(1, cfg.n_shared),
        q_chunk=64,
    )


def _lm_spec(cfg):
    def full():
        return ArchSpec(cfg.name, "lm", cfg, LM_SHAPES)

    def reduced():
        shapes = (
            ShapeCell("train_4k", "train", {"seq_len": 64, "global_batch": 4}),
            ShapeCell("prefill_32k", "prefill", {"seq_len": 128, "global_batch": 2}),
            ShapeCell("decode_32k", "decode", {"seq_len": 128, "global_batch": 4}),
            ShapeCell(
                "long_500k", "decode", {"seq_len": 256, "global_batch": 1, "seq_shard": True}
            ),
        )
        return ArchSpec(cfg.name, "lm", _lm_reduced(cfg), shapes)

    return full, reduced


for _cfg in (GRANITE_3_8B, MINITRON_8B, QWEN2_05B, MOONSHOT_16B_A3B, QWEN3_MOE_235B):
    register(_cfg.name, *_lm_spec(_cfg))

# ---------------------------------------------------------------------------
# GNN: DimeNet
# ---------------------------------------------------------------------------

# triplet_layout="padded" is the §Perf iteration-B result (2.8x less
# collective); --override triplet_layout=flat reproduces the baseline.
DIMENET = DimeNetConfig(
    name="dimenet", n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
    n_radial=6, triplet_layout="padded",
)


def _dimenet_full():
    return ArchSpec("dimenet", "gnn", DIMENET, gnn_shapes())


def _dimenet_reduced():
    cfg = replace(DIMENET, n_blocks=2, d_hidden=32, n_bilinear=4, n_spherical=3, n_radial=4)
    shapes = (
        ShapeCell(
            "full_graph_sm",
            "graph_train",
            {"n_nodes": 64, "n_edges": 256, "d_feat": 32, "n_out": 7, "t_max": 3},
        ),
        ShapeCell(
            "minibatch_lg",
            "graph_train",
            {"n_nodes": 124, "n_edges": 240, "d_feat": 16, "n_out": 5, "t_max": 3},
        ),
        ShapeCell(
            "ogb_products",
            "graph_train",
            {"n_nodes": 128, "n_edges": 512, "d_feat": 16, "n_out": 8, "t_max": 2},
        ),
        ShapeCell(
            "molecule",
            "graph_train",
            {"n_nodes": 10 * 4, "n_edges": 20 * 4, "n_graphs": 4, "t_max": 3, "energy": True},
        ),
    )
    return ArchSpec("dimenet", "gnn", cfg, shapes)


register("dimenet", _dimenet_full, _dimenet_reduced)

# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

DLRM_MLPERF = RecsysConfig(
    name="dlrm-mlperf", kind="dlrm", embed_dim=128, vocab_sizes=CRITEO_VOCABS,
    n_dense=13, bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1),
    interaction="dot",
)
DIN = RecsysConfig(
    name="din", kind="din", embed_dim=18, vocab_sizes=(10_000_000, 1_000_000),
    attn_mlp=(80, 40), top_mlp=(200, 80), seq_len=100, interaction="target-attn",
)
WIDE_DEEP = RecsysConfig(
    name="wide-deep", kind="wide_deep", embed_dim=32,
    vocab_sizes=tuple([1_000_000] * 5 + [100_000] * 10 + [10_000] * 10 + [1_000] * 15),
    top_mlp=(1024, 512, 256), interaction="concat",
)
SASREC = RecsysConfig(
    name="sasrec", kind="sasrec", embed_dim=50, vocab_sizes=(1_000_000,),
    n_blocks=2, n_heads=1, seq_len=50, interaction="self-attn-seq",
)


def _recsys_spec(cfg):
    def full():
        return ArchSpec(cfg.name, "recsys", cfg, RECSYS_SHAPES)

    def reduced():
        r = replace(
            cfg,
            vocab_sizes=tuple(min(v, 1000) for v in cfg.vocab_sizes),
            embed_dim=min(cfg.embed_dim, 16),
            bot_mlp=(tuple(min(x, 32) for x in cfg.bot_mlp[:-1]) + (min(cfg.embed_dim, 16),))
            if cfg.bot_mlp else (),
            top_mlp=tuple(min(x, 32) for x in cfg.top_mlp),
            attn_mlp=tuple(min(x, 16) for x in cfg.attn_mlp),
            seq_len=min(cfg.seq_len, 12) if cfg.seq_len else 0,
        )
        shapes = (
            ShapeCell("train_batch", "train", {"batch": 64}),
            ShapeCell("serve_p99", "serve", {"batch": 16}),
            ShapeCell("serve_bulk", "serve", {"batch": 128}),
            ShapeCell("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 512}),
        )
        return ArchSpec(cfg.name, "recsys", r, shapes)

    return full, reduced


for _cfg in (DLRM_MLPERF, DIN, WIDE_DEEP, SASREC):
    register(_cfg.name, *_recsys_spec(_cfg))
