"""Architecture configs: repro.configs.get("<arch-id>") -> ArchSpec."""

from . import archs  # noqa: F401  (registers the 10 archs)
from .base import ArchSpec, ShapeCell, get, list_archs
