"""Architecture registry: ``--arch <id>`` selects one of the 10 assigned
architectures (plus the paper's own benchmark suite config).

Each arch module exposes ``spec()`` (full published config + its shape
cells) and ``reduced()`` (same topology, tiny dims — the CPU smoke-test
config).  Shape cells carry everything ``input_specs`` needs to build
ShapeDtypeStruct stand-ins for the dry-run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval | graph_train
    dims: dict


@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # lm | gnn | recsys
    config: object
    shapes: tuple  # tuple[ShapeCell, ...]
    notes: str = ""


_REGISTRY: Dict[str, Callable[[], ArchSpec]] = {}
_REDUCED: Dict[str, Callable[[], ArchSpec]] = {}


def register(arch_id: str, spec_fn, reduced_fn):
    _REGISTRY[arch_id] = spec_fn
    _REDUCED[arch_id] = reduced_fn


def get(arch_id: str, reduced: bool = False) -> ArchSpec:
    table = _REDUCED if reduced else _REGISTRY
    if arch_id not in table:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}")
    return table[arch_id]()


def list_archs():
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Shared shape-cell builders
# ---------------------------------------------------------------------------

LM_SHAPES = (
    ShapeCell("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeCell("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeCell("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeCell(
        "long_500k",
        "decode",
        {"seq_len": 524288, "global_batch": 1, "seq_shard": True},
    ),
)

RECSYS_SHAPES = (
    ShapeCell("train_batch", "train", {"batch": 65536}),
    ShapeCell("serve_p99", "serve", {"batch": 512}),
    ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    ShapeCell("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)


def gnn_shapes(t_max: int = 4):
    # minibatch_lg: fanout 15-10 from 1024 seeds -> fixed padded sizes
    mb_nodes = 1024 + 1024 * 15 + 1024 * 15 * 10
    mb_edges = 1024 * 15 + 1024 * 15 * 10
    return (
        ShapeCell(
            "full_graph_sm",
            "graph_train",
            {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_out": 7, "t_max": t_max},
        ),
        ShapeCell(
            "minibatch_lg",
            "graph_train",
            {"n_nodes": mb_nodes, "n_edges": mb_edges, "d_feat": 602, "n_out": 41, "t_max": t_max},
        ),
        ShapeCell(
            "ogb_products",
            "graph_train",
            {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100, "n_out": 47, "t_max": 2},
        ),
        ShapeCell(
            "molecule",
            "graph_train",
            {
                "n_nodes": 30 * 128,
                "n_edges": 64 * 128,
                "n_graphs": 128,
                "t_max": t_max,
                "energy": True,
            },
        ),
    )
