"""repro — Learned Sorted Table Search and Static Indexes in Small Model Space.

Faithful JAX reproduction + TPU-native production framework around the
learned static indexes of Amato, Lo Bosco & Giancarlo (2021).

x64 is enabled globally: the paper's tables hold 64-bit integer keys and
CDF regression needs f64 precision. All *model* code (transformers, GNN,
recsys) uses explicit bf16/f32/int32 dtypes and is unaffected.
"""

import jax

jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
