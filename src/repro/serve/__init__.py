"""Serving substrate: continuous-batching decode engine + paged KV cache
with learned-index page table."""

from . import engine, kvcache
