"""Serving substrate: continuous-batching decode engine + paged KV cache
with learned-index page table + learned hot-key cache."""

from . import engine, hotcache, kvcache
