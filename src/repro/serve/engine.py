"""Batched serving engine: continuous-batching decode loop.

Production-shape request lifecycle without a web front-end: requests
enter a queue, are admitted into free batch slots, prefill fills their
KV rows, then every engine tick decodes one token for all live slots
(continuous batching).  Finished sequences free their slots immediately.

The decode tick is one jitted ``transformer.decode_step`` over the
padded (B, S_max) contiguous cache; per-slot positions are tracked
host-side and masked in-device.  Greedy sampling (argmax) keeps the
engine deterministic for tests.

:meth:`DecodeEngine.metrics` exposes serving counters plus the learned
index substrate's telemetry: compile-cache trace counts
(``repro.index.trace_counts()`` — a serving loop that accidentally
fragments the shared jitted lookup shows up as a climbing count, the
same signal the benchmark-smoke CI gate asserts on), the sharded tier's
routing-imbalance / drop-rate counters
(``repro.dist.tier_metrics()``), and — when the engine is built with a
``tier`` (:class:`repro.tune.rebuild.TunedTier`) — the auto-tuner's
rebuild counters.  ``tick()`` drives the tier's drift policy between
decode steps, so shard refreshes and re-tunes happen on the serving
loop without an external controller.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

import itertools

from repro.models import transformer

_ENGINE_IDS = itertools.count()


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int
    out_tokens: list = field(default_factory=list)
    done: bool = False


class DecodeEngine:
    def __init__(
        self, params, cfg, ctx, *, batch_slots: int = 8, max_seq: int = 512, tier=None
    ):
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.b = batch_slots
        self.max_seq = max_seq
        self.cache = transformer.init_cache(cfg, batch_slots, max_seq)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, dtype=np.int32)
        self.queue: List[Request] = []
        self._decode = jax.jit(self._decode_impl)
        self._prefill_tok = jax.jit(self._prefill_one)
        self.ticks = 0
        self.tokens_decoded = 0
        self.requests_finished = 0
        #: repro.obs label: unique per engine so several engines in one
        #: process keep separate serve_* counter labelsets
        self.name = f"engine{next(_ENGINE_IDS)}"
        # optional self-re-tuning index tier (repro.tune.rebuild.TunedTier):
        # the engine drives its drift policy and surfaces its counters
        self.tier = tier

    def metrics(self) -> dict:
        """Serving counters + learned-index substrate telemetry.

        The hot loop keeps plain int attributes (no registry calls per
        tick); this method publishes them into the ``repro.obs``
        registry (``serve_*``, labeled by engine) and renders the
        result — including the ``index_traces`` gauge mirror of
        ``repro.index.trace_counts()`` — from one registry snapshot.
        """
        from repro import obs
        from repro.dist import tier_metrics

        lbl = dict(engine=self.name)
        obs.metric("serve_ticks").set_value(self.ticks, **lbl)
        obs.metric("serve_tokens_decoded").set_value(self.tokens_decoded, **lbl)
        obs.metric("serve_requests_finished").set_value(self.requests_finished, **lbl)
        obs.metric("serve_queued").set(len(self.queue), **lbl)
        obs.metric("serve_live_slots").set(sum(r is not None for r in self.slot_req), **lbl)
        snap = obs.snapshot()
        traces = {
            f"{s['labels']['kind']}/{s['labels']['backend']}": int(s["value"])
            for s in snap.get("index_traces", {}).get("samples", [])
        }
        out = {
            "ticks": int(obs.sample_value(snap, "serve_ticks", **lbl)),
            "tokens_decoded": int(obs.sample_value(snap, "serve_tokens_decoded", **lbl)),
            "requests_finished": int(obs.sample_value(snap, "serve_requests_finished", **lbl)),
            "queued": int(obs.sample_value(snap, "serve_queued", **lbl)),
            "live_slots": int(obs.sample_value(snap, "serve_live_slots", **lbl)),
            "index_traces": sum(traces.values()),
            "index_trace_counts": traces,
            "tier_routing": tier_metrics(),
        }
        if self.tier is not None:
            out["tier"] = self.tier.metrics()
        return out

    # -- device fns --------------------------------------------------------
    def _decode_impl(self, params, cache, tokens, pos_per_slot):
        """One token for every slot; per-slot positions via vmapped mask."""
        # decode_step uses a single scalar pos; run it at max(pos) and mask
        # per-slot validity host-side (slots are kept position-aligned per
        # admission wave; simple and production-adequate for benches).
        pos = jnp.max(pos_per_slot)
        return transformer.decode_step(params, cache, tokens, pos, self.cfg, self.ctx)

    def _prefill_one(self, params, cache, tokens, pos):
        return transformer.decode_step(params, cache, tokens, pos, self.cfg, self.ctx)

    # -- engine ------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.b):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[slot] = req
                # prefill: feed prompt tokens one step at a time into this
                # slot's cache rows (token-level prefill keeps one jitted fn)
                for i, t in enumerate(req.prompt):
                    toks = np.zeros((self.b, 1), np.int32)
                    toks[slot, 0] = t
                    logits, self.cache = self._prefill_tok(
                        self.params, self.cache, jnp.asarray(toks), jnp.int32(i)
                    )
                self.slot_pos[slot] = len(req.prompt)
                nxt = int(np.argmax(np.asarray(logits)[slot]))
                req.out_tokens.append(nxt)

    def tick(self):
        """One continuous-batching step: admit, decode, retire (and let
        the tuned tier, if any, act on accumulated drift)."""
        if self.tier is not None:
            self.tier.maybe_compact()
            # skew-aware fence rebalancing (PR 9): no-op unless the
            # tier's policy enables it (rebalance_imbalance > 0)
            mr = getattr(self.tier, "maybe_rebalance", None)
            if mr is not None:
                mr()
        self._admit()
        live = [s for s in range(self.b) if self.slot_req[s] is not None]
        if not live:
            return False
        toks = np.zeros((self.b, 1), np.int32)
        for s in live:
            toks[s, 0] = self.slot_req[s].out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(self.slot_pos)
        )
        logits = np.asarray(logits)
        self.ticks += 1
        for s in live:
            req = self.slot_req[s]
            nxt = int(np.argmax(logits[s]))
            req.out_tokens.append(nxt)
            self.slot_pos[s] += 1
            self.tokens_decoded += 1
            if len(req.out_tokens) >= req.max_new_tokens or self.slot_pos[s] >= self.max_seq - 1:
                req.done = True
                self.requests_finished += 1
                self.slot_req[s] = None
        return True

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks
