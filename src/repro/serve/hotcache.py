"""Learned hot-key cache: a model-fronted read cache for Zipf traffic.

The serving analogue of the paper's speed-up-per-byte question: a few
thousand entries of *auxiliary serving state* in front of a
:class:`repro.tune.rebuild.TunedTier` answer the hot head of a skewed
read mix in ONE gather instead of a full sharded dispatch.  The design
is the learned-Bloom-filter idea (Kraska et al.) specialised to exact
membership over a mined hot set:

* **Sketch** — :class:`KeySketch`, a bounded host-side key-frequency
  sketch fed by every lookup batch and exponentially decayed at each
  rebuild, so yesterday's hot set ages out instead of squatting.
* **Mined hot set** — :meth:`HotKeyCache.rebuild` takes the sketch's
  top-``capacity`` keys, sorts them, and resolves their predecessor
  ranks once through the tier's drop-free ``ref`` path.
* **Model front** — the same monotone-linear root model the ``GAPPED``
  kind routes with (:func:`repro.index.updatable._route`): normalise
  the query, predict its slot, bounded-search the measured ±eps window
  (:func:`repro.core.search.bounded_upper_bound`, static step count
  from the cache capacity, so the probe compiles ONCE per capacity).
  A mispredict can only *miss* — never return a wrong rank — so model
  quality affects speed, not correctness.
* **Hits** — exact key matches answer from the resident rank array in
  one gather; a batch of all-hits skips the tier dispatch entirely.
* **Misses** — fall through to ``tier.lookup`` padded to the incoming
  batch shape (a shape the tier is already traced for — the miss path
  can never trigger a new compile mid-serve), then scatter back.
* **Invalidation** — the tier bumps :attr:`TunedTier.epoch` on every
  ``insert_batch`` / ``compact`` / ``refresh_shard`` / restack /
  rebalance; a cache whose ``built_epoch`` lags is *stale* and is
  rebuilt (or bypassed) before it can serve a wrong answer.  The
  ``hotcache_stale`` counter makes skipped invalidation auditable —
  the soak suite's seeded-bug fixture asserts on exactly this seam.

Residency is part of the documented space budget (``docs/serving.md``):
``hotcache_space_bytes`` reports device arrays + host sketch, and every
hit/miss/stale/rebuild decision is a ``hotcache_*`` catalogue metric.
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import search
from repro.core.cdf import POS_DTYPE
from repro.index.impls import _MAXKEY, _bucket_steps, _pow2ceil
from repro.index.index import count_trace

__all__ = ["KeySketch", "HotKeyCache"]


class KeySketch:
    """Bounded, decayed key-frequency sketch (host-side numpy).

    Tracks approximate per-key hit weights in at most ``capacity``
    slots.  ``update`` folds a query batch in exactly (np.unique +
    scatter-add); when the slot budget overflows, the lightest keys are
    evicted (they are, by construction, the least likely hot-set
    members).  ``age`` multiplies every weight by ``decay`` and prunes
    dust, so sustained traffic dominates stale bursts.
    """

    def __init__(self, capacity: int = 16384):
        if capacity < 1:
            raise ValueError("sketch capacity must be >= 1")
        self.capacity = int(capacity)
        self.keys = np.empty(0, dtype=np.uint64)  # sorted unique
        self.weights = np.empty(0, dtype=np.float64)

    def update(self, queries, weight: float = 1.0) -> None:
        """Fold a query batch in; ``weight`` scales the batch's counts
        (an operator priming a known-hot span against a large traffic
        backlog passes weight > 1 so the prime isn't aged into noise)."""
        q, cnt = np.unique(np.asarray(queries, dtype=np.uint64), return_counts=True)
        if len(q) == 0:
            return
        keys = np.union1d(self.keys, q)
        w = np.zeros(len(keys), dtype=np.float64)
        w[np.searchsorted(keys, self.keys)] = self.weights
        w[np.searchsorted(keys, q)] += cnt * float(weight)
        if len(keys) > self.capacity:
            keep = np.sort(np.argpartition(w, -self.capacity)[-self.capacity :])
            keys, w = keys[keep], w[keep]
        self.keys, self.weights = keys, w

    def age(self, decay: float = 0.5) -> None:
        """Exponential decay + dust pruning (weights that rounded to ~0)."""
        self.weights = self.weights * float(decay)
        live = self.weights > 1e-6
        if not live.all():
            self.keys, self.weights = self.keys[live], self.weights[live]

    def top(self, k: int) -> np.ndarray:
        """The ``k`` heaviest keys, sorted ascending (ties by key order)."""
        if len(self.keys) <= k:
            return self.keys.copy()
        pick = np.argpartition(self.weights, -k)[-k:]
        return np.sort(self.keys[pick])

    def space_bytes(self) -> int:
        return int(self.keys.nbytes + self.weights.nbytes)


@partial(jax.jit, static_argnames=("steps",))
def _probe(keys, ranks, model, n_hot, q, *, steps: int):
    """Model-guided membership probe over the resident hot set.

    Returns ``(hit, rank)``: ``hit[i]`` iff ``q[i]`` is exactly a live
    resident key, in which case ``rank[i]`` is its cached predecessor
    rank.  Pad slots sit at positions ``>= n_hot`` so a pad match can
    never count as a hit; an eps-window mispredict degrades to a miss.
    """
    count_trace("hotcache", "probe")  # python side effect: once per trace
    C = keys.shape[0]
    u = jnp.clip((q.astype(jnp.float64) - model["kmin"]) * model["inv_span"], 0.0, 1.0)
    pred = jnp.clip(jnp.floor(model["slope"] * u + model["icept"]), -4.0e15, 4.0e15)
    pred = jnp.clip(pred.astype(POS_DTYPE), 0, C - 1)
    lo = jnp.clip(pred - model["eps"], 0, C - 1)
    hi = jnp.clip(pred + model["eps"], 0, C - 1)
    ub = search.bounded_upper_bound(keys, q, lo, hi - lo + 1, steps=steps)
    pos = jnp.clip(ub - 1, 0, C - 1)
    hit = (jnp.take(keys, pos) == q) & (pos < n_hot)
    return hit, jnp.take(ranks, pos)


@jax.jit
def _merge_misses(hit, cached, tier_ranks, inv):
    """Fixed-shape miss merge: every operand is batch-shaped (``inv``
    gathers each query's compacted miss slot), so the merge compiles
    once per batch shape regardless of how many queries missed."""
    return jnp.where(hit, cached, jnp.take(tier_ranks, inv))


class HotKeyCache:
    """A learned hot-key cache wrapped around a :class:`TunedTier`.

    Drop-in for the tier on the serving path: ``lookup`` probes the
    resident hot set first, and every mutating / policy method delegates
    to the wrapped tier, so :class:`repro.serve.engine.DecodeEngine` and
    :func:`repro.obs.timed_lookup` accept either object unchanged.

    ``capacity`` is rounded up to a power of two (static probe steps =
    one compiled probe per capacity).  ``rebuild_every > 0`` re-mines
    the hot set from the sketch after that many lookups; staleness
    (tier epoch moved) triggers an immediate rebuild when
    ``rebuild_on_stale`` (the default) else a full-batch bypass — both
    are coherent, only their latency profile differs.
    """

    def __init__(
        self,
        tier,
        *,
        capacity: int = 4096,
        sketch_capacity: int | None = None,
        decay: float = 0.5,
        rebuild_every: int = 0,
        rebuild_on_stale: bool = True,
    ):
        self.tier = tier
        self.capacity = _pow2ceil(capacity)
        self.sketch = KeySketch(sketch_capacity or 4 * self.capacity)
        self.decay = float(decay)
        self.rebuild_every = int(rebuild_every)
        self.rebuild_on_stale = bool(rebuild_on_stale)
        self._steps = _bucket_steps(self.capacity)
        self._merge_warmed: set = set()
        self._lookups_since_build = 0
        self.built_epoch = -1  # behind any real epoch until the first rebuild
        self.n_hot = 0
        self._keys = jnp.full((self.capacity,), _MAXKEY, dtype=jnp.uint64)
        self._ranks = jnp.full((self.capacity,), search.NO_PRED, dtype=POS_DTYPE)
        self._model = {
            "kmin": jnp.float64(0.0),
            "inv_span": jnp.float64(0.0),
            "slope": jnp.float64(0.0),
            "icept": jnp.float64(0.0),
            "eps": jnp.asarray(0, dtype=POS_DTYPE),
        }

    # -- passthroughs (timed_lookup / DecodeEngine duck-typing) -----------
    @property
    def spec(self):
        return self.tier.spec

    @property
    def policy(self):
        return self.tier.policy

    @property
    def epoch(self) -> int:
        return self.tier.epoch

    def insert_batch(self, new_keys) -> None:
        self.tier.insert_batch(new_keys)

    def maybe_compact(self):
        return self.tier.maybe_compact()

    def maybe_rebalance(self):
        return self.tier.maybe_rebalance()

    # -- lifecycle ---------------------------------------------------------
    def stale(self) -> bool:
        return self.built_epoch != self.tier.epoch

    def space_bytes(self) -> int:
        """Cache residency: device arrays + model scalars + host sketch."""
        dev = self._keys.size * 8 + self._ranks.size * 8 + 5 * 8
        return int(dev) + self.sketch.space_bytes()

    def rebuild(self) -> int:
        """Re-mine the hot set from the (aged) sketch and refit the probe
        model; returns the resident entry count.  Ranks are resolved
        through the tier's drop-free ``ref`` dispatch with telemetry off,
        so a rebuild never perturbs the routing counters it is fed by."""
        from repro import obs
        from repro.dist.sharded_index import sharded_lookup

        self.sketch.age(self.decay)
        hot = self.sketch.top(self.capacity)
        hot = hot[hot != _MAXKEY]  # reserved pad sentinel, never a live key
        self.n_hot = len(hot)
        if self.n_hot:
            padded = np.full(self.capacity, _MAXKEY, dtype=np.uint64)
            padded[: self.n_hot] = hot
            ranks = sharded_lookup(
                self.tier.sidx,
                jnp.asarray(padded),
                self.tier.ctx,
                backend=self.tier.policy.backend,
                mode="ref",
            )
            self._keys = jnp.asarray(padded)
            self._ranks = jnp.asarray(ranks, dtype=POS_DTYPE)
            self._model = self._fit(hot)
            # A rebuild is off-path maintenance: block on the freshly
            # resolved residency here so its device work can never leak
            # into (and be billed to) the next serving lookup.
            jax.block_until_ready((self._keys, self._ranks))
        self.built_epoch = self.tier.epoch
        self._lookups_since_build = 0
        lbl = dict(tier=getattr(self.tier, "name", "-"))
        obs.metric("hotcache_rebuilds").inc(**lbl)
        obs.metric("hotcache_entries").set(self.n_hot, **lbl)
        obs.metric("hotcache_space_bytes").set(self.space_bytes(), **lbl)
        return self.n_hot

    def _fit(self, hot: np.ndarray) -> dict:
        """Monotone linear slot model + measured eps (host f64, matching
        the probe's device arithmetic; +2 margin absorbs FMA drift — an
        underestimate could only cost a miss, never a wrong rank)."""
        n = len(hot)
        kmin = np.float64(hot[0])
        span = np.float64(hot[-1]) - kmin
        inv_span = np.float64(1.0 / span) if span > 0 else np.float64(0.0)
        u = np.clip((hot.astype(np.float64) - kmin) * inv_span, 0.0, 1.0)
        slots = np.arange(n, dtype=np.float64)
        if n > 1 and span > 0:
            slope, icept = np.polyfit(u, slots, 1)
        else:
            slope, icept = np.float64(0.0), np.float64(0.0)
        pred = np.clip(np.floor(slope * u + icept), -4.0e15, 4.0e15)
        eps = int(np.max(np.abs(pred - slots))) + 2
        return {
            "kmin": jnp.float64(kmin),
            "inv_span": jnp.float64(inv_span),
            "slope": jnp.float64(slope),
            "icept": jnp.float64(icept),
            "eps": jnp.asarray(min(eps, self.capacity), dtype=POS_DTYPE),
        }

    # -- serving path ------------------------------------------------------
    def lookup(self, queries, **kw):
        """Tier-compatible lookup: probe the hot set, answer hits from
        the rank residency in one gather, fall misses through to the
        wrapped tier (padded to the incoming batch shape, which the tier
        is already traced for — a partial-miss batch can never compile),
        scatter back.  Bit-exact vs the cache-off tier by construction:
        hits replay ranks the tier itself resolved at the current
        epoch."""
        from repro import obs

        q_np = np.asarray(queries, dtype=np.uint64)
        self.sketch.update(q_np)
        self._lookups_since_build += 1
        lbl = dict(tier=getattr(self.tier, "name", "-"))
        if self.stale():
            obs.metric("hotcache_stale").inc(**lbl)
            if self.rebuild_on_stale:
                self.rebuild()
            else:
                obs.metric("hotcache_misses").inc(len(q_np), **lbl)
                return self.tier.lookup(queries, **kw)
        elif self.rebuild_every and self._lookups_since_build >= self.rebuild_every:
            self.rebuild()
        if self.n_hot == 0:
            obs.metric("hotcache_misses").inc(len(q_np), **lbl)
            return self.tier.lookup(queries, **kw)
        q = jnp.asarray(q_np)
        hit, cached = _probe(
            self._keys, self._ranks, self._model, self.n_hot, q, steps=self._steps
        )
        if len(q_np) not in self._merge_warmed:
            # trace the miss merge on the FIRST batch of each shape
            # (typically warmup), so the first partial-miss batch later
            # never pays its compile inside a timed serving window
            self._merge_warmed.add(len(q_np))
            zeros = jnp.zeros(len(q_np), dtype=POS_DTYPE)
            jax.block_until_ready(_merge_misses(hit, cached, zeros, zeros))
        hit_np = np.asarray(hit)
        n_hit = int(hit_np.sum())
        obs.metric("hotcache_hits").inc(n_hit, **lbl)
        obs.metric("hotcache_misses").inc(len(q_np) - n_hit, **lbl)
        if n_hit == len(q_np):
            return cached  # one gather, zero tier dispatches
        # fixed-shape fall-through: misses are compacted to the front of a
        # batch-shaped buffer (pad lanes replay the first miss), and the
        # scatter-back is a gather + where over batch-shaped operands — no
        # op in the miss path ever sees a miss-count-dependent shape, so a
        # partial-miss batch can never compile mid-serve
        miss_idx = np.flatnonzero(~hit_np)
        padded = np.full(len(q_np), q_np[miss_idx[0]], dtype=np.uint64)
        padded[: len(miss_idx)] = q_np[miss_idx]
        inv = np.zeros(len(q_np), dtype=POS_DTYPE)
        inv[miss_idx] = np.arange(len(miss_idx))
        tier_ranks = jnp.asarray(self.tier.lookup(padded, **kw), dtype=POS_DTYPE)
        return _merge_misses(hit, cached, tier_ranks, jnp.asarray(inv))

    # -- telemetry ---------------------------------------------------------
    def metrics(self) -> dict:
        """Wrapped tier metrics + a ``hotcache`` section rendered from
        the registry snapshot under the tier's label."""
        from repro import obs

        snap = obs.snapshot(prefix="hotcache_")
        lbl = dict(tier=getattr(self.tier, "name", "-"))
        out = self.tier.metrics()
        out["hotcache"] = {
            "entries": self.n_hot,
            "capacity": self.capacity,
            "space_bytes": self.space_bytes(),
            "built_epoch": self.built_epoch,
            "stale": self.stale(),
            "hits": int(obs.sample_value(snap, "hotcache_hits", **lbl)),
            "misses": int(obs.sample_value(snap, "hotcache_misses", **lbl)),
            "stale_detected": int(obs.sample_value(snap, "hotcache_stale", **lbl)),
            "rebuilds": int(obs.sample_value(snap, "hotcache_rebuilds", **lbl)),
        }
        return out
