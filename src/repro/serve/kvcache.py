"""Paged KV cache with a learned-index page table.

Pages of ``page_size`` tokens are allocated from a global pool; each
sequence owns an ordered list of pages.  Mapping a global token position
to (page, offset) is predecessor search over the sequence's sorted page-
start table — the paper's technique on the serving hot path (DESIGN.md
§3, integration point 5).  For the contiguous fast path used by the
decode benchmarks, :class:`ContiguousCache` wraps the plain (B, S, H, D)
layout that the Pallas flash-decode kernel consumes.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.core.pgm import build_pgm


@dataclass
class ContiguousCache:
    k: jnp.ndarray  # (L, B, S, Hkv, D)
    v: jnp.ndarray
    length: int = 0

    @staticmethod
    def init(n_layers, batch, max_seq, n_kv, head_dim, dtype=jnp.bfloat16):
        shape = (n_layers, batch, max_seq, n_kv, head_dim)
        return ContiguousCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), 0)


class PagedPool:
    """Host-side page allocator + device page store.

    The device store is (n_pages, L, page, Hkv, D) per k/v; sequences
    hold page id lists.  ``position_lookup`` builds/uses a PGM index
    over each sequence's page-start offsets.
    """

    def __init__(self, n_pages, n_layers, page_size, n_kv, head_dim, dtype=jnp.bfloat16):
        self.page_size = page_size
        shape = (n_pages, n_layers, page_size, n_kv, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        self.free = list(range(n_pages))[::-1]
        self.seq_pages: dict = {}
        self.seq_len: dict = {}
        self._pgm: dict = {}

    def add_sequence(self, seq_id: int):
        self.seq_pages[seq_id] = []
        self.seq_len[seq_id] = 0

    def release(self, seq_id: int):
        self.free.extend(self.seq_pages.pop(seq_id, []))
        self.seq_len.pop(seq_id, None)
        self._pgm.pop(seq_id, None)

    def ensure_capacity(self, seq_id: int, new_len: int):
        pages = self.seq_pages[seq_id]
        while len(pages) * self.page_size < new_len:
            if not self.free:
                raise MemoryError("KV pool exhausted")
            pages.append(self.free.pop())
        self.seq_len[seq_id] = new_len
        self._pgm.pop(seq_id, None)  # page table changed -> rebuild index

    def page_starts(self, seq_id: int) -> np.ndarray:
        n = len(self.seq_pages[seq_id])
        return (np.arange(n, dtype=np.uint64) * self.page_size).astype(np.uint64)

    def position_lookup(self, seq_id: int, positions: np.ndarray):
        """global position -> (page_id, offset) via learned predecessor
        search over the page-start table."""
        starts = self.page_starts(seq_id)
        if seq_id not in self._pgm:
            self._pgm[seq_id] = build_pgm(starts, eps=4)
        pgm = self._pgm[seq_id]
        q = jnp.asarray(np.asarray(positions, dtype=np.uint64))
        idx = pgm.predecessor(jnp.asarray(starts), q)
        pages = jnp.asarray(np.asarray(self.seq_pages[seq_id], dtype=np.int64))
        page_id = jnp.take(pages, jnp.maximum(idx, 0))
        offset = q.astype(jnp.int64) - jnp.maximum(idx, 0) * self.page_size
        return page_id, offset

    def utilization(self) -> float:
        total = len(self.free) + sum(len(p) for p in self.seq_pages.values())
        return 1.0 - len(self.free) / max(total, 1)
