"""Single-program device fit-to-serve: fit → assemble → install, one jit.

The classic shard refresh (``TunedTier.refresh``) round-trips through the
host: the fit produces numpy arrays, ``build``/``stack`` re-assemble the
Index leaves host-side, and only the final ``refresh_shard`` swap is a
donated device program.  This module closes that loop for the PGM and RS
kinds: :func:`device_refresh` compiles the WHOLE pipeline — pad the
merged keys to the tier's capacity row, run the O(log n)-depth
``fit="fast"`` corridor fit (or the exact chunked scan with
``fit="scan"``), assemble every stacked leaf (level recursion, flat
scatter concat, radix table, fused-kernel ``pk_*``/``rk_*`` re-encode)
with device segment ops, validate capacities/fences/trip-count budgets,
and install the new shard row into the *donated* tier — as ONE device
program with zero host syncs on the serve path.

Validity is a traced ``ok`` flag, not a host branch: every leaf installs
through ``where(ok, new, old)``, so a failed build (verified-ε miss,
capacity overflow, fence violation, trip-count budget) leaves the tier
bit-identical and serving never observes a torn state.  The caller reads
``ok`` lazily and falls back to the classic host refresh path — which is
exactly what :class:`repro.tune.rebuild.TunedTier` does when its policy
sets ``device_refresh=True`` (the ``device_refreshes`` obs counter
records ok/fallback outcomes).

Capacity-shape discipline: tier refreshes always fit on the padded
capacity-``m`` table (``shard_build_table``), so the leaf-level fit runs
with static ``n == m``; only the PGM *upper* levels carry traced live
counts, which the corridor drivers accept via their ``count`` argument.
A PGM that terminates in fewer levels than the tier refits degenerate
one-segment roots — bit-identical to ``_lift_pgm_levels`` — so the
recursion depth is the tier's static ``levels``, unconditionally.
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.cdf import POS_DTYPE, bit_length_device, ceil_log2_device, segment_ids
from repro.core.pgm import FAST_CHUNK, pgm_device_slopes, pgm_fit_fast, pgm_segments_scan
from repro.core.radix_spline import rs_knots_fast, rs_knots_scan, rs_verified_eps
from repro.dist.sharded_index import ShardedIndex
from repro.index import Index, count_trace
from repro.kernels.ops import pgm_level_reencode_device, rs_kernel_arrays_device

_MAXKEY = jnp.uint64(np.iinfo(np.uint64).max)

#: Kinds whose shard refresh compiles as one donated device program.
DEVICE_REFRESH_KINDS = ("PGM", "RS")

#: Fit modes the device pipeline accepts (the exactness contract per
#: mode is documented in docs/build_pipeline.md).
DEVICE_FITS = ("fast", "scan")


def pad_sorted_table_device(row, count, m: int):
    """Device counterpart of ``sharded_index._pad_sorted_table``: extend
    the ``count``-key prefix of ``row`` to the full capacity ``m`` with
    the same strictly-increasing spread continuation of the last key
    (identical uint64 arithmetic, so the padded rows are bit-equal).

    Example::

        padded = pad_sorted_table_device(row, jnp.asarray(3), 8)
    """
    row = jnp.asarray(row, dtype=jnp.uint64)
    count = jnp.asarray(count, dtype=POS_DTYPE)
    last = jnp.take(row, count - 1)
    pad = (m - count).astype(jnp.uint64)
    room = _MAXKEY - last
    step = jnp.where(room >= pad, room // jnp.maximum(pad, jnp.uint64(1)), jnp.uint64(0))
    idx = jnp.arange(m, dtype=POS_DTYPE)
    k = jnp.maximum(idx - count + 1, 0).astype(jnp.uint64)
    return jnp.where(idx < count, row, last + k * step)


def _pgm_device_arrays(tier: Index, padded_tab, eps, fit: str, chunk: int):
    """Fit + assemble every stacked PGM leaf for one shard row, entirely
    on device.  Returns ``(arrays, ok)`` with ``arrays`` in the tier's
    exact leaf shapes/dtypes and ``ok`` the accumulated validity flag
    (fit verified-ε, root termination, capacity fits, trip-count
    budgets)."""
    m = padded_tab.shape[0]
    levels = tier.s("levels")
    K = int(tier.arrays["keys"].shape[1])
    R = int(tier.arrays["rank0"].shape[1])
    eps = jnp.asarray(eps, dtype=jnp.float64)
    ok = jnp.bool_(True)

    cur_u = padded_tab
    cur_f = padded_tab.astype(jnp.float64)
    cnt = jnp.asarray(m, dtype=POS_DTYPE)
    idx_m = jnp.arange(m, dtype=POS_DTYPE)
    lvls = []  # bottom-up: (keys_u, slopes, start, nseg, parent_cnt)
    for _ in range(levels):
        if fit == "fast":
            mask, fit_ok = pgm_fit_fast(cur_f, eps, chunk=chunk, count=cnt)
            ok &= fit_ok
        else:
            mask = pgm_segments_scan(cur_f, eps, count=cnt)
        slopes, start, _ = pgm_device_slopes(cur_f, mask, eps, count=cnt)
        nseg = jnp.sum(mask.astype(POS_DTYPE))
        sel = jnp.clip(start, 0, m - 1)
        nxt_u = jnp.where(idx_m < nseg, jnp.take(cur_u, sel), _MAXKEY)
        lvls.append((nxt_u, slopes, start, nseg, cnt))
        cur_u = nxt_u
        cur_f = nxt_u.astype(jnp.float64)
        cnt = nseg
    # the greedy must have terminated in a one-segment root within the
    # tier's level budget (a deeper model cannot stack — restack cue)
    ok &= cnt == 1
    lvls.reverse()  # root-first, the stacked flat-concat order

    sizes = jnp.stack([nseg for (_, _, _, nseg, _) in lvls])
    zero = jnp.zeros((1,), dtype=POS_DTYPE)
    off = jnp.concatenate([zero, jnp.cumsum(sizes)])
    off_r = jnp.concatenate([zero, jnp.cumsum(sizes + 1)])
    ok &= off[levels] <= K
    ok &= off_r[levels] <= R

    kmin = padded_tab[0].astype(jnp.float64)
    span = padded_tab[m - 1].astype(jnp.float64) - kmin
    inv_span = jnp.where(span > 0, 1.0 / jnp.where(span > 0, span, 1.0), 1.0)

    # flat scatter-concat at traced offsets; fills mirror the host
    # _pad_pow2 sentinels (max-key / zero slope / leaf-count rank0)
    keys_flat = jnp.full((K,), _MAXKEY, dtype=jnp.uint64)
    slope_flat = jnp.zeros((K,), dtype=jnp.float64)
    pk_u0_flat = jnp.full((K,), 1.0, dtype=jnp.float32)
    pk_slope_flat = jnp.zeros((K,), dtype=jnp.float32)
    rank0_flat = jnp.full((R,), m, dtype=POS_DTYPE)
    idx_m1 = jnp.arange(m + 1, dtype=POS_DTYPE)
    max_err = jnp.float64(0.0)
    for l, (lvl_keys, lvl_slopes, lvl_start, nseg, parent_cnt) in enumerate(lvls):
        child = lvls[l + 1][0] if l + 1 < levels else padded_tab
        child_cnt = lvls[l + 1][3] if l + 1 < levels else jnp.asarray(m, POS_DTYPE)
        u0_l, slope_u, err_l = pgm_level_reencode_device(
            lvl_keys, lvl_slopes, lvl_start, nseg, child, child_cnt, kmin, span, inv_span
        )
        max_err = jnp.maximum(max_err, err_l)
        tgt = jnp.where(idx_m < nseg, off[l] + idx_m, K)
        keys_flat = keys_flat.at[tgt].set(lvl_keys, mode="drop")
        slope_flat = slope_flat.at[tgt].set(lvl_slopes, mode="drop")
        pk_u0_flat = pk_u0_flat.at[tgt].set(u0_l, mode="drop")
        pk_slope_flat = pk_slope_flat.at[tgt].set(slope_u, mode="drop")
        # rank0: nseg starts then the parent-count sentinel
        vals_r = jnp.where(idx_m1 < nseg, jnp.pad(lvl_start, (0, 1)), parent_cnt)
        tgt_r = jnp.where(idx_m1 < nseg + 1, off_r[l] + idx_m1, R)
        rank0_flat = rank0_flat.at[tgt_r].set(vals_r, mode="drop")

    pk_eps = jnp.minimum(jnp.ceil(max_err) + 2.0, float(m)).astype(jnp.int32)
    # the fused descent's trip count must fit the tier's bucketed static
    pk_window = jnp.minimum(2 * (pk_eps.astype(POS_DTYPE) + 1) + 3, max(m, 2))
    ok &= ceil_log2_device(pk_window) <= tier.s("pksteps")
    # "epi" is eps-and-n derived, both static-identical to the tier row

    arrays = {
        "keys": keys_flat,
        "slope": slope_flat,
        "rank0": rank0_flat,
        "off": off,
        "off_r": off_r,
        "sizes": sizes,
        "eps": eps.astype(jnp.int64).reshape(()),
        "pk_u0": pk_u0_flat,
        "pk_slope": pk_slope_flat,
        "pk_eps": pk_eps.reshape(()),
        "pk_kmin": kmin.reshape(()),
        "pk_inv_span": inv_span.reshape(()),
    }
    return arrays, ok


def _rs_device_arrays(tier: Index, padded_tab, eps, fit: str, chunk: int):
    """Fit + assemble every stacked RadixSpline leaf for one shard row,
    entirely on device.  Returns ``(arrays, ok)``."""
    m = padded_tab.shape[0]
    r_bits = tier.s("r_bits")
    Kc = int(tier.arrays["knot_keys"].shape[1])
    eps = jnp.asarray(eps, dtype=jnp.float64)
    keys_f = padded_tab.astype(jnp.float64)

    if fit == "fast":
        kmask, ok = rs_knots_fast(keys_f, eps, chunk=chunk)
    else:
        kmask = rs_knots_scan(keys_f, eps)
        ok = jnp.bool_(True)
    _, kpos = segment_ids(kmask)
    m_valid = jnp.sum(kmask.astype(POS_DTYPE))
    ok &= m_valid <= Kc

    # knot rows at tier capacity (Kc <= m: the capacity table is a power
    # of two and a spline never has more knots than keys)
    ids = jnp.arange(Kc, dtype=POS_DTYPE)
    sel = jnp.clip(jnp.take(kpos, jnp.minimum(ids, m - 1)), 0, m - 1)
    live = ids < m_valid
    kk = jnp.where(live, jnp.take(padded_tab, sel), _MAXKEY)
    kr = jnp.where(live, sel, m - 1)

    kmin_u = padded_tab[0]
    span_u = padded_tab[m - 1] - kmin_u
    span_bits = jnp.maximum(bit_length_device(span_u), 1).astype(POS_DTYPE)
    # r_bits is a structural static: a shard whose key span shrank below
    # it cannot install (host build would lower r_bits -> restack cue)
    ok &= span_bits >= r_bits
    shift = jnp.maximum(span_bits - r_bits, 0).astype(jnp.uint64)

    # radix table: device searchsorted over the capacity knot row; the
    # max-key pads rank at/above 2^r_bits, and clipping to m_valid makes
    # every entry equal to the host's valid-knots-only searchsorted
    pref_cap = jnp.uint64((1 << r_bits) + 1)
    prefixes = jnp.minimum((kk - kmin_u) >> shift, pref_cap).astype(POS_DTYPE)
    rt = jnp.searchsorted(prefixes, jnp.arange((1 << r_bits) + 1, dtype=POS_DTYPE), side="left")
    rt = jnp.minimum(rt, m_valid).astype(POS_DTYPE)

    # post-build verified bound: same clipped-interpolation formula as
    # build_rs, so eps_eff is bit-identical given the same knots
    meas = rs_verified_eps(keys_f, kmask)
    eps_eff = jnp.maximum(jnp.ceil(meas).astype(POS_DTYPE) + 1, 1)

    kmin_f = kmin_u.astype(jnp.float64)
    span_f = padded_tab[m - 1].astype(jnp.float64) - kmin_f
    inv_span = jnp.where(span_f > 0, 1.0 / jnp.where(span_f > 0, span_f, 1.0), 1.0)
    rk_u0, rk_slope, rk_eps = rs_kernel_arrays_device(
        kk, kr, m_valid, padded_tab, kmin_f, span_f, inv_span
    )

    # trip-count budgets against the tier's bucketed statics
    ok &= ceil_log2_device(m_valid) <= tier.s("ksteps")
    ok &= ceil_log2_device(jnp.minimum(2 * eps_eff + 3, max(m, 2))) <= tier.s("epi")
    rk_window = jnp.minimum(2 * rk_eps.astype(POS_DTYPE) + 3, max(m, 2))
    ok &= ceil_log2_device(rk_window) <= tier.s("rk_epi")

    arrays = {
        "knot_keys": kk,
        "knot_ranks": kr,
        "radix_table": rt,
        "kmin": kmin_u.reshape(()),
        "shift": shift.reshape(()),
        "eps_eff": eps_eff.reshape(()),
        "m_valid": m_valid.reshape(()),
        "rk_u0": rk_u0,
        "rk_slope": rk_slope,
        "rk_eps": rk_eps.reshape(()),
        "rk_kmin": kmin_f.reshape(()),
        "rk_inv_span": inv_span.reshape(()),
    }
    return arrays, ok


_KIND_DEVICE_ARRAYS = {"PGM": _pgm_device_arrays, "RS": _rs_device_arrays}


@partial(
    jax.jit, static_argnames=("shard", "fit", "chunk", "assemble"), donate_argnums=(0,)
)
def _device_refresh_impl(
    sidx: ShardedIndex, row, count, eps, shard: int, fit: str, chunk: int, assemble
):
    """The single donated device program: pad → fit → assemble →
    validate → ok-gated install.  Returns ``(new_sidx, ok)``; on
    ``ok == False`` every leaf keeps its old value, so the returned tier
    serves bit-identically to the input.  ``assemble`` is the kind's
    device-arrays builder, resolved host-side and passed static."""
    kind = sidx.index.kind
    count_trace(f"refresh:{kind}", f"device:{fit}")
    m = int(sidx.tables.shape[1])
    n_shards = sidx.n_shards  # static: derived from the stacked leaf shape
    padded_tab = pad_sorted_table_device(row, count, m)
    new_arrays, ok = assemble(sidx.index, padded_tab, eps, fit, chunk)

    # fence discipline, on device (same checks refresh_shard raises for)
    if shard > 0:
        prev_last = jnp.take(sidx.tables[shard - 1], sidx.counts[shard - 1] - 1)
        ok &= jnp.take(row, 0) > prev_last
    if shard + 1 < n_shards:
        ok &= jnp.take(row, count - 1) < sidx.fences[shard + 1]

    def install(new, old):
        return jnp.where(ok, new.astype(old.dtype), old)

    arrays = {
        k: v.at[shard].set(install(new_arrays[k], v[shard]))
        for k, v in sidx.index.arrays.items()
    }
    counts = sidx.counts.at[shard].set(install(count, sidx.counts[shard]))
    offsets = jnp.concatenate([jnp.zeros((1,), POS_DTYPE), jnp.cumsum(counts)[:-1]])
    out = ShardedIndex(
        index=Index(kind, sidx.index.static, arrays),
        tables=sidx.tables.at[shard].set(install(padded_tab, sidx.tables[shard])),
        fences=sidx.fences.at[shard].set(install(jnp.take(row, 0), sidx.fences[shard])),
        counts=counts,
        offsets=offsets,
    )
    return out, ok


def device_refresh(
    sidx: ShardedIndex,
    shard: int,
    merged,
    eps,
    *,
    fit: str = "fast",
    chunk: int = FAST_CHUNK,
):
    """Rebuild + hot-swap one shard as a single donated device program.

    ``merged`` is the shard's raw (unpadded, sorted, unique) key set and
    ``eps`` the tier spec's ε; the fit, every leaf assembly, the fence
    and trip-count validation, and the install all run inside ONE jit
    with the old tier donated — zero host transfers besides the merged
    key row itself.  ``fit="fast"`` uses the O(log n)-depth corridor fit
    (verified-ε checked on device); ``fit="scan"`` uses the exact
    chunked scan and produces bit-identical models to the host build.

    Returns ``(new_sidx, ok)`` where ``ok`` is a *device* bool the
    caller may read lazily: when False the returned tier is
    bit-identical to the input and the caller should fall back to the
    classic host refresh (:class:`repro.tune.rebuild.TunedTier` with
    ``RebuildPolicy(device_refresh=True)`` does, counting outcomes in
    the ``device_refreshes`` obs metric).

    Raises ``ValueError`` host-side only for conditions that require a
    restack anyway (kind unsupported, shard over capacity) — the same
    cues ``refresh_shard`` raises for.

    Example::

        sidx, ok = device_refresh(sidx, 1, merged_keys, eps=64)
        if not bool(ok):  # lazy host sync, off the serve path
            ...  # classic host refresh
    """
    kind = sidx.index.kind
    if kind not in DEVICE_REFRESH_KINDS:
        raise ValueError(
            f"device_refresh supports kinds {DEVICE_REFRESH_KINDS}, not {kind!r}"
        )
    if fit not in DEVICE_FITS:
        raise ValueError(f"unknown device fit {fit!r}; choose from {DEVICE_FITS}")
    merged = np.asarray(merged, dtype=np.uint64)
    m = int(sidx.tables.shape[1])
    if not 0 < len(merged) <= m:
        raise ValueError(
            f"shard has {len(merged)} keys for table capacity {m}: restack the tier"
        )
    if m < 2:
        raise ValueError("capacity-1 tier: use the host refresh path")
    row = np.zeros(m, dtype=np.uint64)
    row[: len(merged)] = merged
    return _device_refresh_impl(
        sidx,
        jnp.asarray(row),
        jnp.asarray(len(merged), dtype=POS_DTYPE),
        jnp.asarray(float(eps), dtype=jnp.float64),
        shard,
        fit,
        int(chunk),
        _KIND_DEVICE_ARRAYS[kind],
    )
