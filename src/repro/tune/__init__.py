"""repro.tune — batched builds + bi-criteria auto-tuning.

PR-1 made every learned index a pytree with one shared jitted lookup;
PR-2 stacked same-spec indexes into a served tier.  This package adds
the layer that decides *which* index to serve:

* :mod:`~repro.tune.batched` — ``build_many`` (one spec, many tables)
  and ``build_grid`` (many specs, one table) with vmapped array-native
  leaf fits and leaf-wise stacking (:class:`BatchedIndexes`).
* :mod:`~repro.tune.pareto` — registry-derived candidate grids, the
  measured time-space Pareto frontier, and ``best_spec_for_budget`` —
  the paper's bi-criteria PGM selection generalised to every kind.
* :mod:`~repro.tune.mining` — the SY-RMI/CDFShop mining procedure
  ported onto the batched builder.
* :mod:`~repro.tune.rebuild` — ``RebuildPolicy`` + ``TunedTier``:
  serving-side drift detection, donated shard hot-swaps, full
  re-tunes, and the counters ``DecodeEngine.metrics()`` reports.
"""

from .batched import BATCH_BACKENDS, FITS, VMAP_KINDS, BatchedIndexes, build_grid, build_many
from .mining import cdfshop_grid, mine_sy_rmi
from .pareto import (
    Candidate,
    best_candidate_for_budget,
    best_spec_for_budget,
    candidate_grid,
    frontier_report,
    pareto_frontier,
    report_specs,
    sweep,
)
from .rebuild import RebuildPolicy, TunedTier

__all__ = [
    "BATCH_BACKENDS",
    "FITS",
    "VMAP_KINDS",
    "BatchedIndexes",
    "build_grid",
    "build_many",
    "cdfshop_grid",
    "mine_sy_rmi",
    "Candidate",
    "best_candidate_for_budget",
    "best_spec_for_budget",
    "candidate_grid",
    "frontier_report",
    "pareto_frontier",
    "report_specs",
    "sweep",
    "RebuildPolicy",
    "TunedTier",
]
