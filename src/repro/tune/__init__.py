"""repro.tune — batched builds + bi-criteria auto-tuning.

PR-1 made every learned index a pytree with one shared jitted lookup;
PR-2 stacked same-spec indexes into a served tier.  This package adds
the layer that decides *which* index to serve:

* :mod:`~repro.tune.batched` — ``build_many`` (one spec, many tables)
  and ``build_grid`` (many specs, one table) with vmapped array-native
  leaf fits and leaf-wise stacking (:class:`BatchedIndexes`).
* :mod:`~repro.tune.pareto` — registry-derived candidate grids, the
  measured time-space Pareto frontier, and ``best_spec_for_budget`` —
  the paper's bi-criteria PGM selection generalised to every kind.
* :mod:`~repro.tune.mining` — the SY-RMI/CDFShop mining procedure
  ported onto the batched builder.
* :mod:`~repro.tune.rebuild` — ``RebuildPolicy`` + ``TunedTier``:
  serving-side drift detection, donated shard hot-swaps, full
  re-tunes, and the counters ``DecodeEngine.metrics()`` reports.
* :mod:`~repro.tune.device_fit` — the single-program device
  fit-to-serve pipeline: ``device_refresh`` compiles fit → leaf
  assembly → donated install as ONE jit for the PGM/RS kinds
  (``RebuildPolicy(device_refresh=True)`` opts a tier in).
"""

from .batched import (
    BATCH_BACKENDS,
    FAST_KINDS,
    FITS,
    VMAP_KINDS,
    BatchedIndexes,
    build_grid,
    build_many,
)
from .device_fit import DEVICE_FITS, DEVICE_REFRESH_KINDS, device_refresh
from .mining import cdfshop_grid, mine_sy_rmi
from .pareto import (
    Candidate,
    best_candidate_for_budget,
    best_spec_for_budget,
    candidate_grid,
    frontier_report,
    pareto_frontier,
    report_specs,
    sweep,
)
from .rebuild import RebuildPolicy, TunedTier

__all__ = [
    "BATCH_BACKENDS",
    "DEVICE_FITS",
    "DEVICE_REFRESH_KINDS",
    "FAST_KINDS",
    "FITS",
    "VMAP_KINDS",
    "device_refresh",
    "BatchedIndexes",
    "build_grid",
    "build_many",
    "cdfshop_grid",
    "mine_sy_rmi",
    "Candidate",
    "best_candidate_for_budget",
    "best_spec_for_budget",
    "candidate_grid",
    "frontier_report",
    "pareto_frontier",
    "report_specs",
    "sweep",
    "RebuildPolicy",
    "TunedTier",
]
