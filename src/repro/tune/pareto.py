"""Bi-criteria Pareto auto-tuner: which index, for this table, within
this space budget?

The paper's central result is that *space* — not accuracy — is the key
to learned-index efficiency: its bi-criteria PGM searches ε-space for
the best model under a byte budget, and the SY-RMI mining procedure
searches architecture-space the same way.  This module generalises that
search to every registered kind:

* :func:`candidate_grid` — the registry-derived spec grid (each
  :class:`~repro.index.specs.IndexSpec` subclass exposes
  ``default_grid(n_keys)``; registering a new kind automatically enrols
  it in the tuner).
* :func:`sweep` — build the grid through the batched builder
  (:func:`repro.tune.batched.build_grid`) and measure the two criteria
  per candidate: ``space_bytes`` (model bytes, the paper's accounting)
  and jit-timed lookup latency through the ONE shared query path per
  kind (a sweep compiles O(kinds), not O(candidates)).
* :func:`pareto_frontier` — the non-dominated (space, time) set.
* :func:`best_spec_for_budget` — the paper's bi-criteria selection for
  all kinds at once: fastest candidate whose model fits the budget.

Candidates and frontiers serialize to plain-dict JSON
(:func:`frontier_report` / :func:`report_specs`) so benchmark artifacts
and serving-side tuners share one format.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.obs.timing import stopwatch
from repro.index import registry
from repro.index.specs import IndexSpec

from .batched import build_grid


@dataclass
class Candidate:
    """One measured point on the time-space plane."""

    spec: IndexSpec
    space_bytes: int
    ns_per_query: float
    build_s: float
    exact: bool
    index: object = None  # the built Index (not serialized)

    @property
    def kind(self) -> str:
        return self.spec.kind

    def space_pct_of(self, n_keys: int) -> float:
        return 100.0 * self.space_bytes / (n_keys * 8)

    def to_dict(self) -> dict:
        return {
            "kind": self.spec.kind,
            "params": self.spec.params(),
            "space_bytes": int(self.space_bytes),
            "ns_per_query": float(self.ns_per_query),
            "build_s": float(self.build_s),
            "exact": bool(self.exact),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Candidate":
        spec = registry.entry(d["kind"]).spec_from_params(**d.get("params", {}))
        return cls(
            spec=spec,
            space_bytes=int(d["space_bytes"]),
            ns_per_query=float(d["ns_per_query"]),
            build_s=float(d["build_s"]),
            exact=bool(d.get("exact", True)),
        )


def candidate_grid(n_keys: int, kinds=None) -> list:
    """Registry-derived default sweep grid, in the paper's kind order.

    ``kinds`` restricts the sweep; spec classes shared by several kinds
    (L/Q/C share :class:`AtomicSpec`) contribute their grid once.
    """
    specs: list[IndexSpec] = []
    seen: set = set()
    for kind in kinds or registry.kinds():
        cls = registry.entry(kind).spec_cls
        if cls in seen:
            continue
        seen.add(cls)
        for spec in cls.default_grid(n_keys):
            if kinds is None or spec.kind in kinds:
                specs.append(spec)
    return specs


def _time_lookup(idx, table_j, queries_j, backend: str, reps: int) -> float:
    """Best-of-reps wall seconds of the shared jitted lookup."""
    idx.lookup(table_j, queries_j, backend=backend).block_until_ready()  # warmup/compile
    best = np.inf
    for _ in range(reps):
        sw = stopwatch()
        idx.lookup(table_j, queries_j, backend=backend).block_until_ready()
        best = min(best, sw.elapsed)
    return best


def sweep(
    table_np,
    specs=None,
    *,
    kinds=None,
    queries=None,
    n_queries: int = 4096,
    backend: str = "xla",
    reps: int = 3,
    seed: int = 0,
    fit: str = "auto",
    check_exact: bool = False,
) -> list:
    """Measure every candidate spec on one table: (space, latency) per
    candidate, batched builds, shared lookup traces.

    ``queries`` defaults to ``n_queries`` keys sampled from the table
    (the paper's simulation-query protocol).  ``check_exact=True`` also
    verifies every candidate's ranks against ``searchsorted`` (slower;
    benchmark gates use it, the serving tuner skips it).
    """
    table_np = np.asarray(table_np, dtype=np.uint64)
    if specs is None:
        specs = candidate_grid(len(table_np), kinds)
    # honest per-kind backend claims: a kind that does not implement the
    # timed backend (e.g. GAPPED has no pallas path yet) cannot compete
    from repro.index.impls import query_impl

    specs = [s for s in specs if backend in query_impl(s.kind).backends]
    if queries is None:
        rng = np.random.default_rng(seed)
        queries = rng.choice(table_np, size=min(n_queries, max(16, len(table_np))))
    queries = np.asarray(queries, dtype=np.uint64)
    table_j, queries_j = jnp.asarray(table_np), jnp.asarray(queries)
    want = None
    if check_exact:
        want = np.searchsorted(table_np, queries, side="right") - 1

    sw = stopwatch()
    indexes = build_grid(specs, table_np, fit=fit)
    build_s_total = sw.elapsed

    out = []
    for spec, idx in zip(specs, indexes):
        dt = _time_lookup(idx, table_j, queries_j, backend, reps)
        exact = True
        if want is not None:
            exact = bool(
                np.array_equal(np.asarray(idx.lookup(table_j, queries_j, backend=backend)), want)
            )
        out.append(
            Candidate(
                spec=spec,
                space_bytes=int(idx.space_bytes()),
                ns_per_query=dt / len(queries) * 1e9,
                build_s=float(idx.info.get("build_time", build_s_total / len(specs))),
                exact=exact,
                index=idx,
            )
        )
    return out


def pareto_frontier(candidates) -> list:
    """Non-dominated candidates, sorted by ascending space.

    A candidate is dominated if another is no larger *and* no slower
    (strictly better in at least one criterion).  Along the returned
    frontier space strictly increases and latency strictly decreases —
    the bi-criteria curve the paper plots.
    """
    ordered = sorted(candidates, key=lambda c: (c.space_bytes, c.ns_per_query))
    front: list[Candidate] = []
    best_t = np.inf
    for c in ordered:
        # the sort puts the fastest candidate of each space first, so a
        # strict time improvement implies a strictly larger space too
        if c.ns_per_query < best_t:
            front.append(c)
            best_t = c.ns_per_query
    return front


def best_candidate_for_budget(candidates, n_keys: int, space_budget_pct: float):
    """Fastest candidate whose model space fits the budget (% of the
    table's key bytes), or ``None`` when nothing fits."""
    budget = space_budget_pct / 100.0 * n_keys * 8
    fits = [c for c in candidates if c.space_bytes <= budget]
    return min(fits, key=lambda c: c.ns_per_query) if fits else None


def best_spec_for_budget(table_np, space_budget_pct: float, **sweep_kw) -> IndexSpec:
    """The paper's bi-criteria selection generalised to every registered
    kind: sweep the grid, keep candidates within ``space_budget_pct`` %
    of the table bytes, return the fastest one's spec.

    Raises ``ValueError`` if no candidate fits (the default grid's
    atomic models are ~56 bytes, so realistic budgets always have one).

    Extra keyword arguments flow to :func:`sweep` (``kinds=`` restricts
    the grid, ``backend=`` picks the timed query path, ``reps``/
    ``n_queries`` trade precision for sweep time).

    Example — pick and build the fastest index that fits 2% of the
    table, then serve it::

        spec = best_spec_for_budget(table, 2.0, n_queries=4096)
        idx = repro.index.build(spec, table)
        assert idx.space_bytes() <= 0.02 * table.nbytes
        ranks = idx.lookup(table, queries, backend="pallas")
    """
    table_np = np.asarray(table_np, dtype=np.uint64)
    cands = sweep(table_np, **sweep_kw)
    best = best_candidate_for_budget(cands, len(table_np), space_budget_pct)
    if best is None:
        floor = min(c.space_bytes for c in cands)
        raise ValueError(
            f"no candidate fits {space_budget_pct}% of {len(table_np)} keys "
            f"({space_budget_pct / 100.0 * len(table_np) * 8:.0f} bytes); "
            f"smallest candidate is {floor} bytes"
        )
    return best.spec


DEFAULT_BUDGET_PCTS = (0.05, 0.7, 2.0, 10.0)


def frontier_report(
    table_np, candidates, frontier=None, *, budget_pcts=DEFAULT_BUDGET_PCTS, extra=None
) -> dict:
    """JSON-ready report: every candidate, the frontier, budget picks."""
    table_np = np.asarray(table_np)
    n = len(table_np)
    frontier = pareto_frontier(candidates) if frontier is None else frontier
    picks = {}
    for pct in budget_pcts:
        best = best_candidate_for_budget(candidates, n, pct)
        if best is not None:
            picks[str(pct)] = best.to_dict()
    report = {
        "n_keys": int(n),
        "table_bytes": int(n * 8),
        "candidates": [c.to_dict() for c in candidates],
        "frontier": [c.to_dict() for c in frontier],
        "budget_picks": picks,
    }
    report.update(extra or {})
    return report


def report_specs(report: dict, section: str = "frontier") -> list:
    """Rebuild the :class:`IndexSpec`s from a report section (the
    round-trip used by serving-side tuners loading a mined artifact)."""
    return [Candidate.from_dict(d).spec for d in report[section]]
