"""Serving-side re-tuning: drift absorption + donated hot swaps.

A production tier is not static: keys are ingested, distributions
drift, and the spec that won the time-space trade-off at build time
stops being the winner.  :class:`TunedTier` closes the loop between the
Pareto tuner and the serving path with ONE documented mutation
lifecycle (shared with :mod:`repro.index.mutation`)::

    absorb -> overflow -> compact -> retune

* **absorb** — when the tier's spec is an *updatable* kind (``GAPPED``),
  :meth:`TunedTier.insert_batch` routes each key to its owner shard by
  the tier's fences and absorbs it **device-side** through the shard's
  gapped leaves (:func:`repro.dist.sharded_index.insert_into_shard` —
  a donated ``.at[shard].set`` swap, no host buffering, no rebuild).
* **overflow** — keys whose leaf is full divert to the shard's sorted
  delta buffer, still inside the same donated insert.
* **compact** — :meth:`TunedTier.maybe_compact` folds any delta past
  :data:`repro.index.mutation.COMPACT_FILL` back into rebalanced leaves
  (:func:`repro.dist.sharded_index.compact_shard`); only *capacity
  exhaustion* (:class:`repro.index.mutation.NeedsRebuild`) escalates to
  a shard rebuild through the donated ``refresh_shard`` path — not
  every insert, which is the point of the gapped design.
* **retune** — when total ingest since the last restack crosses
  :attr:`RebuildPolicy.retune_frac`, the whole tier is re-*tuned*:
  :func:`repro.tune.pareto.best_spec_for_budget` re-runs the
  bi-criteria selection on the merged live table at the policy's space
  budget and the tier is restacked under the (possibly different)
  winning spec.

Static kinds keep the PR-5 behaviour as the fallback arm of the same
lifecycle: ingested keys are buffered host-side per owner shard, and a
shard whose pending fraction crosses
:attr:`RebuildPolicy.shard_refresh_frac` is rebuilt with the tier's
current spec and hot-swapped (``refresh_shard``, ``donate_argnums=0``).

``ingest`` / ``maybe_rebuild`` are deprecated aliases for
:meth:`~TunedTier.insert_batch` / :meth:`~TunedTier.maybe_compact`
(one release; they emit ``DeprecationWarning``).

Every decision is a counter in :meth:`TunedTier.metrics`, surfaced by
the serving engine next to the lookup trace counts.
"""

from __future__ import annotations

import itertools
import warnings
from dataclasses import dataclass

import numpy as np

from repro.dist.sharded_index import (
    ShardedIndex,
    _fresh_tier_metrics,
    _tier_counters_from_obs,
    compact_shard,
    derived_tier_metrics,
    insert_into_shard,
    rebalance_shards,
    refresh_shard,
    route_owners,
    shard_build_table,
    shard_query_weights,
    sharded_lookup,
    weighted_quantile_bounds,
)
from repro.index import mutation, registry
from repro.index.mutation import NeedsRebuild
from repro.index.specs import IndexSpec

from .pareto import best_spec_for_budget


@dataclass(frozen=True)
class RebuildPolicy:
    """When to refresh a shard, when to re-tune the whole tier — and,
    when enabled, when sustained query-skew drift rebalances the fences
    (``rebalance_imbalance > 0``; see :meth:`TunedTier.maybe_rebalance`)."""

    space_budget_pct: float = 2.0  # bi-criteria budget for re-tuning
    shard_refresh_frac: float = 0.05  # pending/resident keys that triggers a shard refresh
    retune_frac: float = 0.25  # total ingested fraction that triggers a full re-tune
    kinds: tuple | None = None  # restrict the re-tune grid (None = every registered kind)
    n_queries: int = 2048  # simulation-query batch for the re-tune sweep
    backend: str = "xla"
    #: windowed mean routing imbalance (busiest / even shard load) that
    #: triggers a fence rebalance; 0.0 (the default) disables rebalancing
    rebalance_imbalance: float = 0.0
    #: windowed drop rate (capacity-factored exchange) that also triggers it
    rebalance_drop_rate: float = 0.002
    #: lookups a drift window must span before it counts as *sustained*
    rebalance_min_lookups: int = 8
    #: run shard refreshes as ONE donated device program (fit → leaf
    #: assembly → install, :func:`repro.tune.device_fit.device_refresh`)
    #: for the kinds that support it; a failed device build (verified-ε
    #: miss, capacity, fences) falls back to the classic host path and
    #: counts in the ``device_refreshes`` obs metric
    device_refresh: bool = False
    #: fit mode of the device refresh program: ``"fast"`` (O(log n)
    #: depth, verified-ε) or ``"scan"`` (exact, O(n / chunk) depth)
    device_fit: str = "fast"


#: lifecycle counter fields, in the order metrics() reports them.  Each
#: backs a ``tier_<field>`` metric in the repro.obs registry, labeled by
#: the tier's unique name; ``pending`` is a gauge (it decreases).
_COUNTER_FIELDS = (
    "lookups",
    "ingested",
    "absorbed",  # merged into gapped leaves in place (updatable kinds)
    "overflowed",  # diverted to a shard's delta buffer
    "duplicates",  # ingested keys already present
    "shard_compactions",  # delta -> leaves folds (device-side)
    "shard_refreshes",
    "retunes",
    "forced_restacks",  # refresh_shard rejected (capacity/static) -> full restack
    "pending",  # host-buffered keys (static-kind fallback arm)
)

_TIER_IDS = itertools.count()


class _Counters:
    """Attribute view over the tier's ``tier_*`` registry metrics.

    Reads and writes (``tier.counters.absorbed += n``) go straight to
    the repro.obs registry under this tier's label, so the dataclass-era
    call sites — including tests that poke ``counters.pending`` — keep
    working while ``metrics()`` renders from registry snapshots.
    """

    __slots__ = ("_tier",)

    def __init__(self, tier: str):
        object.__setattr__(self, "_tier", tier)

    def _metric(self, field: str):
        from repro import obs

        return obs.metric(f"tier_{field}")

    def __getattr__(self, field: str) -> int:
        if field not in _COUNTER_FIELDS:
            raise AttributeError(field)
        return int(self._metric(field).value(tier=self._tier))

    def __setattr__(self, field: str, value) -> None:
        if field not in _COUNTER_FIELDS:
            raise AttributeError(f"unknown tier counter {field!r}")
        self._metric(field).set_value(float(value), tier=self._tier)

    def as_dict(self) -> dict:
        return {f: getattr(self, f) for f in _COUNTER_FIELDS}


class TunedTier:
    """A served, self-re-tuning sharded index tier.

    Build with a spec to pin the architecture, or without one to let the
    bi-criteria tuner pick it for the policy's space budget.  Updatable
    specs (``GAPPED``) absorb ingest device-side; static specs buffer
    and refresh — same lifecycle, see the module docstring.
    """

    def __init__(self, table_np, n_shards: int, policy: RebuildPolicy | None = None, *,
                 spec: IndexSpec | None = None, ctx=None, name: str | None = None):
        self.policy = policy or RebuildPolicy()
        self.ctx = ctx
        table_np = np.asarray(table_np, dtype=np.uint64)
        if spec is None:
            spec = self._tune(table_np)
        self.spec = spec
        self.sidx = ShardedIndex.build(spec, table_np, n_shards=n_shards)
        self._pending: list[list] = [[] for _ in range(n_shards)]
        self._since_retune = 0  # keys ingested since the last restack
        #: registry label: unique per tier so several tiers in one
        #: process keep separate tier_*/route_* counter labelsets
        self.name = name or f"tier{next(_TIER_IDS)}"
        self.counters = _Counters(self.name)
        self._routing = _fresh_tier_metrics()  # legacy dict sink (kept in step)
        #: staleness epoch: bumped on every state change that can alter
        #: served answers (insert/compact/refresh/restack/rebalance).
        #: Derived read structures (repro.serve.hotcache.HotKeyCache)
        #: compare their build epoch against this to detect staleness.
        self.epoch = 0
        # (counters, per-shard weights) snapshot opening the current
        # drift-detection window; None until the first maybe_rebalance
        self._rb_window: tuple | None = None

    def _updatable(self) -> bool:
        return self.spec.kind in mutation.updatable_kinds()

    def _bump_epoch(self) -> None:
        """Mark every derived read structure (hot-key caches) stale."""
        self.epoch += 1

    # -- serving path ------------------------------------------------------
    def lookup(self, queries, **kw):
        """Tier lookup with telemetry on (imbalance/drop counters,
        attributed to this tier's own sink as well as the global view).
        When the policy enables rebalancing, each lookup also feeds the
        drift window (:meth:`maybe_rebalance`) — answers are computed
        against the pre-rebalance fences, so the batch that trips the
        threshold is still served exactly."""
        self.counters.lookups += 1
        kw.setdefault("telemetry", True)
        kw.setdefault("telemetry_sink", self._routing)
        kw.setdefault("telemetry_label", self.name)
        kw.setdefault("backend", self.policy.backend)
        out = sharded_lookup(self.sidx, queries, self.ctx, **kw)
        if self.policy.rebalance_imbalance > 0:
            self.maybe_rebalance()
        return out

    # -- drift: absorb -> overflow ----------------------------------------
    def insert_batch(self, new_keys) -> None:
        """Route new keys to their owner shards (fence routing) and
        absorb them: device-side through the gapped leaves + delta for
        updatable specs, host-buffered for static specs; then apply the
        compact/refresh/retune policy (:meth:`maybe_compact`)."""
        new_keys = np.unique(np.asarray(new_keys, dtype=np.uint64))
        if len(new_keys) == 0:
            return
        self.counters.ingested += len(new_keys)
        self._since_retune += len(new_keys)
        self._bump_epoch()
        if self._updatable():
            todo = new_keys
            while len(todo):
                todo = self._absorb(todo)
        else:
            owners = np.asarray(route_owners(self.sidx.fences, new_keys))
            for s in range(self.sidx.n_shards):
                mine = new_keys[owners == s]
                if len(mine):
                    self._pending[s].append(mine)
            self.counters.pending += len(new_keys)
        self.maybe_compact()

    def _absorb(self, keys: np.ndarray) -> np.ndarray:
        """One fence-routing pass of the absorb arm.  Returns the tail of
        keys that must be *re-routed* because a forced restack moved the
        fences mid-pass (empty when the pass completed)."""
        owners = np.asarray(route_owners(self.sidx.fences, keys))
        for s in range(self.sidx.n_shards):
            mine = keys[owners == s]
            if not len(mine):
                continue
            try:
                self.sidx, report = insert_into_shard(self.sidx, s, mine)
            except NeedsRebuild:
                # leaves + delta exhausted: rebuild just this shard with
                # the tier's spec (the lifecycle's escalation arm)
                self._pending[s].append(mine)
                self.counters.pending += len(mine)
                before = self.counters.forced_restacks
                self.refresh(s)
                if self.counters.forced_restacks > before:
                    # the restack consumed every buffered key but moved
                    # the fences: the unprocessed tail needs re-routing
                    return keys[owners > s]
                continue
            self.counters.absorbed += report.absorbed
            self.counters.overflowed += report.overflowed
            self.counters.duplicates += report.duplicates
            if report.compacted:
                self.counters.shard_compactions += 1
        return keys[:0]

    def _shard_keys(self, s: int) -> np.ndarray:
        if self._updatable():
            from repro.index import updatable

            # the stacked tables are a stale build-time snapshot for
            # self-contained kinds: read the live merged key set instead
            return updatable.live_keys(self.sidx.shard(s))
        cnt = int(self.sidx.counts[s])
        return np.asarray(self.sidx.tables[s][:cnt])

    def _merged_table(self) -> np.ndarray:
        parts = [self._shard_keys(s) for s in range(self.sidx.n_shards)]
        parts += [k for p in self._pending for k in p]
        return np.unique(np.concatenate(parts))

    def _pending_count(self, s: int) -> int:
        return sum(len(k) for k in self._pending[s])

    # -- compact -> retune -------------------------------------------------
    def maybe_compact(self) -> str | None:
        """Apply the policy: ``"retune"``, ``"compact"``, ``"refresh"``
        or ``None``.  Updatable specs compact any shard whose delta fill
        crossed :data:`~repro.index.mutation.COMPACT_FILL`; static specs
        refresh any shard whose host-pending fraction crossed
        :attr:`RebuildPolicy.shard_refresh_frac`."""
        total = int(self.sidx.counts.sum())
        drift = self._since_retune if self._updatable() else self.counters.pending
        if drift >= max(1, int(self.policy.retune_frac * total)):
            self.retune()
            return "retune"
        did = None
        if self._updatable():
            dc = np.asarray(self.sidx.index.arrays["delta_count"])
            dcap = int(self.sidx.index.arrays["delta"].shape[1])
            for s in range(self.sidx.n_shards):
                if int(dc[s]) / max(dcap, 1) < mutation.COMPACT_FILL:
                    continue
                try:
                    self.sidx = compact_shard(self.sidx, s)
                except NeedsRebuild:
                    self.refresh(s)
                    did = "refresh"
                    continue
                self.counters.shard_compactions += 1
                self._bump_epoch()
                did = "compact"
            return did
        for s in range(self.sidx.n_shards):
            resident = int(self.sidx.counts[s])
            if self._pending_count(s) >= max(1, int(self.policy.shard_refresh_frac * resident)):
                self.refresh(s)
                did = "refresh"
        return did

    def refresh(self, s: int) -> None:
        """Rebuild shard ``s`` with the tier's spec and hot-swap it via
        the donated ``refresh_shard`` path; fall back to a full restack
        when the rebuilt shard no longer fits the stacked structure.

        With ``policy.device_refresh`` enabled (and a supporting kind),
        the rebuild first attempts the single-program device pipeline —
        fit, leaf assembly and install compiled as one donated jit
        (:func:`repro.tune.device_fit.device_refresh`); a build the
        device program rejects (verified-ε miss, capacity, fences,
        trip-count budgets) leaves the tier untouched and falls through
        to the classic host path below."""
        merged = np.unique(np.concatenate([self._shard_keys(s)] + self._pending[s]))
        if self._try_device_refresh(s, merged):
            return
        try:
            # static kinds must be FITTED on the padded resident row
            # (shard_build_table), or the installed model mispredicts
            # against the stacked capacity-m table
            build_tab = shard_build_table(
                self.spec.kind, merged, int(self.sidx.tables.shape[1])
            )
            new_index = registry.entry(self.spec.kind).build(self.spec, build_tab)
            self.sidx = refresh_shard(self.sidx, s, new_index, merged)
        except ValueError:
            # outgrew the tier's table capacity / leaf shapes / statics
            self.counters.forced_restacks += 1
            self._restack(self._merged_table(), self.spec)
            return
        self.counters.shard_refreshes += 1
        self.counters.pending -= self._pending_count(s)
        self._pending[s] = []
        self._bump_epoch()

    def _try_device_refresh(self, s: int, merged: np.ndarray) -> bool:
        """The device-program arm of :meth:`refresh`.  Returns True when
        the donated single-program pipeline installed the shard; False
        routes the caller to the classic host path (a build the device
        program *rejected* additionally counts a ``fallback`` outcome in
        the ``device_refreshes`` obs metric — the tier content is
        untouched in that case, so the host path starts clean)."""
        p = self.policy
        if not p.device_refresh:
            return False
        from repro import obs

        from .device_fit import DEVICE_REFRESH_KINDS, device_refresh

        kind = self.spec.kind
        m = int(self.sidx.tables.shape[1])
        if kind not in DEVICE_REFRESH_KINDS or m < 2 or not 0 < len(merged) <= m:
            return False
        self.sidx, ok = device_refresh(self.sidx, s, merged, self.spec.eps, fit=p.device_fit)
        if not bool(ok):  # lazy host sync, off the serve path
            obs.metric("device_refreshes").inc(kind=kind, outcome="fallback")
            return False
        obs.metric("device_refreshes").inc(kind=kind, outcome="ok")
        self.counters.shard_refreshes += 1
        self.counters.pending -= self._pending_count(s)
        self._pending[s] = []
        self._bump_epoch()
        return True

    def retune(self) -> None:
        """Re-run the bi-criteria selection on the merged table and
        restack the tier under the winning spec."""
        merged = self._merged_table()
        self._restack(merged, self._tune(merged))
        self.counters.retunes += 1

    def _tune(self, table_np: np.ndarray) -> IndexSpec:
        p = self.policy
        return best_spec_for_budget(
            table_np, p.space_budget_pct, kinds=p.kinds, n_queries=p.n_queries, backend=p.backend
        )

    def _restack(self, table_np: np.ndarray, spec: IndexSpec, *, bounds=None) -> None:
        self.spec = spec
        self.sidx = ShardedIndex.build(
            spec, table_np, n_shards=self.sidx.n_shards, bounds=bounds
        )
        self._pending = [[] for _ in range(self.sidx.n_shards)]
        self._since_retune = 0
        self.counters.pending = 0
        self._rb_window = None  # fences moved: the drift window restarts
        self._bump_epoch()

    # -- skew-aware rebalancing (query-driven, zero retunes) ---------------
    def maybe_rebalance(self) -> str | None:
        """Rebalance the fences when routing drift is *sustained*.

        Reads the tier's ``route_*`` / ``route_shard_queries`` registry
        counters, windows them against the snapshot taken at the last
        check, and triggers :meth:`rebalance` when the window spans at
        least :attr:`RebuildPolicy.rebalance_min_lookups` lookups AND its
        mean imbalance crosses :attr:`RebuildPolicy.rebalance_imbalance`
        (or its drop rate crosses :attr:`RebuildPolicy.rebalance_drop_rate`).
        Disabled (returns ``None`` immediately) while
        ``rebalance_imbalance <= 0`` — the default, so plain tiers pay
        zero snapshot cost per lookup."""
        p = self.policy
        if p.rebalance_imbalance <= 0:
            return None
        cur = _tier_counters_from_obs(self.name)
        shw = shard_query_weights(self.name, self.sidx.n_shards)
        if self._rb_window is None:
            self._rb_window = (cur, shw)
            return None
        prev, shw0 = self._rb_window
        if cur["lookups"] - prev["lookups"] < p.rebalance_min_lookups:
            return None
        d_even = cur["routed_even"] - prev["routed_even"]
        d_q = cur["queries"] - prev["queries"]
        imb = (cur["routed_max"] - prev["routed_max"]) / d_even if d_even > 0 else 0.0
        drop = (cur["dropped"] - prev["dropped"]) / d_q if d_q > 0 else 0.0
        self._rb_window = (cur, shw)
        if imb < p.rebalance_imbalance and drop <= p.rebalance_drop_rate:
            return None
        self.rebalance(weights=np.maximum(shw - shw0, 0.0), imbalance=imb)
        return "rebalance"

    def rebalance(self, weights=None, *, imbalance: float | None = None) -> None:
        """Recompute the router fences from the observed per-shard owner
        histogram (weighted-quantile split) and re-shard through the
        donated ``refresh_shard`` path — the tier's pinned spec is reused
        as-is (zero full retunes), pending/delta keys merge into the new
        partition, and answers stay bit-exact before and after.  Falls
        back to a full restack *at the same skew-aware bounds* when a
        rebuilt shard no longer fits the stacked structure."""
        from repro import obs

        merged = self._merged_table()
        if weights is None:
            weights = shard_query_weights(self.name, self.sidx.n_shards)
        old_fences = np.asarray(self.sidx.fences)
        bounds = weighted_quantile_bounds(merged, old_fences, weights)
        S = self.sidx.n_shards
        old_own = np.clip(np.searchsorted(old_fences, merged, side="right") - 1, 0, S - 1)
        new_own = np.repeat(np.arange(S), np.diff(bounds))
        moved = int((old_own != new_own).sum())
        build = registry.entry(self.spec.kind).build
        try:
            self.sidx = rebalance_shards(
                self.sidx, merged, bounds, lambda part: build(self.spec, part)
            )
        except ValueError:
            self.counters.forced_restacks += 1
            self._restack(merged, self.spec, bounds=bounds)
        else:
            self._pending = [[] for _ in range(S)]
            self._since_retune = 0
            self.counters.pending = 0
            self._rb_window = None
            self._bump_epoch()
        obs.metric("rebalance_total").inc(tier=self.name)
        obs.metric("rebalance_moved_keys").inc(moved, tier=self.name)
        if imbalance is not None:
            obs.metric("rebalance_last_imbalance").set(imbalance, tier=self.name)

    # -- deprecated aliases (one release) ----------------------------------
    def ingest(self, new_keys) -> None:
        """Deprecated alias for :meth:`insert_batch`."""
        warnings.warn(
            "TunedTier.ingest() is deprecated; use insert_batch()",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.insert_batch(new_keys)

    def maybe_rebuild(self) -> str | None:
        """Deprecated alias for :meth:`maybe_compact`."""
        warnings.warn(
            "TunedTier.maybe_rebuild() is deprecated; use maybe_compact()",
            DeprecationWarning,
            stacklevel=2,
        )
        return self.maybe_compact()

    # -- telemetry ---------------------------------------------------------
    def metrics(self) -> dict:
        """Rebuild counters + this tier's own routing/drop counters,
        rendered from a ``repro.obs`` registry snapshot (the ``tier_*``
        and ``route_*`` metrics under this tier's label)."""
        from repro import obs

        snap = obs.snapshot(prefix="tier_")
        counters = {
            f: int(obs.sample_value(snap, f"tier_{f}", tier=self.name))
            for f in _COUNTER_FIELDS
        }
        rb = obs.snapshot(prefix="rebalance_")
        return {
            "spec": self.spec.display_name(),
            "n_shards": self.sidx.n_shards,
            "n_keys": int(self.sidx.counts.sum()),
            "space_bytes": int(self.sidx.space_bytes()),
            **counters,
            "rebalances": int(obs.sample_value(rb, "rebalance_total", tier=self.name)),
            "rebalance_moved_keys": int(
                obs.sample_value(rb, "rebalance_moved_keys", tier=self.name)
            ),
            "routing": derived_tier_metrics(_tier_counters_from_obs(self.name)),
        }
