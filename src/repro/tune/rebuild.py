"""Serving-side re-tuning: drift detection + donated hot swaps.

A production tier is not static: keys are ingested, distributions
drift, and the spec that won the time-space trade-off at build time
stops being the winner.  :class:`TunedTier` closes the loop between the
Pareto tuner and the serving path:

* **steady state** — lookups run through the shard_map'd
  :func:`repro.dist.sharded_lookup` with telemetry on (routing
  imbalance + drop-rate counters feed ``DecodeEngine.metrics()``);
* **shard drift** — ingested keys are routed to their owner shard by
  the tier's own fences and buffered; once a shard's pending fraction
  crosses :attr:`RebuildPolicy.shard_refresh_frac`, the shard is
  rebuilt *with the tier's current spec* and hot-swapped through the
  donated ``refresh_shard`` path (``donate_argnums=0`` — the old
  stacked buffers are reused, no host round-trip);
* **tier drift** — when total ingest crosses
  :attr:`RebuildPolicy.retune_frac` (or a shard outgrows the stacked
  leaf/table capacity, or its trip-count statics), the whole tier is
  re-*tuned*: :func:`repro.tune.pareto.best_spec_for_budget` re-runs
  the bi-criteria selection on the merged table at the policy's space
  budget and the tier is restacked under the (possibly different)
  winning spec.

Every decision is a counter in :meth:`TunedTier.metrics`, surfaced by
the serving engine next to the lookup trace counts.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.dist.sharded_index import (
    ShardedIndex,
    _fresh_tier_metrics,
    derived_tier_metrics,
    refresh_shard,
    route_owners,
    sharded_lookup,
)
from repro.index import registry
from repro.index.specs import IndexSpec

from .pareto import best_spec_for_budget


@dataclass(frozen=True)
class RebuildPolicy:
    """When to refresh a shard, when to re-tune the whole tier."""

    space_budget_pct: float = 2.0  # bi-criteria budget for re-tuning
    shard_refresh_frac: float = 0.05  # pending/resident keys that triggers a shard refresh
    retune_frac: float = 0.25  # total ingested fraction that triggers a full re-tune
    kinds: tuple | None = None  # restrict the re-tune grid (None = every registered kind)
    n_queries: int = 2048  # simulation-query batch for the re-tune sweep
    backend: str = "xla"


@dataclass
class _Counters:
    lookups: int = 0
    ingested: int = 0
    shard_refreshes: int = 0
    retunes: int = 0
    forced_restacks: int = 0  # refresh_shard rejected (capacity/static) -> full restack
    pending: int = 0


class TunedTier:
    """A served, self-re-tuning sharded index tier.

    Build with a spec to pin the architecture, or without one to let the
    bi-criteria tuner pick it for the policy's space budget.
    """

    def __init__(self, table_np, n_shards: int, policy: RebuildPolicy | None = None, *,
                 spec: IndexSpec | None = None, ctx=None):
        self.policy = policy or RebuildPolicy()
        self.ctx = ctx
        table_np = np.asarray(table_np, dtype=np.uint64)
        if spec is None:
            spec = self._tune(table_np)
        self.spec = spec
        self.sidx = ShardedIndex.build(spec, table_np, n_shards=n_shards)
        self._pending: list[list] = [[] for _ in range(n_shards)]
        self.counters = _Counters()
        self._routing = _fresh_tier_metrics()  # this tier's own sink

    # -- serving path ------------------------------------------------------
    def lookup(self, queries, **kw):
        """Tier lookup with telemetry on (imbalance/drop counters,
        attributed to this tier's own sink as well as the global view)."""
        self.counters.lookups += 1
        kw.setdefault("telemetry", True)
        kw.setdefault("telemetry_sink", self._routing)
        kw.setdefault("backend", self.policy.backend)
        return sharded_lookup(self.sidx, queries, self.ctx, **kw)

    # -- drift -------------------------------------------------------------
    def ingest(self, new_keys) -> None:
        """Buffer new keys with their owner shards (fence routing), then
        refresh / re-tune if the policy's thresholds are crossed."""
        new_keys = np.unique(np.asarray(new_keys, dtype=np.uint64))
        if len(new_keys) == 0:
            return
        owners = np.asarray(route_owners(self.sidx.fences, new_keys))
        for s in range(self.sidx.n_shards):
            mine = new_keys[owners == s]
            if len(mine):
                self._pending[s].append(mine)
        self.counters.ingested += len(new_keys)
        self.counters.pending += len(new_keys)
        self.maybe_rebuild()

    def _shard_keys(self, s: int) -> np.ndarray:
        cnt = int(self.sidx.counts[s])
        return np.asarray(self.sidx.tables[s][:cnt])

    def _merged_table(self) -> np.ndarray:
        parts = [self._shard_keys(s) for s in range(self.sidx.n_shards)]
        parts += [k for p in self._pending for k in p]
        return np.unique(np.concatenate(parts))

    def _pending_count(self, s: int) -> int:
        return sum(len(k) for k in self._pending[s])

    # -- rebuild machinery -------------------------------------------------
    def maybe_rebuild(self) -> str | None:
        """Apply the policy: ``"retune"``, ``"refresh"`` or ``None``."""
        total = int(self.sidx.counts.sum())
        if self.counters.pending >= max(1, int(self.policy.retune_frac * total)):
            self.retune()
            return "retune"
        did = None
        for s in range(self.sidx.n_shards):
            resident = int(self.sidx.counts[s])
            if self._pending_count(s) >= max(1, int(self.policy.shard_refresh_frac * resident)):
                self.refresh(s)
                did = "refresh"
        return did

    def refresh(self, s: int) -> None:
        """Rebuild shard ``s`` with the tier's spec and hot-swap it via
        the donated ``refresh_shard`` path; fall back to a full restack
        when the rebuilt shard no longer fits the stacked structure."""
        merged = np.unique(np.concatenate([self._shard_keys(s)] + self._pending[s]))
        new_index = registry.entry(self.spec.kind).build(self.spec, merged)
        try:
            self.sidx = refresh_shard(self.sidx, s, new_index, merged)
        except ValueError:
            # outgrew the tier's table capacity / leaf shapes / statics
            self.counters.forced_restacks += 1
            self._restack(self._merged_table(), self.spec)
            return
        self.counters.shard_refreshes += 1
        self.counters.pending -= self._pending_count(s)
        self._pending[s] = []

    def retune(self) -> None:
        """Re-run the bi-criteria selection on the merged table and
        restack the tier under the winning spec."""
        merged = self._merged_table()
        self._restack(merged, self._tune(merged))
        self.counters.retunes += 1

    def _tune(self, table_np: np.ndarray) -> IndexSpec:
        p = self.policy
        return best_spec_for_budget(
            table_np, p.space_budget_pct, kinds=p.kinds, n_queries=p.n_queries, backend=p.backend
        )

    def _restack(self, table_np: np.ndarray, spec: IndexSpec) -> None:
        self.spec = spec
        self.sidx = ShardedIndex.build(spec, table_np, n_shards=self.sidx.n_shards)
        self._pending = [[] for _ in range(self.sidx.n_shards)]
        self.counters.pending = 0

    # -- telemetry ---------------------------------------------------------
    def metrics(self) -> dict:
        """Rebuild counters + this tier's own routing/drop counters."""
        return {
            "spec": self.spec.display_name(),
            "n_shards": self.sidx.n_shards,
            "n_keys": int(self.sidx.counts.sum()),
            "space_bytes": int(self.sidx.space_bytes()),
            **self.counters.__dict__,
            "routing": derived_tier_metrics(self._routing),
        }
