"""Batched index construction: many tables or many specs, one engine.

The unified :class:`~repro.index.Index` is a pytree of flat arrays
precisely so that *construction*, not just lookup, can be batched:

* :func:`build_many` — ONE spec over MANY tables (periodic rebuild under
  ingest, per-shard tier builds, multi-tenant serving).  Default path
  loops the registered host builder and stacks the results leaf-wise
  (bit-exact with per-table ``build`` by construction); ``fit="vmap"``
  batches the kind's array-native fit stage in ONE jitted ``vmap``
  trace: the RMI family's leaf stage
  (:func:`repro.core.rmi.rmi_leaf_fit` — segment-sum least squares +
  extended error bounds) and the PGM/RS families' corridor scans
  (:func:`repro.core.pgm.pgm_segments_scan` /
  :func:`repro.core.radix_spline.rs_knots_scan` — the greedy cone
  update as a chunked ``lax.scan``, ε traced so one trace covers every
  ε-config of a batch shape).
* :func:`build_grid` — MANY specs over ONE table (the CDFShop sweep and
  the Pareto tuner's candidate grid).  RMI-family grid entries that
  resolve to the same branching factor share one vmapped leaf-fit
  trace; PGM / PGM_M / RS entries share one vmapped scan-fit trace per
  kind.

The vmapped RMI fit is numerically equivalent to the host fit — its
error bounds are measured against its *own* predictions with the same
arithmetic the query path uses, so predicted windows remain guarantees
and predecessor ranks are bit-identical — but leaf floats may differ by
a few ulp (XLA scatter-add reduction order vs ``np.bincount``).  The
PGM/RS scan fits are **bit-exact** with the host builds: the device
scan walks the same f64 corridor (min/max are exact, so accumulation
order cannot diverge) and emits boundary masks identical to the numpy
greedy, from which the host assembles the same model arrays.  Code that
needs leaf-level bit-exactness with ``build`` for *every* kind uses the
default ``fit="host"``; ``fit="auto"`` is the recommended batch-build
mode now that every learned family has an array-native fit.

Stacking reuses the sharded tier's padding idiom
(:func:`repro.dist.sharded_index.stack_indexes`: per-leaf max shapes,
max-key / edge-replication sentinels, PGM level-lifting), so a
:class:`BatchedIndexes` round-trips: :meth:`BatchedIndexes.unstack`
recovers every per-table index bit-exactly, inverting the PGM lift.

:meth:`BatchedIndexes.lookup` answers a query batch against every table
through one jitted vmapped body over the shared per-kind query path
(:func:`repro.index.lookup_impl`) — at most one trace per (kind,
backend) no matter how many tables the batch holds.
"""

from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs import metric
from repro.obs.timing import stopwatch
from repro.core.cdf import POS_DTYPE
from repro.core.pgm import (
    BICRITERIA_MAX_ITERS,
    bicriteria_eps_bounds,
    build_pgm,
    pgm_fit_fast,
    pgm_segments_scan,
    segment_slopes,
)
from repro.core.radix_spline import build_rs, rs_knots_fast, rs_knots_scan
from repro.core.rmi import assemble_rmi, fit_root, rmi_leaf_fit
from repro.dist.sharded_index import (
    _harmonize,
    _pad_sorted_table,
    _pow2ceil,
    stack_indexes,
)
from repro.index import Index, batched_pallas_impl, count_trace, lookup_impl, registry
from repro.index.specs import IndexSpec

_MAXKEY = np.uint64(np.iinfo(np.uint64).max)

#: Fit strategies: ``host`` loops the registered builder (bit-exact with
#: per-table ``build``); ``vmap`` batches the kind's array-native fit
#: stage (every learned family: RMI leaf fits, PGM/RS corridor scans —
#: bit-exact with the host greedy); ``fast`` uses the O(log n)-depth
#: blocked/associative corridor fits (:func:`repro.core.pgm.pgm_fit_fast`
#: / :func:`repro.core.radix_spline.rs_knots_fast`) — valid ε-models,
#: boundaries explicitly NOT bit-identical, device verified-ε re-measure
#: with lazy host fallback to the exact scan fit; ``auto`` — the
#: recommended batch-build mode — picks ``vmap`` where it applies and
#: falls back to the host builder otherwise.
FITS = ("host", "vmap", "fast", "auto")

#: Kinds with an array-native vmappable fit stage: the two-level RMI
#: family (leaf least-squares) and the scan-formulated corridor fits
#: (PGM greedy ε-PLA, bi-criteria PGM, RadixSpline).
VMAP_KINDS = ("RMI", "SY-RMI", "PGM", "PGM_M", "RS")

#: Kinds with an O(log n)-depth ``fit="fast"`` corridor fit (the
#: ε-corridor families).  Always a subset of :data:`VMAP_KINDS` — the
#: exact scan fit doubles as the fast fit's fallback.  The analyzer's R4
#: registry probe asserts every kind claimed here passes the verified-ε
#: check (or demonstrably falls back) on live probe tables.
FAST_KINDS = ("PGM", "PGM_M", "RS")

#: Backends the batched lookup supports — the full ``Index.lookup``
#: set.  ``pallas`` dispatches the batched ``(table, q_tile)``-grid
#: kernels via :func:`repro.index.batched_pallas_impl` (fused RMI for
#: the RMI family, lane-wide k-ary otherwise) instead of vmapping the
#: single-table path, mirroring the sharded tier's ``TIER_BACKENDS``.
BATCH_BACKENDS = ("xla", "bbs", "pallas", "ref")


def _resolve_spec(kind_or_spec, **params) -> IndexSpec:
    if isinstance(kind_or_spec, IndexSpec):
        return kind_or_spec
    return registry.spec_for(str(kind_or_spec), **params)


def _rmi_plan(spec: IndexSpec, n: int) -> tuple:
    """Resolve an RMI-family spec to its (b, root_type) for a table of
    ``n`` keys — mirrors ``build_rmi`` / ``build_sy_rmi`` exactly."""
    if spec.kind == "RMI":
        return max(2, min(spec.b, n)), spec.root_type
    if spec.kind == "SY-RMI":
        budget = spec.space_pct / 100.0 * n * 8
        return max(2, min(int(budget * spec.ub), n)), spec.winner_root
    raise ValueError(f"kind {spec.kind!r} is not RMI-family (no leaf-stage plan)")


# ---------------------------------------------------------------------------
# The one-trace batched leaf fit
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("b",))
def _leaf_fit_many(u, root_coefs, b: int):
    """vmap of the array-native leaf stage: one trace per (n, b) shape."""
    count_trace("fit:RMI", "vmap")  # python side effect: runs once per trace
    return jax.vmap(rmi_leaf_fit, in_axes=(0, 0, None))(u, root_coefs, b)


@jax.jit
def _normalize_many(tables, kmin, inv_span):
    count_trace("fit:RMI-normalize", "vmap")  # python side effect: per trace
    # identical expression to build_rmi/query: subtract then multiply by
    # the reciprocal — a divide here could flip a boundary key's leaf
    u = (tables.astype(jnp.float64) - kmin[:, None]) * inv_span[:, None]
    return jnp.clip(u, 0.0, 1.0)


def _vmap_fit_rmi(specs: list, tables: list) -> list:
    """Batched RMI-family build: host root fits (tiny), ONE vmapped
    device trace for the whole batch's leaf stage, host assembly of the
    per-table models (f32 kernel re-encoding included).

    ``specs`` and ``tables`` are zipped per slot; every slot must resolve
    to the same branching factor and table length (one trace).
    """
    from repro.index import impls

    sw = stopwatch()
    n = len(tables[0])
    if any(len(t) != n for t in tables):
        raise ValueError("fit='vmap' needs same-length tables (pad first — see build_many)")
    plans = [_rmi_plan(spec, len(t)) for spec, t in zip(specs, tables)]
    bs = {b for b, _ in plans}
    if len(bs) != 1:
        raise ValueError(f"vmapped fit needs one branching factor, got {sorted(bs)}")
    b = bs.pop()
    roots = [fit_root(t, root_type) for t, (_, root_type) in zip(tables, plans)]
    root_coefs = np.stack([rc for rc, _, _ in roots])
    kmin = np.asarray([km for _, km, _ in roots])
    inv_span = np.asarray([iv for _, _, iv in roots])
    u = _normalize_many(jnp.asarray(np.stack(tables)), jnp.asarray(kmin), jnp.asarray(inv_span))
    slopes, icepts, eps, r = _leaf_fit_many(u, jnp.asarray(root_coefs), b)
    slopes, icepts = np.asarray(slopes), np.asarray(icepts)
    eps, r = np.asarray(eps), np.asarray(r)
    per_model_s = (sw.elapsed) / len(tables)  # batch wall time, shared evenly
    out = []
    for i, (spec, t, (_, root_type)) in enumerate(zip(specs, tables, plans)):
        m = assemble_rmi(
            t,
            root_type,
            root_coefs[i],
            kmin[i],
            inv_span[i],
            slopes[i],
            icepts[i],
            eps[i],
            r[i],
            build_time=per_model_s,
        )
        extra = None
        if spec.kind == "SY-RMI":
            m.name = f"SY-RMI[{spec.space_pct}%]"
            extra = {"space_pct": spec.space_pct}
        out.append(impls.rmi_model_to_index(spec.kind, m, t, extra))
    return out


# ---------------------------------------------------------------------------
# The scan-formulated PGM / RS fits: whole-batch corridor scans
# ---------------------------------------------------------------------------


@jax.jit
def _pgm_boundaries_many(tables_f64, eps_f64):
    """vmap of the PGM corridor scan: one trace per (N, n) batch shape —
    ε is traced, so every ε-config of that shape shares the trace."""
    count_trace("fit:PGM", "vmap")  # python side effect: runs once per trace
    return jax.vmap(pgm_segments_scan, in_axes=(0, 0))(tables_f64, eps_f64)


@jax.jit
def _rs_boundaries_many(tables_f64, eps_f64):
    """vmap of the RS corridor scan: one trace per (N, n) batch shape."""
    count_trace("fit:RS", "vmap")  # python side effect: runs once per trace
    return jax.vmap(rs_knots_scan, in_axes=(0, 0))(tables_f64, eps_f64)


@jax.jit
def _pgm_boundaries_fast_many(tables_f64, eps_f64):
    """vmap of the O(log n) blocked PGM fit: returns (masks, oks)."""
    count_trace("fit:PGM", "fast")  # python side effect: runs once per trace
    return jax.vmap(pgm_fit_fast, in_axes=(0, 0))(tables_f64, eps_f64)


@jax.jit
def _rs_boundaries_fast_many(tables_f64, eps_f64):
    """vmap of the O(log n) blocked RS fit: returns (masks, oks)."""
    count_trace("fit:RS", "fast")  # python side effect: runs once per trace
    return jax.vmap(rs_knots_fast, in_axes=(0, 0))(tables_f64, eps_f64)


def _masks_pgm_scan(keys, eps_np):
    return np.asarray(_pgm_boundaries_many(keys, jnp.asarray(eps_np)))


def _masks_rs_scan(keys, eps_np):
    return np.asarray(_rs_boundaries_many(keys, jnp.asarray(eps_np)))


def _fast_masks(keys, eps_np, fast_many, scan_masks, kind: str):
    """Fast boundary masks with the lazy verified-ε fallback: members
    whose device re-measure failed (``ok == False``) are re-fit with the
    exact scan — decided on host AFTER the fast program ran, so the fast
    program never compiles the O(n)-depth exact path into itself."""
    masks, oks = fast_many(keys, jnp.asarray(eps_np))
    # np.array (copy): asarray of a device array is a read-only view,
    # and the fallback arm writes the re-fit rows in place
    masks, oks = np.array(masks), np.asarray(oks)
    if not oks.all():
        bad = np.flatnonzero(~oks)
        metric("fit_fast_fallbacks").inc(len(bad), kind=kind)
        exact = scan_masks(keys[bad], eps_np[bad])
        masks[bad] = exact
    return masks


def _masks_pgm_fast(keys, eps_np):
    return _fast_masks(keys, eps_np, _pgm_boundaries_fast_many, _masks_pgm_scan, "PGM")


def _masks_rs_fast(keys, eps_np):
    return _fast_masks(keys, eps_np, _rs_boundaries_fast_many, _masks_rs_scan, "RS")


def _check_same_length(tables):
    n = len(tables[0])
    if any(len(t) != n for t in tables):
        raise ValueError("fit='vmap' needs same-length tables (pad first — see build_many)")
    return n


def _stacked_f64(tables):
    return jnp.asarray(np.stack([t.astype(np.float64) for t in tables]))


def _pgm_model_from_mask(table, eps: int, mask):
    """Host assembly of one PGMModel from the device boundary mask:
    level-0 slopes from the mask (bit-identical, see
    :func:`repro.core.pgm.segment_slopes`), upper levels recursed
    host-side (tiny: ~n/2ε segment keys)."""
    starts = np.flatnonzero(mask)
    slopes = segment_slopes(table.astype(np.float64), starts, eps)
    return build_pgm(table, eps=eps, l0=(starts, slopes))


def _vmap_fit_pgm(specs: list, tables: list, *, masks_fn=_masks_pgm_scan) -> list:
    """Batched PGM build: ONE vmapped corridor-scan trace for the whole
    batch's leaf segmentation (per-member ε traced), host assembly —
    bit-exact with the registered per-table builder.  ``masks_fn`` swaps
    in the O(log n) fast boundaries for ``fit="fast"``."""
    from repro.index import impls

    _check_same_length(tables)
    eps = np.asarray([max(int(s.eps), 1) for s in specs], dtype=np.float64)
    masks = masks_fn(_stacked_f64(tables), eps)
    return [
        impls.pgm_model_to_index(spec.kind, _pgm_model_from_mask(t, int(e), mask), t)
        for spec, t, e, mask in zip(specs, tables, eps, masks)
    ]


def _vmap_fit_pgm_bicriteria(specs: list, tables: list, *, masks_fn=_masks_pgm_scan) -> list:
    """Batched bi-criteria PGM: the per-member ε bisection of
    :func:`repro.core.pgm.build_pgm_bicriteria` run in lockstep, every
    step's segmentations answered by the shared vmapped scan trace
    (ε is traced, so all bisection steps and members share ONE trace).
    Per-member decisions use the same ``PGMModel.space_bytes()``
    accounting over bit-identical models, so the chosen ε — and the
    final arrays — match the host builder exactly."""
    from repro.index import impls

    _check_same_length(tables)
    keys = _stacked_f64(tables)
    n_members = len(specs)
    lo, hi, best = [], [], [None] * n_members
    for spec, t in zip(specs, tables):
        eps_m, eps_M = bicriteria_eps_bounds(len(t), spec.a)
        lo.append(eps_m)
        hi.append(eps_M)

    def batch_models(eps_by_member: dict) -> dict:
        """One shared-trace scan call for this step's ε choices."""
        eps_all = np.asarray(
            [float(eps_by_member.get(i, 1)) for i in range(n_members)], dtype=np.float64
        )
        masks = masks_fn(keys, eps_all)
        return {
            i: _pgm_model_from_mask(tables[i], e, masks[i]) for i, e in eps_by_member.items()
        }

    for _ in range(BICRITERIA_MAX_ITERS):
        mids = {i: (lo[i] + hi[i]) // 2 for i in range(n_members) if lo[i] <= hi[i]}
        if not mids:
            break
        for i, m in batch_models(mids).items():
            if m.space_bytes() <= specs[i].budget_for(len(tables[i])):
                if best[i] is None or m.eps < best[i].eps:
                    best[i] = m
                hi[i] = mids[i] - 1  # try smaller eps (bigger model)
            else:
                lo[i] = mids[i] + 1
    missing = {
        i: bicriteria_eps_bounds(len(tables[i]), specs[i].a)[1]
        for i in range(n_members)
        if best[i] is None
    }
    for i, m in (batch_models(missing) if missing else {}).items():
        best[i] = m
    out = []
    for i, spec in enumerate(specs):
        best[i].name = f"PGM_M_{spec.a}[eps={best[i].eps}]"
        out.append(impls.pgm_model_to_index(spec.kind, best[i], tables[i], {"a": spec.a}))
    return out


def _vmap_fit_rs(specs: list, tables: list, *, masks_fn=_masks_rs_scan) -> list:
    """Batched RadixSpline build: ONE vmapped corridor-scan trace for
    the whole batch's knot selection (per-member ε traced), host
    assembly (radix table + verified ε re-measure) — bit-exact with the
    registered per-table builder.  ``masks_fn`` swaps in the O(log n)
    fast knots for ``fit="fast"`` (``eps_eff`` is always re-measured
    from the actual knots, so correctness is fit-mode independent)."""
    from repro.index import impls

    _check_same_length(tables)
    eps = np.asarray([int(s.eps) for s in specs], dtype=np.float64)
    masks = masks_fn(_stacked_f64(tables), eps)
    out = []
    for spec, t, mask in zip(specs, tables, masks):
        knots = np.flatnonzero(mask).astype(np.int64)
        m = build_rs(t, eps=spec.eps, r_bits=spec.r_bits, knots=knots)
        out.append(impls.rs_model_to_index(spec.kind, m, t))
    return out


#: kind -> batched array-native fit (all members must share the kind).
_VMAP_FITS = {
    "RMI": _vmap_fit_rmi,
    "SY-RMI": _vmap_fit_rmi,
    "PGM": _vmap_fit_pgm,
    "PGM_M": _vmap_fit_pgm_bicriteria,
    "RS": _vmap_fit_rs,
}

#: kind -> batched O(log n) fast fit (``fit="fast"``): the corridor fits
#: with the fast boundary stage swapped in; assembly is shared with the
#: exact path.
_FAST_FITS = {
    "PGM": partial(_vmap_fit_pgm, masks_fn=_masks_pgm_fast),
    "PGM_M": partial(_vmap_fit_pgm_bicriteria, masks_fn=_masks_pgm_fast),
    "RS": partial(_vmap_fit_rs, masks_fn=_masks_rs_fast),
}


def _vmap_fit(specs: list, tables: list) -> list:
    kind = specs[0].kind
    fit_fn = _VMAP_FITS.get(kind)
    if fit_fn is None:
        raise ValueError(
            f"fit='vmap' is not supported for kind {kind!r}: it has no array-native "
            f"fit stage (vmappable kinds: {VMAP_KINDS}); use fit='auto' to vmap where "
            "supported and fall back to the host builder otherwise"
        )
    return fit_fn(specs, tables)


def _fast_fit(specs: list, tables: list) -> list:
    kind = specs[0].kind
    fit_fn = _FAST_FITS.get(kind)
    if fit_fn is None:
        raise ValueError(
            f"fit='fast' is not supported for kind {kind!r}: it has no O(log n) "
            f"corridor fit (fast kinds: {FAST_KINDS}); use fit='vmap' or 'auto'"
        )
    return fit_fn(specs, tables)


# ---------------------------------------------------------------------------
# BatchedIndexes: the stacked many-table artifact
# ---------------------------------------------------------------------------


class BatchedIndexes:
    """N same-spec indexes over N tables, stacked leaf-wise.

    Attributes
    ----------
    index:   stacked :class:`Index` — every leaf has leading table axis.
    tables:  ``(N, m)`` uint64 — per-table keys, padded to a common
             power-of-two ``m`` (strictly increasing continuation).
    counts:  ``(N,)`` int64 — valid (unpadded) keys per table.
    meta:    per-table host metadata (original static aux, harmonized
             leaf shapes, build info) backing bit-exact :meth:`unstack`.
    """

    __slots__ = ("index", "tables", "counts", "meta", "info")

    def __init__(self, index: Index, tables, counts, meta, info=None):
        object.__setattr__(self, "index", index)
        object.__setattr__(self, "tables", tables)
        object.__setattr__(self, "counts", counts)
        object.__setattr__(self, "meta", list(meta))
        object.__setattr__(self, "info", dict(info or {}))

    # -- pytree protocol --------------------------------------------------
    def tree_flatten(self):
        return (self.index, self.tables, self.counts), tuple(
            (m["static"], tuple(sorted(m["shapes"].items()))) for m in self.meta
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        index, tables, counts = children
        meta = [{"static": s, "shapes": dict(sh), "info": {}} for s, sh in aux]
        return cls(index, tables, counts, meta)

    # -- metadata ---------------------------------------------------------
    @property
    def n_tables(self) -> int:
        return len(self.meta)

    @property
    def kind(self) -> str:
        return self.index.kind

    def __repr__(self):
        return (
            f"BatchedIndexes(kind={self.kind!r}, n_tables={self.n_tables}, "
            f"m={int(self.tables.shape[1])})"
        )

    # -- unstack: recover the per-table indexes bit-exactly ---------------
    def unstack(self) -> list:
        lifted = self.index.s("levels") if _is_pgm(self.kind) else 0
        out = []
        for i, m in enumerate(self.meta):
            arrays = {
                k: v[i][tuple(slice(0, int(s)) for s in m["shapes"][k])]
                for k, v in self.index.arrays.items()
            }
            if lifted:
                orig_levels = dict(m["static"])["levels"]
                arrays = _lower_pgm_arrays(arrays, lifted, orig_levels)
            out.append(Index(self.kind, m["static"], arrays, info=m.get("info")))
        return out

    # -- batched lookup: one trace per (kind, backend) ---------------------
    def lookup(self, queries, *, backend: str = "xla"):
        """Predecessor ranks per table: ``(N, B)`` for ``(N, B)`` queries
        (a ``(B,)`` batch is broadcast to every table)."""
        if backend not in BATCH_BACKENDS:
            raise ValueError(
                f"unknown batched backend {backend!r}; choose from {BATCH_BACKENDS}"
            )
        from repro.index.impls import query_impl

        kind_backends = query_impl(self.kind).backends
        if backend not in kind_backends:
            raise ValueError(
                f"kind {self.kind!r} supports backends {kind_backends}, not {backend!r}"
            )
        queries = jnp.asarray(queries)
        if queries.ndim == 1:
            queries = jnp.broadcast_to(queries[None, :], (self.n_tables, queries.shape[0]))
        if queries.ndim != 2 or queries.shape[0] != self.n_tables:
            raise ValueError(
                f"expected (B,) or ({self.n_tables}, B) queries, got {tuple(queries.shape)}"
            )
        return _lookup_many_jit(self.index, self.tables, self.counts, queries, backend)

    def space_bytes(self) -> int:
        """Summed per-table model bytes."""
        return sum(i.space_bytes() for i in self.unstack())


jax.tree_util.register_pytree_node_class(BatchedIndexes)


def _is_pgm(kind: str) -> bool:
    return registry.entry(kind).query_key == "pgm"


@partial(jax.jit, static_argnames=("backend",))
def _lookup_many_jit(index: Index, tables, counts, queries, backend: str):
    count_trace(f"batched:{index.kind}", backend)  # python side effect: per trace

    if backend == "pallas":
        # one batched (table, q_tile)-grid kernel call for the whole
        # batch instead of a vmap of the single-table kernel
        r = batched_pallas_impl(index, tables, queries)
        return jnp.minimum(r.astype(POS_DTYPE), counts[:, None] - 1)

    def one(idx, tab, cnt, q):
        r = lookup_impl(idx, tab, q, backend)
        # clamp hits in the padded tail back to the last real key
        r = jnp.minimum(r.astype(POS_DTYPE), cnt - 1)
        return r

    return jax.vmap(one)(index, tables, counts, queries)


def _lower_pgm_arrays(arrays: dict, lifted: int, target: int) -> dict:
    """Invert :func:`repro.dist.sharded_index._lift_pgm_levels`: strip the
    ``lifted - target`` synthetic one-segment root levels and re-pad.

    The lift prepends trivial levels (key ``keys[0]``, slope 0, rank0
    ``[0, 1]``, size 1) and the power-of-two sentinel pad is
    deterministic, so stripping + re-padding reproduces the original
    build's arrays bit-exactly.
    """
    from repro.index.impls import _pad_pow2

    extra = lifted - target
    if extra == 0:
        return arrays
    if extra < 0:
        raise ValueError(f"cannot lower {lifted} levels to {target}: not lifted")
    sizes = np.asarray(arrays["sizes"])
    if not (sizes[:extra] == 1).all():
        raise ValueError("leading levels are not synthetic one-segment roots")
    kv = int(sizes.sum())
    rv = int((sizes + 1).sum())
    keys = np.asarray(arrays["keys"])[:kv][extra:]
    slope = np.asarray(arrays["slope"])[:kv][extra:]
    rank0 = np.asarray(arrays["rank0"])[:rv][2 * extra :]
    pk_u0 = np.asarray(arrays["pk_u0"])[:kv][extra:]
    pk_slope = np.asarray(arrays["pk_slope"])[:kv][extra:]
    new_sizes = sizes[extra:].astype(np.int64)
    out = dict(arrays)
    out["keys"] = jnp.asarray(_pad_pow2(keys, _MAXKEY))
    out["slope"] = jnp.asarray(_pad_pow2(slope, 0.0))
    out["rank0"] = jnp.asarray(_pad_pow2(rank0, rank0[-1]))
    out["pk_u0"] = jnp.asarray(_pad_pow2(pk_u0, np.float32(1.0)))
    out["pk_slope"] = jnp.asarray(_pad_pow2(pk_slope, np.float32(0.0)))
    out["sizes"] = jnp.asarray(new_sizes)
    out["off"] = jnp.asarray(np.concatenate([[0], np.cumsum(new_sizes)]).astype(np.int64))
    out["off_r"] = jnp.asarray(np.concatenate([[0], np.cumsum(new_sizes + 1)]).astype(np.int64))
    return out


# ---------------------------------------------------------------------------
# build_many: one spec, many tables
# ---------------------------------------------------------------------------


def build_many(kind_or_spec, tables, *, fit: str = "host", **params) -> BatchedIndexes:
    """Build one index per table, stacked into a :class:`BatchedIndexes`.

    ``fit="host"`` (default) loops the registered builder — over
    same-length tables the result :meth:`~BatchedIndexes.unstack`\\ s
    bit-exactly to per-table ``build(spec, t)``.  Ragged batches first
    pad every table to a common power-of-two length with the sharded
    tier's strictly increasing continuation (ranks clamp back to the
    last real key at lookup), and the per-table indexes are built over
    those padded tables — the tier idiom of
    :meth:`repro.dist.ShardedIndex.build`.

    ``fit="vmap"`` batches the kind's array-native fit stage in one
    jitted trace — RMI-family leaf fits, and the PGM / PGM_M / RS
    corridor scans (bit-exact with the host builders; see the module
    docstring).  ``fit="auto"`` — the recommended batch-build mode —
    picks ``vmap`` for every learned family and the host builder for
    the rest; explicit ``fit="vmap"`` on a kind without an array-native
    fit raises.

    ``fit="fast"`` (corridor kinds only, :data:`FAST_KINDS`) uses the
    O(log n)-depth blocked/associative fits: valid ε-models whose
    boundaries are explicitly NOT bit-identical to the greedy's; a
    device verified-ε re-measure falls back to the exact scan fit per
    member when it fails (counted in the ``fit_fast_fallbacks``
    metric).  Example::

        bm = build_many(PGMSpec(eps=32), [t0, t1], fit="fast")
        assert np.array_equal(bm.lookup(q), build_many(
            PGMSpec(eps=32), [t0, t1]).lookup(q))  # ranks always exact

    Example — one spec, a tier of tables, every backend incl. the
    batched Pallas kernels::

        bm = build_many(RMISpec(b=1024), [t0, t1, t2], fit="auto")
        ranks = bm.lookup(queries)                    # (3, B), one trace
        ranks = bm.lookup(queries, backend="pallas")  # one pallas_call
        per_table = bm.unstack()                      # bit-exact Indexes
    """
    if fit not in FITS:
        raise ValueError(f"unknown fit {fit!r}; choose from {FITS}")
    spec = _resolve_spec(kind_or_spec, **params)
    tables = [np.asarray(t, dtype=np.uint64) for t in tables]
    if not tables:
        raise ValueError("need at least one table")
    counts = np.asarray([len(t) for t in tables], dtype=np.int64)
    if len(set(counts.tolist())) == 1:
        fit_tables = tables  # equal lengths: no padding, bit-exact with build()
    else:
        m = _pow2ceil(int(counts.max()))
        fit_tables = [_pad_sorted_table(t, m) for t in tables]
    entry = registry.entry(spec.kind)
    use_vmap = fit == "vmap" or (fit == "auto" and spec.kind in VMAP_KINDS)
    if fit == "fast":
        per = _fast_fit([spec] * len(fit_tables), fit_tables)
    elif use_vmap:
        per = _vmap_fit([spec] * len(fit_tables), fit_tables)
    else:
        per = [entry.build(spec, t) for t in fit_tables]
    return _stack_with_meta(spec, per, fit_tables, counts)


def _stack_with_meta(spec: IndexSpec, per: list, fit_tables: list, counts) -> BatchedIndexes:
    harmonized = _harmonize(spec.kind, per)
    stacked = stack_indexes(harmonized)
    meta = [
        {"static": p.static, "shapes": {k: tuple(v.shape) for k, v in h.arrays.items()},
         "info": dict(p.info)}
        for p, h in zip(per, harmonized)
    ]
    info = {
        "spec": spec.display_name(),
        "n_tables": len(fit_tables),
        "m": len(fit_tables[0]),
    }
    return BatchedIndexes(
        index=stacked,
        tables=jnp.asarray(np.stack(fit_tables)),
        counts=jnp.asarray(counts),
        meta=meta,
        info=info,
    )


# ---------------------------------------------------------------------------
# build_grid: many specs, one table
# ---------------------------------------------------------------------------


def build_grid(specs, table_np, *, fit: str = "auto") -> list:
    """Build one index per spec over a single table, in spec order.

    The grid engine behind the Pareto tuner and the CDFShop/SY-RMI
    mining sweep.  Under ``fit="auto"`` (the recommended mode) /
    ``"vmap"``, RMI-family entries that resolve to the same branching
    factor (e.g. every root type at one ``b``) share ONE vmapped
    leaf-fit trace, and PGM / PGM_M / RS entries share ONE vmapped
    corridor-scan trace per kind (ε is traced, so a whole ε-grid is one
    device call); every other entry uses its registered host builder.
    Specs of one kind + structure already share their jitted *lookup*
    (the PR-1 invariant), so a full grid sweep compiles O(kinds), not
    O(specs).

    Example — the CDFShop-style sweep behind the Pareto tuner::

        specs = [RMISpec(b=512, root_type=r) for r in ("linear", "cubic")]
        specs += [PGMSpec(eps=64), RSSpec(eps=32)]
        built = build_grid(specs, table)   # spec order preserved
        sizes = [idx.space_bytes() for idx in built]
    """
    if fit not in FITS:
        raise ValueError(f"unknown fit {fit!r}; choose from {FITS}")
    specs = [_resolve_spec(s) for s in specs]
    table_np = np.asarray(table_np, dtype=np.uint64)
    n = len(table_np)
    out: dict[int, Index] = {}
    groups: dict[tuple, list] = {}
    if fit in ("auto", "vmap", "fast"):
        for i, spec in enumerate(specs):
            if spec.kind in ("RMI", "SY-RMI"):
                b, _ = _rmi_plan(spec, n)
                groups.setdefault(("rmi", b), []).append((i, spec))
            elif spec.kind in VMAP_KINDS:
                # scan-fit kinds: ε is traced, so every member of a kind
                # shares one vmapped corridor-scan call
                groups.setdefault((spec.kind,), []).append((i, spec))
    for key, members in groups.items():
        use_fast = fit == "fast" and key[0] in FAST_KINDS
        if len(members) < 2 and not use_fast:
            continue  # a lone entry gains nothing from the batch axis
        fit_fn = _fast_fit if use_fast else _vmap_fit
        built = fit_fn([s for _, s in members], [table_np] * len(members))
        for (i, _), idx in zip(members, built):
            out[i] = idx
    for i, spec in enumerate(specs):
        if i not in out:
            out[i] = registry.entry(spec.kind).build(spec, table_np)
    return [out[i] for i in range(len(specs))]
