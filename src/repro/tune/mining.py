"""SY-RMI mining on the batched builder (paper §3.2/§4, Figure 4).

The original mining engine (:mod:`repro.core.sy_rmi`) looped
``build_rmi`` over the CDFShop grid and timed raw ``RMIModel``s.  This
port runs the same procedure through the tuner's machinery so mining
and Pareto tuning share ONE engine:

* the CDFShop sweep is a grid of :class:`~repro.index.RMISpec`\\ s built
  by :func:`repro.tune.batched.build_grid` — every root type at one
  branching factor shares a single vmapped leaf-fit trace (and when a
  mined grid carries PGM/RS candidates, their corridor fits share one
  vmapped scan trace per kind the same way);
* query timing goes through the shared jitted ``Index.lookup`` (one
  trace per grid, not per model);
* UB mining reads ``b`` / ``space_bytes`` off the built indexes.

``mine_sy_rmi`` keeps the historical signature and
:class:`~repro.core.sy_rmi.SyRMIResult` shape;
``repro.core.sy_rmi.mine_sy_rmi`` now delegates here.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.obs.timing import stopwatch
from repro.core.rmi import ROOT_TYPES
from repro.core.sy_rmi import SyRMIResult
from repro.index.specs import RMISpec

from .batched import build_grid
from .pareto import _time_lookup


def cdfshop_grid(n: int, max_models: int = 10) -> list:
    """Deterministic CDFShop analogue as a spec grid: roots x geometric
    branching factors, thinned to ``max_models`` with coverage of both
    axes (the paper uses CDFShop's ~10 models per table)."""
    bs = [b for b in (64, 256, 1024, 4096, 16384, 65536, 262144) if b <= max(n // 2, 2)]
    combos = [(root, b) for root in ROOT_TYPES for b in bs]
    if len(combos) > max_models:
        idx = np.linspace(0, len(combos) - 1, max_models).astype(int)
        combos = [combos[i] for i in idx]
    return [RMISpec(b=b, root_type=root) for root, b in combos]


def mine_ub(candidates) -> float:
    """UB = median branching factor per byte of model space (§3.2)."""
    ratios = [c.b / c.space_bytes() for c in candidates]
    return float(np.median(ratios))


def pick_winner(candidates, table_np: np.ndarray, queries_np: np.ndarray, reps: int = 3):
    """Relative-majority winner by query time on the simulation set."""
    import jax.numpy as jnp

    table_j = jnp.asarray(table_np)
    q_j = jnp.asarray(queries_np)
    times = [_time_lookup(c, table_j, q_j, "xla", reps) / len(queries_np) for c in candidates]
    best = int(np.argmin(times))
    return candidates[best].root_type, times


def mine_sy_rmi(
    tables: Sequence[np.ndarray],
    query_frac: float = 0.01,
    n_queries: int = 1_000_000,
    seed: int = 0,
    max_models: int = 10,
) -> SyRMIResult:
    """Full mining pass over a set of same-tier tables (paper §4)."""
    rng = np.random.default_rng(seed)
    sw = stopwatch()
    all_cands, votes, sizes, times_all = [], [], [], []
    for table in tables:
        table = np.asarray(table, dtype=np.uint64)
        specs = cdfshop_grid(len(table), max_models=max_models)
        cands = build_grid(specs, table, fit="auto")
        all_cands.extend(cands)
        nq = max(16, int(n_queries * query_frac))
        queries = rng.choice(table, size=nq, replace=True)
        winner, times = pick_winner(cands, table, queries)
        votes.append(winner)
        sizes.append([c.space_bytes() for c in cands])
        times_all.append(times)
    ub = mine_ub(all_cands)
    roots, counts = np.unique(votes, return_counts=True)
    winner_root = str(roots[np.argmax(counts)])
    return SyRMIResult(
        ub=ub,
        winner_root=winner_root,
        sweep_sizes=sizes,
        sweep_times=times_all,
        mining_time=sw.elapsed,
    )
