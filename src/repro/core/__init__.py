"""Core library: the paper's learned static indexes as composable JAX modules.

Hierarchy (paper §3.2): constant-space atomic models (L/Q/C) and KO-BFS;
parametric-space two-level RMIs and the synoptic SY-RMI; CDF-approximation
controlled PGM (+ bi-criteria) and RadixSpline; B+-tree and plain Sorted
Table Search procedures as baselines.
"""

from . import atomic, btree, builder, cdf, kbfs, pgm, radix_spline, rmi, search, sy_rmi
from .builder import KINDS, build_index, model_reduction_factor
from .cdf import as_table, reduction_factor, true_ranks

__all__ = [
    "atomic", "btree", "builder", "cdf", "kbfs", "pgm", "radix_spline",
    "rmi", "search", "sy_rmi",
    "KINDS", "build_index", "model_reduction_factor",
    "as_table", "reduction_factor", "true_ranks",
]
