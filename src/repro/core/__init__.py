"""Core library: the paper's learned static indexes as composable JAX code.

Layering (post Index-API redesign):

* **This package** owns the *math*: Sorted Table Search procedures
  (:mod:`~repro.core.search`) and the per-kind fitting algorithms
  (atomic L/Q/C, KO-BFS, RMI, SY-RMI, PGM (+ bi-criteria), RadixSpline,
  B+-tree) — host-side builds that produce model parameters.
* :mod:`repro.index` owns the *API*: hashable build specs in a
  decorator registry, and the :class:`~repro.index.Index` pytree whose
  leaves are the fitted flat arrays, queried through one shared jitted
  lookup per kind with ``xla`` / ``bbs`` / ``pallas`` / ``ref``
  backends.

The pre-registry shims (``KINDS`` / ``build_index``) are gone: use
``repro.index.kinds()`` and ``repro.index.build(spec, table)``.
"""

from . import atomic, btree, cdf, kbfs, pgm, radix_spline, rmi, search, sy_rmi
from .cdf import as_table, model_reduction_factor, reduction_factor, true_ranks

__all__ = [
    "atomic",
    "btree",
    "cdf",
    "kbfs",
    "pgm",
    "radix_spline",
    "rmi",
    "search",
    "sy_rmi",
    "model_reduction_factor",
    "as_table",
    "reduction_factor",
    "true_ranks",
]
