"""KO-BFS / KO-BBS — the paper's first new model (§3.2, class 2).

Two-level hybrid, constant space: partition the *table* into ``k``
equal-rank segments (k <= 20), fit all three atomic models per segment,
keep the one with the best reduction factor (for fixed-window atomic
models, RF ordering == error-bound ordering, so we pick the smallest
exact eps).  Query: sequential fence scan (k is a small constant) ->
per-segment polynomial predict -> bounded branch-free (KO-BFS) or
branchy (KO-BBS) search.

``build_ko`` backs the ``KO`` kind in :mod:`repro.index`; the KO-BBS
epilogue is the generic ``backend="bbs"`` path there.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.obs.timing import stopwatch
from . import search
from .atomic import poly_fit, poly_exact_eps, poly_eval_jnp
from .cdf import POS_DTYPE


@dataclass
class KOModel:
    k: int
    fences: jnp.ndarray  # (k-1,) uint64 — first key of segments 1..k-1
    coef: jnp.ndarray  # (k, 4) f64 ascending, per-segment
    kmin_seg: jnp.ndarray  # (k,) f64
    inv_span_seg: jnp.ndarray  # (k,) f64
    eps: jnp.ndarray  # (k,) int64
    seg_start: jnp.ndarray  # (k+1,) int64 rank fences
    max_eps: int
    max_width: int
    n: int
    build_time: float = 0.0
    name: str = "KO"

    def _segment(self, q):
        # Sequential-scan semantics of the paper: k-1 fence compares.
        return jnp.sum(
            (q[..., None] >= self.fences[None, :]).astype(POS_DTYPE), axis=-1
        )

    def intervals(self, table, q):
        s = self._segment(q)
        coef = jnp.take(self.coef, s, axis=0)
        kmin = jnp.take(self.kmin_seg, s)
        inv_span = jnp.take(self.inv_span_seg, s)
        eps = jnp.take(self.eps, s)
        u = (q.astype(jnp.float64) - kmin) * inv_span
        u = jnp.clip(u, 0.0, 1.0)
        p = jnp.clip(poly_eval_jnp(coef, u), -4.0e15, 4.0e15)
        lo = jnp.floor(p).astype(POS_DTYPE) - eps
        hi = jnp.ceil(p).astype(POS_DTYPE) + eps
        # The fence scan proves pred in [seg_start[s]-1, seg_start[s+1]-1]:
        # clamp the window into that range (handles model blow-ups).
        b_lo = jnp.maximum(jnp.take(self.seg_start, s) - 1, 0)
        b_hi = jnp.take(self.seg_start, s + 1) - 1
        lo = jnp.clip(lo, b_lo, b_hi)
        hi = jnp.clip(hi, b_lo, b_hi)
        return lo, hi

    @property
    def max_window(self) -> int:
        return min(2 * self.max_eps + 3, self.max_width + 2, self.n)

    def predecessor(self, table, q, *, branchy: bool = False):
        lo, hi = self.intervals(table, q)
        if branchy:  # KO-BBS epilogue
            return _bounded_bbs(table, q, lo, hi)
        return search.bounded_bfs(table, q, lo, hi, max_window=self.max_window)

    def space_bytes(self) -> int:
        # fences + coeffs + rescale + eps per segment: O(k) = constant.
        return self.k * (8 + 32 + 16 + 4) + 8


def _bounded_bbs(table, q, lo, hi):
    """Branchy bounded epilogue (for KO-BBS) — shared impl in search."""
    return search.bounded_bbs_branchy(table, q, lo, hi)


def build_ko(table_np: np.ndarray, k: int = 15) -> KOModel:
    """Fit L/Q/C per segment, keep the best (smallest exact eps)."""
    sw = stopwatch()
    n = len(table_np)
    k = max(1, min(k, n))
    seg_start = (np.arange(k + 1, dtype=np.int64) * n) // k
    fences = table_np[seg_start[1:k]]

    coefs = np.zeros((k, 4), dtype=np.float64)
    kmins = np.zeros(k, dtype=np.float64)
    inv_spans = np.ones(k, dtype=np.float64)
    epss = np.zeros(k, dtype=np.int64)

    for s in range(k):
        a, b = int(seg_start[s]), int(seg_start[s + 1])
        # extended range for the boundary-safe error bound
        ea, eb = max(a - 1, 0), min(b + 1, n)
        keys = table_np[ea:eb]
        ranks = np.arange(ea, eb, dtype=np.float64)
        kmin, kmax = table_np[a], table_np[min(b, n - 1) if b < n else n - 1]
        span = np.float64(kmax - kmin)
        inv = 1.0 / span if span > 0 else 1.0
        u = (keys.astype(np.float64) - np.float64(kmin)) * inv
        best = None
        if b - a < 8:
            coef = np.zeros(4)
            coef[0] = float(a)
            best = (b - a + 2, coef)
        else:
            for deg in (1, 2, 3):
                coef = poly_fit(u, ranks, deg)
                eps = poly_exact_eps(coef, u, ranks, float(u[0]), float(u[-1]))
                if best is None or eps < best[0]:
                    best = (eps, coef)
        epss[s] = min(best[0], 1 << 40)
        coefs[s] = best[1]
        kmins[s] = np.float64(kmin)
        inv_spans[s] = inv

    dt = sw.elapsed
    return KOModel(
        k=k,
        fences=jnp.asarray(fences),
        coef=jnp.asarray(coefs),
        kmin_seg=jnp.asarray(kmins),
        inv_span_seg=jnp.asarray(inv_spans),
        eps=jnp.asarray(epss),
        seg_start=jnp.asarray(seg_start),
        max_eps=int(epss.max()),
        max_width=int(np.max(np.diff(seg_start))),
        n=n,
        build_time=dt,
        name=f"{k}O",
    )
