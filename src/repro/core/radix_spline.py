"""RadixSpline (paper §3.2, class 4) — single-pass ε-spline + radix table.

GreedySplineCorridor: knots are actual (key, rank) points; a candidate
point is accepted while the slope from the current anchor stays inside
the corridor cone; on violation the previous point becomes a knot and the
cone restarts.  A radix table over the top ``r`` bits of (key - kmin)
narrows the knot search.  Build is one O(n) pass (chunk-vectorised).
The verified error bound is re-measured post-build over all keys, so the
reported window is a guarantee even under f64 rounding.

``build_rs`` backs the ``RS`` kind in :mod:`repro.index`; knots are
padded to a power of two there for jit-cache sharing.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.obs.timing import stopwatch
from . import search
from .cdf import POS_DTYPE, blocked_corridor_scan, ceil_log2, chunked_corridor_scan, segment_ids
from .pgm import FAST_CHUNK, SCAN_CHUNK

_CHUNK = 4096


def spline_knots(keys_f64: np.ndarray, eps: int) -> np.ndarray:
    """Greedy corridor spline: returns knot indices (always incl. 0, n-1)."""
    n = len(keys_f64)
    if n <= 2:
        return np.arange(n, dtype=np.int64)
    knots = [0]
    x0, y0 = keys_f64[0], 0.0
    lo, hi = -np.inf, np.inf
    i = 1
    while i < n - 1:
        i2 = min(i + _CHUNK, n - 1)
        dx = keys_f64[i:i2] - x0
        dy = np.arange(i, i2, dtype=np.float64) - y0
        slope = dy / dx
        lo_b = (dy - eps) / dx
        hi_b = (dy + eps) / dx
        # cone *before* including each point: shifted running bounds
        lo_pre = np.maximum(np.concatenate([[lo], np.maximum.accumulate(lo_b)[:-1]]), lo)
        hi_pre = np.minimum(np.concatenate([[hi], np.minimum.accumulate(hi_b)[:-1]]), hi)
        bad = (slope < lo_pre) | (slope > hi_pre)
        if bad.any():
            k = int(np.argmax(bad))
            knot = i + k - 1  # previous point becomes a knot
            knots.append(knot)
            x0, y0 = keys_f64[knot], float(knot)
            lo, hi = -np.inf, np.inf
            i = knot + 1
        else:
            lo = float(np.maximum(lo_pre[-1], lo_b[-1]))
            hi = float(np.minimum(hi_pre[-1], hi_b[-1]))
            i = i2
    knots.append(n - 1)
    return np.unique(np.asarray(knots, dtype=np.int64))


def rs_knots_scan(keys_f64, eps, *, chunk: int = SCAN_CHUNK):
    """Array-native GreedySplineCorridor: the device form of
    :func:`spline_knots`, as a chunked ``lax.scan`` over the corridor
    cone.

    Returns an ``(n,)`` bool mask, True exactly at the knot indices
    :func:`spline_knots` emits.  Per point the carry is the (anchor key,
    anchor rank, cone lo, cone hi) state; a cone violation at point
    ``i`` makes point ``i - 1`` a knot and re-anchors there, after which
    point ``i`` is accepted against the fresh cone — identical f64
    arithmetic to the numpy single pass (min/max are exact).  ``eps``
    may be a traced scalar so a whole batch of (table, ε) pairs shares
    ONE jitted trace under ``vmap``.
    """
    keys = jnp.asarray(keys_f64, dtype=jnp.float64)
    n = keys.shape[0]
    if n <= 2:
        return jnp.ones((n,), dtype=bool)
    eps = jnp.asarray(eps, dtype=jnp.float64)
    # interior points 1 .. n-2; each step also sees its left neighbour
    # (the knot a violation creates) and its absolute rank
    x = keys[1 : n - 1]
    xprev = keys[0 : n - 2]
    ranks = jnp.arange(1, n - 1, dtype=jnp.float64)
    step = _rs_corridor_step(eps)
    init = (keys[0], jnp.float64(0.0), jnp.float64(-jnp.inf), jnp.float64(jnp.inf))
    flags = chunked_corridor_scan(step, init, (x, xprev, ranks), n - 2, chunk)
    # a violation at point i marks knot i-1; endpoints are always knots
    mask = jnp.pad(flags, (0, 2))
    return mask.at[0].set(True).at[n - 1].set(True)


def _rs_corridor_step(eps):
    """Per-point GreedySplineCorridor recurrence, shared by the exact
    chunked scan and the blocked fast fit."""

    def step(carry, inp):
        x0, y0, lo, hi = carry
        xi, xp, r, v = inp
        slope = (r - y0) / (xi - x0)
        bad = (slope < lo) | (slope > hi)
        # on violation the previous point becomes the knot/anchor and
        # the current point is accepted against the restarted cone
        x0n = jnp.where(bad, xp, x0)
        y0n = jnp.where(bad, r - 1.0, y0)
        dx = xi - x0n
        dy = r - y0n
        lo_b = (dy - eps) / dx
        hi_b = (dy + eps) / dx
        nxt = (
            x0n,
            y0n,
            jnp.where(bad, lo_b, jnp.maximum(lo, lo_b)),
            jnp.where(bad, hi_b, jnp.minimum(hi, hi_b)),
        )
        carry = tuple(jnp.where(v, a, b) for a, b in zip(nxt, carry))
        return carry, bad & v

    return step


def _rs_merge_round(keys, kmask, eps):
    """One parity merge round over the knot mask: every odd-id knot is
    a removal candidate; the chord from its left to its right neighbour
    knot is re-measured over all spanned elements (associative segment
    reductions, O(log n) depth) and the knot is dropped when the chord
    error stays within ``eps``.  Endpoint knots (id 0 and the last id)
    are never candidates."""
    import jax

    n = keys.shape[0]
    idx = jnp.arange(n, dtype=POS_DTYPE)
    kid, kpos = segment_ids(kmask)
    last = kid[n - 1]
    g = kid | 1  # the candidate knot this element's chord error tests
    p0 = jnp.take(kpos, jnp.maximum(g - 1, 0))
    p1 = jnp.take(kpos, jnp.minimum(g + 1, n - 1))
    x0 = jnp.take(keys, jnp.clip(p0, 0, n - 1))
    x1 = jnp.take(keys, jnp.clip(p1, 0, n - 1))
    r0 = p0.astype(jnp.float64)
    r1 = p1.astype(jnp.float64)
    pred = r0 + (keys - x0) * (r1 - r0) / (x1 - x0)
    err = jnp.abs(pred - idx.astype(jnp.float64))
    maxerr = jax.ops.segment_max(err, g, num_segments=n, indices_are_sorted=True)
    ok_g = maxerr <= eps  # NaN (colliding f64 keys) compares False
    drop = kmask & ((kid % 2) == 1) & (kid < last) & jnp.take(ok_g, kid)
    return kmask & ~drop


def rs_verified_eps(keys, kmask):
    """Measured max |chord prediction - rank| for the spline induced by
    ``kmask``, on device — the same clipped-interpolation formula
    :func:`build_rs` uses for its post-build ``eps_eff``, so given the
    same knots the two agree bit-for-bit."""
    keys = jnp.asarray(keys, dtype=jnp.float64)
    n = keys.shape[0]
    if n <= 2:
        return jnp.float64(0.0)
    idx = jnp.arange(n, dtype=POS_DTYPE)
    kid, kpos = segment_ids(kmask)
    last = kid[n - 1]
    j = jnp.minimum(kid, last - 1)
    p0 = jnp.take(kpos, j)
    p1 = jnp.take(kpos, j + 1)
    x1 = jnp.take(keys, jnp.clip(p0, 0, n - 1))
    x2 = jnp.take(keys, jnp.clip(p1, 0, n - 1))
    t = jnp.clip((keys - x1) / jnp.maximum(x2 - x1, 1.0), 0.0, 1.0)
    pred = p0.astype(jnp.float64) + t * (p1 - p0).astype(jnp.float64)
    return jnp.max(jnp.abs(pred - idx.astype(jnp.float64)))


def rs_knots_fast(keys_f64, eps, *, chunk: int = FAST_CHUNK, rounds=None):
    """O(log n)-depth GreedySplineCorridor fit: the ``fit="fast"`` RS
    entry point.

    Blocked vmapped greedy — block ``b`` re-anchors at element
    ``b * chunk``, which becomes a forced knot — followed by
    associative parity merge rounds that remove block-boundary knots
    whose neighbour-to-neighbour chord stays within ``eps``, then a
    device chord re-measure.  Knot placement is NOT bit-identical to
    :func:`spline_knots` (a few % extra knots on curvy data) but the
    corridor quality contract is re-checked: ``ok`` is True iff the
    measured chord error is within ``eps``.  On ``ok == False`` callers
    fall back to the exact scan fit; either way ``build_rs`` re-derives
    ``eps_eff`` from the actual knots, so *correctness* never depends
    on which fit produced them.  Compiled sequential depth is
    O(chunk + log² n), constant in the table size.

    Returns ``(mask, ok)`` — ``(n,)`` bool knot mask (always includes
    0 and n-1) and the scalar device bool.

    Example::

        mask, ok = rs_knots_fast(table.astype(np.float64), eps=32)
        model = build_rs(table, eps=32, knots=np.flatnonzero(np.asarray(mask)))
    """
    keys = jnp.asarray(keys_f64, dtype=jnp.float64)
    n = keys.shape[0]
    if n <= 2:
        return jnp.ones((n,), dtype=bool), jnp.bool_(True)
    chunk = max(int(chunk), 2)
    eps = jnp.asarray(eps, dtype=jnp.float64)
    # elements 1 .. n-1; block b anchors at element b*chunk (forced knot)
    x = keys[1:]
    xprev = keys[:-1]
    ranks = jnp.arange(1, n, dtype=jnp.float64)
    step = _rs_corridor_step(eps)

    def block_init(first):
        xi, xp, r, v = first
        return (xp, r - 1.0, jnp.float64(-jnp.inf), jnp.float64(jnp.inf))

    flags = blocked_corridor_scan(step, block_init, (x, xprev, ranks), n - 1, chunk)
    # a violation flag at element i marks knot i-1 — i.e. mask position
    # i-1, which is exactly the flag's own position in the shifted array
    kmask = jnp.pad(flags, (0, 1))
    kmask = kmask | (jnp.arange(n, dtype=POS_DTYPE) % chunk == 0)
    kmask = kmask.at[n - 1].set(True)
    nblocks = -(-n // chunk)
    r = int(rounds) if rounds is not None else ceil_log2(max(nblocks, 2)) + 1
    for _ in range(r):
        kmask = _rs_merge_round(keys, kmask, eps)
    ok = rs_verified_eps(keys, kmask) <= eps
    return kmask, ok


@dataclass
class RSModel:
    eps: int
    eps_eff: int  # post-build verified bound (incl. f64 rounding slack)
    knot_keys: jnp.ndarray  # (m,) uint64
    knot_ranks: jnp.ndarray  # (m,) int64
    radix_table: jnp.ndarray  # (2^r + 1,) int64
    kmin: jnp.ndarray  # uint64 scalar
    shift: int
    r_bits: int
    n: int
    m: int
    build_time: float = 0.0
    name: str = "RS"

    def intervals(self, table, q):
        qc = jnp.maximum(q, self.kmin)
        prefix = ((qc - self.kmin) >> self.shift).astype(POS_DTYPE)
        prefix = jnp.clip(prefix, 0, (1 << self.r_bits) - 1)
        lo_k = jnp.maximum(jnp.take(self.radix_table, prefix) - 1, 0)
        hi_k = jnp.take(self.radix_table, prefix + 1)
        length = jnp.maximum(hi_k - lo_k, 1)
        ub = search.bounded_upper_bound(
            self.knot_keys, q, lo_k, length, steps=search.ceil_log2(self.m)
        )
        j = jnp.clip(ub - 1, 0, self.m - 2)
        x1 = jnp.take(self.knot_keys, j).astype(jnp.float64)
        x2 = jnp.take(self.knot_keys, j + 1).astype(jnp.float64)
        y1 = jnp.take(self.knot_ranks, j).astype(jnp.float64)
        y2 = jnp.take(self.knot_ranks, j + 1).astype(jnp.float64)
        t = (qc.astype(jnp.float64) - x1) / jnp.maximum(x2 - x1, 1.0)
        pred = y1 + jnp.clip(t, 0.0, 1.0) * (y2 - y1)
        lo = jnp.floor(pred).astype(POS_DTYPE) - self.eps_eff
        hi = jnp.ceil(pred).astype(POS_DTYPE) + self.eps_eff
        return jnp.clip(lo, 0, self.n - 1), jnp.clip(hi, 0, self.n - 1)

    @property
    def max_window(self) -> int:
        return min(2 * self.eps_eff + 3, self.n)

    def predecessor(self, table, q):
        lo, hi = self.intervals(table, q)
        return search.bounded_bfs(table, q, lo, hi, max_window=self.max_window)

    def space_bytes(self) -> int:
        # knots (key 8 + rank 8) + radix table (8 per entry).
        return self.m * 16 + ((1 << self.r_bits) + 1) * 8 + 16


def build_rs(table_np: np.ndarray, eps: int = 32, r_bits: int = 12, *, knots=None) -> RSModel:
    """Single-pass RadixSpline build.  ``knots`` optionally supplies the
    knot indices — e.g. from the device scan fit
    (:func:`rs_knots_scan`); the radix table and the verified error
    bound are always re-derived from them."""
    sw = stopwatch()
    n = len(table_np)
    keys = table_np.astype(np.float64)
    if knots is None:
        knots = spline_knots(keys, eps)
    knots = np.asarray(knots, dtype=np.int64)
    m = len(knots)
    knot_keys = table_np[knots]
    knot_ranks = knots.astype(np.int64)

    kmin, kmax = table_np[0], table_np[-1]
    span = int(kmax - kmin)
    span_bits = max(span.bit_length(), 1)
    r_bits = min(r_bits, span_bits)
    shift = max(0, span_bits - r_bits)
    prefixes = ((knot_keys - kmin) >> np.uint64(shift)).astype(np.int64)
    rt = np.searchsorted(prefixes, np.arange((1 << r_bits) + 1), side="left").astype(np.int64)

    # post-build verified bound over all keys (linear interp between knots)
    seg = np.clip(np.searchsorted(knots, np.arange(n), side="right") - 1, 0, m - 2)
    x1 = keys[knots[seg]]
    x2 = keys[knots[seg + 1]]
    y1 = knots[seg].astype(np.float64)
    y2 = knots[seg + 1].astype(np.float64)
    t = np.clip((keys - x1) / np.maximum(x2 - x1, 1.0), 0.0, 1.0)
    pred = y1 + t * (y2 - y1)
    eps_eff = int(np.ceil(np.max(np.abs(pred - np.arange(n, dtype=np.float64))))) + 1

    dt = sw.elapsed
    return RSModel(
        eps=eps,
        eps_eff=max(eps_eff, 1),
        knot_keys=jnp.asarray(knot_keys),
        knot_ranks=jnp.asarray(knot_ranks),
        radix_table=jnp.asarray(rt),
        kmin=jnp.asarray(np.uint64(kmin)),
        shift=shift,
        r_bits=r_bits,
        n=n,
        m=m,
        build_time=dt,
        name=f"RS[eps={eps},r={r_bits}]",
    )
