"""RadixSpline (paper §3.2, class 4) — single-pass ε-spline + radix table.

GreedySplineCorridor: knots are actual (key, rank) points; a candidate
point is accepted while the slope from the current anchor stays inside
the corridor cone; on violation the previous point becomes a knot and the
cone restarts.  A radix table over the top ``r`` bits of (key - kmin)
narrows the knot search.  Build is one O(n) pass (chunk-vectorised).
The verified error bound is re-measured post-build over all keys, so the
reported window is a guarantee even under f64 rounding.

``build_rs`` backs the ``RS`` kind in :mod:`repro.index`; knots are
padded to a power of two there for jit-cache sharing.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.obs.timing import stopwatch
from . import search
from .cdf import POS_DTYPE, chunked_corridor_scan
from .pgm import SCAN_CHUNK

_CHUNK = 4096


def spline_knots(keys_f64: np.ndarray, eps: int) -> np.ndarray:
    """Greedy corridor spline: returns knot indices (always incl. 0, n-1)."""
    n = len(keys_f64)
    if n <= 2:
        return np.arange(n, dtype=np.int64)
    knots = [0]
    x0, y0 = keys_f64[0], 0.0
    lo, hi = -np.inf, np.inf
    i = 1
    while i < n - 1:
        i2 = min(i + _CHUNK, n - 1)
        dx = keys_f64[i:i2] - x0
        dy = np.arange(i, i2, dtype=np.float64) - y0
        slope = dy / dx
        lo_b = (dy - eps) / dx
        hi_b = (dy + eps) / dx
        # cone *before* including each point: shifted running bounds
        lo_pre = np.maximum(np.concatenate([[lo], np.maximum.accumulate(lo_b)[:-1]]), lo)
        hi_pre = np.minimum(np.concatenate([[hi], np.minimum.accumulate(hi_b)[:-1]]), hi)
        bad = (slope < lo_pre) | (slope > hi_pre)
        if bad.any():
            k = int(np.argmax(bad))
            knot = i + k - 1  # previous point becomes a knot
            knots.append(knot)
            x0, y0 = keys_f64[knot], float(knot)
            lo, hi = -np.inf, np.inf
            i = knot + 1
        else:
            lo = float(np.maximum(lo_pre[-1], lo_b[-1]))
            hi = float(np.minimum(hi_pre[-1], hi_b[-1]))
            i = i2
    knots.append(n - 1)
    return np.unique(np.asarray(knots, dtype=np.int64))


def rs_knots_scan(keys_f64, eps, *, chunk: int = SCAN_CHUNK):
    """Array-native GreedySplineCorridor: the device form of
    :func:`spline_knots`, as a chunked ``lax.scan`` over the corridor
    cone.

    Returns an ``(n,)`` bool mask, True exactly at the knot indices
    :func:`spline_knots` emits.  Per point the carry is the (anchor key,
    anchor rank, cone lo, cone hi) state; a cone violation at point
    ``i`` makes point ``i - 1`` a knot and re-anchors there, after which
    point ``i`` is accepted against the fresh cone — identical f64
    arithmetic to the numpy single pass (min/max are exact).  ``eps``
    may be a traced scalar so a whole batch of (table, ε) pairs shares
    ONE jitted trace under ``vmap``.
    """
    keys = jnp.asarray(keys_f64, dtype=jnp.float64)
    n = keys.shape[0]
    if n <= 2:
        return jnp.ones((n,), dtype=bool)
    eps = jnp.asarray(eps, dtype=jnp.float64)
    # interior points 1 .. n-2; each step also sees its left neighbour
    # (the knot a violation creates) and its absolute rank
    x = keys[1 : n - 1]
    xprev = keys[0 : n - 2]
    ranks = jnp.arange(1, n - 1, dtype=jnp.float64)

    def step(carry, inp):
        x0, y0, lo, hi = carry
        xi, xp, r, v = inp
        slope = (r - y0) / (xi - x0)
        bad = (slope < lo) | (slope > hi)
        # on violation the previous point becomes the knot/anchor and
        # the current point is accepted against the restarted cone
        x0n = jnp.where(bad, xp, x0)
        y0n = jnp.where(bad, r - 1.0, y0)
        dx = xi - x0n
        dy = r - y0n
        lo_b = (dy - eps) / dx
        hi_b = (dy + eps) / dx
        nxt = (
            x0n,
            y0n,
            jnp.where(bad, lo_b, jnp.maximum(lo, lo_b)),
            jnp.where(bad, hi_b, jnp.minimum(hi, hi_b)),
        )
        carry = tuple(jnp.where(v, a, b) for a, b in zip(nxt, carry))
        return carry, bad & v

    init = (keys[0], jnp.float64(0.0), jnp.float64(-jnp.inf), jnp.float64(jnp.inf))
    flags = chunked_corridor_scan(step, init, (x, xprev, ranks), n - 2, chunk)
    # a violation at point i marks knot i-1; endpoints are always knots
    mask = jnp.pad(flags, (0, 2))
    return mask.at[0].set(True).at[n - 1].set(True)


@dataclass
class RSModel:
    eps: int
    eps_eff: int  # post-build verified bound (incl. f64 rounding slack)
    knot_keys: jnp.ndarray  # (m,) uint64
    knot_ranks: jnp.ndarray  # (m,) int64
    radix_table: jnp.ndarray  # (2^r + 1,) int64
    kmin: jnp.ndarray  # uint64 scalar
    shift: int
    r_bits: int
    n: int
    m: int
    build_time: float = 0.0
    name: str = "RS"

    def intervals(self, table, q):
        qc = jnp.maximum(q, self.kmin)
        prefix = ((qc - self.kmin) >> self.shift).astype(POS_DTYPE)
        prefix = jnp.clip(prefix, 0, (1 << self.r_bits) - 1)
        lo_k = jnp.maximum(jnp.take(self.radix_table, prefix) - 1, 0)
        hi_k = jnp.take(self.radix_table, prefix + 1)
        length = jnp.maximum(hi_k - lo_k, 1)
        ub = search.bounded_upper_bound(
            self.knot_keys, q, lo_k, length, steps=search.ceil_log2(self.m)
        )
        j = jnp.clip(ub - 1, 0, self.m - 2)
        x1 = jnp.take(self.knot_keys, j).astype(jnp.float64)
        x2 = jnp.take(self.knot_keys, j + 1).astype(jnp.float64)
        y1 = jnp.take(self.knot_ranks, j).astype(jnp.float64)
        y2 = jnp.take(self.knot_ranks, j + 1).astype(jnp.float64)
        t = (qc.astype(jnp.float64) - x1) / jnp.maximum(x2 - x1, 1.0)
        pred = y1 + jnp.clip(t, 0.0, 1.0) * (y2 - y1)
        lo = jnp.floor(pred).astype(POS_DTYPE) - self.eps_eff
        hi = jnp.ceil(pred).astype(POS_DTYPE) + self.eps_eff
        return jnp.clip(lo, 0, self.n - 1), jnp.clip(hi, 0, self.n - 1)

    @property
    def max_window(self) -> int:
        return min(2 * self.eps_eff + 3, self.n)

    def predecessor(self, table, q):
        lo, hi = self.intervals(table, q)
        return search.bounded_bfs(table, q, lo, hi, max_window=self.max_window)

    def space_bytes(self) -> int:
        # knots (key 8 + rank 8) + radix table (8 per entry).
        return self.m * 16 + ((1 << self.r_bits) + 1) * 8 + 16


def build_rs(table_np: np.ndarray, eps: int = 32, r_bits: int = 12, *, knots=None) -> RSModel:
    """Single-pass RadixSpline build.  ``knots`` optionally supplies the
    knot indices — e.g. from the device scan fit
    (:func:`rs_knots_scan`); the radix table and the verified error
    bound are always re-derived from them."""
    sw = stopwatch()
    n = len(table_np)
    keys = table_np.astype(np.float64)
    if knots is None:
        knots = spline_knots(keys, eps)
    knots = np.asarray(knots, dtype=np.int64)
    m = len(knots)
    knot_keys = table_np[knots]
    knot_ranks = knots.astype(np.int64)

    kmin, kmax = table_np[0], table_np[-1]
    span = int(kmax - kmin)
    span_bits = max(span.bit_length(), 1)
    r_bits = min(r_bits, span_bits)
    shift = max(0, span_bits - r_bits)
    prefixes = ((knot_keys - kmin) >> np.uint64(shift)).astype(np.int64)
    rt = np.searchsorted(prefixes, np.arange((1 << r_bits) + 1), side="left").astype(np.int64)

    # post-build verified bound over all keys (linear interp between knots)
    seg = np.clip(np.searchsorted(knots, np.arange(n), side="right") - 1, 0, m - 2)
    x1 = keys[knots[seg]]
    x2 = keys[knots[seg + 1]]
    y1 = knots[seg].astype(np.float64)
    y2 = knots[seg + 1].astype(np.float64)
    t = np.clip((keys - x1) / np.maximum(x2 - x1, 1.0), 0.0, 1.0)
    pred = y1 + t * (y2 - y1)
    eps_eff = int(np.ceil(np.max(np.abs(pred - np.arange(n, dtype=np.float64))))) + 1

    dt = sw.elapsed
    return RSModel(
        eps=eps,
        eps_eff=max(eps_eff, 1),
        knot_keys=jnp.asarray(knot_keys),
        knot_ranks=jnp.asarray(knot_ranks),
        radix_table=jnp.asarray(rt),
        kmin=jnp.asarray(np.uint64(kmin)),
        shift=shift,
        r_bits=r_bits,
        n=n,
        m=m,
        build_time=dt,
        name=f"RS[eps={eps},r={r_bits}]",
    )
