"""Static array-packed B+-tree baseline (paper §3.1, Classic Indexes).

Built bottom-up over the sorted table: each internal level holds the
first key of every fanout-F group of the level below, padded with the
max key.  Query: descend with a vectorised F-way fence compare per level
(cache-conscious CSS-tree style — the natural static B+-tree on a vector
machine), then a bounded branch-free search inside the final leaf block.

``build_btree`` backs the ``BTREE`` kind in :mod:`repro.index`; levels
are concatenated into one flat key array + offset table there.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from repro.obs.timing import stopwatch
from . import search
from .cdf import POS_DTYPE


@dataclass
class BTreeModel:
    fanout: int
    levels: list  # root-first list of jnp uint64 arrays, padded to F multiples
    valid: list  # real (non-pad) entry count per level
    n: int
    build_time: float = 0.0
    name: str = "BTree"

    def intervals(self, table, q):
        f = self.fanout
        if not self.levels:  # degenerate: table no larger than one block
            z = jnp.zeros(q.shape, dtype=POS_DTYPE)
            return z, z + (self.n - 1)
        node = jnp.zeros(q.shape, dtype=POS_DTYPE)  # node index at current level
        for keys, nv in zip(self.levels, self.valid):
            base = node * f
            fence = base[..., None] + jnp.arange(f, dtype=POS_DTYPE)
            v = jnp.take(keys, fence, mode="clip")
            child = jnp.sum((v <= q[..., None]).astype(POS_DTYPE), axis=-1)
            child = jnp.maximum(child - 1, 0)  # child i covers [key_i, key_{i+1})
            # clamp into the real entries: q == max-key pads otherwise
            # walk into padding and break the final block window
            node = jnp.minimum(base + child, nv - 1)
        node = jnp.minimum(node, (self.n + f - 1) // f - 1)
        lo = node * f
        hi = jnp.minimum(lo + f - 1, self.n - 1)
        lo = jnp.maximum(lo - 1, 0)  # predecessor may sit one block left
        return lo, hi

    @property
    def max_window(self) -> int:
        return min(self.fanout + 1, self.n)

    def predecessor(self, table, q):
        lo, hi = self.intervals(table, q)
        return search.bounded_bfs(table, q, lo, hi, max_window=self.max_window)

    def space_bytes(self) -> int:
        return sum(int(l.shape[0]) for l in self.levels) * 8 + 8


def build_btree(table_np: np.ndarray, fanout: int = 16) -> BTreeModel:
    sw = stopwatch()
    n = len(table_np)
    f = max(2, fanout)
    maxk = np.iinfo(np.uint64).max

    levels = []
    valid = []
    cur = table_np
    while len(cur) > f:
        first = cur[::f]
        n_groups = len(first)
        padded_len = ((n_groups + f - 1) // f) * f
        lvl = np.full(padded_len, maxk, dtype=np.uint64)
        lvl[:n_groups] = first
        levels.append(lvl)
        valid.append(n_groups)
        cur = first

    levels.reverse()  # root first (empty if the table fits in one block)
    valid.reverse()
    # NOTE: level l holds first-keys of groups of level l+1; the *leaf*
    # level's groups index directly into the table.
    dt = sw.elapsed
    return BTreeModel(
        fanout=f,
        levels=[jnp.asarray(l) for l in levels],
        valid=valid,
        n=n,
        build_time=dt,
        name=f"BTree[f={f}]",
    )
