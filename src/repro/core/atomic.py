"""Atomic models (paper §3.2, first class): L / Q / C regression of the CDF.

A single polynomial (degree 1, 2, 3) fit to the key->rank curve via least
squares — constant space.  The verified error bound is *exact*: we bound
the polynomial between consecutive keys through its critical points, so
the predicted window provably contains the predecessor (the paper relies
on empirically-measured max error; we tighten that to a guarantee so the
downstream bounded search never needs a fallback).

``build_atomic`` is the fitting backend of the ``L``/``Q``/``C`` kinds
in :mod:`repro.index`; the fitted coefficients become Index pytree
leaves there.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from repro.obs.timing import stopwatch
from . import search
from .cdf import POS_DTYPE


def poly_fit(u: np.ndarray, y: np.ndarray, degree: int) -> np.ndarray:
    """Least-squares polynomial fit, ascending coefficients, padded to 4."""
    # Vandermonde least squares in f64; np.polyfit returns descending.
    coef_desc = np.polyfit(u, y, degree)
    coef_asc = coef_desc[::-1]
    out = np.zeros(4, dtype=np.float64)
    out[: degree + 1] = coef_asc
    return out


def poly_eval_np(coef: np.ndarray, u: np.ndarray) -> np.ndarray:
    return ((coef[3] * u + coef[2]) * u + coef[1]) * u + coef[0]


def poly_eval_jnp(coef, u):
    return ((coef[..., 3] * u + coef[..., 2]) * u + coef[..., 1]) * u + coef[..., 0]


def poly_crit_points(coef: np.ndarray) -> np.ndarray:
    """Real roots of p' (ascending coef, padded-cubic) — where p can turn."""
    # p'(u) = c1 + 2 c2 u + 3 c3 u^2
    c1, c2, c3 = coef[1], 2.0 * coef[2], 3.0 * coef[3]
    if c3 != 0.0:
        disc = c2 * c2 - 4.0 * c3 * c1
        if disc < 0:
            return np.empty(0)
        s = np.sqrt(disc)
        return np.array([(-c2 - s) / (2 * c3), (-c2 + s) / (2 * c3)])
    if c2 != 0.0:
        return np.array([-c1 / c2])
    return np.empty(0)


def poly_exact_eps(
    coef: np.ndarray,
    u_keys: np.ndarray,
    ranks: np.ndarray,
    u_lo: float,
    u_hi: float,
) -> int:
    """Exact bound on max |p(x) - pred_rank(x)| for x in [u_lo, u_hi].

    Polynomial extremes between consecutive keys occur at interval
    endpoints or critical points of p; evaluating both and adding the
    rank-slack of 1 yields a guaranteed window half-width.
    """
    preds = poly_eval_np(coef, u_keys)
    eps_keys = float(np.max(np.abs(preds - ranks))) if len(ranks) else 0.0
    eps_crit = 0.0
    for uc in poly_crit_points(coef):
        if u_lo < uc < u_hi:
            j = int(np.searchsorted(u_keys, uc, side="right")) - 1
            j = min(max(j, 0), len(ranks) - 1)
            pc = float(poly_eval_np(coef, np.array([uc]))[0])
            nxt = ranks[j] + 1 if j + 1 < len(ranks) else ranks[j]
            eps_crit = max(eps_crit, abs(pc - ranks[j]), abs(pc - nxt))
    return int(np.ceil(max(eps_keys, eps_crit))) + 1


@dataclass
class AtomicModel:
    """L (degree=1) / Q (2) / C (3) regression over the whole table."""

    degree: int
    coef: jnp.ndarray  # (4,) f64 ascending
    kmin: jnp.ndarray  # scalar f64
    inv_span: jnp.ndarray  # scalar f64
    eps: int
    n: int
    build_time: float = 0.0
    name: str = field(default="")

    def intervals(self, table, q):
        u = (q.astype(jnp.float64) - self.kmin) * self.inv_span
        u = jnp.clip(u, 0.0, 1.0)  # out-of-domain queries clamp to the span
        p = jnp.clip(poly_eval_jnp(self.coef, u), -4.0e15, 4.0e15)
        lo = jnp.floor(p).astype(POS_DTYPE) - self.eps
        hi = jnp.ceil(p).astype(POS_DTYPE) + self.eps
        return jnp.clip(lo, 0, self.n - 1), jnp.clip(hi, 0, self.n - 1)

    @property
    def max_window(self) -> int:
        return min(2 * self.eps + 3, self.n)

    def predecessor(self, table, q):
        lo, hi = self.intervals(table, q)
        return search.bounded_bfs(table, q, lo, hi, max_window=self.max_window)

    def space_bytes(self) -> int:
        # coefficients actually used + kmin/span + eps: constant space.
        return 8 * (self.degree + 1) + 16 + 8


def build_atomic(table_np: np.ndarray, degree: int = 1) -> AtomicModel:
    sw = stopwatch()
    n = len(table_np)
    kmin, kmax = table_np[0], table_np[-1]
    span = np.float64(kmax - kmin)
    inv_span_np = np.float64(1.0) / span if span > 0 else np.float64(1.0)
    # same expression as the query path (multiply by reciprocal)
    u = (table_np.astype(np.float64) - np.float64(kmin)) * inv_span_np
    ranks = np.arange(n, dtype=np.float64)
    if n <= degree + 1:
        coef = np.zeros(4)
        coef[0] = 0.0
        coef[1] = float(n - 1) if n > 1 else 0.0
        eps = n
    else:
        coef = poly_fit(u, ranks, degree)
        eps = poly_exact_eps(coef, u, ranks, 0.0, 1.0)
    dt = sw.elapsed
    return AtomicModel(
        degree=degree,
        coef=jnp.asarray(coef),
        kmin=jnp.float64(np.float64(kmin)),
        inv_span=jnp.float64(inv_span_np),
        eps=int(min(eps, 1 << 40)),  # NEVER clip to n: the window math needs the true bound
        n=n,
        build_time=dt,
        name={1: "L", 2: "Q", 3: "C"}[degree],
    )
