"""PGM index (paper §3.2, class 4) — ε-controlled piecewise linear model.

Build: streaming anchored-cone greedy PLA (the FSW corridor — each
segment anchors at its first (key, rank) point and maintains the feasible
slope cone; a new segment starts when the cone empties).  The scan is
vectorised: per segment we grow a chunked window and locate the first
cone violation with running max/min, so total work is O(n) numpy with a
Python loop only over *segments*.  Levels recurse bottom-up over segment
first-keys until one segment remains, exactly as in Ferragina &
Vinciguerra's PGM.

Query: top-down; at each level the prediction is refined with an exact
bounded branch-free search of width 2(ε+1)+1 over that level's keys.

``build_pgm_bicriteria`` implements the paper's PGM_M_a: given a space
budget, bisect ε in [ε_m, ε_M] with ε_m = a · 2 · cls/size (cls
re-derived for the TPU gather granularity, see DESIGN.md §7).

``build_pgm`` / ``build_pgm_bicriteria`` back the ``PGM`` / ``PGM_M``
kinds in :mod:`repro.index`; levels are concatenated into flat padded
arrays there so same-shape models share one jitted query trace.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np
import jax.numpy as jnp

from repro.obs.timing import stopwatch
from . import search
from .cdf import (
    POS_DTYPE,
    blocked_corridor_scan,
    ceil_log2,
    chunked_corridor_scan,
    segment_ids,
)

_CHUNK = 4096

#: Block size of the device scan fit (``pgm_segments_scan``): the outer
#: ``lax.scan`` streams the table in blocks of this many keys.
SCAN_CHUNK = 128

#: Block size of the O(log n)-depth fast fit (``pgm_fit_fast``): keys are
#: fit greedily inside vmapped blocks of this many elements, then block
#: boundaries are merged away with associative passes.
FAST_CHUNK = 256


def pla_segments(keys_f64: np.ndarray, eps: int):
    """Anchored-cone greedy ε-PLA over (key, rank) pairs.

    Returns (starts, slopes): segment start indices (int64) and slopes
    (f64, >= 0) such that for every i in segment s,
    |rank_start[s] + slope[s] * (x_i - x_start[s]) - i| <= eps.
    """
    n = len(keys_f64)
    starts: List[int] = []
    slopes: List[float] = []
    s = 0
    while s < n:
        starts.append(s)
        x0 = keys_f64[s]
        lo, hi = 0.0, np.inf
        e = s + 1
        # grow in chunks, tracking the running cone
        while e < n:
            e2 = min(e + _CHUNK, n)
            dx = keys_f64[e:e2] - x0  # > 0: keys dedup'd
            dy = np.arange(e, e2, dtype=np.float64) - s
            hi_run = np.minimum.accumulate((dy + eps) / dx)
            lo_run = np.maximum.accumulate((dy - eps) / dx)
            hi_run = np.minimum(hi_run, hi)
            lo_run = np.maximum(lo_run, lo)
            bad = lo_run > hi_run
            if bad.any():
                k = int(np.argmax(bad))
                if k > 0:
                    lo = float(lo_run[k - 1])
                    hi = float(hi_run[k - 1])
                e = e + k
                break
            lo = float(lo_run[-1])
            hi = float(hi_run[-1])
            e = e2
        if e == s + 1:  # single-point segment
            slopes.append(max(lo, 0.0) if np.isfinite(lo) else 0.0)
            s = e
            continue
        hi_f = hi if np.isfinite(hi) else max(lo, 0.0) + 1.0
        slopes.append(max(0.5 * (max(lo, 0.0) + max(hi_f, 0.0)), 0.0))
        s = e
    return np.asarray(starts, dtype=np.int64), np.asarray(slopes, dtype=np.float64)


def pgm_segments_scan(keys_f64, eps, *, chunk: int = SCAN_CHUNK, count=None):
    """Array-native anchored-cone greedy ε-PLA: the device form of
    :func:`pla_segments`, as a chunked ``lax.scan`` over the running
    min/max corridor.

    Returns an ``(n,)`` bool mask, True exactly at the segment start
    indices :func:`pla_segments` emits — the carry is the (anchor key,
    anchor rank, cone lo, cone hi) state the numpy build threads through
    its chunk loop, updated one key at a time with identical f64
    arithmetic (min/max are exact, so the chunked accumulation order
    cannot diverge).  ``eps`` may be a traced scalar, which is what lets
    a whole batch of (table, ε) pairs share ONE jitted trace under
    ``vmap`` (:func:`repro.tune.batched.build_many` with
    ``fit="vmap"``).  Slopes are host-side O(n) vectorised work over the
    mask (:func:`segment_slopes`); the upper PGM levels recurse on the
    ~n/2ε segment keys and stay host-side, like the RMI root fit.
    """
    keys = jnp.asarray(keys_f64, dtype=jnp.float64)
    n = keys.shape[0]
    eps = jnp.asarray(eps, dtype=jnp.float64)
    ranks = jnp.arange(n, dtype=jnp.float64)
    step, init = _pgm_corridor_step(eps)
    return chunked_corridor_scan(step, init, (keys, ranks), n, chunk, count=count)


def _pgm_corridor_step(eps):
    """(step, init) of the anchored-cone recurrence, shared by the exact
    chunked scan and the blocked fast fit.  ``init`` uses ``s = -1`` as
    the no-anchor sentinel, so the first valid element always flags."""

    def step(carry, inp):
        x0, s, lo, hi = carry
        x, r, v = inp
        dx = x - x0
        dy = r - s
        new_lo = jnp.maximum(lo, (dy - eps) / dx)
        new_hi = jnp.minimum(hi, (dy + eps) / dx)
        # s < 0: no anchor yet — the first valid key starts segment 0
        bad = (new_lo > new_hi) | (s < 0.0)
        nxt = (
            jnp.where(bad, x, x0),
            jnp.where(bad, r, s),
            jnp.where(bad, 0.0, new_lo),
            jnp.where(bad, jnp.inf, new_hi),
        )
        carry = tuple(jnp.where(v, a, b) for a, b in zip(nxt, carry))
        return carry, bad & v

    init = (jnp.float64(0.0), jnp.float64(-1.0), jnp.float64(0.0), jnp.float64(jnp.inf))
    return step, init


def _pgm_merge_round(keys, ranks, mask, eps, count=None):
    """One parity merge round: re-test every odd-id segment against its
    even left neighbour's *anchor* cone (exact corridor feasibility over
    the union) and drop the odd boundary where the merged cone is
    non-empty.  All reductions are associative-scan / segment ops —
    O(log n) depth.  Chains of k mergeable segments collapse in
    ceil(log2 k) rounds because ids re-densify between rounds.
    Elements at positions >= ``count`` (traced live prefix, capacity
    builds) contribute identity bounds."""
    import jax

    n = keys.shape[0]
    idx = jnp.arange(n, dtype=POS_DTYPE)
    seg, start = segment_ids(mask)
    pair = seg // 2
    a_pos = jnp.take(start, 2 * pair)
    xa = jnp.take(keys, a_pos)
    dy = ranks - a_pos.astype(jnp.float64)
    dx = keys - xa
    anchor = idx == a_pos
    lo_b = jnp.where(anchor, -jnp.inf, (dy - eps) / dx)
    hi_b = jnp.where(anchor, jnp.inf, (dy + eps) / dx)
    if count is not None:
        live = idx < count
        lo_b = jnp.where(live, lo_b, -jnp.inf)
        hi_b = jnp.where(live, hi_b, jnp.inf)
    lo = jax.ops.segment_max(lo_b, pair, num_segments=n, indices_are_sorted=True)
    hi = jax.ops.segment_min(hi_b, pair, num_segments=n, indices_are_sorted=True)
    # NaN bounds (colliding f64 keys) compare False -> merge vetoed.
    ok_pair = lo <= hi
    drop = mask & ((seg % 2) == 1) & jnp.take(ok_pair, pair)
    return mask & ~drop


def pgm_device_slopes(keys, mask, eps, count=None):
    """Device counterpart of :func:`segment_slopes` over a start mask.

    Returns ``(slopes, start, seg)``: per-segment slopes at capacity
    ``n`` (entries past the live segment count are unused), the segment
    start index array, and the per-element segment id.  Exact min/max
    segment reductions reproduce ``np.minimum.reduceat`` bit-for-bit,
    so a mask produced by the exact scan fit yields byte-identical
    slopes to the host assembly.

    Example::

        mask, ok = pgm_fit_fast(keys_f64, eps=16)
        slopes, start, seg = pgm_device_slopes(jnp.asarray(keys_f64), mask, 16.0)
    """
    import jax

    keys = jnp.asarray(keys, dtype=jnp.float64)
    n = keys.shape[0]
    eps = jnp.asarray(eps, dtype=jnp.float64)
    idx = jnp.arange(n, dtype=POS_DTYPE)
    seg, start = segment_ids(mask)
    a_pos = jnp.take(start, seg)
    dy = idx.astype(jnp.float64) - a_pos.astype(jnp.float64)
    dx = keys - jnp.take(keys, a_pos)
    anchor = idx == a_pos
    lo_b = jnp.where(anchor, -jnp.inf, (dy - eps) / dx)
    hi_b = jnp.where(anchor, jnp.inf, (dy + eps) / dx)
    ones = jnp.ones((n,), dtype=POS_DTYPE)
    if count is not None:
        live = idx < count
        lo_b = jnp.where(live, lo_b, -jnp.inf)
        hi_b = jnp.where(live, hi_b, jnp.inf)
        ones = jnp.where(live, ones, 0)
    lo = jax.ops.segment_max(lo_b, seg, num_segments=n, indices_are_sorted=True)
    hi = jax.ops.segment_min(hi_b, seg, num_segments=n, indices_are_sorted=True)
    length = jax.ops.segment_sum(
        ones, seg, num_segments=n, indices_are_sorted=True
    )
    hi_f = jnp.where(jnp.isfinite(hi), hi, jnp.maximum(lo, 0.0) + 1.0)
    slopes = jnp.maximum(0.5 * (jnp.maximum(lo, 0.0) + jnp.maximum(hi_f, 0.0)), 0.0)
    slopes = jnp.where(length == 1, 0.0, slopes)
    return slopes, start, seg


def pgm_verified_eps(keys, mask, eps, count=None):
    """Measured max |prediction - rank| of the PLA induced by ``mask``,
    on device (the verified-ε re-measure backing ``fit="fast"``).  NaN
    propagates (and compares False against any bound), so degenerate
    fits always fail the ``measured <= eps`` check and fall back."""
    keys = jnp.asarray(keys, dtype=jnp.float64)
    n = keys.shape[0]
    slopes, start, seg = pgm_device_slopes(keys, mask, eps, count=count)
    a_pos = jnp.take(start, seg)
    pred = a_pos.astype(jnp.float64) + jnp.take(slopes, seg) * (
        keys - jnp.take(keys, a_pos)
    )
    err = jnp.abs(pred - jnp.arange(n, dtype=jnp.float64))
    if count is not None:
        err = jnp.where(jnp.arange(n, dtype=POS_DTYPE) < count, err, 0.0)
    return jnp.max(err)


def pgm_fit_fast(keys_f64, eps, *, chunk: int = FAST_CHUNK, rounds=None, count=None):
    """O(log n)-depth ε-PLA fit: the ``fit="fast"`` PGM entry point.

    Blocked vmapped greedy (exact corridor inside ``chunk``-sized
    blocks, every block re-anchored at its boundary) followed by
    associative parity merge rounds that collapse the spurious block
    boundaries, then a device verified-ε re-measure.  The result is a
    *valid* ε-PLA — every segment satisfies the corridor invariant —
    but segment boundaries are NOT bit-identical to the greedy's
    (typically a few % extra segments on curvy data).  Compiled
    sequential depth is O(chunk + log² n), constant in the table size,
    vs O(n / SCAN_CHUNK) for :func:`pgm_segments_scan`.

    Returns ``(mask, ok)``: the boolean segment-start mask and a scalar
    device bool — ``ok`` is False when the measured error exceeds
    ``eps`` (degenerate f64 key collisions), in which case callers fall
    back to the exact scan fit (:mod:`repro.tune.batched` does this
    lazily on host).

    Example::

        mask, ok = pgm_fit_fast(table.astype(np.float64), eps=32)
        starts = np.flatnonzero(np.asarray(mask))  # valid ε-PLA starts
    """
    keys = jnp.asarray(keys_f64, dtype=jnp.float64)
    n = keys.shape[0]
    eps = jnp.asarray(eps, dtype=jnp.float64)
    ranks = jnp.arange(n, dtype=jnp.float64)
    step, init = _pgm_corridor_step(eps)
    mask = blocked_corridor_scan(
        step, lambda first: init, (keys, ranks), n, chunk, count=count
    )
    nblocks = -(-n // max(int(chunk), 1))
    r = int(rounds) if rounds is not None else ceil_log2(max(nblocks, 2)) + 1
    for _ in range(r):
        mask = _pgm_merge_round(keys, ranks, mask, eps, count=count)
    ok = pgm_verified_eps(keys, mask, eps, count=count) <= eps
    return mask, ok


def segment_slopes(keys_f64: np.ndarray, starts: np.ndarray, eps) -> np.ndarray:
    """Slopes for given segment ``starts`` — bit-identical to the ones
    :func:`pla_segments` pairs with them.

    The final cone of segment ``[s, e)`` is the min/max of the per-key
    slope bounds over its non-anchor keys; min/max reductions are exact
    in f64, so ``np.minimum.reduceat`` reproduces the running chunked
    accumulation bit-for-bit (the anchor key contributes ``∓inf`` —
    identity elements — and single-key segments take the host's fresh
    cone ``lo = 0``, giving slope 0).
    """
    keys_f64 = np.asarray(keys_f64, dtype=np.float64)
    starts = np.asarray(starts, dtype=np.int64)
    n = len(keys_f64)
    eps = np.float64(eps)
    lens = np.diff(np.append(starts, n))
    seg_of = np.repeat(np.arange(len(starts)), lens)
    dx = keys_f64 - keys_f64[starts[seg_of]]
    dy = np.arange(n, dtype=np.float64) - starts[seg_of].astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        lo_b = (dy - eps) / dx
        hi_b = (dy + eps) / dx
    lo = np.maximum.reduceat(lo_b, starts)
    hi = np.minimum.reduceat(hi_b, starts)
    hi_f = np.where(np.isfinite(hi), hi, np.maximum(lo, 0.0) + 1.0)
    slopes = np.maximum(0.5 * (np.maximum(lo, 0.0) + np.maximum(hi_f, 0.0)), 0.0)
    return np.where(lens == 1, 0.0, slopes)


@dataclass
class PGMModel:
    eps: int
    # levels stored root-first; level arrays concatenated
    level_keys: list  # list of jnp uint64 arrays, root..leaf-level
    level_slope: list  # list of jnp f64
    level_rank0: list  # list of jnp int64 (start rank of each segment)
    level_sizes: list  # python ints: #segments per level
    n: int
    n_segments_l0: int
    build_time: float = 0.0
    name: str = "PGM"

    def intervals(self, table, q):
        """Predicted window in the table for each query."""
        eps = self.eps
        qf = q.astype(jnp.float64)
        # descend levels: maintain current segment index per query
        seg = jnp.zeros(q.shape, dtype=POS_DTYPE)
        for lvl in range(len(self.level_keys)):
            keys = self.level_keys[lvl]
            slope = self.level_slope[lvl]
            rank0 = self.level_rank0[lvl]  # (size+1,) incl. sentinel
            x0 = jnp.take(keys, seg).astype(jnp.float64)
            a = jnp.take(slope, seg)
            r0 = jnp.take(rank0, seg)
            pred = r0.astype(jnp.float64) + a * jnp.maximum(qf - x0, 0.0)
            pred = jnp.clip(pred, -1.0, 4.0e15)  # overflow-safe int cast
            # segment s of this level covers entries [r0[s], r0[s+1]) of
            # the next level, so the predecessor entry is guaranteed in
            # [r0[s]-1, r0[s+1]-1]: clamp the window into that range
            # (kills gap-extrapolation blow-ups).
            b_lo = jnp.maximum(r0 - 1, 0)
            b_hi = jnp.take(rank0, seg + 1) - 1
            lo = jnp.clip(jnp.floor(pred).astype(POS_DTYPE) - (eps + 1), b_lo, b_hi)
            hi = jnp.clip(jnp.ceil(pred).astype(POS_DTYPE) + (eps + 1), b_lo, b_hi)
            if lvl + 1 < len(self.level_keys):
                nxt = self.level_keys[lvl + 1]
                nxt_n = self.level_sizes[lvl + 1]
                length = jnp.maximum(hi - lo + 1, 1)
                ub = search.bounded_upper_bound(
                    nxt, q, lo, length, steps=search.ceil_log2(2 * (eps + 2) + 3)
                )
                seg = jnp.clip(ub - 1, 0, nxt_n - 1)
            else:
                return jnp.clip(lo, 0, self.n - 1), jnp.clip(hi, 0, self.n - 1)
        raise AssertionError("unreachable")

    @property
    def max_window(self) -> int:
        return min(2 * (self.eps + 2) + 3, self.n)

    def predecessor(self, table, q):
        lo, hi = self.intervals(table, q)
        return search.bounded_bfs(table, q, lo, hi, max_window=self.max_window)

    def space_bytes(self) -> int:
        # key (8) + slope (8) + rank0 (8) per segment, all levels.
        return sum(self.level_sizes) * 24 + 16


def build_pgm(table_np: np.ndarray, eps: int = 64, *, l0=None) -> PGMModel:
    """Recursive PGM build.  ``l0`` optionally supplies the bottom
    level's ``(starts, slopes)`` — e.g. from the device scan fit
    (:func:`pgm_segments_scan` + :func:`segment_slopes`); the upper
    levels always recurse host-side over the segment first-keys."""
    sw = stopwatch()
    n = len(table_np)
    eps = max(int(eps), 1)

    keys = table_np.astype(np.float64)
    level_keys, level_slope, level_rank0, level_sizes = [], [], [], []

    cur_keys_u64 = table_np
    cur_keys = keys
    while True:
        if l0 is not None:
            starts, slopes = l0
            l0 = None
        else:
            starts, slopes = pla_segments(cur_keys, eps)
        # rank0 with sentinel: segment s covers [rank0[s], rank0[s+1])
        rank0 = np.concatenate([starts, [len(cur_keys)]]).astype(np.int64)
        level_keys.append(jnp.asarray(cur_keys_u64[starts]))
        level_slope.append(jnp.asarray(slopes))
        level_rank0.append(jnp.asarray(rank0))
        level_sizes.append(len(starts))
        if len(starts) <= 1:
            break
        cur_keys_u64 = cur_keys_u64[starts]
        cur_keys = cur_keys[starts]

    # root-first ordering
    level_keys.reverse()
    level_slope.reverse()
    level_rank0.reverse()
    level_sizes.reverse()

    dt = sw.elapsed
    return PGMModel(
        eps=eps,
        level_keys=level_keys,
        level_slope=level_slope,
        level_rank0=level_rank0,
        level_sizes=level_sizes,
        n=n,
        n_segments_l0=level_sizes[-1],
        build_time=dt,
        name=f"PGM[eps={eps}]",
    )


# TPU gather granularity stands in for the cache line (DESIGN.md §7):
# one VREG row of 64 keys x 8 B = 512 B vs the paper's cls = 64 B.
TPU_CLS_BYTES = 512
KEY_BYTES = 8

#: Bisection depth of the bi-criteria search (shared by the host build
#: and the batched lockstep fit, which must take identical decisions).
BICRITERIA_MAX_ITERS = 16


def bicriteria_eps_bounds(n: int, a: float = 1.0, cls_bytes: int = TPU_CLS_BYTES) -> tuple:
    """The bi-criteria search range [ε_m, ε_M] for a table of ``n`` keys
    (paper: ε_m = a · 2 · cls/size).  Single source of truth — the
    batched scan fit re-derives the host bisection from these bounds,
    and drift here would silently break their bit-exactness contract."""
    eps_m = max(1, int(a * 2 * (cls_bytes / KEY_BYTES)))
    return eps_m, max(eps_m + 1, n // 2)


def build_pgm_bicriteria(
    table_np: np.ndarray,
    space_budget_bytes: int,
    a: float = 1.0,
    cls_bytes: int = TPU_CLS_BYTES,
    max_iters: int = BICRITERIA_MAX_ITERS,
) -> PGMModel:
    """Bi-criteria PGM_M_a: smallest ε whose model fits the budget."""
    eps_m, eps_M = bicriteria_eps_bounds(len(table_np), a, cls_bytes)

    best = None
    lo, hi = eps_m, eps_M
    for _ in range(max_iters):
        mid = (lo + hi) // 2
        m = build_pgm(table_np, eps=mid)
        if m.space_bytes() <= space_budget_bytes:
            best = m if best is None or m.eps < best.eps else best
            hi = mid - 1  # try smaller eps (bigger model)
        else:
            lo = mid + 1
        if lo > hi:
            break
    if best is None:
        best = build_pgm(table_np, eps=eps_M)
    best.name = f"PGM_M_{a}[eps={best.eps}]"
    return best
