"""PGM index (paper §3.2, class 4) — ε-controlled piecewise linear model.

Build: streaming anchored-cone greedy PLA (the FSW corridor — each
segment anchors at its first (key, rank) point and maintains the feasible
slope cone; a new segment starts when the cone empties).  The scan is
vectorised: per segment we grow a chunked window and locate the first
cone violation with running max/min, so total work is O(n) numpy with a
Python loop only over *segments*.  Levels recurse bottom-up over segment
first-keys until one segment remains, exactly as in Ferragina &
Vinciguerra's PGM.

Query: top-down; at each level the prediction is refined with an exact
bounded branch-free search of width 2(ε+1)+1 over that level's keys.

``build_pgm_bicriteria`` implements the paper's PGM_M_a: given a space
budget, bisect ε in [ε_m, ε_M] with ε_m = a · 2 · cls/size (cls
re-derived for the TPU gather granularity, see DESIGN.md §7).

``build_pgm`` / ``build_pgm_bicriteria`` back the ``PGM`` / ``PGM_M``
kinds in :mod:`repro.index`; levels are concatenated into flat padded
arrays there so same-shape models share one jitted query trace.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List

import numpy as np
import jax.numpy as jnp

from . import search
from .cdf import POS_DTYPE

_CHUNK = 4096


def pla_segments(keys_f64: np.ndarray, eps: int):
    """Anchored-cone greedy ε-PLA over (key, rank) pairs.

    Returns (starts, slopes): segment start indices (int64) and slopes
    (f64, >= 0) such that for every i in segment s,
    |rank_start[s] + slope[s] * (x_i - x_start[s]) - i| <= eps.
    """
    n = len(keys_f64)
    starts: List[int] = []
    slopes: List[float] = []
    s = 0
    while s < n:
        starts.append(s)
        x0 = keys_f64[s]
        lo, hi = 0.0, np.inf
        e = s + 1
        # grow in chunks, tracking the running cone
        while e < n:
            e2 = min(e + _CHUNK, n)
            dx = keys_f64[e:e2] - x0  # > 0: keys dedup'd
            dy = np.arange(e, e2, dtype=np.float64) - s
            hi_run = np.minimum.accumulate((dy + eps) / dx)
            lo_run = np.maximum.accumulate((dy - eps) / dx)
            hi_run = np.minimum(hi_run, hi)
            lo_run = np.maximum(lo_run, lo)
            bad = lo_run > hi_run
            if bad.any():
                k = int(np.argmax(bad))
                if k > 0:
                    lo = float(lo_run[k - 1])
                    hi = float(hi_run[k - 1])
                e = e + k
                break
            lo = float(lo_run[-1])
            hi = float(hi_run[-1])
            e = e2
        if e == s + 1:  # single-point segment
            slopes.append(max(lo, 0.0) if np.isfinite(lo) else 0.0)
            s = e
            continue
        hi_f = hi if np.isfinite(hi) else max(lo, 0.0) + 1.0
        slopes.append(max(0.5 * (max(lo, 0.0) + max(hi_f, 0.0)), 0.0))
        s = e
    return np.asarray(starts, dtype=np.int64), np.asarray(slopes, dtype=np.float64)


@dataclass
class PGMModel:
    eps: int
    # levels stored root-first; level arrays concatenated
    level_keys: list  # list of jnp uint64 arrays, root..leaf-level
    level_slope: list  # list of jnp f64
    level_rank0: list  # list of jnp int64 (start rank of each segment)
    level_sizes: list  # python ints: #segments per level
    n: int
    n_segments_l0: int
    build_time: float = 0.0
    name: str = "PGM"

    def intervals(self, table, q):
        """Predicted window in the table for each query."""
        eps = self.eps
        qf = q.astype(jnp.float64)
        # descend levels: maintain current segment index per query
        seg = jnp.zeros(q.shape, dtype=POS_DTYPE)
        for lvl in range(len(self.level_keys)):
            keys = self.level_keys[lvl]
            slope = self.level_slope[lvl]
            rank0 = self.level_rank0[lvl]  # (size+1,) incl. sentinel
            x0 = jnp.take(keys, seg).astype(jnp.float64)
            a = jnp.take(slope, seg)
            r0 = jnp.take(rank0, seg)
            pred = r0.astype(jnp.float64) + a * jnp.maximum(qf - x0, 0.0)
            pred = jnp.clip(pred, -1.0, 4.0e15)  # overflow-safe int cast
            # segment s of this level covers entries [r0[s], r0[s+1]) of
            # the next level, so the predecessor entry is guaranteed in
            # [r0[s]-1, r0[s+1]-1]: clamp the window into that range
            # (kills gap-extrapolation blow-ups).
            b_lo = jnp.maximum(r0 - 1, 0)
            b_hi = jnp.take(rank0, seg + 1) - 1
            lo = jnp.clip(jnp.floor(pred).astype(POS_DTYPE) - (eps + 1), b_lo, b_hi)
            hi = jnp.clip(jnp.ceil(pred).astype(POS_DTYPE) + (eps + 1), b_lo, b_hi)
            if lvl + 1 < len(self.level_keys):
                nxt = self.level_keys[lvl + 1]
                nxt_n = self.level_sizes[lvl + 1]
                length = jnp.maximum(hi - lo + 1, 1)
                ub = search.bounded_upper_bound(
                    nxt, q, lo, length, steps=search.ceil_log2(2 * (eps + 2) + 3)
                )
                seg = jnp.clip(ub - 1, 0, nxt_n - 1)
            else:
                return jnp.clip(lo, 0, self.n - 1), jnp.clip(hi, 0, self.n - 1)
        raise AssertionError("unreachable")

    @property
    def max_window(self) -> int:
        return min(2 * (self.eps + 2) + 3, self.n)

    def predecessor(self, table, q):
        lo, hi = self.intervals(table, q)
        return search.bounded_bfs(table, q, lo, hi, max_window=self.max_window)

    def space_bytes(self) -> int:
        # key (8) + slope (8) + rank0 (8) per segment, all levels.
        return sum(self.level_sizes) * 24 + 16


def build_pgm(table_np: np.ndarray, eps: int = 64) -> PGMModel:
    t0 = time.perf_counter()
    n = len(table_np)
    eps = max(int(eps), 1)

    keys = table_np.astype(np.float64)
    level_keys, level_slope, level_rank0, level_sizes = [], [], [], []

    cur_keys_u64 = table_np
    cur_keys = keys
    while True:
        starts, slopes = pla_segments(cur_keys, eps)
        # rank0 with sentinel: segment s covers [rank0[s], rank0[s+1])
        rank0 = np.concatenate([starts, [len(cur_keys)]]).astype(np.int64)
        level_keys.append(jnp.asarray(cur_keys_u64[starts]))
        level_slope.append(jnp.asarray(slopes))
        level_rank0.append(jnp.asarray(rank0))
        level_sizes.append(len(starts))
        if len(starts) <= 1:
            break
        cur_keys_u64 = cur_keys_u64[starts]
        cur_keys = cur_keys[starts]

    # root-first ordering
    level_keys.reverse()
    level_slope.reverse()
    level_rank0.reverse()
    level_sizes.reverse()

    dt = time.perf_counter() - t0
    return PGMModel(
        eps=eps,
        level_keys=level_keys,
        level_slope=level_slope,
        level_rank0=level_rank0,
        level_sizes=level_sizes,
        n=n,
        n_segments_l0=level_sizes[-1],
        build_time=dt,
        name=f"PGM[eps={eps}]",
    )


# TPU gather granularity stands in for the cache line (DESIGN.md §7):
# one VREG row of 64 keys x 8 B = 512 B vs the paper's cls = 64 B.
TPU_CLS_BYTES = 512
KEY_BYTES = 8


def build_pgm_bicriteria(
    table_np: np.ndarray,
    space_budget_bytes: int,
    a: float = 1.0,
    cls_bytes: int = TPU_CLS_BYTES,
    max_iters: int = 16,
) -> PGMModel:
    """Bi-criteria PGM_M_a: smallest ε whose model fits the budget."""
    eps_m = max(1, int(a * 2 * (cls_bytes / KEY_BYTES)))
    eps_M = max(eps_m + 1, len(table_np) // 2)

    best = None
    lo, hi = eps_m, eps_M
    for _ in range(max_iters):
        mid = (lo + hi) // 2
        m = build_pgm(table_np, eps=mid)
        if m.space_bytes() <= space_budget_bytes:
            best = m if best is None or m.eps < best.eps else best
            hi = mid - 1  # try smaller eps (bigger model)
        else:
            lo = mid + 1
        if lo > hi:
            break
    if best is None:
        best = build_pgm(table_np, eps=eps_M)
    best.name = f"PGM_M_{a}[eps={best.eps}]"
    return best
