"""Two-level RMI with parametric branching factor (paper §3.2, class 3).

Root model (monotone: linear regression, endpoint spline, or cubic with a
monotonicity check + linear fallback) partitions the *universe*; ``b``
linear leaf models predict the rank.  Build is a single O(n) pass after
the root fit.  Per-leaf error bounds are computed over the leaf's rank
range extended by one key on each side and leaf slopes are clamped >= 0,
which (with a monotone root) makes the predicted window a *guarantee* —
see DESIGN.md §3.

``build_rmi`` backs the ``RMI`` and ``SY-RMI`` kinds in
:mod:`repro.index`; the leaf arrays (and their f32 kernel re-encoding)
become Index pytree leaves there.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs.timing import stopwatch
from . import search
from .atomic import poly_fit, poly_eval_jnp, poly_eval_np
from .cdf import POS_DTYPE

ROOT_TYPES = ("linear", "cubic", "spline")


@dataclass
class RMIModel:
    root_type: str
    root_coef: jnp.ndarray  # (4,) f64, predicts rank from u
    b: int
    leaf_slope: jnp.ndarray  # (b,) f64 — rank per unit u
    leaf_icept: jnp.ndarray  # (b,) f64
    leaf_eps: jnp.ndarray  # (b,) int64
    leaf_r: jnp.ndarray  # (b+1,) int64 — first rank per leaf (guarantee clamp)
    kmin: jnp.ndarray
    inv_span: jnp.ndarray
    max_eps: int
    max_window_: int
    n: int
    build_time: float = 0.0
    name: str = "RMI"

    def _leaf_of(self, u):
        p = jnp.clip(poly_eval_jnp(self.root_coef, u), -4.0e15, 4.0e15)
        leaf = jnp.floor(p * (self.b / self.n)).astype(POS_DTYPE)
        return jnp.clip(leaf, 0, self.b - 1)

    def intervals(self, table, q):
        u = (q.astype(jnp.float64) - self.kmin) * self.inv_span
        u = jnp.clip(u, 0.0, 1.0)
        leaf = self._leaf_of(u)
        slope = jnp.take(self.leaf_slope, leaf)
        icept = jnp.take(self.leaf_icept, leaf)
        eps = jnp.take(self.leaf_eps, leaf)
        p = jnp.clip(slope * u + icept, -4.0e15, 4.0e15)
        lo = jnp.floor(p).astype(POS_DTYPE) - eps
        hi = jnp.ceil(p).astype(POS_DTYPE) + eps
        # Monotone root proves pred in [r_l - 1, r_{l+1} - 1]: clamp the
        # window into that range (survives leaf-model blow-ups on gaps).
        # High fence is r_{l+1}, NOT r_{l+1} - 1: XLA may evaluate the
        # root polynomial within 1 ulp of the build-time NumPy value,
        # flipping floor() at a leaf boundary; the extended eps already
        # covers the boundary key, so the fence must not cut it off.
        b_lo = jnp.maximum(jnp.take(self.leaf_r, leaf) - 1, 0)
        b_hi = jnp.minimum(jnp.take(self.leaf_r, leaf + 1), self.n - 1)
        lo = jnp.clip(lo, b_lo, b_hi)
        hi = jnp.clip(hi, b_lo, b_hi)
        return lo, hi

    @property
    def max_window(self) -> int:
        return max(self.max_window_, 1)

    def predecessor(self, table, q):
        lo, hi = self.intervals(table, q)
        return search.bounded_bfs(table, q, lo, hi, max_window=self.max_window)

    def space_bytes(self) -> int:
        # slope + intercept (f64) + eps (i32) + rank fence (i64) per leaf
        # (the fence backs the correctness guarantee), + root.
        return self.b * (8 + 8 + 4 + 8) + 32 + 24


def rmi_leaf_fit(u, root_coef, b: int):
    """Array-native leaf fit: the jittable/vmappable core of ``build_rmi``.

    Given the normalised keys ``u`` (f64, sorted) and a fitted monotone
    root polynomial, performs the whole leaf stage on device — leaf
    assignment, per-leaf least-squares via segment sums, extended-window
    error bounds — mirroring the NumPy pipeline in :func:`build_rmi`
    op-for-op.  ``vmap`` over ``(u, root_coef)`` builds many same-shape
    RMIs in ONE trace (the batched-build path of :mod:`repro.tune`).

    Floats can differ from the host fit by a few ulp (XLA scatter-add
    reduction order vs ``np.bincount``'s sequential sums), but the error
    bounds are measured against *this* fit's own predictions with the
    same arithmetic the query path uses, so the predicted windows remain
    guarantees and predecessor ranks are bit-identical either way.

    Returns ``(slopes, icepts, eps, r)`` with shapes ``(b,)``/``(b+1,)``.
    """
    n = u.shape[0]
    ranks = jnp.arange(n, dtype=jnp.float64)
    p = poly_eval_jnp(root_coef, u)
    leaf_of = jnp.clip(jnp.floor(p * (b / n)), 0, b - 1).astype(jnp.int64)
    seg = jax.lax.cummax(leaf_of, axis=0)  # enforce monotone against fp jitter
    r = jnp.searchsorted(seg, jnp.arange(b + 1, dtype=jnp.int64), side="left").astype(jnp.int64)
    # vectorised per-leaf linear fits via segment sums (one scatter-add each)
    z = jnp.zeros(b, dtype=jnp.float64)
    cnt = z.at[seg].add(1.0)
    su = z.at[seg].add(u)
    sr = z.at[seg].add(ranks)
    suu = z.at[seg].add(u * u)
    sur = z.at[seg].add(u * ranks)
    var = cnt * suu - su * su
    cov = cnt * sur - su * sr
    nz = (cnt > 1) & (var > 1e-30)
    slopes = jnp.where(nz, jnp.maximum(cov / jnp.where(nz, var, 1.0), 0.0), 0.0)
    icepts = jnp.where(nz, (sr - slopes * su) / jnp.where(nz, cnt, 1.0), 0.0)
    icepts = jnp.where(cnt == 1, sr, icepts)
    icepts = jnp.where(cnt == 0, r[:-1].astype(jnp.float64), icepts)  # predict range start
    # per-leaf eps over rank range extended by one key each side
    pred = slopes[seg] * u + icepts[seg]
    eps_core = z.at[seg].max(jnp.abs(pred - ranks))
    lo_idx = jnp.clip(r[:-1] - 1, 0, n - 1)
    hi_idx = jnp.clip(r[1:], 0, n - 1)
    err_lo = jnp.abs(slopes * u[lo_idx] + icepts - ranks[lo_idx])
    err_hi = jnp.abs(slopes * u[hi_idx] + icepts - ranks[hi_idx])
    eps_f = jnp.maximum(eps_core, jnp.maximum(err_lo, err_hi))
    eps = jnp.ceil(jnp.minimum(eps_f, float(1 << 40))).astype(jnp.int64) + 1
    return slopes, icepts, eps, r


def fit_root(table_np: np.ndarray, root_type: str) -> tuple:
    """Host root fit of ``build_rmi`` exposed for the batched builder.

    Returns ``(root_coef, kmin, inv_span)`` — everything the array-native
    leaf stage (:func:`rmi_leaf_fit`) needs.
    """
    n = len(table_np)
    kmin, kmax = table_np[0], table_np[-1]
    span = np.float64(kmax - kmin)
    inv_span = np.float64(1.0) / span if span > 0 else np.float64(1.0)
    u = (table_np.astype(np.float64) - np.float64(kmin)) * inv_span
    ranks = np.arange(n, dtype=np.float64)
    return _fit_root(u, ranks, root_type), np.float64(kmin), inv_span


def assemble_rmi(
    table_np: np.ndarray,
    root_type: str,
    root_coef: np.ndarray,
    kmin: np.float64,
    inv_span: np.float64,
    slopes: np.ndarray,
    icepts: np.ndarray,
    eps: np.ndarray,
    r: np.ndarray,
    build_time: float = 0.0,
) -> RMIModel:
    """Assemble an :class:`RMIModel` from leaf-fit arrays (batched path)."""
    b = len(slopes)
    width = np.diff(r)  # leaf rank-range widths (+3: one-ulp fence slack)
    max_window = int(np.max(np.minimum(2 * eps + 3, width + 3))) if b else 1
    return RMIModel(
        root_type=root_type,
        root_coef=jnp.asarray(root_coef),
        b=b,
        leaf_slope=jnp.asarray(slopes),
        leaf_icept=jnp.asarray(icepts),
        leaf_eps=jnp.asarray(eps),
        leaf_r=jnp.asarray(r),
        kmin=jnp.float64(kmin),
        inv_span=jnp.float64(inv_span),
        max_eps=int(eps.max()) if b else 0,
        max_window_=max_window,
        n=len(table_np),
        build_time=build_time,
        name=f"RMI[{root_type},b={b}]",
    )


def _fit_root(u: np.ndarray, ranks: np.ndarray, root_type: str) -> np.ndarray:
    n = len(ranks)
    if root_type == "spline" or n < 8:
        coef = np.zeros(4)
        coef[1] = float(n - 1) if n > 1 else 0.0  # endpoint line through CDF
        return coef
    if root_type == "linear":
        return poly_fit(u, ranks, 1)
    if root_type == "cubic":
        coef = poly_fit(u, ranks, 3)
        # monotonicity check on [0,1]; fall back to linear if p' < 0 anywhere.
        # p' is a quadratic, so its minimum over [0,1] is at an endpoint or
        # at its vertex u* = -c2/(3 c3) — probing the roots of p' (as a
        # previous revision did) always reads p' = 0 and misses the dip
        # *between* them.
        probes = [0.0, 1.0]
        if coef[3] != 0.0:
            vertex = -coef[2] / (3.0 * coef[3])
            if 0.0 < vertex < 1.0:
                probes.append(vertex)
        probes = np.asarray(probes)
        dp = coef[1] + 2 * coef[2] * probes + 3 * coef[3] * probes**2
        if np.any(dp < 0):
            return poly_fit(u, ranks, 1)
        return coef
    raise ValueError(root_type)


def build_rmi(table_np: np.ndarray, b: int = 1024, root_type: str = "linear") -> RMIModel:
    sw = stopwatch()
    n = len(table_np)
    b = max(2, min(b, n))
    kmin, kmax = table_np[0], table_np[-1]
    span = np.float64(kmax - kmin)
    inv_span = np.float64(1.0) / span if span > 0 else np.float64(1.0)
    # IMPORTANT: identical expression to the query path (multiply by the
    # reciprocal) — a 1-ulp divide/multiply mismatch can flip the leaf of
    # a boundary key and void the fence guarantee.
    u = (table_np.astype(np.float64) - np.float64(kmin)) * inv_span
    ranks = np.arange(n, dtype=np.float64)

    root = _fit_root(u, ranks, root_type)
    # leaf assignment (monotone root => contiguous, non-decreasing)
    leaf_of = np.clip(np.floor(poly_eval_np(root, u) * (b / n)), 0, b - 1).astype(np.int64)
    leaf_of = np.maximum.accumulate(leaf_of)  # enforce monotone against fp jitter
    # first rank of each leaf
    r = np.searchsorted(leaf_of, np.arange(b + 1), side="left").astype(np.int64)

    slopes = np.zeros(b, dtype=np.float64)
    icepts = np.zeros(b, dtype=np.float64)

    # Vectorised per-leaf linear fits via segment sums (single pass).
    seg = leaf_of
    cnt = np.bincount(seg, minlength=b).astype(np.float64)
    su = np.bincount(seg, weights=u, minlength=b)
    sr = np.bincount(seg, weights=ranks, minlength=b)
    suu = np.bincount(seg, weights=u * u, minlength=b)
    sur = np.bincount(seg, weights=u * ranks, minlength=b)
    var = cnt * suu - su * su
    cov = cnt * sur - su * sr
    nz = (cnt > 1) & (var > 1e-30)
    slopes[nz] = np.maximum(cov[nz] / var[nz], 0.0)  # clamp >= 0 (monotone)
    icepts[nz] = (sr[nz] - slopes[nz] * su[nz]) / cnt[nz]
    one = (cnt == 1)
    icepts[one] = sr[one]
    empty = cnt == 0
    icepts[empty] = r[:-1][empty].astype(np.float64)  # predict range start

    # per-leaf eps over rank range extended by one key each side
    pred = slopes[seg] * u + icepts[seg]
    err = np.abs(pred - ranks)
    eps_core = np.zeros(b)
    np.maximum.at(eps_core, seg, err)
    # extended: evaluate leaf l on boundary keys r[l]-1 and r[l+1]
    lo_idx = np.clip(r[:-1] - 1, 0, n - 1)
    hi_idx = np.clip(r[1:], 0, n - 1)
    err_lo = np.abs(slopes * u[lo_idx] + icepts - ranks[lo_idx])
    err_hi = np.abs(slopes * u[hi_idx] + icepts - ranks[hi_idx])
    eps_f = np.maximum(eps_core, np.maximum(err_lo, err_hi))
    eps = (np.ceil(np.minimum(eps_f, float(1 << 40))).astype(np.int64) + 1)

    width = np.diff(r)  # leaf rank-range widths (+3: one-ulp fence slack)
    max_window = int(np.max(np.minimum(2 * eps + 3, width + 3))) if b else 1

    dt = sw.elapsed
    return RMIModel(
        root_type=root_type,
        root_coef=jnp.asarray(root),
        b=b,
        leaf_slope=jnp.asarray(slopes),
        leaf_icept=jnp.asarray(icepts),
        leaf_eps=jnp.asarray(eps),
        leaf_r=jnp.asarray(r),
        kmin=jnp.float64(np.float64(kmin)),
        inv_span=jnp.float64(inv_span),
        max_eps=int(eps.max()),
        max_window_=max_window,
        n=n,
        build_time=dt,
        name=f"RMI[{root_type},b={b}]",
    )
