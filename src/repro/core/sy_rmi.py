"""SY-RMI — the paper's second new model (§3.2, "Synoptic RMI").

Pipeline, faithful to §3.2/§4:
  1. ``cdfshop_sweep`` — a deterministic stand-in for CDFShop: up to 10
     two-level RMIs per table over a (root type x branching factor) grid.
  2. ``mine_ub`` — for the whole set of swept models, UB = median of
     (branching factor) / (model space bytes).
  3. ``pick_winner`` — relative-majority architecture by measured query
     time over a 1% simulation query set (paper §4).
  4. ``build_sy_rmi`` — given a space budget (a % of the table bytes),
     instantiate the winner architecture with b = UB x budget.

``build_sy_rmi`` backs the ``SY-RMI`` kind in :mod:`repro.index`
(spec: ``SYRMISpec(space_pct, ub, winner_root)``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs.timing import stopwatch
from .rmi import RMIModel, build_rmi, ROOT_TYPES


def cdfshop_sweep(table_np: np.ndarray, max_models: int = 10):
    """Deterministic CDFShop analogue: grid of 2-level RMIs.

    Roots x geometric branching factors, capped at ``max_models`` models
    (the paper uses CDFShop's ~10 models per table).
    """
    n = len(table_np)
    bs = [b for b in (64, 256, 1024, 4096, 16384, 65536, 262144) if b <= max(n // 2, 2)]
    combos = []
    for root in ROOT_TYPES:
        for b in bs:
            combos.append((root, b))
    # deterministic thinning to max_models, keeping coverage of both axes
    if len(combos) > max_models:
        idx = np.linspace(0, len(combos) - 1, max_models).astype(int)
        combos = [combos[i] for i in idx]
    return [build_rmi(table_np, b=b, root_type=root) for root, b in combos]


def mine_ub(models: Sequence[RMIModel]) -> float:
    """UB = median branching factor per byte of model space (paper §3.2)."""
    ratios = [m.b / m.space_bytes() for m in models]
    return float(np.median(ratios))


def measure_query_time(model, table_j, queries_j, reps: int = 3) -> float:
    """Average per-query wall time of the jitted predecessor pipeline."""
    fn = jax.jit(lambda t, q: model.predecessor(t, q))
    out = fn(table_j, queries_j)
    out.block_until_ready()
    best = np.inf
    for _ in range(reps):
        sw = stopwatch()
        fn(table_j, queries_j).block_until_ready()
        best = min(best, sw.elapsed)
    return best / queries_j.shape[0]


def pick_winner(models: Sequence[RMIModel], table_np: np.ndarray, queries_np: np.ndarray):
    """Relative-majority winner by query time on the 1% simulation set."""
    table_j = jnp.asarray(table_np)
    q_j = jnp.asarray(queries_np)
    times = [measure_query_time(m, table_j, q_j) for m in models]
    best = int(np.argmin(times))
    return models[best].root_type, times


@dataclass
class SyRMIResult:
    ub: float
    winner_root: str
    sweep_sizes: list
    sweep_times: list
    mining_time: float


def mine_sy_rmi(
    tables: Sequence[np.ndarray],
    query_frac: float = 0.01,
    n_queries: int = 1_000_000,
    seed: int = 0,
    max_models: int = 10,
) -> SyRMIResult:
    """Full mining pass over a set of same-tier tables (paper §4).

    Delegates to :func:`repro.tune.mining.mine_sy_rmi` — the mining
    procedure now runs on the batched grid builder (one vmapped
    leaf-fit trace per branching factor, shared jitted lookup timing)
    so mining and Pareto tuning share one engine.  Import is lazy to
    keep ``repro.core`` free of upward dependencies.
    """
    from repro.tune.mining import mine_sy_rmi as _mine

    return _mine(
        tables,
        query_frac=query_frac,
        n_queries=n_queries,
        seed=seed,
        max_models=max_models,
    )


def build_sy_rmi(
    table_np: np.ndarray,
    space_pct: float,
    ub: float,
    winner_root: str = "linear",
) -> RMIModel:
    """Instantiate the synoptic RMI for a space budget (% of table bytes)."""
    budget = space_pct / 100.0 * len(table_np) * 8
    b = max(2, int(budget * ub))
    m = build_rmi(table_np, b=b, root_type=winner_root)
    m.name = f"SY-RMI[{space_pct}%]"
    return m
