"""CDF / rank utilities shared by every learned index model.

A sorted table ``A[0..n)`` of (unsigned) 64-bit keys induces the empirical
CDF ``rank(x) = #{i : A[i] <= x}``.  Predecessor search returns
``rank(x) - 1``, i.e. the largest ``j`` with ``A[j] <= x`` (``-1`` if
``x < A[0]``).  Every model in :mod:`repro.core` predicts an interval
``[lo, hi]`` guaranteed to contain the predecessor; the reduction factor
(paper §2) measures how much of the table a prediction discards.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Keys are stored as uint64.  For regression they are mapped into f64 via a
# per-model affine rescaling; uint64 -> f64 loses bits below 2^-11 of the
# range, which is absorbed into the model's verified error bound.
KEY_DTYPE = np.uint64
POS_DTYPE = np.int64


def as_table(keys) -> np.ndarray:
    """Sorted, deduplicated uint64 table (host side)."""
    arr = np.asarray(keys, dtype=KEY_DTYPE)
    arr = np.unique(arr)  # sorts and dedups
    return arr


def keys_to_unit(keys: np.ndarray, kmin: np.uint64, kmax: np.uint64) -> np.ndarray:
    """Map keys into [0, 1] f64 for regression (host side)."""
    span = np.float64(kmax - kmin)
    if span == 0:
        span = 1.0
    return (keys.astype(np.float64) - np.float64(kmin)) / span


def keys_to_unit_jnp(keys, kmin, inv_span):
    """Same mapping, jittable.  ``inv_span`` precomputed as 1/(kmax-kmin)."""
    return (keys.astype(jnp.float64) - kmin.astype(jnp.float64)) * inv_span


def true_ranks(table: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Oracle predecessor ranks via numpy (testing / reduction factor)."""
    return np.searchsorted(table, queries, side="right").astype(POS_DTYPE) - 1


def reduction_factor(interval_lo, interval_hi, n: int) -> float:
    """Paper §2: avg % of the table discarded by the model's predictions.

    ``interval_lo/hi`` are inclusive bounds per query (device or host
    arrays).  Empty or clipped intervals count their clipped length.
    """
    lo = np.asarray(interval_lo, dtype=np.float64)
    hi = np.asarray(interval_hi, dtype=np.float64)
    lengths = np.clip(hi - lo + 1.0, 1.0, float(n))
    return float(100.0 * (1.0 - lengths.mean() / float(n)))


def model_reduction_factor(model, table_np: np.ndarray, queries_np: np.ndarray) -> float:
    """Paper §2 empirical reduction factor of a model on a query batch.

    ``model`` is anything with the shared ``intervals(table, queries)``
    query surface — a :class:`repro.index.Index` or a core model object.
    """
    lo, hi = model.intervals(jnp.asarray(table_np), jnp.asarray(queries_np))
    return reduction_factor(np.asarray(lo), np.asarray(hi), len(table_np))


def verified_max_error(predictions: np.ndarray, ranks: np.ndarray) -> int:
    """Max |prediction - rank| over the table's own keys (build-time)."""
    return int(np.max(np.abs(np.round(predictions) - ranks)))


def ceil_log2(n: int) -> int:
    n = max(int(n), 1)
    return max(1, int(np.ceil(np.log2(n)))) if n > 1 else 1


def ceil_log2_device(x):
    """Device form of :func:`ceil_log2`: smallest ``k >= 1`` with
    ``2**k >= x``, computed with exact integer shifts (no float log —
    ``f64`` cannot represent every ``uint64`` exactly).  Used by the
    single-program device builds to compare *required* search trip
    counts against a tier's bucketed statics."""
    x = jnp.maximum(jnp.asarray(x, dtype=jnp.int64), 2)
    bl = bit_length_device((x - 1).astype(jnp.uint64)).astype(jnp.int64)
    return jnp.maximum(bl, 1)


def bit_length_device(x):
    """``int.bit_length`` for uint64 device scalars/arrays, via exact
    binary-shift reduction (f64 ``log2`` rounds above 2**53)."""
    x = jnp.asarray(x, dtype=jnp.uint64)
    out = jnp.zeros(x.shape, dtype=jnp.int32)
    for sh in (32, 16, 8, 4, 2, 1):
        has = (x >> jnp.uint64(sh)) > 0
        out = out + jnp.where(has, jnp.int32(sh), jnp.int32(0))
        x = jnp.where(has, x >> jnp.uint64(sh), x)
    return out + jnp.where(x > 0, jnp.int32(1), jnp.int32(0))


def segment_ids(mask):
    """``(seg, start)`` for a boolean segment-start ``mask`` of shape
    ``(n,)``: per-element segment id (dense, 0-based) and the per-id
    start *index* array (capacity ``n``; unused ids hold the sentinel
    ``n``).  The id assignment is one ``lax.associative_scan`` (log-depth
    prefix sum) — the workhorse of the O(log n) fast-fit passes."""
    import jax
    from jax import lax

    n = mask.shape[0]
    seg = lax.associative_scan(jnp.add, mask.astype(jnp.int32)) - 1
    idx = jnp.arange(n, dtype=POS_DTYPE)
    start = jax.ops.segment_min(
        jnp.where(mask, idx, n), seg, num_segments=n, indices_are_sorted=True
    )
    return seg, start


def blocked_corridor_scan(step, block_init, inputs, n: int, chunk: int, count=None):
    """Run a greedy corridor recurrence *blockwise*: O(chunk) sequential
    depth regardless of ``n`` (vs the O(n / chunk) outer-scan depth of
    :func:`chunked_corridor_scan`).  ``count`` optionally restricts
    validity to a traced prefix (the device build pipeline fits PGM
    upper levels over fixed-capacity arrays with traced live counts).

    Elements are padded up to a multiple of ``chunk`` and reshaped to
    ``(n // chunk, chunk)`` blocks; every block runs the *exact* greedy
    ``step`` recurrence over its own elements under ``vmap``, seeded by
    ``block_init(first_elem_inputs) -> carry`` — i.e. each block is
    forced to re-anchor at its boundary.  The result is a valid corridor
    segmentation with up to ``n / chunk`` extra boundaries, which the
    kind-specific merge rounds (``pgm_segments_fast`` /
    ``rs_knots_fast``) collapse in O(log n) associative passes.  The
    same carry-through validity convention as
    :func:`chunked_corridor_scan` applies (``step`` sees a validity flag
    as its last input).

    Returns the ``(n,)`` per-element flag array.
    """
    import jax
    from jax import lax

    chunk = max(int(chunk), 1)
    pad = (-n) % chunk
    valid = jnp.arange(n + pad) < n
    if count is not None:
        valid = valid & (jnp.arange(n + pad) < count)
    padded = [jnp.pad(jnp.asarray(a), (0, pad)) for a in inputs] + [valid]
    blocks = [a.reshape(-1, chunk) for a in padded]

    def one_block(*block):
        init = block_init(tuple(b[0] for b in block))

        def elem(j, st):
            c, flags = st
            c, f = step(c, tuple(b[j] for b in block))
            return c, flags.at[j].set(f)

        _, flags = lax.fori_loop(
            0, chunk, elem, (init, jnp.zeros((chunk,), dtype=bool))
        )
        return flags

    flags = jax.vmap(one_block)(*blocks)
    return flags.reshape(-1)[:n]


def chunked_corridor_scan(step, init, inputs, n: int, chunk: int, count=None):
    """Run a greedy corridor recurrence as a chunked ``lax.scan``.

    ``step(carry, inp) -> (carry, flag)`` is the per-element cone update
    (the same running min/max corridor the numpy builds walk); ``inputs``
    is a tuple of ``(n,)`` arrays.  Elements are padded up to a multiple
    of ``chunk`` and streamed as ``(n // chunk, chunk)`` blocks through
    an outer ``lax.scan`` whose body walks one block with a
    ``fori_loop`` — the trace stays O(1) in ``n`` while the sequential
    dependency (each element sees the cone its predecessors left) is
    preserved exactly.  Padded elements are masked via the carry-through
    convention: ``step`` receives a validity flag as its last input and
    must leave the carry untouched (and emit False) when it is unset.

    Returns the ``(n,)`` array of per-element flags — jittable and
    vmappable (this is what lets :mod:`repro.tune.batched` fit a whole
    batch of tables in ONE trace).
    """
    from jax import lax

    chunk = max(int(chunk), 1)
    pad = (-n) % chunk
    valid = jnp.arange(n + pad) < n
    if count is not None:
        valid = valid & (jnp.arange(n + pad) < count)
    padded = [jnp.pad(jnp.asarray(a), (0, pad)) for a in inputs] + [valid]
    blocks = [a.reshape(-1, chunk) for a in padded]

    def body(carry, block):
        def elem(j, st):
            c, flags = st
            c, f = step(c, tuple(b[j] for b in block))
            return c, flags.at[j].set(f)

        carry, flags = lax.fori_loop(
            0, chunk, elem, (carry, jnp.zeros((chunk,), dtype=bool))
        )
        return carry, flags

    _, flags = lax.scan(body, init, tuple(blocks))
    return flags.reshape(-1)[:n]
