"""DEPRECATED shims over :mod:`repro.index` — the unified Index API.

This module used to own the model hierarchy behind a string if-chain;
that role moved to the spec registry in :mod:`repro.index.registry`.
Kept as thin wrappers so old call sites keep working:

* ``KINDS`` is now an alias of ``repro.index.kinds()`` (same strings,
  same paper order), resolved lazily to keep ``repro.core`` importable
  without dragging in the index package.
* ``build_index(kind, table, **params)`` routes through the registry and
  returns a :class:`repro.index.Index` (a pytree of flat arrays) instead
  of a per-class model object.  ``Index`` keeps the old query surface
  (``intervals`` / ``predecessor`` / ``space_bytes`` and the build-info
  attributes), so most callers migrate by doing nothing — new code
  should use ``repro.index.build`` with an explicit spec.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .cdf import reduction_factor


def __getattr__(name):
    if name == "KINDS":
        from repro import index

        return index.kinds()
    raise AttributeError(name)


def build_index(kind: str, table_np: np.ndarray, **params):
    """DEPRECATED: use ``repro.index.build(spec, table)``."""
    from repro import index

    return index.build(kind, table_np, **params)


def model_reduction_factor(model, table_np: np.ndarray, queries_np: np.ndarray) -> float:
    """Paper §2 empirical reduction factor of a model on a query batch."""
    lo, hi = model.intervals(jnp.asarray(table_np), jnp.asarray(queries_np))
    return reduction_factor(np.asarray(lo), np.asarray(hi), len(table_np))
