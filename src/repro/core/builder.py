"""Unified build/query API over every model class in the paper's hierarchy.

``build_index(kind, table, **params)`` -> model object exposing
``intervals(table, q)``, ``predecessor(table, q)``, ``space_bytes()``,
``build_time`` and ``max_window``; ``KINDS`` enumerates the hierarchy in
the paper's order (constant-space models first).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .atomic import build_atomic
from .kbfs import build_ko
from .rmi import build_rmi
from .pgm import build_pgm, build_pgm_bicriteria
from .radix_spline import build_rs
from .btree import build_btree
from .sy_rmi import build_sy_rmi
from .cdf import as_table, true_ranks, reduction_factor

KINDS = (
    "L",  # linear atomic
    "Q",  # quadratic atomic
    "C",  # cubic atomic
    "KO",  # KO-BFS hybrid (new, paper)
    "RMI",  # two-level RMI
    "SY-RMI",  # synoptic RMI (new, paper)
    "PGM",
    "PGM_M",  # bi-criteria
    "RS",
    "BTREE",
)


def build_index(kind: str, table_np: np.ndarray, **params):
    kind = kind.upper()
    if kind == "L":
        return build_atomic(table_np, degree=1)
    if kind == "Q":
        return build_atomic(table_np, degree=2)
    if kind == "C":
        return build_atomic(table_np, degree=3)
    if kind == "KO":
        return build_ko(table_np, k=params.get("k", 15))
    if kind == "RMI":
        return build_rmi(
            table_np, b=params.get("b", 1024), root_type=params.get("root_type", "linear")
        )
    if kind == "SY-RMI":
        return build_sy_rmi(
            table_np,
            space_pct=params.get("space_pct", 2.0),
            ub=params.get("ub", 0.05),
            winner_root=params.get("winner_root", "linear"),
        )
    if kind == "PGM":
        return build_pgm(table_np, eps=params.get("eps", 64))
    if kind == "PGM_M":
        return build_pgm_bicriteria(
            table_np,
            space_budget_bytes=params.get(
                "space_budget_bytes",
                int(params.get("space_pct", 2.0) / 100.0 * len(table_np) * 8),
            ),
            a=params.get("a", 1.0),
        )
    if kind == "RS":
        return build_rs(table_np, eps=params.get("eps", 32), r_bits=params.get("r_bits", 12))
    if kind == "BTREE":
        return build_btree(table_np, fanout=params.get("fanout", 16))
    raise ValueError(f"unknown index kind {kind!r}; choose from {KINDS}")


def model_reduction_factor(model, table_np: np.ndarray, queries_np: np.ndarray) -> float:
    """Paper §2 empirical reduction factor of a model on a query batch."""
    lo, hi = model.intervals(jnp.asarray(table_np), jnp.asarray(queries_np))
    return reduction_factor(np.asarray(lo), np.asarray(hi), len(table_np))
