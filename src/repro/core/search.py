"""Sorted Table Search procedures (paper §3.1, Supplementary §1) in JAX.

All procedures are *vectorised over a query batch* and jittable.  The
paper's branchy/branch-free distinction maps onto JAX as follows:

* **branch-free (BFS, BFE, K-BFS)** — fixed trip count ``ceil(log2 n)``
  loops of selects: the native idiom for TPU/XLA (no data-dependent
  control flow at all).  These are the procedures every learned model
  bolts onto.
* **branchy (BBS, K-BBS)** — data-dependent early exit.  A vector machine
  cannot retire lanes early, so BBS is modelled as a ``lax.while_loop``
  that exits when *all* lanes have converged — faithful to the paper's
  semantics, and measurably slower on batched hardware, which is itself a
  finding we report.

Conventions: all public entry points return the **predecessor rank**
``j = rank(x) - 1 in [-1, n-1]`` with ``A[j] <= x < A[j+1]``.  Internal
helpers compute ``upper_bound`` (first index with ``A[i] > x``).
"""

from __future__ import annotations

import math

import jax.numpy as jnp
from jax import lax

from .cdf import ceil_log2

#: Predecessor rank reported when ``q`` is smaller than every key —
#: ``rank(x) - 1`` for rank 0.  Every search procedure and index kind
#: shares this sentinel (re-exported by :mod:`repro.dist.sharded_index`).
NO_PRED = -1

# ---------------------------------------------------------------------------
# Branch-free binary search (BFS) — Algorithm 1 of the paper, vectorised.
# ---------------------------------------------------------------------------


def _take(table, idx):
    return jnp.take(table, idx, mode="clip")


def bounded_upper_bound(table, q, lo, length, *, steps: int):
    """First index in [lo, lo+length) with table[i] > q; lo+length if none.

    Branch-free: exactly ``steps`` iterations of the Khuong–Morin loop
    (supplementary Algorithm 1) with ``<=`` comparisons, vectorised over
    queries.  ``steps`` must be >= ceil(log2(max length)).
    Zero-length windows return ``lo``.
    """
    base = lo.astype(jnp.int64)
    n = length.astype(jnp.int64)

    def body(_, carry):
        base, n = carry
        half = n >> 1
        mid = base + half
        go_right = (_take(table, mid) <= q) & (n > 1)
        base = jnp.where(go_right, mid, base)
        n = n - jnp.where(n > 1, half, 0)
        return base, n

    base, n = lax.fori_loop(0, steps, body, (base, n))
    ub = base + (_take(table, base) <= q).astype(jnp.int64)
    return jnp.where(length > 0, ub, lo)


def bfs(table, q, *, n: int | None = None):
    """Branch-free Binary Search over the whole table -> predecessor rank."""
    n = int(table.shape[0]) if n is None else n
    lo = jnp.zeros(q.shape, dtype=jnp.int64)
    ln = jnp.full(q.shape, n, dtype=jnp.int64)
    ub = bounded_upper_bound(table, q, lo, ln, steps=ceil_log2(n))
    return ub - 1


def bounded_bfs(table, q, lo, hi, *, max_window: int):
    """Predecessor rank given a guaranteed inclusive window [lo, hi].

    The learned-procedure epilogue: every model feeds its predicted
    interval here.  Guarantee required from the caller: the predecessor
    rank lies in [lo, hi] (lo may be -1, meaning "possibly before A[0]").
    """
    n = table.shape[0]
    lo_c = jnp.clip(lo, 0, n - 1).astype(jnp.int64)
    hi_c = jnp.clip(hi, 0, n - 1).astype(jnp.int64)
    length = jnp.maximum(hi_c - lo_c + 1, 0)
    ub = bounded_upper_bound(table, q, lo_c, length, steps=ceil_log2(max_window))
    return ub - 1


def bounded_bbs_branchy(table, q, lo, hi):
    """Branchy bounded epilogue (the paper's \\*-BBS variants).

    Early-exit while_loop over a guaranteed inclusive window [lo, hi]:
    all lanes iterate until every lane has converged — the vectorised
    semantics of the paper's scalar branchy loop.  Shared by the
    ``backend="bbs"`` path of every :class:`repro.index.Index` kind.
    """
    n = table.shape[0]
    res0 = jnp.full(q.shape, NO_PRED, dtype=jnp.int64)
    active0 = jnp.ones(q.shape, dtype=bool)
    lo = jnp.clip(lo.astype(jnp.int64), 0, n - 1)
    hi = jnp.clip(hi.astype(jnp.int64), 0, n - 1)

    def cond(state):
        return jnp.any(state[3])

    def body(state):
        lo, hi, res, active = state
        mid = (lo + hi) >> 1
        v = _take(table, mid)
        found = active & (v == q)
        res = jnp.where(found, mid, res)
        go_right = v < q
        lo_n = jnp.where(active & go_right, mid + 1, lo)
        hi_n = jnp.where(active & ~go_right, mid - 1, hi)
        res = jnp.where(active & ~found & (lo_n > hi_n), hi_n, res)
        active = active & ~found & (lo_n <= hi_n)
        return lo_n, hi_n, res, active

    _, _, res, _ = lax.while_loop(cond, body, (lo, hi, res0, active0))
    return res


def bounded_upper_bound_branchy(table, q, lo, count):
    """Branchy counterpart of :func:`bounded_upper_bound` for prefix
    windows: the number of keys ``<= q`` among ``table[lo : lo+count]``,
    in ``[0, count]``, via the early-exit BBS loop.

    The two-tier updatable read path (``GAPPED``) uses this on both its
    gapped-leaf valid prefix and its delta-buffer valid prefix under
    ``backend="bbs"``; ``count`` may be zero (empty leaf / empty delta),
    which the clamp resolves to 0 regardless of what pad slots the probe
    touched.  Assumes unique keys within the window (the equality early
    exit identifies *the* match).
    """
    lo = lo.astype(jnp.int64)
    count = count.astype(jnp.int64)
    res = bounded_bbs_branchy(table, q, lo, lo + count - 1)
    return jnp.clip(res - lo + 1, 0, count)


# ---------------------------------------------------------------------------
# Branchy binary search (BBS) — early-exit semantics via while_loop.
# ---------------------------------------------------------------------------


def bbs(table, q, *, n: int | None = None):
    """Branchy Binary Search: classic lo/hi loop with equality early exit.

    All lanes iterate until every lane has converged (vector semantics of
    a branchy scalar loop)."""
    n = int(table.shape[0]) if n is None else n
    lo0 = jnp.zeros(q.shape, dtype=jnp.int64)
    hi0 = jnp.full(q.shape, n - 1, dtype=jnp.int64)
    res0 = jnp.full(q.shape, NO_PRED, dtype=jnp.int64)
    active0 = jnp.ones(q.shape, dtype=bool)

    def cond(state):
        _, _, _, active = state
        return jnp.any(active)

    def body(state):
        lo, hi, res, active = state
        mid = (lo + hi) >> 1
        v = _take(table, mid)
        found = active & (v == q)
        res = jnp.where(found, mid, res)
        go_right = v < q
        lo_n = jnp.where(active & go_right, mid + 1, lo)
        hi_n = jnp.where(active & ~go_right, mid - 1, hi)
        active_n = active & ~found & (lo_n <= hi_n)
        # On exhaustion the predecessor is hi (last index with A[i] < q).
        res = jnp.where(active & ~found & ~(lo_n <= hi_n), hi_n, res)
        return lo_n, hi_n, res, active_n

    _, _, res, _ = lax.while_loop(cond, body, (lo0, hi0, res0, active0))
    # Equality hits return the matched index; duplicates are deduped at
    # build time so the match *is* the predecessor.
    return res


# ---------------------------------------------------------------------------
# Eytzinger layout (BFE) — supplementary Algorithm 3.
# ---------------------------------------------------------------------------


def eytzinger_layout(table_np):
    """Host-side: permute sorted table into Eytzinger (BFS tree) order.

    Returns (layout, inorder_rank, height).  The layout is padded to
    2^h - 1 entries with the max key so the tree is perfect; the
    closed-form in-order rank of each node vectorises the construction
    and provides the position->sorted-rank map the search epilogue needs
    (Khuong–Morin's recovery yields a *layout* position).
    """
    import numpy as np

    n = int(table_np.shape[0])
    h = max(1, int(math.ceil(math.log2(n + 1))))
    m = (1 << h) - 1
    pad = np.full(m, np.iinfo(np.uint64).max, dtype=np.uint64)
    pad[:n] = table_np
    k = np.arange(m, dtype=np.int64)
    d = np.floor(np.log2(k + 1)).astype(np.int64)  # depth
    # in-order rank of eytzinger node k in a perfect tree of height h
    rank = (2 * (k + 1 - (1 << d)) + 1) * (1 << (h - 1 - d)) - 1
    layout = pad[rank]
    return layout, rank, h


def bfe(layout, inorder_rank, q, *, height: int, n: int):
    """Branch-free Eytzinger search -> predecessor rank (paper Alg. 3).

    ``layout``/``inorder_rank`` come from :func:`eytzinger_layout`; uses
    ``q < A[i]`` so the walk computes upper_bound; the ffs bit-trick
    recovers the *layout* position of the successor, mapped to a sorted
    rank via ``inorder_rank``.
    """
    i = jnp.zeros(q.shape, dtype=jnp.int64)

    def body(_, i):
        v = _take(layout, i)
        return jnp.where(q < v, 2 * i + 1, 2 * i + 2)

    i = lax.fori_loop(0, height, body, i)
    t = i + 1
    # j = t >> ffs(~t); ffs(~t) = 1 + (number of trailing one bits of t)
    low_zero = (~t) & (t + 1)  # isolate lowest zero bit of t
    trailing_ones = lax.population_count(low_zero - 1)
    j = t >> (trailing_ones + 1)
    m = jnp.int64(layout.shape[0])
    ub = jnp.where(j == 0, m, _take(inorder_rank, jnp.maximum(j - 1, 0)))
    # ub indexes the padded sorted order; clamp pads back to n
    return jnp.minimum(ub, n) - 1


# ---------------------------------------------------------------------------
# k-ary search (K-BFS) — supplementary Algorithm 2, plus the TPU-native
# lane-wide variant (k = 128) used by the Pallas kernels.
# ---------------------------------------------------------------------------


def bounded_kary_upper_bound(table, q, lo, length, *, k: int, steps: int):
    """Upper bound via k-ary splitting: each step gathers k-1 fences and
    reduces the window by ~k.  steps >= ceil(log_k(max length))."""
    base = lo.astype(jnp.int64)
    n = length.astype(jnp.int64)
    frac = jnp.arange(1, k, dtype=jnp.int64)

    def body(_, carry):
        base, n = carry
        fence = base[..., None] + (frac * n[..., None]) // k
        v = _take(table, fence)
        seg = jnp.sum((v <= q[..., None]).astype(jnp.int64), axis=-1)
        new_base = base + (seg * n) // k
        new_n = (jnp.minimum(seg + 1, k) * n) // k - (seg * n) // k
        keep = n > 1
        base = jnp.where(keep, new_base, base)
        n = jnp.where(keep, new_n, n)
        return base, n

    base, n = lax.fori_loop(0, steps, body, (base, n))
    ub = base + (_take(table, base) <= q).astype(jnp.int64)
    return jnp.where(length > 0, ub, lo)


def kbfs(table, q, *, k: int = 6, n: int | None = None):
    """k-ary branch-free search -> predecessor rank (paper's K-BFS)."""
    n = int(table.shape[0]) if n is None else n
    steps = max(1, int(math.ceil(math.log(max(n, 2)) / math.log(k))))
    lo = jnp.zeros(q.shape, dtype=jnp.int64)
    ln = jnp.full(q.shape, n, dtype=jnp.int64)
    ub = bounded_kary_upper_bound(table, q, lo, ln, k=k, steps=steps)
    return ub - 1


def kbbs(table, q, *, k: int = 6, n: int | None = None):
    """Branchy k-ary search: while_loop until all lanes have window<=1."""
    n = int(table.shape[0]) if n is None else n
    frac = jnp.arange(1, k, dtype=jnp.int64)
    base0 = jnp.zeros(q.shape, dtype=jnp.int64)
    n0 = jnp.full(q.shape, n, dtype=jnp.int64)

    def cond(carry):
        _, ln = carry
        return jnp.any(ln > 1)

    def body(carry):
        base, ln = carry
        fence = base[..., None] + (frac * ln[..., None]) // k
        v = _take(table, fence)
        seg = jnp.sum((v <= q[..., None]).astype(jnp.int64), axis=-1)
        new_base = base + (seg * ln) // k
        new_n = (jnp.minimum(seg + 1, k) * ln) // k - (seg * ln) // k
        keep = ln > 1
        return jnp.where(keep, new_base, base), jnp.where(keep, new_n, ln)

    base, _ = lax.while_loop(cond, body, (base0, n0))
    ub = base + (_take(table, base) <= q).astype(jnp.int64)
    return ub - 1


# ---------------------------------------------------------------------------
# Interpolation search (IBS) and 3-point interpolation (TIP).
# ---------------------------------------------------------------------------


def ibs(table, q, *, n: int | None = None, max_steps: int = 16):
    """Interpolation search: ``max_steps`` fixed interpolation rounds with
    masking, then a branch-free binary epilogue on the surviving window.
    Matches classic IBS on uniform data in O(loglog n) effective rounds."""
    n = int(table.shape[0]) if n is None else n
    lo = jnp.zeros(q.shape, dtype=jnp.int64)
    hi = jnp.full(q.shape, n - 1, dtype=jnp.int64)

    def body(_, carry):
        lo, hi = carry
        a_lo = _take(table, lo).astype(jnp.float64)
        a_hi = _take(table, hi).astype(jnp.float64)
        qe = q.astype(jnp.float64)
        denom = jnp.maximum(a_hi - a_lo, 1.0)
        pos = lo + ((qe - a_lo) * (hi - lo).astype(jnp.float64) / denom).astype(jnp.int64)
        pos = jnp.clip(pos, lo, hi)
        v = _take(table, pos)
        go_right = v <= q
        new_lo = jnp.where(go_right, pos + 1, lo)
        new_hi = jnp.where(go_right, hi, pos - 1)
        keep = lo <= hi
        return jnp.where(keep, new_lo, lo), jnp.where(keep, new_hi, hi)

    lo, hi = lax.fori_loop(0, max_steps, body, (lo, hi))
    # After interpolation rounds, predecessor is in [lo-1, hi] (loop
    # invariant: everything < lo is <= q, everything > hi is > q).
    win_lo = jnp.maximum(lo - 1, 0)
    length = jnp.maximum(hi - win_lo + 1, 0)
    ub = bounded_upper_bound(table, q, win_lo, jnp.maximum(length, 1), steps=ceil_log2(n))
    return jnp.where(length > 0, ub - 1, hi)


def tip(table, q, *, n: int | None = None, max_steps: int = 8, guard: int = 8):
    """Three-point interpolation (Van Sandt et al.) — fixed-round variant.

    Uses quadratic (3-point) interpolation of the key->rank curve; falls
    back to the branch-free epilogue once the window is below ``guard``.
    """
    n = int(table.shape[0]) if n is None else n
    lo = jnp.zeros(q.shape, dtype=jnp.int64)
    hi = jnp.full(q.shape, n - 1, dtype=jnp.int64)

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) >> 1
        y0 = _take(table, lo).astype(jnp.float64) - q.astype(jnp.float64)
        y1 = _take(table, mid).astype(jnp.float64) - q.astype(jnp.float64)
        y2 = _take(table, hi).astype(jnp.float64) - q.astype(jnp.float64)
        dm = (mid - lo).astype(jnp.float64)
        num = y1 * dm * (1.0 + (y0 - y1) / jnp.where(y1 == y2, 1.0, y1 - y2))
        den = y0 - y2 * ((y0 - y1) / jnp.where(y1 == y2, 1.0, y1 - y2))
        expected = mid + (num / jnp.where(den == 0, 1.0, den)).astype(jnp.int64)
        expected = jnp.clip(expected, lo, hi)
        v = _take(table, expected)
        go_right = v <= q
        new_lo = jnp.where(go_right, expected + 1, lo)
        new_hi = jnp.where(go_right, hi, expected - 1)
        keep = (hi - lo) > guard
        return jnp.where(keep, new_lo, lo), jnp.where(keep, new_hi, hi)

    lo, hi = lax.fori_loop(0, max_steps, body, (lo, hi))
    win_lo = jnp.maximum(lo - 1, 0)
    length = jnp.maximum(hi - win_lo + 1, 0)
    ub = bounded_upper_bound(table, q, win_lo, jnp.maximum(length, 1), steps=ceil_log2(n))
    return jnp.where(length > 0, ub - 1, hi)


# ---------------------------------------------------------------------------
# Registry of plain (model-free) procedures.
# ---------------------------------------------------------------------------

PROCEDURES = {
    "bfs": bfs,
    "bbs": bbs,
    "kbfs": kbfs,
    "kbbs": kbbs,
    "ibs": ibs,
    "tip": tip,
}
