"""Paper Supplementary Table 6: synoptic space / time / reduction-factor
table, normalised against the best query-time model per tier."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_index, model_reduction_factor
from repro.core.sy_rmi import cdfshop_sweep, mine_ub, build_sy_rmi

from .common import TIERS, bench_tables, emit, queries_for, time_fn


def run():
    for tier in TIERS:
        bts = [bt for bt in bench_tables(datasets=("amzn64", "osm", "wiki")) if bt.tier == tier]
        agg = {}
        for bt in bts:
            table = bt.table
            qs = queries_for(table, 20_000)
            tj, qj = jnp.asarray(table), jnp.asarray(qs)
            sweep = cdfshop_sweep(table, max_models=4)
            ub = mine_ub(sweep)
            models = [("BestRMI", min(sweep, key=lambda m: m.max_eps))]
            for pct in (0.05, 0.7, 2.0):
                models.append((f"SY-RMI{pct}", build_sy_rmi(table, pct, ub)))
                budget = int(pct / 100 * len(table) * 8)
                models.append((f"PGM{pct}", build_index("PGM_M", table, space_budget_bytes=budget)))
            models.append(("RS", build_index("RS", table, eps=64)))
            models.append(("BTree", build_index("BTREE", table, fanout=16)))
            for label, m in models:
                fn = jax.jit(lambda t, q, m=m: m.predecessor(t, q))
                dt = time_fn(fn, tj, qj, reps=2) / len(qs)
                rf = model_reduction_factor(m, table, qs[:2000])
                agg.setdefault(label, []).append((dt, m.space_bytes(), rf))

        best_label = min(agg, key=lambda k: np.mean([r[0] for r in agg[k]]))
        bt_, bs_, brf = (np.mean([r[i] for r in agg[best_label]]) for i in range(3))
        for label, rows in sorted(agg.items()):
            t, s, rf = (np.mean([r[i] for r in rows]) for i in range(3))
            emit(
                f"synoptic/{tier}/{label}",
                t * 1e6,
                f"time_ratio={t / bt_:.3g};space_ratio={s / bs_:.3g};rf={rf:.2f};best={best_label}",
            )
