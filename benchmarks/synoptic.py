"""Paper Supplementary Table 6: synoptic space / time / reduction-factor
table, normalised against the best query-time model per tier.

All models are built from ``repro.index`` specs and queried through the
shared jitted lookup; across a tier's tables, same-structure models of a
kind reuse one trace instead of recompiling per model.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro import index as ix
from repro.core import model_reduction_factor
from repro.core.sy_rmi import cdfshop_sweep, mine_ub
from repro.index import impls

from .common import TIERS, bench_tables, emit, queries_for, time_fn


def run():
    for tier in TIERS:
        bts = [bt for bt in bench_tables(datasets=("amzn64", "osm", "wiki")) if bt.tier == tier]
        agg = {}
        for bt in bts:
            table = bt.table
            qs = queries_for(table, 20_000)
            tj, qj = jnp.asarray(table), jnp.asarray(qs)
            sweep = cdfshop_sweep(table, max_models=4)
            ub = mine_ub(sweep)
            best_rmi = min(sweep, key=lambda m: m.max_eps)
            specs = []
            for pct in (0.05, 0.7, 2.0):
                specs.append((f"SY-RMI{pct}", ix.SYRMISpec(space_pct=pct, ub=ub)))
                budget = int(pct / 100 * len(table) * 8)
                specs.append((f"PGM{pct}", ix.PGMBicriteriaSpec(space_budget_bytes=budget)))
            specs.append(("RS", ix.RSSpec(eps=64)))
            specs.append(("BTree", ix.BTreeSpec(fanout=16)))
            # wrap the sweep's already-fitted winner instead of refitting it
            models = [("BestRMI", impls.rmi_model_to_index("RMI", best_rmi, table))]
            models += [(label, ix.build(spec, table)) for label, spec in specs]
            for label, m in models:
                dt = time_fn(lambda t, q: m.lookup(t, q), tj, qj, reps=2) / len(qs)
                rf = model_reduction_factor(m, table, qs[:2000])
                agg.setdefault(label, []).append((dt, m.space_bytes(), rf))

        best_label = min(agg, key=lambda k: np.mean([r[0] for r in agg[k]]))
        bt_, bs_, brf = (np.mean([r[i] for r in agg[best_label]]) for i in range(3))
        for label, rows in sorted(agg.items()):
            t, s, rf = (np.mean([r[i] for r in rows]) for i in range(3))
            emit(
                f"synoptic/{tier}/{label}",
                t * 1e6,
                f"time_ratio={t / bt_:.3g};space_ratio={s / bs_:.3g};rf={rf:.2f};best={best_label}",
            )
