"""Serving SLO benchmark: Zipf + adversarial query mixes over ``TunedTier``.

The scaled traffic harness of the ROADMAP's SLO item (grown past smoke
in PR 9): a pinned-spec tier serves skewed query streams, every batch
timed through :func:`repro.obs.timing.timed_lookup` — p50/p99 come from
the ``lookup_latency_us`` histogram snapshot, the way a production SLO
is actually evaluated (distributions, not means; the SOSD methodology).
At ``REPRO_BENCH_SCALE=1`` each leg serves on the order of a million
queries (``PHASES x BATCHES_PER_PHASE x BATCH``); smoke scale shrinks
the batch count, never the batch shape, so the trace set is identical.

Three leg groups:

* **mixed** (``slo/*``) — the original shifting-Zipf stream with a
  growing near-miss fraction; drop-rate + latency + exactness gates.
* **cache A/B** (``slo/cache_off/*`` vs ``slo/cache/*``) — the same
  concentrated-Zipf hot traffic served by a bare tier and by a
  :class:`repro.serve.hotcache.HotKeyCache`-fronted tier whose sketch
  is primed per phase (the hot set shifts, the decayed sketch follows).
  ``slo/cache/speedup_p99`` is the headline: the trend gate fails if
  the cache-on leg stops beating cache-off p99 in the same artifact.
* **adversarial** (``slo/adv/*``) — a rebalance-enabled tier under
  single-shard hammering, hot-set inversion, and a miss flood:
  query-driven fence rebalancing must trigger (``slo/adv/rebalances``)
  with zero retunes while every batch stays bit-exact.

Gates (``--check``, and ``benchmarks/trend.py`` via the committed
``benchmarks/baselines/serve_slo.json``):

* ``slo/drop_rate`` — must stay ≤ :data:`DROP_RATE_SLO` (absolute);
* every ``*/exact`` metric — pinned 1.0 (bit-exact vs ``true_ranks``);
* ``slo/cache/speedup_p99`` — must stay > 1.0 in the fresh artifact;
* ``slo/compiles`` + trace counts — exact: the serving loop keeps the
  one-trace discipline across cache probes, rebuild lookups, and the
  adversarial tier's forced restack.

``python -m benchmarks.serve_slo [--json OUT] [--jsonl SNAP] [--check]``;
``--jsonl`` exports the full registry snapshot in the stable JSONL
schema (``python -m repro.obs dump`` reads it).
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

import repro  # noqa: F401
from repro import index as ix
from repro import obs
from repro.core.cdf import true_ranks
from repro.data import distributions
from repro.serve.hotcache import HotKeyCache
from repro.tune.rebuild import RebuildPolicy, TunedTier

from .common import SCALE, emit as _emit

_METRICS: dict = {}

#: absolute SLO: fraction of queries the capacity-factored exchange may drop
DROP_RATE_SLO = 0.01
#: traffic shape: phases shift the Zipf hot set and raise the miss mix
PHASES = 3
#: ~1M queries per leg group at SCALE=1 (PHASES x this x BATCH); smoke
#: scale shrinks the batch COUNT only — batch shapes (and therefore the
#: trace set the baseline pins) are scale-invariant
BATCHES_PER_PHASE = max(4, int(round(320 * SCALE)))
BATCH = 1024
ZIPF_A = 1.15
#: concentrated-Zipf hot-set span for the cache A/B legs — strictly
#: inside CACHE_CAP so a primed sketch makes the whole span resident
HOT_SPAN = 2048
CACHE_CAP = 4096


def emit(name: str, value: float, derived: str = ""):
    _METRICS[name] = float(value)
    _emit(name, value, derived)


def _phase_queries(rng, table: np.ndarray, phase: int) -> np.ndarray:
    """One batch of the mixed leg's traffic: Zipf ranks around a shifting
    hot offset, plus a growing fraction of near-miss probes (key+1 —
    a legitimate predecessor query that is not a stored key)."""
    n = len(table)
    ranks = (rng.zipf(ZIPF_A, size=BATCH) - 1 + phase * n // PHASES) % n
    qs = table[ranks]
    miss = rng.random(BATCH) < 0.05 * phase
    return np.where(miss & (qs < np.uint64(np.iinfo(np.uint64).max)), qs + np.uint64(1), qs)


def _hot_queries(rng, table: np.ndarray, phase: int) -> np.ndarray:
    """Concentrated Zipf: every query inside the phase's HOT_SPAN-rank
    hot window (the cache A/B traffic — a resident hot set answers it)."""
    n = len(table)
    ranks = (phase * n // PHASES + (rng.zipf(ZIPF_A, size=BATCH) - 1) % HOT_SPAN) % n
    return table[ranks]


def _hot_span(table: np.ndarray, phase: int) -> np.ndarray:
    n = len(table)
    return table[(phase * n // PHASES + np.arange(HOT_SPAN)) % n]


def _latency(snap, tier: str, phase: str) -> tuple:
    s = obs.find_sample(
        snap, "lookup_latency_us", kind="RMI", backend="xla", tier=tier, phase=phase
    )
    return obs.hist_quantile(s, 0.50), obs.hist_quantile(s, 0.99), s["count"]


def _leg_mixed(table: np.ndarray, rng) -> None:
    """The original shifting-Zipf leg: drop/latency/exactness gates."""
    tier = TunedTier(
        table, n_shards=4, policy=RebuildPolicy(backend="xla"), spec=ix.RMISpec(b=512),
        name="slo",
    )
    # warm the serving path once (same batch shape -> same traces), so
    # the latency histogram measures steady-state serving, not compile
    tier.lookup(_phase_queries(rng, table, 0))
    exact = True
    for phase in range(PHASES):
        for _ in range(BATCHES_PER_PHASE):
            qs = _phase_queries(rng, table, phase)
            with obs.span("serve_slo.batch"):
                out = obs.timed_lookup(tier, qs, tier="slo")
            # spot-check every phase's last batch against searchsorted
            got = np.asarray(out)
        exact &= bool((got == true_ranks(table, np.asarray(qs))).all())
    snap = obs.snapshot()
    m = tier.metrics()
    for phase_name, phase in (("host", "host"), ("", "device")):
        p50, p99, count = _latency(snap, "slo", phase)
        prefix = f"slo/{phase_name}_" if phase_name else "slo/"
        emit(f"{prefix}p50_us", p50, f"count={count}")
        emit(f"{prefix}p99_us", p99)
    emit(
        "slo/queries",
        float(m["routing"]["queries"]),
        f"{PHASES} phases x {BATCHES_PER_PHASE} + warmup",
    )
    emit("slo/drop_rate", m["routing"]["drop_rate"], f"SLO <= {DROP_RATE_SLO}")
    emit("slo/imbalance_peak", m["routing"]["imbalance_peak"], "Zipf skew, peak shard load")
    emit("slo/exact", float(exact), "per-phase spot batches vs searchsorted")


def _serve_leg(target, table, rng, label: str, *, prime=None) -> tuple:
    """Serve PHASES x BATCHES_PER_PHASE concentrated-Zipf batches through
    ``target``, timing every batch under ``tier=label``; returns
    ``(exact, p50, p99)`` from the device-phase histogram."""
    exact = True
    target.lookup(_hot_queries(rng, table, 0))  # warm compile, untimed
    for phase in range(PHASES):
        if prime is not None:
            prime(phase)
        for _ in range(BATCHES_PER_PHASE):
            qs = _hot_queries(rng, table, phase)
            with obs.span(f"serve_slo.{label}"):
                out = obs.timed_lookup(target, qs, tier=label)
            exact &= bool((np.asarray(out) == true_ranks(table, qs)).all())
    p50, p99, _ = _latency(obs.snapshot(), label, "device")
    return exact, p50, p99


def _leg_cache_ab(table: np.ndarray, rng) -> None:
    """Cache-off vs cache-on over identical concentrated-Zipf traffic."""
    policy = RebuildPolicy(backend="xla")
    spec = ix.RMISpec(b=512)
    off = TunedTier(table, n_shards=4, policy=policy, spec=spec, name="slo_off")
    rng_off = np.random.default_rng(rng.integers(1 << 31))
    rng_on = np.random.default_rng(rng.integers(1 << 31))
    exact_off, p50_off, p99_off = _serve_leg(off, table, rng_off, "slo_off")

    hot_tier = TunedTier(table, n_shards=4, policy=policy, spec=spec, name="slo_hot")
    cache = HotKeyCache(hot_tier, capacity=CACHE_CAP)
    hits0 = [0]

    def prime(phase: int) -> None:
        # the decayed sketch follows the shifting hot set: pin the
        # phase's hot span with weight proportional to the per-phase
        # traffic volume (so the once-decayed prime still outweighs the
        # previous phase's accumulated counts), feed one real traffic
        # batch, then rebuild the residency off-path
        cache.sketch.update(_hot_span(table, phase), weight=4.0 * BATCHES_PER_PHASE)
        cache.sketch.update(_hot_queries(rng_on, table, phase))
        cache.rebuild()
        if phase == 0:  # runs post-warmup, pre-timing: timed-hit floor
            hits0[0] = int(obs.metric("hotcache_hits").value(tier="slo_hot"))

    exact_on, p50_on, p99_on = _serve_leg(cache, table, rng_on, "slo_hot", prime=prime)
    hits = int(obs.metric("hotcache_hits").value(tier="slo_hot")) - hits0[0]
    misses = int(obs.metric("hotcache_misses").value(tier="slo_hot"))
    served = PHASES * BATCHES_PER_PHASE * BATCH

    emit("slo/cache_off/p50_us", p50_off)
    emit("slo/cache_off/p99_us", p99_off)
    emit("slo/cache_off/exact", float(exact_off), "every batch vs searchsorted")
    emit("slo/cache/p50_us", p50_on)
    emit("slo/cache/p99_us", p99_on)
    emit("slo/cache/hit_rate", hits / max(served, 1), f"{hits} hits / {served} timed")
    emit("slo/cache/misses", float(misses), "fall-throughs incl. warmup")
    emit("slo/cache/rebuilds", float(obs.metric("hotcache_rebuilds").value(tier="slo_hot")))
    emit("slo/cache/space_bytes", float(cache.space_bytes()), "residency budget")
    emit("slo/cache/speedup_p99", p99_off / p99_on, "cache-off p99 / cache-on p99")
    emit("slo/cache/exact", float(exact_on), "every batch vs searchsorted")


def _leg_adversarial(table: np.ndarray, rng) -> None:
    """Hammer one shard, invert the hot set, flood with misses — the
    query-driven rebalancer must fire (zero retunes), every batch exact."""
    n = len(table)
    tier = TunedTier(
        table,
        n_shards=4,
        policy=RebuildPolicy(
            backend="xla",
            rebalance_imbalance=1.5,
            rebalance_min_lookups=max(2, min(8, BATCHES_PER_PHASE - 2)),
        ),
        spec=ix.RMISpec(b=512),
        name="slo_adv",
    )
    tier.lookup(table[rng.integers(0, n, BATCH)])  # warm compile, untimed

    def hammer(r):  # every query inside the last shard's initial range
        return table[3 * n // 4 + (r.zipf(ZIPF_A, BATCH) - 1) % (n - 3 * n // 4)]

    def invert(r):  # hot set flips to the first shard's initial range
        return table[(r.zipf(ZIPF_A, BATCH) - 1) % (n // 4)]

    def flood(r):  # half near-miss probes (key+1), never a stored key hit
        qs = table[r.integers(0, n, BATCH)].copy()
        probe = r.random(BATCH) < 0.5
        qs[probe] = np.minimum(
            qs[probe] + np.uint64(1), np.uint64(np.iinfo(np.uint64).max) - np.uint64(1)
        )
        qs[:2] = [np.uint64(0), table[0]]  # below-min -> NO_PRED when min > 0
        return qs

    for name, gen in (("hammer", hammer), ("invert", invert), ("flood", flood)):
        exact = True
        for _ in range(BATCHES_PER_PHASE):
            qs = gen(rng)
            with obs.span(f"serve_slo.adv_{name}"):
                out = obs.timed_lookup(tier, qs, tier="slo_adv")
            exact &= bool((np.asarray(out) == true_ranks(table, qs)).all())
        emit(f"slo/adv/{name}/exact", float(exact), "every batch, incl. mid-rebalance")
    m = tier.metrics()
    emit("slo/adv/rebalances", float(m["rebalances"]), "query-driven fence rebalances")
    emit("slo/adv/moved_keys", float(m["rebalance_moved_keys"]))
    emit("slo/adv/forced_restacks", float(m["forced_restacks"]), "capacity fallback arm")
    emit("slo/adv/retunes", float(m["retunes"]), "must stay 0: rebalancing is retune-free")
    emit("slo/adv/drop_rate", m["routing"]["drop_rate"], f"SLO <= {DROP_RATE_SLO}")


def run(jsonl: str | None = None) -> dict:
    _METRICS.clear()
    ix.reset_trace_counts()
    obs.reset()
    rng = np.random.default_rng(29)
    n = max(1 << 13, int((1 << 18) * SCALE))
    table = distributions.generate("osm", n, seed=11)

    _leg_mixed(table, rng)
    _leg_cache_ab(table, rng)
    _leg_adversarial(table, rng)

    traces = {f"{k}/{b}": v for (k, b), v in sorted(ix.trace_counts().items())}
    emit("slo/compiles", float(sum(traces.values())), "total traces (exact gate)")

    if jsonl:
        with open(jsonl, "w") as f:
            f.write(obs.to_jsonl(obs.snapshot()))
    return {
        "metrics": dict(_METRICS),
        "slo": {"drop_rate_max": DROP_RATE_SLO},
        "trace_counts": traces,
        "total_traces": sum(traces.values()),
    }


def check_slo(report: dict) -> list:
    """The absolute SLO gates: drop-rate ceilings, sane (non-degenerate)
    histogram quantiles, every leg's exactness flag.  Baseline-free —
    these hold on any machine at any scale."""
    fails = []
    m = report["metrics"]
    # a leg that silently vanished from the report is a gate failure, not
    # a KeyError — every required metric is checked for presence first
    required = (
        "slo/drop_rate",
        "slo/adv/drop_rate",
        "slo/p50_us",
        "slo/p99_us",
        "slo/cache_off/p50_us",
        "slo/cache_off/p99_us",
        "slo/cache/p50_us",
        "slo/cache/p99_us",
        "slo/adv/retunes",
    )
    missing = [k for k in required if k not in m]
    if missing:
        return [f"missing metric {k} (leg dropped from the report?)" for k in missing]
    for k in ("slo/drop_rate", "slo/adv/drop_rate"):
        if m[k] > report["slo"]["drop_rate_max"]:
            fails.append(f"{k} {m[k]:.4f} > SLO {report['slo']['drop_rate_max']}")
    for pre in ("slo/", "slo/cache_off/", "slo/cache/"):
        if not 0 < m[pre + "p50_us"] <= m[pre + "p99_us"]:
            fails.append(
                f"degenerate latency quantiles: {pre}p50={m[pre + 'p50_us']}, "
                f"{pre}p99={m[pre + 'p99_us']}"
            )
    for k in sorted(m):
        if k.endswith("/exact") and m[k] != 1.0:
            fails.append(f"{k} != 1 (served ranks diverged from searchsorted)")
    if m["slo/adv/retunes"] != 0.0:
        fails.append(f"slo/adv/retunes = {m['slo/adv/retunes']} (rebalancing must not retune)")
    return fails


def check(report: dict, baseline_path: str, tol: float = 8.0) -> list:
    """The full gate: :func:`check_slo` plus the bench-trend diff
    (ratio-gated latencies, exact traces, cache-speedup self-gate)
    against the committed baseline."""
    from pathlib import Path

    from . import trend

    base = Path(baseline_path)
    return check_slo(report) + trend.check_artifact_data(base.name, report, base.parent, tol)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write metrics + trace counts as JSON")
    ap.add_argument("--jsonl", default=None, help="export the registry snapshot as JSONL")
    ap.add_argument(
        "--check",
        action="store_true",
        help="apply the SLO gates against benchmarks/baselines/serve_slo.json",
    )
    ap.add_argument("--baseline", default="benchmarks/baselines/serve_slo.json")
    ap.add_argument("--tolerance", type=float, default=8.0)
    args = ap.parse_args()
    report = run(jsonl=args.jsonl)
    if args.json:
        with open(args.json, "w") as f:
            f.write(json.dumps(report, indent=2) + "\n")
    if args.check:
        fails = check(report, args.baseline, args.tolerance)
        for f in fails:
            print(f"SERVE SLO: {f}", file=sys.stderr)
        if fails:
            sys.exit(1)
        print("serve_slo: SLO gates OK")


if __name__ == "__main__":
    main()
