"""Serving SLO benchmark: Zipf + shifting query-mix over ``TunedTier``.

The traffic harness the ROADMAP's SLO item asks for, sized to the
bench-smoke budget: a pinned-spec tier serves a skewed (Zipf) query
stream whose hot set *shifts* between phases (and picks up a growing
miss fraction), every batch timed through
:func:`repro.obs.timing.timed_lookup` — so p50/p99 come from the
``lookup_latency_us`` histogram snapshot, the way a production SLO is
actually evaluated (distributions, not means; the SOSD methodology).

Gates (``--check``, and ``benchmarks/trend.py`` via the committed
``benchmarks/baselines/serve_slo.json``):

* ``slo/drop_rate`` — must stay ≤ :data:`DROP_RATE_SLO` (absolute);
* ``slo/p50_us`` / ``slo/p99_us`` — device-phase histogram quantiles,
  ratio-gated against the baseline (CI machines vary);
* ``slo/exact`` — a spot-check batch must bit-match ``true_ranks``
  (pinned 1.0);
* ``slo/compiles`` + trace counts — the serving loop keeps the
  one-trace discipline: ONE shared lookup trace + ONE owner-histogram
  trace + ONE obs histogram-update trace (exact).

``python -m benchmarks.serve_slo [--json OUT] [--jsonl SNAP] [--check]``;
``--jsonl`` exports the full registry snapshot in the stable JSONL
schema (``python -m repro.obs dump`` reads it).
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

import repro  # noqa: F401
from repro import index as ix
from repro import obs
from repro.core.cdf import true_ranks
from repro.data import distributions
from repro.tune.rebuild import RebuildPolicy, TunedTier

from .common import SCALE, emit as _emit

_METRICS: dict = {}

#: absolute SLO: fraction of queries the capacity-factored exchange may drop
DROP_RATE_SLO = 0.01
#: traffic shape: phases shift the Zipf hot set and raise the miss mix
PHASES = 3
BATCHES_PER_PHASE = 6
BATCH = 1024
ZIPF_A = 1.15


def emit(name: str, value: float, derived: str = ""):
    _METRICS[name] = float(value)
    _emit(name, value, derived)


def _phase_queries(rng, table: np.ndarray, phase: int) -> np.ndarray:
    """One batch of the phase's traffic: Zipf ranks around a shifting
    hot offset, plus a growing fraction of near-miss probes (key+1 —
    a legitimate predecessor query that is not a stored key)."""
    n = len(table)
    ranks = (rng.zipf(ZIPF_A, size=BATCH) - 1 + phase * n // PHASES) % n
    qs = table[ranks]
    miss = rng.random(BATCH) < 0.05 * phase
    return np.where(miss & (qs < np.uint64(np.iinfo(np.uint64).max)), qs + np.uint64(1), qs)


def run(jsonl: str | None = None) -> dict:
    _METRICS.clear()
    ix.reset_trace_counts()
    obs.reset()
    rng = np.random.default_rng(29)
    n = max(1 << 13, int((1 << 18) * SCALE))
    table = distributions.generate("osm", n, seed=11)

    tier = TunedTier(
        table,
        n_shards=4,
        policy=RebuildPolicy(backend="xla"),
        spec=ix.RMISpec(b=512),
    )

    # warm the serving path once (same batch shape -> same traces), so
    # the latency histogram measures steady-state serving, not compile
    tier.lookup(_phase_queries(rng, table, 0))

    # ---- serve the shifting Zipf stream, one histogram per batch ---------
    exact = True
    for phase in range(PHASES):
        for _ in range(BATCHES_PER_PHASE):
            qs = _phase_queries(rng, table, phase)
            with obs.span("serve_slo.batch"):
                out = obs.timed_lookup(tier, qs, tier="slo")
            # spot-check every phase's last batch against searchsorted
            got = np.asarray(out)
        exact &= bool((got == true_ranks(table, np.asarray(qs))).all())

    # ---- render the SLO metrics from the registry snapshot ---------------
    snap = obs.snapshot()
    m = tier.metrics()
    for phase_name, phase in (("host", "host"), ("", "device")):
        s = obs.find_sample(
            snap, "lookup_latency_us", kind="RMI", backend="xla", tier="slo", phase=phase
        )
        prefix = f"slo/{phase_name}_" if phase_name else "slo/"
        emit(f"{prefix}p50_us", obs.hist_quantile(s, 0.50), f"count={s['count']}")
        emit(f"{prefix}p99_us", obs.hist_quantile(s, 0.99))
    emit(
        "slo/queries",
        float(m["routing"]["queries"]),
        f"{PHASES} phases x {BATCHES_PER_PHASE} + warmup",
    )
    emit("slo/drop_rate", m["routing"]["drop_rate"], f"SLO <= {DROP_RATE_SLO}")
    emit("slo/imbalance_peak", m["routing"]["imbalance_peak"], "Zipf skew, peak shard load")
    emit("slo/exact", float(exact), "per-phase spot batches vs searchsorted")

    traces = {f"{k}/{b}": v for (k, b), v in sorted(ix.trace_counts().items())}
    emit("slo/compiles", float(sum(traces.values())), "total traces (exact gate)")

    if jsonl:
        with open(jsonl, "w") as f:
            f.write(obs.to_jsonl(obs.snapshot()))
    return {
        "metrics": dict(_METRICS),
        "slo": {"drop_rate_max": DROP_RATE_SLO},
        "trace_counts": traces,
        "total_traces": sum(traces.values()),
    }


def check_slo(report: dict) -> list:
    """The absolute SLO gates: drop-rate ceiling, sane (non-degenerate)
    histogram quantiles, exactness.  Baseline-free — these hold on any
    machine at any scale."""
    fails = []
    m = report["metrics"]
    if m["slo/drop_rate"] > report["slo"]["drop_rate_max"]:
        fails.append(
            f"drop_rate {m['slo/drop_rate']:.4f} > SLO {report['slo']['drop_rate_max']}"
        )
    if not 0 < m["slo/p50_us"] <= m["slo/p99_us"]:
        fails.append(f"degenerate latency quantiles: p50={m['slo/p50_us']}, p99={m['slo/p99_us']}")
    if m["slo/exact"] != 1.0:
        fails.append("slo/exact != 1 (served ranks diverged from searchsorted)")
    return fails


def check(report: dict, baseline_path: str, tol: float = 8.0) -> list:
    """The full gate: :func:`check_slo` plus the bench-trend diff
    (ratio-gated latencies, exact traces) against the committed
    baseline."""
    from pathlib import Path

    from . import trend

    base = Path(baseline_path)
    return check_slo(report) + trend.check_artifact_data(base.name, report, base.parent, tol)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write metrics + trace counts as JSON")
    ap.add_argument("--jsonl", default=None, help="export the registry snapshot as JSONL")
    ap.add_argument(
        "--check",
        action="store_true",
        help="apply the SLO gates against benchmarks/baselines/serve_slo.json",
    )
    ap.add_argument("--baseline", default="benchmarks/baselines/serve_slo.json")
    ap.add_argument("--tolerance", type=float, default=8.0)
    args = ap.parse_args()
    report = run(jsonl=args.jsonl)
    if args.json:
        with open(args.json, "w") as f:
            f.write(json.dumps(report, indent=2) + "\n")
    if args.check:
        fails = check(report, args.baseline, args.tolerance)
        for f in fails:
            print(f"SERVE SLO: {f}", file=sys.stderr)
        if fails:
            sys.exit(1)
        print("serve_slo: SLO gates OK")


if __name__ == "__main__":
    main()
