"""Time-space Pareto frontier of every registered index kind.

The paper's core claim — space, not accuracy, is the key to learned
index efficiency — as one artifact: the registry-derived candidate grid
(:func:`repro.tune.pareto.candidate_grid`) is built through the batched
builder, each candidate is measured (model bytes, jit-timed lookup
latency through the shared query path), and the non-dominated frontier
plus the bi-criteria budget picks are emitted as a JSON report per
(dataset, tier)::

    REPRO_BENCH_SCALE=0.01 PYTHONPATH=src \
        python -m benchmarks.pareto_frontier --json pareto_frontier.json

``--check`` turns the report into a CI gate: every frontier must be
non-empty and monotone (space strictly increasing, latency strictly
decreasing along it), every candidate exact, and every budget pick's
built ``space_bytes`` within its budget.

``--fit vmap`` runs the sweep through the device-native fits and adds a
``fit`` section to the report: ``vmap_exact`` (the PGM / PGM_M / RS
scan fits rebuild each tier table bit-identically to ``fit="host"``)
and the fit-trace budget (one vmapped trace per (kind, n, ε-config) —
fewer in practice, since ε is traced).  Under ``--check`` both are
gates.
"""

from __future__ import annotations

import argparse
import json
import sys

import numpy as np

from repro import index as ix
from repro import tune
from repro.tune import pareto

from .common import bench_tables, emit

BUDGET_PCTS = (0.7, 2.0, 10.0)

#: Scan-fit kinds × the spec used for the vmap-exactness probe.
_VMAP_EXACT_SPECS = (
    lambda n: ix.PGMSpec(eps=32),
    lambda n: ix.PGMBicriteriaSpec(space_pct=2.0),
    lambda n: ix.RSSpec(eps=32, r_bits=8 if n < 1 << 16 else 12),
)


def _check_vmap_exact(table) -> bool:
    """The acceptance probe: fit='vmap' boundaries == fit='host' for the
    PGM / PGM_M / RS families, asserted per-table after unstack()."""
    ok = True
    for make in _VMAP_EXACT_SPECS:
        spec = make(len(table))
        got = tune.build_many(spec, [table], fit="vmap").unstack()[0]
        want = ix.build(spec, table)
        ok &= got.static == want.static
        ok &= all(
            np.array_equal(np.asarray(got.arrays[k]), np.asarray(want.arrays[k]))
            for k in want.arrays
        )
    return ok


def run(
    tiers=("L1",),
    datasets=("amzn64", "osm"),
    n_queries: int = 4096,
    backend: str = "xla",
    budget_pcts=BUDGET_PCTS,
    fit: str = "auto",
):
    ix.reset_trace_counts()
    reports = {}
    vmap_exact = True
    fit_trace_budget = 0
    for bt in bench_tables():
        if bt.tier not in tiers or bt.dataset not in datasets:
            continue
        cands = pareto.sweep(
            bt.table, n_queries=n_queries, backend=backend, check_exact=True, fit=fit
        )
        front = pareto.pareto_frontier(cands)
        report = pareto.frontier_report(
            bt.table,
            cands,
            front,
            budget_pcts=budget_pcts,
            extra={"dataset": bt.dataset, "tier": bt.tier},
        )
        reports[bt.name] = report
        if fit == "vmap":
            vmap_exact &= _check_vmap_exact(bt.table)
            # one trace per (kind, n, ε-config) is the ceiling; ε-configs
            # of one (kind, n) share a trace because ε is traced
            grid = pareto.candidate_grid(len(bt.table))
            fit_trace_budget += len(
                {(s.kind, s.params().get("eps")) for s in grid if s.kind in tune.VMAP_KINDS}
            ) + len(_VMAP_EXACT_SPECS)
        for c in front:
            emit(
                f"pareto/{bt.name}/{c.spec.display_name()}",
                c.ns_per_query / 1e3,
                f"space={c.space_bytes}B;pct={c.space_pct_of(len(bt.table)):.4f}",
            )
    traces = {f"{k}/{b}": v for (k, b), v in sorted(ix.trace_counts().items())}
    out = {
        "reports": reports,
        "trace_counts": traces,
        "total_traces": sum(traces.values()),
    }
    if fit == "vmap":
        fit_traces = {k: v for k, v in traces.items() if k.startswith("fit:")}
        out["fit"] = {
            "vmap_exact": int(vmap_exact),
            "fit_traces": fit_traces,
            "fit_traces_total": sum(fit_traces.values()),
            "fit_trace_budget": fit_trace_budget,
        }
        emit("fit/vmap_exact", float(int(vmap_exact)), "1.0 == scan fits bit-exact")
    return out


def check(out: dict) -> list:
    """Frontier-sanity gate; returns a list of failure strings."""
    fails = []
    for name, rep in out["reports"].items():
        front = rep["frontier"]
        if not front:
            fails.append(f"{name}: empty frontier")
            continue
        spaces = [c["space_bytes"] for c in front]
        times = [c["ns_per_query"] for c in front]
        if spaces != sorted(spaces) or len(set(spaces)) != len(spaces):
            fails.append(f"{name}: frontier space not strictly increasing: {spaces}")
        if any(times[i] <= times[i + 1] for i in range(len(times) - 1)):
            fails.append(f"{name}: frontier latency not strictly decreasing: {times}")
        inexact = [c["kind"] for c in rep["candidates"] if not c["exact"]]
        if inexact:
            fails.append(f"{name}: inexact candidates {inexact}")
        for pct, pick in rep["budget_picks"].items():
            budget = float(pct) / 100.0 * rep["table_bytes"]
            if pick["space_bytes"] > budget:
                fails.append(
                    f"{name}: pick {pick['kind']} at {pct}% is {pick['space_bytes']}B "
                    f"> budget {budget:.0f}B"
                )
    if "fit" in out:
        f = out["fit"]
        if f["vmap_exact"] != 1:
            fails.append("fit/vmap_exact != 1: scan fits diverged from the host builds")
        if f["fit_traces_total"] > f["fit_trace_budget"]:
            fails.append(
                f"fit-trace budget exceeded: {f['fit_traces_total']} > "
                f"{f['fit_trace_budget']} (one trace per (kind, n, ε-config))"
            )
        if not f["fit_traces"]:
            fails.append("fit=vmap produced no fit traces: the scan fits did not run")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiers", default="L1", help="comma-separated tier names")
    ap.add_argument("--datasets", default="amzn64,osm")
    ap.add_argument("--queries", type=int, default=4096)
    ap.add_argument("--backend", default="xla")
    ap.add_argument("--budgets", default=",".join(str(p) for p in BUDGET_PCTS))
    ap.add_argument("--fit", default="auto", choices=("auto", "host", "vmap"),
                    help="batched-build fit mode; 'vmap' adds the scan-fit exactness gate")
    ap.add_argument("--json", default=None, help="write the JSON report here")
    ap.add_argument("--check", action="store_true", help="fail on frontier-sanity violations")
    args = ap.parse_args()
    out = run(
        tiers=tuple(t for t in args.tiers.split(",") if t),
        datasets=tuple(d for d in args.datasets.split(",") if d),
        n_queries=args.queries,
        backend=args.backend,
        budget_pcts=tuple(float(p) for p in args.budgets.split(",") if p),
        fit=args.fit,
    )
    text = json.dumps(out, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    print(text)
    if args.check:
        fails = check(out)
        if fails:
            for f in fails:
                print(f"FRONTIER GATE: {f}", file=sys.stderr)
            sys.exit(1)
        print(f"frontier gate: OK ({len(out['reports'])} reports)")


if __name__ == "__main__":
    main()
