"""Time-space Pareto frontier of every registered index kind.

The paper's core claim — space, not accuracy, is the key to learned
index efficiency — as one artifact: the registry-derived candidate grid
(:func:`repro.tune.pareto.candidate_grid`) is built through the batched
builder, each candidate is measured (model bytes, jit-timed lookup
latency through the shared query path), and the non-dominated frontier
plus the bi-criteria budget picks are emitted as a JSON report per
(dataset, tier)::

    REPRO_BENCH_SCALE=0.01 PYTHONPATH=src \
        python -m benchmarks.pareto_frontier --json pareto_frontier.json

``--check`` turns the report into a CI gate: every frontier must be
non-empty and monotone (space strictly increasing, latency strictly
decreasing along it), every candidate exact, and every budget pick's
built ``space_bytes`` within its budget.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro import index as ix
from repro.tune import pareto

from .common import bench_tables, emit

BUDGET_PCTS = (0.7, 2.0, 10.0)


def run(
    tiers=("L1",),
    datasets=("amzn64", "osm"),
    n_queries: int = 4096,
    backend: str = "xla",
    budget_pcts=BUDGET_PCTS,
):
    ix.reset_trace_counts()
    reports = {}
    for bt in bench_tables():
        if bt.tier not in tiers or bt.dataset not in datasets:
            continue
        cands = pareto.sweep(
            bt.table, n_queries=n_queries, backend=backend, check_exact=True
        )
        front = pareto.pareto_frontier(cands)
        report = pareto.frontier_report(
            bt.table,
            cands,
            front,
            budget_pcts=budget_pcts,
            extra={"dataset": bt.dataset, "tier": bt.tier},
        )
        reports[bt.name] = report
        for c in front:
            emit(
                f"pareto/{bt.name}/{c.spec.display_name()}",
                c.ns_per_query / 1e3,
                f"space={c.space_bytes}B;pct={c.space_pct_of(len(bt.table)):.4f}",
            )
    traces = {f"{k}/{b}": v for (k, b), v in sorted(ix.trace_counts().items())}
    return {
        "reports": reports,
        "trace_counts": traces,
        "total_traces": sum(traces.values()),
    }


def check(out: dict) -> list:
    """Frontier-sanity gate; returns a list of failure strings."""
    fails = []
    for name, rep in out["reports"].items():
        front = rep["frontier"]
        if not front:
            fails.append(f"{name}: empty frontier")
            continue
        spaces = [c["space_bytes"] for c in front]
        times = [c["ns_per_query"] for c in front]
        if spaces != sorted(spaces) or len(set(spaces)) != len(spaces):
            fails.append(f"{name}: frontier space not strictly increasing: {spaces}")
        if any(times[i] <= times[i + 1] for i in range(len(times) - 1)):
            fails.append(f"{name}: frontier latency not strictly decreasing: {times}")
        inexact = [c["kind"] for c in rep["candidates"] if not c["exact"]]
        if inexact:
            fails.append(f"{name}: inexact candidates {inexact}")
        for pct, pick in rep["budget_picks"].items():
            budget = float(pct) / 100.0 * rep["table_bytes"]
            if pick["space_bytes"] > budget:
                fails.append(
                    f"{name}: pick {pick['kind']} at {pct}% is {pick['space_bytes']}B "
                    f"> budget {budget:.0f}B"
                )
    return fails


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiers", default="L1", help="comma-separated tier names")
    ap.add_argument("--datasets", default="amzn64,osm")
    ap.add_argument("--queries", type=int, default=4096)
    ap.add_argument("--backend", default="xla")
    ap.add_argument("--budgets", default=",".join(str(p) for p in BUDGET_PCTS))
    ap.add_argument("--json", default=None, help="write the JSON report here")
    ap.add_argument("--check", action="store_true", help="fail on frontier-sanity violations")
    args = ap.parse_args()
    out = run(
        tiers=tuple(t for t in args.tiers.split(",") if t),
        datasets=tuple(d for d in args.datasets.split(",") if d),
        n_queries=args.queries,
        backend=args.backend,
        budget_pcts=tuple(float(p) for p in args.budgets.split(",") if p),
    )
    text = json.dumps(out, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
    print(text)
    if args.check:
        fails = check(out)
        if fails:
            for f in fails:
                print(f"FRONTIER GATE: {f}", file=sys.stderr)
            sys.exit(1)
        print(f"frontier gate: OK ({len(out['reports'])} reports)")


if __name__ == "__main__":
    main()
