"""Benchmark harness entry: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``
prints ``name,us_per_call,derived`` CSV lines (common.emit).

``--trend`` switches to the artifact pipeline: the six JSON-artifact
benchmarks run at the CI bench-smoke configuration (smoke scale, the
same flags ``.github/workflows/ci.yml`` passes), artifacts land in
``--artifacts-dir``, and each is immediately diffed against the
committed baselines by :mod:`benchmarks.trend` — one command reproduces
the whole CI bench gate locally, ending with a one-line PASS summary
per artifact (checked-metric count + worst latency ratio)::

    PYTHONPATH=src python -m benchmarks.run --trend
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path

#: the CI bench-smoke configuration (keep in sync with ci.yml bench-smoke)
SMOKE_ENV = {"REPRO_BENCH_SCALE": "0.01", "REPRO_BENCH_QUERIES": "4096"}
SMOKE_SHARDED = dict(n=8192, n_queries=4096)
SMOKE_PARETO = dict(tiers=("L1",), datasets=("osm",), n_queries=2048, fit="vmap")
SMOKE_TRAIN = dict(n=8192, datasets=("osm",), queries=4096)


def run_suites(only: str | None) -> None:
    from . import (
        kernel_roofline,
        pareto_frontier,
        query_constant,
        query_parametric,
        sy_rmi_mining,
        synoptic,
        training_time,
    )

    suites = [
        ("training_time", training_time.run),  # paper Tables 2-5
        ("query_constant", query_constant.run),  # paper Figs 5-6
        ("query_parametric", query_parametric.run),  # paper Figs 7-8
        ("sy_rmi_mining", sy_rmi_mining.run),  # paper Fig 4
        ("synoptic", synoptic.run),  # paper supp Table 6
        ("kernel_roofline", kernel_roofline.run),  # TPU kernel terms
        ("pareto_frontier", pareto_frontier.run),  # bi-criteria tuner frontier
    ]
    for name, fn in suites:
        if only and only not in name:
            continue
        t0 = time.perf_counter()
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness going; report the failure
            print(f"# {name} FAILED: {e!r}", file=sys.stderr, flush=True)
        print(f"# === {name} done in {time.perf_counter() - t0:.1f}s ===", flush=True)


def run_trend(artifacts_dir: Path, baselines: Path, tolerance: float) -> int:
    """Generate the six JSON artifacts at smoke scale, then diff each
    against the committed baselines.  Returns the number of failures."""
    # common.py reads SCALE/N_QUERIES from the environment at import
    # time, so pin the smoke config BEFORE any benchmark module import
    # (explicit flags win: only setdefault here)
    for k, v in SMOKE_ENV.items():
        os.environ.setdefault(k, v)

    from . import (
        kernel_roofline,
        pareto_frontier,
        serve_slo,
        sharded_lookup,
        training_time,
        trend,
        write_workload,
    )

    artifacts_dir.mkdir(parents=True, exist_ok=True)
    fails: list = []
    produced: list = []

    def produce(name: str, make) -> None:
        t0 = time.perf_counter()
        print(f"# === {name} (smoke artifact) ===", flush=True)
        try:
            report = make()
        except Exception as e:
            fails.append(f"{name}: benchmark failed before producing an artifact ({e!r})")
            print(f"# {name} FAILED: {e!r}", file=sys.stderr, flush=True)
            return
        path = artifacts_dir / f"{name}.json"
        path.write_text(json.dumps(report, indent=2) + "\n")
        fresh = trend.check_artifact(path, baselines, tolerance)
        fails.extend(fresh)
        produced.append((name, path, len(fresh)))
        status = "OK" if not fresh else f"{len(fresh)} trend failure(s)"
        print(
            f"# === {name} done in {time.perf_counter() - t0:.1f}s -> {path} [{status}] ===",
            flush=True,
        )

    produce("sharded_lookup", lambda: sharded_lookup.run(**SMOKE_SHARDED))

    def _pareto():
        report = pareto_frontier.run(**SMOKE_PARETO)
        # same sanity gates the CI --check flag applies (frontier
        # non-empty/monotone, exact candidates, budget picks in budget)
        fails.extend(f"pareto_frontier: {f}" for f in pareto_frontier.check(report))
        return report

    produce("pareto_frontier", _pareto)
    produce("training_time", lambda: training_time.run(**SMOKE_TRAIN))
    produce("kernel_roofline", kernel_roofline.run)
    produce("write_workload", write_workload.run)

    def _slo():
        # also export the registry snapshot CI uploads next to the artifact
        report = serve_slo.run(jsonl=str(artifacts_dir / "serve_slo_snapshot.jsonl"))
        # the absolute SLO gates (drop-rate ceiling, sane quantiles,
        # exactness); the baseline diff is produce()'s trend check
        fails.extend(f"serve_slo: {f}" for f in serve_slo.check_slo(report))
        return report

    produce("serve_slo", _slo)

    for f in fails:
        print(f"BENCH TREND: {f}", file=sys.stderr)
    for name, path, n_fail in produced:
        if n_fail:
            print(f"# {name}: FAIL ({n_fail} problem(s))", flush=True)
            continue
        n, ratio, where = trend.summarize_artifact(path, baselines)
        print(f"# {name}: PASS ({n} metrics checked, max latency ratio {ratio:.2f}x @ {where})", flush=True)
    if fails:
        print(f"bench-trend: FAILED ({len(fails)} problem(s))", file=sys.stderr)
    else:
        print(f"bench-trend: OK ({len(produced)} artifacts vs {baselines})")
    return len(fails)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on benchmark module")
    ap.add_argument(
        "--trend",
        action="store_true",
        help="generate the JSON artifacts at CI smoke scale and diff them "
        "against the committed baselines (benchmarks/trend.py)",
    )
    ap.add_argument(
        "--artifacts-dir",
        default="bench_artifacts",
        help="where --trend writes the fresh JSON artifacts",
    )
    ap.add_argument(
        "--baselines",
        default="benchmarks/baselines",
        help="committed baseline directory for --trend",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=8.0,
        help="latency ratio allowed either way in --trend mode",
    )
    args = ap.parse_args()

    if args.trend:
        if args.only:
            ap.error("--only and --trend are mutually exclusive")
        sys.exit(1 if run_trend(Path(args.artifacts_dir), Path(args.baselines), args.tolerance) else 0)
    run_suites(args.only)


if __name__ == "__main__":
    main()
