"""Benchmark harness entry: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``
prints ``name,us_per_call,derived`` CSV lines (common.emit).
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter on benchmark module")
    args = ap.parse_args()

    from . import (
        kernel_roofline,
        pareto_frontier,
        query_constant,
        query_parametric,
        sy_rmi_mining,
        synoptic,
        training_time,
    )

    suites = [
        ("training_time", training_time.run),  # paper Tables 2-5
        ("query_constant", query_constant.run),  # paper Figs 5-6
        ("query_parametric", query_parametric.run),  # paper Figs 7-8
        ("sy_rmi_mining", sy_rmi_mining.run),  # paper Fig 4
        ("synoptic", synoptic.run),  # paper supp Table 6
        ("kernel_roofline", kernel_roofline.run),  # TPU kernel terms
        ("pareto_frontier", pareto_frontier.run),  # bi-criteria tuner frontier
    ]
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception as e:  # keep the harness going; report the failure
            print(f"# {name} FAILED: {e!r}", file=sys.stderr, flush=True)
        print(f"# === {name} done in {time.perf_counter() - t0:.1f}s ===", flush=True)


if __name__ == "__main__":
    main()
