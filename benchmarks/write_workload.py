"""Write-workload benchmark: the GAPPED ingest path end to end.

Measures the mutation surface the updatable kind exposes (absorb ->
overflow -> compact -> retune, docs/architecture.md): build cost of the
gapped layout, absorb and overflow throughput of ``insert_batch``,
``compact()`` cost, the read amplification a populated delta buffer
adds to lookups, and the ``TunedTier`` drift path (which must absorb
device-side with ZERO shard refreshes / restacks / re-tunes).

Gates (enforced by benchmarks/trend.py against the committed baseline):

* ``write/exact`` — post-insert and post-compact answers bit-match
  ``searchsorted`` on the merged keyset (must stay 1.0);
* ``write/compiles`` + trace counts — the insert/compact paths keep the
  one-trace-per-(kind, op, pow2-bucket) invariant (exact);
* everything else — generous latency-ratio trend.

``python -m benchmarks.write_workload [--json OUT]`` prints the usual
``name,us,derived`` CSV; ``--json`` also writes the trend artifact.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax.numpy as jnp

import repro  # noqa: F401
from repro import index as ix
from repro.core.cdf import true_ranks
from repro.data import distributions, tables
from repro.tune.rebuild import RebuildPolicy, TunedTier

from .common import N_QUERIES, SCALE, emit as _emit, time_fn

_METRICS: dict = {}

#: one pow2 insert bucket for every single-index leg (pins trace counts)
BATCH = 2048


def emit(name: str, value: float, derived: str = ""):
    _METRICS[name] = float(value)
    _emit(name, value, derived)


def _gap_midpoints(table: np.ndarray) -> np.ndarray:
    """Fresh candidate keys that follow the TABLE's density: midpoints
    of adjacent-key gaps.  Uniform-in-keyspace drift would land almost
    entirely in the distribution's sparse regions — a handful of leaves
    — and the all-or-nothing absorb would divert every batch to the
    delta, measuring only the overflow path."""
    gaps = table[1:] - table[:-1]
    return (table[:-1] + gaps // np.uint64(2))[gaps >= 2]


def _fresh_keys(rng, table: np.ndarray, n: int) -> np.ndarray:
    """Exactly n sorted fresh keys spread across the whole table."""
    cand = _gap_midpoints(table)
    assert len(cand) >= n, "table too dense for the drift batch"
    return np.sort(rng.choice(cand, n, replace=False))


def run():
    _METRICS.clear()
    ix.reset_trace_counts()
    rng = np.random.default_rng(23)
    n = max(1 << 14, int((1 << 18) * SCALE))
    table = distributions.generate("osm", n, seed=11)
    spec = dict(leaf_cap=64, fill=0.75, delta_cap=4096)

    # ---- build: the gapped layout vs a plain static build ----------------
    dt = time_fn(lambda: ix.build(ix.GappedSpec(**spec), table))
    emit("write/build_us", dt * 1e6, f"n={n}")
    g0 = ix.build(ix.GappedSpec(**spec), table)

    # ---- absorb throughput: inserts into a gappy index -------------------
    batch = _fresh_keys(rng, table, BATCH)
    dt = time_fn(lambda: g0.insert_batch(batch))  # pure: same start state each rep
    g1, rep = g0.insert_batch(batch)
    emit(
        "write/absorb_keys_per_s",
        BATCH / dt,
        f"absorbed={rep.absorbed};overflowed={rep.overflowed}",
    )
    assert rep.absorbed + rep.overflowed == BATCH and rep.duplicates == 0

    # ---- overflow throughput: inserts into a zero-gap index --------------
    full = ix.build(ix.GappedSpec(leaf_cap=64, fill=1.0, delta_cap=4096), table)
    dt = time_fn(lambda: full.insert_batch(batch))
    _, rep_f = full.insert_batch(batch)
    emit("write/overflow_keys_per_s", BATCH / dt, f"overflowed={rep_f.overflowed}")
    assert rep_f.overflowed == BATCH, "fill=1.0 leaves must divert wholesale"

    # ---- read amplification of a populated delta -------------------------
    # a clustered batch — TWO interior points per low-end gap — loads
    # the first few leaves past their gap budget, so the all-or-nothing
    # absorb diverts it to the delta: the state whose two-tier read
    # path and compact() cost we want to measure
    lo = table[: BATCH + 1]
    lg = lo[1:] - lo[:-1]
    clustered = np.unique(
        np.concatenate(
            [(lo[:-1] + lg // np.uint64(4))[lg >= 4], (lo[:-1] + lg - lg // np.uint64(4))[lg >= 4]]
        )
    )[:BATCH]
    assert len(clustered) == BATCH, "low-end gaps too narrow for the clustered batch"
    gd, rep_d = g0.insert_batch(clustered)
    assert rep_d.delta_count > BATCH // 2, "clustered batch should mostly overflow"
    merged_d = np.union1d(table, clustered)
    queries = tables.make_queries(merged_d, N_QUERIES, seed=13)
    want_d = true_ranks(merged_d, queries)
    tj, qj = jnp.asarray(table), jnp.asarray(queries)
    fresh_d = ix.build(ix.GappedSpec(**spec), merged_d)
    dt_fresh = time_fn(lambda: fresh_d.lookup(tj, qj))
    emit("write/lookup_fresh_us_per_q", dt_fresh / N_QUERIES * 1e6, f"nq={N_QUERIES}")
    dt_post = time_fn(lambda: gd.lookup(tj, qj))
    emit(
        "write/lookup_post_insert_us_per_q",
        dt_post / N_QUERIES * 1e6,
        f"delta_count={rep_d.delta_count}",
    )
    emit("write/read_amp", dt_post / dt_fresh, "post-insert / fresh-build lookup")

    # ---- compact: fold the delta back into rebalanced leaves -------------
    dt = time_fn(lambda: gd.compact())  # pure: same start state each rep
    gc = gd.compact()
    emit("write/compact_us", dt * 1e6, f"drained={rep_d.delta_count}")

    # ---- exactness gate: every claimed backend, all three states ---------
    merged_1 = np.union1d(table, batch)
    q1 = tables.make_queries(merged_1, N_QUERIES, seed=17)
    want_1 = true_ranks(merged_1, q1)
    exact = True
    for state, qs, want in ((g1, jnp.asarray(q1), want_1), (gd, qj, want_d), (gc, qj, want_d)):
        for be in state.backends():
            got = np.asarray(state.lookup(tj, qs, backend=be))
            exact &= bool((got == want).all())
    emit("write/exact", float(exact), "post-insert + post-compact vs searchsorted")

    # ---- TunedTier drift: absorb device-side, zero rebuilds --------------
    tier = TunedTier(
        table,
        n_shards=4,
        policy=RebuildPolicy(backend="xla"),
        spec=ix.GappedSpec(**spec),
    )
    drift = _fresh_keys(rng, table, BATCH)
    t0 = time.perf_counter()
    tier.insert_batch(drift)  # InsertReport readback syncs the device
    dt = time.perf_counter() - t0
    c = tier.counters
    assert c.absorbed + c.overflowed == BATCH
    emit("write/tier_ingest_keys_per_s", BATCH / dt, f"absorbed={c.absorbed}")
    emit(
        "write/tier_rebuilds",
        float(c.shard_refreshes + c.forced_restacks + c.retunes),
        "must stay 0: GAPPED absorbs without rebuilding",
    )

    traces = {f"{k}/{b}": v for (k, b), v in sorted(ix.trace_counts().items())}
    emit("write/compiles", float(sum(traces.values())), "total traces (exact gate)")
    return {
        "metrics": dict(_METRICS),
        "trace_counts": traces,
        "total_traces": sum(traces.values()),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write metrics + trace counts as JSON")
    args = ap.parse_args()
    report = run()
    if args.json:
        with open(args.json, "w") as f:
            f.write(json.dumps(report, indent=2) + "\n")


if __name__ == "__main__":
    main()
