"""Kernel-level roofline terms for the Pallas kernels (TPU v5e targets).

Wall-clock on this CPU container is meaningless for the TPU kernels, so
per DESIGN.md §7 each kernel's analytic HBM/VMEM traffic and FLOPs are
derived from its BlockSpec tiling and reported as v5e roofline seconds,
alongside the measured XLA-path wall time (the production fallback) for
a like-for-like functional check.

The fused PGM / RadixSpline kernels and the batched (table, q_tile)
RMI / PGM / RS kernels get the same treatment, plus a small-table
exactness + trace-count smoke: the ``kernel/compiles`` row reports how
many times the shared pallas lookup traced across the sweep, and the CI
bench gate fails when it exceeds the budget (a per-model-retrace
regression).

``--json PATH`` additionally writes the emitted metrics + trace counts
as a JSON artifact (the ``bench-trend`` baseline format)::

    PYTHONPATH=src python -m benchmarks.kernel_roofline --json out.json
"""

from __future__ import annotations

import argparse
import json
import math

import numpy as np
import jax
import jax.numpy as jnp

from repro import index as ix
from repro import tune
from repro.core import as_table, search, true_ranks
from repro.core.rmi import build_rmi
from repro.kernels import ops

from .common import emit as _emit, time_fn

PEAK_FLOPS = 197e12
HBM_BW = 819e9

_METRICS: dict = {}


def emit(name: str, value: float, derived: str = ""):
    _METRICS[name] = float(value)
    _emit(name, value, derived)


def run():
    _METRICS.clear()
    rng = np.random.default_rng(3)
    n = 1 << 20
    table = as_table(rng.integers(0, 2**64 - 1, size=int(n * 1.2), dtype=np.uint64))[:n]
    nq = 65536
    qs = rng.choice(table, nq).astype(np.uint64)

    # ---- fused RMI search ----
    m = build_rmi(table, b=4096)
    _, ksteps_rmi = ops.rmi_kernel_arrays(m, table)
    # traffic per query: u(4) + q limbs(8) + leaf params(3 gathers ~24B)
    # + window gathers: steps x 8B limb pairs + result(4)
    traffic = nq * (4 + 8 + 24 + ksteps_rmi * 8 + 4)
    t_mem = traffic / HBM_BW
    emit(
        "kernel/rmi_search/v5e_mem_bound",
        t_mem / nq * 1e6,
        f"steps={ksteps_rmi};bytes/q={traffic / nq:.0f}",
    )
    xla = jax.jit(lambda t, q: m.predecessor(t, q))
    dt = time_fn(xla, jnp.asarray(table), jnp.asarray(qs))
    emit("kernel/rmi_search/xla_cpu", dt / nq * 1e6, "functional fallback")

    # ---- lane-wide k-ary ----
    steps = max(1, math.ceil(math.log(n, 128)))
    traffic = nq * (8 + steps * 128 * 8 + 4)
    emit("kernel/kary128/v5e_mem_bound", traffic / HBM_BW / nq * 1e6, f"steps={steps}")
    xla = jax.jit(lambda t, q: search.kbfs(t, q, k=128))
    dt = time_fn(xla, jnp.asarray(table), jnp.asarray(qs))
    emit("kernel/kary128/xla_cpu", dt / nq * 1e6, "")

    # binary-search baseline traffic: ceil(log2 n) dependent 8B gathers
    steps_b = math.ceil(math.log2(n))
    emit(
        "kernel/bfs_baseline/v5e_mem_bound",
        nq * (8 + steps_b * 8 + 4) / HBM_BW / nq * 1e6,
        f"steps={steps_b}",
    )

    # ---- fused PGM descent ----
    pgm = ix.build(ix.PGMSpec(eps=64), table)
    levels = pgm.s("levels")
    psteps = pgm.s("pksteps")
    # per query: u(4) + q limbs(8) + per level (u0+slope+r0/r1 gathers
    # ~20B + window-search limb gathers) + final window + result(4)
    traffic = nq * (4 + 8 + levels * (20 + psteps * 8) + psteps * 8 + 4)
    emit(
        "kernel/pgm_search/v5e_mem_bound",
        traffic / HBM_BW / nq * 1e6,
        f"levels={levels};steps={psteps};bytes/q={traffic / nq:.0f}",
    )
    xla = jax.jit(lambda t, q: pgm.lookup(t, q))
    emit(
        "kernel/pgm_search/xla_cpu",
        time_fn(xla, jnp.asarray(table), jnp.asarray(qs)) / nq * 1e6,
        "functional fallback",
    )

    # ---- fused RadixSpline lookup ----
    rs = ix.build(ix.RSSpec(eps=64, r_bits=12), table)
    ksteps = rs.s("ksteps")
    rsteps = rs.s("rk_epi")
    # per query: u(4) + prefix(4) + q limbs(8) + radix gather(16) +
    # knot search + knot params (y1/u0/slope ~12B) + window + result(4)
    traffic = nq * (4 + 4 + 8 + 16 + ksteps * 8 + 12 + rsteps * 8 + 4)
    emit(
        "kernel/rs_search/v5e_mem_bound",
        traffic / HBM_BW / nq * 1e6,
        f"ksteps={ksteps};steps={rsteps};bytes/q={traffic / nq:.0f}",
    )
    xla = jax.jit(lambda t, q: rs.lookup(t, q))
    emit(
        "kernel/rs_search/xla_cpu",
        time_fn(xla, jnp.asarray(table), jnp.asarray(qs)) / nq * 1e6,
        "functional fallback",
    )

    # ---- batched fused RMI (tier of tables, grid over (table, q_tile)) ----
    n_tables = 8
    n_loc = n // n_tables
    parts = [np.sort(rng.choice(table, n_loc, replace=False)) for _ in range(n_tables)]
    bm = tune.build_many(ix.RMISpec(b=4096 // n_tables), [as_table(p) for p in parts])
    bsteps = bm.index.s("ksteps")
    # per (table, query): same shape as the single-table fused RMI row;
    # the batch amortises the table/param residency across q tiles
    traffic = n_tables * nq * (4 + 8 + 24 + bsteps * 8 + 4)
    emit(
        "kernel/rmi_search_batched/v5e_mem_bound",
        traffic / HBM_BW / (n_tables * nq) * 1e6,
        f"tables={n_tables};steps={bsteps};bytes/q={traffic / (n_tables * nq):.0f}",
    )
    xla_b = jax.jit(lambda q: bm.lookup(q))
    dt = time_fn(xla_b, jnp.asarray(qs))
    emit("kernel/rmi_search_batched/xla_cpu", dt / (n_tables * nq) * 1e6, "functional fallback")

    # ---- batched fused PGM descent (tier of tables) ----
    bpgm = tune.build_many(ix.PGMSpec(eps=64), [as_table(p) for p in parts], fit="vmap")
    blv = bpgm.index.s("levels")
    bps = bpgm.index.s("pksteps")
    traffic = n_tables * nq * (4 + 8 + blv * (20 + bps * 8) + bps * 8 + 4)
    emit(
        "kernel/pgm_search_batched/v5e_mem_bound",
        traffic / HBM_BW / (n_tables * nq) * 1e6,
        f"tables={n_tables};levels={blv};steps={bps};bytes/q={traffic / (n_tables * nq):.0f}",
    )
    xla_bp = jax.jit(lambda q: bpgm.lookup(q))
    dt = time_fn(xla_bp, jnp.asarray(qs))
    emit("kernel/pgm_search_batched/xla_cpu", dt / (n_tables * nq) * 1e6, "functional fallback")

    # ---- batched fused RadixSpline (tier of tables) ----
    brs = tune.build_many(ix.RSSpec(eps=64, r_bits=12), [as_table(p) for p in parts], fit="vmap")
    bks = brs.index.s("ksteps")
    brr = brs.index.s("rk_epi")
    traffic = n_tables * nq * (4 + 4 + 8 + 16 + bks * 8 + 12 + brr * 8 + 4)
    emit(
        "kernel/rs_search_batched/v5e_mem_bound",
        traffic / HBM_BW / (n_tables * nq) * 1e6,
        f"tables={n_tables};ksteps={bks};steps={brr};bytes/q={traffic / (n_tables * nq):.0f}",
    )
    xla_br = jax.jit(lambda q: brs.lookup(q))
    dt = time_fn(xla_br, jnp.asarray(qs))
    emit("kernel/rs_search_batched/xla_cpu", dt / (n_tables * nq) * 1e6, "functional fallback")

    # ---- pallas exactness + trace-count smoke (small tables) ----
    ix.reset_trace_counts()
    small = table[:: max(1, n // 8192)]
    sq = rng.choice(small, 2048).astype(np.uint64)
    want = true_ranks(small, sq)
    exact = True
    for spec in (ix.RMISpec(b=256), ix.PGMSpec(eps=32), ix.RSSpec(eps=32, r_bits=10)):
        m = ix.build(spec, small)
        got = np.asarray(m.lookup(jnp.asarray(small), jnp.asarray(sq), backend="pallas"))
        got2 = np.asarray(m.lookup(jnp.asarray(small), jnp.asarray(sq), backend="pallas"))
        exact &= bool(np.array_equal(got, want) and np.array_equal(got2, want))
    sparts = [
        as_table(np.sort(rng.choice(small, len(small) // 4, replace=False))) for _ in range(4)
    ]
    # every family with a batched fused kernel answers its batch in ONE
    # pallas_call: fused RMI, fused PGM descent, fused RadixSpline
    for spec in (ix.RMISpec(b=64), ix.PGMSpec(eps=32), ix.RSSpec(eps=32, r_bits=10)):
        bsm = tune.build_many(spec, sparts)
        outs = np.asarray(bsm.lookup(sq, backend="pallas"))
        for i, p in enumerate(sparts):
            exact &= bool(np.array_equal(outs[i], true_ranks(p, sq)))
    traces = sum(ix.trace_counts().values())
    per_kind = {}
    for (k, _), v in sorted(ix.trace_counts().items()):
        per_kind[k] = per_kind.get(k, 0) + v
    emit("kernel/pallas_smoke/exact", float(exact), "1.0 == bit-exact")
    # one shared trace per (kind, backend) + one batched trace per
    # family: a per-model retrace would multiply this by the model count
    emit("kernel/compiles", traces, f"per_kind={per_kind}")

    # ---- embedding bag ----
    v, d, items, bags = 4096, 128, 8192, 1024
    table_f = rng.normal(size=(v, d)).astype(np.float32)
    ids = rng.integers(0, v, items).astype(np.int32)
    seg = np.sort(rng.integers(0, bags, items)).astype(np.int32)
    w = rng.normal(size=items).astype(np.float32)
    flops = 2.0 * items * v * d / 512 * 512  # one-hot matmuls dominate
    t_cmp = (2.0 * items * v + 2.0 * bags * items * d) / PEAK_FLOPS
    t_memb = (v * d * 4 + items * (4 + 4 + 4) + bags * d * 4) / HBM_BW
    emit(
        "kernel/embedding_bag/v5e_bound",
        max(t_cmp, t_memb) * 1e6,
        f"dominant={'compute' if t_cmp > t_memb else 'memory'}",
    )
    from repro.kernels import ref

    xla = jax.jit(lambda t, i, s, ww: ref.embedding_bag_ref(t, i, s, ww, bags))
    dt = time_fn(xla, jnp.asarray(table_f), jnp.asarray(ids), jnp.asarray(seg), jnp.asarray(w))
    emit("kernel/embedding_bag/xla_cpu", dt * 1e6, f"items={items}")

    # ---- flash decode ----
    b, hq, hkv, dh, s = 8, 32, 8, 128, 32768
    flops = 2.0 * b * hq * s * dh * 2
    bytes_ = b * s * hkv * dh * 2 * 2  # stream K and V once (bf16)
    t_cmp = flops / PEAK_FLOPS
    t_memd = bytes_ / HBM_BW
    emit(
        "kernel/decode_attention/v5e_bound",
        max(t_cmp, t_memd) * 1e6,
        f"dominant={'memory' if t_memd > t_cmp else 'compute'};arith_int={flops / bytes_:.2f}",
    )

    smoke_traces = {f"{k}/{b}": v for (k, b), v in sorted(ix.trace_counts().items())}
    return {
        "metrics": dict(_METRICS),
        "trace_counts": smoke_traces,
        "total_traces": sum(smoke_traces.values()),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write metrics + trace counts as JSON")
    args = ap.parse_args()
    report = run()
    if args.json:
        with open(args.json, "w") as f:
            f.write(json.dumps(report, indent=2) + "\n")


if __name__ == "__main__":
    main()
